//! Hostile-client coverage for the multiplexed server (DESIGN.md §15).
//!
//! The thread-per-connection baseline paid for isolation with a thread
//! per peer; the multiplexed server must provide the same isolation from
//! shared worker threads.  These tests pin the three load-bearing
//! guarantees: a fleet of slow-loris peers parked mid-frame cannot
//! starve an honest client (and is reaped on the `io_timeout_ms` stall
//! clock), dials past `[net].max_conns` are refused with a typed
//! [`ErrorCode::TooManyConnections`] answer before close (so a polite
//! client can back off and re-dial instead of guessing at a reset), and
//! one stalled peer sharing a worker with an honest client adds at most
//! a poll tick — not a timeout — to the honest client's round trip.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use dorm::app::CheckpointStore;
use dorm::config::{ClusterConfig, DormConfig, NetConfig};
use dorm::master::DormMaster;
use dorm::net::{serve, ControlPlane, ServerHandle, TcpTransport};
use dorm::proto::{wire, ErrorCode, Request, Response, PROTO_MAJOR, PROTO_MINOR};
use dorm::resources::Res;

fn store(tag: &str) -> CheckpointStore {
    let dir = std::env::temp_dir().join(format!("dorm_hostile_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    CheckpointStore::new(dir).unwrap()
}

fn serve_master(tag: &str, cfg: &NetConfig) -> ServerHandle {
    let m = DormMaster::new(
        &ClusterConfig::uniform(2, Res::cpu_gpu_ram(12.0, 0.0, 64.0)),
        DormConfig { theta1: 0.5, theta2: 0.5 },
        store(tag),
    );
    serve(m, cfg).unwrap()
}

/// Raw frame client; writes are best-effort because a rejected or reaped
/// connection may already be closing under us.
struct Raw {
    stream: TcpStream,
}

impl Raw {
    fn connect(handle: &ServerHandle) -> Raw {
        let stream = TcpStream::connect(handle.addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        Raw { stream }
    }

    fn send_payload(&mut self, payload: &[u8]) {
        let _ = wire::write_frame(&mut self.stream, payload, usize::MAX);
    }

    fn recv(&mut self) -> Result<Response, wire::WireError> {
        let payload = wire::read_frame(&mut self.stream, 1 << 20)?;
        wire::decode_response(&payload)
    }

    fn hello(&mut self) {
        self.send_payload(&wire::encode_request(&Request::Hello {
            major: PROTO_MAJOR,
            minor: PROTO_MINOR,
        }));
        match self.recv().unwrap() {
            Response::HelloAck { .. } => {}
            other => panic!("handshake answered {other:?}"),
        }
    }

    /// Park mid-frame: promise a body and never deliver it.
    fn stall_mid_frame(&mut self) {
        let _ = self.stream.write_all(&100u32.to_be_bytes());
        let _ = self.stream.write_all(&[1, 2, 3]);
    }

    /// The server closed our connection (EOF / reset) within `deadline`.
    fn assert_closed(mut self, deadline: Duration) {
        self.stream.set_read_timeout(Some(deadline)).unwrap();
        let mut buf = [0u8; 1];
        match self.stream.read(&mut buf) {
            Ok(0) => {}
            Ok(_) => panic!("server kept talking on a connection it should close"),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                panic!("server left the stalled connection open past the deadline")
            }
            Err(_) => {}
        }
    }
}

/// A dozen slow-loris peers park mid-frame on the shared worker pool; an
/// honest client's requests must still be answered while they sit there,
/// and every loris is reaped on the stall clock rather than held forever.
#[test]
fn slow_loris_fleet_cannot_starve_honest_clients() {
    let cfg = NetConfig {
        bind_addr: "127.0.0.1:0".into(),
        io_timeout_ms: 1500,
        ..NetConfig::default()
    };
    let handle = serve_master("loris", &cfg);

    let mut fleet: Vec<Raw> = (0..12)
        .map(|_| {
            let mut raw = Raw::connect(&handle);
            raw.hello();
            raw.stall_mid_frame();
            raw
        })
        .collect();

    // while the fleet holds its half-frames, honest round trips proceed
    let mut ctl = TcpTransport::connect(&handle.addr().to_string(), &cfg).unwrap();
    for _ in 0..10 {
        match ctl.call(Request::QueryState { app: None }).unwrap() {
            Response::State(v) => assert_eq!(v.total_servers, 2),
            other => panic!("query under loris load answered {other:?}"),
        }
    }

    // the stall clock reaps every loris; none outlives io_timeout_ms by
    // more than the test's generous scheduling margin
    for raw in fleet.drain(..) {
        raw.assert_closed(Duration::from_secs(10));
    }

    // and the seats they held are free again for honest dials
    drop(TcpTransport::connect(&handle.addr().to_string(), &cfg).unwrap());
    handle.stop();
}

/// Dialing past `[net].max_conns` is answered with a typed
/// `TooManyConnections` error and a close — and the seat count is live:
/// hanging up one held connection frees a seat for the next dial.
#[test]
fn connection_limit_rejects_with_typed_error_and_frees_seats() {
    let cfg = NetConfig {
        bind_addr: "127.0.0.1:0".into(),
        io_timeout_ms: 5000,
        max_conns: 2,
        ..NetConfig::default()
    };
    let handle = serve_master("limit", &cfg);

    let mut held1 = Raw::connect(&handle);
    held1.hello();
    let mut held2 = Raw::connect(&handle);
    held2.hello();

    // the third dial is told why it was refused, before the close
    let mut third = Raw::connect(&handle);
    match third.recv().unwrap() {
        Response::Error(e) => {
            assert_eq!(e.code, ErrorCode::TooManyConnections);
            assert!(e.detail.contains("max_conns"), "detail names the knob: {}", e.detail);
        }
        other => panic!("over-limit dial answered {other:?}"),
    }
    third.assert_closed(Duration::from_secs(5));

    // hang up one seat; the server must notice the EOF and admit a new
    // dial within the poll cadence
    drop(held1);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let mut retry = Raw::connect(&handle);
        retry.send_payload(&wire::encode_request(&Request::Hello {
            major: PROTO_MAJOR,
            minor: PROTO_MINOR,
        }));
        match retry.recv() {
            Ok(Response::HelloAck { .. }) => break,
            Ok(Response::Error(e)) if e.code == ErrorCode::TooManyConnections => {
                assert!(Instant::now() < deadline, "released seat never became dialable");
                std::thread::sleep(Duration::from_millis(50));
            }
            other => panic!("re-dial answered {other:?}"),
        }
    }
    handle.stop();
}

/// One stalled peer sharing the *same worker* as an honest client must
/// not couple its stall into the honest client's latency: the honest
/// round trip costs at most a poll tick extra, never a timeout.  Pinned
/// with workers = 1 so the two connections are guaranteed neighbours.
#[test]
fn stalled_client_adds_at_most_a_poll_tick_to_neighbours() {
    let cfg = NetConfig {
        bind_addr: "127.0.0.1:0".into(),
        // long stall clock: the loris stays parked for the whole
        // measurement window, so reaping never rescues the bad design
        io_timeout_ms: 30_000,
        workers: 1,
        ..NetConfig::default()
    };
    let handle = serve_master("neighbour", &cfg);

    let mut loris = Raw::connect(&handle);
    loris.hello();
    loris.stall_mid_frame();

    let mut ctl = TcpTransport::connect(&handle.addr().to_string(), &cfg).unwrap();
    let mut rtts: Vec<Duration> = Vec::new();
    for _ in 0..10 {
        let t0 = Instant::now();
        match ctl.call(Request::QueryState { app: None }).unwrap() {
            Response::State(_) => {}
            other => panic!("query next to a stalled peer answered {other:?}"),
        }
        rtts.push(t0.elapsed());
    }
    rtts.sort();
    let median = rtts[rtts.len() / 2];
    // one poll tick is <= 16 ms; 250 ms leaves a fat margin for a busy
    // CI box while still catching any design that parks the worker on
    // the stalled peer's io_timeout (30 s here)
    assert!(
        median < Duration::from_millis(250),
        "median honest round trip {median:?} — the stalled neighbour is coupling its \
         stall into other clients"
    );
    handle.stop();
}

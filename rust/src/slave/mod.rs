//! DormSlave: the per-server agent (§III-A-2).
//!
//! Reports local capacity to the master and owns the container lifecycle
//! on its server.  In the paper a container is a Docker cgroup; here it is
//! a resource-accounted execution slot (DESIGN.md §1) — the slave enforces
//! its server's capacity independently of the master's bookkeeping
//! (double-entry: a buggy master decision is caught at the slave).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::app::AppId;
use crate::resources::Res;

/// Unique container identifier (slave-local counter + slave name).
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ContainerId {
    pub slave: String,
    pub serial: u64,
}

/// A live container: a resource bundle bound to one application.
#[derive(Clone, Debug)]
pub struct Container {
    pub id: ContainerId,
    pub app: AppId,
    pub demand: Res,
}

/// One heartbeat's payload (§III-A-2): what a slave would ship to the
/// master each reporting period over a networked transport (ROADMAP open
/// item).  The in-process master needs only the heartbeat's *arrival* —
/// `DormMaster::heartbeat` renews the liveness lease without
/// materializing a report — so today this type is the wire-format
/// scaffolding, not a consumed message.
#[derive(Clone, Debug, PartialEq)]
pub struct SlaveReport {
    pub name: String,
    pub capacity: Res,
    pub available: Res,
    /// Containers per app currently hosted (the slave's xᵢⱼ column).
    pub containers: BTreeMap<AppId, u32>,
}

/// The per-server agent.
#[derive(Clone, Debug)]
pub struct DormSlave {
    pub name: String,
    capacity: Res,
    containers: Vec<Container>,
    next_serial: u64,
}

impl DormSlave {
    pub fn new(name: impl Into<String>, capacity: Res) -> Self {
        DormSlave {
            name: name.into(),
            capacity,
            containers: Vec::new(),
            next_serial: 0,
        }
    }

    /// §III-A-2: report available resources to the master.
    pub fn available(&self) -> Res {
        let used = self.used();
        self.capacity.saturating_sub(&used)
    }

    pub fn used(&self) -> Res {
        self.containers
            .iter()
            .fold(Res::zeros(self.capacity.m()), |mut acc, c| {
                acc += &c.demand;
                acc
            })
    }

    pub fn capacity(&self) -> &Res {
        &self.capacity
    }

    /// Adopt a new capacity vector (control-plane capacity event: the
    /// slave is authoritative about its own hardware).  The resource
    /// dimensionality is fixed for the cluster's lifetime; shrinking
    /// below current usage is allowed — the master re-solves and the
    /// overcommit drains as containers are destroyed.
    pub fn set_capacity(&mut self, capacity: Res) -> Result<()> {
        if capacity.m() != self.capacity.m() {
            bail!(
                "slave {}: capacity has {} resource types, cluster uses {}",
                self.name,
                capacity.m(),
                self.capacity.m()
            );
        }
        self.capacity = capacity;
        Ok(())
    }

    /// Create `count` containers for `app`; all-or-nothing.
    pub fn create(&mut self, app: AppId, demand: &Res, count: u32) -> Result<Vec<ContainerId>> {
        let need = demand.times(count);
        let used = self.used();
        if !(used.clone() + need).fits_in(&self.capacity) {
            bail!(
                "slave {}: cannot create {count} x {demand} (used {used}, cap {})",
                self.name,
                self.capacity
            );
        }
        let mut ids = Vec::with_capacity(count as usize);
        for _ in 0..count {
            self.next_serial += 1;
            let id = ContainerId { slave: self.name.clone(), serial: self.next_serial };
            self.containers.push(Container {
                id: id.clone(),
                app,
                demand: demand.clone(),
            });
            ids.push(id);
        }
        Ok(ids)
    }

    /// Destroy `count` containers of `app`; all-or-nothing.
    pub fn destroy(&mut self, app: AppId, count: u32) -> Result<()> {
        let have = self.count_for(app);
        if have < count {
            bail!("slave {}: {app} has {have} containers, asked to destroy {count}", self.name);
        }
        let mut left = count;
        self.containers.retain(|c| {
            if left > 0 && c.app == app {
                left -= 1;
                false
            } else {
                true
            }
        });
        Ok(())
    }

    /// Destroy everything belonging to `app` (completion path).
    pub fn destroy_all(&mut self, app: AppId) -> u32 {
        let before = self.containers.len();
        self.containers.retain(|c| c.app != app);
        (before - self.containers.len()) as u32
    }

    pub fn count_for(&self, app: AppId) -> u32 {
        self.containers.iter().filter(|c| c.app == app).count() as u32
    }

    /// Containers grouped by `(app, demand)`, insertion-ordered — the
    /// serializable form of this slave's book (`crate::master::ha`).
    /// Grouping by demand too (not just app) keeps admin-created
    /// containers with non-spec demands faithful across a checkpoint
    /// restore; [`DormSlave::create`] rebuilds each group exactly.
    pub fn container_groups(&self) -> Vec<(AppId, Res, u32)> {
        let mut out: Vec<(AppId, Res, u32)> = Vec::new();
        for c in &self.containers {
            match out.iter_mut().find(|(a, d, _)| *a == c.app && *d == c.demand) {
                Some((_, _, n)) => *n += 1,
                None => out.push((c.app, c.demand.clone(), 1)),
            }
        }
        out
    }

    /// Containers per app (the xᵢⱼ column this slave holds).
    pub fn inventory(&self) -> BTreeMap<AppId, u32> {
        let mut out = BTreeMap::new();
        for c in &self.containers {
            *out.entry(c.app).or_insert(0) += 1;
        }
        out
    }

    /// Build the §III-A-2 heartbeat payload (see [`SlaveReport`] — wire
    /// scaffolding for a networked control plane; the in-process
    /// `DormMaster::heartbeat` renews the lease without one).
    pub fn report(&self) -> SlaveReport {
        SlaveReport {
            name: self.name.clone(),
            capacity: self.capacity.clone(),
            available: self.available(),
            containers: self.inventory(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slave() -> DormSlave {
        DormSlave::new("s0", Res::cpu_gpu_ram(12.0, 1.0, 128.0))
    }

    #[test]
    fn create_destroy_accounting() {
        let mut s = slave();
        let d = Res::cpu_gpu_ram(2.0, 0.0, 8.0);
        let ids = s.create(AppId(1), &d, 3).unwrap();
        assert_eq!(ids.len(), 3);
        assert_eq!(s.count_for(AppId(1)), 3);
        assert_eq!(s.available(), Res::cpu_gpu_ram(6.0, 1.0, 104.0));
        s.destroy(AppId(1), 2).unwrap();
        assert_eq!(s.count_for(AppId(1)), 1);
    }

    #[test]
    fn capacity_enforced_all_or_nothing() {
        let mut s = slave();
        let d = Res::cpu_gpu_ram(5.0, 0.0, 8.0);
        assert!(s.create(AppId(1), &d, 3).is_err(), "15 CPU > 12");
        assert_eq!(s.count_for(AppId(1)), 0, "no partial create");
        s.create(AppId(1), &d, 2).unwrap();
    }

    #[test]
    fn gpu_scarcity() {
        let mut s = slave();
        let d = Res::cpu_gpu_ram(1.0, 1.0, 8.0);
        s.create(AppId(1), &d, 1).unwrap();
        assert!(s.create(AppId(2), &d, 1).is_err(), "only 1 GPU");
    }

    #[test]
    fn destroy_all_and_inventory() {
        let mut s = slave();
        let d = Res::cpu_gpu_ram(1.0, 0.0, 4.0);
        s.create(AppId(1), &d, 2).unwrap();
        s.create(AppId(2), &d, 1).unwrap();
        let inv = s.inventory();
        assert_eq!(inv[&AppId(1)], 2);
        assert_eq!(inv[&AppId(2)], 1);
        assert_eq!(s.destroy_all(AppId(1)), 2);
        assert_eq!(s.count_for(AppId(1)), 0);
        assert_eq!(s.count_for(AppId(2)), 1);
    }

    #[test]
    fn container_ids_unique() {
        let mut s = slave();
        let d = Res::cpu_gpu_ram(1.0, 0.0, 4.0);
        let a = s.create(AppId(1), &d, 2).unwrap();
        s.destroy(AppId(1), 2).unwrap();
        let b = s.create(AppId(1), &d, 2).unwrap();
        assert!(a.iter().all(|id| !b.contains(id)));
    }

    #[test]
    fn heartbeat_report_reflects_books() {
        let mut s = slave();
        let d = Res::cpu_gpu_ram(2.0, 0.0, 8.0);
        s.create(AppId(3), &d, 2).unwrap();
        let r = s.report();
        assert_eq!(r.name, "s0");
        assert_eq!(r.capacity, Res::cpu_gpu_ram(12.0, 1.0, 128.0));
        assert_eq!(r.available, Res::cpu_gpu_ram(8.0, 1.0, 112.0));
        assert_eq!(r.containers[&AppId(3)], 2);
    }

    #[test]
    fn destroy_more_than_held_fails() {
        let mut s = slave();
        let d = Res::cpu_gpu_ram(1.0, 0.0, 4.0);
        s.create(AppId(1), &d, 1).unwrap();
        assert!(s.destroy(AppId(1), 2).is_err());
        assert_eq!(s.count_for(AppId(1)), 1);
    }
}

//! Discrete-event simulator of the paper's testbed experiments (§V).
//!
//! The 24-hour evaluations (Figs 6–9) are functions of the allocator and
//! the workload, not of the hardware (DESIGN.md §1), so they run here in
//! simulated time: the same [`crate::sched::AllocationEngine`] the live
//! master uses makes every decision (pinned by `tests/parity.rs`), the
//! same [`crate::cluster::ClusterState`] bookkeeping tracks placements,
//! and the same [`crate::metrics`] series are sampled.
//!
//! * [`engine`] — the event queue (time-ordered heap with cancellation).
//! * [`perf_model`] — iterative-training progress: speedup vs container
//!   count, checkpoint/kill/resume pauses.
//! * [`runner`] — drives a [`CmsPolicy`] over a workload and collects
//!   [`crate::metrics::RunMetrics`]; policies are Dorm (θ-configured) and
//!   the baselines in [`crate::baselines`].

pub mod dorm_policy;
pub mod engine;
pub mod experiment;
pub mod perf_model;
pub mod runner;

pub use dorm_policy::DormPolicy;
pub use engine::{EventQueue, SimTime};
pub use experiment::{fairness_reduction, headline_over_seeds, matched_speedups, mean_speedup, speedup_by_tag, utilization_ratio, Experiment, SystemRun};
pub use perf_model::PerfModel;
pub use runner::{
    run_sim, run_sim_faulty, run_sim_stream, run_sim_stream_traced, ArrivalSource, SimApp,
    SimArrival, SimOutcome, SliceSource,
};
// The policy interface moved to the shared scheduling core; re-exported
// here so simulation-facing callers keep one import path.
pub use crate::sched::{AllocationUpdate, CmsPolicy, SchedApp, SchedCtx};

/// Former name of the policy snapshot, kept for downstream code: the sim
/// and the live master now hand policies the same [`SchedCtx`].
pub type SimCtx<'a> = SchedCtx<'a>;

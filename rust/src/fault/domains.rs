//! Failure domains: a two-level server topology (rack → power domain) and
//! an online MTBF estimator over it.
//!
//! The paper's evaluation (§V) assumes independent server failures, but
//! real clusters lose whole racks and power domains at once — precisely
//! when checkpoint-driven recovery matters most.  This module gives the
//! rest of the crate one vocabulary for that correlation:
//!
//! * [`DomainTopology`] — maps every server ordinate into a rack, and
//!   every rack into a power domain.  Built either from a
//!   `[fault.domains]` config section (contiguous racks of `domain_size`
//!   servers) or derived from registered slave names like `rack1-a`
//!   (the prefix before the last `-` is the rack).
//! * [`MtbfEstimator`] — observed failures/repairs per server and per
//!   rack, updated online from heartbeat lease expiries and
//!   `FailServer`/`RecoverServer` events on the live master, and from
//!   `ServerFail`/`ServerRecover` events in the DES.  Its per-rack
//!   failure-rate estimates ([`MtbfEstimator::rack_risks`]) feed the
//!   risk-aware placement tie-break
//!   ([`crate::cluster::SpreadCtx`]) and the cell-routing penalty
//!   ([`crate::sched::CellScheduler`]).

/// Two-level failure-domain map: server → rack → power domain.
#[derive(Clone, Debug, PartialEq)]
pub struct DomainTopology {
    /// Rack index per server ordinate.
    rack_of: Vec<usize>,
    /// Power-domain index per rack.
    power_of_rack: Vec<usize>,
    /// Rack display names (config/derived; synthesized for grouped maps).
    rack_names: Vec<String>,
}

impl DomainTopology {
    /// Contiguous racks of `domain_size` servers; every `racks_per_power`
    /// consecutive racks share a power domain.  `domain_size == 0` is
    /// treated as 1 (each server its own rack).
    pub fn grouped(n_servers: usize, domain_size: usize, racks_per_power: usize) -> Self {
        let size = domain_size.max(1);
        let rpp = racks_per_power.max(1);
        let rack_of: Vec<usize> = (0..n_servers).map(|j| j / size).collect();
        let n_racks = rack_of.last().map(|&r| r + 1).unwrap_or(0);
        DomainTopology {
            rack_of,
            power_of_rack: (0..n_racks).map(|r| r / rpp).collect(),
            rack_names: (0..n_racks).map(|r| format!("rack{r}")).collect(),
        }
    }

    /// Derive racks from slave names: the prefix before the *last* `-` is
    /// the rack (`rack1-a` and `rack1-b` share `rack1`); a name without a
    /// `-` is its own rack.  Racks are numbered in first-appearance order
    /// and grouped into power domains `racks_per_power` at a time.
    pub fn from_names<S: AsRef<str>>(names: &[S], racks_per_power: usize) -> Self {
        let rpp = racks_per_power.max(1);
        let mut rack_names: Vec<String> = Vec::new();
        let mut rack_of = Vec::with_capacity(names.len());
        for name in names {
            let n = name.as_ref();
            let rack = n.rsplit_once('-').map(|(pre, _)| pre).unwrap_or(n);
            let idx = match rack_names.iter().position(|r| r == rack) {
                Some(i) => i,
                None => {
                    rack_names.push(rack.to_string());
                    rack_names.len() - 1
                }
            };
            rack_of.push(idx);
        }
        let n_racks = rack_names.len();
        DomainTopology {
            rack_of,
            power_of_rack: (0..n_racks).map(|r| r / rpp).collect(),
            rack_names,
        }
    }

    pub fn n_servers(&self) -> usize {
        self.rack_of.len()
    }

    pub fn n_racks(&self) -> usize {
        self.rack_names.len()
    }

    pub fn n_power_domains(&self) -> usize {
        self.power_of_rack.iter().map(|&p| p + 1).max().unwrap_or(0)
    }

    /// Rack index of server `j`.
    pub fn rack_of(&self, j: usize) -> usize {
        self.rack_of[j]
    }

    /// Power-domain index of server `j`.
    pub fn power_of_server(&self, j: usize) -> usize {
        self.power_of_rack[self.rack_of[j]]
    }

    pub fn rack_name(&self, r: usize) -> &str {
        &self.rack_names[r]
    }

    /// Server ordinates belonging to rack `r`.
    pub fn rack_members(&self, r: usize) -> Vec<usize> {
        (0..self.rack_of.len()).filter(|&j| self.rack_of[j] == r).collect()
    }

    /// The server → rack map as a slice (what [`crate::cluster::SpreadCtx`]
    /// consumes).
    pub fn rack_map(&self) -> &[usize] {
        &self.rack_of
    }
}

#[derive(Clone, Debug, Default)]
struct ServerObs {
    /// Down right now (as far as the observer knows).
    down: bool,
    /// Start of the current up/down stretch.
    since: f64,
    /// Accumulated observed up-time before `since`.
    up_hours: f64,
    failures: u32,
    repairs: u32,
}

/// Online per-server / per-rack MTBF estimation from observed failure and
/// repair events.  Time units are whatever the backend clock uses (hours
/// in the DES, the event counter on the live master) — the estimates are
/// rates *relative to that clock*, which is all the risk ranking needs.
#[derive(Clone, Debug)]
pub struct MtbfEstimator {
    topo: DomainTopology,
    server: Vec<ServerObs>,
    /// Failure events charged to each rack (every member death counts —
    /// a whole-rack outage of k servers is k observations of the rack
    /// being a bad place to live).
    rack_failures: Vec<u32>,
}

impl MtbfEstimator {
    /// All servers assumed up since time 0.
    pub fn new(topo: DomainTopology) -> Self {
        let n = topo.n_servers();
        let racks = topo.n_racks();
        MtbfEstimator {
            topo,
            server: vec![ServerObs::default(); n],
            rack_failures: vec![0; racks],
        }
    }

    pub fn topology(&self) -> &DomainTopology {
        &self.topo
    }

    /// Server `j` observed dead at `now` (lease expiry, `FailServer`,
    /// DES `ServerFail`).  Idempotent while already down.
    pub fn observe_failure(&mut self, j: usize, now: f64) {
        let Some(obs) = self.server.get_mut(j) else { return };
        if obs.down {
            return;
        }
        obs.up_hours += (now - obs.since).max(0.0);
        obs.down = true;
        obs.since = now;
        obs.failures += 1;
        self.rack_failures[self.topo.rack_of(j)] += 1;
    }

    /// Server `j` observed back at `now` (`RecoverServer`, re-register,
    /// DES `ServerRecover`).  Idempotent while already up.
    pub fn observe_repair(&mut self, j: usize, now: f64) {
        let Some(obs) = self.server.get_mut(j) else { return };
        if !obs.down {
            return;
        }
        obs.down = false;
        obs.since = now;
        obs.repairs += 1;
    }

    fn observed_up_hours(&self, j: usize, now: f64) -> f64 {
        let obs = &self.server[j];
        let tail = if obs.down { 0.0 } else { (now - obs.since).max(0.0) };
        obs.up_hours + tail
    }

    /// Observed per-server MTBF: up-time through `now` over failures seen.
    /// `None` until the first failure (no evidence yet).
    pub fn server_mtbf(&self, j: usize, now: f64) -> Option<f64> {
        let obs = self.server.get(j)?;
        (obs.failures > 0).then(|| self.observed_up_hours(j, now) / obs.failures as f64)
    }

    /// Observed per-rack MTBF: aggregate member up-time over failures
    /// charged to the rack.  `None` until the rack's first failure.
    pub fn rack_mtbf(&self, r: usize, now: f64) -> Option<f64> {
        if r >= self.rack_failures.len() || self.rack_failures[r] == 0 {
            return None;
        }
        let up: f64 = self
            .topo
            .rack_members(r)
            .iter()
            .map(|&j| self.observed_up_hours(j, now))
            .sum();
        Some(up / self.rack_failures[r] as f64)
    }

    /// Estimated failure rate of rack `r` (failures per observed member
    /// up-hour); 0 until evidence exists.  Higher = riskier.
    pub fn rack_risk(&self, r: usize, now: f64) -> f64 {
        match self.rack_mtbf(r, now) {
            Some(mtbf) if mtbf > 0.0 => 1.0 / mtbf,
            Some(_) => f64::MAX,
            None => 0.0,
        }
    }

    /// Per-rack risk vector (index = rack), the shape
    /// [`crate::cluster::SpreadCtx`] and the cell router consume.
    pub fn rack_risks(&self, now: f64) -> Vec<f64> {
        (0..self.topo.n_racks()).map(|r| self.rack_risk(r, now)).collect()
    }

    /// Per-rack risk ranked by observed failure *counts* (index = rack).
    /// Unlike [`MtbfEstimator::rack_risks`], this does not divide by
    /// observed up-time, so it is independent of the backend's clock units
    /// (simulated hours in the DES, the event counter on the live master)
    /// — all racks share the same observation window, so counts rank
    /// failure rates identically on both backends.  This is the vector
    /// [`crate::sched::DormPolicy`] feeds into placement, which is what
    /// keeps risk-aware master↔sim decisions byte-identical.
    pub fn rack_risks_by_count(&self) -> Vec<f64> {
        self.rack_failures.iter().map(|&c| c as f64).collect()
    }

    pub fn server_failures(&self, j: usize) -> u32 {
        self.server.get(j).map(|o| o.failures).unwrap_or(0)
    }

    pub fn rack_failure_count(&self, r: usize) -> u32 {
        self.rack_failures.get(r).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouped_topology_partitions_contiguously() {
        let t = DomainTopology::grouped(10, 4, 2);
        assert_eq!(t.n_servers(), 10);
        assert_eq!(t.n_racks(), 3);
        assert_eq!(t.rack_map(), &[0, 0, 0, 0, 1, 1, 1, 1, 2, 2]);
        assert_eq!(t.rack_members(1), vec![4, 5, 6, 7]);
        // racks 0,1 share power domain 0; rack 2 is alone in domain 1
        assert_eq!(t.power_of_server(0), 0);
        assert_eq!(t.power_of_server(7), 0);
        assert_eq!(t.power_of_server(9), 1);
        assert_eq!(t.n_power_domains(), 2);
        // degenerate sizes clamp instead of panicking
        assert_eq!(DomainTopology::grouped(3, 0, 0).n_racks(), 3);
        assert_eq!(DomainTopology::grouped(0, 4, 2).n_racks(), 0);
    }

    #[test]
    fn names_derive_racks_by_last_dash_prefix() {
        let names = ["rack1-a", "rack1-b", "rack2-a", "lonely", "rack1-c"];
        let t = DomainTopology::from_names(&names, 2);
        assert_eq!(t.n_racks(), 3);
        assert_eq!(t.rack_map(), &[0, 0, 1, 2, 0]);
        assert_eq!(t.rack_name(0), "rack1");
        assert_eq!(t.rack_name(1), "rack2");
        assert_eq!(t.rack_name(2), "lonely");
        // a multi-dash name splits on the LAST dash
        let t2 = DomainTopology::from_names(&["eu-west-a", "eu-west-b"], 1);
        assert_eq!(t2.n_racks(), 1);
        assert_eq!(t2.rack_name(0), "eu-west");
    }

    #[test]
    fn estimator_tracks_observed_mtbf_per_server_and_rack() {
        let t = DomainTopology::grouped(4, 2, 1);
        let mut e = MtbfEstimator::new(t);
        // nothing observed: no evidence, zero risk
        assert_eq!(e.server_mtbf(0, 10.0), None);
        assert_eq!(e.rack_risk(0, 10.0), 0.0);

        e.observe_failure(0, 2.0);
        e.observe_repair(0, 2.5);
        e.observe_failure(0, 4.5); // up 2.0 + 2.0 = 4.0 h over 2 failures
        assert_eq!(e.server_mtbf(0, 4.5), Some(2.0));
        assert_eq!(e.server_failures(0), 2);

        // rack 0 = servers {0,1}: member 1 contributes up-time, no failures
        let mtbf = e.rack_mtbf(0, 4.5).unwrap();
        assert!((mtbf - (4.0 + 4.5) / 2.0).abs() < 1e-9, "{mtbf}");
        assert!(e.rack_risk(0, 4.5) > 0.0);
        assert_eq!(e.rack_risk(1, 4.5), 0.0, "quiet rack stays zero-risk");
        let risks = e.rack_risks(4.5);
        assert_eq!(risks.len(), 2);
        assert!(risks[0] > risks[1]);
    }

    #[test]
    fn estimator_is_idempotent_under_double_events() {
        let t = DomainTopology::grouped(2, 1, 1);
        let mut e = MtbfEstimator::new(t);
        e.observe_failure(0, 1.0);
        e.observe_failure(0, 1.5); // already down: ignored
        assert_eq!(e.server_failures(0), 1);
        e.observe_repair(0, 2.0);
        e.observe_repair(0, 2.5); // already up: ignored
        e.observe_failure(0, 3.0);
        assert_eq!(e.server_failures(0), 2);
        // up-time: [0,1] + [2,3] = 2h over 2 failures
        assert_eq!(e.server_mtbf(0, 3.0), Some(1.0));
        // out-of-range servers are ignored, not a panic
        e.observe_failure(99, 1.0);
        e.observe_repair(99, 2.0);
    }
}

//! Fig. 8 reproduction: resource-adjustment overhead over 24 h.
//!
//! Paper headlines (§V-B-3): Dorm-2/Dorm-3 kill+resume at most 2 apps per
//! adjustment operation and affect ~80 / ~76 apps in total over 24 h; the
//! bound ⌈θ₂·|Aᵗ∩Aᵗ⁻¹|⌉ holds per operation.

#[path = "harness/mod.rs"]
mod harness;

use dorm::report;
use dorm::sim::Experiment;

fn main() {
    harness::banner("Fig. 8 — cumulative adjusted applications over 24 h");
    let exp = Experiment::paper(17);
    let runs = exp.run_all();

    let mut rows = Vec::new();
    for r in &runs {
        let batches = &r.metrics().adjustment_batch_sizes;
        let max_batch = batches.iter().copied().max().unwrap_or(0);
        rows.push(vec![
            r.label.clone(),
            format!("{:.0}", r.metrics().adjustments.last().unwrap_or(0.0)),
            format!("{}", batches.len()),
            format!("{max_batch}"),
        ]);
    }
    println!(
        "{}",
        report::table(
            &["system", "total adjusted apps", "adjust operations", "max apps/op"],
            &rows
        )
    );

    let d2 = &runs[2]; // dorm(t1=0.1,t2=0.2)
    let d3 = &runs[3]; // dorm(t1=0.1,t2=0.1)
    harness::paper_row(
        "Dorm-2 total adjusted apps in 24 h",
        "~80",
        &format!("{:.0}", d2.metrics().adjustments.last().unwrap_or(0.0)),
    );
    harness::paper_row(
        "Dorm-3 total adjusted apps in 24 h",
        "~76",
        &format!("{:.0}", d3.metrics().adjustments.last().unwrap_or(0.0)),
    );
    for d in [d2, d3] {
        let max_batch = d
            .metrics()
            .adjustment_batch_sizes
            .iter()
            .copied()
            .max()
            .unwrap_or(0);
        harness::paper_row(
            &format!("max apps killed+resumed per operation ({})", d.label),
            "<= 2",
            &format!("{max_batch}"),
        );
    }
    harness::paper_row(
        "Dorm-2 (θ₂=0.2) adjusts >= Dorm-3 (θ₂=0.1)",
        "yes",
        if d2.metrics().adjustments.last() >= d3.metrics().adjustments.last() {
            "yes"
        } else {
            "no"
        },
    );

    let series: Vec<(String, Vec<(f64, f64)>)> = runs
        .iter()
        .map(|r| (r.label.clone(), r.metrics().adjustments.resample(0.0, 24.0, 64)))
        .collect();
    let refs: Vec<(&str, &[(f64, f64)])> =
        series.iter().map(|(l, s)| (l.as_str(), s.as_slice())).collect();
    println!("\n{}", report::ascii_chart(&refs, 12, 64));
}

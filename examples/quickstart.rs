//! Quickstart: a two-application shared cluster in ~80 lines.
//!
//! Builds a 4-server cluster, submits an LR and an MF training job through
//! the DormMaster, lets the utilization–fairness optimizer partition the
//! cluster, trains both models through the AOT'd JAX/Pallas artifacts, and
//! survives a server failure via checkpoint-driven recovery.
//!
//! Runs with or without compute artifacts: when `artifacts/` is missing or
//! no PJRT backend is linked (the offline `vendor/xla-stub` build, e.g. in
//! CI), the control plane runs alone — resource management, adjustment and
//! failure recovery all still happen, just without real training.  Run
//! `make artifacts` first for the full experience.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dorm::app::{AppSpec, CheckpointStore, Engine};
use dorm::config::{ClusterConfig, DormConfig};
use dorm::master::DormMaster;
use dorm::resources::Res;
use dorm::runtime::{ComputeService, Manifest};

fn main() -> anyhow::Result<()> {
    dorm::util::logger::init();

    // 1. The compute substrate: PJRT CPU client + the AOT'd models —
    //    optional, so the quickstart also smokes the pure control plane.
    let compute = Manifest::load("artifacts").and_then(|manifest| {
        let service = ComputeService::start_filtered(&manifest, Some(&["lr", "mf"]))?;
        Ok((service, manifest))
    });

    // 2. A small cluster and a Dorm master (θ₁ = θ₂ = 0.2).
    let cluster = ClusterConfig::uniform(4, Res::cpu_gpu_ram(12.0, 0.0, 64.0));
    let store = CheckpointStore::new(std::env::temp_dir().join("dorm_quickstart"))?;
    let mut master =
        DormMaster::new(&cluster, DormConfig { theta1: 0.2, theta2: 0.2 }, store);
    let _service = match compute {
        Ok((service, manifest)) => {
            master = master.with_compute(service.handle(), manifest);
            Some(service)
        }
        Err(e) => {
            println!("(no compute service: {e:#}; running the control plane only)");
            None
        }
    };
    let has_compute = _service.is_some();

    // 3. Submit the paper's 6-tuples: (executor, d, w, n_max, n_min, cmd).
    let lr = master.submit(AppSpec {
        executor: Engine::MxNet,
        demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0),
        weight: 1,
        n_max: 16,
        n_min: 1,
        cmd: ["lr".into(), "lr".into()],
    })?;
    println!(
        "submitted {lr}: LR gets {} containers (alone in the cluster)",
        master.containers_of(lr)
    );

    let mf = master.submit(AppSpec {
        executor: Engine::TensorFlow,
        demand: Res::cpu_gpu_ram(2.0, 0.0, 6.0),
        weight: 2,
        n_max: 16,
        n_min: 1,
        cmd: ["mf".into(), "mf".into()],
    })?;
    println!(
        "submitted {mf}: optimizer re-partitioned -> LR {} / MF {} containers, \
         {} adjustment(s), cluster utilization {:.2}",
        master.containers_of(lr),
        master.containers_of(mf),
        master.total_adjustments,
        master.utilization()
    );

    // 4. Train both for a few BSP rounds (each container = 1 worker slot);
    //    without compute, progress is bookkeeping steps.
    for round in 1..=5 {
        if has_compute {
            let logs = master.train_round(5)?;
            print!("round {round}:");
            for (id, step, loss) in logs {
                print!("  {id} step {step} loss {loss:.4}");
            }
            println!();
        } else {
            master.advance_steps(lr, 5)?;
            master.advance_steps(mf, 5)?;
        }
    }

    // 5. Checkpoint, then kill a server: affected apps roll back to the
    //    checkpoint and resume at the scale the optimizer re-solves on the
    //    3 surviving servers (lease liveness + recovery, DESIGN.md §8).
    master.checkpoint_all()?;
    let victims = master.fail_server(0)?;
    println!(
        "server 0 died -> {} app(s) recovered; LR {} / MF {} containers, \
         lost work {:.0} steps, utilization {:.2}",
        victims.len(),
        master.containers_of(lr),
        master.containers_of(mf),
        master.recovery_log().total_lost_work(),
        master.utilization()
    );
    master.recover_server(0)?;
    println!("server 0 rejoined -> LR {} / MF {} containers",
        master.containers_of(lr), master.containers_of(mf));

    // 6. Completing LR frees its partition; MF scales up.
    master.complete(lr)?;
    println!(
        "completed {lr} -> MF rescaled to {} containers (utilization {:.2})",
        master.containers_of(mf),
        master.utilization()
    );
    master.complete(mf)?;
    println!("done");
    Ok(())
}

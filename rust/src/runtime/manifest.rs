//! `artifacts/manifest.kv` parsing — the contract between `aot.py` and the
//! Rust runtime (DESIGN.md §5).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::parse_kv_file;

/// Element type of a model input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype {other:?} in manifest"),
        }
    }
}

/// One model's metadata + artifact paths.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub n_params: usize,
    pub x_shape: Vec<usize>,
    pub x_dtype: Dtype,
    pub y_shape: Vec<usize>,
    pub y_dtype: Dtype,
    /// Free-form model hyperparameters (vocab, d_model, ...).
    pub meta: BTreeMap<String, String>,
    pub init_path: PathBuf,
    pub grad_path: PathBuf,
    pub apply_path: PathBuf,
}

impl ModelMeta {
    /// Per-worker batch size (first x dimension).
    pub fn batch(&self) -> usize {
        self.x_shape.first().copied().unwrap_or(1)
    }

    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.parse().ok())
    }
}

/// The parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelMeta>,
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    if s.is_empty() {
        return Ok(vec![]);
    }
    s.split('x')
        .map(|p| p.parse::<usize>().map_err(|_| anyhow!("bad shape {s:?}")))
        .collect()
}

impl Manifest {
    /// Load `dir/manifest.kv` and resolve artifact paths.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let kv = parse_kv_file(&dir.join("manifest.kv"))?;
        let names = kv
            .get("manifest.models")
            .ok_or_else(|| anyhow!("manifest.models missing"))?;
        let mut models = BTreeMap::new();
        for name in names.split(',').filter(|s| !s.is_empty()) {
            let pfx = format!("model.{name}");
            let get = |k: &str| -> Result<&String> {
                kv.get(&format!("{pfx}.{k}"))
                    .ok_or_else(|| anyhow!("{pfx}.{k} missing from manifest"))
            };
            let meta = kv
                .iter()
                .filter_map(|(k, v)| {
                    k.strip_prefix(&format!("{pfx}.meta."))
                        .map(|mk| (mk.to_string(), v.clone()))
                })
                .collect();
            let m = ModelMeta {
                name: name.to_string(),
                n_params: get("params")?.parse().context("params")?,
                x_shape: parse_shape(get("x.shape")?)?,
                x_dtype: Dtype::parse(get("x.dtype")?)?,
                y_shape: parse_shape(get("y.shape")?)?,
                y_dtype: Dtype::parse(get("y.dtype")?)?,
                meta,
                init_path: dir.join(get("artifact.init")?),
                grad_path: dir.join(get("artifact.grad")?),
                apply_path: dir.join(get("artifact.apply")?),
            };
            for p in [&m.init_path, &m.grad_path, &m.apply_path] {
                if !p.exists() {
                    bail!("artifact {} missing (run `make artifacts`)", p.display());
                }
            }
            models.insert(name.to_string(), m);
        }
        Ok(Manifest { dir, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest ({:?})",
                                   self.models.keys().collect::<Vec<_>>()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, extra: &str) {
        std::fs::create_dir_all(dir).unwrap();
        for f in ["toy_init.hlo.txt", "toy_grad.hlo.txt", "toy_apply.hlo.txt"] {
            std::fs::write(dir.join(f), "HloModule toy").unwrap();
        }
        std::fs::write(
            dir.join("manifest.kv"),
            format!(
                "manifest.models=toy\n\
                 model.toy.params=5\n\
                 model.toy.x.shape=8x4\n\
                 model.toy.x.dtype=f32\n\
                 model.toy.y.shape=8\n\
                 model.toy.y.dtype=f32\n\
                 model.toy.meta.d=4\n\
                 model.toy.artifact.init=toy_init.hlo.txt\n\
                 model.toy.artifact.grad=toy_grad.hlo.txt\n\
                 model.toy.artifact.apply=toy_apply.hlo.txt\n{extra}"
            ),
        )
        .unwrap();
    }

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("dorm_manifest_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn parses_toy_manifest() {
        let dir = tmp("ok");
        write_manifest(&dir, "");
        let m = Manifest::load(&dir).unwrap();
        let toy = m.model("toy").unwrap();
        assert_eq!(toy.n_params, 5);
        assert_eq!(toy.x_shape, vec![8, 4]);
        assert_eq!(toy.batch(), 8);
        assert_eq!(toy.x_dtype, Dtype::F32);
        assert_eq!(toy.meta_usize("d"), Some(4));
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn missing_artifact_fails() {
        let dir = tmp("missing");
        write_manifest(&dir, "");
        std::fs::remove_file(dir.join("toy_grad.hlo.txt")).unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        // integration: parse the actual artifacts/ directory when built
        let dir = Path::new("artifacts");
        if dir.join("manifest.kv").exists() {
            let m = Manifest::load(dir).unwrap();
            for name in ["lr", "mf", "tfm"] {
                let meta = m.model(name).unwrap();
                assert!(meta.n_params > 0);
            }
        }
    }
}

//! §II-C reproduction: per-task scheduling latency of task-level two-level
//! sharing (Mesos-like) vs Dorm's local task placement — plus the
//! allocation-engine incremental re-solve path (snapshot cache +
//! warm-started solves) that keeps Dorm's per-event decision cost low.
//!
//! Paper measurement: "in a 100-node Mesos cluster ... the average
//! scheduling latency per task is about 430 ms"; Dorm places tasks on the
//! local TaskExecutor (§III-D) with no central round-trip.

#[path = "harness/mod.rs"]
mod harness;

use std::collections::BTreeMap;

use dorm::app::AppId;
use dorm::baselines::tasklevel::{dorm_local_placement_ms, TaskLevelModel};
use dorm::config::DormConfig;
use dorm::optimizer::OptApp;
use dorm::report;
use dorm::resources::Res;
use dorm::sched::{AllocationEngine, EngineApp};
use dorm::util::Rng;
use dorm::workload::table2_rows;

/// A paper-scale snapshot: `napps` Table II apps, all pending.
fn paper_snapshot(napps: usize, rng: &mut Rng) -> Vec<EngineApp> {
    let rows = table2_rows();
    (0..napps)
        .map(|i| {
            // CPU-bound rows (LR/MF/CaffeNet) — 46 of the paper's 50 apps;
            // keeps Σ n_min within the 5-GPU testbed so one solve admits all
            let row = &rows[rng.below(3) as usize];
            EngineApp {
                opt: OptApp {
                    id: AppId(i as u64),
                    demand: row.demand.clone(),
                    weight: row.weight as f64,
                    n_min: row.n_min,
                    n_max: row.n_max,
                    prev: None,
                    current: BTreeMap::new(),
                },
                submit: i as f64,
            }
        })
        .collect()
}

fn paper_capacities() -> Vec<Res> {
    (0..20)
        .map(|i| Res::cpu_gpu_ram(12.0, if i < 5 { 1.0 } else { 0.0 }, 128.0))
        .collect()
}

/// The engine section: quantify the incremental re-solve paths.
fn engine_resolve_bench() {
    harness::banner("allocation engine — incremental re-solve (50 apps, 20 slaves)");
    let mut rng = Rng::new(11);
    let caps = paper_capacities();
    let pending = paper_snapshot(50, &mut rng);

    // cold: a fresh engine per event — what every event cost pre-refactor
    let (cold_mean, _, _) = harness::bench_micro(
        "engine.decide, cold (fresh engine per event)",
        2,
        20,
        || {
            let mut eng = AllocationEngine::new(DormConfig::DORM3);
            let _ = eng.decide(&pending, &caps);
        },
    );

    // cache: identical snapshot re-presented (unchanged-event fast path)
    let mut eng = AllocationEngine::new(DormConfig::DORM3);
    let first = eng.decide(&pending, &caps).expect("paper workload feasible");
    let (hit_mean, _, _) = harness::bench_micro(
        "engine.decide, unchanged snapshot (cache hit)",
        2,
        50,
        || {
            let _ = eng.decide(&pending, &caps);
        },
    );
    let again = eng.decide(&pending, &caps).expect("still feasible");
    assert!(again.stats.cache_hit, "identical snapshot must hit the cache");
    assert_eq!(
        again.counts, first.counts,
        "cache must not change solver outputs"
    );

    // warm re-solve: carried state + an alternating arrival, so every call
    // is a genuine re-solve seeded by the previous solution
    let carried: Vec<EngineApp> = pending
        .iter()
        .map(|e| {
            let held = first.counts.get(&e.opt.id).copied().unwrap_or(0);
            EngineApp {
                opt: OptApp {
                    prev: (held > 0).then_some(held),
                    current: first
                        .placement
                        .assignment
                        .get(&e.opt.id)
                        .cloned()
                        .unwrap_or_default(),
                    ..e.opt.clone()
                },
                submit: e.submit,
            }
        })
        .collect();
    let rows = table2_rows();
    let mut with_arrival = carried.clone();
    with_arrival.push(EngineApp {
        opt: OptApp {
            id: AppId(999),
            demand: rows[0].demand.clone(),
            weight: rows[0].weight as f64,
            n_min: rows[0].n_min,
            n_max: rows[0].n_max,
            prev: None,
            current: BTreeMap::new(),
        },
        submit: 999.0,
    });
    let mut flip = false;
    let (warm_mean, _, _) = harness::bench_micro(
        "engine.decide, warm re-solve (alternating arrival)",
        2,
        30,
        || {
            flip = !flip;
            let snap: &[EngineApp] = if flip { &with_arrival } else { &carried };
            let _ = eng.decide(snap, &caps);
        },
    );

    let stats = eng.stats();
    println!(
        "  engine stats: {} solves, {} cache hits, {} warm-started",
        stats.solves, stats.cache_hits, stats.warm_start_hits
    );
    assert!(stats.cache_hits >= 50, "cache path must serve unchanged snapshots");
    assert!(stats.warm_start_hits >= 1, "warm path must seed re-solves");
    harness::paper_row(
        "re-solve on unchanged snapshot vs cold solve",
        "full solve per event",
        &format!("{:.0}x faster (cache hit)", cold_mean / hit_mean.max(0.01)),
    );
    harness::paper_row(
        "warm-started re-solve vs cold solve",
        "n/a (new in this repo)",
        &format!("{:.2}x", cold_mean / warm_mean.max(0.01)),
    );
}

fn main() {
    engine_resolve_bench();

    harness::banner("§II-C — task-level scheduling latency vs cluster size");
    let mut rng = Rng::new(7);
    let sizes = [10usize, 25, 50, 75, 100, 150];
    let mut rows = Vec::new();
    let mut means = Vec::new();
    for &nodes in &sizes {
        let m = TaskLevelModel { nodes, ..Default::default() };
        let s = m.simulate(300, &mut rng);
        means.push((nodes as f64, s.mean_ms));
        rows.push(vec![
            format!("{nodes}"),
            format!("{:.2}", m.rho()),
            m.analytic_mean_ms()
                .map(|a| format!("{a:.0}"))
                .unwrap_or_else(|| "sat".into()),
            format!("{:.0}", s.mean_ms),
            format!("{:.0}", s.p50_ms),
            format!("{:.0}", s.p99_ms),
        ]);
    }
    println!(
        "{}",
        report::table(
            &["nodes", "offered load ρ", "M/M/1 (ms)", "mean (ms)", "p50", "p99"],
            &rows
        )
    );

    let hundred = means.iter().find(|(n, _)| *n == 100.0).unwrap().1;
    harness::paper_row(
        "mean scheduling latency per task, 100 nodes",
        "~430 ms",
        &format!("{hundred:.0} ms"),
    );
    harness::paper_row(
        "Dorm local task placement (§III-D)",
        "~0 (no petition)",
        &format!("{:.3} ms", dorm_local_placement_ms()),
    );
    harness::paper_row(
        "latency ratio (task-level / Dorm)",
        ">> 10^4",
        &format!("{:.0}x", hundred / dorm_local_placement_ms()),
    );

    println!("\nlatency vs cluster size:");
    println!("{}", report::ascii_chart(&[("mean ms", &means)], 10, 60));
}

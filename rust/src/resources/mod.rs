//! Resource algebra: the `m`-typed vectors underlying every equation in the
//! paper (§IV, Table I).
//!
//! A [`Res`] is a non-negative vector over the cluster's resource types.
//! The paper's testbed uses m = 3 (CPU cores, GPUs, RAM GB) — provided by
//! [`Res::cpu_gpu_ram`] — but everything here (and in [`crate::drf`] /
//! [`crate::solver`]) works for arbitrary `m`, which the property tests
//! exercise.

use std::fmt;
use std::ops::{Add, AddAssign, Index, Mul, Sub, SubAssign};

/// Names of the standard testbed resource dimensions.
pub const STD_KINDS: [&str; 3] = ["cpu", "gpu", "ram_gb"];

/// A non-negative resource vector (demand, capacity or usage).
#[derive(Clone, Debug, PartialEq)]
pub struct Res(pub Vec<f64>);

impl Res {
    /// Zero vector with `m` resource types.
    pub fn zeros(m: usize) -> Self {
        Res(vec![0.0; m])
    }

    /// The standard ⟨CPU, GPU, RAM-GB⟩ triple used by the paper's testbed.
    pub fn cpu_gpu_ram(cpu: f64, gpu: f64, ram_gb: f64) -> Self {
        Res(vec![cpu, gpu, ram_gb])
    }

    /// Number of resource types (the paper's `m`).
    pub fn m(&self) -> usize {
        self.0.len()
    }

    pub fn is_zero(&self) -> bool {
        self.0.iter().all(|&x| x == 0.0)
    }

    /// True iff every component of `self` fits within `cap`.
    pub fn fits_in(&self, cap: &Res) -> bool {
        debug_assert_eq!(self.m(), cap.m());
        self.0.iter().zip(&cap.0).all(|(d, c)| d <= &(c + 1e-9))
    }

    /// Component-wise max.
    pub fn max(&self, other: &Res) -> Res {
        debug_assert_eq!(self.m(), other.m());
        Res(self
            .0
            .iter()
            .zip(&other.0)
            .map(|(a, b)| a.max(*b))
            .collect())
    }

    /// Saturating subtraction (clamps at zero) — useful for "free capacity"
    /// bookkeeping where float dust must not go negative.
    pub fn saturating_sub(&self, other: &Res) -> Res {
        debug_assert_eq!(self.m(), other.m());
        Res(self
            .0
            .iter()
            .zip(&other.0)
            .map(|(a, b)| (a - b).max(0.0))
            .collect())
    }

    /// Dominant share of this demand against a capacity: the max over
    /// resource types of demand/capacity (zero-capacity types are skipped —
    /// a demand on a zero-capacity type never fits and is caught by
    /// `fits_in`). This is the DRF "dominant share" primitive (§IV-A-2).
    pub fn dominant_share(&self, cap: &Res) -> f64 {
        debug_assert_eq!(self.m(), cap.m());
        self.0
            .iter()
            .zip(&cap.0)
            .filter(|(_, c)| **c > 0.0)
            .map(|(d, c)| d / c)
            .fold(0.0, f64::max)
    }

    /// Index of the dominant resource (argmax of demand/capacity).
    pub fn dominant_kind(&self, cap: &Res) -> usize {
        let mut best = (0usize, -1.0f64);
        for (k, (d, c)) in self.0.iter().zip(&cap.0).enumerate() {
            if *c > 0.0 {
                let s = d / c;
                if s > best.1 {
                    best = (k, s);
                }
            }
        }
        best.0
    }

    /// Eq. (1) inner term: sum over types of usage/capacity ("sum of all m
    /// types of hardware resources' utilization"). Ranges in [0, m].
    pub fn utilization_sum(&self, cap: &Res) -> f64 {
        debug_assert_eq!(self.m(), cap.m());
        self.0
            .iter()
            .zip(&cap.0)
            .filter(|(_, c)| **c > 0.0)
            .map(|(u, c)| u / c)
            .sum()
    }

    /// Scale by an integer container count.
    pub fn times(&self, n: u32) -> Res {
        self.clone() * n as f64
    }
}

impl Add for Res {
    type Output = Res;
    fn add(self, rhs: Res) -> Res {
        debug_assert_eq!(self.m(), rhs.m());
        Res(self.0.iter().zip(&rhs.0).map(|(a, b)| a + b).collect())
    }
}

impl AddAssign<&Res> for Res {
    fn add_assign(&mut self, rhs: &Res) {
        debug_assert_eq!(self.m(), rhs.m());
        for (a, b) in self.0.iter_mut().zip(&rhs.0) {
            *a += b;
        }
    }
}

impl Sub for Res {
    type Output = Res;
    fn sub(self, rhs: Res) -> Res {
        debug_assert_eq!(self.m(), rhs.m());
        Res(self.0.iter().zip(&rhs.0).map(|(a, b)| a - b).collect())
    }
}

impl SubAssign<&Res> for Res {
    fn sub_assign(&mut self, rhs: &Res) {
        debug_assert_eq!(self.m(), rhs.m());
        for (a, b) in self.0.iter_mut().zip(&rhs.0) {
            *a -= b;
        }
    }
}

impl Mul<f64> for Res {
    type Output = Res;
    fn mul(self, k: f64) -> Res {
        Res(self.0.iter().map(|a| a * k).collect())
    }
}

impl Index<usize> for Res {
    type Output = f64;
    fn index(&self, i: usize) -> &f64 {
        &self.0[i]
    }
}

impl fmt::Display for Res {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "⟨")?;
        for (i, v) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            if self.m() == 3 {
                write!(f, "{} {}", v, STD_KINDS[i])?;
            } else {
                write!(f, "{v}")?;
            }
        }
        write!(f, "⟩")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn fits_and_arith() {
        let d = Res::cpu_gpu_ram(2.0, 1.0, 8.0);
        let c = Res::cpu_gpu_ram(12.0, 1.0, 64.0);
        assert!(d.fits_in(&c));
        assert!(!d.times(2).fits_in(&c)); // 2 GPUs > 1
        let free = c.clone().sub(d.clone());
        assert_eq!(free, Res::cpu_gpu_ram(10.0, 0.0, 56.0));
        assert!((d.clone() * 3.0)[0] - 6.0 < 1e-12);
    }

    #[test]
    fn dominant_share_matches_paper_example() {
        // demand ⟨2 CPU, 0 GPU, 8 GB⟩ on capacity ⟨240, 5, 2560⟩:
        // shares = (1/120, 0, 1/320) -> dominant = CPU.
        let d = Res::cpu_gpu_ram(2.0, 0.0, 8.0);
        let c = Res::cpu_gpu_ram(240.0, 5.0, 2560.0);
        assert!((d.dominant_share(&c) - 2.0 / 240.0).abs() < 1e-12);
        assert_eq!(d.dominant_kind(&c), 0);
        // with a GPU the GPU dominates: 1/5 > 4/240
        let d2 = Res::cpu_gpu_ram(4.0, 1.0, 32.0);
        assert_eq!(d2.dominant_kind(&c), 1);
        assert!((d2.dominant_share(&c) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn utilization_sum_bounds() {
        let c = Res::cpu_gpu_ram(10.0, 2.0, 100.0);
        assert_eq!(Res::zeros(3).utilization_sum(&c), 0.0);
        assert!((c.utilization_sum(&c) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn zero_capacity_types_are_skipped() {
        let d = Res(vec![1.0, 0.0]);
        let c = Res(vec![2.0, 0.0]);
        assert_eq!(d.dominant_share(&c), 0.5);
        assert_eq!(d.utilization_sum(&c), 0.5);
    }

    #[test]
    fn saturating_sub_clamps() {
        let a = Res(vec![1.0, 5.0]);
        let b = Res(vec![2.0, 3.0]);
        assert_eq!(a.saturating_sub(&b), Res(vec![0.0, 2.0]));
    }

    #[test]
    fn prop_dominant_share_scales_linearly() {
        prop::check(200, |rng| {
            let m = rng.range_u64(1, 5) as usize;
            let d = Res((0..m).map(|_| rng.range_f64(0.0, 10.0)).collect());
            let c = Res((0..m).map(|_| rng.range_f64(1.0, 100.0)).collect());
            let k = rng.range_u64(1, 9) as u32;
            prop::close(
                d.times(k).dominant_share(&c),
                d.dominant_share(&c) * k as f64,
                1e-9,
            )
        });
    }

    #[test]
    fn prop_fits_in_consistent_with_dominant_share() {
        prop::check(200, |rng| {
            let m = rng.range_u64(1, 4) as usize;
            let c = Res((0..m).map(|_| rng.range_f64(1.0, 50.0)).collect());
            let d = Res((0..m).map(|_| rng.range_f64(0.0, 60.0)).collect());
            let fits = d.fits_in(&c);
            let share = d.dominant_share(&c);
            if fits && share > 1.0 + 1e-9 {
                return Err(format!("fits but share {share} > 1"));
            }
            if !fits && share <= 1.0 - 1e-9 {
                return Err(format!("doesn't fit but share {share} <= 1"));
            }
            Ok(())
        });
    }
}

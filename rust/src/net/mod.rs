//! Control-plane transports (DESIGN.md §9).
//!
//! [`crate::proto`] defines *what* travels; this module defines *how*:
//!
//! * [`ControlPlane`] — the one-method client interface.  Everything that
//!   drives a master (harnesses, slave agents, the `dorm ctl` CLI, the
//!   parity tests) programs against this trait and cannot tell the
//!   transports apart — that indistinguishability is pinned by
//!   `tests/transport_parity.rs`.
//! * [`LocalTransport`] — direct dispatch into an owned
//!   [`DormMaster`]: zero-copy, no serialization, preserves the
//!   in-process semantics every pre-existing test runs under.
//! * [`TcpTransport`] — std-only TCP client: length-prefixed frames
//!   ([`crate::proto::wire`]), version handshake on connect, typed error
//!   responses end-to-end.
//! * [`FailoverTransport`] — TCP with a *candidate list*: re-dials the
//!   candidates on connection loss (riding out a standby takeover) and
//!   fences off deposed primaries by refusing any master whose epoch is
//!   lower than the highest one observed (DESIGN.md §11).
//! * [`serve`] ([`server`]) — the master side of TCP: the *multiplexed*
//!   server of DESIGN.md §15 (a worker pool owning non-blocking
//!   connections, per-connection frame reassembly, per-tick batch
//!   dispatch with coalesced heartbeats), plus per-connection handshake
//!   enforcement, arrival-time stamping, lease sweeping, and the serving
//!   epoch trailed on every response.  [`serve_legacy`] keeps the
//!   original thread-per-connection server as the measured baseline and
//!   parity reference, and [`loadgen`] is the closed-loop client fleet
//!   that `dorm bench rpc-throughput` and `benches/rpc_throughput.rs`
//!   both drive at the two of them.  [`SlaveAgent`] ([`agent`]) is the standalone
//!   slave event loop that heartbeats over any transport and applies the
//!   master's reconciliation directives to its local container book.
//! * [`run_standby`] ([`standby`]) — the `dorm master --standby` body:
//!   watch the primary with the same lease discipline slaves live under,
//!   and on expiry promote the checkpointed master state at `epoch + 1`.

#![deny(missing_docs)]

mod agent;
pub mod loadgen;
mod server;
mod standby;

use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

pub use agent::{HeartbeatOutcome, SlaveAgent};
pub use server::{serve, serve_legacy, ServerHandle};
pub use standby::{run_standby, StandbyOpts};

use crate::config::NetConfig;
use crate::master::DormMaster;
use crate::proto::{wire, Request, Response, PROTO_MAJOR, PROTO_MINOR};

/// A client view of the control plane: send one [`Request`], get one
/// [`Response`].  `Err` is reserved for *transport* failures (connection
/// lost, frame undecodable); every semantic failure arrives in-band as
/// [`Response::Error`] so both transports surface identical values.
pub trait ControlPlane {
    /// Send one request and block until its response arrives.  `Err`
    /// means the transport itself failed; a live master's refusals come
    /// back as `Ok(Response::Error(..))`.
    fn call(&mut self, req: Request) -> Result<Response>;

    /// The serving master's epoch (term) as last observed on this
    /// transport, if it reported one.  Callers ([`SlaveAgent`],
    /// [`FailoverTransport`], `dorm ctl`) compare it against the highest
    /// epoch they have ever seen to fence off a deposed primary
    /// (DESIGN.md §11).  `None` = the peer predates epochs (proto v1.0).
    fn last_epoch(&self) -> Option<u64> {
        None
    }
}

/// Direct dispatch into an owned master — the zero-cost transport the
/// in-process tests and simulator harnesses use.
pub struct LocalTransport {
    master: DormMaster,
}

impl LocalTransport {
    /// Wrap an owned master so it can be driven through [`ControlPlane`].
    pub fn new(master: DormMaster) -> Self {
        LocalTransport { master }
    }

    /// Inspect the wrapped master without dispatching.
    pub fn master(&self) -> &DormMaster {
        &self.master
    }

    /// Mutate the wrapped master directly (test scaffolding).
    pub fn master_mut(&mut self) -> &mut DormMaster {
        &mut self.master
    }

    /// Unwrap, handing the master back to the caller.
    pub fn into_master(self) -> DormMaster {
        self.master
    }
}

impl ControlPlane for LocalTransport {
    fn call(&mut self, req: Request) -> Result<Response> {
        Ok(self.master.dispatch(req))
    }

    fn last_epoch(&self) -> Option<u64> {
        Some(self.master.epoch())
    }
}

/// Std-only TCP client: length-prefixed frames plus the version handshake
/// (connect fails with the peer's typed rejection on a version mismatch).
pub struct TcpTransport {
    stream: TcpStream,
    max_frame: usize,
    /// Epoch the peer stamped on its last response (`None` until the
    /// handshake completes or for an epoch-less v1.0 peer).
    peer_epoch: Option<u64>,
}

impl TcpTransport {
    /// Connect and handshake.  `cfg` supplies the frame-size limit and IO
    /// timeout (`io_timeout_ms = 0` blocks forever).  The handshake
    /// records the master's epoch ([`TcpTransport::last_epoch`]).
    pub fn connect(addr: &str, cfg: &NetConfig) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Self::handshake(stream, addr, cfg)
    }

    /// As [`TcpTransport::connect`], but the TCP connect itself is bounded
    /// by `timeout`.  `TcpStream::connect` only returns fast when the peer
    /// actively refuses (the `kill -9` case); a powered-off host or a
    /// blackholed network leaves it in SYN retries for minutes, which
    /// would stall a standby's death detection or a client's candidate
    /// walk far past any configured lease.
    pub fn connect_with_timeout(addr: &str, cfg: &NetConfig, timeout: Duration) -> Result<Self> {
        use std::net::ToSocketAddrs;
        let mut last: Option<std::io::Error> = None;
        let mut stream = None;
        for sa in addr.to_socket_addrs().with_context(|| format!("resolve {addr}"))? {
            match TcpStream::connect_timeout(&sa, timeout) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => last = Some(e),
            }
        }
        let Some(stream) = stream else {
            let e = last.unwrap_or_else(|| {
                std::io::Error::new(std::io::ErrorKind::AddrNotAvailable, "no addresses resolved")
            });
            return Err(anyhow::Error::new(e).context(format!("connect {addr}")));
        };
        Self::handshake(stream, addr, cfg)
    }

    fn handshake(stream: TcpStream, addr: &str, cfg: &NetConfig) -> Result<Self> {
        stream.set_nodelay(true).ok();
        let timeout = (cfg.io_timeout_ms > 0).then(|| Duration::from_millis(cfg.io_timeout_ms));
        stream.set_read_timeout(timeout)?;
        stream.set_write_timeout(timeout)?;
        let mut t = TcpTransport { stream, max_frame: cfg.max_frame_bytes, peer_epoch: None };
        match t.call(Request::Hello { major: PROTO_MAJOR, minor: PROTO_MINOR })? {
            Response::HelloAck { .. } => Ok(t),
            Response::Error(e) => bail!("handshake rejected by {addr}: {e}"),
            other => bail!("unexpected handshake response from {addr}: {other:?}"),
        }
    }
}

impl TcpTransport {
    /// As [`ControlPlane::call`], but stamp `rid` as the request's v1.3
    /// retry id (a trailing extension older masters simply ignore).  A
    /// client that re-sends the same mutating frame with the same rid —
    /// the [`FailoverTransport`] re-dial path — gets the master's cached
    /// response instead of a second application.
    pub fn call_rid(&mut self, req: Request, rid: Option<u64>) -> Result<Response> {
        let payload = wire::encode_request_rid(&req, rid);
        wire::write_frame(&mut self.stream, &payload, self.max_frame)
            .context("send request frame")?;
        let payload = wire::read_frame(&mut self.stream, self.max_frame)
            .context("receive response frame")?;
        let (rsp, epoch) = wire::decode_response_ep(&payload).context("decode response")?;
        if epoch.is_some() {
            self.peer_epoch = epoch;
        }
        Ok(rsp)
    }
}

impl ControlPlane for TcpTransport {
    fn call(&mut self, req: Request) -> Result<Response> {
        self.call_rid(req, None)
    }

    fn last_epoch(&self) -> Option<u64> {
        self.peer_epoch
    }
}

/// A client that re-dials a candidate list of masters and fences off
/// deposed ones (DESIGN.md §11): on any transport failure it drops the
/// connection and walks the candidates again — with bounded backoff, so
/// a standby takeover window (primary dead, standby not yet serving) is
/// ridden out — and it remembers the highest epoch it has ever observed,
/// refusing to talk to a master that answers with a lower one.
///
/// Retry semantics (v1.3): every logical `Submit`/`Complete` is stamped
/// with one randomly-drawn retry id, *reused verbatim across re-dials* of
/// the same call, so a master that already applied the first copy answers
/// the re-sent frame from its dedupe cache instead of applying it twice.
/// The residual ambiguity is a takeover that lost the WAL tail (or an id
/// evicted from the bounded cache): those callers still reconcile via
/// QueryState — the failover smoke's "modulo in-flight requests" contract.
pub struct FailoverTransport {
    candidates: Vec<String>,
    cfg: NetConfig,
    current: Option<TcpTransport>,
    /// Highest epoch ever observed — the fence.
    fence: u64,
    /// Retry-id stream, wall-clock seeded so two clients (or two runs of
    /// one client) never share an id sequence.
    rids: crate::util::Rng,
}

impl FailoverTransport {
    /// Try each candidate once; error if none is reachable right now.
    /// (`cfg.redial_rounds` × `cfg.redial_backoff_ms` bounds later
    /// re-dials inside [`FailoverTransport::call`].)
    pub fn connect(candidates: Vec<String>, cfg: &NetConfig) -> Result<Self> {
        if candidates.is_empty() {
            bail!("failover transport needs at least one candidate address");
        }
        let seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0)
            ^ (std::process::id() as u64).rotate_left(32);
        let mut t = FailoverTransport {
            candidates,
            cfg: cfg.clone(),
            current: None,
            fence: 0,
            rids: crate::util::Rng::new(seed),
        };
        t.current = t.dial();
        if t.current.is_none() {
            bail!("no master reachable among {:?}", t.candidates);
        }
        Ok(t)
    }

    /// The highest epoch observed so far (0 = none yet).
    pub fn fence(&self) -> u64 {
        self.fence
    }

    /// Walk the candidate list once; skip stale-epoch masters.  Each
    /// connect attempt is bounded (a blackholed candidate must not stall
    /// the walk past the redial budget; see
    /// [`TcpTransport::connect_with_timeout`]).
    fn dial(&mut self) -> Option<TcpTransport> {
        let connect_timeout = Duration::from_millis(if self.cfg.io_timeout_ms > 0 {
            self.cfg.io_timeout_ms
        } else {
            5000
        });
        for addr in &self.candidates {
            match TcpTransport::connect_with_timeout(addr, &self.cfg, connect_timeout) {
                Ok(t) => {
                    if let Some(e) = t.last_epoch() {
                        if e < self.fence {
                            log::warn!(
                                "master {addr} serves epoch {e} < fence {}; skipping \
                                 deposed primary",
                                self.fence
                            );
                            continue;
                        }
                        self.fence = e;
                    }
                    log::info!("connected to master {addr}");
                    return Some(t);
                }
                Err(e) => log::debug!("candidate {addr} unreachable: {e:#}"),
            }
        }
        None
    }
}

impl ControlPlane for FailoverTransport {
    fn call(&mut self, req: Request) -> Result<Response> {
        // one retry id per *logical* mutating call, drawn here and reused
        // on every re-dial below — the id's sameness is what lets the
        // master tell "the network re-sent it" from "a second submission"
        let rid = matches!(req, Request::Submit { .. } | Request::Complete { .. })
            .then(|| self.rids.next_u64());
        let rounds = self.cfg.redial_rounds.max(1);
        let backoff = Duration::from_millis(self.cfg.redial_backoff_ms.max(1));
        for round in 0..rounds {
            let conn = match self.current.take() {
                Some(t) => Some(t),
                None => self.dial(),
            };
            if let Some(mut t) = conn {
                match t.call_rid(req.clone(), rid) {
                    Ok(rsp) => {
                        if let Some(e) = t.last_epoch() {
                            if e < self.fence {
                                // mid-connection demotion cannot happen on a
                                // sane master; treat as a stale peer and move on
                                log::warn!("master answered with stale epoch {e}; re-dialing");
                                continue; // t dropped: connection abandoned
                            }
                            self.fence = e;
                        }
                        self.current = Some(t);
                        return Ok(rsp);
                    }
                    Err(e) => {
                        log::info!("master connection lost ({e:#}); re-dialing candidates");
                        continue; // t dropped
                    }
                }
            }
            if round + 1 < rounds {
                std::thread::sleep(backoff);
            }
        }
        // deliberately NOT a ProtoError: exhaustion means "the control
        // plane is gone", which agents treat as a clean drain, not as a
        // typed rejection by a live master
        bail!(
            "no master reachable among {:?} after {rounds} rounds (fence epoch {})",
            self.candidates,
            self.fence
        )
    }

    fn last_epoch(&self) -> Option<u64> {
        (self.fence > 0).then_some(self.fence)
    }
}

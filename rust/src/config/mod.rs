//! Configuration system: a TOML-subset parser plus typed views for the
//! cluster, the Dorm thresholds and the simulated workload.
//!
//! serde is not in this image's vendored registry (DESIGN.md §6), so this is
//! a small hand-rolled parser covering the subset the repo uses:
//! `[section]` headers, `key = value` with string / number / bool / arrays
//! of numbers or strings, `#` comments, and `key=value` flat files (the
//! artifact `manifest.kv` format shares the scalar grammar).

mod parse;
mod schema;

pub use parse::{parse_kv_file, parse_toml, TomlDoc, Value};
pub use schema::{
    CellsConfig, ClusterConfig, DomainsConfig, DormConfig, FaultConfig, HaConfig, NetConfig,
    ServerConfig, SimConfig, TraceConfig,
};

//! Closed-loop load generator for the control plane (DESIGN.md §15).
//!
//! Drives M concurrent request-response clients over real loopback TCP —
//! the slave fleet's steady-state packet mix: mostly lease-only
//! heartbeats, a full `QueryState` every [`QUERY_STRIDE`]-th call, and an
//! occasional submit/complete pair so the sweep is not a read-only
//! fiction — each as fast as the server answers, and reports the
//! *sustained* aggregate rate with client-observed latency percentiles.
//! `dorm bench rpc-throughput` and `benches/rpc_throughput.rs` are both
//! thin wrappers over [`drive`], so the CLI verb and the tracked bench
//! series can never drift apart.

use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::app::{AppSpec, Engine};
use crate::config::NetConfig;
use crate::master::DormMaster;
use crate::net::{serve, serve_legacy, ControlPlane, ServerHandle, TcpTransport};
use crate::proto::{Request, Response};
use crate::resources::Res;

/// Every `QUERY_STRIDE`-th call is a full `QueryState` (the largest
/// response payload); the rest are heartbeats.
pub const QUERY_STRIDE: u64 = 16;
/// Client 0 replaces every `SUBMIT_STRIDE`-th call with a submit (paired
/// with an immediate complete, so the app population stays fixed).
pub const SUBMIT_STRIDE: u64 = 64;

/// Which server implementation a load point drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServerKind {
    /// The original one-thread-per-connection blocking server
    /// ([`serve_legacy`]) — the measured baseline.
    Legacy,
    /// The multiplexed worker-pool server ([`serve`]).
    Mux,
}

impl ServerKind {
    /// Stable label used in reports and the `BENCH_sched.json` series.
    pub fn label(self) -> &'static str {
        match self {
            ServerKind::Legacy => "legacy",
            ServerKind::Mux => "mux",
        }
    }

    /// Bind and serve `master` with this implementation.
    pub fn serve(self, master: DormMaster, net: &NetConfig) -> Result<ServerHandle> {
        match self {
            ServerKind::Legacy => serve_legacy(master, net),
            ServerKind::Mux => serve(master, net),
        }
    }
}

/// One measured load point, aggregated over every client.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Concurrent clients driven.
    pub clients: usize,
    /// Wall seconds measured (barrier release to last client exit).
    pub wall_secs: f64,
    /// Completed round trips summed across all clients.
    pub calls: u64,
    /// Sustained aggregate request rate, calls per second.
    pub req_per_sec: f64,
    /// Heartbeats within `calls`, per second — the fan-in rate.
    pub heartbeats_per_sec: f64,
    /// Client-observed round-trip median, microseconds.
    pub p50_us: f64,
    /// Client-observed round-trip 99th percentile, microseconds.
    pub p99_us: f64,
}

/// The app shape the occasional submit/complete pair uses — also the
/// seed population a bench master starts with, so heartbeat
/// reconciliation and `QueryState` have real work to answer with.
pub fn bench_spec(i: u32) -> AppSpec {
    AppSpec {
        executor: Engine::MxNet,
        demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0),
        weight: 1 + (i % 3),
        n_max: 8,
        n_min: 1,
        cmd: ["lr".into(), "lr".into()],
    }
}

/// Nearest-rank percentile of an already-sorted sample.
fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

/// Drive `clients` concurrent closed-loop clients against `handle` for
/// `duration`.  `servers` bounds the heartbeat ordinates (client `c`
/// beats as server `c % servers`, so every lease stays renewed).  Every
/// response is checked: an in-band [`Response::Error`] fails the drive —
/// a saturated server must degrade by latency, never by wrong answers.
pub fn drive(
    handle: &ServerHandle,
    net: &NetConfig,
    servers: u32,
    clients: usize,
    duration: Duration,
) -> Result<LoadReport> {
    if clients == 0 || servers == 0 {
        bail!("need at least one client and one server ordinate");
    }
    let addr = handle.addr().to_string();
    let start = Arc::new(Barrier::new(clients + 1));
    let mut threads = Vec::with_capacity(clients);
    for c in 0..clients {
        let addr = addr.clone();
        let net = net.clone();
        let start = Arc::clone(&start);
        threads.push(std::thread::spawn(move || -> Result<(Vec<f64>, u64)> {
            let mut t =
                TcpTransport::connect(&addr, &net).with_context(|| format!("client {c} connect"))?;
            let mut lat: Vec<f64> = Vec::with_capacity(4096);
            let mut hb = 0u64;
            start.wait();
            let deadline = Instant::now() + duration;
            let mut i = 0u64;
            while Instant::now() < deadline {
                let req = if i % QUERY_STRIDE == 0 {
                    Request::QueryState { app: None }
                } else if c == 0 && i % SUBMIT_STRIDE == 1 {
                    Request::Submit { spec: bench_spec(i as u32) }
                } else {
                    hb += 1;
                    // NAN = "stamp arrival at the server", the slave
                    // agent's steady-state form
                    Request::Heartbeat {
                        server: c as u32 % servers,
                        now_hours: f64::NAN,
                        report: None,
                        acks: vec![],
                    }
                };
                let t0 = Instant::now();
                let rsp = t.call(req)?;
                lat.push(t0.elapsed().as_secs_f64() * 1e6);
                match rsp {
                    Response::Error(e) => bail!("in-band error mid-drive: {e}"),
                    Response::Submitted { app } => {
                        let t0 = Instant::now();
                        let done = t.call(Request::Complete { app })?;
                        lat.push(t0.elapsed().as_secs_f64() * 1e6);
                        if let Response::Error(e) = done {
                            bail!("complete refused mid-drive: {e}");
                        }
                        i += 1; // the pair counts as two calls
                    }
                    _ => {}
                }
                i += 1;
            }
            Ok((lat, hb))
        }));
    }

    start.wait();
    let t0 = Instant::now();
    let mut lat: Vec<f64> = Vec::new();
    let mut heartbeats = 0u64;
    for th in threads {
        let (l, hb) = th.join().map_err(|_| anyhow!("load client panicked"))??;
        lat.extend(l);
        heartbeats += hb;
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    lat.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let calls = lat.len() as u64;
    Ok(LoadReport {
        clients,
        wall_secs: wall,
        calls,
        req_per_sec: calls as f64 / wall,
        heartbeats_per_sec: heartbeats as f64 / wall,
        p50_us: pct(&lat, 0.50),
        p99_us: pct(&lat, 0.99),
    })
}

/// Splice the measured `"rpc"` series into the `BENCH_sched.json`-layout
/// document at `path` (replacing any previous `"rpc"` key, or starting a
/// fresh document when the file is absent).  `scripts/check_bench.sh`
/// gates the result against `BENCH_baseline/`; `dorm bench
/// rpc-throughput --json` and `benches/rpc_throughput.rs` both emit
/// through here so the two can never drift apart.
pub fn splice_rpc_json(
    path: &str,
    points: &[(ServerKind, LoadReport)],
    speedup: f64,
) -> Result<()> {
    let mut text = std::fs::read_to_string(path)
        .unwrap_or_else(|_| "{\n  \"bench\": \"sched_latency_churn\"\n}\n".to_string());
    if let Some(i) = text.find(",\n  \"rpc\"") {
        // a previous rpc splice: drop it and close the object again
        text.truncate(i);
        text.push_str("\n}\n");
    }
    let end = text.rfind('}').ok_or_else(|| anyhow!("{path} is not a JSON object"))?;
    let mut out = text[..end].trim_end().to_string();
    let frags: Vec<String> = points
        .iter()
        .map(|(kind, p)| {
            format!(
                concat!(
                    "      {{\"server\": \"{}\", \"clients\": {}, ",
                    "\"req_per_sec\": {:.1}, \"heartbeats_per_sec\": {:.1}, ",
                    "\"p50_us\": {:.1}, \"p99_us\": {:.1}, \"calls\": {}}}"
                ),
                kind.label(),
                p.clients,
                p.req_per_sec,
                p.heartbeats_per_sec,
                p.p50_us,
                p.p99_us,
                p.calls
            )
        })
        .collect();
    out.push_str(&format!(
        ",\n  \"rpc\": {{\n    \"speedup_mux_vs_legacy\": {speedup:.2},\n    \
         \"points\": [\n{}\n    ]\n  }}\n}}\n",
        frags.join(",\n")
    ));
    std::fs::write(path, out).with_context(|| format!("write {path}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::CheckpointStore;
    use crate::config::{ClusterConfig, DormConfig};

    fn master(tag: &str) -> DormMaster {
        let dir = std::env::temp_dir().join(format!("dorm_loadgen_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut m = DormMaster::new(
            &ClusterConfig::uniform(4, Res::cpu_gpu_ram(12.0, 0.0, 64.0)),
            DormConfig { theta1: 0.1, theta2: 0.1 },
            CheckpointStore::new(dir).unwrap(),
        );
        m.submit(bench_spec(0)).unwrap();
        m
    }

    fn net() -> NetConfig {
        NetConfig { bind_addr: "127.0.0.1:0".into(), io_timeout_ms: 5_000, ..NetConfig::default() }
    }

    /// The JSON splice is idempotent: a second splice replaces the first
    /// `"rpc"` series instead of appending a sibling key.
    #[test]
    fn rpc_json_splice_is_idempotent() {
        let path = std::env::temp_dir()
            .join(format!("dorm_rpc_splice_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        let rep = LoadReport {
            clients: 2,
            wall_secs: 1.0,
            calls: 10,
            req_per_sec: 10.0,
            heartbeats_per_sec: 8.0,
            p50_us: 100.0,
            p99_us: 200.0,
        };
        let pts = vec![(ServerKind::Legacy, rep.clone()), (ServerKind::Mux, rep)];
        splice_rpc_json(&path, &pts, 1.5).unwrap();
        splice_rpc_json(&path, &pts, 2.5).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.matches("\"rpc\"").count(), 1, "{text}");
        assert!(text.contains("\"speedup_mux_vs_legacy\": 2.50"), "{text}");
        assert_eq!(text.matches("\"server\": \"mux\"").count(), 1, "{text}");
        let _ = std::fs::remove_file(&path);
    }

    /// Both server kinds take a short concurrent drive: every response
    /// well-formed, sane percentiles, non-zero sustained rate.
    #[test]
    fn loadgen_drives_both_server_kinds() {
        for kind in [ServerKind::Legacy, ServerKind::Mux] {
            let net = net();
            let handle = kind.serve(master(kind.label()), &net).unwrap();
            let rep = drive(&handle, &net, 4, 3, Duration::from_millis(200)).unwrap();
            handle.stop();
            assert!(rep.calls > 0, "{}: no calls completed", kind.label());
            assert!(rep.req_per_sec > 0.0);
            assert!(rep.p99_us >= rep.p50_us, "{rep:?}");
            assert!(rep.heartbeats_per_sec > 0.0, "{rep:?}");
        }
    }
}

//! The utilization–fairness optimizer (paper §IV).
//!
//! Builds the paper's **P2** from the current cluster state and solves it.
//! Per DESIGN.md §6 the solve is count-aggregated: the paper's own
//! observation that containers of one application are uniform (§III-A-4)
//! collapses the per-(i,j) variables xᵢⱼ into per-app counts nᵢ = Σⱼ xᵢⱼ
//! checked against aggregate capacity, followed by a placement round
//! ([`crate::cluster::place`]) that reconstructs xᵢⱼ; if packing fails the
//! optimizer retries with reduced counts.
//!
//! Three solve modes:
//! * [`SolveMode::Heuristic`] — DRF-seeded greedy + local search (µs-scale);
//! * [`SolveMode::Exact`] — our branch-and-bound MILP (the CPLEX stand-in),
//!   warm-started with the heuristic incumbent;
//! * [`SolveMode::Auto`] — exact for small |A|, heuristic beyond.
//!
//! The tests cross-validate heuristic vs exact on random instances; the
//! `solver_micro` bench tracks both latencies against the paper's implied
//! sub-second allocation budget.

mod milp_build;

pub use milp_build::{build_count_milp, counts_to_point};

use std::collections::BTreeMap;
use std::time::Instant;

use crate::app::AppId;
use crate::cluster::{place_spread, place_delta, PackState, Placement, PlacementInput, ServerId};
use crate::config::DormConfig;
use crate::resources::Res;
use crate::solver::heuristic::{
    heuristic_solve, heuristic_solve_from, heuristic_solve_relaxed, CountApp, CountProblem,
};
use crate::solver::{milp, MilpOptions, MilpOutcome};

/// One application as the optimizer sees it.
#[derive(Clone, Debug)]
pub struct OptApp {
    pub id: AppId,
    pub demand: Res,
    pub weight: f64,
    pub n_min: u32,
    pub n_max: u32,
    /// Containers held at t−1 (None for new arrivals; Eq. 4 exempts them).
    pub prev: Option<u32>,
    /// Current placement (empty for new arrivals).
    pub current: BTreeMap<ServerId, u32>,
}

/// How to solve the count problem.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolveMode {
    Heuristic,
    Exact,
    /// Exact (warm-started) when |A| ≤ 16, heuristic otherwise.
    Auto,
}

/// Solver telemetry for the benches.
#[derive(Clone, Debug, Default)]
pub struct SolveStats {
    pub used_exact: bool,
    /// Fairness bound was unreachable; the best-effort relaxation was used
    /// (fairness loss minimized instead of bounded, DESIGN.md §6).
    pub relaxed: bool,
    pub bb_nodes: usize,
    pub solve_micros: u128,
    /// Decision served from an engine's snapshot cache — no solve ran
    /// (set by [`crate::sched::AllocationEngine`], never by the optimizer).
    pub cache_hit: bool,
    /// A previous solution seeded this solve as a feasible warm-start
    /// incumbent (extra heuristic anchor + branch-and-bound bound).
    pub warm_start: bool,
    /// The placement round ran on the delta-aware packer without falling
    /// back to a full BFD re-pack (see [`crate::cluster::place_delta`]).
    pub delta_path: bool,
    /// Containers the placement physically moves (Σ destroys + creates) —
    /// the adjustment churn this decision costs.
    pub moved_containers: u64,
}

/// The optimizer's output: new counts + concrete placement + the Eq. 1/2/4
/// metrics of the decision.
#[derive(Clone, Debug)]
pub struct Decision {
    pub counts: BTreeMap<AppId, u32>,
    pub placement: Placement,
    pub utilization: f64,
    pub fairness_loss: f64,
    /// Carried-over apps whose allocation changed (Eq. 4 numerator).
    pub adjusted: Vec<AppId>,
    pub stats: SolveStats,
}

/// The utilization–fairness optimizer (a module of the DormMaster, §III-A).
#[derive(Clone, Debug)]
pub struct Optimizer {
    pub cfg: DormConfig,
    pub mode: SolveMode,
}

impl Optimizer {
    pub fn new(cfg: DormConfig) -> Self {
        Optimizer { cfg, mode: SolveMode::Auto }
    }

    pub fn with_mode(cfg: DormConfig, mode: SolveMode) -> Self {
        Optimizer { cfg, mode }
    }

    fn count_problem(&self, apps: &[OptApp], cap: &Res) -> CountProblem {
        CountProblem::new(
            apps.iter()
                .map(|a| CountApp {
                    demand: a.demand.clone(),
                    weight: a.weight,
                    n_min: a.n_min,
                    n_max: a.n_max,
                    prev: a.prev,
                })
                .collect(),
            cap.clone(),
            self.cfg.theta1,
            self.cfg.theta2,
        )
    }

    /// Solve for per-app container counts. `None` = no feasible allocation
    /// (the master keeps existing allocations, paper §IV-B).
    pub fn solve_counts(
        &self,
        apps: &[OptApp],
        cap: &Res,
    ) -> Option<(Vec<u32>, SolveStats)> {
        self.solve_counts_warm(apps, cap, None)
    }

    /// As [`Optimizer::solve_counts`], additionally seeding the solve with
    /// `warm` — the counts of a previous solution, per app (apps without an
    /// entry anchor at `n_min`).  The warm point runs as an extra anchored
    /// heuristic pipeline and, in exact mode, supplies the branch-and-bound
    /// incumbent, so it can only improve (or tie) the cold result; with
    /// `warm = None` this is byte-identical to the cold path.
    pub fn solve_counts_warm(
        &self,
        apps: &[OptApp],
        cap: &Res,
        warm: Option<&BTreeMap<AppId, u32>>,
    ) -> Option<(Vec<u32>, SolveStats)> {
        let t0 = Instant::now();
        let p = self.count_problem(apps, cap);
        let mut stats = SolveStats::default();

        let heur = heuristic_solve(&p);
        let warm_cand = warm
            .filter(|w| apps.iter().any(|a| w.contains_key(&a.id)))
            .and_then(|w| {
                let seed: Vec<u32> = apps
                    .iter()
                    .map(|a| w.get(&a.id).copied().unwrap_or(a.n_min))
                    .collect();
                heuristic_solve_from(&p, &seed)
            });
        stats.warm_start = warm_cand.is_some();
        // best feasible incumbent of the cold pipelines and the warm anchor
        let heur = match (heur, warm_cand) {
            (Some(a), Some(b)) => {
                Some(if p.utilization(&a) >= p.utilization(&b) { a } else { b })
            }
            (a, b) => a.or(b),
        };

        let use_exact = match self.mode {
            SolveMode::Heuristic => false,
            SolveMode::Exact => true,
            SolveMode::Auto => apps.len() <= 16,
        };

        let counts = if use_exact {
            let milp_prob = build_count_milp(&p);
            let opts = MilpOptions {
                warm_start: heur
                    .as_ref()
                    .map(|c| milp_build::counts_to_point(&p, c)),
                node_limit: 50_000,
                ..Default::default()
            };
            match milp::solve(&milp_prob, &opts) {
                MilpOutcome::Optimal { x, nodes, .. }
                | MilpOutcome::Feasible { x, nodes, .. } => {
                    stats.used_exact = true;
                    stats.bb_nodes = nodes;
                    let counts: Vec<u32> =
                        (0..apps.len()).map(|i| x[i].round() as u32).collect();
                    // exact solution must itself be feasible in problem terms
                    if p.is_feasible(&counts) {
                        Some(counts)
                    } else {
                        heur
                    }
                }
                _ => heur,
            }
        } else {
            heur
        };
        let counts = match counts {
            Some(c) => Some(c),
            None => {
                stats.relaxed = true;
                heuristic_solve_relaxed(&p)
            }
        };

        stats.solve_micros = t0.elapsed().as_micros();
        counts.map(|c| (c, stats))
    }

    /// Full allocation: counts + placement.  Reduces counts (adjusted/new
    /// apps first) when the packing round fails on fragmentation.
    pub fn allocate(&self, apps: &[OptApp], capacities: &[Res]) -> Option<Decision> {
        self.allocate_warm(apps, capacities, None)
    }

    /// As [`Optimizer::allocate`], seeding the count solve with a previous
    /// solution's counts (see [`Optimizer::solve_counts_warm`]).
    pub fn allocate_warm(
        &self,
        apps: &[OptApp],
        capacities: &[Res],
        warm: Option<&BTreeMap<AppId, u32>>,
    ) -> Option<Decision> {
        self.allocate_incremental(apps, capacities, warm, None)
    }

    /// The incremental hot path: as [`Optimizer::allocate_warm`], but when
    /// `pack` is given the placement round runs the delta-aware packer
    /// against that persistent state ([`crate::cluster::place_delta`])
    /// instead of a from-scratch re-pack, and the placement-input buffer is
    /// built once and reused across the reduce-counts retries.
    pub fn allocate_incremental(
        &self,
        apps: &[OptApp],
        capacities: &[Res],
        warm: Option<&BTreeMap<AppId, u32>>,
        mut pack: Option<&mut PackState>,
    ) -> Option<Decision> {
        let m = capacities.first().map(|c| c.m()).unwrap_or(0);
        let cap = capacities.iter().fold(Res::zeros(m), |mut acc, c| {
            acc += c;
            acc
        });
        let (mut counts, mut stats) = self.solve_counts_warm(apps, &cap, warm)?;
        let p = self.count_problem(apps, &cap);

        // placement inputs are built once; only the targets change across
        // the reduce-counts retries below
        let mut inputs: Vec<PlacementInput> = apps
            .iter()
            .zip(&counts)
            .map(|(a, &c)| PlacementInput {
                app: a.id,
                demand: a.demand.clone(),
                target: c,
                current: a.current.clone(),
            })
            .collect();

        // Once a delta attempt fails, its internal full-re-pack fallback has
        // also failed and the pack state is cold — plain full packing for
        // the remaining retries of this call, so the reduce-counts storm
        // costs one packing pass per retry (same as the legacy loop), not
        // two.  The pack's failure-domain tie-break context still applies
        // on that path (risk-aware placement must not silently degrade to
        // risk-blind mid-retry).
        let spread_ctx = pack.as_deref().and_then(|s| s.spread().cloned());
        let mut use_delta = pack.is_some();
        for _attempt in 0..256 {
            for (inp, &c) in inputs.iter_mut().zip(&counts) {
                inp.target = c;
            }
            let placed = if use_delta {
                let state = pack.as_deref_mut().expect("use_delta implies pack");
                let p = place_delta(&inputs, capacities, state);
                if p.is_none() {
                    use_delta = false;
                }
                p
            } else {
                place_spread(&inputs, capacities, spread_ctx.as_ref())
            };
            if let Some(placement) = placed {
                stats.delta_path = placement.delta_path;
                stats.moved_containers = placement.moved_containers();
                let counts_map: BTreeMap<AppId, u32> = apps
                    .iter()
                    .zip(&counts)
                    .map(|(a, &c)| (a.id, c))
                    .collect();
                let adjusted: Vec<AppId> = apps
                    .iter()
                    .zip(&counts)
                    .filter(|(a, &c)| {
                        a.prev.map_or(false, |prev| {
                            prev != c
                                || placement.assignment.get(&a.id) != Some(&a.current)
                        })
                    })
                    .map(|(a, _)| a.id)
                    .collect();
                return Some(Decision {
                    utilization: p.utilization(&counts),
                    fairness_loss: p.fairness_loss_of(&counts),
                    counts: counts_map,
                    placement,
                    adjusted,
                    stats,
                });
            }
            // Packing failed: decrement the shrink-preferred app with the
            // lowest utilization density — prefer apps already being
            // adjusted or new, so the θ₂ budget is not eaten by repair.
            let mut cand: Option<(usize, (u8, f64))> = None;
            for (i, a) in apps.iter().enumerate() {
                if counts[i] > a.n_min {
                    let already_adjusted =
                        a.prev.map_or(true, |prev| prev != counts[i]);
                    let class = if already_adjusted { 0u8 } else { 1u8 };
                    let density = a.demand.utilization_sum(&cap);
                    let key = (class, density);
                    match &cand {
                        Some((_, bk)) if *bk <= key => {}
                        _ => cand = Some((i, key)),
                    }
                }
            }
            let (i, _) = cand?;
            counts[i] -= 1;
            let still_ok = if stats.relaxed {
                // relaxed mode: capacity/bounds/θ₂ only
                counts
                    .iter()
                    .zip(apps)
                    .all(|(&c, a)| c >= a.n_min && c <= a.n_max)
                    && p.used_of(&counts).fits_in(&cap)
                    && p.adjustments(&counts) <= p.adjust_bound()
            } else {
                p.is_feasible(&counts)
            };
            if !still_ok {
                // feasibility lost (e.g. θ₂): give up — master keeps the
                // previous allocation.
                return None;
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    fn oapp(id: u64, cpu: f64, ram: f64, lo: u32, hi: u32, prev: Option<u32>) -> OptApp {
        OptApp {
            id: AppId(id),
            demand: Res(vec![cpu, ram]),
            weight: 1.0,
            n_min: lo,
            n_max: hi,
            prev,
            current: BTreeMap::new(),
        }
    }

    fn caps(n: usize, cpu: f64, ram: f64) -> Vec<Res> {
        (0..n).map(|_| Res(vec![cpu, ram])).collect()
    }

    #[test]
    fn single_app_scales_to_max() {
        let opt = Optimizer::new(DormConfig::DORM3);
        let apps = vec![oapp(1, 2.0, 8.0, 1, 10, None)];
        let d = opt.allocate(&apps, &caps(4, 12.0, 64.0)).unwrap();
        assert_eq!(d.counts[&AppId(1)], 10);
        assert!(d.adjusted.is_empty(), "new app is not an adjustment");
    }

    #[test]
    fn exact_and_heuristic_agree_on_objective() {
        let apps = vec![
            oapp(1, 2.0, 4.0, 1, 12, None),
            oapp(2, 3.0, 2.0, 1, 12, None),
            oapp(3, 1.0, 6.0, 1, 12, None),
        ];
        let cap = Res(vec![24.0, 48.0]);
        let he = Optimizer::with_mode(DormConfig::DORM1, SolveMode::Heuristic);
        let ex = Optimizer::with_mode(DormConfig::DORM1, SolveMode::Exact);
        let (ch, _) = he.solve_counts(&apps, &cap).unwrap();
        let (ce, se) = ex.solve_counts(&apps, &cap).unwrap();
        assert!(se.used_exact);
        let p = ex.count_problem(&apps, &cap);
        // exact is optimal: its objective dominates (or ties) the heuristic
        assert!(
            p.utilization(&ce) >= p.utilization(&ch) - 1e-9,
            "exact {} < heuristic {}",
            p.utilization(&ce),
            p.utilization(&ch)
        );
    }

    #[test]
    fn warm_start_no_worse_than_cold_and_flagged() {
        // carried apps + one arrival; warm = the previous counts
        let mut apps: Vec<OptApp> = (0..3)
            .map(|i| {
                let mut a = oapp(i, 2.0, 2.0, 1, 12, Some(4));
                // spread across both servers so the pinned state fits
                a.current = [(ServerId(i as usize % 2), 4)].into_iter().collect();
                a
            })
            .collect();
        apps.push(oapp(9, 2.0, 2.0, 1, 12, None));
        let warm: BTreeMap<AppId, u32> =
            (0..3).map(|i| (AppId(i), 4u32)).collect();
        let capacities = caps(2, 16.0, 16.0);
        let opt = Optimizer::with_mode(
            DormConfig { theta1: 1.0, theta2: 0.4 },
            SolveMode::Heuristic,
        );
        let cold = opt.allocate(&apps, &capacities).unwrap();
        let warm_d = opt.allocate_warm(&apps, &capacities, Some(&warm)).unwrap();
        assert!(warm_d.stats.warm_start, "warm incumbent must be recorded");
        assert!(!cold.stats.warm_start);
        assert!(
            warm_d.utilization >= cold.utilization - 1e-9,
            "warm {} < cold {}",
            warm_d.utilization,
            cold.utilization
        );
    }

    #[test]
    fn adjustment_budget_respected_end_to_end() {
        // 5 carried apps, θ₂ = 0.2 -> at most ⌈1⌉ = 1 adjustment
        let apps: Vec<OptApp> = (0..5)
            .map(|i| {
                let mut a = oapp(i, 1.0, 1.0, 1, 20, Some(2));
                a.current = [(ServerId(0), 2)].into_iter().collect();
                a
            })
            .collect();
        let opt = Optimizer::with_mode(
            DormConfig { theta1: 1.0, theta2: 0.2 },
            SolveMode::Heuristic,
        );
        let d = opt.allocate(&apps, &caps(2, 20.0, 20.0)).unwrap();
        assert!(d.adjusted.len() <= 1, "{:?}", d.adjusted);
    }

    #[test]
    fn infeasible_floors_yield_none() {
        let opt = Optimizer::new(DormConfig::DORM3);
        let apps = vec![oapp(1, 10.0, 10.0, 4, 8, None)];
        assert!(opt.allocate(&apps, &caps(1, 12.0, 12.0)).is_none());
    }

    #[test]
    fn fragmentation_reduces_counts() {
        // aggregate would admit more, but per-server caps of 4 CPUs hold
        // only 2 containers of 2 CPUs each.
        let opt = Optimizer::with_mode(
            DormConfig { theta1: 1.0, theta2: 1.0 },
            SolveMode::Heuristic,
        );
        let apps = vec![oapp(1, 2.0, 1.0, 1, 5, None)];
        let capacities = vec![Res(vec![4.0, 100.0]), Res(vec![4.0, 100.0])];
        let d = opt.allocate(&apps, &capacities).unwrap();
        assert_eq!(d.counts[&AppId(1)], 4);
    }

    #[test]
    fn prop_exact_never_worse_than_heuristic() {
        prop::check(25, |rng: &mut Rng| {
            let napps = rng.range_u64(1, 5) as usize;
            let apps: Vec<OptApp> = (0..napps)
                .map(|i| OptApp {
                    id: AppId(i as u64),
                    demand: Res(vec![
                        rng.range_f64(0.5, 4.0),
                        rng.range_f64(0.5, 4.0),
                    ]),
                    weight: rng.range_f64(0.5, 3.0),
                    n_min: 1,
                    n_max: 1 + rng.range_u64(0, 8) as u32,
                    prev: if rng.f64() < 0.4 {
                        Some(rng.range_u64(1, 5) as u32)
                    } else {
                        None
                    },
                    current: BTreeMap::new(),
                })
                .collect();
            let cap = Res(vec![rng.range_f64(15.0, 60.0), rng.range_f64(15.0, 60.0)]);
            let cfg = DormConfig {
                theta1: rng.range_f64(0.1, 0.6),
                theta2: rng.range_f64(0.1, 0.8),
            };
            let he = Optimizer::with_mode(cfg, SolveMode::Heuristic);
            let ex = Optimizer::with_mode(cfg, SolveMode::Exact);
            let p = ex.count_problem(&apps, &cap);
            match (he.solve_counts(&apps, &cap), ex.solve_counts(&apps, &cap)) {
                (Some((ch, _)), Some((ce, _))) => {
                    if p.utilization(&ce) + 1e-6 < p.utilization(&ch) {
                        return Err(format!(
                            "exact {:?} (u={}) worse than heuristic {:?} (u={})",
                            ce,
                            p.utilization(&ce),
                            ch,
                            p.utilization(&ch)
                        ));
                    }
                    Ok(())
                }
                // heuristic may fail where exact succeeds; the reverse
                // (exact fails, heuristic succeeds) is a solver bug.
                (Some(_), None) => Err("exact failed where heuristic found a point".into()),
                _ => Ok(()),
            }
        });
    }
}

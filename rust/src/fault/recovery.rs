//! Lost-work accounting for checkpoint-driven recovery.
//!
//! A server death loses everything an affected app computed since its last
//! checkpoint — the §III-C-2 protocol can only resume from reliable
//! storage.  Both backends record each (failure, resume) pair here: the
//! live master in BSP steps, the DES in work-hours; the unit is the
//! backend's, the bookkeeping is shared.

use crate::app::AppId;

/// One failure → recovery cycle of one application.
#[derive(Clone, Debug, PartialEq)]
pub struct RecoveryRecord {
    pub app: AppId,
    /// Server whose death broke the app's partition.
    pub server: usize,
    /// Backend time of the failure (simulated hours / event counter).
    pub failed_at: f64,
    /// Work discarded: progress since the last checkpoint (steps on the
    /// live master, work-hours in the DES).
    pub lost_work: f64,
    /// Set when the app is running again; `None` while still down.
    pub resumed_at: Option<f64>,
    /// Container count the optimizer granted at resume (the "newly solved
    /// scale").
    pub resumed_scale: u32,
}

/// Append-only log of recovery cycles.
#[derive(Clone, Debug, Default)]
pub struct RecoveryLog {
    records: Vec<RecoveryRecord>,
}

impl RecoveryLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild a log from serialized records (master checkpoint restore,
    /// `crate::master::ha`).
    pub fn from_records(records: Vec<RecoveryRecord>) -> Self {
        RecoveryLog { records }
    }

    /// A server death took `app` down.
    pub fn failed(&mut self, app: AppId, server: usize, failed_at: f64, lost_work: f64) {
        self.records.push(RecoveryRecord {
            app,
            server,
            failed_at,
            lost_work,
            resumed_at: None,
            resumed_scale: 0,
        });
    }

    /// `app` is running again at `scale` containers: closes its oldest
    /// open record (failures during recovery open a new one each).
    pub fn resumed(&mut self, app: AppId, at: f64, scale: u32) {
        if let Some(r) = self
            .records
            .iter_mut()
            .find(|r| r.app == app && r.resumed_at.is_none())
        {
            r.resumed_at = Some(at);
            r.resumed_scale = scale;
        }
    }

    /// The oldest not-yet-resumed record for `app`, if any.
    pub fn open(&self, app: AppId) -> Option<&RecoveryRecord> {
        self.records.iter().find(|r| r.app == app && r.resumed_at.is_none())
    }

    pub fn records(&self) -> &[RecoveryRecord] {
        &self.records
    }

    /// Σ lost work across all recorded failures.
    pub fn total_lost_work(&self) -> f64 {
        self.records.iter().map(|r| r.lost_work).sum()
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failure_then_resume_closes_oldest_open_record() {
        let mut log = RecoveryLog::new();
        log.failed(AppId(1), 0, 1.0, 10.0);
        log.failed(AppId(2), 0, 1.0, 4.0);
        log.failed(AppId(1), 2, 2.0, 3.0); // failed again mid-recovery
        assert_eq!(log.open(AppId(1)).unwrap().failed_at, 1.0);
        log.resumed(AppId(1), 3.0, 8);
        assert_eq!(log.open(AppId(1)).unwrap().failed_at, 2.0);
        log.resumed(AppId(1), 3.5, 6);
        assert!(log.open(AppId(1)).is_none());
        assert!(log.open(AppId(2)).is_some(), "app 2 untouched");
        assert_eq!(log.total_lost_work(), 17.0);
        assert_eq!(log.len(), 3);
        let r = &log.records()[0];
        assert_eq!((r.resumed_at, r.resumed_scale), (Some(3.0), 8));
    }
}

//! Dominant Resource Fairness (Ghodsi et al., NSDI'11) — progressive
//! filling over *containers*.
//!
//! The utilization–fairness optimizer (§IV) needs each application's
//! **theoretical dominant share** ŝᵢ (Table I): the share DRF would give it
//! against the aggregate cluster capacity, honoring the application's
//! per-container demand dᵢ, weight wᵢ and container bounds [n_min, n_max].
//! [`drf_allocate`] computes exactly that by weighted progressive filling:
//! repeatedly grant one container to the application with the smallest
//! weighted dominant share that can still grow.
//!
//! It is also used directly as a standalone allocator baseline (the
//! "fairness-only" ablation in `benches/ablation_theta.rs`).

use crate::resources::Res;

/// Per-application DRF input.
#[derive(Clone, Debug)]
pub struct DrfApp {
    /// Per-container demand vector dᵢ.
    pub demand: Res,
    /// Weight wᵢ (>= 1 in the paper's workload; any positive value works).
    pub weight: f64,
    pub n_min: u32,
    pub n_max: u32,
}

/// DRF allocation result.
#[derive(Clone, Debug, PartialEq)]
pub struct DrfAllocation {
    /// Containers per application (paper's Σⱼ xᵢⱼ aggregated).
    pub containers: Vec<u32>,
    /// Theoretical dominant shares ŝᵢ.
    pub shares: Vec<f64>,
}

/// Weighted DRF progressive filling against aggregate capacity `cap`.
///
/// Starts every application at `n_min` containers (constraint Eq. 8); the
/// caller is responsible for the cluster being able to hold Σ n_min (the
/// optimizer guarantees it by construction of the admitted set). Then grants
/// containers one at a time to the app minimizing (dominant share / weight),
/// skipping apps at `n_max` or whose next container would exceed capacity.
pub fn drf_allocate(apps: &[DrfApp], cap: &Res) -> DrfAllocation {
    let n = apps.len();
    let mut counts: Vec<u32> = apps.iter().map(|a| a.n_min).collect();
    let mut used = Res::zeros(cap.m());
    for (a, &c) in apps.iter().zip(&counts) {
        used += &a.demand.times(c);
    }

    loop {
        // candidate with the smallest weighted dominant share that can grow
        let mut best: Option<(usize, f64)> = None;
        for i in 0..n {
            if counts[i] >= apps[i].n_max {
                continue;
            }
            let next = used.clone() + apps[i].demand.clone();
            if !next.fits_in(cap) {
                continue;
            }
            let share = apps[i].demand.times(counts[i]).dominant_share(cap);
            let key = share / apps[i].weight.max(1e-12);
            match best {
                Some((_, bk)) if bk <= key => {}
                _ => best = Some((i, key)),
            }
        }
        match best {
            Some((i, _)) => {
                used += &apps[i].demand;
                counts[i] += 1;
            }
            None => break,
        }
    }

    let shares = apps
        .iter()
        .zip(&counts)
        .map(|(a, &c)| a.demand.times(c).dominant_share(cap))
        .collect();
    DrfAllocation { containers: counts, shares }
}

/// Eq. (2): fairness loss Σᵢ |sᵢ − ŝᵢ| given actual and theoretical shares.
pub fn fairness_loss(actual: &[f64], theoretical: &[f64]) -> f64 {
    debug_assert_eq!(actual.len(), theoretical.len());
    actual
        .iter()
        .zip(theoretical)
        .map(|(s, sh)| (s - sh).abs())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    fn app(cpu: f64, gpu: f64, ram: f64, w: f64, lo: u32, hi: u32) -> DrfApp {
        DrfApp {
            demand: Res::cpu_gpu_ram(cpu, gpu, ram),
            weight: w,
            n_min: lo,
            n_max: hi,
        }
    }

    #[test]
    fn classic_drf_example() {
        // Ghodsi et al. §3: cluster <9 CPU, 18 GB>, app A <1,4>, app B <3,1>.
        // DRF equalizes dominant shares: A gets 3 tasks (12 GB -> 2/3),
        // B gets 2 tasks (6 CPU -> 2/3).
        let cap = Res(vec![9.0, 18.0]);
        let apps = vec![
            DrfApp { demand: Res(vec![1.0, 4.0]), weight: 1.0, n_min: 0, n_max: 100 },
            DrfApp { demand: Res(vec![3.0, 1.0]), weight: 1.0, n_min: 0, n_max: 100 },
        ];
        let out = drf_allocate(&apps, &cap);
        assert_eq!(out.containers, vec![3, 2]);
        assert!((out.shares[0] - 2.0 / 3.0).abs() < 1e-9);
        assert!((out.shares[1] - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn respects_n_max_and_gives_leftovers_to_others() {
        let cap = Res::cpu_gpu_ram(100.0, 0.0, 1000.0);
        let apps = vec![
            app(1.0, 0.0, 1.0, 1.0, 1, 2),
            app(1.0, 0.0, 1.0, 1.0, 1, 1000),
        ];
        let out = drf_allocate(&apps, &cap);
        assert_eq!(out.containers[0], 2);
        assert_eq!(out.containers[1], 98); // rest of the CPUs
    }

    #[test]
    fn respects_n_min_floor() {
        let cap = Res::cpu_gpu_ram(10.0, 0.0, 100.0);
        let apps = vec![app(1.0, 0.0, 1.0, 1.0, 4, 10), app(1.0, 0.0, 1.0, 100.0, 1, 10)];
        let out = drf_allocate(&apps, &cap);
        assert!(out.containers[0] >= 4);
    }

    #[test]
    fn weights_bias_allocation() {
        let cap = Res::cpu_gpu_ram(90.0, 0.0, 900.0);
        let apps = vec![
            app(1.0, 0.0, 1.0, 2.0, 0, 1000),
            app(1.0, 0.0, 1.0, 1.0, 0, 1000),
        ];
        let out = drf_allocate(&apps, &cap);
        // weighted DRF: shares proportional to weights -> 60 vs 30
        assert_eq!(out.containers, vec![60, 30]);
    }

    #[test]
    fn gpu_scarcity_limits_gpu_apps() {
        let cap = Res::cpu_gpu_ram(240.0, 5.0, 2560.0);
        let apps = vec![
            app(4.0, 1.0, 32.0, 1.0, 1, 5), // VGG-16 row of Table II
            app(2.0, 0.0, 8.0, 1.0, 1, 32), // LR row
        ];
        let out = drf_allocate(&apps, &cap);
        assert!(out.containers[0] <= 5, "only 5 GPUs exist");
    }

    #[test]
    fn fairness_loss_eq2() {
        assert_eq!(fairness_loss(&[0.5, 0.2], &[0.3, 0.2]), 0.2);
        assert_eq!(fairness_loss(&[], &[]), 0.0);
    }

    #[test]
    fn prop_never_exceeds_capacity_and_bounds() {
        prop::check(100, |rng: &mut Rng| {
            let m = rng.range_u64(1, 4) as usize;
            let cap = Res((0..m).map(|_| rng.range_f64(10.0, 200.0)).collect());
            let napps = rng.range_u64(1, 8) as usize;
            let apps: Vec<DrfApp> = (0..napps)
                .map(|_| {
                    let lo = rng.range_u64(0, 2) as u32;
                    DrfApp {
                        demand: Res((0..m).map(|_| rng.range_f64(0.1, 5.0)).collect()),
                        weight: rng.range_f64(0.5, 4.0),
                        n_min: lo,
                        n_max: lo + rng.range_u64(0, 20) as u32,
                    }
                })
                .collect();
            let out = drf_allocate(&apps, &cap);
            let mut used = Res::zeros(m);
            for (a, &c) in apps.iter().zip(&out.containers) {
                if c < a.n_min || c > a.n_max {
                    return Err(format!("count {c} outside [{}, {}]", a.n_min, a.n_max));
                }
                used += &a.demand.times(c);
            }
            // capacity may be exceeded only by the n_min floors
            let floor_used = apps.iter().fold(Res::zeros(m), |mut acc, a| {
                acc += &a.demand.times(a.n_min);
                acc
            });
            let effective_cap = cap.max(&floor_used);
            if !used.fits_in(&effective_cap) {
                return Err(format!("used {used:?} exceeds cap {cap:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_pareto_no_app_can_grow() {
        prop::check(100, |rng: &mut Rng| {
            let m = 2;
            let cap = Res((0..m).map(|_| rng.range_f64(20.0, 100.0)).collect());
            let napps = rng.range_u64(1, 6) as usize;
            let apps: Vec<DrfApp> = (0..napps)
                .map(|_| DrfApp {
                    demand: Res((0..m).map(|_| rng.range_f64(0.5, 4.0)).collect()),
                    weight: 1.0,
                    n_min: 0,
                    n_max: 50,
                })
                .collect();
            let out = drf_allocate(&apps, &cap);
            let mut used = Res::zeros(m);
            for (a, &c) in apps.iter().zip(&out.containers) {
                used += &a.demand.times(c);
            }
            // Pareto efficiency: no app below n_max can still fit +1 container.
            for (i, a) in apps.iter().enumerate() {
                if out.containers[i] < a.n_max {
                    let next = used.clone() + a.demand.clone();
                    if next.fits_in(&cap) {
                        return Err(format!("app {i} could still grow"));
                    }
                }
            }
            Ok(())
        });
    }
}

"""L1: flash-style causal attention Pallas kernel.

``causal_attention(q, k, v)`` computes softmax(q @ k^T / sqrt(dh) + causal
mask) @ v with the flash-attention recurrence: the KV sequence is processed
in blocks with running row-max / row-sum statistics so the S x S score matrix
is never materialized in HBM.

TPU adaptation (DESIGN.md §2): flash attention on GPU keeps the running
statistics in registers and communicates via warp shuffles; on TPU the
per-(batch, head) Q tile and the (m, l, acc) statistics live in VMEM for the
whole KV sweep, and the KV blocks are streamed HBM->VMEM by the grid
pipeline.  The grid is (B*H, S/bq, S/bk) with the KV dimension innermost so
the statistics scratch is revisited across KV steps.

interpret=True only on this image (CPU PJRT cannot run Mosaic custom-calls).
Oracle: ``ref.attention_ref``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, scale, bq, bk, kv_steps):
    """One (batch*head, q-block, kv-block) grid cell of the flash recurrence."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # [bq, dh]
    k = k_ref[0].astype(jnp.float32)                  # [bk, dh]
    v = v_ref[0].astype(jnp.float32)                  # [bk, dh]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [bq, bk]

    # Causal mask in global coordinates: query row qi*bq + r attends to
    # kv column ki*bk + c iff global_q >= global_k.
    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    s = jnp.where(rows >= cols, s, NEG_INF)

    m_prev = m_ref[...]                               # [bq]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    # Guard fully-masked rows (all NEG_INF) against exp overflow/nan.
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where((rows >= cols), p, 0.0)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ki == kv_steps - 1)
    def _store():
        # Rows with l == 0 cannot occur under the causal mask (row attends to
        # itself), but keep the division safe anyway.
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def _pick_block(dim: int, preferred: int) -> int:
    if dim <= preferred:
        return dim
    for cand in (preferred, 128, 64, 32, 16, 8, 4, 2):
        if cand <= preferred and dim % cand == 0:
            return cand
    return 1


def causal_attention_fwd(q, k, v, *, bq=128, bk=128):
    """softmax(q k^T / sqrt(dh), causal) v via the flash recurrence.

    q, k, v: [B, H, S, Dh]. Returns [B, H, S, Dh] in q.dtype.
    """
    if q.shape != k.shape or q.shape != v.shape or q.ndim != 4:
        raise ValueError(f"expected q=k=v [B,H,S,Dh]; got {q.shape} {k.shape} {v.shape}")
    b, h, s, dh = q.shape
    bq = _pick_block(s, bq)
    bk = _pick_block(s, bk)
    grid = (b * h, s // bq, s // bk)
    scale = 1.0 / math.sqrt(dh)

    qf = q.reshape(b * h, s, dh)
    kf = k.reshape(b * h, s, dh)
    vf = v.reshape(b * h, s, dh)

    kernel = functools.partial(
        _attn_kernel, scale=scale, bq=bq, bk=bk, kv_steps=grid[2])
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),    # running row max m
            pltpu.VMEM((bq,), jnp.float32),    # running row sum l
            pltpu.VMEM((bq, dh), jnp.float32),  # output accumulator
        ],
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, s, dh)


@jax.custom_vjp
def causal_attention(q, k, v):
    """Differentiable causal attention: forward is the Pallas flash kernel;
    backward recomputes through the jnp oracle (same numerics to kernel tol),
    keeping the AOT'd backward pass free of unexpanded custom calls."""
    return causal_attention_fwd(q, k, v)


def _attn_ref(q, k, v):
    from . import ref  # local import to avoid a cycle at module load
    return ref.attention_ref(q, k, v)


def _attn_vjp_fwd(q, k, v):
    return causal_attention_fwd(q, k, v), (q, k, v)


def _attn_vjp_bwd(res, g):
    q, k, v = res
    _, vjp = jax.vjp(_attn_ref, q, k, v)
    return vjp(g)


causal_attention.defvjp(_attn_vjp_fwd, _attn_vjp_bwd)


def vmem_footprint_bytes(s, dh, bq=128, bk=128, in_bytes=4):
    """Static VMEM footprint for the chosen tiling: resident Q tile +
    statistics + accumulator, double-buffered streamed K/V tiles."""
    bq, bk = _pick_block(s, bq), _pick_block(s, bk)
    resident = bq * dh * in_bytes + bq * 4 * 2 + bq * dh * 4 + bq * dh * in_bytes
    stream = 2 * (bk * dh * in_bytes) * 2
    return resident + stream

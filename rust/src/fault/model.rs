//! Failure injection: when and which servers die and come back.
//!
//! Two generators behind one interface: per-server exponential MTBF/MTTR
//! (the standard machine-churn model, deterministic via
//! [`crate::util::Rng`]) and scripted traces (tests, replay, the
//! master↔sim parity suite).  A trace is a time-sorted list of
//! [`FailureEvent`]s the DES feeds into its event queue and a live-master
//! harness replays through `fail_server`/`recover_server`.

use crate::util::Rng;

/// A server goes down or comes back — or the *master* does (control-plane
/// failover, DESIGN.md §11).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FailureKind {
    /// The server dies: capacity and containers are lost.
    Kill,
    /// The server rejoins with its original capacity (empty).
    Recover,
    /// The CMS master dies.  Running partitions keep computing (§III-D:
    /// apps launch tasks locally), but no allocation decisions happen
    /// until a standby takes over.
    MasterKill,
    /// A standby master finished taking over; deferred allocation work
    /// (arrivals, completions, failures seen during the outage) is
    /// reconciled in one catch-up solve.
    MasterRecover,
}

impl FailureKind {
    /// Does this event name a specific server (vs the master)?
    pub fn is_server_event(self) -> bool {
        matches!(self, FailureKind::Kill | FailureKind::Recover)
    }
}

/// One churn event in a trace.
#[derive(Clone, Debug, PartialEq)]
pub struct FailureEvent {
    /// Hours from run start.
    pub time: f64,
    /// Server index (`crate::cluster::ServerId` ordinate); meaningless
    /// (`usize::MAX`) for master events.
    pub server: usize,
    pub kind: FailureKind,
}

impl FailureEvent {
    pub fn kill(time: f64, server: usize) -> Self {
        FailureEvent { time, server, kind: FailureKind::Kill }
    }

    pub fn recover(time: f64, server: usize) -> Self {
        FailureEvent { time, server, kind: FailureKind::Recover }
    }

    /// The CMS master dies at `time`.
    pub fn master_kill(time: f64) -> Self {
        FailureEvent { time, server: usize::MAX, kind: FailureKind::MasterKill }
    }

    /// A standby finishes taking over at `time`.
    pub fn master_recover(time: f64) -> Self {
        FailureEvent { time, server: usize::MAX, kind: FailureKind::MasterRecover }
    }
}

/// Trace generator.
#[derive(Clone, Debug)]
pub enum FailureModel {
    /// No churn (the paper's implicit assumption).
    None,
    /// Each server independently alternates up-time ~ Exp(mtbf) and
    /// down-time ~ Exp(mttr).  Deterministic for a given seed; each server
    /// draws from its own forked stream so traces are stable under
    /// cluster-size changes.
    Exponential { mtbf_hours: f64, mttr_hours: f64, seed: u64 },
    /// Replay exactly these events (times need not be sorted).
    Scripted(Vec<FailureEvent>),
}

impl FailureModel {
    /// The model a `[fault]` config section asks for: exponential churn
    /// when enabled, [`FailureModel::None`] otherwise.
    pub fn from_config(cfg: &crate::config::FaultConfig) -> FailureModel {
        if !cfg.enabled {
            return FailureModel::None;
        }
        FailureModel::Exponential {
            mtbf_hours: cfg.mtbf_hours,
            mttr_hours: cfg.mttr_hours,
            seed: cfg.seed,
        }
    }

    /// Materialize the trace for `n_servers` over `[0, horizon_hours]`,
    /// sorted by (time, server).  Scripted events outside the horizon or
    /// naming unknown servers are dropped.
    pub fn trace(&self, n_servers: usize, horizon_hours: f64) -> Vec<FailureEvent> {
        let mut out = match self {
            FailureModel::None => Vec::new(),
            FailureModel::Scripted(events) => events
                .iter()
                .filter(|e| {
                    e.time <= horizon_hours
                        && (!e.kind.is_server_event() || e.server < n_servers)
                })
                .cloned()
                .collect(),
            FailureModel::Exponential { mtbf_hours, mttr_hours, seed } => {
                assert!(*mtbf_hours > 0.0, "MTBF must be positive");
                assert!(*mttr_hours >= 0.0, "MTTR must be non-negative");
                let mut base = Rng::new(seed ^ 0xFA17_70DE);
                let mut events = Vec::new();
                for server in 0..n_servers {
                    let mut rng = base.fork(server as u64 + 1);
                    let mut t = rng.exponential(*mtbf_hours);
                    while t <= horizon_hours {
                        events.push(FailureEvent::kill(t, server));
                        t += rng.exponential(mttr_hours.max(1e-6));
                        if t > horizon_hours {
                            break;
                        }
                        events.push(FailureEvent::recover(t, server));
                        t += rng.exponential(*mtbf_hours);
                    }
                }
                events
            }
        };
        out.sort_by(|a, b| a.time.total_cmp(&b.time).then(a.server.cmp(&b.server)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponential_trace_is_deterministic_and_alternating() {
        let m = FailureModel::Exponential { mtbf_hours: 2.0, mttr_hours: 0.5, seed: 7 };
        let a = m.trace(5, 100.0);
        let b = m.trace(5, 100.0);
        assert_eq!(a, b, "same seed must replay identically");
        assert!(!a.is_empty(), "2h MTBF over 100h must produce failures");
        // per server: strictly alternating Kill / Recover, times increasing
        for j in 0..5 {
            let evs: Vec<&FailureEvent> = a.iter().filter(|e| e.server == j).collect();
            for (i, e) in evs.iter().enumerate() {
                let want = if i % 2 == 0 { FailureKind::Kill } else { FailureKind::Recover };
                assert_eq!(e.kind, want, "server {j} event {i}");
                if i > 0 {
                    assert!(e.time >= evs[i - 1].time);
                }
            }
        }
        // globally time-sorted
        for w in a.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
    }

    #[test]
    fn exponential_rates_roughly_match_mtbf() {
        let m = FailureModel::Exponential { mtbf_hours: 10.0, mttr_hours: 1.0, seed: 3 };
        let trace = m.trace(20, 1000.0);
        let kills = trace.iter().filter(|e| e.kind == FailureKind::Kill).count();
        // each server is up ~10/11 of the time -> ~91 kills per server per
        // 1000h/11h cycle; loose 2x bounds on the aggregate
        let expected = 20.0 * 1000.0 / 11.0;
        assert!(
            (kills as f64) > expected * 0.5 && (kills as f64) < expected * 2.0,
            "kills {kills} vs expected ~{expected:.0}"
        );
    }

    #[test]
    fn from_config_respects_the_enabled_switch() {
        use crate::config::FaultConfig;
        let off = FaultConfig::default();
        assert!(FailureModel::from_config(&off).trace(8, 100.0).is_empty());
        let on = FaultConfig {
            enabled: true,
            mtbf_hours: 4.0,
            mttr_hours: 0.5,
            seed: 9,
            ..Default::default()
        };
        let t = FailureModel::from_config(&on).trace(8, 100.0);
        assert!(!t.is_empty());
        // same knobs, same trace (seed flows through)
        assert_eq!(
            t,
            FailureModel::Exponential { mtbf_hours: 4.0, mttr_hours: 0.5, seed: 9 }
                .trace(8, 100.0)
        );
    }

    #[test]
    fn scripted_trace_filters_and_sorts() {
        let m = FailureModel::Scripted(vec![
            FailureEvent::recover(5.0, 1),
            FailureEvent::kill(1.0, 1),
            FailureEvent::kill(2.0, 9), // unknown server: dropped
            FailureEvent::kill(99.0, 0), // past horizon: dropped
        ]);
        let t = m.trace(4, 10.0);
        assert_eq!(t, vec![FailureEvent::kill(1.0, 1), FailureEvent::recover(5.0, 1)]);
        assert!(FailureModel::None.trace(4, 10.0).is_empty());
    }
}

//! Streaming trace reader: an iterator of validated [`TraceRecord`]s over
//! any `BufRead`, holding one line in memory at a time.
//!
//! The reader owns the stateful validation the per-line adapter cannot do:
//! monotone submission times across records.  Blank lines and `#` comments
//! are skipped.  The first error fuses the iterator (a trace is a totally
//! ordered replay log — there is no meaningful "skip the bad record and
//! continue").

use std::io::BufRead;

use super::schema::{SchemaAdapter, SchemaDefaults, TraceError, TraceRecord, TraceSchema};

/// Line-by-line reader; `Iterator<Item = Result<TraceRecord, TraceError>>`.
pub struct TraceReader<R: BufRead> {
    input: R,
    adapter: SchemaAdapter,
    line_no: usize,
    last_submit: f64,
    /// Fused after the first error or EOF.
    done: bool,
    buf: String,
}

impl<R: BufRead> TraceReader<R> {
    /// Read the header line, detect the schema, resolve columns.
    pub fn new(input: R) -> Result<Self, TraceError> {
        Self::with_defaults(input, SchemaDefaults::default())
    }

    /// [`TraceReader::new`] with explicit width defaults (the `[trace]`
    /// config section maps onto these).
    pub fn with_defaults(mut input: R, defaults: SchemaDefaults) -> Result<Self, TraceError> {
        let mut buf = String::new();
        let mut line_no = 0usize;
        // the header is the first non-blank, non-comment line
        let header = loop {
            buf.clear();
            let n = input
                .read_line(&mut buf)
                .map_err(|e| TraceError::Io(e.to_string()))?;
            if n == 0 {
                return Err(TraceError::EmptyTrace);
            }
            line_no += 1;
            let t = buf.trim();
            if !t.is_empty() && !t.starts_with('#') {
                break t.to_string();
            }
        };
        let adapter = SchemaAdapter::detect(&header, defaults)?;
        Ok(TraceReader {
            input,
            adapter,
            line_no,
            last_submit: f64::NEG_INFINITY,
            done: false,
            buf: String::new(),
        })
    }

    pub fn schema(&self) -> TraceSchema {
        self.adapter.schema()
    }
}

impl<R: BufRead> Iterator for TraceReader<R> {
    type Item = Result<TraceRecord, TraceError>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.done {
            return None;
        }
        loop {
            self.buf.clear();
            let n = match self.input.read_line(&mut self.buf) {
                Ok(n) => n,
                Err(e) => {
                    self.done = true;
                    return Some(Err(TraceError::Io(e.to_string())));
                }
            };
            if n == 0 {
                self.done = true;
                return None;
            }
            self.line_no += 1;
            let t = self.buf.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            let rec = match self.adapter.parse_line(self.line_no, t) {
                Ok(r) => r,
                Err(e) => {
                    self.done = true;
                    return Some(Err(e));
                }
            };
            if rec.submit_hours < self.last_submit {
                self.done = true;
                return Some(Err(TraceError::NonMonotone {
                    line: self.line_no,
                    prev_hours: self.last_submit,
                    now_hours: rec.submit_hours,
                }));
            }
            self.last_submit = rec.submit_hours;
            return Some(Ok(rec));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const DORM: &str = "\
# a comment, then the header
submit_hours,model,engine,cpus,gpus,ram_gb,weight,n_min,n_max,baseline_n,duration_hours
0.0,LR,MxNet,2,0,8,1,1,32,8,1.5

0.25,MF,TensorFlow,2,0,6,2,1,32,8,0.75
";

    #[test]
    fn reads_native_trace_with_comments_and_blanks() {
        let mut r = TraceReader::new(Cursor::new(DORM)).unwrap();
        assert_eq!(r.schema(), TraceSchema::Dorm);
        let a = r.next().unwrap().unwrap();
        assert_eq!(a.tag, "LR");
        assert_eq!(a.submit_hours, 0.0);
        assert_eq!(a.baseline_n, 8);
        let b = r.next().unwrap().unwrap();
        assert_eq!(b.tag, "MF");
        assert!((b.duration_hours - 0.75).abs() < 1e-12);
        assert!(r.next().is_none());
        assert!(r.next().is_none(), "fused after EOF");
    }

    #[test]
    fn empty_input_is_typed() {
        assert_eq!(TraceReader::new(Cursor::new("")).err(), Some(TraceError::EmptyTrace));
        assert_eq!(
            TraceReader::new(Cursor::new("# only comments\n\n")).err(),
            Some(TraceError::EmptyTrace)
        );
    }

    #[test]
    fn non_monotone_times_fuse_the_stream() {
        let text = "start_time,job_name,plan_cpu,plan_mem,duration\n\
                    3600, a, 100, 4, 60\n\
                    1800, b, 100, 4, 60\n\
                    7200, c, 100, 4, 60\n";
        let mut r = TraceReader::new(Cursor::new(text)).unwrap();
        assert!(r.next().unwrap().is_ok());
        let e = r.next().unwrap().unwrap_err();
        assert_eq!(e, TraceError::NonMonotone { line: 3, prev_hours: 1.0, now_hours: 0.5 });
        assert!(r.next().is_none(), "errors fuse the reader");
    }

    #[test]
    fn bad_row_fuses_the_stream() {
        let text = "start_time,job_name,plan_cpu,plan_mem,duration\n\
                    0, a, 100, 4, 60\n\
                    10, b, 100, 4\n";
        let mut r = TraceReader::new(Cursor::new(text)).unwrap();
        assert!(r.next().unwrap().is_ok());
        assert!(matches!(r.next().unwrap().unwrap_err(), TraceError::ShortRow { .. }));
        assert!(r.next().is_none());
    }

    #[test]
    fn equal_times_are_fine() {
        let text = "start_time,job_name,plan_cpu,plan_mem,duration\n\
                    0, a, 100, 4, 60\n\
                    0, b, 100, 4, 60\n";
        let r = TraceReader::new(Cursor::new(text)).unwrap();
        let recs: Result<Vec<_>, _> = r.collect();
        assert_eq!(recs.unwrap().len(), 2);
    }
}

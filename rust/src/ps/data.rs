//! Deterministic synthetic training data, sharded by worker slot.
//!
//! Every model gets a *teacher*: a fixed function drawn from the data seed
//! that labels random inputs.  Losses therefore decrease toward the
//! teacher's noise floor, giving the examples a real learning signal
//! without external datasets (the paper's Criteo/MovieLens/ImageNet
//! corpora are substituted per DESIGN.md §1).
//!
//! Shard determinism: batch for (worker w, step s) depends only on
//! (data_seed, w, s) — rescaling from W to W′ workers replays distinct,
//! well-defined shards, so checkpoint/resume at a different scale is
//! exactly data-parallel training at the new width.

use crate::runtime::{ModelMeta, TensorData};
#[cfg(test)]
use crate::runtime::Dtype;
use crate::util::Rng;

/// Synthetic shard generator for one application.
#[derive(Clone, Debug)]
pub struct ShardGen {
    meta: ModelMeta,
    data_seed: u64,
    /// Teacher parameters (model-family specific).
    teacher: Vec<f32>,
}

impl ShardGen {
    pub fn new(meta: &ModelMeta, data_seed: u64) -> Self {
        let mut rng = Rng::new(data_seed ^ 0x7EAC_4E2A);
        let teacher_len = match meta.name.as_str() {
            n if n.starts_with("lr") => meta.x_shape.get(1).copied().unwrap_or(1),
            n if n.starts_with("mf") => 64,
            _ => 0, // token models use an arithmetic successor teacher
        };
        let teacher = (0..teacher_len).map(|_| rng.normal() as f32).collect();
        ShardGen { meta: meta.clone(), data_seed, teacher }
    }

    fn rng_for(&self, worker: u32, step: u64) -> Rng {
        Rng::new(
            self.data_seed
                ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ step.wrapping_mul(0xBF58_476D_1CE4_E5B9),
        )
    }

    /// The (x, y) batch for worker `worker` at step `step`.
    pub fn batch(&self, worker: u32, step: u64) -> (TensorData, TensorData) {
        let mut rng = self.rng_for(worker, step);
        match self.meta.name.as_str() {
            n if n.starts_with("lr") => self.lr_batch(&mut rng),
            n if n.starts_with("mf") => self.mf_batch(&mut rng),
            _ => self.lm_batch(&mut rng),
        }
    }

    /// LR: x ~ N(0,1); y = 1[x·teacher > 0] with 5% label noise.
    fn lr_batch(&self, rng: &mut Rng) -> (TensorData, TensorData) {
        let b = self.meta.x_shape[0];
        let d = self.meta.x_shape[1];
        let x: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..b)
            .map(|i| {
                let z: f32 = (0..d).map(|j| x[i * d + j] * self.teacher[j]).sum();
                let label = if z > 0.0 { 1.0 } else { 0.0 };
                if rng.f64() < 0.05 { 1.0 - label } else { label }
            })
            .collect();
        (TensorData::F32(x), TensorData::F32(y))
    }

    /// MF: (user, item) uniform; rating from a smooth low-rank-ish teacher.
    fn mf_batch(&self, rng: &mut Rng) -> (TensorData, TensorData) {
        let b = self.meta.x_shape[0];
        let nu = self.meta.meta_usize("n_users").unwrap_or(64) as u64;
        let ni = self.meta.meta_usize("n_items").unwrap_or(64) as u64;
        let mut x = Vec::with_capacity(b * 2);
        let mut y = Vec::with_capacity(b);
        for _ in 0..b {
            let u = rng.below(nu) as i32;
            let i = rng.below(ni) as i32;
            x.push(u);
            x.push(i);
            let t = |k: usize| self.teacher[k % self.teacher.len()];
            let rating = (u as f32 * 0.13 + t(u as usize)).sin()
                + (i as f32 * 0.07 + t(i as usize)).cos()
                + 0.05 * rng.normal() as f32;
            y.push(rating);
        }
        (TensorData::I32(x), TensorData::F32(y))
    }

    /// LM: token sequences from a deterministic successor chain
    /// (next = cur*31 + 7 mod V) with 10% uniform noise — fully learnable,
    /// so cross-entropy falls from ln V toward the noise floor.
    fn lm_batch(&self, rng: &mut Rng) -> (TensorData, TensorData) {
        let b = self.meta.x_shape[0];
        let s = self.meta.x_shape[1];
        let v = self.meta.meta_usize("vocab").unwrap_or(256) as i64;
        let mut x = Vec::with_capacity(b * s);
        let mut y = Vec::with_capacity(b * s);
        for _ in 0..b {
            let mut cur = rng.below(v as u64) as i64;
            for _ in 0..s {
                x.push(cur as i32);
                let mut next = (cur * 31 + 7) % v;
                if rng.f64() < 0.10 {
                    next = rng.below(v as u64) as i64;
                }
                y.push(next as i32);
                cur = next;
            }
        }
        (TensorData::I32(x), TensorData::I32(y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn meta(name: &str, x_shape: Vec<usize>, x_dtype: Dtype, y_shape: Vec<usize>, y_dtype: Dtype,
            extra: &[(&str, &str)]) -> ModelMeta {
        ModelMeta {
            name: name.into(),
            n_params: 1,
            x_shape,
            x_dtype,
            y_shape,
            y_dtype,
            meta: extra.iter().map(|&(k, v)| (k.into(), v.into())).collect(),
            init_path: "/dev/null".into(),
            grad_path: "/dev/null".into(),
            apply_path: "/dev/null".into(),
        }
    }

    #[test]
    fn shards_deterministic_and_distinct() {
        let m = meta("lr", vec![8, 4], Dtype::F32, vec![8], Dtype::F32, &[("d", "4")]);
        let g = ShardGen::new(&m, 5);
        let (x1, _) = g.batch(0, 0);
        let (x2, _) = g.batch(0, 0);
        let (x3, _) = g.batch(1, 0);
        let (x4, _) = g.batch(0, 1);
        let as_f32 = |t: &TensorData| match t {
            TensorData::F32(v) => v.clone(),
            _ => panic!(),
        };
        assert_eq!(as_f32(&x1), as_f32(&x2), "same (worker, step) must replay");
        assert_ne!(as_f32(&x1), as_f32(&x3), "workers get distinct shards");
        assert_ne!(as_f32(&x1), as_f32(&x4), "steps get distinct batches");
    }

    #[test]
    fn mf_batch_bounds() {
        let m = meta("mf", vec![16, 2], Dtype::I32, vec![16], Dtype::F32,
                     &[("n_users", "32"), ("n_items", "16")]);
        let g = ShardGen::new(&m, 1);
        let (x, y) = g.batch(0, 0);
        let TensorData::I32(ids) = x else { panic!() };
        let TensorData::F32(ratings) = y else { panic!() };
        assert_eq!(ids.len(), 32);
        assert_eq!(ratings.len(), 16);
        for pair in ids.chunks(2) {
            assert!(pair[0] >= 0 && pair[0] < 32);
            assert!(pair[1] >= 0 && pair[1] < 16);
        }
    }

    #[test]
    fn lm_successor_structure() {
        let m = meta("tfm", vec![2, 32], Dtype::I32, vec![2, 32], Dtype::I32,
                     &[("vocab", "64")]);
        let g = ShardGen::new(&m, 9);
        let (x, y) = g.batch(0, 0);
        let (TensorData::I32(xs), TensorData::I32(ys)) = (x, y) else { panic!() };
        // most targets follow the successor rule (90%)
        let mut follow = 0;
        for (xi, yi) in xs.iter().zip(&ys) {
            if *yi as i64 == (*xi as i64 * 31 + 7) % 64 {
                follow += 1;
            }
        }
        assert!(follow as f64 / xs.len() as f64 > 0.8, "{follow}/{}", xs.len());
        assert!(ys.iter().all(|&t| t >= 0 && t < 64));
    }

    #[test]
    fn unused_meta_map_is_fine() {
        let _ = BTreeMap::<String, String>::new();
    }
}

//! DormMaster: the central manager (§III-A-1) driving the live runtime.
//!
//! Owns the cluster bookkeeping and the checkpoint store; talks to
//! per-server [`DormSlave`]s for container lifecycle and to the PS runtime
//! ([`crate::ps::Trainer`]) for the actual training work.  All scheduling
//! goes through a [`CmsPolicy`] — by default Dorm's shared
//! [`crate::sched::AllocationEngine`] (the same code the simulator runs),
//! but any policy, including the [`crate::baselines`], can drive the live
//! master via [`DormMaster::with_policy`].  The §III-C-2 adjustment
//! protocol and the Fig. 5 flow:
//!
//! 1. submission / completion snapshots the cluster and asks the policy;
//! 2. new allocations are enforced by destroying/creating containers;
//! 3. adjusted apps are checkpointed, killed and resumed at the new scale.
//!
//! When no compute service is attached (e.g. artifacts not built) the
//! master still performs all resource management — apps are bookkeeping
//! entries without trainers, which is what the control-plane tests use.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::app::{AppId, AppSpec, AppState, CheckpointStore};
use crate::cluster::ServerId;
use crate::config::{ClusterConfig, DormConfig};
use crate::optimizer::SolveMode;
use crate::ps::{Trainer, TrainerConfig};
use crate::resources::Res;
use crate::runtime::{ComputeHandle, Manifest};
use crate::sched::{AllocationUpdate, CmsPolicy, DormPolicy, SchedApp, SchedCtx};
use crate::slave::DormSlave;

/// One application under management.
pub struct ManagedApp {
    pub id: AppId,
    pub spec: AppSpec,
    pub state: AppState,
    /// Model name (from `cmd[0]`) when a compute service is attached.
    pub model: Option<String>,
    pub trainer: Option<Trainer>,
    /// Kill/resume cycles this app went through (Fig. 9b bookkeeping).
    pub adjustments: u32,
}

/// The central manager.
pub struct DormMaster {
    pub slaves: Vec<DormSlave>,
    policy: Box<dyn CmsPolicy>,
    store: CheckpointStore,
    compute: Option<(ComputeHandle, Manifest)>,
    apps: BTreeMap<AppId, ManagedApp>,
    next_id: u64,
    /// Total adjusted-app count (Eq. 4 accumulated).
    pub total_adjustments: u32,
}

impl DormMaster {
    /// A master running the paper's system: the shared allocation engine
    /// with the given θ thresholds.
    pub fn new(
        cluster: &ClusterConfig,
        dorm: DormConfig,
        store: CheckpointStore,
    ) -> Self {
        Self::with_policy(
            cluster,
            Box::new(DormPolicy::with_mode(dorm, SolveMode::Heuristic)),
            store,
        )
    }

    /// A master driven by an arbitrary [`CmsPolicy`] — the same objects the
    /// simulator runs (Dorm, static/Swarm, Mesos app-level, IaaS, ...).
    pub fn with_policy(
        cluster: &ClusterConfig,
        policy: Box<dyn CmsPolicy>,
        store: CheckpointStore,
    ) -> Self {
        DormMaster {
            slaves: cluster
                .servers
                .iter()
                .map(|s| DormSlave::new(s.name.clone(), s.capacity.clone()))
                .collect(),
            policy,
            store,
            compute: None,
            apps: BTreeMap::new(),
            next_id: 0,
            total_adjustments: 0,
        }
    }

    /// Attach the PJRT compute service: submitted apps now get trainers.
    pub fn with_compute(mut self, handle: ComputeHandle, manifest: Manifest) -> Self {
        self.compute = Some((handle, manifest));
        self
    }

    /// §III-B: submit the 6-tuple. Returns the assigned id; triggers an
    /// allocation round.
    pub fn submit(&mut self, spec: AppSpec) -> Result<AppId> {
        spec.validate().context("invalid submission")?;
        self.next_id += 1;
        let id = AppId(self.next_id);
        let model = self.compute.is_some().then(|| spec.cmd[0].clone());
        if let (Some((_, manifest)), Some(m)) = (&self.compute, &model) {
            let meta = manifest.model(m)?;
            if meta.n_params == 0 {
                bail!("model {m} has no parameters");
            }
        }
        self.apps.insert(
            id,
            ManagedApp {
                id,
                spec,
                state: AppState::Pending,
                model,
                trainer: None,
                adjustments: 0,
            },
        );
        self.reallocate()?;
        Ok(id)
    }

    /// Mark an app completed (trainer converged / user cancelled), free its
    /// partition and re-optimize for the survivors.
    pub fn complete(&mut self, id: AppId) -> Result<()> {
        let app = self
            .apps
            .get_mut(&id)
            .ok_or_else(|| anyhow!("unknown app {id}"))?;
        if app.state.is_terminal() {
            bail!("{id} already terminal");
        }
        app.state = AppState::Completed;
        app.trainer = None;
        for s in &mut self.slaves {
            s.destroy_all(id);
        }
        let _ = self.store.gc(id);
        self.reallocate()?;
        Ok(())
    }

    /// Containers currently held by `id` across all slaves.
    pub fn containers_of(&self, id: AppId) -> u32 {
        self.slaves.iter().map(|s| s.count_for(id)).sum()
    }

    /// Current xᵢⱼ row for `id`.
    fn placement_of(&self, id: AppId) -> BTreeMap<ServerId, u32> {
        self.slaves
            .iter()
            .enumerate()
            .filter_map(|(j, s)| {
                let c = s.count_for(id);
                (c > 0).then_some((ServerId(j), c))
            })
            .collect()
    }

    /// Eq. 1 over the slaves' double-entry books.
    pub fn utilization(&self) -> f64 {
        let m = self.slaves.first().map(|s| s.capacity().m()).unwrap_or(0);
        let (used, cap) = self.slaves.iter().fold(
            (Res::zeros(m), Res::zeros(m)),
            |(mut u, mut c), s| {
                u += &s.used();
                c += s.capacity();
                (u, c)
            },
        );
        used.utilization_sum(&cap)
    }

    /// Snapshot the cluster, ask the policy, enforce the update (§III-C).
    /// The snapshot/decide/enforce split is what lets the DES and the live
    /// master share every policy: this method is the live counterpart of
    /// the simulator's event handler.
    pub fn reallocate(&mut self) -> Result<()> {
        let capacities: Vec<Res> = self.slaves.iter().map(|s| s.capacity().clone()).collect();

        let mut snapshot: BTreeMap<AppId, SchedApp> = BTreeMap::new();
        for app in self.apps.values() {
            if app.state.is_terminal() {
                continue;
            }
            let placement = self.placement_of(app.id);
            snapshot.insert(
                app.id,
                SchedApp {
                    id: app.id,
                    demand: app.spec.demand.clone(),
                    weight: app.spec.weight as f64,
                    n_min: app.spec.n_min,
                    n_max: app.spec.n_max,
                    containers: placement.values().sum(),
                    placement,
                    // ids are assigned in submission order, so they double
                    // as the FIFO key (the DES uses simulated hours)
                    submit: app.id.0 as f64,
                    // static policies run the app at its requested width
                    baseline_n: app.spec.n_max,
                    engine: app.spec.executor,
                },
            );
        }

        let update = {
            let ctx = SchedCtx {
                now: self.next_id as f64,
                apps: &snapshot,
                capacities: &capacities,
            };
            self.policy.on_change(&ctx)
        };
        let Some(update) = update else {
            log::warn!("no feasible allocation; keeping existing partitions");
            return Ok(());
        };

        self.enforce(update)
    }

    /// Fig. 5 steps (3)–(4): destroy/create containers, checkpoint + kill +
    /// resume the adjusted apps, start the newly admitted ones.
    fn enforce(&mut self, update: AllocationUpdate) -> Result<()> {
        let adjusted: Vec<AppId> = update.adjusted.clone();

        // (a) checkpoint + kill adjusted apps before touching containers
        for id in &adjusted {
            let Some(app) = self.apps.get_mut(id) else {
                log::warn!("policy adjusted unknown {id}; ignoring");
                continue;
            };
            if let Some(trainer) = &app.trainer {
                app.state = AppState::Checkpointing;
                trainer.checkpoint(&self.store).context("checkpoint")?;
            }
            app.trainer = None;
            app.state = AppState::Killed;
            app.adjustments += 1;
        }
        self.total_adjustments += adjusted.len() as u32;

        // (b) diff the target assignment against the slaves' books:
        // all destroys first (shrinkers free the space), then all creates
        let active: Vec<AppId> = self
            .apps
            .iter()
            .filter(|(_, a)| !a.state.is_terminal())
            .map(|(id, _)| *id)
            .collect();
        let mut creates: Vec<(AppId, BTreeMap<ServerId, u32>)> = Vec::new();
        for id in &active {
            let target = update.assignment.get(id).cloned().unwrap_or_default();
            let current = self.placement_of(*id);
            if target == current {
                continue;
            }
            for (sid, cnt) in &current {
                self.slaves[sid.0].destroy(*id, *cnt)?;
            }
            creates.push((*id, target));
        }
        for (id, target) in &creates {
            let demand = self.apps[id].spec.demand.clone();
            for (sid, cnt) in target {
                self.slaves[sid.0].create(*id, &demand, *cnt)?;
            }
        }

        // (c) resume adjusted + start newly admitted apps
        let ids: Vec<AppId> = self.apps.keys().copied().collect();
        for id in ids {
            let held = self.containers_of(id);
            let app = self.apps.get_mut(&id).unwrap();
            if app.state.is_terminal() {
                continue;
            }
            match app.state {
                AppState::Killed if held > 0 => {
                    // resume from checkpoint at the new width
                    if let (Some((h, manifest)), Some(model)) = (&self.compute, &app.model) {
                        let meta = manifest.model(model)?;
                        let cfg = TrainerConfig {
                            workers: held,
                            ..TrainerConfig::default()
                        };
                        app.state = AppState::Resuming;
                        app.trainer = Some(
                            Trainer::resume(id, meta, h.clone(), cfg, &self.store)
                                .context("resume")?,
                        );
                    }
                    app.state = AppState::Running;
                }
                AppState::Pending if held > 0 => {
                    if let (Some((h, manifest)), Some(model)) = (&self.compute, &app.model) {
                        let meta = manifest.model(model)?;
                        let cfg = TrainerConfig {
                            workers: held,
                            ..TrainerConfig::default()
                        };
                        app.trainer = Some(
                            Trainer::new(id, meta, h.clone(), cfg).context("start")?,
                        );
                    }
                    app.state = AppState::Running;
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Drive every running trainer `steps` BSP steps (time-shared on this
    /// 1-core image). Returns (app, step, loss) logs.
    pub fn train_round(&mut self, steps: u64) -> Result<Vec<(AppId, u64, f32)>> {
        let mut out = Vec::new();
        for app in self.apps.values_mut() {
            if let Some(t) = &mut app.trainer {
                let log = t.run(steps)?;
                out.push((app.id, log.step, log.loss));
            }
        }
        Ok(out)
    }

    pub fn app_state(&self, id: AppId) -> Option<AppState> {
        self.apps.get(&id).map(|a| a.state)
    }

    pub fn app(&self, id: AppId) -> Option<&ManagedApp> {
        self.apps.get(&id)
    }

    pub fn store(&self) -> &CheckpointStore {
        &self.store
    }

    /// Active (non-terminal) app count.
    pub fn active_apps(&self) -> usize {
        self.apps.values().filter(|a| !a.state.is_terminal()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::Engine;

    fn store(tag: &str) -> CheckpointStore {
        let d = std::env::temp_dir().join(format!("dorm_master_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        CheckpointStore::new(d).unwrap()
    }

    fn spec(cpu: f64, gpu: f64, ram: f64, w: u32, lo: u32, hi: u32) -> AppSpec {
        AppSpec {
            executor: Engine::MxNet,
            demand: Res::cpu_gpu_ram(cpu, gpu, ram),
            weight: w,
            n_max: hi,
            n_min: lo,
            cmd: ["lr".into(), "lr".into()],
        }
    }

    fn master(tag: &str) -> DormMaster {
        DormMaster::new(
            &ClusterConfig::uniform(4, Res::cpu_gpu_ram(12.0, 0.0, 64.0)),
            DormConfig { theta1: 0.5, theta2: 0.5 },
            store(tag),
        )
    }

    #[test]
    fn lone_app_gets_max_partition() {
        let mut m = master("lone");
        let id = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 12)).unwrap();
        assert_eq!(m.app_state(id), Some(AppState::Running));
        assert_eq!(m.containers_of(id), 12);
        assert!(m.utilization() > 0.0);
    }

    #[test]
    fn second_submission_shrinks_first() {
        let mut m = master("shrink");
        let a = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 24)).unwrap();
        assert_eq!(m.containers_of(a), 24); // all 48 CPUs
        let b = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 24)).unwrap();
        // capacity: 48 CPUs -> 24 containers split between the two
        let (ca, cb) = (m.containers_of(a), m.containers_of(b));
        assert!(ca + cb <= 24);
        assert!(cb >= 1, "newcomer must be admitted");
        assert!(m.total_adjustments >= 1, "first app was adjusted");
        assert_eq!(m.app_state(a), Some(AppState::Running));
        assert_eq!(m.app_state(b), Some(AppState::Running));
    }

    #[test]
    fn completion_releases_and_regrows() {
        let mut m = master("release");
        let a = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 24)).unwrap();
        let b = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 24)).unwrap();
        m.complete(a).unwrap();
        assert_eq!(m.app_state(a), Some(AppState::Completed));
        assert_eq!(m.containers_of(a), 0);
        // survivor takes the freed capacity (within θ₂ limits: 1 app -> 1 adjustment allowed)
        assert!(m.containers_of(b) > 12, "{}", m.containers_of(b));
        assert!(m.complete(a).is_err(), "double completion rejected");
    }

    #[test]
    fn invalid_submissions_rejected() {
        let mut m = master("invalid");
        assert!(m.submit(spec(2.0, 0.0, 8.0, 1, 0, 4)).is_err()); // n_min 0
        assert!(m.submit(spec(2.0, 0.0, 8.0, 0, 1, 4)).is_err()); // weight 0
        assert_eq!(m.active_apps(), 0);
    }

    #[test]
    fn oversized_floor_defers_app() {
        let mut m = master("defer");
        // demands exceed the whole cluster -> stays pending
        let id = m.submit(spec(50.0, 0.0, 8.0, 1, 1, 2)).unwrap();
        assert_eq!(m.app_state(id), Some(AppState::Pending));
        assert_eq!(m.containers_of(id), 0);
    }

    #[test]
    fn static_baseline_drives_live_master() {
        use crate::baselines::StaticPolicy;
        let cluster = ClusterConfig::uniform(4, Res::cpu_gpu_ram(12.0, 0.0, 64.0));
        let mut m = DormMaster::with_policy(
            &cluster,
            Box::new(StaticPolicy::new()),
            store("static"),
        );
        // the Swarm baseline gives each app its fixed width and never
        // resizes — now running against the real control plane
        let a = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 8)).unwrap();
        assert_eq!(m.containers_of(a), 8);
        let b = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 8)).unwrap();
        assert_eq!(m.containers_of(a), 8, "static never resizes");
        assert_eq!(m.containers_of(b), 8);
        assert_eq!(m.total_adjustments, 0);
        // an app whose full fixed partition does not fit waits pending
        let c = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 16)).unwrap();
        assert_eq!(m.app_state(c), Some(AppState::Pending));
        assert_eq!(m.containers_of(c), 0);
        // completion frees space; the queued app starts at full width
        m.complete(a).unwrap();
        assert_eq!(m.containers_of(c), 16);
        assert_eq!(m.app_state(c), Some(AppState::Running));
        assert_eq!(m.total_adjustments, 0, "static adjusted nothing");
    }

    #[test]
    fn dorm_master_reuses_engine_cache_on_identical_snapshots() {
        let mut m = master("cache");
        let id = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 12)).unwrap();
        let held = m.containers_of(id);
        // no state change between explicit re-solves: snapshot identical,
        // so the engine must answer from its cache and change nothing
        m.reallocate().unwrap();
        m.reallocate().unwrap();
        assert_eq!(m.containers_of(id), held);
        assert_eq!(m.total_adjustments, 0);
    }

    #[test]
    fn slave_books_match_master_utilization() {
        let mut m = master("books");
        let _ = m.submit(spec(3.0, 0.0, 16.0, 1, 1, 8)).unwrap();
        let _ = m.submit(spec(2.0, 0.0, 8.0, 2, 1, 8)).unwrap();
        // every slave within capacity
        for s in &m.slaves {
            assert!(s.used().fits_in(s.capacity()), "{}", s.name);
        }
        assert!(m.utilization() > 0.0 && m.utilization() <= 3.0);
    }
}

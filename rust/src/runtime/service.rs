//! The compute service: a dedicated thread owning the PJRT CPU client and
//! every compiled model executable, serving init/grad/apply requests over
//! channels (see module docs in `mod.rs` for why a single owner thread).

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{Dtype, Manifest, ModelMeta};

/// An input tensor crossing the service boundary.
#[derive(Clone, Debug)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn dtype(&self) -> Dtype {
        match self {
            TensorData::F32(_) => Dtype::F32,
            TensorData::I32(_) => Dtype::I32,
        }
    }
}

/// grad() output: loss + flat gradient.
#[derive(Clone, Debug)]
pub struct GradOut {
    pub loss: f32,
    pub grads: Vec<f32>,
}

enum Request {
    Init { model: String, seed: i32, reply: mpsc::Sender<Result<Vec<f32>>> },
    Grad {
        model: String,
        params: Vec<f32>,
        x: TensorData,
        y: TensorData,
        reply: mpsc::Sender<Result<GradOut>>,
    },
    Apply {
        model: String,
        params: Vec<f32>,
        gsum: Vec<f32>,
        count: f32,
        lr: f32,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Stats { reply: mpsc::Sender<ServiceStats> },
    Shutdown,
}

/// Execution counters (perf pass bookkeeping).
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub init_calls: u64,
    pub grad_calls: u64,
    pub apply_calls: u64,
    pub exec_micros: u128,
}

/// Cloneable handle to the compute-service thread.
#[derive(Clone)]
pub struct ComputeHandle {
    tx: mpsc::Sender<Request>,
}

impl ComputeHandle {
    /// Run `{model}_init`: seed -> params.
    pub fn init(&self, model: &str, seed: i32) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Init { model: model.into(), seed, reply })
            .map_err(|_| anyhow!("compute service down"))?;
        rx.recv().map_err(|_| anyhow!("compute service died"))?
    }

    /// Run `{model}_grad`: (params, x, y) -> (loss, grads).
    pub fn grad(&self, model: &str, params: Vec<f32>, x: TensorData, y: TensorData) -> Result<GradOut> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Grad { model: model.into(), params, x, y, reply })
            .map_err(|_| anyhow!("compute service down"))?;
        rx.recv().map_err(|_| anyhow!("compute service died"))?
    }

    /// Run `{model}_apply`: SGD over summed worker grads.
    pub fn apply(&self, model: &str, params: Vec<f32>, gsum: Vec<f32>, count: f32, lr: f32) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Apply { model: model.into(), params, gsum, count, lr, reply })
            .map_err(|_| anyhow!("compute service down"))?;
        rx.recv().map_err(|_| anyhow!("compute service died"))?
    }

    pub fn stats(&self) -> Result<ServiceStats> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Stats { reply })
            .map_err(|_| anyhow!("compute service down"))?;
        rx.recv().map_err(|_| anyhow!("compute service died"))
    }
}

/// The service: spawn once, hand out [`ComputeHandle`]s.
pub struct ComputeService {
    handle: ComputeHandle,
    join: Option<JoinHandle<()>>,
    tx: mpsc::Sender<Request>,
}

struct Compiled {
    init: xla::PjRtLoadedExecutable,
    grad: xla::PjRtLoadedExecutable,
    apply: xla::PjRtLoadedExecutable,
    meta: ModelMeta,
}

impl ComputeService {
    /// Start the service thread: creates the PJRT CPU client and compiles
    /// every model in the manifest (reported errors fail the constructor).
    pub fn start(manifest: &Manifest) -> Result<ComputeService> {
        Self::start_filtered(manifest, None)
    }

    /// As [`ComputeService::start`] but compiling only the named models —
    /// XLA compilation of the big transformer takes tens of seconds on
    /// this 1-core image, so tests and examples compile what they use.
    pub fn start_filtered(manifest: &Manifest, only: Option<&[&str]>) -> Result<ComputeService> {
        let mut manifest = manifest.clone();
        if let Some(names) = only {
            manifest.models.retain(|k, _| names.contains(&k.as_str()));
        }
        let manifest = manifest;
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("dorm-compute".into())
            .spawn(move || service_main(manifest, rx, ready_tx))
            .context("spawning compute thread")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("compute service died during startup"))??;
        Ok(ComputeService {
            handle: ComputeHandle { tx: tx.clone() },
            join: Some(join),
            tx,
        })
    }

    pub fn handle(&self) -> ComputeHandle {
        self.handle.clone()
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn service_main(manifest: Manifest, rx: mpsc::Receiver<Request>, ready: mpsc::Sender<Result<()>>) {
    let setup = (|| -> Result<(xla::PjRtClient, BTreeMap<String, Compiled>)> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e}"))?;
        let mut compiled = BTreeMap::new();
        for (name, meta) in &manifest.models {
            let load = |p: &std::path::Path| -> Result<xla::PjRtLoadedExecutable> {
                let proto = xla::HloModuleProto::from_text_file(
                    p.to_str().ok_or_else(|| anyhow!("bad path"))?,
                )
                .map_err(|e| anyhow!("parsing {}: {e}", p.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {}: {e}", p.display()))
            };
            compiled.insert(
                name.clone(),
                Compiled {
                    init: load(&meta.init_path)?,
                    grad: load(&meta.grad_path)?,
                    apply: load(&meta.apply_path)?,
                    meta: meta.clone(),
                },
            );
            log::info!("compiled model {name} ({} params)", meta.n_params);
        }
        Ok((client, compiled))
    })();

    let (_client, compiled) = match setup {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let mut stats = ServiceStats::default();
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Stats { reply } => {
                let _ = reply.send(stats.clone());
            }
            Request::Init { model, seed, reply } => {
                let t0 = std::time::Instant::now();
                let out = run_init(&compiled, &model, seed);
                stats.init_calls += 1;
                stats.exec_micros += t0.elapsed().as_micros();
                let _ = reply.send(out);
            }
            Request::Grad { model, params, x, y, reply } => {
                let t0 = std::time::Instant::now();
                let out = run_grad(&compiled, &model, &params, &x, &y);
                stats.grad_calls += 1;
                stats.exec_micros += t0.elapsed().as_micros();
                let _ = reply.send(out);
            }
            Request::Apply { model, params, gsum, count, lr, reply } => {
                let t0 = std::time::Instant::now();
                let out = run_apply(&compiled, &model, &params, &gsum, count, lr);
                stats.apply_calls += 1;
                stats.exec_micros += t0.elapsed().as_micros();
                let _ = reply.send(out);
            }
        }
    }
}

fn get<'a>(compiled: &'a BTreeMap<String, Compiled>, model: &str) -> Result<&'a Compiled> {
    compiled
        .get(model)
        .ok_or_else(|| anyhow!("model {model:?} not loaded"))
}

fn tensor_literal(data: &TensorData, shape: &[usize], expect: Dtype) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let n: usize = shape.iter().product();
    if data.len() != n {
        bail!("tensor has {} elements, shape {shape:?} wants {n}", data.len());
    }
    if data.dtype() != expect {
        bail!("dtype mismatch: got {:?}, expected {expect:?}", data.dtype());
    }
    let lit = match data {
        TensorData::F32(v) => xla::Literal::vec1(v),
        TensorData::I32(v) => xla::Literal::vec1(v),
    };
    lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e}"))
}

fn params_literal(params: &[f32], n: usize) -> Result<xla::Literal> {
    if params.len() != n {
        bail!("params has {} elements, model wants {n}", params.len());
    }
    Ok(xla::Literal::vec1(params))
}

fn first_result(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<xla::Literal> {
    let bufs = exe.execute::<xla::Literal>(args).map_err(|e| anyhow!("execute: {e}"))?;
    bufs[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal: {e}"))
}

fn run_init(compiled: &BTreeMap<String, Compiled>, model: &str, seed: i32) -> Result<Vec<f32>> {
    let c = get(compiled, model)?;
    let seed_lit = xla::Literal::scalar(seed);
    let out = first_result(&c.init, &[seed_lit])?;
    let params = out.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
    params.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))
}

fn run_grad(
    compiled: &BTreeMap<String, Compiled>,
    model: &str,
    params: &[f32],
    x: &TensorData,
    y: &TensorData,
) -> Result<GradOut> {
    let c = get(compiled, model)?;
    let p = params_literal(params, c.meta.n_params)?;
    let xl = tensor_literal(x, &c.meta.x_shape, c.meta.x_dtype)?;
    let yl = tensor_literal(y, &c.meta.y_shape, c.meta.y_dtype)?;
    let out = first_result(&c.grad, &[p, xl, yl])?;
    let (loss, grads) = out.to_tuple2().map_err(|e| anyhow!("untuple2: {e}"))?;
    Ok(GradOut {
        loss: loss.to_vec::<f32>().map_err(|e| anyhow!("loss: {e}"))?[0],
        grads: grads.to_vec::<f32>().map_err(|e| anyhow!("grads: {e}"))?,
    })
}

fn run_apply(
    compiled: &BTreeMap<String, Compiled>,
    model: &str,
    params: &[f32],
    gsum: &[f32],
    count: f32,
    lr: f32,
) -> Result<Vec<f32>> {
    let c = get(compiled, model)?;
    let p = params_literal(params, c.meta.n_params)?;
    let g = params_literal(gsum, c.meta.n_params)?;
    let out = first_result(&c.apply, &[p, g, xla::Literal::scalar(count), xla::Literal::scalar(lr)])?;
    let new_params = out.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
    new_params.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<Manifest> {
        let dir = std::path::Path::new("artifacts");
        if dir.join("manifest.kv").exists() {
            Some(Manifest::load(dir).unwrap())
        } else {
            None
        }
    }

    /// End-to-end: init -> grad -> apply on the real LR artifact; loss must
    /// decrease over a few SGD steps.  Skipped when artifacts are absent
    /// (CI without `make artifacts`).
    #[test]
    fn lr_train_loop_reduces_loss() {
        let Some(manifest) = artifacts() else { return };
        let svc = ComputeService::start_filtered(&manifest, Some(&["lr"])).unwrap();
        let h = svc.handle();
        let meta = manifest.model("lr").unwrap();
        let (b, d) = (meta.x_shape[0], meta.x_shape[1]);

        // deterministic synthetic teacher data
        let mut rng = crate::util::Rng::new(7);
        let teacher: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let x: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..b)
            .map(|i| {
                let z: f32 = (0..d).map(|j| x[i * d + j] * teacher[j]).sum();
                if z > 0.0 { 1.0 } else { 0.0 }
            })
            .collect();

        let mut params = h.init("lr", 1).unwrap();
        assert_eq!(params.len(), meta.n_params);
        let first = h
            .grad("lr", params.clone(), TensorData::F32(x.clone()), TensorData::F32(y.clone()))
            .unwrap();
        let mut last = first.loss;
        for _ in 0..30 {
            let g = h
                .grad("lr", params.clone(), TensorData::F32(x.clone()), TensorData::F32(y.clone()))
                .unwrap();
            params = h.apply("lr", params, g.grads, 1.0, 0.5).unwrap();
            last = g.loss;
        }
        assert!(
            last < first.loss * 0.8,
            "loss did not decrease: {} -> {last}",
            first.loss
        );
        let stats = h.stats().unwrap();
        assert!(stats.grad_calls >= 31 && stats.apply_calls == 30);
    }

    #[test]
    fn shape_and_dtype_errors_reported() {
        let Some(manifest) = artifacts() else { return };
        let svc = ComputeService::start_filtered(&manifest, Some(&["lr"])).unwrap();
        let h = svc.handle();
        let meta = manifest.model("lr").unwrap();
        let n = meta.x_shape.iter().product::<usize>();
        // wrong param count
        assert!(h
            .grad("lr", vec![0.0; 3], TensorData::F32(vec![0.0; n]),
                  TensorData::F32(vec![0.0; meta.x_shape[0]]))
            .is_err());
        // wrong dtype
        assert!(h
            .grad("lr", vec![0.0; meta.n_params], TensorData::I32(vec![0; n]),
                  TensorData::F32(vec![0.0; meta.x_shape[0]]))
            .is_err());
        // unknown model
        assert!(h.init("bogus", 0).is_err());
    }

    /// The same seed must produce identical parameters (jax PRNG is
    /// deterministic through the AOT path).
    #[test]
    fn init_deterministic_through_pjrt() {
        let Some(manifest) = artifacts() else { return };
        let svc = ComputeService::start_filtered(&manifest, Some(&["mf"])).unwrap();
        let h = svc.handle();
        let a = h.init("mf", 42).unwrap();
        let b = h.init("mf", 42).unwrap();
        let c = h.init("mf", 43).unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}

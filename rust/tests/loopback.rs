//! Loopback integration: the TCP control plane end to end on 127.0.0.1.
//!
//! Covers the full two-process story in one process — submit → resize →
//! complete through a served master with real `SlaveAgent` event loops —
//! plus the protocol-evolution contract: version handshakes, unknown
//! request tags, malformed/truncated/oversized frames and raw byte fuzz
//! must all produce decodable typed errors (or a clean close), never a
//! panic or a hang.  Lease expiry is exercised by *actually stopping* a
//! slave's heartbeat thread: the master's own sweep declares it dead from
//! missed packets, which is the ROADMAP's "real transport" goal.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use dorm::app::{AppId, AppSpec, CheckpointStore, Engine};
use dorm::config::{ClusterConfig, DormConfig, FaultConfig, NetConfig};
use dorm::master::DormMaster;
use dorm::net::{serve, ControlPlane, ServerHandle, SlaveAgent, TcpTransport};
use dorm::proto::{wire, ErrorCode, Request, Response, PROTO_MAJOR, PROTO_MINOR};
use dorm::resources::Res;
use dorm::slave::DormSlave;
use dorm::util::Rng;

const CAP: [f64; 3] = [12.0, 0.0, 64.0];

fn store(tag: &str) -> CheckpointStore {
    let dir = std::env::temp_dir().join(format!("dorm_loopback_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    CheckpointStore::new(dir).unwrap()
}

fn net_cfg() -> NetConfig {
    NetConfig {
        bind_addr: "127.0.0.1:0".into(),
        // short enough that a stalled-peer test finishes quickly, long
        // enough that a busy CI box does not time out honest requests
        io_timeout_ms: 2000,
        ..NetConfig::default()
    }
}

fn serve_master(tag: &str, n: usize, cfg: &NetConfig, fault: Option<FaultConfig>) -> ServerHandle {
    let mut m = DormMaster::new(
        &ClusterConfig::uniform(n, Res::cpu_gpu_ram(CAP[0], CAP[1], CAP[2])),
        DormConfig { theta1: 0.5, theta2: 0.5 },
        store(tag),
    );
    if let Some(f) = fault {
        m = m.with_fault(&f);
    }
    serve(m, cfg).unwrap()
}

fn spec(n_max: u32) -> AppSpec {
    AppSpec {
        executor: Engine::MxNet,
        demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0),
        weight: 1,
        n_max,
        n_min: 1,
        cmd: ["lr".into(), "lr".into()],
    }
}

/// Raw frame client for protocol-evolution tests (no client-side decode
/// assumptions beyond the wire helpers).
struct Raw {
    stream: TcpStream,
}

impl Raw {
    fn connect(handle: &ServerHandle) -> Raw {
        let stream = TcpStream::connect(handle.addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        Raw { stream }
    }

    fn send_payload(&mut self, payload: &[u8]) {
        wire::write_frame(&mut self.stream, payload, usize::MAX).unwrap();
    }

    fn recv(&mut self) -> Result<Response, wire::WireError> {
        let payload = wire::read_frame(&mut self.stream, 1 << 20)?;
        wire::decode_response(&payload)
    }

    fn hello(&mut self) {
        self.send_payload(&wire::encode_request(&Request::Hello {
            major: PROTO_MAJOR,
            minor: PROTO_MINOR,
        }));
        match self.recv().unwrap() {
            Response::HelloAck { .. } => {}
            other => panic!("handshake answered {other:?}"),
        }
    }

    fn expect_error(&mut self, code: ErrorCode) {
        match self.recv().unwrap() {
            Response::Error(e) => assert_eq!(e.code, code, "detail: {}", e.detail),
            other => panic!("expected {code:?}, got {other:?}"),
        }
    }

    /// The server closed our connection (EOF / reset), within `deadline`.
    fn assert_closed(mut self, deadline: Duration) {
        self.stream.set_read_timeout(Some(deadline)).unwrap();
        let mut buf = [0u8; 1];
        match self.stream.read(&mut buf) {
            Ok(0) => {} // clean EOF
            Ok(_) => panic!("server kept talking on a connection it should close"),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                panic!("server left the connection open past the deadline")
            }
            Err(_) => {} // reset counts as closed
        }
    }
}

#[test]
fn submit_resize_complete_over_tcp_with_live_agents() {
    let cfg = net_cfg();
    let handle = serve_master("cycle", 2, &cfg, None);
    let addr = handle.addr().to_string();

    // two slave agents beating in their own threads, like two processes
    let stop = Arc::new(AtomicBool::new(false));
    let mut agents = Vec::new();
    for j in 0..2u32 {
        let addr = addr.clone();
        let cfg = cfg.clone();
        let stop = Arc::clone(&stop);
        agents.push(std::thread::spawn(move || {
            let t = TcpTransport::connect(&addr, &cfg).unwrap();
            let slave = DormSlave::new(format!("slave{j:02}"), Res::cpu_gpu_ram(12.0, 0.0, 64.0));
            let mut agent = SlaveAgent::new(slave, j, t);
            while !stop.load(Ordering::SeqCst) {
                agent.step(f64::NAN).unwrap();
                std::thread::sleep(Duration::from_millis(20));
            }
            agent.local().inventory()
        }));
    }

    let mut ctl = TcpTransport::connect(&addr, &cfg).unwrap();
    // submit → the lone app takes the whole 2-server cluster
    let a = match ctl.call(Request::Submit { spec: spec(12) }).unwrap() {
        Response::Submitted { app } => app,
        other => panic!("submit answered {other:?}"),
    };
    let view = |ctl: &mut TcpTransport, id: AppId| -> u32 {
        match ctl.call(Request::QueryState { app: Some(id) }).unwrap() {
            Response::State(v) => v.apps[0].containers,
            other => panic!("query answered {other:?}"),
        }
    };
    assert_eq!(view(&mut ctl, a), 12);

    // resize: a second submission shrinks the first
    let b = match ctl.call(Request::Submit { spec: spec(12) }).unwrap() {
        Response::Submitted { app } => app,
        other => panic!("submit answered {other:?}"),
    };
    let (ca, cb) = (view(&mut ctl, a), view(&mut ctl, b));
    assert!(ca < 12, "first app must shrink, holds {ca}");
    assert!(cb >= 1, "second app admitted with {cb}");

    // let the agents converge their books on the master's
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        std::thread::sleep(Duration::from_millis(50));
        let m = handle.master();
        let m = m.lock().unwrap();
        let books: u32 = (0..2).map(|j| m.slaves[j].count_for(a) + m.slaves[j].count_for(b)).sum();
        if books == ca + cb || Instant::now() > deadline {
            break;
        }
    }

    // complete both; agents drain on their next beats
    assert_eq!(ctl.call(Request::Complete { app: a }).unwrap(), Response::Ok);
    assert_eq!(ctl.call(Request::Complete { app: b }).unwrap(), Response::Ok);
    std::thread::sleep(Duration::from_millis(200));
    stop.store(true, Ordering::SeqCst);
    for h in agents {
        let inventory = h.join().unwrap();
        assert!(inventory.is_empty(), "agent book must drain, had {inventory:?}");
    }

    // clean shutdown: request acknowledged, server exits
    assert_eq!(ctl.call(Request::Shutdown).unwrap(), Response::Ok);
    let deadline = Instant::now() + Duration::from_secs(5);
    while !handle.is_stopped() && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(handle.is_stopped(), "shutdown must stop the server");
}

#[test]
fn version_handshake_rules_enforced() {
    let cfg = net_cfg();
    let handle = serve_master("versions", 1, &cfg, None);

    // matching version accepted (TcpTransport::connect performs it)
    drop(TcpTransport::connect(&handle.addr().to_string(), &cfg).unwrap());

    // newer major refused with a typed, decodable error, then closed
    let mut raw = Raw::connect(&handle);
    raw.send_payload(&wire::encode_request(&Request::Hello {
        major: PROTO_MAJOR + 1,
        minor: 0,
    }));
    raw.expect_error(ErrorCode::VersionMismatch);
    raw.assert_closed(Duration::from_secs(5));

    // newer minor likewise (it may carry requests we cannot decode)
    let mut raw = Raw::connect(&handle);
    raw.send_payload(&wire::encode_request(&Request::Hello {
        major: PROTO_MAJOR,
        minor: PROTO_MINOR + 1,
    }));
    raw.expect_error(ErrorCode::VersionMismatch);
    raw.assert_closed(Duration::from_secs(5));

    // skipping the handshake entirely is refused
    let mut raw = Raw::connect(&handle);
    raw.send_payload(&wire::encode_request(&Request::QueryState { app: None }));
    raw.expect_error(ErrorCode::HandshakeRequired);
    raw.assert_closed(Duration::from_secs(5));
}

#[test]
fn unknown_tags_and_malformed_frames_get_typed_errors() {
    let cfg = net_cfg();
    let handle = serve_master("evolution", 1, &cfg, None);
    let mut raw = Raw::connect(&handle);
    raw.hello();

    // a newer peer's unknown request tag: typed refusal, connection lives
    raw.send_payload(&[0x7f, 1, 2, 3]);
    raw.expect_error(ErrorCode::UnsupportedRequest);

    // truncated payload: Submit tag with half a spec
    let mut buf = wire::encode_request(&Request::Submit { spec: spec(4) });
    buf.truncate(buf.len() / 2);
    raw.send_payload(&buf);
    raw.expect_error(ErrorCode::MalformedFrame);

    // the same connection still serves honest requests afterwards
    raw.send_payload(&wire::encode_request(&Request::QueryState { app: None }));
    match raw.recv().unwrap() {
        Response::State(v) => assert_eq!(v.total_servers, 1),
        other => panic!("query after errors answered {other:?}"),
    }

    // an oversized frame is refused with a typed error, then closed
    // (framing cannot resync past an unread body)
    let mut raw2 = Raw::connect(&handle);
    raw2.hello();
    raw2.stream
        .write_all(&((cfg.max_frame_bytes as u32 + 1).to_be_bytes()))
        .unwrap();
    raw2.expect_error(ErrorCode::FrameTooLarge);
    raw2.assert_closed(Duration::from_secs(5));
}

#[test]
fn half_frames_and_fuzz_never_hang_the_server() {
    let cfg = NetConfig { io_timeout_ms: 300, ..net_cfg() };
    let handle = serve_master("fuzz", 1, &cfg, None);

    // a half-sent frame followed by silence: the read timeout reaps the
    // connection instead of wedging the handler thread
    let mut raw = Raw::connect(&handle);
    raw.hello();
    raw.stream.write_all(&100u32.to_be_bytes()).unwrap();
    raw.stream.write_all(&[1, 2, 3]).unwrap(); // 3 of the promised 100
    raw.assert_closed(Duration::from_secs(5));

    // deterministic fuzz: random payloads (valid framing, hostile bytes)
    // always produce a decodable error response or a clean close
    let mut rng = Rng::new(0xfeed);
    for round in 0..30 {
        let mut raw = Raw::connect(&handle);
        raw.hello();
        let len = 1 + rng.below(48) as usize;
        let mut payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        // hostile bytes may accidentally decode to a legal request; keep
        // the fuzz honest but steer clear of the three tags that would
        // change what the final liveness assertion means
        if [0x0a, 0x0b, 0x0e].contains(&payload[0]) {
            payload[0] = 0x7f; // ExpireLeases / FailServer / Shutdown
        }
        raw.send_payload(&payload);
        match raw.recv() {
            // a typed error or any well-formed response is acceptable
            Ok(_) => {}
            Err(wire::WireError::Io(_)) => {} // server chose to close
            Err(e) => panic!("round {round}: undecodable response: {e}"),
        }
    }

    // after all that abuse the server still answers honest clients
    let mut ctl = TcpTransport::connect(&handle.addr().to_string(), &cfg).unwrap();
    match ctl.call(Request::QueryState { app: None }).unwrap() {
        Response::State(v) => assert_eq!(v.alive_servers, 1),
        other => panic!("post-fuzz query answered {other:?}"),
    }
}

#[test]
fn missed_heartbeats_expire_leases_over_real_tcp() {
    // lease timeout 0.5 s, master sweeps every 50 ms: expiry is driven
    // purely by packet arrival, not by any scripted clock
    let cfg = NetConfig {
        lease_sweep_ms: 50,
        ..net_cfg()
    };
    let fault = FaultConfig {
        lease_timeout_hours: 0.5 / 3600.0,
        ..FaultConfig::default()
    };
    let handle = serve_master("lease", 2, &cfg, Some(fault));
    let addr = handle.addr().to_string();

    // both slaves beat every 50 ms from their own threads
    let stop0 = Arc::new(AtomicBool::new(false));
    let stop1 = Arc::new(AtomicBool::new(false));
    let mut threads = Vec::new();
    for (j, stop) in [(0u32, Arc::clone(&stop0)), (1u32, Arc::clone(&stop1))] {
        let addr = addr.clone();
        let cfg = cfg.clone();
        threads.push(std::thread::spawn(move || {
            let t = TcpTransport::connect(&addr, &cfg).unwrap();
            let slave = DormSlave::new(format!("slave{j:02}"), Res::cpu_gpu_ram(12.0, 0.0, 64.0));
            let mut agent = SlaveAgent::new(slave, j, t);
            while !stop.load(Ordering::SeqCst) {
                let out = agent.step(f64::NAN).unwrap();
                if !out.alive {
                    agent.rejoin(f64::NAN).unwrap();
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }));
    }

    let mut ctl = TcpTransport::connect(&addr, &cfg).unwrap();
    let a = match ctl.call(Request::Submit { spec: spec(12) }).unwrap() {
        Response::Submitted { app } => app,
        other => panic!("submit answered {other:?}"),
    };
    let state = |ctl: &mut TcpTransport| -> (u32, u32) {
        match ctl.call(Request::QueryState { app: None }).unwrap() {
            Response::State(v) => (v.alive_servers, v.apps[0].containers),
            other => panic!("query answered {other:?}"),
        }
    };
    // both agents beating (tolerate a slow-start transient: an agent that
    // connected late gets expired once and rejoins on its next beat)
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if state(&mut ctl).0 == 2 {
            break;
        }
        assert!(Instant::now() < deadline, "agents never both alive");
        std::thread::sleep(Duration::from_millis(50));
    }

    // silence slave 0: the master must notice from missed packets alone
    stop0.store(true, Ordering::SeqCst);
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        let (alive, _) = state(&mut ctl);
        if alive == 1 {
            break;
        }
        assert!(Instant::now() < deadline, "lease never expired from missed packets");
        std::thread::sleep(Duration::from_millis(50));
    }
    // the app survived on the remaining server (recovery re-solved)
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (_, containers) = state(&mut ctl);
        if (1..=6).contains(&containers) {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "app never recovered on the survivor (holds {containers})"
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    // a fresh agent process takes over server 0 and rejoins when told dead
    let addr2 = addr.clone();
    let cfg2 = cfg.clone();
    let stop0b = Arc::new(AtomicBool::new(false));
    let stop0b_t = Arc::clone(&stop0b);
    threads.push(std::thread::spawn(move || {
        let t = TcpTransport::connect(&addr2, &cfg2).unwrap();
        let slave = DormSlave::new("slave00", Res::cpu_gpu_ram(12.0, 0.0, 64.0));
        let mut agent = SlaveAgent::new(slave, 0, t);
        while !stop0b_t.load(Ordering::SeqCst) {
            let out = agent.step(f64::NAN).unwrap();
            if !out.alive {
                agent.rejoin(f64::NAN).unwrap();
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    }));
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        let (alive, _) = state(&mut ctl);
        if alive == 2 {
            break;
        }
        assert!(Instant::now() < deadline, "rejoin never landed");
        std::thread::sleep(Duration::from_millis(50));
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (_, containers) = state(&mut ctl);
        if containers >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "app lost its partition after rejoin");
        std::thread::sleep(Duration::from_millis(50));
    }
    assert_eq!(ctl.call(Request::Complete { app: a }).unwrap(), Response::Ok);

    stop1.store(true, Ordering::SeqCst);
    stop0b.store(true, Ordering::SeqCst);
    for t in threads {
        t.join().unwrap();
    }
}

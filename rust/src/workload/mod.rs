//! Workload models: the literal Table II synthetic workload and the Fig. 1
//! duration distributions fitted to the paper's stated quantiles
//! (DESIGN.md §1 — the Sensetime production trace is proprietary, so the
//! published CDF shapes are what we reproduce).

mod durations;
mod spec;
mod table2;
pub mod trace;

pub use durations::{app_duration_hours, task_duration_secs, DurationModel};
pub use spec::{WorkloadSpec, WorkloadStream};
pub use table2::{table2_rows, Table2Row, WorkloadApp, WorkloadGen};

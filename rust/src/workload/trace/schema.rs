//! Trace records, typed parse errors, and the schema-adapter layer that
//! maps foreign CSV column layouts onto [`TraceRecord`].
//!
//! Three layouts are recognized, detected from the header line:
//!
//! * **dorm** — the native export schema (`submit_hours,model,engine,…`),
//!   lossless round-trip with [`super::export`].
//! * **alibaba** — an Alibaba-cluster-trace-like job table
//!   (`start_time` seconds, `plan_cpu` in centi-cores, `plan_mem` GB,
//!   `inst_num` instances, `duration` seconds).
//! * **borg** — a Google-Borg-like task-events layout (`time` in
//!   microseconds, `cpu_request`/`memory_request` as fractions of one
//!   nominal machine, `priority`, `instances`, `runtime` seconds).
//!
//! Columns are resolved *by name*, not position, so reordered or
//! extra columns in a foreign trace are fine; a missing required column
//! is a typed [`TraceError::MissingColumn`].  Every field is validated on
//! parse — NaN, negative demand, non-positive duration and non-monotone
//! timestamps are all typed errors, never panics (`tests/trace.rs`
//! feeds the hostile cases).

use crate::app::Engine;
use crate::resources::Res;
use crate::sim::SimArrival;

/// Nominal machine the Borg-like normalized requests are scaled by:
/// ⟨cores, GPUs, RAM GB⟩.  Borg traces publish requests as fractions of
/// the largest machine; any consistent scale works for replay since the
/// cluster config is chosen to match.
pub const BORG_MACHINE: [f64; 3] = [64.0, 0.0, 256.0];

/// One parsed job-arrival record, schema-independent.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// Submission time, hours from trace start (non-negative, finite,
    /// non-decreasing across a trace).
    pub submit_hours: f64,
    /// Job tag (model name / job id) — metrics grouping only.
    pub tag: String,
    pub engine: Engine,
    /// Per-container demand vector ⟨CPUs, GPUs, RAM GB⟩.
    pub demand: Res,
    pub weight: f64,
    pub n_min: u32,
    pub n_max: u32,
    /// Container count the static baselines pin this job at.
    pub baseline_n: u32,
    /// Duration at `baseline_n` containers, hours (positive, finite).
    pub duration_hours: f64,
    /// Scheduling priority, where the source trace has one (borg).
    pub priority: Option<u32>,
    /// Submitting user, where the source trace has one.
    pub user: Option<String>,
}

impl TraceRecord {
    /// The self-describing arrival the DES consumes.
    pub fn to_arrival(&self) -> SimArrival {
        SimArrival {
            tag: self.tag.clone(),
            engine: self.engine,
            demand: self.demand.clone(),
            weight: self.weight,
            n_min: self.n_min,
            n_max: self.n_max,
            baseline_n: self.baseline_n,
            submit_hours: self.submit_hours,
            duration_at_baseline_hours: self.duration_hours,
        }
    }
}

/// Typed trace-parse failures.  `PartialEq` so tests can assert the exact
/// variant; `Display`/`Error` so they thread through `anyhow` unchanged.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceError {
    /// Underlying reader failed (message of the `io::Error`).
    Io(String),
    /// The input had no header line at all.
    EmptyTrace,
    /// The header matched none of the known layouts.
    UnknownSchema { header: String },
    /// A required column for the detected schema is absent.
    MissingColumn { schema: &'static str, column: &'static str },
    /// A data row has fewer fields than the header promised.
    ShortRow { line: usize, want: usize, got: usize },
    /// A field failed to parse or failed validation (NaN, negative
    /// demand, zero duration, unknown engine, …).
    BadField { line: usize, column: &'static str, value: String, reason: &'static str },
    /// Submission times went backwards.
    NonMonotone { line: usize, prev_hours: f64, now_hours: f64 },
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceError::Io(e) => write!(f, "trace read failed: {e}"),
            TraceError::EmptyTrace => write!(f, "empty trace: no header line"),
            TraceError::UnknownSchema { header } => write!(
                f,
                "unrecognized trace schema (header {header:?}); expected a dorm \
                 (submit_hours,…), alibaba-like (plan_cpu,…) or borg-like \
                 (cpu_request,…) layout"
            ),
            TraceError::MissingColumn { schema, column } => {
                write!(f, "{schema} trace is missing required column {column:?}")
            }
            TraceError::ShortRow { line, want, got } => {
                write!(f, "line {line}: expected {want} fields, got {got}")
            }
            TraceError::BadField { line, column, value, reason } => {
                write!(f, "line {line}: bad {column} {value:?}: {reason}")
            }
            TraceError::NonMonotone { line, prev_hours, now_hours } => write!(
                f,
                "line {line}: submission time went backwards ({now_hours} h after \
                 {prev_hours} h); traces must be sorted by submit time"
            ),
        }
    }
}

impl std::error::Error for TraceError {}

/// The recognized column layouts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceSchema {
    Dorm,
    Alibaba,
    Borg,
}

impl TraceSchema {
    pub fn name(&self) -> &'static str {
        match self {
            TraceSchema::Dorm => "dorm",
            TraceSchema::Alibaba => "alibaba",
            TraceSchema::Borg => "borg",
        }
    }
}

/// Width/weight defaults applied where a foreign schema has no matching
/// column (see [`crate::config::TraceConfig`] for the `[trace]` knobs).
#[derive(Clone, Debug)]
pub struct SchemaDefaults {
    /// Clamp on widths taken from trace columns (`inst_num`/`instances`).
    pub max_width: u32,
    /// Width used when the trace has no instance-count column.
    pub default_width: u32,
}

impl Default for SchemaDefaults {
    fn default() -> Self {
        SchemaDefaults { max_width: 32, default_width: 8 }
    }
}

/// A resolved header: which physical column each logical field lives in.
#[derive(Clone, Debug)]
pub struct SchemaAdapter {
    schema: TraceSchema,
    ncols: usize,
    defaults: SchemaDefaults,
    // logical field -> column index (None = optional column absent)
    submit: usize,
    tag: usize,
    cpu: usize,
    mem: usize,
    duration: usize,
    gpu: Option<usize>,
    width: Option<usize>,
    engine: Option<usize>,
    weight: Option<usize>,
    n_min: Option<usize>,
    baseline: Option<usize>,
    priority: Option<usize>,
    user: Option<usize>,
}

fn split_csv(line: &str) -> Vec<&str> {
    line.split(',').map(str::trim).collect()
}

impl SchemaAdapter {
    /// Detect the layout from a header line and resolve its columns.
    pub fn detect(header: &str, defaults: SchemaDefaults) -> Result<Self, TraceError> {
        let cols = split_csv(header);
        let find = |name: &str| cols.iter().position(|c| c.eq_ignore_ascii_case(name));
        let schema = if find("submit_hours").is_some() {
            TraceSchema::Dorm
        } else if find("plan_cpu").is_some() {
            TraceSchema::Alibaba
        } else if find("cpu_request").is_some() {
            TraceSchema::Borg
        } else {
            return Err(TraceError::UnknownSchema { header: header.to_string() });
        };
        let need = |name: &'static str| {
            find(name).ok_or(TraceError::MissingColumn { schema: schema.name(), column: name })
        };
        let adapter = match schema {
            TraceSchema::Dorm => SchemaAdapter {
                schema,
                ncols: cols.len(),
                defaults,
                submit: need("submit_hours")?,
                tag: need("model")?,
                cpu: need("cpus")?,
                mem: need("ram_gb")?,
                duration: need("duration_hours")?,
                gpu: Some(need("gpus")?),
                width: Some(need("n_max")?),
                engine: Some(need("engine")?),
                weight: Some(need("weight")?),
                n_min: Some(need("n_min")?),
                baseline: Some(need("baseline_n")?),
                priority: find("priority"),
                user: find("user"),
            },
            TraceSchema::Alibaba => SchemaAdapter {
                schema,
                ncols: cols.len(),
                defaults,
                submit: need("start_time")?,
                tag: need("job_name")?,
                cpu: need("plan_cpu")?,
                mem: need("plan_mem")?,
                duration: need("duration")?,
                gpu: find("plan_gpu"),
                width: find("inst_num"),
                engine: None,
                weight: None,
                n_min: None,
                baseline: None,
                priority: None,
                user: find("user"),
            },
            TraceSchema::Borg => SchemaAdapter {
                schema,
                ncols: cols.len(),
                defaults,
                submit: need("time")?,
                tag: need("job_id")?,
                cpu: need("cpu_request")?,
                mem: need("memory_request")?,
                duration: need("runtime")?,
                gpu: find("gpu_request"),
                width: find("instances"),
                engine: None,
                weight: None,
                n_min: None,
                baseline: None,
                priority: find("priority"),
                user: find("user"),
            },
        };
        Ok(adapter)
    }

    pub fn schema(&self) -> TraceSchema {
        self.schema
    }

    /// Parse one data row into a validated [`TraceRecord`].
    pub fn parse_line(&self, line_no: usize, line: &str) -> Result<TraceRecord, TraceError> {
        let fields = split_csv(line);
        if fields.len() < self.ncols {
            return Err(TraceError::ShortRow {
                line: line_no,
                want: self.ncols,
                got: fields.len(),
            });
        }
        let num = |idx: usize, column: &'static str| -> Result<f64, TraceError> {
            let raw = fields[idx];
            let v: f64 = raw.parse().map_err(|_| TraceError::BadField {
                line: line_no,
                column,
                value: raw.to_string(),
                reason: "not a number",
            })?;
            if !v.is_finite() {
                return Err(TraceError::BadField {
                    line: line_no,
                    column,
                    value: raw.to_string(),
                    reason: "not finite",
                });
            }
            Ok(v)
        };
        let non_neg = |idx: usize, column: &'static str| -> Result<f64, TraceError> {
            let v = num(idx, column)?;
            if v < 0.0 {
                return Err(TraceError::BadField {
                    line: line_no,
                    column,
                    value: fields[idx].to_string(),
                    reason: "must be >= 0",
                });
            }
            Ok(v)
        };
        let width_of = |v: f64, column: &'static str| -> Result<u32, TraceError> {
            if v < 1.0 || v > u32::MAX as f64 {
                return Err(TraceError::BadField {
                    line: line_no,
                    column,
                    value: format!("{v}"),
                    reason: "must be a count >= 1",
                });
            }
            Ok((v as u32).min(self.defaults.max_width).max(1))
        };

        // timing: native hours; alibaba seconds; borg microseconds
        let raw_submit = non_neg(self.submit, "submit time")?;
        let submit_hours = match self.schema {
            TraceSchema::Dorm => raw_submit,
            TraceSchema::Alibaba => raw_submit / 3_600.0,
            TraceSchema::Borg => raw_submit / 3.6e9,
        };
        let raw_duration = num(self.duration, "duration")?;
        let duration_hours = match self.schema {
            TraceSchema::Dorm => raw_duration,
            TraceSchema::Alibaba | TraceSchema::Borg => raw_duration / 3_600.0,
        };
        if duration_hours <= 0.0 {
            return Err(TraceError::BadField {
                line: line_no,
                column: "duration",
                value: fields[self.duration].to_string(),
                reason: "must be > 0",
            });
        }

        // demand: native absolute; alibaba centi-cores + GB; borg
        // machine-fractions scaled by BORG_MACHINE
        let raw_cpu = non_neg(self.cpu, "cpu demand")?;
        let raw_mem = non_neg(self.mem, "memory demand")?;
        let raw_gpu = match self.gpu {
            Some(idx) => non_neg(idx, "gpu demand")?,
            None => 0.0,
        };
        let demand = match self.schema {
            TraceSchema::Dorm => Res::cpu_gpu_ram(raw_cpu, raw_gpu, raw_mem),
            TraceSchema::Alibaba => Res::cpu_gpu_ram(raw_cpu / 100.0, raw_gpu, raw_mem),
            TraceSchema::Borg => Res::cpu_gpu_ram(
                raw_cpu * BORG_MACHINE[0],
                raw_gpu * BORG_MACHINE[1].max(1.0),
                raw_mem * BORG_MACHINE[2],
            ),
        };
        if demand.is_zero() {
            return Err(TraceError::BadField {
                line: line_no,
                column: "cpu demand",
                value: fields[self.cpu].to_string(),
                reason: "demand vector is all zero",
            });
        }

        let n_max = match self.width {
            Some(idx) => width_of(num(idx, "instance count")?, "instance count")?,
            None => self.defaults.default_width,
        };
        let n_min = match self.n_min {
            Some(idx) => width_of(num(idx, "n_min")?, "n_min")?,
            None => 1,
        };
        if n_min > n_max {
            return Err(TraceError::BadField {
                line: line_no,
                column: "n_min",
                value: format!("{n_min}"),
                reason: "n_min exceeds n_max",
            });
        }
        let baseline_n = match self.baseline {
            Some(idx) => width_of(num(idx, "baseline_n")?, "baseline_n")?,
            None => n_max,
        };
        let priority = match self.priority {
            Some(idx) => {
                let v = non_neg(idx, "priority")?;
                Some(v as u32)
            }
            None => None,
        };
        // weight: native column; borg derives from priority bands; else 1
        let weight = match self.weight {
            Some(idx) => {
                let w = num(idx, "weight")?;
                if w <= 0.0 {
                    return Err(TraceError::BadField {
                        line: line_no,
                        column: "weight",
                        value: fields[idx].to_string(),
                        reason: "must be > 0",
                    });
                }
                w
            }
            None => match priority {
                Some(p) => 1.0 + (p / 4) as f64,
                None => 1.0,
            },
        };
        let engine = match self.engine {
            Some(idx) => Engine::parse(fields[idx]).map_err(|_| TraceError::BadField {
                line: line_no,
                column: "engine",
                value: fields[idx].to_string(),
                reason: "unknown engine",
            })?,
            None => Engine::MxNet,
        };
        let user = self.user.map(|idx| fields[idx].to_string());

        Ok(TraceRecord {
            submit_hours,
            tag: fields[self.tag].to_string(),
            engine,
            demand,
            weight,
            n_min,
            n_max,
            baseline_n,
            duration_hours,
            priority,
            user,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detects_all_three_schemas() {
        let d = SchemaAdapter::detect(
            "submit_hours,model,engine,cpus,gpus,ram_gb,weight,n_min,n_max,baseline_n,duration_hours",
            SchemaDefaults::default(),
        )
        .unwrap();
        assert_eq!(d.schema(), TraceSchema::Dorm);
        let a = SchemaAdapter::detect(
            "start_time,job_name,inst_num,plan_cpu,plan_mem,plan_gpu,duration",
            SchemaDefaults::default(),
        )
        .unwrap();
        assert_eq!(a.schema(), TraceSchema::Alibaba);
        let b = SchemaAdapter::detect(
            "time,job_id,priority,cpu_request,memory_request,instances,runtime",
            SchemaDefaults::default(),
        )
        .unwrap();
        assert_eq!(b.schema(), TraceSchema::Borg);
        let e = SchemaAdapter::detect("a,b,c", SchemaDefaults::default()).unwrap_err();
        assert!(matches!(e, TraceError::UnknownSchema { .. }));
    }

    #[test]
    fn column_order_does_not_matter() {
        // same columns, shuffled order
        let a = SchemaAdapter::detect(
            "plan_mem,duration,job_name,plan_cpu,start_time",
            SchemaDefaults::default(),
        )
        .unwrap();
        let r = a.parse_line(2, "8, 7200, j1, 400, 0").unwrap();
        assert_eq!(r.demand, Res::cpu_gpu_ram(4.0, 0.0, 8.0));
        assert!((r.duration_hours - 2.0).abs() < 1e-12);
        assert_eq!(r.tag, "j1");
    }

    #[test]
    fn missing_required_column_is_typed() {
        let e = SchemaAdapter::detect(
            "start_time,job_name,plan_cpu,duration", // no plan_mem
            SchemaDefaults::default(),
        )
        .unwrap_err();
        assert_eq!(
            e,
            TraceError::MissingColumn { schema: "alibaba", column: "plan_mem" }
        );
    }

    #[test]
    fn alibaba_units_convert() {
        let a = SchemaAdapter::detect(
            "start_time,job_name,inst_num,plan_cpu,plan_mem,duration",
            SchemaDefaults::default(),
        )
        .unwrap();
        let r = a.parse_line(2, "7200, job-7, 4, 200, 16, 1800").unwrap();
        assert!((r.submit_hours - 2.0).abs() < 1e-12);
        assert_eq!(r.demand, Res::cpu_gpu_ram(2.0, 0.0, 16.0));
        assert_eq!(r.n_max, 4);
        assert_eq!(r.baseline_n, 4);
        assert!((r.duration_hours - 0.5).abs() < 1e-12);
        assert_eq!(r.engine, Engine::MxNet);
        assert!((r.weight - 1.0).abs() < 1e-12);
    }

    #[test]
    fn borg_units_and_priority_weight() {
        let b = SchemaAdapter::detect(
            "time,job_id,priority,cpu_request,memory_request,instances,runtime",
            SchemaDefaults::default(),
        )
        .unwrap();
        let r = b.parse_line(2, "3600000000, 42, 9, 0.0625, 0.03125, 2, 360").unwrap();
        assert!((r.submit_hours - 1.0).abs() < 1e-9);
        assert_eq!(r.demand, Res::cpu_gpu_ram(4.0, 0.0, 8.0));
        assert_eq!(r.priority, Some(9));
        assert!((r.weight - 3.0).abs() < 1e-12, "priority 9 -> band 2 -> weight 3");
        assert_eq!(r.n_max, 2);
        assert!((r.duration_hours - 0.1).abs() < 1e-12);
    }

    #[test]
    fn hostile_fields_are_typed_not_panics() {
        let a = SchemaAdapter::detect(
            "start_time,job_name,plan_cpu,plan_mem,duration",
            SchemaDefaults::default(),
        )
        .unwrap();
        // NaN demand
        let e = a.parse_line(3, "0, j, NaN, 8, 60").unwrap_err();
        assert!(matches!(e, TraceError::BadField { column: "cpu demand", reason: "not finite", .. }), "{e:?}");
        // negative demand
        let e = a.parse_line(3, "0, j, -100, 8, 60").unwrap_err();
        assert!(matches!(e, TraceError::BadField { reason: "must be >= 0", .. }));
        // zero duration
        let e = a.parse_line(3, "0, j, 100, 8, 0").unwrap_err();
        assert!(matches!(e, TraceError::BadField { column: "duration", .. }));
        // short row
        let e = a.parse_line(3, "0, j, 100").unwrap_err();
        assert_eq!(e, TraceError::ShortRow { line: 3, want: 5, got: 3 });
        // unparsable number
        let e = a.parse_line(3, "soon, j, 100, 8, 60").unwrap_err();
        assert!(matches!(e, TraceError::BadField { reason: "not a number", .. }));
        // all-zero demand vector
        let e = a.parse_line(3, "0, j, 0, 0, 60").unwrap_err();
        assert!(matches!(e, TraceError::BadField { reason: "demand vector is all zero", .. }));
    }

    #[test]
    fn width_clamped_by_defaults() {
        let a = SchemaAdapter::detect(
            "start_time,job_name,inst_num,plan_cpu,plan_mem,duration",
            SchemaDefaults { max_width: 16, default_width: 8 },
        )
        .unwrap();
        let r = a.parse_line(2, "0, j, 4000, 100, 1, 60").unwrap();
        assert_eq!(r.n_max, 16);
    }
}

//! Offline stub of the `xla` (xla-rs) PJRT binding.
//!
//! The vendored registry on this image has no usable XLA/PJRT binding, so
//! this shim provides the exact API surface `dorm::runtime::service` uses.
//! Every entry point that would touch PJRT fails at [`PjRtClient::cpu`]
//! with a descriptive error; the dorm compute service surfaces that as a
//! startup error and the rest of the system (master, optimizer, simulator)
//! keeps working without trainers — the same degradation path as running
//! without `make artifacts`.  Swap the `xla` path dependency in
//! `rust/Cargo.toml` for a real xla-rs checkout to enable training.

use std::fmt;

/// Error type mirroring xla-rs's: printable via `{}` in `anyhow!` wrappers.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable() -> XlaError {
    XlaError(
        "PJRT backend not available: built against the offline xla stub \
         (see rust/Cargo.toml to link a real xla-rs)"
            .to_string(),
    )
}

/// Element types the stub's literals accept (mirror of xla-rs NativeType).
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Parsed HLO module (stub: parsing always "succeeds" lazily; the failure
/// happens earlier, at client construction, so this is never reached).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Host-side tensor literal (stub: carries nothing).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: NativeType>(_t: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_descriptively() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}

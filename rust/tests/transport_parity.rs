//! Golden transport parity: the API redesign's key invariant.
//!
//! `LocalTransport` (direct dispatch into an in-process `DormMaster`) and
//! `TcpTransport` (length-prefixed frames over loopback to a served
//! master) must be *indistinguishable* to a client: the same scripted
//! request sequence — submissions, progress, checkpoints, heartbeats,
//! lease expiry, capacity events, recovery, completions, and typed
//! errors — must produce identical response values AND identical
//! observable master state after every single request.  If either
//! transport grows private semantics (stamping, reordering, lossy
//! encoding, divergent error mapping), this breaks.
//!
//! The TCP leg runs twice: once against the multiplexed worker-pool
//! server (`serve`, DESIGN.md §15) and once against the retained
//! thread-per-connection baseline (`serve_legacy`) — the two server
//! implementations must stay response-sequence-identical, not merely
//! each individually correct.
//!
//! Protocol notes: all times in the script are finite and explicit — the
//! TCP server only substitutes wall clock for non-finite times, so the
//! script stays deterministic on both transports.

use dorm::app::{AppId, AppSpec, CheckpointStore, Engine};
use dorm::config::{ClusterConfig, DormConfig, FaultConfig, NetConfig};
use dorm::master::DormMaster;
use dorm::net::{serve, serve_legacy, ControlPlane, LocalTransport, TcpTransport};
use dorm::proto::{ErrorCode, Request, Response};
use dorm::resources::Res;
use dorm::slave::SlaveReport;

fn store(tag: &str) -> CheckpointStore {
    let dir = std::env::temp_dir().join(format!("dorm_tparity_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    CheckpointStore::new(dir).unwrap()
}

fn master(tag: &str) -> DormMaster {
    DormMaster::new(
        &ClusterConfig::uniform(3, Res::cpu_gpu_ram(12.0, 0.0, 64.0)),
        DormConfig { theta1: 0.3, theta2: 0.34 },
        store(tag),
    )
    .with_fault(&FaultConfig { lease_timeout_hours: 1.0, ..Default::default() })
}

fn spec(cpu: f64, ram: f64, w: u32, lo: u32, hi: u32) -> AppSpec {
    AppSpec {
        executor: Engine::MxNet,
        demand: Res::cpu_gpu_ram(cpu, 0.0, ram),
        weight: w,
        n_max: hi,
        n_min: lo,
        cmd: ["parity".into(), "parity".into()],
    }
}

/// An empty-book report matching the master's view of server `j` — what
/// a freshly started remote slave would send.
fn empty_report(j: usize) -> SlaveReport {
    SlaveReport {
        name: format!("slave{j:02}"),
        capacity: Res::cpu_gpu_ram(12.0, 0.0, 64.0),
        available: Res::cpu_gpu_ram(12.0, 0.0, 64.0),
        containers: Default::default(),
    }
}

/// The scripted workload: happy paths, fault paths, capacity events and
/// typed-error paths, all with explicit times.
fn script() -> Vec<Request> {
    vec![
        // a second in-band handshake must answer identically everywhere
        Request::Hello { major: dorm::proto::PROTO_MAJOR, minor: dorm::proto::PROTO_MINOR },
        Request::Submit { spec: spec(2.0, 8.0, 1, 1, 24) }, // app1: spans cluster
        Request::Submit { spec: spec(2.0, 6.0, 2, 1, 24) }, // app2: forces adjustment
        Request::AdvanceSteps { app: AppId(1), steps: 100 },
        Request::CheckpointApp { app: AppId(1) },
        Request::AdvanceSteps { app: AppId(1), steps: 40 },
        // servers 1 and 2 report at t=2; server 0 has gone silent
        Request::Heartbeat { server: 1, now_hours: 2.0, report: None, acks: vec![] },
        Request::Heartbeat { server: 2, now_hours: 2.0, report: Some(empty_report(2)), acks: vec![] },
        Request::ExpireLeases { now_hours: 3.0 }, // kills server 0
        // capacity event: server 1 shrinks; engine caches must drop and
        // the re-solve must land identically on both transports
        Request::Heartbeat {
            server: 1,
            now_hours: 3.1,
            report: Some(SlaveReport {
                capacity: Res::cpu_gpu_ram(10.0, 0.0, 64.0),
                available: Res::cpu_gpu_ram(10.0, 0.0, 64.0),
                ..empty_report(1)
            }),
            acks: vec![],
        },
        Request::RecoverServer { server: 0, now_hours: 4.0 },
        // typed errors must be value-identical end to end
        Request::Complete { app: AppId(99) },
        Request::Heartbeat { server: 9, now_hours: 4.1, report: None, acks: vec![] },
        Request::Submit { spec: spec(2.0, 8.0, 1, 0, 4) }, // n_min 0: invalid
        Request::FailServer { server: 77 },
        Request::Complete { app: AppId(2) },
        Request::CheckpointApp { app: AppId(2) }, // terminal: InvalidState
        Request::Reallocate,
        Request::Complete { app: AppId(1) },
        Request::QueryState { app: Some(AppId(1)) },
    ]
}

/// Run the script, recording each request's response plus the full state
/// view after it — the (decision, observable-state) sequence.
fn run_script(t: &mut dyn ControlPlane) -> Vec<(Response, Response)> {
    script()
        .into_iter()
        .map(|req| {
            let rsp = t.call(req).expect("transport must not fail mid-script");
            let view = t.call(Request::QueryState { app: None }).expect("query");
            (rsp, view)
        })
        .collect()
}

#[test]
fn local_and_tcp_transports_replay_identical_sequences() {
    // ---- local side -----------------------------------------------------
    let mut local = LocalTransport::new(master("local"));
    let local_seq = run_script(&mut local);

    // ---- TCP side: same master config served over loopback, once per
    // ---- server implementation ------------------------------------------
    let net = NetConfig {
        bind_addr: "127.0.0.1:0".into(),
        io_timeout_ms: 10_000,
        ..NetConfig::default()
    };
    let mux = serve(master("tcp"), &net).unwrap();
    let mut tcp = TcpTransport::connect(&mux.addr().to_string(), &net).unwrap();
    let tcp_seq = run_script(&mut tcp);
    mux.stop();

    let leg = serve_legacy(master("legacy"), &net).unwrap();
    let mut ltcp = TcpTransport::connect(&leg.addr().to_string(), &net).unwrap();
    let legacy_seq = run_script(&mut ltcp);
    leg.stop();

    // ---- the invariant --------------------------------------------------
    for (label, seq) in [("mux", &tcp_seq), ("legacy", &legacy_seq)] {
        assert_eq!(local_seq.len(), seq.len());
        for (i, (l, t)) in local_seq.iter().zip(seq.iter()).enumerate() {
            assert_eq!(l.0, t.0, "{label}: response {i} diverged (request {:?})", script()[i]);
            assert_eq!(l.1, t.1, "{label}: state after request {i} diverged ({:?})", script()[i]);
        }
    }

    // ---- sanity: the script exercised the interesting paths -------------
    let rsp = |i: usize| &local_seq[i].0;
    assert_eq!(rsp(1), &Response::Submitted { app: AppId(1) });
    assert_eq!(rsp(2), &Response::Submitted { app: AppId(2) });
    assert_eq!(rsp(8), &Response::Expired { dead: vec![0] }, "silent server 0 expired");
    match rsp(9) {
        Response::HeartbeatAck { alive, .. } => assert!(*alive, "server 1 lives"),
        other => panic!("capacity-event heartbeat answered {other:?}"),
    }
    for (i, code) in [
        (11, ErrorCode::UnknownApp),
        (12, ErrorCode::UnknownServer),
        (13, ErrorCode::InvalidSpec),
        (14, ErrorCode::UnknownServer),
        (16, ErrorCode::InvalidState),
    ] {
        match rsp(i) {
            Response::Error(e) => assert_eq!(e.code, code, "request {i}"),
            other => panic!("request {i} answered {other:?}, wanted {code:?}"),
        }
    }
    // the fault path actually ran: app1 lost the 40 post-checkpoint steps
    // and recovered; the capacity event forced at least one more re-solve
    let final_view = match &local_seq.last().unwrap().1 {
        Response::State(v) => v,
        other => panic!("query answered {other:?}"),
    };
    assert_eq!(final_view.active_apps, 0, "script drains fully");
    assert!(final_view.total_recoveries >= 1, "server death recovery ran");
    assert!(final_view.total_adjustments >= 1, "second arrival adjusted app1");
    let app1 = match rsp(19) {
        Response::State(v) => v.apps[0].clone(),
        other => panic!("filtered query answered {other:?}"),
    };
    assert_eq!(app1.id, AppId(1));
    assert_eq!(app1.steps_done, 100, "rolled back to the checkpoint");
}

//! Fig. 6 reproduction: resource utilization of the testbed over 24 h —
//! static baseline vs Dorm-1/2/3.
//!
//! Paper headline (§V-B-1): Dorm-1/2/3 increase utilization by ×2.55 /
//! ×2.46 / ×2.32 on average in the first 5 hours.

#[path = "harness/mod.rs"]
mod harness;

use dorm::baselines::IaasPolicy;
use dorm::config::DormConfig;
use dorm::report;
use dorm::sim::{headline_over_seeds, utilization_ratio, Experiment};

fn main() {
    harness::banner("Fig. 6 — resource utilization over 24 h (50 apps, 20 slaves)");
    let t0 = std::time::Instant::now();
    let exp = Experiment::paper(17);
    let runs = exp.run_all();
    println!("  (4 systems x 24 h simulated in {:.2?})", t0.elapsed());
    let (baseline, dorms) = runs.split_first().unwrap();

    let mut rows = Vec::new();
    for r in &runs {
        rows.push(vec![
            r.label.clone(),
            format!("{:.2}", r.metrics().utilization.mean_over(0.0, 5.0)),
            format!("{:.2}", r.metrics().utilization.mean_over(0.0, 24.0)),
            format!("{:.2}", r.metrics().utilization.max()),
        ]);
    }
    println!(
        "{}",
        report::table(&["system", "mean util 0-5h", "mean util 0-24h", "peak"], &rows)
    );

    let paper = ["2.55x", "2.46x", "2.32x"];
    for (d, p) in dorms.iter().zip(paper) {
        harness::paper_row(
            &format!("utilization gain vs baseline, first 5h ({})", d.label),
            p,
            &format!("{:.2}x", utilization_ratio(d, baseline, 5.0)),
        );
    }

    // IaaS comparator (§II-B): engine-partitioned virtual clusters
    let iaas = exp.run(&mut IaasPolicy::proportional(20));
    harness::paper_row(
        "IaaS (engine-partitioned) mean util 0-24h vs static",
        "worse (no flow between engines)",
        &format!(
            "{:.2} vs {:.2}",
            iaas.metrics().utilization.mean_over(0.0, 24.0),
            baseline.metrics().utilization.mean_over(0.0, 24.0)
        ),
    );

    // multi-seed robustness of the headline (3 seeds)
    let agg = headline_over_seeds(DormConfig::DORM3, &[17, 23, 42]);
    harness::paper_row(
        "Dorm-3 utilization gain, 3 seeds (mean±std)",
        "2.32x",
        &format!("{:.2}x ± {:.2}", agg[0].0, agg[0].1),
    );

    // the Fig. 6 curves
    let series: Vec<(String, Vec<(f64, f64)>)> = runs
        .iter()
        .map(|r| (r.label.clone(), r.metrics().utilization.resample(0.0, 24.0, 64)))
        .collect();
    let refs: Vec<(&str, &[(f64, f64)])> =
        series.iter().map(|(l, s)| (l.as_str(), s.as_slice())).collect();
    println!("\n{}", report::ascii_chart(&refs, 14, 64));

    for (label, s) in &series {
        let _ = report::write_csv(
            &format!("fig6_{}.csv", label.replace(['(', ')', '=', ',', '.'], "_")),
            &[
                ("hours", s.iter().map(|&(t, _)| t).collect()),
                ("utilization", s.iter().map(|&(_, u)| u).collect()),
            ],
        );
    }
}

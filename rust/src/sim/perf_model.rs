//! Application performance model for the simulator.
//!
//! §III-A-4: distributed-ML apps are iterative with uniform containers, so
//! progress is modeled as a rate that depends only on the container count.
//! We use the standard communication-overhead speedup curve
//!
//! ```text
//! speed(n) = n / (1 + α·(n − 1))        (α = parallel inefficiency)
//! ```
//!
//! which is linear at α = 0 and saturates at 1/α.  The default α = 0.02 is
//! calibrated to the paper's own measurements: Fig. 9a reports ≈ 2.7×
//! mean speedup when LR/MF apps scale from their baseline 8 containers to
//! n_max = 32, and speed(32)/speed(8) = 2.81 at α = 0.02 (BSP on 10 GbE
//! with sparse pushes is near-linear at these widths).
//!
//! The checkpoint-based adjustment protocol (§III-C-2) costs a pause:
//! save + kill + create + resume.  Fig. 9b's "≈ 5 % overhead at ≥ 3 h with
//! 2 adjustments" pins the default: 2 · pause ≈ 0.05 · 3 h ⇒ pause ≈ 4.5
//! min, split between save and restore.

use super::SimTime;

/// Progress + adjustment-cost model shared by all simulated apps.
#[derive(Clone, Debug)]
pub struct PerfModel {
    /// Parallel inefficiency α ∈ [0, 1].
    pub alpha: f64,
    /// Checkpoint save time (hours) — state to reliable storage.
    pub ckpt_save_hours: f64,
    /// Kill + container create/destroy + resume time (hours).
    pub restart_hours: f64,
    /// Periodic checkpoint cadence for the fault model (`crate::fault`):
    /// every this many hours a running app's progress is persisted, capping
    /// what a server death can cost.  0 (the default) checkpoints only on
    /// adjustment — the bare §III-C-2 protocol, where an app that is never
    /// adjusted loses everything on failure.  Periodic saves are modeled
    /// as asynchronous (no pause; DESIGN.md §8).
    pub ckpt_period_hours: f64,
}

impl Default for PerfModel {
    fn default() -> Self {
        PerfModel {
            alpha: 0.02,
            // 4.5 min total pause -> 5% overhead on a 3h app with 2 kills
            ckpt_save_hours: 1.5 / 60.0,
            restart_hours: 3.0 / 60.0,
            ckpt_period_hours: 0.0,
        }
    }
}

impl PerfModel {
    /// Effective speed with `n` containers, in "work units"/hour, where
    /// 1 container ⇒ speed 1.
    pub fn speed(&self, n: u32) -> f64 {
        if n == 0 {
            return 0.0;
        }
        let nf = n as f64;
        nf / (1.0 + self.alpha * (nf - 1.0))
    }

    /// Total work implied by "this app takes `dur` hours at `n` containers".
    pub fn work_for(&self, dur_hours: f64, n: u32) -> f64 {
        dur_hours * self.speed(n)
    }

    /// Full adjustment pause (kill + resume path of Fig. 5).
    pub fn adjust_pause_hours(&self) -> SimTime {
        self.ckpt_save_hours + self.restart_hours
    }

    /// Speedup of running at `n` vs `base` containers.
    pub fn speedup(&self, n: u32, base: u32) -> f64 {
        self.speed(n) / self.speed(base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn speed_monotone_and_saturating() {
        let m = PerfModel::default();
        assert_eq!(m.speed(0), 0.0);
        assert_eq!(m.speed(1), 1.0);
        let mut prev = 0.0;
        for n in 1..200 {
            let s = m.speed(n);
            assert!(s > prev, "speed must increase with n");
            prev = s;
        }
        // saturates below 1/alpha
        assert!(m.speed(10_000) < 1.0 / m.alpha);
    }

    #[test]
    fn linear_when_alpha_zero() {
        let m = PerfModel { alpha: 0.0, ..Default::default() };
        assert_eq!(m.speed(32), 32.0);
        assert_eq!(m.speedup(32, 8), 4.0);
    }

    #[test]
    fn work_roundtrip() {
        let m = PerfModel::default();
        // app takes 10h at 8 containers; at 16 containers it must take
        // 10h / speedup(16, 8)
        let work = m.work_for(10.0, 8);
        let dur16 = work / m.speed(16);
        assert!((dur16 - 10.0 / m.speedup(16, 8)).abs() < 1e-12);
        assert!(dur16 < 10.0);
    }

    #[test]
    fn default_pause_matches_fig9b_anchor() {
        let m = PerfModel::default();
        // 2 adjustments on a 3-hour app ≈ 5% overhead
        let overhead = 2.0 * m.adjust_pause_hours() / 3.0;
        assert!((overhead - 0.05).abs() < 0.005, "{overhead}");
    }

    #[test]
    fn prop_speedup_bounded_by_count_ratio() {
        prop::check(100, |rng| {
            let m = PerfModel { alpha: rng.range_f64(0.0, 0.3), ..Default::default() };
            let base = rng.range_u64(1, 16) as u32;
            let n = base + rng.range_u64(0, 32) as u32;
            let s = m.speedup(n, base);
            if s > n as f64 / base as f64 + 1e-9 {
                return Err(format!("superlinear speedup {s}"));
            }
            if s < 1.0 - 1e-9 {
                return Err(format!("scaling up slowed the app: {s}"));
            }
            Ok(())
        });
    }
}

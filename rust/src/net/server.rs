//! The master side of the TCP control plane.
//!
//! [`serve`] binds a listener, moves the [`DormMaster`] behind a mutex,
//! and runs a *multiplexed* server (DESIGN.md §15): a blocking accept
//! thread hands connections to a fixed pool of worker threads, each of
//! which owns a share of the open connections as non-blocking sockets
//! with per-connection frame-reassembly state.  A partial frame never
//! blocks a worker — the worker simply moves on to its other
//! connections — and all requests that completed within one poll tick
//! are dispatched under a single master-lock acquisition, with runs of
//! heartbeats coalesced through `DormMaster::dispatch_heartbeats` (one
//! lease-table pass, at most one re-solve).  [`serve_legacy`] keeps the
//! original thread-per-connection blocking-read server; the transport
//! parity tests pin the two response-sequence-identical, and the
//! `rpc_throughput` bench uses it as the saturation baseline.
//!
//! Shared design points (both servers):
//!
//! * **Handshake first.**  The first frame of every connection must be
//!   [`Request::Hello`]; version mismatches and pre-handshake requests
//!   are answered with a typed error and the connection is closed.
//! * **Errors are answers.**  An unknown request tag or an undecodable
//!   payload produces a decodable [`Response::Error`] and the connection
//!   *survives* (framing is intact — the whole frame was consumed).
//!   Only unrecoverable conditions close it: an oversized frame (framing
//!   cannot resync past an unread body), an IO error, or a peer silent
//!   for `io_timeout_ms` mid-frame — so a stalled or malicious peer
//!   cannot wedge a worker.  A connection arriving past `[net].max_conns`
//!   is answered with [`ErrorCode::TooManyConnections`] and closed.
//! * **The server owns wall time.**  Heartbeats/expiries carrying a
//!   non-finite `now_hours` are stamped with hours since server start —
//!   one clock domain for the whole lease table, no cross-process clock
//!   agreement needed.  When `NetConfig::lease_sweep_ms > 0` a dedicated
//!   sweeper thread drives [`Request::ExpireLeases`], which is what
//!   makes lease expiry reflect *real missed packets* in the two-process
//!   demo.
//! * **No artificial latency.**  Nothing in either accept path sleeps on
//!   a timer: accept blocks in the kernel (a self-connection wakes it on
//!   shutdown), an idle worker parks on a condvar, and a worker with a
//!   single quiet connection parks in a blocking `peek` so a lone
//!   client's round-trip costs no poll tick at all.

use std::collections::VecDeque;
use std::io::ErrorKind;
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::NetConfig;
use crate::master::DormMaster;
use crate::proto::{wire, ErrorCode, ProtoError, Request, Response};

/// Worker poll quantum while it owns quiet connections: the wait starts
/// here and backs off (doubling per idle pass) to [`POLL_TICK_MAX`], so
/// a loaded worker never waits and a quiet one costs little CPU.
const POLL_TICK_MIN_MS: u64 = 1;
/// Upper bound of the idle back-off; also the advertised "one poll tick"
/// bound on how long a stalled peer can delay another client's
/// round-trip on the same worker.
const POLL_TICK_MAX_MS: u64 = 16;
/// Blocking-wait quantum (single-connection peek, legacy reads): long
/// enough to cost nothing, short enough to observe `stop` promptly.
const BLOCK_QUANTUM_MS: u64 = 100;
/// Idle passes a worker burns (yielding, not sleeping) before it starts
/// the timed back-off.  std has no readiness notification, so a worker
/// that parked would eat a whole poll tick of latency on the next
/// request; spinning briefly after each active burst covers the client
/// turnaround gap of request-response traffic at microsecond cost.
const SPIN_PASSES: u32 = 128;

// ---- shared plumbing ----------------------------------------------------

/// One worker's handoff queue: the accept thread pushes accepted
/// sockets, the owning worker drains them into its connection set.
struct WorkerQueue {
    inbox: Mutex<Vec<TcpStream>>,
    cv: Condvar,
}

/// State shared by the accept thread, the workers, and the sweeper.
struct Shared {
    stop: AtomicBool,
    addr: SocketAddr,
    /// The serving master's epoch, cached so pre-dispatch errors (bad
    /// frames, connection rejections) can be stamped without a lock.
    epoch: AtomicU64,
    /// Open connections across all workers (`[net].max_conns` gate).
    conns: AtomicUsize,
    stop_mu: Mutex<()>,
    stop_cv: Condvar,
    workers: Vec<Arc<WorkerQueue>>,
}

impl Shared {
    fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Idempotently stop the server: set the flag, wake every parked
    /// thread, and dial the listener once so the blocking accept
    /// returns.
    fn request_stop(&self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        {
            let _g = self.stop_mu.lock().unwrap_or_else(|p| p.into_inner());
            self.stop_cv.notify_all();
        }
        for w in &self.workers {
            let _g = w.inbox.lock().unwrap_or_else(|p| p.into_inner());
            w.cv.notify_all();
        }
        let _ = TcpStream::connect_timeout(&wake_addr(self.addr), Duration::from_millis(200));
    }
}

/// Where a self-connection can reach our own listener: an unspecified
/// bind address (`0.0.0.0` / `::`) is dialed via loopback.
fn wake_addr(addr: SocketAddr) -> SocketAddr {
    if addr.ip().is_unspecified() {
        let ip = match addr.ip() {
            IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
            IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
        };
        SocketAddr::new(ip, addr.port())
    } else {
        addr
    }
}

/// Running server: address, shared master, and the serving threads.
pub struct ServerHandle {
    addr: SocketAddr,
    master: Arc<Mutex<DormMaster>>,
    shared: Arc<Shared>,
    threads: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (resolves `:0` ephemeral ports for tests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared master, e.g. for in-process inspection in tests.
    pub fn master(&self) -> Arc<Mutex<DormMaster>> {
        Arc::clone(&self.master)
    }

    /// Has a [`Request::Shutdown`] (or [`ServerHandle::stop`]) landed?
    pub fn is_stopped(&self) -> bool {
        self.shared.stopping()
    }

    /// Ask the serving threads to exit without waiting for them.
    pub fn stop(&self) {
        self.shared.request_stop();
    }

    /// Block until the serving threads exit (a client sent Shutdown, or
    /// [`ServerHandle::stop`] was called).
    pub fn wait(mut self) {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shared.request_stop();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn hours_since(wall_epoch: Instant) -> f64 {
    wall_epoch.elapsed().as_secs_f64() / 3600.0
}

fn lock_master(m: &Mutex<DormMaster>) -> std::sync::MutexGuard<'_, DormMaster> {
    // a handler that panicked mid-dispatch poisons the lock; the master's
    // state is still the best available, so serving beats aborting
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Substitute the server's wall clock for "stamp at arrival" markers.
fn stamp(req: Request, wall_epoch: Instant) -> Request {
    match req {
        Request::Heartbeat { server, now_hours, report, acks } if !now_hours.is_finite() => {
            Request::Heartbeat { server, now_hours: hours_since(wall_epoch), report, acks }
        }
        Request::ExpireLeases { now_hours } if !now_hours.is_finite() => {
            Request::ExpireLeases { now_hours: hours_since(wall_epoch) }
        }
        Request::RecoverServer { server, now_hours } if !now_hours.is_finite() => {
            Request::RecoverServer { server, now_hours: hours_since(wall_epoch) }
        }
        other => other,
    }
}

/// Encode one response trailed by the serving master's `epoch` (proto
/// v1.1 split-brain fencing).  A response that would itself exceed the
/// frame limit (e.g. a `StateView` over a very large app population) is
/// replaced by an in-band typed error rather than silently dropping the
/// connection — errors are answers here too.
fn encode_fitting(rsp: &Response, max: usize, epoch: u64) -> Vec<u8> {
    let mut payload = wire::encode_response_ep(rsp, epoch);
    if payload.len() > max {
        // progressively shorter details so the substitute itself fits
        // even a pathologically small (but legal, >= 64 B) frame limit
        let full = format!(
            "response of {} B exceeds the {max} B frame limit; \
             narrow the query or raise [net].max_frame_bytes",
            payload.len()
        );
        for detail in [full.as_str(), "response too large", ""] {
            let sub = wire::encode_response_ep(
                &Response::Error(ProtoError::new(ErrorCode::FrameTooLarge, detail)),
                epoch,
            );
            if sub.len() <= max {
                payload = sub;
                break;
            }
        }
    }
    payload
}

/// Write one response frame on a blocking stream (legacy path and the
/// connection-limit rejection).
fn send(stream: &mut TcpStream, rsp: &Response, max: usize, epoch: u64) -> bool {
    let payload = encode_fitting(rsp, max, epoch);
    wire::write_frame(stream, &payload, max).is_ok()
}

// ---- the multiplexed server (DESIGN.md §15) -----------------------------

/// Serve `master` on `cfg.bind_addr` until a shutdown request arrives.
///
/// The multiplexed server: `cfg.workers` handler threads (0 = one per
/// available core, capped at 8) each own a share of the connections;
/// partial frames never block a worker, completed requests are
/// dispatched in per-tick batches under one lock, and runs of heartbeats
/// coalesce into a single lease pass with at most one re-solve when
/// `cfg.coalesce_heartbeats` holds.
pub fn serve(master: DormMaster, cfg: &NetConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.bind_addr)
        .with_context(|| format!("bind {}", cfg.bind_addr))?;
    let addr = listener.local_addr()?;
    let n = if cfg.workers > 0 {
        cfg.workers
    } else {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).clamp(1, 8)
    };
    let wall_epoch = Instant::now();
    let epoch0 = master.epoch();
    let master = Arc::new(Mutex::new(master));
    let shared = Arc::new(Shared {
        stop: AtomicBool::new(false),
        addr,
        epoch: AtomicU64::new(epoch0),
        conns: AtomicUsize::new(0),
        stop_mu: Mutex::new(()),
        stop_cv: Condvar::new(),
        workers: (0..n)
            .map(|_| Arc::new(WorkerQueue { inbox: Mutex::new(Vec::new()), cv: Condvar::new() }))
            .collect(),
    });

    let mut threads = Vec::with_capacity(n + 2);
    for idx in 0..n {
        let master = Arc::clone(&master);
        let shared = Arc::clone(&shared);
        let cfg = cfg.clone();
        threads.push(std::thread::spawn(move || {
            worker_loop(idx, master, shared, cfg, wall_epoch)
        }));
    }
    threads.push(spawn_sweeper(&master, &shared, cfg, wall_epoch));
    {
        let shared = Arc::clone(&shared);
        let cfg = cfg.clone();
        threads.push(std::thread::spawn(move || mux_accept_loop(listener, shared, cfg)));
    }
    Ok(ServerHandle { addr, master, shared, threads })
}

/// Lease sweeps move off the accept path onto their own thread (both
/// servers): cadence-driven, woken early only by shutdown.
fn spawn_sweeper(
    master: &Arc<Mutex<DormMaster>>,
    shared: &Arc<Shared>,
    cfg: &NetConfig,
    wall_epoch: Instant,
) -> JoinHandle<()> {
    let master = Arc::clone(master);
    let shared = Arc::clone(shared);
    let period = (cfg.lease_sweep_ms > 0).then(|| Duration::from_millis(cfg.lease_sweep_ms));
    std::thread::spawn(move || {
        let Some(period) = period else { return };
        let mut last_sweep = Instant::now();
        loop {
            {
                let g = shared.stop_mu.lock().unwrap_or_else(|p| p.into_inner());
                drop(shared.stop_cv.wait_timeout(g, period));
            }
            if shared.stopping() {
                return;
            }
            if last_sweep.elapsed() < period {
                continue; // spurious or early wake
            }
            last_sweep = Instant::now();
            let now = hours_since(wall_epoch);
            let rsp = lock_master(&master).dispatch(Request::ExpireLeases { now_hours: now });
            shared.epoch.store(lock_master(&master).epoch(), Ordering::SeqCst);
            if let Response::Expired { dead } = rsp {
                if !dead.is_empty() {
                    log::warn!("lease sweep at {now:.5} h: servers {dead:?} expired");
                }
            }
        }
    })
}

/// Blocking accept loop: no timer sleeps anywhere.  Shutdown wakes it
/// via a self-connection; transient accept errors back off on the stop
/// condvar (interruptible, not a busy spin).
fn mux_accept_loop(listener: TcpListener, shared: Arc<Shared>, cfg: NetConfig) {
    let mut next = 0usize;
    loop {
        if shared.stopping() {
            return;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                if shared.stopping() {
                    return; // the wake-up self-connection
                }
                if shared.conns.load(Ordering::SeqCst) >= cfg.max_conns {
                    reject_over_limit(stream, &shared, &cfg);
                    continue;
                }
                log::debug!("control-plane connection from {peer}");
                shared.conns.fetch_add(1, Ordering::SeqCst);
                let w = &shared.workers[next % shared.workers.len()];
                next = next.wrapping_add(1);
                let mut inbox = w.inbox.lock().unwrap_or_else(|p| p.into_inner());
                inbox.push(stream);
                w.cv.notify_all();
            }
            Err(e) => {
                log::warn!("accept failed: {e}; backing off");
                let g = shared.stop_mu.lock().unwrap_or_else(|p| p.into_inner());
                drop(shared.stop_cv.wait_timeout(g, Duration::from_millis(50)));
            }
        }
    }
}

/// Answer a connection past `[net].max_conns` with a typed error and
/// close it — refused, never silently dropped.  The frame is tiny, so
/// the bounded blocking write cannot stall the accept thread.
fn reject_over_limit(mut stream: TcpStream, shared: &Shared, cfg: &NetConfig) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(1000)));
    let e = ProtoError::new(
        ErrorCode::TooManyConnections,
        format!("connection limit [net].max_conns = {} reached; re-dial later", cfg.max_conns),
    );
    send(
        &mut stream,
        &Response::Error(e),
        cfg.max_frame_bytes,
        shared.epoch.load(Ordering::SeqCst),
    );
}

/// What a decoded frame owes: an immediate answer (no master involved)
/// or a dispatch through the master.
enum Step {
    Respond(Response),
    Dispatch { req: Request, rid: Option<u64>, kind: ItemKind },
}

#[derive(Clone, Copy, PartialEq)]
enum ItemKind {
    Normal,
    Hello,
    Shutdown,
}

/// One multiplexed connection: socket plus frame-reassembly and write
/// buffering state, so a partial read or a slow reader never blocks the
/// owning worker.
struct Conn {
    stream: TcpStream,
    negotiated: bool,
    /// A Hello is in this tick's dispatch batch; frames pipelined behind
    /// it stay deferred until its verdict lands.
    hello_pending: bool,
    hdr: [u8; wire::FRAME_HEADER],
    hdr_pos: usize,
    body: Vec<u8>,
    body_pos: usize,
    reading_body: bool,
    /// Complete frames pumped off the socket but not yet processed.
    deferred: VecDeque<Vec<u8>>,
    /// Declared length of an oversized frame, noted by the reader for
    /// the pass to answer (fatal: framing cannot resync past it).
    oversize: Option<usize>,
    /// Pending response bytes not yet accepted by the socket.
    out: Vec<u8>,
    /// Stop reading (fatal frame or half-close); flush `out`, then die.
    read_dead: bool,
    close_after_flush: bool,
    dead: bool,
    quiet_since: Option<Instant>,
    write_quiet: Option<Instant>,
}

impl Conn {
    fn adopt(stream: TcpStream) -> Option<Conn> {
        stream.set_nodelay(true).ok();
        stream.set_nonblocking(true).ok()?;
        Some(Conn {
            stream,
            negotiated: false,
            hello_pending: false,
            hdr: [0u8; wire::FRAME_HEADER],
            hdr_pos: 0,
            body: Vec::new(),
            body_pos: 0,
            reading_body: false,
            deferred: VecDeque::new(),
            oversize: None,
            out: Vec::new(),
            read_dead: false,
            close_after_flush: false,
            dead: false,
            quiet_since: None,
            write_quiet: None,
        })
    }

    /// A frame is partially read (stall deadline applies); idle *between*
    /// frames is healthy and may last indefinitely.
    fn mid_frame(&self) -> bool {
        self.hdr_pos > 0 || self.reading_body
    }

    /// Non-blocking write of whatever the socket will take.
    fn flush(&mut self) -> bool {
        use std::io::Write;
        let mut progress = false;
        while !self.out.is_empty() && !self.dead {
            match self.stream.write(&self.out) {
                Ok(0) => self.dead = true,
                Ok(n) => {
                    self.out.drain(..n);
                    progress = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => self.dead = true,
            }
        }
        if progress {
            self.write_quiet = None;
        }
        if self.out.is_empty() && self.close_after_flush {
            self.dead = true;
        }
        progress
    }

    /// Non-blocking read: reassemble as many complete frames as the
    /// socket has bytes for, onto `deferred`.
    fn pump(&mut self, max: usize) -> bool {
        use std::io::Read;
        let mut progress = false;
        while !self.read_dead && !self.dead {
            if self.reading_body {
                if self.body_pos == self.body.len() {
                    let frame = std::mem::take(&mut self.body);
                    self.deferred.push_back(frame);
                    self.reading_body = false;
                    self.hdr_pos = 0;
                    continue;
                }
                match self.stream.read(&mut self.body[self.body_pos..]) {
                    Ok(0) => self.on_eof(),
                    Ok(n) => {
                        self.body_pos += n;
                        progress = true;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => self.dead = true,
                }
            } else {
                match self.stream.read(&mut self.hdr[self.hdr_pos..]) {
                    Ok(0) => self.on_eof(),
                    Ok(n) => {
                        self.hdr_pos += n;
                        progress = true;
                        if self.hdr_pos == wire::FRAME_HEADER {
                            let len = u32::from_be_bytes(self.hdr) as usize;
                            if len > max {
                                // fatal to framing: note for the pass to
                                // answer, read nothing further
                                self.oversize = Some(len);
                                self.read_dead = true;
                            } else {
                                self.body = vec![0u8; len];
                                self.body_pos = 0;
                                self.reading_body = true;
                            }
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => self.dead = true,
                }
            }
        }
        if progress {
            self.quiet_since = None;
        }
        progress
    }

    /// EOF: clean between frames with nothing owed; otherwise flush what
    /// the peer is still owed (half-close), then close.
    fn on_eof(&mut self) {
        self.read_dead = true;
        if self.out.is_empty() && self.deferred.is_empty() {
            self.dead = true;
        } else {
            self.close_after_flush = true;
        }
    }

    /// Append one framed response to the write buffer.
    fn queue(&mut self, rsp: &Response, max: usize, epoch: u64) {
        let payload = encode_fitting(rsp, max, epoch);
        let _ = wire::write_frame(&mut self.out, &payload, max);
    }

    /// Decide what one complete frame owes (no master lock involved).
    fn step(&mut self, frame: &[u8], wall_epoch: Instant) -> Step {
        let (req, rid) = match wire::decode_request_rid(frame) {
            Ok(r) => r,
            Err(wire::WireError::UnknownRequestTag(t)) => {
                // a newer peer's message: typed refusal, connection lives
                return Step::Respond(Response::Error(ProtoError::new(
                    ErrorCode::UnsupportedRequest,
                    format!(
                        "request tag {t:#04x} is not known to protocol v{}.{}",
                        crate::proto::PROTO_MAJOR,
                        crate::proto::PROTO_MINOR
                    ),
                )));
            }
            Err(e) => {
                return Step::Respond(Response::Error(ProtoError::new(
                    ErrorCode::MalformedFrame,
                    e,
                )));
            }
        };
        if !self.negotiated {
            if let Request::Hello { .. } = req {
                self.hello_pending = true;
                return Step::Dispatch { req, rid, kind: ItemKind::Hello };
            }
            self.read_dead = true;
            self.close_after_flush = true;
            return Step::Respond(Response::Error(ProtoError::new(
                ErrorCode::HandshakeRequired,
                "first frame on a connection must be Hello",
            )));
        }
        if req == Request::Shutdown {
            self.read_dead = true;
            return Step::Dispatch { req, rid, kind: ItemKind::Shutdown };
        }
        Step::Dispatch { req: stamp(req, wall_epoch), rid, kind: ItemKind::Normal }
    }
}

/// One dispatch batch entry: which connection it answers, and how the
/// response is interpreted.
struct Item {
    conn: usize,
    kind: ItemKind,
    req: Request,
    rid: Option<u64>,
}

/// Dispatch one tick's batch under a single master-lock acquisition,
/// coalescing maximal runs of heartbeats (arrival order preserved).
fn dispatch_batch(
    master: &Mutex<DormMaster>,
    shared: &Shared,
    items: &mut Vec<Item>,
    coalesce: bool,
) -> Vec<Response> {
    let mut m = lock_master(master);
    let mut rsps: Vec<Response> = Vec::with_capacity(items.len());
    let mut run: Vec<Request> = Vec::new();
    for item in items.drain(..) {
        let is_beat = matches!(item.req, Request::Heartbeat { .. });
        if coalesce && is_beat && item.kind == ItemKind::Normal {
            run.push(item.req);
            continue;
        }
        if !run.is_empty() {
            rsps.extend(m.dispatch_heartbeats(std::mem::take(&mut run)));
        }
        // v1.3: the trailing retry id (when the client stamped one)
        // makes a re-sent Submit/Complete answer from the dedupe cache
        // instead of double-applying after a re-dial
        rsps.push(m.dispatch_rid(item.req, item.rid));
    }
    if !run.is_empty() {
        rsps.extend(m.dispatch_heartbeats(run));
    }
    shared.epoch.store(m.epoch(), Ordering::SeqCst);
    rsps
}

/// The worker: drain the inbox, poll owned connections, batch-dispatch,
/// write answers, reap the dead — then park until there is reason to
/// wake (condvar when idle, bounded back-off tick while owning quiet
/// connections, blocking peek when owning exactly one).
fn worker_loop(
    idx: usize,
    master: Arc<Mutex<DormMaster>>,
    shared: Arc<Shared>,
    cfg: NetConfig,
    wall_epoch: Instant,
) {
    let me = Arc::clone(&shared.workers[idx]);
    let stall = (cfg.io_timeout_ms > 0).then(|| Duration::from_millis(cfg.io_timeout_ms));
    let mut conns: Vec<Conn> = Vec::new();
    let mut idle_streak = 0u32;
    loop {
        // adopt new connections
        {
            let mut inbox = me.inbox.lock().unwrap_or_else(|p| p.into_inner());
            for stream in inbox.drain(..) {
                match Conn::adopt(stream) {
                    Some(c) => conns.push(c),
                    None => {
                        shared.conns.fetch_sub(1, Ordering::SeqCst);
                    }
                }
            }
        }
        if shared.stopping() {
            shutdown_flush(&mut conns, stall);
            shared.conns.fetch_sub(conns.len(), Ordering::SeqCst);
            let mut inbox = me.inbox.lock().unwrap_or_else(|p| p.into_inner());
            shared.conns.fetch_sub(inbox.drain(..).count(), Ordering::SeqCst);
            return;
        }
        let did = pass(&mut conns, &master, &shared, &cfg, wall_epoch, stall);
        // reap and release seats
        let before = conns.len();
        conns.retain(|c| !c.dead);
        let reaped = before - conns.len();
        if reaped > 0 {
            shared.conns.fetch_sub(reaped, Ordering::SeqCst);
        }
        if did || reaped > 0 {
            idle_streak = 0;
            continue;
        }
        idle_streak = idle_streak.saturating_add(1);
        if conns.is_empty() {
            // no connections: true zero-CPU park until handoff or stop
            let mut inbox = me.inbox.lock().unwrap_or_else(|p| p.into_inner());
            while inbox.is_empty() && !shared.stopping() {
                inbox = me.cv.wait(inbox).unwrap_or_else(|p| p.into_inner());
            }
            idle_streak = 0;
            continue;
        }
        let lone_quiet = conns.len() == 1
            && conns[0].out.is_empty()
            && conns[0].deferred.is_empty()
            && !conns[0].read_dead;
        if lone_quiet {
            // one quiet connection: a blocking peek waits in the kernel,
            // so a lone client's round-trip costs no poll tick
            blocking_peek(&mut conns[0]);
        } else if idle_streak <= SPIN_PASSES {
            // spin-then-park: stay hot across the client turnaround gap
            std::thread::yield_now();
        } else {
            let shift = (idle_streak - SPIN_PASSES).min(4);
            let tick = Duration::from_millis((POLL_TICK_MIN_MS << shift).min(POLL_TICK_MAX_MS));
            let inbox = me.inbox.lock().unwrap_or_else(|p| p.into_inner());
            if inbox.is_empty() && !shared.stopping() {
                drop(me.cv.wait_timeout(inbox, tick));
            }
        }
    }
}

/// Kernel-blocking wait for the single-connection fast path: `peek`
/// returns the moment a byte (or EOF) arrives, bounded by
/// [`BLOCK_QUANTUM_MS`] so stop/inbox changes are still observed.
fn blocking_peek(c: &mut Conn) {
    let blocking_ok = c.stream.set_nonblocking(false).is_ok()
        && c.stream.set_read_timeout(Some(Duration::from_millis(BLOCK_QUANTUM_MS))).is_ok();
    if !blocking_ok {
        c.dead = true;
        return;
    }
    let mut probe = [0u8; 1];
    let r = c.stream.peek(&mut probe);
    if c.stream.set_nonblocking(true).is_err() {
        c.dead = true;
        return;
    }
    match r {
        Ok(0) => c.on_eof(),
        Ok(_) => {}
        Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {}
        Err(e) if e.kind() == ErrorKind::Interrupted => {}
        Err(_) => c.dead = true,
    }
}

/// One poll pass over a worker's connections: flush, pump, process
/// frames, batch-dispatch, answer, enforce stall deadlines.
fn pass(
    conns: &mut [Conn],
    master: &Mutex<DormMaster>,
    shared: &Shared,
    cfg: &NetConfig,
    wall_epoch: Instant,
    stall: Option<Duration>,
) -> bool {
    let max = cfg.max_frame_bytes;
    let mut did = false;
    let mut batch: Vec<Item> = Vec::new();
    let cached_epoch = shared.epoch.load(Ordering::SeqCst);
    for (ci, c) in conns.iter_mut().enumerate() {
        if c.dead {
            continue;
        }
        did |= c.flush();
        did |= c.pump(max);
        while let Some(frame) = c.deferred.pop_front() {
            did = true;
            if c.read_dead && c.close_after_flush {
                continue; // discard frames pipelined past a fatal one
            }
            if c.hello_pending {
                // the Hello's verdict decides this frame's fate next tick
                c.deferred.push_front(frame);
                break;
            }
            match c.step(&frame, wall_epoch) {
                Step::Respond(rsp) => c.queue(&rsp, max, cached_epoch),
                Step::Dispatch { req, rid, kind } => {
                    // at most one dispatch per connection per tick, so
                    // answers stay in request order even when a client
                    // pipelines dispatched and immediately-answered
                    // frames; batching happens *across* connections
                    batch.push(Item { conn: ci, kind, req, rid });
                    break;
                }
            }
        }
    }
    if !batch.is_empty() {
        did = true;
        let kinds: Vec<(usize, ItemKind)> = batch.iter().map(|i| (i.conn, i.kind)).collect();
        let rsps = dispatch_batch(master, shared, &mut batch, cfg.coalesce_heartbeats);
        let epoch = shared.epoch.load(Ordering::SeqCst);
        let mut shutdown = false;
        for ((ci, kind), rsp) in kinds.into_iter().zip(rsps) {
            let c = &mut conns[ci];
            match kind {
                ItemKind::Hello => {
                    c.hello_pending = false;
                    if matches!(rsp, Response::HelloAck { .. }) {
                        c.negotiated = true;
                    } else {
                        // version rejected: typed error then close
                        c.read_dead = true;
                        c.close_after_flush = true;
                        c.deferred.clear();
                    }
                }
                ItemKind::Shutdown => {
                    c.close_after_flush = true;
                    shutdown = true;
                }
                ItemKind::Normal => {}
            }
            c.queue(&rsp, max, epoch);
        }
        if shutdown {
            shared.request_stop();
        }
    }
    for c in conns.iter_mut() {
        if c.dead {
            continue;
        }
        if c.deferred.is_empty() && !c.hello_pending {
            if let Some(len) = c.oversize.take() {
                // framing cannot resync past an unread body: answer
                // (after every earlier frame's response), then close
                let e = ProtoError::new(
                    ErrorCode::FrameTooLarge,
                    format!("frame of {len} B exceeds the {max} B limit"),
                );
                c.queue(&Response::Error(e), max, shared.epoch.load(Ordering::SeqCst));
                c.close_after_flush = true;
                did = true;
            }
        }
        c.flush();
        if let Some(stall) = stall {
            // a peer silent mid-frame, or one not draining its answers,
            // is stalled: reap so it cannot pin a connection seat
            if c.mid_frame() || !c.out.is_empty() {
                let since = *c.quiet_since.get_or_insert_with(Instant::now);
                let wsince = *c.write_quiet.get_or_insert_with(Instant::now);
                if since.elapsed() >= stall || wsince.elapsed() >= stall {
                    c.dead = true;
                }
            } else {
                c.quiet_since = None;
                c.write_quiet = None;
            }
        }
    }
    did
}

/// Best-effort bounded flush of every owed response at shutdown, so the
/// client that sent Shutdown reads its Ok before the socket closes.
fn shutdown_flush(conns: &mut [Conn], stall: Option<Duration>) {
    const GRACE_CAP: Duration = Duration::from_millis(1000);
    let grace = stall.unwrap_or(GRACE_CAP).min(GRACE_CAP);
    for c in conns.iter_mut() {
        if c.dead || c.out.is_empty() {
            continue;
        }
        if c.stream.set_nonblocking(false).is_err() {
            continue;
        }
        let _ = c.stream.set_write_timeout(Some(grace));
        use std::io::Write;
        let _ = c.stream.write_all(&c.out);
        let _ = c.stream.flush();
    }
}

// ---- the legacy thread-per-connection server ----------------------------

/// Serve `master` with the original one-thread-per-connection blocking
/// server.  Retained as the measured baseline for `bench rpc-throughput`
/// and to pin, in `tests/transport_parity.rs`, that the multiplexed
/// [`serve`] is response-sequence-identical to it.  The accept loop is
/// shutdown-woken and sleep-free like the multiplexed one, and lease
/// sweeps run on the same dedicated sweeper thread.
pub fn serve_legacy(master: DormMaster, cfg: &NetConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.bind_addr)
        .with_context(|| format!("bind {}", cfg.bind_addr))?;
    let addr = listener.local_addr()?;
    let wall_epoch = Instant::now();
    let epoch0 = master.epoch();
    let master = Arc::new(Mutex::new(master));
    let shared = Arc::new(Shared {
        stop: AtomicBool::new(false),
        addr,
        epoch: AtomicU64::new(epoch0),
        conns: AtomicUsize::new(0),
        stop_mu: Mutex::new(()),
        stop_cv: Condvar::new(),
        workers: Vec::new(),
    });
    let mut threads = Vec::with_capacity(2);
    threads.push(spawn_sweeper(&master, &shared, cfg, wall_epoch));
    {
        let master = Arc::clone(&master);
        let shared = Arc::clone(&shared);
        let cfg = cfg.clone();
        threads.push(std::thread::spawn(move || {
            legacy_accept_loop(listener, master, shared, cfg, wall_epoch)
        }));
    }
    Ok(ServerHandle { addr, master, shared, threads })
}

fn legacy_accept_loop(
    listener: TcpListener,
    master: Arc<Mutex<DormMaster>>,
    shared: Arc<Shared>,
    cfg: NetConfig,
    wall_epoch: Instant,
) {
    loop {
        if shared.stopping() {
            return;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                if shared.stopping() {
                    return; // the wake-up self-connection
                }
                if shared.conns.load(Ordering::SeqCst) >= cfg.max_conns {
                    reject_over_limit(stream, &shared, &cfg);
                    continue;
                }
                log::debug!("control-plane connection from {peer}");
                shared.conns.fetch_add(1, Ordering::SeqCst);
                let master = Arc::clone(&master);
                let shared = Arc::clone(&shared);
                let cfg = cfg.clone();
                std::thread::spawn(move || {
                    handle_conn(stream, master, &shared, cfg, wall_epoch);
                    shared.conns.fetch_sub(1, Ordering::SeqCst);
                });
            }
            Err(e) => {
                log::warn!("accept failed: {e}; backing off");
                let g = shared.stop_mu.lock().unwrap_or_else(|p| p.into_inner());
                drop(shared.stop_cv.wait_timeout(g, Duration::from_millis(50)));
            }
        }
    }
}

/// Read exactly `buf.len()` bytes in bounded blocking polls.  While no
/// byte of `buf` has arrived and `idle_ok` holds, waiting is healthy (a
/// control connection between commands) and continues indefinitely; once
/// a frame is partially read — or for a frame body — a peer silent for
/// `stall` is stalled and the read fails so the handler can reap the
/// connection.  Checks `stop` between polls.  `Ok(false)` = clean EOF
/// before byte 0.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    idle_ok: bool,
    stall: Option<Duration>,
) -> std::result::Result<bool, ()> {
    use std::io::Read;
    let mut pos = 0;
    let mut quiet_since: Option<Instant> = None;
    while pos < buf.len() {
        if stop.load(Ordering::SeqCst) {
            return Ok(false);
        }
        match stream.read(&mut buf[pos..]) {
            Ok(0) => return if pos == 0 { Ok(false) } else { Err(()) },
            Ok(n) => {
                pos += n;
                quiet_since = None;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                if idle_ok && pos == 0 {
                    continue;
                }
                let since = *quiet_since.get_or_insert_with(Instant::now);
                if let Some(stall) = stall {
                    if since.elapsed() >= stall {
                        return Err(());
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return Err(()),
        }
    }
    Ok(true)
}

fn handle_conn(
    mut stream: TcpStream,
    master: Arc<Mutex<DormMaster>>,
    shared: &Shared,
    cfg: NetConfig,
    wall_epoch: Instant,
) {
    stream.set_nodelay(true).ok();
    // accepted sockets may inherit non-blocking from the listener on
    // some platforms, which would turn the timeout reads below into a
    // busy spin and make mid-frame writes fail spuriously — clear it
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    // bounded poll quantum: reads wake often enough to observe `stop`
    // and to enforce the mid-frame stall deadline without busy-waiting
    if stream.set_read_timeout(Some(Duration::from_millis(BLOCK_QUANTUM_MS))).is_err() {
        return;
    }
    let stall = (cfg.io_timeout_ms > 0).then(|| Duration::from_millis(cfg.io_timeout_ms));
    if stream.set_write_timeout(stall).is_err() {
        return;
    }
    let max = cfg.max_frame_bytes;
    let stop = &shared.stop;
    let mut negotiated = false;
    // the serving epoch, refreshed after every dispatch (it changes only
    // on promotion, but the cache spares a lock on pre-dispatch errors)
    let mut cur_epoch = lock_master(&master).epoch();
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        // header: idle waiting is healthy between commands
        let mut hdr = [0u8; wire::FRAME_HEADER];
        match read_full(&mut stream, &mut hdr, stop, true, stall) {
            Ok(true) => {}
            _ => return, // EOF, stop, or a peer stalled mid-header
        }
        let len = u32::from_be_bytes(hdr) as usize;
        if len > max {
            // framing cannot resync past an unread body: answer, close
            let e = ProtoError::new(
                ErrorCode::FrameTooLarge,
                format!("frame of {len} B exceeds the {max} B limit"),
            );
            send(&mut stream, &Response::Error(e), max, cur_epoch);
            return;
        }
        // body: a silent peer mid-frame is stalled — reap, never hang
        let mut payload = vec![0u8; len];
        match read_full(&mut stream, &mut payload, stop, false, stall) {
            Ok(true) => {}
            _ => return,
        }
        let (req, rid) = match wire::decode_request_rid(&payload) {
            Ok(r) => r,
            Err(wire::WireError::UnknownRequestTag(t)) => {
                // a newer peer's message: typed refusal, connection lives
                let e = ProtoError::new(
                    ErrorCode::UnsupportedRequest,
                    format!(
                        "request tag {t:#04x} is not known to protocol v{}.{}",
                        crate::proto::PROTO_MAJOR,
                        crate::proto::PROTO_MINOR
                    ),
                );
                if !send(&mut stream, &Response::Error(e), max, cur_epoch) {
                    return;
                }
                continue;
            }
            Err(e) => {
                let e = ProtoError::new(ErrorCode::MalformedFrame, e);
                if !send(&mut stream, &Response::Error(e), max, cur_epoch) {
                    return;
                }
                continue;
            }
        };
        if !negotiated {
            match req {
                Request::Hello { .. } => {
                    let rsp = {
                        let mut m = lock_master(&master);
                        let r = m.dispatch(req);
                        cur_epoch = m.epoch();
                        r
                    };
                    shared.epoch.store(cur_epoch, Ordering::SeqCst);
                    let ok = matches!(rsp, Response::HelloAck { .. });
                    if !send(&mut stream, &rsp, max, cur_epoch) || !ok {
                        return; // version rejected: typed error then close
                    }
                    negotiated = true;
                    continue;
                }
                _ => {
                    let e = ProtoError::new(
                        ErrorCode::HandshakeRequired,
                        "first frame on a connection must be Hello",
                    );
                    send(&mut stream, &Response::Error(e), max, cur_epoch);
                    return;
                }
            }
        }
        let shutdown = req == Request::Shutdown;
        let rsp = {
            let mut m = lock_master(&master);
            // v1.3: the trailing retry id (when the client stamped one)
            // makes a re-sent Submit/Complete answer from the dedupe
            // cache instead of double-applying after a re-dial
            let r = m.dispatch_rid(stamp(req, wall_epoch), rid);
            cur_epoch = m.epoch();
            r
        };
        shared.epoch.store(cur_epoch, Ordering::SeqCst);
        let sent = send(&mut stream, &rsp, max, cur_epoch);
        if shutdown {
            shared.request_stop();
            return;
        }
        if !sent {
            return;
        }
    }
}

//! Command-line interface (clap is not in the vendored registry; this is a
//! small positional+flag parser with typed accessors and usage text).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line: subcommand + flags (`--key value` / `--flag`).
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Cli {
    /// Parse from an argv slice (without the binary name).
    pub fn parse(args: &[String]) -> Result<Cli> {
        let mut cli = Cli::default();
        let mut it = args.iter().peekable();
        cli.command = it
            .next()
            .cloned()
            .ok_or_else(|| anyhow!("missing subcommand"))?;
        if cli.command.starts_with('-') {
            bail!("expected a subcommand, got flag {:?}", cli.command);
        }
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bad flag {arg:?}");
                }
                // flag value = next token unless it is another flag / end
                // (a single leading '-' is a value: negative numbers)
                let value = match it.peek() {
                    Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                    _ => "true".to_string(),
                };
                if cli.flags.insert(key.to_string(), value).is_some() {
                    bail!("duplicate flag --{key} (each flag may appear once)");
                }
            } else {
                cli.positional.push(arg.clone());
            }
        }
        Ok(cli)
    }

    pub fn str_flag(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.into())
    }

    pub fn u64_flag(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} wants an integer, got {v:?}")),
        }
    }

    pub fn f64_flag(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} wants a number, got {v:?}")),
        }
    }

    pub fn bool_flag(&self, key: &str) -> bool {
        self.flags.get(key).map(|v| v == "true").unwrap_or(false)
    }
}

/// Top-level usage text.
pub const USAGE: &str = "\
dorm — dynamically-partitioned cluster management for distributed ML
        (reproduction of Sun et al., SMARTCOMP'17)

USAGE: dorm <command> [flags]

COMMANDS:
  simulate   run the §V testbed experiment (static + Dorm-1/2/3, 24 h DES)
               --seed N          workload seed (default 17)
               --horizon H       hours (default 24)
  churn      failure-injection sweep: Dorm + all four baselines vs MTBF
               --seed N          workload + failure seed (default 17)
               --horizon H       hours (default 8)
               --apps N          workload size (default 16)
               --mtbfs LIST      comma-separated MTBF hours (default 2,4,8,16,32)
               --mttr H          mean repair time in hours (default 0.5)
               --ckpt H          periodic checkpoint cadence hours (0 = on adjustment only)
               --master-fail H   kill the CMS master at hour H (0 = never)
               --takeover H      standby takeover latency in hours (default 0.05)
               --csv             also write reports/churn_<system>.csv
               --domains         correlated failure-domain sweep instead
                                 (DESIGN.md §14): whole racks die in one
                                 batch; Dorm runs risk-blind AND risk-
                                 aware (online MTBF estimator steering
                                 placement); writes churn_domains_*.csv
               --domain-mtbfs L  domain MTBF hours to sweep (default 2,4,8,16)
               --domain-size N   servers per rack (default 4)
               --domain-mttr H   rack repair time hours (default 1)
               --hot-factor X    rack 0 fails X times more often (default 4)
               --server-mtbf H   independent per-server MTBF alongside the
                                 rack outages (default 1e9 = off)
  replay     stream a job-arrival trace through the DES or a live master
             (DESIGN.md §13; never materializes the trace)
               --trace FILE      trace CSV (dorm / alibaba-like / borg-like
                                 layout, detected from the header); or
               --gen N           synthesize an N-arrival trace instead
               --seed N          seed for --gen (default 17)
               --mode M          des | live | sweep (default des)
               --buffer N        streaming look-ahead, records (default 4096)
               --time-scale X    open-loop timestamp multiplier (default 1)
               --rate R          closed-loop arrivals per simulated hour
                                 (0 = open loop; default 0)
               --horizon H       DES horizon hours (default 24)
               --slaves N        DES/live cluster size (default 20)
               --cpu/--gpu/--ram per-slave capacity (default 12/0.25/128)
               --connect LIST    live/sweep: TCP master candidates; omit to
                                 run against an in-process master
               --window N        live in-flight window (default 64)
               --ms-per-hour T   live wall pacing, ms per replayed hour
                                 (default 0 = as fast as admitted)
               --max-apps N      live: stop after N submissions (0 = all)
               --rates LIST      sweep: offered arrivals/sec, comma-
                                 separated (default 50,100,200,400,800)
               --apps-per-rate N sweep: submissions per rate (default 200)
               --export FILE     write the (generated) trace as CSV and exit
               --csv             write reports/replay_*.csv series
               --config FILE     TOML file; [trace] section sets the
                                 defaults for the flags above
  fig1       print the Fig. 1 duration-CDF model
  train      train a model through the full Dorm stack (needs artifacts/)
               --model NAME      lr | mf | tfm | tfm_e2e (default lr)
               --steps N         BSP steps (default 100)
               --workers W       worker slots (default 4)
               --lr X            learning rate (default 0.1)
  latency    task-level scheduling-latency analysis (§II-C, 430 ms claim)
               --nodes N         cluster size (default 100)
  master     serve the control plane over TCP (DESIGN.md §9, §11)
               --bind ADDR       listen address (default 127.0.0.1:4600)
               --slaves N        cluster size (default 2)
               --cpu/--gpu/--ram per-slave capacity (default 12/0/64)
               --theta1/--theta2 Dorm thresholds (default 0.1/0.1)
               --cells N         shard the scheduler into N cells solving
                                 in parallel ([cells] config section;
                                 default 1 = the single engine)
               --racks R         name the slaves rackK-sJ in R contiguous
                                 blocks and enable risk-aware (domain-
                                 spread) placement over the derived rack
                                 topology (DESIGN.md §14; default off)
               --lease-ms T      lease timeout; 0 = never expire (default 0)
               --sweep-ms T      lease sweep period (default 250 when
                                 --lease-ms > 0, else off)
               --workers N       multiplexed-server worker threads; 0 =
                                 one per core, clamped to 8
                                 ([net].workers)
               --max-conns N     open-connection cap; dials past it get a
                                 typed TooManyConnections reject
                                 ([net].max_conns, default 1024)
               --legacy-net      serve with the retained thread-per-
                                 connection baseline instead of the
                                 multiplexed worker pool (DESIGN.md §15)
               --store DIR       checkpoint dir (default net_checkpoints)
               --ha              self-checkpoint the master through the
                                 store; on restart, resume from the
                                 newest snapshot at a fresh epoch
                                 ([ha] config section)
               --standby         watch a primary instead of serving; on
                                 its lease lapse, promote the checkpointed
                                 state at epoch+1 and serve it
               --watch ADDR      primary address a standby probes
               --master-lease-ms T  standby declares the primary dead
                                 after T ms without a good probe
               --probe-ms T      standby probe period (default 250)
               --snapshot-every N  full master snapshot every N mutating
                                 events, WAL in between (default 64)
               --epoch N         start at an explicit epoch (testing /
                                 deposed-primary simulation)
             master/slave/ctl all also take:
               --config FILE     TOML file; its [net]/[ha] sections set
                                 frame limit / timeouts / worker pool /
                                 heartbeat coalescing / failover knobs
               --frame-kib N     frame-size limit override, KiB
               --io-timeout-ms T mid-frame stall timeout override
  slave      run one DormSlave as a separate process
               --connect LIST    master candidates, comma-separated in
                                 dial order (default: [ha].candidates
                                 from --config, else 127.0.0.1:4600);
                                 re-dials across a failover, refuses a
                                 deposed (stale-epoch) master's directives
               --index J         preassigned server ordinate; omit it to
                                 join via the Register RPC (the master
                                 picks a free seat; duplicate live names
                                 are refused with a typed error)
               --name S          slave name (default slave<J> with
                                 --index, slave-<pid> when registering)
               --period-ms T     heartbeat period (default:
                                 [net].heartbeat_period_ms = 500)
               --cpu/--gpu/--ram local capacity (default 12/0/64)
  ctl        one control-plane request against a running master
               --connect LIST    master candidates, comma-separated
                                 (default: [ha].candidates from
                                 --config, else 127.0.0.1:4600)
               --min-epoch N     refuse masters serving an epoch < N
                                 (fences a deposed primary's writes)
               ops: submit [--cpu C --gpu G --ram R --weight W
                            --nmin N --nmax N]   | complete --app N
                    query [--app N] | advance --app N --steps S
                    checkpoint --app N | expire | fail --server J
                    recover --server J | shutdown
  bench      run a tracked benchmark from the installed binary
               rpc-throughput    control-plane saturation sweep: drive
                                 concurrent heartbeat/query/submit
                                 clients against the legacy and the
                                 multiplexed server, report sustained
                                 req/s + p50/p99 (DESIGN.md §15)
               --clients N       concurrent clients (default 64)
               --servers N       cluster size = heartbeat ordinates
                                 (default 64)
               --seconds S       seconds per sweep point (default 2)
               --json FILE       splice the measured `rpc` series into
                                 FILE (BENCH_sched.json layout, gated by
                                 scripts/check_bench.sh)
  help       this text
";

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_command_flags_positionals() {
        let c = Cli::parse(&argv("simulate extra --seed 42 --fig1")).unwrap();
        assert_eq!(c.command, "simulate");
        assert_eq!(c.u64_flag("seed", 0).unwrap(), 42);
        assert!(c.bool_flag("fig1"));
        assert_eq!(c.positional, vec!["extra"]);
    }

    #[test]
    fn defaults_apply() {
        let c = Cli::parse(&argv("train")).unwrap();
        assert_eq!(c.str_flag("model", "lr"), "lr");
        assert_eq!(c.u64_flag("steps", 100).unwrap(), 100);
        assert!(!c.bool_flag("verbose"));
    }

    #[test]
    fn errors_reported() {
        assert!(Cli::parse(&[]).is_err());
        assert!(Cli::parse(&["--seed".into(), "2".into()]).is_err());
        let c = Cli::parse(&argv("train --steps abc")).unwrap();
        assert!(c.u64_flag("steps", 1).is_err());
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let c = Cli::parse(&argv("simulate --verbose --seed 3")).unwrap();
        assert!(c.bool_flag("verbose"));
        assert_eq!(c.u64_flag("seed", 0).unwrap(), 3);
    }

    #[test]
    fn f64_flag_parses() {
        let c = Cli::parse(&argv("train --lr 0.25")).unwrap();
        assert_eq!(c.f64_flag("lr", 0.1).unwrap(), 0.25);
        assert_eq!(c.f64_flag("other", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn duplicate_flags_rejected() {
        let e = Cli::parse(&argv("simulate --seed 1 --seed 2")).unwrap_err();
        assert!(e.to_string().contains("duplicate flag --seed"), "{e}");
        // a value-less duplicate is just as wrong
        assert!(Cli::parse(&argv("simulate --csv --csv")).is_err());
        // and a bool/value mix must not silently pick a winner
        assert!(Cli::parse(&argv("simulate --seed --seed 2")).is_err());
    }

    #[test]
    fn empty_double_dash_rejected() {
        assert!(Cli::parse(&argv("simulate -- 3")).is_err());
        assert!(Cli::parse(&argv("simulate --")).is_err());
    }

    #[test]
    fn negative_number_values() {
        // a single leading '-' is a value, not a flag
        let c = Cli::parse(&argv("train --lr -0.5 --delta -3")).unwrap();
        assert_eq!(c.f64_flag("lr", 0.1).unwrap(), -0.5);
        assert_eq!(c.f64_flag("delta", 0.0).unwrap(), -3.0);
        // negative integers refuse to parse as unsigned, with a message
        assert!(c.u64_flag("delta", 0).is_err());
        // a bare negative token with no preceding flag is positional
        let c = Cli::parse(&argv("simulate -7")).unwrap();
        assert_eq!(c.positional, vec!["-7"]);
    }
}

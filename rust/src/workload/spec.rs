//! One documented seed for every synthesized workload.
//!
//! Before this existed, each call site seeded its own `Rng` ad hoc
//! (`Experiment::paper`, the churn sweep, the figure benches), which made
//! "the workload for seed 17" a property of the call path rather than of
//! the seed.  [`WorkloadSpec`] is the single source of truth: the same
//! `(seed, napps, mean_interarrival_min)` triple produces byte-identical
//! workloads whether they are materialized for the DES
//! ([`WorkloadSpec::generate`]), exported as a trace CSV
//! ([`super::trace::export`]), or streamed arrival-by-arrival at scales
//! that must never be materialized ([`WorkloadSpec::stream`]).
//!
//! The finite [`WorkloadSpec::generate`] path reproduces the historical
//! `Rng::new(seed)` + [`WorkloadGen::generate`] sequence exactly, so every
//! seeded experiment in the repo (and every blessed bench baseline) is
//! unchanged by the refactor.

use crate::util::Rng;

use super::durations::DurationModel;
use super::table2::{table2_rows, Table2Row, WorkloadApp, WorkloadGen};

/// A reproducible synthesized workload: seed + shape, nothing hidden.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    /// The one seed.  Everything derived from this spec — type shuffle,
    /// Poisson arrivals, log-normal durations — is a pure function of it.
    pub seed: u64,
    /// Cap on generated apps for [`WorkloadSpec::generate`]
    /// (0 = the full Table-II mix, 50 apps).
    pub napps: usize,
    /// Mean Poisson inter-arrival time in minutes (§V-A-3: 20).
    pub mean_interarrival_min: f64,
    pub duration_model: DurationModel,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            seed: 17,
            napps: 0,
            mean_interarrival_min: 20.0,
            duration_model: DurationModel::synthetic_eval(),
        }
    }
}

impl WorkloadSpec {
    /// The paper's §V workload under `seed`.
    pub fn paper(seed: u64) -> Self {
        WorkloadSpec { seed, ..Default::default() }
    }

    fn gen(&self) -> WorkloadGen {
        WorkloadGen {
            rows: table2_rows(),
            mean_interarrival_min: self.mean_interarrival_min,
            duration_model: self.duration_model.clone(),
        }
    }

    /// The Table-II rows this spec draws from.
    pub fn rows(&self) -> Vec<Table2Row> {
        table2_rows()
    }

    /// Materialize the workload (identical to the pre-spec
    /// `WorkloadGen::generate(&mut Rng::new(seed))` sequence).
    pub fn generate(&self) -> Vec<WorkloadApp> {
        let mut rng = Rng::new(self.seed);
        let mut wl = self.gen().generate(&mut rng);
        if self.napps > 0 {
            wl.truncate(self.napps);
        }
        wl
    }

    /// An unbounded arrival stream for trace-scale synthesis (`dorm
    /// replay --gen N`): rows sampled in proportion to their Table-II
    /// `num` counts, Poisson arrivals, log-normal durations.  Its RNG is
    /// forked off the spec seed, so the stream is reproducible from the
    /// same single `--seed` without perturbing [`WorkloadSpec::generate`]
    /// (which must keep its historical draw order).
    pub fn stream(&self) -> WorkloadStream {
        let gen = self.gen();
        let weights: Vec<u32> = gen.rows.iter().map(|r| r.num).collect();
        WorkloadStream {
            gen,
            weights,
            rng: Rng::new(self.seed).fork(0x7261_7465), // "rate"
            t_hours: 0.0,
        }
    }
}

/// Infinite iterator of [`WorkloadApp`]s from [`WorkloadSpec::stream`].
pub struct WorkloadStream {
    gen: WorkloadGen,
    weights: Vec<u32>,
    rng: Rng,
    t_hours: f64,
}

impl Iterator for WorkloadStream {
    type Item = WorkloadApp;

    fn next(&mut self) -> Option<WorkloadApp> {
        self.t_hours += self.rng.exponential(self.gen.mean_interarrival_min) / 60.0;
        // sample a row index proportional to the Table-II type counts
        let total: u32 = self.weights.iter().sum();
        let mut pick = self.rng.below(total as u64) as u32;
        let mut row_idx = 0usize;
        for (i, &w) in self.weights.iter().enumerate() {
            if pick < w {
                row_idx = i;
                break;
            }
            pick -= w;
        }
        let row = &self.gen.rows[row_idx];
        let dur = row.duration_median_hours
            * self.rng.log_normal(0.0, self.gen.duration_model.app_sigma);
        Some(WorkloadApp {
            row: row_idx,
            tag: row.model.to_string(),
            submit_hours: self.t_hours,
            duration_at_baseline_hours: dur,
            baseline_n: row.baseline_containers.max(1),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The spec must reproduce the exact pre-refactor sequence: this is
    /// what keeps `Experiment::paper(seed)` (and every blessed baseline)
    /// stable across the seed-threading change.
    #[test]
    fn generate_matches_legacy_draw_order() {
        let legacy = {
            let gen = WorkloadGen::default();
            let mut rng = Rng::new(17);
            gen.generate(&mut rng)
        };
        let spec = WorkloadSpec::paper(17).generate();
        assert_eq!(legacy.len(), spec.len());
        for (a, b) in legacy.iter().zip(&spec) {
            assert_eq!(a.row, b.row);
            assert_eq!(a.submit_hours, b.submit_hours);
            assert_eq!(a.duration_at_baseline_hours, b.duration_at_baseline_hours);
        }
    }

    #[test]
    fn same_seed_same_workload_different_seed_differs() {
        let a = WorkloadSpec::paper(3).generate();
        let b = WorkloadSpec::paper(3).generate();
        let c = WorkloadSpec::paper(4).generate();
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.submit_hours == y.submit_hours));
        assert!(a.iter().zip(&c).any(|(x, y)| x.submit_hours != y.submit_hours));
    }

    #[test]
    fn napps_truncates() {
        let spec = WorkloadSpec { napps: 5, ..WorkloadSpec::paper(17) };
        assert_eq!(spec.generate().len(), 5);
        // the prefix is the same workload
        let full = WorkloadSpec::paper(17).generate();
        assert_eq!(spec.generate()[4].submit_hours, full[4].submit_hours);
    }

    #[test]
    fn stream_is_monotone_reproducible_and_mixes_types() {
        let spec = WorkloadSpec::paper(11);
        let a: Vec<_> = spec.stream().take(2_000).collect();
        let b: Vec<_> = spec.stream().take(2_000).collect();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.submit_hours, y.submit_hours);
            assert_eq!(x.row, y.row);
        }
        for w in a.windows(2) {
            assert!(w[0].submit_hours <= w[1].submit_hours);
        }
        // all 7 Table-II types appear in 2000 draws, short types dominate
        let rows = table2_rows();
        let mut counts = vec![0usize; rows.len()];
        for x in &a {
            counts[x.row] += 1;
        }
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
        assert!(counts[0] > counts[3], "LR (num 20) outdraws VGG (num 1): {counts:?}");
        // mean inter-arrival ≈ 20 min
        let mean_min = a.last().unwrap().submit_hours * 60.0 / a.len() as f64;
        assert!((mean_min - 20.0).abs() < 2.0, "{mean_min}");
    }
}

//! Server liveness via leases.
//!
//! Each DormSlave periodically reports to the master (§III-A-2); the
//! report renews a lease.  A server whose lease has not been renewed for
//! `timeout_hours` is declared dead: the master reclaims its capacity and
//! containers and re-drives the allocation engine (`crate::master`).  The
//! DES reuses the same table as its alive-set bookkeeping (failures arrive
//! as injected events rather than missed heartbeats, so its timeout is
//! infinite).

/// Per-server lease table.  Time is whatever monotone clock the backend
/// uses: simulated hours in the DES, an event counter on the live master.
#[derive(Clone, Debug)]
pub struct LeaseTable {
    timeout: f64,
    /// Last renewal time per server (meaningless while dead).
    renewed: Vec<f64>,
    alive: Vec<bool>,
}

impl LeaseTable {
    /// All servers start alive with leases renewed at time 0.
    pub fn new(n_servers: usize, timeout: f64) -> Self {
        assert!(timeout > 0.0, "lease timeout must be positive");
        LeaseTable {
            timeout,
            renewed: vec![0.0; n_servers],
            alive: vec![true; n_servers],
        }
    }

    /// An alive server's heartbeat landed at `now`.  Renewals from dead
    /// servers are ignored — a dead server must be explicitly recovered
    /// (its containers are gone; a late heartbeat must not resurrect it
    /// with stale bookkeeping).
    pub fn renew(&mut self, server: usize, now: f64) {
        if self.alive[server] {
            self.renewed[server] = self.renewed[server].max(now);
        }
    }

    /// Alive servers whose lease lapsed before `now`.
    pub fn expired(&self, now: f64) -> Vec<usize> {
        (0..self.alive.len())
            .filter(|&j| self.alive[j] && now - self.renewed[j] > self.timeout)
            .collect()
    }

    pub fn mark_dead(&mut self, server: usize) {
        self.alive[server] = false;
    }

    /// The server came back; its lease restarts at `now`.
    pub fn mark_alive(&mut self, server: usize, now: f64) {
        self.alive[server] = true;
        self.renewed[server] = now;
    }

    pub fn is_alive(&self, server: usize) -> bool {
        self.alive[server]
    }

    /// The whole liveness column at once.  Sweep-style consumers (the
    /// master's utilization/reallocation paths, the cell router's capacity
    /// masking) index this slice directly instead of issuing one
    /// [`LeaseTable::is_alive`] probe per server per pass.
    pub fn alive_mask(&self) -> &[bool] {
        &self.alive
    }

    /// Latest renewal timestamp across alive servers — the table's best
    /// estimate of "now" when the caller has no clock of its own (e.g.
    /// re-anchoring a recovered server's lease so it does not instantly
    /// re-expire against later heartbeats).
    pub fn latest_renewal(&self) -> f64 {
        self.renewed
            .iter()
            .zip(&self.alive)
            .filter(|&(_, &alive)| alive)
            .map(|(&r, _)| r)
            .fold(0.0, f64::max)
    }

    pub fn n_alive(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    pub fn len(&self) -> usize {
        self.alive.len()
    }

    pub fn is_empty(&self) -> bool {
        self.alive.is_empty()
    }

    pub fn timeout(&self) -> f64 {
        self.timeout
    }

    /// Decompose into `(timeout, renewed, alive)` — the serializable parts
    /// a master checkpoint persists (`crate::master::ha`).
    pub fn to_parts(&self) -> (f64, Vec<f64>, Vec<bool>) {
        (self.timeout, self.renewed.clone(), self.alive.clone())
    }

    /// Rebuild a table from its serialized parts (inverse of
    /// [`LeaseTable::to_parts`]).  The two vectors must be the same length.
    pub fn from_parts(timeout: f64, renewed: Vec<f64>, alive: Vec<bool>) -> Self {
        assert!(timeout > 0.0, "lease timeout must be positive");
        assert_eq!(renewed.len(), alive.len(), "lease parts length mismatch");
        LeaseTable { timeout, renewed, alive }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heartbeats_keep_servers_alive() {
        let mut t = LeaseTable::new(3, 1.0);
        t.renew(0, 0.9);
        t.renew(1, 0.9);
        // server 2 never heartbeats after t=0
        assert_eq!(t.expired(1.5), vec![2]);
        assert_eq!(t.n_alive(), 3, "expiry is detected, not applied");
        t.mark_dead(2);
        assert_eq!(t.n_alive(), 2);
        assert!(t.expired(1.5).is_empty(), "dead servers not re-reported");
    }

    #[test]
    fn dead_servers_ignore_late_heartbeats() {
        let mut t = LeaseTable::new(1, 1.0);
        t.mark_dead(0);
        t.renew(0, 5.0); // late packet from a zombie
        assert!(!t.is_alive(0));
        t.mark_alive(0, 6.0);
        assert!(t.is_alive(0));
        assert!(t.expired(6.5).is_empty(), "lease restarted at recovery");
        assert_eq!(t.expired(7.1), vec![0]);
    }

    #[test]
    fn latest_renewal_tracks_alive_servers_only() {
        let mut t = LeaseTable::new(3, 1.0);
        t.renew(0, 4.0);
        t.renew(1, 9.0);
        t.mark_dead(1); // dead server's timestamp must not count
        assert_eq!(t.latest_renewal(), 4.0);
        t.mark_alive(2, t.latest_renewal());
        assert!(t.expired(4.5).is_empty());
    }

    #[test]
    fn alive_mask_mirrors_per_server_probes() {
        let mut t = LeaseTable::new(4, 1.0);
        t.mark_dead(1);
        t.mark_dead(3);
        assert_eq!(t.alive_mask(), &[true, false, true, false]);
        for j in 0..t.len() {
            assert_eq!(t.alive_mask()[j], t.is_alive(j));
        }
    }

    #[test]
    fn boundary_is_strict() {
        let t = LeaseTable::new(1, 1.0);
        assert!(t.expired(1.0).is_empty(), "exactly at timeout still held");
        assert_eq!(t.expired(1.0 + 1e-9), vec![0]);
    }
}

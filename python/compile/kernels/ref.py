"""Pure-jnp oracles for the L1 Pallas kernels.

These are the CORE correctness signal: pytest asserts the kernels match
these references across shape/dtype sweeps (hypothesis), and the kernels'
custom-vjp backward passes are validated against jax.grad of these.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

_ACTIVATIONS = {
    "linear": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": jax.nn.gelu,
    "sigmoid": jax.nn.sigmoid,
}


def matmul_ref(x, w, b, activation="linear"):
    """act(x @ w + b) with f32 accumulation, matching the kernel contract."""
    z = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    z = z + b.astype(jnp.float32)
    return _ACTIVATIONS[activation](z).astype(x.dtype)


def attention_ref(q, k, v):
    """Causal softmax(q k^T / sqrt(dh)) v over [B, H, S, Dh], f32 math."""
    b, h, s, dh = q.shape
    scale = 1.0 / math.sqrt(dh)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return out.astype(q.dtype)

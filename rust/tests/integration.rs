//! Cross-module integration tests: the live control plane against the
//! simulator's physics, failure injection on the checkpoint path, and the
//! §V experiment's end-to-end invariants.

use std::collections::BTreeMap;

use dorm::app::{AppId, AppSpec, AppState, Checkpoint, CheckpointStore, Engine};
use dorm::baselines::StaticPolicy;
use dorm::config::{ClusterConfig, DormConfig, SimConfig};
use dorm::master::DormMaster;
use dorm::optimizer::{Optimizer, OptApp, SolveMode};
use dorm::resources::Res;
use dorm::sim::{run_sim, DormPolicy, Experiment, PerfModel};
use dorm::util::prop;
use dorm::util::Rng;
use dorm::workload::{table2_rows, WorkloadGen};

fn store(tag: &str) -> CheckpointStore {
    let d = std::env::temp_dir().join(format!("dorm_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    CheckpointStore::new(d).unwrap()
}

fn spec(cpu: f64, ram: f64, w: u32, lo: u32, hi: u32) -> AppSpec {
    AppSpec {
        executor: Engine::MxNet,
        demand: Res::cpu_gpu_ram(cpu, 0.0, ram),
        weight: w,
        n_max: hi,
        n_min: lo,
        cmd: ["lr".into(), "lr".into()],
    }
}

/// The live master and the simulator share the optimizer; their decisions
/// on the same app mix must agree on aggregate container counts.
#[test]
fn master_and_sim_agree_on_allocation() {
    let cluster = ClusterConfig::uniform(4, Res::cpu_gpu_ram(12.0, 0.0, 64.0));
    let mut master = DormMaster::new(&cluster, DormConfig::DORM1, store("agree"));
    let a = master.submit(spec(2.0, 8.0, 1, 1, 16)).unwrap();
    let b = master.submit(spec(4.0, 8.0, 2, 1, 8)).unwrap();

    // same instance solved directly through the optimizer
    let opt = Optimizer::with_mode(DormConfig::DORM1, SolveMode::Heuristic);
    let apps = vec![
        OptApp {
            id: AppId(100),
            demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0),
            weight: 1.0,
            n_min: 1,
            n_max: 16,
            prev: None,
            current: BTreeMap::new(),
        },
        OptApp {
            id: AppId(101),
            demand: Res::cpu_gpu_ram(4.0, 0.0, 8.0),
            weight: 2.0,
            n_min: 1,
            n_max: 8,
            prev: None,
            current: BTreeMap::new(),
        },
    ];
    let caps: Vec<Res> = (0..4).map(|_| Res::cpu_gpu_ram(12.0, 0.0, 64.0)).collect();
    let d = opt.allocate(&apps, &caps).unwrap();

    // the master submitted sequentially (a alone, then b arrives), so only
    // the final state is comparable — and both must satisfy capacity and
    // sum to a Pareto-ish fill
    let (ca, cb) = (master.containers_of(a), master.containers_of(b));
    assert!(ca >= 1 && cb >= 1);
    let direct: u32 = d.counts.values().sum();
    assert!(
        (ca + cb) as i64 - direct as i64 <= 4,
        "master {}+{} vs direct {}",
        ca,
        cb,
        direct
    );
}

/// Kill the master's checkpoint mid-write (simulated by corrupting the
/// file): resume must fall back to the previous good snapshot.
#[test]
fn corrupted_checkpoint_falls_back() {
    let st = store("corrupt");
    let ck = |step: u64, v: f32| Checkpoint {
        app: AppId(9),
        step,
        model: "lr".into(),
        loss: 0.5,
        params: vec![v; 65],
    };
    st.save(&ck(1, 1.0)).unwrap();
    let p2 = st.save(&ck(2, 2.0)).unwrap();
    // corrupt latest
    let mut bytes = std::fs::read(&p2).unwrap();
    let n = bytes.len();
    bytes[n / 2] ^= 0x55;
    std::fs::write(&p2, bytes).unwrap();
    let got = st.load_latest(AppId(9)).unwrap().unwrap();
    assert_eq!(got.step, 1);
    assert_eq!(got.params[0], 1.0);
}

/// A checkpoint truncated mid-write (crash before the tail was flushed)
/// must be rejected and recovery must fall back to the previous good
/// snapshot — same guarantee as digest corruption, different failure mode.
#[test]
fn truncated_checkpoint_falls_back() {
    let st = store("truncated");
    let ck = |step: u64, v: f32| Checkpoint {
        app: AppId(12),
        step,
        model: "lr".into(),
        loss: 0.5,
        params: vec![v; 65],
    };
    st.save(&ck(1, 1.0)).unwrap();
    let p2 = st.save(&ck(2, 2.0)).unwrap();
    // truncate the newest file: drop the digest and half the params
    let bytes = std::fs::read(&p2).unwrap();
    std::fs::write(&p2, &bytes[..bytes.len() / 2]).unwrap();
    assert!(Checkpoint::from_bytes(&bytes[..bytes.len() / 2]).is_err());
    let got = st.load_latest(AppId(12)).unwrap().unwrap();
    assert_eq!(got.step, 1, "recovery must use the previous good snapshot");
    assert_eq!(got.params[0], 1.0);

    // degenerate truncations: empty and shorter-than-header files
    let p3 = st.save(&ck(3, 3.0)).unwrap();
    std::fs::write(&p3, b"").unwrap();
    assert_eq!(st.load_latest(AppId(12)).unwrap().unwrap().step, 1);
    std::fs::write(&p3, b"DORM").unwrap();
    assert_eq!(st.load_latest(AppId(12)).unwrap().unwrap().step, 1);
}

/// Bad-digest checkpoints must not survive retention either: pruning keeps
/// the newest good snapshot, so corruption + pruning still recovers.
#[test]
fn corrupt_checkpoint_rejected_even_after_pruning() {
    let st = store("corrupt_prune");
    let ck = |step: u64, v: f32| Checkpoint {
        app: AppId(13),
        step,
        model: "lr".into(),
        loss: 0.5,
        params: vec![v; 33],
    };
    st.save(&ck(1, 1.0)).unwrap();
    st.save(&ck(2, 2.0)).unwrap();
    let p3 = st.save(&ck(3, 3.0)).unwrap();
    // flip one digest byte of the newest
    let mut bytes = std::fs::read(&p3).unwrap();
    let n = bytes.len();
    bytes[n - 1] ^= 0x01;
    std::fs::write(&p3, bytes).unwrap();
    // retention to 1 file: the newest good snapshot (step 2) must survive
    st.prune(AppId(13), 1).unwrap();
    let got = st.load_latest(AppId(13)).unwrap().unwrap();
    assert_eq!(got.step, 2);
    assert_eq!(got.params[0], 2.0);
}

/// Slave failure injection: removing a slave's capacity mid-run must not
/// break the master's books (apps on other slaves unaffected).
#[test]
fn master_survives_app_churn() {
    let cluster = ClusterConfig::uniform(3, Res::cpu_gpu_ram(8.0, 0.0, 32.0));
    let mut master = DormMaster::new(
        &cluster,
        DormConfig { theta1: 0.5, theta2: 0.5 },
        store("churn"),
    );
    let mut rng = Rng::new(11);
    let mut live: Vec<AppId> = Vec::new();
    for i in 0..30 {
        if rng.f64() < 0.6 || live.is_empty() {
            let hi = rng.range_u64(2, 8) as u32;
            if let Ok(id) = master.submit(spec(
                rng.range_f64(1.0, 3.0).round(),
                rng.range_f64(2.0, 8.0).round(),
                1 + (i % 3) as u32,
                1,
                hi,
            )) {
                live.push(id);
            }
        } else {
            let idx = rng.below(live.len() as u64) as usize;
            let id = live.swap_remove(idx);
            master.complete(id).unwrap();
        }
        // invariant: every slave within capacity after every event
        for s in &master.slaves {
            assert!(
                s.used().fits_in(s.capacity()),
                "slave {} over capacity after event {i}",
                s.name
            );
        }
        assert!(master.utilization() <= 3.0 + 1e-9);
    }
}

/// Determinism: the same workload seed must produce identical metrics.
#[test]
fn simulation_deterministic() {
    let a = Experiment::scaled(7, 6.0, 12);
    let b = Experiment::scaled(7, 6.0, 12);
    let ra = a.run(&mut DormPolicy::new(DormConfig::DORM3));
    let rb = b.run(&mut DormPolicy::new(DormConfig::DORM3));
    assert_eq!(
        ra.metrics().utilization.points,
        rb.metrics().utilization.points
    );
    assert_eq!(ra.outcome.completed, rb.outcome.completed);
}

/// Dorm's decision-time guarantees hold across seeds (property-style over
/// whole simulations, smaller scale for speed).
#[test]
fn prop_dorm_invariants_across_seeds() {
    prop::check(8, |rng| {
        let seed = rng.next_u64() % 1000;
        let exp = Experiment::scaled(seed, 5.0, 10);
        let run = exp.run(&mut DormPolicy::new(DormConfig::DORM3));
        // adjustment batches bounded by ceil(theta2 * carried) <= ceil(0.1*10) = 1..2
        for &b in &run.metrics().adjustment_batch_sizes {
            if b > 2 {
                return Err(format!("seed {seed}: batch {b} > 2"));
            }
        }
        // utilization in [0, m]
        if run.metrics().utilization.max() > 3.0 + 1e-9 {
            return Err(format!("seed {seed}: utilization > m"));
        }
        Ok(())
    });
}

/// Static baseline never adjusts; Dorm's utilization dominates it for the
/// same workload (the §V headline, property-checked across seeds).
#[test]
fn prop_dorm_dominates_static_utilization() {
    prop::check(5, |rng| {
        let seed = rng.next_u64() % 500;
        let exp = Experiment::scaled(seed, 6.0, 12);
        let b = exp.run(&mut StaticPolicy::new());
        let d = exp.run(&mut DormPolicy::new(DormConfig::DORM1));
        if b.metrics().adjustments.last().unwrap_or(0.0) != 0.0 {
            return Err("static adjusted".into());
        }
        let ub = b.metrics().utilization.mean_over(0.0, 6.0);
        let ud = d.metrics().utilization.mean_over(0.0, 6.0);
        if ud + 1e-9 < ub * 0.95 {
            return Err(format!("seed {seed}: dorm {ud} << static {ub}"));
        }
        Ok(())
    });
}

/// Horizon-zero / empty-workload edge cases terminate cleanly.
#[test]
fn degenerate_simulations() {
    let rows = table2_rows();
    let cfg = ClusterConfig::paper_testbed();
    let sim = SimConfig { horizon_hours: 0.0, ..Default::default() };
    let out = run_sim(
        &mut DormPolicy::new(DormConfig::DORM3),
        &rows,
        &[],
        &cfg,
        &sim,
        &PerfModel::default(),
    );
    assert_eq!(out.completed, 0);

    let gen = WorkloadGen::default();
    let mut rng = Rng::new(1);
    let wl = gen.generate(&mut rng);
    let sim = SimConfig { horizon_hours: 0.001, ..Default::default() };
    let out = run_sim(
        &mut StaticPolicy::new(),
        &rows,
        &wl,
        &cfg,
        &sim,
        &PerfModel::default(),
    );
    assert_eq!(out.completed, 0);
}

/// Lifecycle: app states traverse only legal edges through a full
/// submit -> adjust -> complete cycle on the live master.
#[test]
fn lifecycle_states_progress_legally() {
    let cluster = ClusterConfig::uniform(2, Res::cpu_gpu_ram(8.0, 0.0, 32.0));
    let mut master = DormMaster::new(
        &cluster,
        DormConfig { theta1: 0.5, theta2: 1.0 },
        store("lifecycle"),
    );
    let a = master.submit(spec(2.0, 4.0, 1, 1, 8)).unwrap();
    assert_eq!(master.app_state(a), Some(AppState::Running));
    let b = master.submit(spec(2.0, 4.0, 1, 1, 8)).unwrap();
    assert_eq!(master.app_state(b), Some(AppState::Running));
    master.complete(a).unwrap();
    assert_eq!(master.app_state(a), Some(AppState::Completed));
    assert!(!AppState::Completed.can_transition(AppState::Running));
    master.complete(b).unwrap();
    assert_eq!(master.active_apps(), 0);
}

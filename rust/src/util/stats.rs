//! Statistics helpers used by the metrics pipeline, the workload model and
//! the bench harness: mean/std, percentiles, empirical CDFs and a tiny
//! online accumulator.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation; 0.0 for < 2 samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, p in [0, 100]. Panics on empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Empirical CDF evaluated at `points`: fraction of samples <= point.
pub fn ecdf(samples: &[f64], points: &[f64]) -> Vec<f64> {
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    points
        .iter()
        .map(|&p| {
            let idx = v.partition_point(|&x| x <= p);
            idx as f64 / v.len().max(1) as f64
        })
        .collect()
}

/// Online mean/min/max/count accumulator (constant memory).
#[derive(Clone, Debug, Default)]
pub struct Acc {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Acc {
    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        self.sum += x;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

/// Time-weighted average of a step function sampled as (time, value) points
/// over [t0, t1]; each value holds until the next sample.
pub fn time_weighted_mean(series: &[(f64, f64)], t0: f64, t1: f64) -> f64 {
    if series.is_empty() || t1 <= t0 {
        return 0.0;
    }
    let mut total = 0.0;
    for (idx, &(t, v)) in series.iter().enumerate() {
        let start = t.max(t0);
        let end = series.get(idx + 1).map(|&(tn, _)| tn).unwrap_or(t1).min(t1);
        if end > start {
            total += v * (end - start);
        }
    }
    total / (t1 - t0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((std_dev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn ecdf_fraction() {
        let s = [1.0, 2.0, 3.0, 4.0];
        let c = ecdf(&s, &[0.5, 2.0, 10.0]);
        assert_eq!(c, vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn acc_tracks_min_max_mean() {
        let mut a = Acc::default();
        for x in [3.0, 1.0, 2.0] {
            a.push(x);
        }
        assert_eq!(a.min, 1.0);
        assert_eq!(a.max, 3.0);
        assert!((a.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn time_weighted() {
        // value 1 on [0,10), value 3 on [10,20)
        let series = [(0.0, 1.0), (10.0, 3.0)];
        assert!((time_weighted_mean(&series, 0.0, 20.0) - 2.0).abs() < 1e-12);
        assert!((time_weighted_mean(&series, 0.0, 10.0) - 1.0).abs() < 1e-12);
    }
}

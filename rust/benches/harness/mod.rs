//! Minimal benchmark harness (criterion is not in the vendored registry).
//!
//! Provides timed micro-benchmarks (warmup + N iterations, mean/p50/p99)
//! and a uniform banner/report style for the figure benches, which are
//! *reproduction* benches: they regenerate a paper table/figure and print
//! paper-vs-measured rows.

use std::time::Instant;

/// Time `f` with warmup; returns (mean_us, p50_us, p99_us).
pub fn bench_micro<F: FnMut()>(label: &str, warmup: u32, iters: u32, mut f: F) -> (f64, f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let p99 = samples[((samples.len() as f64 * 0.99) as usize).min(samples.len() - 1)];
    println!("  {label:<44} mean {mean:>10.1} us   p50 {p50:>10.1} us   p99 {p99:>10.1} us");
    (mean, p50, p99)
}

/// Section banner.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

/// A paper-vs-measured row.
pub fn paper_row(metric: &str, paper: &str, measured: &str) {
    println!("  {metric:<52} paper: {paper:<16} measured: {measured}");
}

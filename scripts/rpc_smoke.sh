#!/usr/bin/env bash
# RPC-throughput smoke (DESIGN.md §15): exercise the `dorm bench
# rpc-throughput` CLI verb at a tiny scale — both server implementations
# must answer a concurrent closed-loop drive without one in-band error —
# then run the tracked benches/rpc_throughput.rs sweep at CI scale and
# gate its spliced "rpc" series against BENCH_baseline/ with
# scripts/check_bench.sh.
#
# Usage, from the repo root (after `cargo build --release`):
#   bash scripts/rpc_smoke.sh
#
# Knobs: BIN (default rust/target/release/dorm), DORM_BENCH_JSON (where
# the sweep splices its series, default ./BENCH_sched.json — the file CI
# uploads as an artifact).
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${BIN:-rust/target/release/dorm}
WORK=$(mktemp -d)
cleanup() { rm -rf "$WORK"; }
trap cleanup EXIT

fail() {
  echo "RPC SMOKE FAIL: $1" >&2
  exit 1
}

[ -x "$BIN" ] || fail "$BIN missing; run: cargo build --release --manifest-path rust/Cargo.toml"

echo "== CLI verb: dorm bench rpc-throughput (tiny drive, both servers)"
OUT=$("$BIN" bench rpc-throughput --clients 8 --servers 8 --seconds 1 \
  --json "$WORK/cli_rpc.json") || fail "bench verb exited non-zero: $OUT"
echo "$OUT"
echo "$OUT" | grep -q "multiplexed vs legacy" || fail "no speedup line in: $OUT"
grep -q '"rpc"' "$WORK/cli_rpc.json" || fail "--json did not emit an rpc series"

echo
echo "== tracked sweep: benches/rpc_throughput.rs at CI scale"
export DORM_SCHED_SCALE=ci
export DORM_BENCH_JSON="${DORM_BENCH_JSON:-$PWD/BENCH_sched.json}"
# start the document fresh so the gate sees exactly this run's rpc series
# (the sched/replay series are then absent and skipped, not gated)
rm -f "$DORM_BENCH_JSON"
cargo bench --manifest-path rust/Cargo.toml --bench rpc_throughput

echo
echo "== gate: scripts/check_bench.sh vs BENCH_baseline/"
bash scripts/check_bench.sh

echo "RPC SMOKE PASS"

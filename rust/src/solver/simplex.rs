//! Two-phase primal simplex over a dense tableau.
//!
//! Supports `max`/`min` of a linear objective over constraints of the form
//! `a·x ≤ b`, `a·x ≥ b`, `a·x = b` with `x ≥ 0` — exactly the shape of the
//! paper's P2 relaxation.  Dantzig pricing with a Bland's-rule fallback
//! after a degeneracy threshold (guarantees termination), artificial
//! variables for phase 1.
//!
//! Dense is the right trade-off here: the optimizer's count-aggregated form
//! of P2 is ~|A| variables × ~(2|A| + m) rows (DESIGN.md §6), i.e. at most a
//! few hundred entries per solve at paper scale.

/// Comparison operator of a [`Constraint`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

/// Sparse linear constraint `Σ coeffs[k].1 · x[coeffs[k].0]  cmp  rhs`.
#[derive(Clone, Debug)]
pub struct Constraint {
    pub coeffs: Vec<(usize, f64)>,
    pub cmp: Cmp,
    pub rhs: f64,
}

impl Constraint {
    pub fn new(coeffs: Vec<(usize, f64)>, cmp: Cmp, rhs: f64) -> Self {
        Constraint { coeffs, cmp, rhs }
    }
}

/// A linear program over `n` non-negative structural variables.
#[derive(Clone, Debug)]
pub struct Lp {
    pub n: usize,
    /// Dense objective coefficients, length `n`.
    pub objective: Vec<f64>,
    pub maximize: bool,
    pub constraints: Vec<Constraint>,
}

/// Result of [`solve`].
#[derive(Clone, Debug)]
pub enum LpOutcome {
    Optimal { x: Vec<f64>, obj: f64 },
    Infeasible,
    Unbounded,
}

const EPS: f64 = 1e-9;
/// Iterations of Dantzig pricing before switching to Bland's rule.
const BLAND_AFTER: usize = 2_000;
/// Hard iteration cap (defense in depth; Bland guarantees termination).
const MAX_ITERS: usize = 200_000;

struct Tableau {
    /// rows × (cols + 1); last column is RHS.
    a: Vec<Vec<f64>>,
    /// objective row (reduced costs), length cols + 1; we *maximize* it.
    z: Vec<f64>,
    basis: Vec<usize>,
    cols: usize,
}

impl Tableau {
    fn pivot(&mut self, row: usize, col: usize) {
        let piv = self.a[row][col];
        debug_assert!(piv.abs() > EPS);
        let inv = 1.0 / piv;
        for v in self.a[row].iter_mut() {
            *v *= inv;
        }
        let prow = self.a[row].clone();
        for (r, arow) in self.a.iter_mut().enumerate() {
            if r != row {
                let f = arow[col];
                if f.abs() > EPS {
                    for (av, pv) in arow.iter_mut().zip(&prow) {
                        *av -= f * pv;
                    }
                }
            }
        }
        let f = self.z[col];
        if f.abs() > EPS {
            for (zv, pv) in self.z.iter_mut().zip(&prow) {
                *zv -= f * pv;
            }
        }
        self.basis[row] = col;
    }

    /// Run simplex iterations until optimal/unbounded. Returns false on
    /// unbounded.
    fn optimize(&mut self) -> bool {
        for iter in 0..MAX_ITERS {
            let bland = iter >= BLAND_AFTER;
            // entering column: positive reduced cost (maximization)
            let mut col = None;
            if bland {
                for j in 0..self.cols {
                    if self.z[j] > EPS {
                        col = Some(j);
                        break;
                    }
                }
            } else {
                let mut best = EPS;
                for j in 0..self.cols {
                    if self.z[j] > best {
                        best = self.z[j];
                        col = Some(j);
                    }
                }
            }
            let col = match col {
                Some(c) => c,
                None => return true, // optimal
            };
            // ratio test
            let mut row = None;
            let mut best_ratio = f64::INFINITY;
            for r in 0..self.a.len() {
                let arc = self.a[r][col];
                if arc > EPS {
                    let ratio = self.a[r][self.cols] / arc;
                    let better = ratio < best_ratio - EPS
                        || (bland
                            && (ratio - best_ratio).abs() <= EPS
                            && row.map_or(true, |pr: usize| self.basis[r] < self.basis[pr]));
                    if better {
                        best_ratio = ratio;
                        row = Some(r);
                    }
                }
            }
            match row {
                Some(r) => self.pivot(r, col),
                None => return false, // unbounded
            }
        }
        // iteration cap: treat as optimal-with-current-basis; callers only
        // see this under pathological cycling, which Bland prevents.
        true
    }
}

/// Solve the LP. Variables are implicitly bounded below by 0.
pub fn solve(lp: &Lp) -> LpOutcome {
    let n = lp.n;
    let m = lp.constraints.len();
    debug_assert_eq!(lp.objective.len(), n);

    // Column layout: [structural | slack/surplus | artificial].
    let mut n_slack = 0usize;
    let mut n_art = 0usize;
    // (row, slack_col_or_none, art_col_or_none) computed in a first pass
    let mut row_plan = Vec::with_capacity(m);
    for c in &lp.constraints {
        // normalize rhs >= 0 by flipping the row
        let flip = c.rhs < 0.0;
        let cmp = match (c.cmp, flip) {
            (Cmp::Le, false) | (Cmp::Ge, true) => Cmp::Le,
            (Cmp::Ge, false) | (Cmp::Le, true) => Cmp::Ge,
            (Cmp::Eq, _) => Cmp::Eq,
        };
        let (slack, art) = match cmp {
            Cmp::Le => (Some(n_slack), None),
            Cmp::Ge => (Some(n_slack), Some(n_art)),
            Cmp::Eq => (None, Some(n_art)),
        };
        if slack.is_some() {
            n_slack += 1;
        }
        if art.is_some() {
            n_art += 1;
        }
        row_plan.push((flip, cmp, slack, art));
    }

    let cols = n + n_slack + n_art;
    let mut a = vec![vec![0.0; cols + 1]; m];
    let mut basis = vec![0usize; m];

    for (r, (c, &(flip, cmp, slack, art))) in
        lp.constraints.iter().zip(&row_plan).enumerate()
    {
        let sign = if flip { -1.0 } else { 1.0 };
        for &(j, v) in &c.coeffs {
            debug_assert!(j < n, "coefficient index out of range");
            a[r][j] += sign * v;
        }
        a[r][cols] = sign * c.rhs;
        if let Some(s) = slack {
            let sc = n + s;
            a[r][sc] = match cmp {
                Cmp::Le => 1.0,
                Cmp::Ge => -1.0,
                Cmp::Eq => unreachable!(),
            };
            if cmp == Cmp::Le {
                basis[r] = sc;
            }
        }
        if let Some(t) = art {
            let ac = n + n_slack + t;
            a[r][ac] = 1.0;
            basis[r] = ac;
        }
    }

    let mut tab = Tableau { a, z: vec![0.0; cols + 1], basis, cols };

    // ---- Phase 1: maximize -(sum of artificials) -------------------------
    if n_art > 0 {
        // z = -Σ art  => reduced costs: start from c_j = 0 except art = -1,
        // then add rows whose basis is artificial (price out the basis).
        for j in 0..cols + 1 {
            let mut zj = 0.0;
            for r in 0..m {
                if tab.basis[r] >= n + n_slack {
                    zj += tab.a[r][j];
                }
            }
            // maximize -sum(art): reduced cost = (sum of art rows) - c_j
            // where c_j = 1 for artificial columns.
            let cj = if j >= n + n_slack && j < cols { 1.0 } else { 0.0 };
            tab.z[j] = zj - cj;
        }
        if !tab.optimize() {
            return LpOutcome::Infeasible; // phase-1 unbounded can't happen
        }
        if tab.z[cols] > 1e-6 {
            return LpOutcome::Infeasible;
        }
        // Drive remaining artificials out of the basis where possible.
        for r in 0..m {
            if tab.basis[r] >= n + n_slack {
                if let Some(j) = (0..n + n_slack).find(|&j| tab.a[r][j].abs() > 1e-7) {
                    tab.pivot(r, j);
                }
                // else: redundant row; its artificial stays basic at 0.
            }
        }
        // Forbid artificials from re-entering.
        for r in 0..m {
            for j in n + n_slack..cols {
                tab.a[r][j] = 0.0;
            }
        }
    }

    // ---- Phase 2: the real objective -------------------------------------
    let sgn = if lp.maximize { 1.0 } else { -1.0 };
    let cost = |j: usize| -> f64 {
        if j < n {
            sgn * lp.objective[j]
        } else {
            0.0
        }
    };
    for j in 0..cols + 1 {
        let mut zj = 0.0;
        for r in 0..m {
            zj += cost(tab.basis[r]) * tab.a[r][j];
        }
        let cj = if j < cols { cost(j) } else { 0.0 };
        tab.z[j] = cj - zj;
    }
    // artificial columns stay zeroed / never priced in
    for j in n + n_slack..cols {
        tab.z[j] = f64::NEG_INFINITY.max(-1e18); // strongly negative
    }
    if !tab.optimize() {
        return LpOutcome::Unbounded;
    }

    let mut x = vec![0.0; n];
    for r in 0..m {
        if tab.basis[r] < n {
            x[tab.basis[r]] = tab.a[r][cols].max(0.0);
        }
    }
    let obj: f64 = lp
        .objective
        .iter()
        .zip(&x)
        .map(|(c, v)| c * v)
        .sum();
    LpOutcome::Optimal { x, obj }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn le(coeffs: Vec<(usize, f64)>, rhs: f64) -> Constraint {
        Constraint::new(coeffs, Cmp::Le, rhs)
    }

    #[test]
    fn textbook_max() {
        // max 3x + 5y st x<=4, 2y<=12, 3x+2y<=18 -> x=2,y=6, obj=36
        let lp = Lp {
            n: 2,
            objective: vec![3.0, 5.0],
            maximize: true,
            constraints: vec![
                le(vec![(0, 1.0)], 4.0),
                le(vec![(1, 2.0)], 12.0),
                le(vec![(0, 3.0), (1, 2.0)], 18.0),
            ],
        };
        match solve(&lp) {
            LpOutcome::Optimal { x, obj } => {
                assert!((x[0] - 2.0).abs() < 1e-7, "{x:?}");
                assert!((x[1] - 6.0).abs() < 1e-7);
                assert!((obj - 36.0).abs() < 1e-7);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn minimization_with_ge() {
        // min 2x + 3y st x + y >= 4, x >= 1 -> x=4? obj: 2*4=8 (y=0)
        let lp = Lp {
            n: 2,
            objective: vec![2.0, 3.0],
            maximize: false,
            constraints: vec![
                Constraint::new(vec![(0, 1.0), (1, 1.0)], Cmp::Ge, 4.0),
                Constraint::new(vec![(0, 1.0)], Cmp::Ge, 1.0),
            ],
        };
        match solve(&lp) {
            LpOutcome::Optimal { x, obj } => {
                assert!((obj - 8.0).abs() < 1e-7, "{x:?} {obj}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn equality_constraints() {
        // max x + y st x + y = 5, x <= 3 -> obj 5
        let lp = Lp {
            n: 2,
            objective: vec![1.0, 1.0],
            maximize: true,
            constraints: vec![
                Constraint::new(vec![(0, 1.0), (1, 1.0)], Cmp::Eq, 5.0),
                le(vec![(0, 1.0)], 3.0),
            ],
        };
        match solve(&lp) {
            LpOutcome::Optimal { x, obj } => {
                assert!((obj - 5.0).abs() < 1e-7);
                assert!((x[0] + x[1] - 5.0).abs() < 1e-7);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn infeasible_detected() {
        // x <= 1 and x >= 2
        let lp = Lp {
            n: 1,
            objective: vec![1.0],
            maximize: true,
            constraints: vec![
                le(vec![(0, 1.0)], 1.0),
                Constraint::new(vec![(0, 1.0)], Cmp::Ge, 2.0),
            ],
        };
        assert!(matches!(solve(&lp), LpOutcome::Infeasible));
    }

    #[test]
    fn unbounded_detected() {
        let lp = Lp {
            n: 1,
            objective: vec![1.0],
            maximize: true,
            constraints: vec![Constraint::new(vec![(0, 1.0)], Cmp::Ge, 0.0)],
        };
        assert!(matches!(solve(&lp), LpOutcome::Unbounded));
    }

    #[test]
    fn negative_rhs_normalized() {
        // -x <= -2  ==  x >= 2; max -x -> x = 2, obj = -2
        let lp = Lp {
            n: 1,
            objective: vec![-1.0],
            maximize: true,
            constraints: vec![le(vec![(0, -1.0)], -2.0)],
        };
        match solve(&lp) {
            LpOutcome::Optimal { x, obj } => {
                assert!((x[0] - 2.0).abs() < 1e-7);
                assert!((obj + 2.0).abs() < 1e-7);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn degenerate_lp_terminates() {
        // classic degenerate corner; just needs to terminate at obj 0 corner
        let lp = Lp {
            n: 2,
            objective: vec![1.0, 1.0],
            maximize: true,
            constraints: vec![
                le(vec![(0, 1.0), (1, 1.0)], 1.0),
                le(vec![(0, 1.0), (1, 1.0)], 1.0),
                le(vec![(0, 1.0)], 1.0),
            ],
        };
        match solve(&lp) {
            LpOutcome::Optimal { obj, .. } => assert!((obj - 1.0).abs() < 1e-7),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn prop_feasible_solution_satisfies_constraints() {
        use crate::util::prop;
        prop::check(150, |rng| {
            let n = rng.range_u64(1, 6) as usize;
            let m = rng.range_u64(1, 6) as usize;
            let lp = Lp {
                n,
                objective: (0..n).map(|_| rng.range_f64(-3.0, 3.0)).collect(),
                maximize: true,
                constraints: (0..m)
                    .map(|_| {
                        // a·x <= b with a >= 0, b >= 0 keeps it feasible+bounded
                        Constraint::new(
                            (0..n).map(|j| (j, rng.range_f64(0.1, 2.0))).collect(),
                            Cmp::Le,
                            rng.range_f64(0.5, 20.0),
                        )
                    })
                    .collect(),
            };
            match solve(&lp) {
                LpOutcome::Optimal { x, .. } => {
                    for (ci, c) in lp.constraints.iter().enumerate() {
                        let lhs: f64 = c.coeffs.iter().map(|&(j, v)| v * x[j]).sum();
                        if lhs > c.rhs + 1e-6 {
                            return Err(format!("constraint {ci} violated: {lhs} > {}", c.rhs));
                        }
                    }
                    if x.iter().any(|&v| v < -1e-9) {
                        return Err("negative variable".into());
                    }
                    Ok(())
                }
                other => Err(format!("expected optimal, got {other:?}")),
            }
        });
    }
}

//! The paper's §V experiment, packaged: Table II workload on the 21-server
//! testbed for 24 h under the baseline and Dorm-1/2/3, plus the summary
//! statistics every figure bench and `examples/shared_cluster_sim.rs`
//! report.

use crate::baselines::StaticPolicy;
use crate::config::{ClusterConfig, DormConfig, SimConfig};
use crate::metrics::RunMetrics;
use crate::util::stats;
use crate::workload::{table2_rows, WorkloadApp, WorkloadSpec};

use crate::sched::CmsPolicy;

use super::dorm_policy::DormPolicy;
use super::perf_model::PerfModel;
use super::runner::SimOutcome;

/// One system's results over the experiment.
pub struct SystemRun {
    pub label: String,
    pub outcome: SimOutcome,
}

impl SystemRun {
    pub fn metrics(&self) -> &RunMetrics {
        &self.outcome.metrics
    }
}

/// The full §V testbed experiment.
pub struct Experiment {
    pub cluster: ClusterConfig,
    pub sim: SimConfig,
    pub pm: PerfModel,
    pub workload: Vec<WorkloadApp>,
}

impl Experiment {
    /// Paper defaults: 20 slaves, 24 h, 50 apps, Poisson(20 min).
    pub fn paper(seed: u64) -> Self {
        Self::from_spec(&WorkloadSpec::paper(seed))
    }

    /// Build from an explicit [`WorkloadSpec`] — the single seed behind
    /// the DES run, the churn sweep and the trace export, so the exact
    /// workload of any experiment is reproducible (and exportable as a
    /// trace) from `spec.seed` alone.  `Experiment::paper(seed)` is
    /// `from_spec(&WorkloadSpec::paper(seed))` and keeps its historical
    /// draw order (`workload::spec` pins this).
    pub fn from_spec(spec: &WorkloadSpec) -> Self {
        Experiment {
            cluster: ClusterConfig::paper_testbed(),
            sim: SimConfig {
                seed: spec.seed,
                mean_interarrival_min: spec.mean_interarrival_min,
                ..Default::default()
            },
            pm: PerfModel::default(),
            workload: spec.generate(),
        }
    }

    /// A scaled-down variant for fast tests/benches (horizon in hours):
    /// fewer apps and durations shrunk so a meaningful fraction complete
    /// within the shorter horizon.
    pub fn scaled(seed: u64, horizon_hours: f64, napps: usize) -> Self {
        let mut e = Self::paper(seed);
        e.sim.horizon_hours = horizon_hours;
        e.workload.truncate(napps);
        let factor = (horizon_hours / 24.0).min(1.0) * 0.5;
        for w in &mut e.workload {
            w.duration_at_baseline_hours *= factor;
            // compress arrivals proportionally too
            w.submit_hours *= horizon_hours / 24.0;
        }
        e
    }

    pub fn run(&self, policy: &mut dyn CmsPolicy) -> SystemRun {
        self.run_with_faults(policy, &[])
    }

    /// Apply a `[fault]` config to this experiment: set the periodic
    /// checkpoint cadence on the perf model and materialize the failure
    /// trace its model asks for (empty when `enabled = false`).  Feed the
    /// returned trace to [`Experiment::run_with_faults`].  Invalid fault
    /// parameters surface as a typed [`crate::fault::FaultError`], never a
    /// panic.
    pub fn apply_fault(
        &mut self,
        cfg: &crate::config::FaultConfig,
    ) -> Result<Vec<crate::fault::FailureEvent>, crate::fault::FaultError> {
        self.pm.ckpt_period_hours = cfg.ckpt_period_hours;
        crate::fault::FailureModel::from_config(cfg)
            .trace(self.cluster.servers.len(), self.sim.horizon_hours)
    }

    /// [`Experiment::run`] under an injected server-churn trace
    /// (`crate::fault`): the same workload and cluster, with servers dying
    /// and rejoining per `faults`.
    pub fn run_with_faults(
        &self,
        policy: &mut dyn CmsPolicy,
        faults: &[crate::fault::FailureEvent],
    ) -> SystemRun {
        let rows = table2_rows();
        let label = policy.name();
        let outcome = super::runner::run_sim_faulty(
            policy, &rows, &self.workload, &self.cluster, &self.sim, &self.pm, faults,
        );
        SystemRun { label, outcome }
    }

    /// Run the baseline + the three Dorm configurations of §V-A-2.
    pub fn run_all(&self) -> Vec<SystemRun> {
        let mut out = Vec::new();
        out.push(self.run(&mut StaticPolicy::new()));
        for cfg in [DormConfig::DORM1, DormConfig::DORM2, DormConfig::DORM3] {
            out.push(self.run(&mut DormPolicy::new(cfg)));
        }
        out
    }
}

/// Multi-seed aggregate of the three §V headline ratios for one Dorm
/// config: (mean, std) of utilization gain, fairness reduction, speedup.
/// Seeds vary the Poisson arrivals, the type shuffle and the durations —
/// the benches report this so single-seed luck is visible.
pub fn headline_over_seeds(
    cfg: crate::config::DormConfig,
    seeds: &[u64],
) -> [(f64, f64); 3] {
    let mut gains = [Vec::new(), Vec::new(), Vec::new()];
    for &seed in seeds {
        let exp = Experiment::paper(seed);
        let b = exp.run(&mut StaticPolicy::new());
        let d = exp.run(&mut DormPolicy::new(cfg));
        gains[0].push(utilization_ratio(&d, &b, 5.0));
        gains[1].push(fairness_reduction(&d, &b, 24.0));
        gains[2].push(mean_speedup(&d, &b));
    }
    [
        (stats::mean(&gains[0]), stats::std_dev(&gains[0])),
        (stats::mean(&gains[1]), stats::std_dev(&gains[1])),
        (stats::mean(&gains[2]), stats::std_dev(&gains[2])),
    ]
}

/// §V-B-1 headline: ratio of mean utilization over the first `hours` hours.
pub fn utilization_ratio(dorm: &SystemRun, baseline: &SystemRun, hours: f64) -> f64 {
    let d = dorm.metrics().utilization.mean_over(0.0, hours);
    let b = baseline.metrics().utilization.mean_over(0.0, hours).max(1e-9);
    d / b
}

/// §V-B-2: ratio of mean fairness loss (baseline / dorm — >1 means Dorm is
/// fairer).
pub fn fairness_reduction(dorm: &SystemRun, baseline: &SystemRun, hours: f64) -> f64 {
    let d = dorm.metrics().fairness_loss.mean_over(0.0, hours).max(1e-9);
    let b = baseline.metrics().fairness_loss.mean_over(0.0, hours);
    b / d
}

/// §V-B-4: mean matched-pair speedup — each application completed under
/// *both* systems contributes dur_baseline / dur_dorm.  Matching by app
/// (not by tag means) avoids the censoring bias where the two systems
/// complete different subsets of the workload within the horizon.
pub fn mean_speedup(dorm: &SystemRun, baseline: &SystemRun) -> f64 {
    stats::mean(&matched_speedups(dorm, baseline).iter().map(|&(_, s)| s).collect::<Vec<_>>())
}

/// Matched-pair speedups as (tag, ratio) — the Fig. 9a series.
pub fn matched_speedups(dorm: &SystemRun, baseline: &SystemRun) -> Vec<(String, f64)> {
    let d = &dorm.metrics().app_durations;
    let b = &baseline.metrics().app_durations;
    let mut out = Vec::new();
    for (id, (tag, dur_d)) in d {
        if let Some((_, dur_b)) = b.get(id) {
            if *dur_d > 0.0 {
                out.push((tag.clone(), dur_b / dur_d));
            }
        }
    }
    out
}

/// Per-tag mean of the matched-pair speedups (the Fig. 9a bars).
pub fn speedup_by_tag(dorm: &SystemRun, baseline: &SystemRun) -> Vec<(String, f64)> {
    let pairs = matched_speedups(dorm, baseline);
    let mut tags: Vec<String> = pairs.iter().map(|(t, _)| t.clone()).collect();
    tags.sort();
    tags.dedup();
    tags.into_iter()
        .map(|tag| {
            let rs: Vec<f64> = pairs
                .iter()
                .filter(|(t, _)| *t == tag)
                .map(|&(_, r)| r)
                .collect();
            (tag, stats::mean(&rs))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The headline §V shape test: Dorm beats the static baseline on
    /// utilization and speedup while bounding adjustments.  Scaled horizon
    /// keeps the test fast; the full 24 h run lives in the benches.
    #[test]
    fn dorm_beats_baseline_on_scaled_experiment() {
        let exp = Experiment::scaled(17, 8.0, 16);
        let runs = exp.run_all();
        let (baseline, dorms) = runs.split_first().unwrap();
        assert_eq!(baseline.label, "static");
        for d in dorms {
            let ur = utilization_ratio(d, baseline, 5.0);
            assert!(
                ur > 1.1,
                "{}: utilization ratio {ur} not > 1.1",
                d.label
            );
            let sp = mean_speedup(d, baseline);
            assert!(sp > 1.0, "{}: speedup {sp} not > 1", d.label);
        }
    }

    #[test]
    fn adjustment_overhead_ordered_by_theta2() {
        // Dorm-2 (θ₂=0.2) is allowed more adjustments than Dorm-3 (θ₂=0.1);
        // over a full run it should adjust at least as often.
        let exp = Experiment::scaled(23, 8.0, 16);
        let d2 = exp.run(&mut DormPolicy::new(DormConfig::DORM2));
        let d3 = exp.run(&mut DormPolicy::new(DormConfig::DORM3));
        let a2 = d2.metrics().adjustments.last().unwrap_or(0.0);
        let a3 = d3.metrics().adjustments.last().unwrap_or(0.0);
        assert!(a2 + 1.0 >= a3, "dorm2 {a2} vs dorm3 {a3}");
    }

    #[test]
    fn per_operation_batch_bounded() {
        // Fig. 8: "would kill and resume 2 applications at most per
        // resource adjustment operation" for θ₂ = 0.1/0.2 at ≤ ~20 carried
        // apps. Check the decision-time bound ⌈θ₂·|carried|⌉ holds.
        let exp = Experiment::scaled(29, 8.0, 16);
        let run = exp.run(&mut DormPolicy::new(DormConfig::DORM3));
        for &batch in &run.metrics().adjustment_batch_sizes {
            assert!(batch <= 2, "batch {batch} > bound");
        }
    }
}

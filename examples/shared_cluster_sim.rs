//! The paper's §V testbed experiment, end to end: Table II's 50 apps on the
//! 21-server cluster for 24 simulated hours under the static baseline and
//! Dorm-1/2/3, reporting the Figs 6–9 summary statistics.
//!
//! ```bash
//! cargo run --release --example shared_cluster_sim [seed] [--fig1]
//! ```

use dorm::report;
use dorm::sim::{fairness_reduction, mean_speedup, utilization_ratio, Experiment};
use dorm::util::stats;
use dorm::util::Rng;
use dorm::workload::{app_duration_hours, task_duration_secs, DurationModel};

fn fig1() {
    println!("== Fig. 1: CDFs of distributed-ML app/task duration (model) ==");
    let model = DurationModel::default();
    let mut rng = Rng::new(1);
    let apps: Vec<f64> = (0..20_000).map(|_| app_duration_hours(&model, &mut rng)).collect();
    let tasks: Vec<f64> = (0..20_000).map(|_| task_duration_secs(&model, &mut rng)).collect();
    let hours = [1.0, 3.0, 6.0, 12.0, 24.0, 48.0];
    let secs = [0.5, 1.0, 1.5, 3.0, 10.0, 30.0];
    let app_cdf = stats::ecdf(&apps, &hours);
    let task_cdf = stats::ecdf(&tasks, &secs);
    let rows: Vec<Vec<String>> = hours
        .iter()
        .zip(&app_cdf)
        .zip(secs.iter().zip(&task_cdf))
        .map(|((h, ac), (s, tc))| {
            vec![format!("{h}h"), format!("{ac:.3}"), format!("{s}s"), format!("{tc:.3}")]
        })
        .collect();
    println!(
        "{}",
        report::table(&["app dur", "CDF", "task dur", "CDF"], &rows)
    );
    println!(
        "paper anchors: P(app > 6h) ≈ 0.9 (got {:.3}); P(task < 1.5s) ≈ 0.5 (got {:.3})\n",
        1.0 - app_cdf[2],
        task_cdf[2]
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if args.iter().any(|a| a == "--fig1") {
        fig1();
        return;
    }
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(17);

    println!("== §V experiment: 50 apps / 20 slaves / 24 h (seed {seed}) ==");
    let exp = Experiment::paper(seed);
    let t0 = std::time::Instant::now();
    let runs = exp.run_all();
    println!("(4 systems simulated in {:.2?})\n", t0.elapsed());

    let (baseline, dorms) = runs.split_first().unwrap();

    // Fig. 6-8 summary table
    let mut rows = Vec::new();
    for run in &runs {
        rows.push(vec![
            run.label.clone(),
            format!("{:.2}", run.metrics().utilization.mean_over(0.0, 5.0)),
            format!("{:.2}", run.metrics().utilization.mean_over(0.0, 24.0)),
            format!("{:.2}", run.metrics().fairness_loss.max()),
            format!("{:.0}", run.metrics().adjustments.last().unwrap_or(0.0)),
            format!("{}", run.outcome.completed),
        ]);
    }
    println!(
        "{}",
        report::table(
            &["system", "util(0-5h)", "util(24h)", "max fairness loss", "adjusted apps", "completed"],
            &rows
        )
    );

    // headline ratios (paper: ×2.55/2.46/2.32 util, ×1.52 fairness, ×2.7 speedup)
    let mut rows = Vec::new();
    for d in dorms {
        rows.push(vec![
            d.label.clone(),
            format!("{:.2}x", utilization_ratio(d, baseline, 5.0)),
            format!("{:.2}x", fairness_reduction(d, baseline, 24.0)),
            format!("{:.2}x", mean_speedup(d, baseline)),
        ]);
    }
    println!(
        "{}",
        report::table(
            &["system", "utilization gain (first 5h)", "fairness-loss reduction", "mean speedup"],
            &rows
        )
    );

    // utilization chart (Fig. 6 shape)
    let series: Vec<(&str, Vec<(f64, f64)>)> = runs
        .iter()
        .map(|r| (r.label.as_str(), r.metrics().utilization.resample(0.0, 24.0, 60)))
        .collect();
    let series_refs: Vec<(&str, &[(f64, f64)])> =
        series.iter().map(|(l, s)| (*l, s.as_slice())).collect();
    println!("Fig. 6 shape — resource utilization over 24h:");
    println!("{}", report::ascii_chart(&series_refs, 14, 64));
}

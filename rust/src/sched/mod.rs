//! Backend-agnostic scheduling core shared by the live control plane and
//! the discrete-event simulator.
//!
//! Dorm's central mechanism (§III–§IV) is one loop — on every arrival or
//! completion, snapshot cluster/application state, rebuild the
//! utilization–fairness problem, solve it, and enforce the delta.  This
//! module owns that loop once so both backends run the *same* code:
//!
//! * [`CmsPolicy`] — the cluster-management policy interface.  A policy
//!   sees a neutral [`SchedCtx`] snapshot ([`SchedApp`] rows + server
//!   capacities) and returns an [`AllocationUpdate`]; it cannot tell
//!   whether a real master ([`crate::master::DormMaster`]) or the DES
//!   ([`crate::sim::run_sim`]) is driving it, so every policy — Dorm and
//!   all the baselines in [`crate::baselines`] — runs against either.
//! * [`AllocationEngine`] — Dorm's shared decision loop: FIFO admission
//!   with newest-first deferral on infeasibility (§IV-B), solve via
//!   [`crate::optimizer::Optimizer`], emit the delta.  It also owns the
//!   incremental re-solve state (DESIGN.md §10): an (apps, capacity)
//!   snapshot cache (64-bit pre-key + allocation-free exact compare,
//!   hits served behind an `Arc`), the previous solution counts fed to
//!   the solvers as a warm-start incumbent, the persistent
//!   [`crate::cluster::PackState`] driving delta-aware placement, and an
//!   amortized admission loop that solves prefix slices of one buffer
//!   and skips floor-infeasible prefixes outright (reported through
//!   [`crate::optimizer::SolveStats`] and [`EngineStats`]).
//! * [`DormPolicy`] — the paper's system as a [`CmsPolicy`]: a thin
//!   adapter over [`AllocationEngine`].  With a failure-domain topology
//!   ([`DormPolicy::enable_risk_aware`], DESIGN.md §14) it also owns an
//!   online [`crate::fault::MtbfEstimator`] fed by the
//!   [`CmsPolicy::on_server_failed`]/`on_server_recovered` hooks and
//!   steers equal-slack placement ties toward low-risk domains — never
//!   changing allocation totals, only which server a container lands on.
//! * [`CellScheduler`] — the sharded root (DESIGN.md §12): partitions the
//!   servers into cells, each with its own [`AllocationEngine`], solves
//!   them in parallel on scoped threads, and scatter/gathers the per-cell
//!   decisions back into the single-view shape both backends expect.  Its
//!   risk-aware mode additionally penalizes routing new apps into cells
//!   whose headroom is concentrated in a single at-risk domain.

mod cells;
mod engine;
mod policy;

pub use cells::{CellScheduler, CellView, CellsSnapshot};
pub use engine::{AllocationEngine, DormPolicy, EngineApp, EngineStats};
pub use policy::{AllocationUpdate, CmsPolicy, SchedApp, SchedCtx};

#!/usr/bin/env bash
# Scheduler-latency smoke (DESIGN.md §10, §12): run the sched_latency
# bench's churn sweep — legacy vs incremental decision path over a
# saturated cluster with a deferred backlog — plus the sharded-scheduler
# cells x apps sweep (1/2/4/8 cells at a fixed cluster size), and emit
# BENCH_sched.json (per-scale p50/p99 decision latency + moved-container
# counts) so the perf trajectory is tracked from PR 4 forward.
#
# Usage, from the repo root:
#   bash scripts/bench_sched.sh          # reduced CI sweep (fast)
#   bash scripts/bench_sched.sh full     # full sweep incl. 1000 apps x 500 servers
#
# The bench itself asserts old≡new decision parity at the small scales,
# that the delta packer actually ran, and that it never moves more
# containers than the full re-pack — so this doubles as a functional
# check of the incremental path.
#
# The replay_rate bench runs second (DESIGN.md §13): DES streaming
# throughput behind the bounded trace buffer plus the live rate sweep
# against fresh in-process masters; it splices its "replay" series into
# the same BENCH_sched.json.
#
# The rpc_throughput bench runs third (DESIGN.md §15): control-plane
# saturation over loopback TCP, thread-per-connection baseline vs the
# multiplexed worker pool; it splices its "rpc" series into the same
# BENCH_sched.json.
set -euo pipefail
cd "$(dirname "$0")/.."

MODE="${1:-ci}"
case "$MODE" in
  ci)   export DORM_SCHED_SCALE=ci ;;
  full) export DORM_SCHED_SCALE=full ;;
  *)    echo "usage: $0 [ci|full]" >&2; exit 2 ;;
esac

export DORM_BENCH_JSON="${DORM_BENCH_JSON:-$PWD/BENCH_sched.json}"

cargo bench --manifest-path rust/Cargo.toml --bench sched_latency
cargo bench --manifest-path rust/Cargo.toml --bench replay_rate
cargo bench --manifest-path rust/Cargo.toml --bench rpc_throughput

echo
echo "== BENCH_sched.json"
cat "$DORM_BENCH_JSON"

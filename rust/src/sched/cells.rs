//! Sharded multi-cell scheduling: parallel per-cell solves behind a
//! scatter/gather root (DESIGN.md §12).
//!
//! One [`super::AllocationEngine`] is the scalability ceiling: every
//! arrival/completion funnels through one sequential decide path, so
//! aggregate cluster size is bounded by one core's solve rate.
//! [`CellScheduler`] removes that ceiling without touching any backend:
//!
//! * **Partitioning** — the server ordinals `[0, n)` are split into
//!   `count` contiguous cells; each cell owns its own engine (snapshot
//!   cache, warm start, delta [`crate::cluster::PackState`]) and solves
//!   only its slice of the capacity vector.
//! * **Routing** — every app is pinned to one cell.  New arrivals go to
//!   the cell with the lowest projected dominant-share utilization after
//!   admitting the app's floor (`n_min` containers); ties break by a
//!   hash of the app id (deterministic — no RNG, replay-identical); a
//!   saturated best cell spills over to the next candidate.
//! * **Scatter/gather** — per event, each cell's routed apps are remapped
//!   into cell-local [`crate::cluster::ServerId`]s and solved *in
//!   parallel on scoped worker threads*; the per-cell assignments are
//!   shifted back and merged into one [`AllocationUpdate`], so the
//!   master, the DES, `ctl`, and every baseline see the exact
//!   single-view shape they always did.  A cell with no feasible
//!   solution keeps its current in-cell allocations (the §IV-B rule,
//!   applied per cell); only if *every* cell is infeasible does the
//!   whole event return `None`.
//! * **Rebalancing** — every `rebalance_every` events, if max/min cell
//!   dominant-share utilization exceeds `imbalance_threshold`, the
//!   cheapest-to-move apps (fewest containers) migrate from the hottest
//!   to the coolest cell.  A migrated app is presented to its new cell
//!   as pending (it re-enters through the normal admission path and the
//!   existing delta-placement machinery) and is reported in `adjusted`,
//!   so the backend checkpoint+kills it before its containers move —
//!   rebalance can never overcommit a server, because each cell only
//!   ever places within its own slice.
//!
//! `count = 1` short-circuits to the exact [`super::DormPolicy`] code
//! path — no routing, no threads — and `tests/cells.rs` pins the
//! allocation sequences bit-identical.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::app::checkpoint::fnv1a;
use crate::app::AppId;
use crate::cluster::{Assignment, ServerId, SpreadCtx};
use crate::config::{CellsConfig, DormConfig};
use crate::fault::{DomainTopology, MtbfEstimator};
use crate::optimizer::SolveMode;
use crate::resources::Res;

use super::engine::{AllocationEngine, EngineApp, EngineStats};
use super::policy::{AllocationUpdate, CmsPolicy, SchedApp, SchedCtx};

/// One cell's observable state, refreshed on every scheduling event.
/// `tests/cells.rs` asserts the gathered totals (capacity, usage, app
/// counts) equal the sum of these per-cell views.
#[derive(Clone, Debug, PartialEq)]
pub struct CellView {
    pub cell: usize,
    /// Owned server ordinals: `[lo, hi)` in cluster-global numbering.
    pub lo: usize,
    pub hi: usize,
    /// Aggregate capacity of the cell's (alive) servers.
    pub capacity: Res,
    /// Aggregate usage of the apps routed here (demand × containers).
    pub used: Res,
    /// Apps routed to this cell.
    pub apps: u32,
    /// Dominant-share utilization: max over resource types of used/cap.
    pub dominant_share: f64,
}

/// The persistent half of a [`CellScheduler`] — what the master's HA
/// checkpoint carries so a standby rebuilds the same routing
/// (`crate::master::ha`).  Engine caches are deliberately absent: they
/// re-derive on the first solve, like every other restored policy.
#[derive(Clone, Debug, PartialEq)]
pub struct CellsSnapshot {
    pub count: u32,
    pub rebalance_every: u64,
    pub imbalance_threshold: f64,
    /// `(app, cell)` routing pins, ascending by app id.
    pub routes: Vec<(AppId, u32)>,
}

struct Cell {
    lo: usize,
    hi: usize,
    engine: AllocationEngine,
}

/// The scatter/gather root: a [`CmsPolicy`] that shards the cluster into
/// independently-(and concurrently-)solved cells.
pub struct CellScheduler {
    cells: Vec<Cell>,
    /// app → cell index pin.  Routed once on arrival, moved only by
    /// rebalancing, pruned on departure.
    routes: BTreeMap<AppId, usize>,
    cfg: CellsConfig,
    /// Scheduling events seen (the rebalance cadence counter).
    events: u64,
    views: Vec<CellView>,
    label: String,
    /// Online failure observer (risk-aware mode, DESIGN.md §14).
    estimator: Option<MtbfEstimator>,
    /// Cluster-global failure-domain context derived from the estimator;
    /// sliced per cell into each engine and consulted by routing.
    spread: Option<SpreadCtx>,
}

/// Deterministic routing tiebreak: a stable per-app hash.
fn app_hash(id: AppId) -> u64 {
    fnv1a(&id.0.to_be_bytes())
}

impl CellScheduler {
    /// Partition `n_servers` into `cfg.count` contiguous cells (clamped
    /// to at most one cell per server) running the given θ thresholds.
    pub fn new(dorm: DormConfig, cfg: CellsConfig, n_servers: usize) -> Self {
        let count = cfg.count.max(1).min(n_servers.max(1));
        let cells: Vec<Cell> = (0..count)
            .map(|k| Cell {
                lo: k * n_servers / count,
                hi: (k + 1) * n_servers / count,
                engine: AllocationEngine::with_mode(dorm, SolveMode::Heuristic),
            })
            .collect();
        CellScheduler {
            label: format!(
                "cells({count}x dorm(t1={},t2={}))",
                dorm.theta1, dorm.theta2
            ),
            cells,
            routes: BTreeMap::new(),
            cfg: CellsConfig { count, ..cfg },
            events: 0,
            views: Vec::new(),
            estimator: None,
            spread: None,
        }
    }

    /// Risk-aware mode (DESIGN.md §14): own an online [`MtbfEstimator`]
    /// over `topo`, slice its failure-domain context into every cell
    /// engine's placement tie-break, and penalize routing into cells whose
    /// headroom is concentrated in an at-risk domain.
    pub fn enable_risk_aware(&mut self, topo: DomainTopology) {
        self.label = format!("{}+risk", self.label);
        self.estimator = Some(MtbfEstimator::new(topo));
        self.refresh_risk();
    }

    /// The online estimator, when risk-aware mode is on.
    pub fn estimator(&self) -> Option<&MtbfEstimator> {
        self.estimator.as_ref()
    }

    /// Re-derive the global spread context from the estimator's counts and
    /// push cell-local slices (global domain indices, cell-local server
    /// ordinates) into every engine.
    fn refresh_risk(&mut self) {
        self.spread = self.estimator.as_ref().map(|est| SpreadCtx {
            domain_of: est.topology().rack_map().to_vec(),
            risk: est.rack_risks_by_count(),
        });
        for cell in &mut self.cells {
            let sub = self.spread.as_ref().map(|s| SpreadCtx {
                domain_of: s.domain_of
                    [cell.lo.min(s.domain_of.len())..cell.hi.min(s.domain_of.len())]
                    .to_vec(),
                risk: s.risk.clone(),
            });
            cell.engine.set_spread(sub);
        }
    }

    /// Routing risk per cell: how much of the cell's capacity sits in its
    /// riskiest domain — `max_d (domain capacity in cell / cell capacity)
    /// × risk[d]`.  All zeros without risk data (or evidence), keeping the
    /// default routing order replay-identical.
    fn cell_risks(&self, ctx: &SchedCtx) -> Vec<f64> {
        let Some(s) = &self.spread else {
            return vec![0.0; self.cells.len()];
        };
        let m = ctx.capacities.first().map(Res::m).unwrap_or(0);
        self.cells
            .iter()
            .map(|cell| {
                let hi = cell.hi.min(ctx.capacities.len());
                let mut cell_cap = Res::zeros(m);
                let mut dom_caps: BTreeMap<usize, Res> = BTreeMap::new();
                for j in cell.lo..hi {
                    cell_cap += &ctx.capacities[j];
                    let d = s.domain_of.get(j).copied().unwrap_or(0);
                    dom_caps
                        .entry(d)
                        .and_modify(|c| *c += &ctx.capacities[j])
                        .or_insert_with(|| ctx.capacities[j].clone());
                }
                dom_caps
                    .iter()
                    .map(|(d, c)| {
                        c.dominant_share(&cell_cap)
                            * s.risk.get(*d).copied().unwrap_or(0.0)
                    })
                    .fold(0.0, f64::max)
            })
            .collect()
    }

    /// Rebuild from a checkpointed [`CellsSnapshot`] (HA restore):
    /// same partitioning, restored routing pins, cold engines.
    pub fn from_snapshot(dorm: DormConfig, snap: &CellsSnapshot, n_servers: usize) -> Self {
        let cfg = CellsConfig {
            count: snap.count as usize,
            rebalance_every: snap.rebalance_every,
            imbalance_threshold: snap.imbalance_threshold,
        };
        let mut s = Self::new(dorm, cfg, n_servers);
        let count = s.cells.len();
        s.routes = snap
            .routes
            .iter()
            .map(|&(id, k)| (id, (k as usize).min(count - 1)))
            .collect();
        s
    }

    pub fn cell_count(&self) -> usize {
        self.cells.len()
    }

    /// Scheduling events consumed so far (one per backend `on_change` —
    /// a whole lease sweep that kills several servers still counts 1).
    pub fn events(&self) -> u64 {
        self.events
    }

    /// The current routing pins, ascending by app id.
    pub fn routes(&self) -> Vec<(AppId, u32)> {
        self.routes.iter().map(|(&id, &k)| (id, k as u32)).collect()
    }

    fn snapshot(&self) -> CellsSnapshot {
        CellsSnapshot {
            count: self.cells.len() as u32,
            rebalance_every: self.cfg.rebalance_every,
            imbalance_threshold: self.cfg.imbalance_threshold,
            routes: self.routes(),
        }
    }

    /// Per-cell aggregate (capacity, usage, app count) from the live
    /// snapshot — the basis for routing, rebalancing and [`CellView`]s.
    fn aggregates(&self, ctx: &SchedCtx) -> (Vec<Res>, Vec<Res>, Vec<u32>) {
        let m = ctx.capacities.first().map(Res::m).unwrap_or(0);
        let mut caps = vec![Res::zeros(m); self.cells.len()];
        let mut used = vec![Res::zeros(m); self.cells.len()];
        let mut napps = vec![0u32; self.cells.len()];
        for (k, cell) in self.cells.iter().enumerate() {
            for c in &ctx.capacities[cell.lo..cell.hi.min(ctx.capacities.len())] {
                caps[k] += c;
            }
        }
        for a in ctx.apps.values() {
            let Some(&k) = self.routes.get(&a.id) else { continue };
            used[k] += &a.demand.times(a.containers);
            napps[k] += 1;
        }
        (caps, used, napps)
    }

    fn refresh_views(&mut self, caps: &[Res], used: &[Res], napps: &[u32]) {
        self.views = self
            .cells
            .iter()
            .enumerate()
            .map(|(k, c)| CellView {
                cell: k,
                lo: c.lo,
                hi: c.hi,
                capacity: caps[k].clone(),
                used: used[k].clone(),
                apps: napps[k],
                dominant_share: used[k].dominant_share(&caps[k]),
            })
            .collect();
    }

    /// Pin every unrouted app: best-fit by projected dominant share after
    /// the app's floor, hashed-id tiebreak, spillover past saturated
    /// cells, hashed fallback when nothing fits (the cell's engine then
    /// defers the app exactly like a saturated single engine would).
    fn route_new_apps(&mut self, ctx: &SchedCtx, caps: &[Res], used: &mut [Res]) {
        let n = self.cells.len();
        let risks = self.cell_risks(ctx);
        for a in ctx.apps.values() {
            if self.routes.contains_key(&a.id) {
                continue;
            }
            let floor = a.demand.times(a.n_min.max(1));
            let h = (app_hash(a.id) % n as u64) as usize;
            // candidate order: ascending projected share, then ascending
            // concentration risk (risk-aware mode; all zeros otherwise so
            // historical routing is replay-identical), ties rotated by
            // the app hash so equal cells don't all collect the same apps
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&x, &y| {
                let sx = used[x].clone().add_ref(&floor).dominant_share(&caps[x]);
                let sy = used[y].clone().add_ref(&floor).dominant_share(&caps[y]);
                sx.total_cmp(&sy)
                    .then(risks[x].total_cmp(&risks[y]))
                    .then(((x + n - h) % n).cmp(&((y + n - h) % n)))
            });
            let pick = order
                .iter()
                .copied()
                .find(|&k| floor.fits_in(&caps[k].saturating_sub(&used[k])))
                .unwrap_or(h);
            self.routes.insert(a.id, pick);
            // count the floor so same-event arrivals spread out
            used[pick] += &floor;
        }
    }

    /// Move the cheapest apps from the hottest to the coolest cell when
    /// the dominant-share imbalance exceeds the configured ratio.
    /// Returns the migrated apps (the backend must checkpoint+kill them:
    /// they are appended to the gathered `adjusted` set).
    fn rebalance(&mut self, ctx: &SchedCtx, caps: &[Res], used: &mut [Res]) -> Vec<AppId> {
        /// Bound on migrations per rebalance tick: re-leveling is
        /// incremental by design — each migration checkpoint+kills an
        /// app, so a tick must not churn a whole cell at once.
        const MAX_MOVES: usize = 4;
        let mut migrated = Vec::new();
        if self.cells.len() < 2 || self.events % self.cfg.rebalance_every != 0 {
            return migrated;
        }
        for _ in 0..MAX_MOVES {
            let share = |k: usize| used[k].dominant_share(&caps[k]);
            let usable: Vec<usize> =
                (0..self.cells.len()).filter(|&k| !caps[k].is_zero()).collect();
            let Some(&hot) = usable.iter().max_by(|&&a, &&b| share(a).total_cmp(&share(b)))
            else {
                break;
            };
            let Some(&cool) = usable.iter().min_by(|&&a, &&b| share(a).total_cmp(&share(b)))
            else {
                break;
            };
            if hot == cool || share(hot) <= self.cfg.imbalance_threshold * share(cool).max(1e-9)
            {
                break;
            }
            // cheapest-to-move first: fewest containers, ties by id
            let mut movable: Vec<&SchedApp> = ctx
                .apps
                .values()
                .filter(|a| self.routes.get(&a.id) == Some(&hot))
                .collect();
            movable.sort_by(|a, b| a.containers.cmp(&b.containers).then(a.id.cmp(&b.id)));
            let moved = movable.iter().find(|a| {
                let floor = a.demand.times(a.n_min.max(1));
                floor.fits_in(&caps[cool].saturating_sub(&used[cool]))
            });
            let Some(app) = moved else { break };
            let floor = app.demand.times(app.n_min.max(1));
            used[hot] = used[hot].saturating_sub(&app.demand.times(app.containers));
            used[cool] += &floor;
            self.routes.insert(app.id, cool);
            migrated.push(app.id);
        }
        migrated
    }

    /// Remap one app into its cell's local server numbering.  An app
    /// whose placement lies outside the cell (it just migrated) comes out
    /// pending — it re-enters through the cell's normal admission path.
    fn scatter_one(a: &SchedApp, lo: usize, hi: usize) -> EngineApp {
        let placement: BTreeMap<ServerId, u32> = a
            .placement
            .iter()
            .filter(|(sid, _)| sid.0 >= lo && sid.0 < hi)
            .map(|(sid, &c)| (ServerId(sid.0 - lo), c))
            .collect();
        let mut local = a.clone();
        local.containers = placement.values().sum();
        local.placement = placement;
        EngineApp::from_sched(&local)
    }

    /// Solve every cell over its slice — cell 0 on the calling thread,
    /// the rest on scoped worker threads — and return the per-cell
    /// decisions' (assignment, adjusted) pairs shifted back to global
    /// server ids, or `None` per cell when that cell was infeasible.
    #[allow(clippy::type_complexity)]
    fn solve_cells(
        &mut self,
        inputs: &[Vec<EngineApp>],
        capacities: &[Res],
    ) -> Vec<Option<(Arc<Assignment>, Vec<AppId>)>> {
        let (first, rest) = self.cells.split_first_mut().expect("at least one cell");
        let decisions = std::thread::scope(|s| {
            let handles: Vec<_> = rest
                .iter_mut()
                .zip(inputs[1..].iter())
                .map(|(cell, apps)| {
                    let caps = &capacities[cell.lo..cell.hi];
                    let engine = &mut cell.engine;
                    s.spawn(move || engine.decide(apps, caps))
                })
                .collect();
            let mut out =
                vec![first.engine.decide(&inputs[0], &capacities[first.lo..first.hi])];
            for h in handles {
                out.push(h.join().expect("cell solver thread panicked"));
            }
            out
        });
        decisions
            .into_iter()
            .map(|d| d.map(|d| (d.placement.assignment.clone(), d.adjusted.clone())))
            .collect()
    }
}

/// `Res + &Res` without an owned intermediate on the right — routing
/// projects floors in a tight loop.
trait AddRef {
    fn add_ref(self, rhs: &Res) -> Res;
}

impl AddRef for Res {
    fn add_ref(mut self, rhs: &Res) -> Res {
        self += rhs;
        self
    }
}

impl CmsPolicy for CellScheduler {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn on_change(&mut self, ctx: &SchedCtx) -> Option<AllocationUpdate> {
        self.events += 1;
        self.routes.retain(|id, _| ctx.apps.contains_key(id));

        if self.cells.len() == 1 {
            // the unsharded fast path: exactly DormPolicy::on_change —
            // no routing, no threads, pinned bit-identical by
            // tests/cells.rs
            for a in ctx.apps.values() {
                self.routes.entry(a.id).or_insert(0);
            }
            let (caps, used, napps) = self.aggregates(ctx);
            self.refresh_views(&caps, &used, &napps);
            let apps: Vec<EngineApp> = ctx.apps.values().map(EngineApp::from_sched).collect();
            let d = self.cells[0].engine.decide(&apps, ctx.capacities)?;
            return Some(AllocationUpdate {
                assignment: d.placement.assignment.clone(),
                adjusted: d.adjusted.clone(),
            });
        }

        let (caps, mut used, _) = self.aggregates(ctx);
        self.route_new_apps(ctx, &caps, &mut used);
        let migrated = self.rebalance(ctx, &caps, &mut used);

        // scatter: per-cell app lists in cell-local server numbering
        let mut inputs: Vec<Vec<EngineApp>> = vec![Vec::new(); self.cells.len()];
        for a in ctx.apps.values() {
            let k = *self.routes.get(&a.id).expect("routed above");
            let (lo, hi) = (self.cells[k].lo, self.cells[k].hi);
            inputs[k].push(Self::scatter_one(a, lo, hi));
        }

        let results = self.solve_cells(&inputs, ctx.capacities);

        // views reflect the routing this event actually solved with
        let (caps, used, napps) = self.aggregates(ctx);
        self.refresh_views(&caps, &used, &napps);

        if results.iter().all(Option::is_none) {
            return None; // §IV-B: keep every current allocation
        }

        // gather: shift per-cell assignments back to global server ids;
        // an infeasible cell keeps its apps' current *in-cell* placements
        // (rows outside the cell belong to a migration source and must
        // drain, or another cell would double-book the space)
        let mut assignment = Assignment::new();
        let mut adjusted: Vec<AppId> = Vec::new();
        for (k, res) in results.into_iter().enumerate() {
            let (lo, hi) = (self.cells[k].lo, self.cells[k].hi);
            match res {
                Some((cell_assignment, cell_adjusted)) => {
                    for (id, row) in cell_assignment.iter() {
                        let shifted: BTreeMap<ServerId, u32> = row
                            .iter()
                            .map(|(sid, &c)| (ServerId(sid.0 + lo), c))
                            .collect();
                        assignment.insert(*id, shifted);
                    }
                    adjusted.extend(cell_adjusted);
                }
                None => {
                    for a in ctx.apps.values() {
                        if self.routes.get(&a.id) != Some(&k) {
                            continue;
                        }
                        let kept: BTreeMap<ServerId, u32> = a
                            .placement
                            .iter()
                            .filter(|(sid, _)| sid.0 >= lo && sid.0 < hi)
                            .map(|(sid, &c)| (*sid, c))
                            .collect();
                        if !kept.is_empty() {
                            assignment.insert(a.id, kept);
                        }
                    }
                }
            }
        }
        for id in migrated {
            // the backend checkpoint+kills migrated apps before their
            // containers move cells (skipped when the whole event was
            // infeasible above — then nothing moved)
            if !adjusted.contains(&id) {
                adjusted.push(id);
            }
        }
        Some(AllocationUpdate { assignment: Arc::new(assignment), adjusted })
    }

    /// Capacity changed somewhere: every cell's cached solve state was
    /// derived from a slice of the old vector — drop them all (the sweep
    /// that killed servers across cells still costs one dispatch, one
    /// scatter/gather round).
    fn on_capacity_change(&mut self) {
        for c in &mut self.cells {
            c.engine.invalidate();
        }
    }

    /// Feed the outage into the estimator and re-slice the refreshed risk
    /// context into every cell; the capacity-change invalidation that
    /// always follows drops any cached solves built on the old context.
    fn on_server_failed(&mut self, server: ServerId, now: f64) {
        if let Some(est) = self.estimator.as_mut() {
            est.observe_failure(server.0, now);
            self.refresh_risk();
        }
    }

    fn on_server_recovered(&mut self, server: ServerId, now: f64) {
        if let Some(est) = self.estimator.as_mut() {
            est.observe_repair(server.0, now);
            self.refresh_risk();
        }
    }

    /// Aggregated over all cells.
    fn engine_stats(&self) -> Option<EngineStats> {
        let mut total = EngineStats::default();
        for c in &self.cells {
            let s = c.engine.stats();
            total.solves += s.solves;
            total.cache_hits += s.cache_hits;
            total.warm_start_hits += s.warm_start_hits;
            total.admit_prefixes_skipped += s.admit_prefixes_skipped;
            total.delta_packs += s.delta_packs;
            total.full_repacks += s.full_repacks;
        }
        Some(total)
    }

    fn cell_views(&self) -> Option<Vec<CellView>> {
        Some(self.views.clone())
    }

    fn cells_snapshot(&self) -> Option<CellsSnapshot> {
        Some(self.snapshot())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::Engine;

    fn cfg() -> DormConfig {
        DormConfig { theta1: 0.5, theta2: 0.5 }
    }

    fn cells_cfg(count: usize) -> CellsConfig {
        CellsConfig { count, rebalance_every: 4, imbalance_threshold: 1.2 }
    }

    fn caps(n: usize) -> Vec<Res> {
        (0..n).map(|_| Res::cpu_gpu_ram(12.0, 0.0, 64.0)).collect()
    }

    fn app(id: u64, n_min: u32, n_max: u32) -> SchedApp {
        SchedApp {
            id: AppId(id),
            demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0),
            weight: 1.0,
            n_min,
            n_max,
            containers: 0,
            placement: BTreeMap::new(),
            submit: id as f64,
            baseline_n: n_max,
            engine: Engine::MxNet,
        }
    }

    /// Drive one event and write the decision back into the snapshot,
    /// the way a backend enforces an update.
    fn drive(
        pol: &mut CellScheduler,
        apps: &mut BTreeMap<AppId, SchedApp>,
        capacities: &[Res],
        now: f64,
    ) -> Option<AllocationUpdate> {
        let update = {
            let ctx = SchedCtx { now, apps, capacities };
            pol.on_change(&ctx)
        };
        if let Some(u) = &update {
            for a in apps.values_mut() {
                let row = u.assignment.get(&a.id).cloned().unwrap_or_default();
                a.containers = row.values().sum();
                a.placement = row;
            }
        }
        update
    }

    #[test]
    fn partition_covers_all_servers_without_overlap() {
        for (n, count) in [(4, 2), (10, 3), (7, 4), (3, 8), (1, 1)] {
            let s = CellScheduler::new(cfg(), cells_cfg(count), n);
            assert_eq!(s.cells[0].lo, 0);
            assert_eq!(s.cells.last().unwrap().hi, n);
            for w in s.cells.windows(2) {
                assert_eq!(w[0].hi, w[1].lo, "contiguous, non-overlapping");
            }
            assert!(s.cell_count() <= n, "never more cells than servers");
        }
    }

    #[test]
    fn apps_spread_across_cells_and_views_total() {
        let n = 4;
        let mut pol = CellScheduler::new(cfg(), cells_cfg(2), n);
        let mut apps = BTreeMap::new();
        for id in 1..=4u64 {
            apps.insert(AppId(id), app(id, 2, 6));
            let u = drive(&mut pol, &mut apps, &caps(n), id as f64).expect("feasible");
            assert!(u.assignment.values().all(|row| !row.is_empty()));
        }
        let views = pol.cell_views().unwrap();
        assert_eq!(views.len(), 2);
        assert!(views.iter().all(|v| v.apps > 0), "both cells got apps: {views:?}");
        let apps_total: u32 = views.iter().map(|v| v.apps).sum();
        assert_eq!(apps_total, 4);
        let cap_total: f64 = views.iter().map(|v| v.capacity[0]).sum();
        assert_eq!(cap_total, 12.0 * n as f64);
        // every placement stays inside its cell's slice
        for (id, &k) in &pol.routes {
            let (lo, hi) = (pol.cells[k].lo, pol.cells[k].hi);
            for sid in apps[id].placement.keys() {
                assert!(sid.0 >= lo && sid.0 < hi, "{id} leaked out of cell {k}");
            }
        }
    }

    #[test]
    fn single_cell_matches_dorm_policy_exactly() {
        use super::super::DormPolicy;
        let n = 4;
        let mut sharded = CellScheduler::new(cfg(), cells_cfg(1), n);
        let mut plain = DormPolicy::with_mode(cfg(), SolveMode::Heuristic);
        let mut a1 = BTreeMap::new();
        let mut a2 = BTreeMap::new();
        for id in 1..=5u64 {
            a1.insert(AppId(id), app(id, 1, 8));
            a2.insert(AppId(id), app(id, 1, 8));
            let u1 = drive(&mut sharded, &mut a1, &caps(n), id as f64);
            let u2 = {
                let ctx = SchedCtx { now: id as f64, apps: &a2, capacities: &caps(n) };
                plain.on_change(&ctx)
            };
            match (&u1, &u2) {
                (Some(x), Some(y)) => {
                    assert_eq!(x.assignment, y.assignment, "event {id}");
                    assert_eq!(x.adjusted, y.adjusted, "event {id}");
                }
                (None, None) => {}
                other => panic!("decisions diverged at event {id}: {other:?}"),
            }
            if let Some(u) = &u2 {
                for a in a2.values_mut() {
                    let row = u.assignment.get(&a.id).cloned().unwrap_or_default();
                    a.containers = row.values().sum();
                    a.placement = row;
                }
            }
        }
    }

    #[test]
    fn rebalance_migrates_and_reports_adjusted() {
        let n = 4;
        // rebalance on every event, hair-trigger threshold
        let mut pol = CellScheduler::new(
            cfg(),
            CellsConfig { count: 2, rebalance_every: 1, imbalance_threshold: 1.01 },
            n,
        );
        let mut apps = BTreeMap::new();
        // pin 3 apps into cell 0 by hand to force imbalance
        for id in 1..=3u64 {
            apps.insert(AppId(id), app(id, 2, 4));
            pol.routes.insert(AppId(id), 0);
        }
        let u = drive(&mut pol, &mut apps, &caps(n), 1.0).expect("feasible");
        assert!(
            pol.routes.values().any(|&k| k == 1),
            "imbalance must trigger a migration: {:?}",
            pol.routes
        );
        // whatever migrated was reported adjusted (checkpoint+kill)
        let moved: Vec<AppId> =
            pol.routes.iter().filter(|(_, &k)| k == 1).map(|(&id, _)| id).collect();
        for id in &moved {
            // a migrated app that was actually re-placed must be adjusted
            if u.assignment.get(id).is_some_and(|r| !r.is_empty()) {
                assert!(u.adjusted.contains(id), "{id} moved cells without adjustment");
            }
        }
    }

    #[test]
    fn routes_prune_on_departure_and_snapshot_roundtrips() {
        let n = 4;
        let mut pol = CellScheduler::new(cfg(), cells_cfg(2), n);
        let mut apps = BTreeMap::new();
        for id in 1..=4u64 {
            apps.insert(AppId(id), app(id, 1, 4));
        }
        drive(&mut pol, &mut apps, &caps(n), 1.0);
        apps.remove(&AppId(2));
        drive(&mut pol, &mut apps, &caps(n), 2.0);
        assert!(!pol.routes.contains_key(&AppId(2)), "departed app unpinned");

        let snap = pol.cells_snapshot().unwrap();
        assert_eq!(snap.count, 2);
        let rebuilt = CellScheduler::from_snapshot(cfg(), &snap, n);
        assert_eq!(rebuilt.routes(), pol.routes());
        assert_eq!(rebuilt.snapshot(), snap);
    }

    #[test]
    fn risk_aware_routing_avoids_hot_rack_cell() {
        use crate::fault::DomainTopology;
        let n = 4;
        // cells [0,2) and [2,4); racks {0,1} and {2,3} line up with them
        let mut pol = CellScheduler::new(cfg(), cells_cfg(2), n);
        pol.enable_risk_aware(DomainTopology::grouped(n, 2, 1));
        assert!(pol.name().ends_with("+risk"));

        // rack 0 (cell 0's servers) suffers an outage and comes back
        pol.on_server_failed(ServerId(0), 1.0);
        pol.on_server_failed(ServerId(1), 1.0);
        pol.on_capacity_change();
        pol.on_server_recovered(ServerId(0), 1.5);
        pol.on_server_recovered(ServerId(1), 1.5);
        pol.on_capacity_change();
        let est = pol.estimator().expect("risk-aware");
        assert_eq!(est.rack_failure_count(0), 2);
        assert_eq!(est.rack_failure_count(1), 0);

        // both cells have equal capacity and zero usage: the projected
        // shares tie, and the risk term must steer the app to cell 1
        let mut apps = BTreeMap::new();
        apps.insert(AppId(1), app(1, 1, 2));
        let u = drive(&mut pol, &mut apps, &caps(n), 2.0).expect("feasible");
        assert_eq!(pol.routes.get(&AppId(1)), Some(&1), "routed into the hot rack");
        let row = u.assignment.get(&AppId(1)).expect("placed");
        assert!(
            row.keys().all(|sid| sid.0 >= 2),
            "containers must land on rack 1's servers: {row:?}"
        );
    }

    #[test]
    fn dead_cell_defers_to_live_cells() {
        let n = 4;
        let mut pol = CellScheduler::new(cfg(), cells_cfg(2), n);
        // cell 1's servers are dead (zero capacity)
        let mut capacities = caps(n);
        capacities[2] = Res::zeros(3);
        capacities[3] = Res::zeros(3);
        let mut apps = BTreeMap::new();
        for id in 1..=3u64 {
            apps.insert(AppId(id), app(id, 1, 4));
            drive(&mut pol, &mut apps, &capacities, id as f64);
        }
        for (id, &k) in &pol.routes {
            assert_eq!(k, 0, "{id} routed into the dead cell");
        }
        assert!(apps.values().all(|a| a.containers > 0), "all admitted on the live half");
    }
}

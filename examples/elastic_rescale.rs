//! Elastic rescale: the §III-C-2 checkpoint-based adjustment protocol on a
//! real training job.
//!
//! Trains MF, then walks the exact Fig. 5 cycle — checkpoint → kill →
//! create/destroy containers → resume at a different width — twice, and
//! verifies the loss curve continues across both adjustments (no restart
//! from iteration 0, the whole point of the protocol).
//!
//! ```bash
//! cargo run --release --example elastic_rescale
//! ```

use dorm::app::{AppId, CheckpointStore};
use dorm::ps::{Trainer, TrainerConfig};
use dorm::runtime::{ComputeService, Manifest};

fn main() -> anyhow::Result<()> {
    dorm::util::logger::init();
    let manifest = Manifest::load("artifacts")?;
    let service = ComputeService::start_filtered(&manifest, Some(&["mf"]))?;
    let meta = manifest.model("mf")?;
    let store = CheckpointStore::new(std::env::temp_dir().join("dorm_rescale"))?;
    let app = AppId(1);

    // phase 1: 2 containers
    let cfg = TrainerConfig { workers: 2, lr: 0.3, seed: 1, data_seed: 7, ..Default::default() };
    let mut t = Trainer::new(app, meta, service.handle(), cfg.clone())?;
    let l0 = t.run(20)?;
    println!("phase 1 (W=2): step {:3} loss {:.4}", l0.step, l0.loss);

    // adjustment 1: scale UP to 6 containers
    t.checkpoint(&store)?;
    drop(t); // kill
    let cfg = TrainerConfig { workers: 6, ..cfg };
    let mut t = Trainer::resume(app, meta, service.handle(), cfg.clone(), &store)?;
    assert_eq!(t.current_step(), 20, "resume continues, not restarts");
    let l1 = t.run(20)?;
    println!("phase 2 (W=6): step {:3} loss {:.4}  (resumed at step 20)", l1.step, l1.loss);

    // adjustment 2: scale DOWN to 3 containers
    t.checkpoint(&store)?;
    drop(t);
    let cfg = TrainerConfig { workers: 3, ..cfg };
    let mut t = Trainer::resume(app, meta, service.handle(), cfg, &store)?;
    let l2 = t.run(20)?;
    println!("phase 3 (W=3): step {:3} loss {:.4}", l2.step, l2.loss);

    assert_eq!(t.current_step(), 60);
    assert!(
        l2.loss < l0.loss,
        "loss must keep improving across adjustments: {} -> {}",
        l0.loss,
        l2.loss
    );
    println!(
        "loss improved monotonically across 2 kill/resume cycles: {:.4} -> {:.4} -> {:.4}",
        l0.loss, l1.loss, l2.loss
    );
    Ok(())
}

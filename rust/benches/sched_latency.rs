//! §II-C reproduction: per-task scheduling latency of task-level two-level
//! sharing (Mesos-like) vs Dorm's local task placement — plus the
//! allocation-engine incremental re-solve path (snapshot cache +
//! warm-started solves, delta placement, amortized admission) that keeps
//! Dorm's per-event decision cost low.
//!
//! Paper measurement: "in a 100-node Mesos cluster ... the average
//! scheduling latency per task is about 430 ms"; Dorm places tasks on the
//! local TaskExecutor (§III-D) with no central round-trip.
//!
//! The **churn sweep** (DESIGN.md §10) scales a saturated cluster with a
//! standing deferred backlog up to 1000 apps × 500 servers and replays the
//! same completion/arrival churn through the legacy decision path
//! (per-prefix clones + full re-pack) and the incremental path (floor-
//! skipped admission + delta packing), reporting per-event decision
//! latency and moved containers.  Set `DORM_SCHED_SCALE=ci` for the
//! reduced CI sweep and `DORM_BENCH_JSON=<path>` to emit the machine-
//! readable `BENCH_sched.json` (scripts/bench_sched.sh wires both).

#[path = "harness/mod.rs"]
mod harness;

use std::collections::BTreeMap;
use std::time::Instant;

use dorm::app::{AppId, Engine};
use dorm::baselines::tasklevel::{dorm_local_placement_ms, TaskLevelModel};
use dorm::config::{CellsConfig, DormConfig};
use dorm::optimizer::{Decision, OptApp};
use dorm::report;
use dorm::resources::Res;
use dorm::sched::{
    AllocationEngine, AllocationUpdate, CellScheduler, CmsPolicy, EngineApp, SchedApp, SchedCtx,
};
use dorm::util::Rng;
use dorm::workload::table2_rows;

/// A paper-scale snapshot: `napps` Table II apps, all pending.
fn paper_snapshot(napps: usize, rng: &mut Rng) -> Vec<EngineApp> {
    let rows = table2_rows();
    (0..napps)
        .map(|i| {
            // CPU-bound rows (LR/MF/CaffeNet) — 46 of the paper's 50 apps;
            // keeps Σ n_min within the 5-GPU testbed so one solve admits all
            let row = &rows[rng.below(3) as usize];
            EngineApp {
                opt: OptApp {
                    id: AppId(i as u64),
                    demand: row.demand.clone(),
                    weight: row.weight as f64,
                    n_min: row.n_min,
                    n_max: row.n_max,
                    prev: None,
                    current: BTreeMap::new(),
                },
                submit: i as f64,
            }
        })
        .collect()
}

fn paper_capacities() -> Vec<Res> {
    (0..20)
        .map(|i| Res::cpu_gpu_ram(12.0, if i < 5 { 1.0 } else { 0.0 }, 128.0))
        .collect()
}

/// The engine section: quantify the incremental re-solve paths.
fn engine_resolve_bench() {
    harness::banner("allocation engine — incremental re-solve (50 apps, 20 slaves)");
    let mut rng = Rng::new(11);
    let caps = paper_capacities();
    let pending = paper_snapshot(50, &mut rng);

    // cold: a fresh engine per event — what every event cost pre-refactor
    let (cold_mean, _, _) = harness::bench_micro(
        "engine.decide, cold (fresh engine per event)",
        2,
        20,
        || {
            let mut eng = AllocationEngine::new(DormConfig::DORM3);
            let _ = eng.decide(&pending, &caps);
        },
    );

    // cache: identical snapshot re-presented (unchanged-event fast path)
    let mut eng = AllocationEngine::new(DormConfig::DORM3);
    let first = eng.decide(&pending, &caps).expect("paper workload feasible");
    let (hit_mean, _, _) = harness::bench_micro(
        "engine.decide, unchanged snapshot (cache hit)",
        2,
        50,
        || {
            let _ = eng.decide(&pending, &caps);
        },
    );
    let again = eng.decide(&pending, &caps).expect("still feasible");
    assert!(again.stats.cache_hit, "identical snapshot must hit the cache");
    assert_eq!(
        again.counts, first.counts,
        "cache must not change solver outputs"
    );

    // warm re-solve: carried state + an alternating arrival, so every call
    // is a genuine re-solve seeded by the previous solution
    let carried: Vec<EngineApp> = pending
        .iter()
        .map(|e| {
            let held = first.counts.get(&e.opt.id).copied().unwrap_or(0);
            EngineApp {
                opt: OptApp {
                    prev: (held > 0).then_some(held),
                    current: first
                        .placement
                        .assignment
                        .get(&e.opt.id)
                        .cloned()
                        .unwrap_or_default(),
                    ..e.opt.clone()
                },
                submit: e.submit,
            }
        })
        .collect();
    let rows = table2_rows();
    let mut with_arrival = carried.clone();
    with_arrival.push(EngineApp {
        opt: OptApp {
            id: AppId(999),
            demand: rows[0].demand.clone(),
            weight: rows[0].weight as f64,
            n_min: rows[0].n_min,
            n_max: rows[0].n_max,
            prev: None,
            current: BTreeMap::new(),
        },
        submit: 999.0,
    });
    let mut flip = false;
    let (warm_mean, _, _) = harness::bench_micro(
        "engine.decide, warm re-solve (alternating arrival)",
        2,
        30,
        || {
            flip = !flip;
            let snap: &[EngineApp] = if flip { &with_arrival } else { &carried };
            let _ = eng.decide(snap, &caps);
        },
    );

    let stats = eng.stats();
    println!(
        "  engine stats: {} solves, {} cache hits, {} warm-started",
        stats.solves, stats.cache_hits, stats.warm_start_hits
    );
    assert!(stats.cache_hits >= 50, "cache path must serve unchanged snapshots");
    assert!(stats.warm_start_hits >= 1, "warm path must seed re-solves");
    harness::paper_row(
        "re-solve on unchanged snapshot vs cold solve",
        "full solve per event",
        &format!("{:.0}x faster (cache hit)", cold_mean / hit_mean.max(0.01)),
    );
    harness::paper_row(
        "warm-started re-solve vs cold solve",
        "n/a (new in this repo)",
        &format!("{:.2}x", cold_mean / warm_mean.max(0.01)),
    );
}

/// One churn scenario's measurements for one decision path.
struct ChurnRun {
    cold_us: f64,
    samples_us: Vec<f64>,
    moved_containers: u64,
    /// Per-event decided counts (for old-vs-new parity checking).
    count_seqs: Vec<BTreeMap<AppId, u32>>,
    delta_packs: u64,
    full_repacks: u64,
    admit_skips: u64,
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((sorted.len() as f64 * q) as usize).min(sorted.len() - 1);
    sorted[i]
}

/// Synthetic churn app mix: three container shapes on ⟨16 CPU, 64 GB⟩
/// servers, floors sized so a saturated cluster keeps a deferred backlog
/// (the admission loop's worst case).
fn churn_app(id: u64, submit: f64) -> EngineApp {
    const SHAPES: [(f64, f64, u32); 3] =
        [(1.0, 4.0, 24), (2.0, 8.0, 16), (3.0, 12.0, 8)];
    let (cpu, ram, n_max) = SHAPES[(id % 3) as usize];
    EngineApp {
        opt: OptApp {
            id: AppId(id),
            demand: Res::cpu_gpu_ram(cpu, 0.0, ram),
            weight: 1.0,
            n_min: 4,
            n_max,
            prev: None,
            current: BTreeMap::new(),
        },
        submit,
    }
}

/// Apply a decision back onto the snapshot, as the master/DES would.
fn enforce(apps: &mut [EngineApp], d: &Decision) {
    for e in apps.iter_mut() {
        match d.counts.get(&e.opt.id) {
            Some(&c) if c > 0 => {
                e.opt.prev = Some(c);
                e.opt.current = d
                    .placement
                    .assignment
                    .get(&e.opt.id)
                    .cloned()
                    .unwrap_or_default();
            }
            _ => {
                e.opt.prev = None;
                e.opt.current = BTreeMap::new();
            }
        }
    }
}

/// Run the scripted churn (completion + arrival per event) through one
/// engine configuration and measure per-event decision latency.
fn churn_run(napps: usize, nservers: usize, events: usize, incremental: bool) -> ChurnRun {
    let caps: Vec<Res> = (0..nservers)
        .map(|_| Res::cpu_gpu_ram(16.0, 0.0, 64.0))
        .collect();
    // backlog: a few more apps than the floors admit, so every event
    // exercises the deferral loop
    let backlog = 6usize;
    let mut apps: Vec<EngineApp> = (0..napps + backlog)
        .map(|i| churn_app(i as u64, i as f64))
        .collect();
    let mut next_id = (napps + backlog) as u64;

    let mut eng = AllocationEngine::new(DormConfig::DORM3);
    eng.set_incremental(incremental);

    let t0 = Instant::now();
    let d = eng.decide(&apps, &caps).expect("cold churn snapshot solvable");
    let cold_us = t0.elapsed().as_secs_f64() * 1e6;
    enforce(&mut apps, &d);

    let mut run = ChurnRun {
        cold_us,
        samples_us: Vec::with_capacity(events),
        moved_containers: 0,
        count_seqs: Vec::with_capacity(events),
        delta_packs: 0,
        full_repacks: 0,
        admit_skips: 0,
    };
    for _ in 0..events {
        // complete the oldest running app, submit a fresh one
        if let Some(pos) = apps.iter().position(|e| e.opt.prev.is_some()) {
            apps.remove(pos);
        }
        apps.push(churn_app(next_id, next_id as f64));
        next_id += 1;

        let t0 = Instant::now();
        let d = eng.decide(&apps, &caps).expect("churn snapshot solvable");
        run.samples_us.push(t0.elapsed().as_secs_f64() * 1e6);
        run.moved_containers += d.stats.moved_containers;
        run.count_seqs.push(d.counts.clone());
        enforce(&mut apps, &d);
    }
    let s = eng.stats();
    run.delta_packs = s.delta_packs;
    run.full_repacks = s.full_repacks;
    run.admit_skips = s.admit_prefixes_skipped;
    run
}

/// Scales for the churn sweep: (apps, servers, churn events).
fn churn_scales() -> Vec<(usize, usize, usize)> {
    match std::env::var("DORM_SCHED_SCALE").as_deref() {
        Ok("ci") => vec![(60, 30, 12), (200, 100, 8)],
        _ => vec![(50, 20, 16), (200, 100, 10), (1000, 500, 6)],
    }
}

/// The tentpole measurement: old-vs-new decision path over the churn
/// workload, per scale; returns the JSON fragments for BENCH_sched.json.
fn churn_sweep() -> Vec<String> {
    harness::banner("incremental decision path — churn sweep (old vs new)");
    let scales = churn_scales();
    let mut rows = Vec::new();
    let mut json_scales = Vec::new();
    for &(napps, nservers, events) in &scales {
        let old = churn_run(napps, nservers, events, false);
        let new = churn_run(napps, nservers, events, true);

        let mut old_sorted = old.samples_us.clone();
        old_sorted.sort_by(|a, b| a.total_cmp(b));
        let mut new_sorted = new.samples_us.clone();
        new_sorted.sort_by(|a, b| a.total_cmp(b));
        let (op50, op99) = (percentile(&old_sorted, 0.5), percentile(&old_sorted, 0.99));
        let (np50, np99) = (percentile(&new_sorted, 0.5), percentile(&new_sorted, 0.99));
        let speedup = op50 / np50.max(0.01);

        // count parity is pinned at unit level
        // (sched::engine::tests::legacy_and_incremental_paths_agree and the
        // place_delta≡place property test); here it is reported — at full
        // saturation the delta packer may legitimately fit a placement the
        // from-scratch re-pack fragments on, which shifts counts upward
        let counts_match = old.count_seqs == new.count_seqs;
        if !counts_match {
            println!(
                "  NOTE: counts diverged at {napps}x{nservers} \
                 (delta packing admitted a placement the re-pack could not)"
            );
        }
        assert!(new.delta_packs >= 1, "delta path must run in the churn phase");
        assert_eq!(old.delta_packs, 0, "legacy path must never delta-pack");
        if counts_match && new.full_repacks == 0 {
            // same decisions and every event delta-packed: the delta path
            // moves exactly Σ|Δnᵢ| containers, the netted re-pack at least
            // that.  (A fallback event re-packs against the incremental
            // run's own placement history, so the comparison only holds
            // when no fallback fired.)
            assert!(
                new.moved_containers <= old.moved_containers,
                "delta packing may not move more containers ({} > {})",
                new.moved_containers,
                old.moved_containers
            );
        }

        rows.push(vec![
            format!("{napps}x{nservers}"),
            format!("{events}"),
            format!("{:.0}", op50),
            format!("{:.0}", np50),
            format!("{speedup:.1}x"),
            format!("{:.0}", op99),
            format!("{:.0}", np99),
            old.moved_containers.to_string(),
            new.moved_containers.to_string(),
        ]);
        json_scales.push(format!(
            concat!(
                "    {{\"apps\": {}, \"servers\": {}, \"events\": {},\n",
                "     \"old\": {{\"cold_us\": {:.1}, \"p50_us\": {:.1}, \"p99_us\": {:.1}, ",
                "\"moved_containers\": {}}},\n",
                "     \"new\": {{\"cold_us\": {:.1}, \"p50_us\": {:.1}, \"p99_us\": {:.1}, ",
                "\"moved_containers\": {}, \"delta_packs\": {}, \"full_repacks\": {}, ",
                "\"admit_prefixes_skipped\": {}}},\n",
                "     \"speedup_p50\": {:.2}, \"counts_match\": {}}}"
            ),
            napps,
            nservers,
            events,
            old.cold_us,
            op50,
            op99,
            old.moved_containers,
            new.cold_us,
            np50,
            np99,
            new.moved_containers,
            new.delta_packs,
            new.full_repacks,
            new.admit_skips,
            speedup,
            counts_match,
        ));
        println!(
            "  {napps}x{nservers}: old p50 {:.0} us -> new p50 {:.0} us ({speedup:.1}x), \
             moved {} -> {}, {} prefixes skipped",
            op50, np50, old.moved_containers, new.moved_containers, new.admit_skips
        );
    }
    println!(
        "{}",
        report::table(
            &[
                "apps x servers",
                "events",
                "old p50 (us)",
                "new p50 (us)",
                "speedup",
                "old p99",
                "new p99",
                "old moved",
                "new moved",
            ],
            &rows
        )
    );

    json_scales
}

// ---- sharded scheduler: cells x apps sweep (DESIGN.md §12) --------------

/// Churn app in the policy-level shape the [`CellScheduler`] consumes.
fn cells_app(id: u64, submit: f64) -> SchedApp {
    const SHAPES: [(f64, f64, u32); 3] =
        [(1.0, 4.0, 24), (2.0, 8.0, 16), (3.0, 12.0, 8)];
    let (cpu, ram, n_max) = SHAPES[(id % 3) as usize];
    SchedApp {
        id: AppId(id),
        demand: Res::cpu_gpu_ram(cpu, 0.0, ram),
        weight: 1.0,
        n_min: 4,
        n_max,
        containers: 0,
        placement: std::collections::BTreeMap::new(),
        submit,
        baseline_n: 8,
        engine: Engine::MxNet,
    }
}

/// Write a policy decision back onto the app map, as the backends do.
fn apply_update(apps: &mut BTreeMap<AppId, SchedApp>, u: &AllocationUpdate) {
    for a in apps.values_mut() {
        match u.assignment.get(&a.id) {
            Some(row) => {
                a.placement = row.clone();
                a.containers = row.values().sum();
            }
            None => {
                a.placement.clear();
                a.containers = 0;
            }
        }
    }
}

/// One sharded churn run: per-event `on_change` latency through the full
/// route/solve/gather pipeline at `cells` cells.
fn cells_run(cells: usize, napps: usize, nservers: usize, events: usize) -> (f64, Vec<f64>) {
    let caps: Vec<Res> = (0..nservers)
        .map(|_| Res::cpu_gpu_ram(16.0, 0.0, 64.0))
        .collect();
    let mut pol = CellScheduler::new(
        DormConfig::DORM3,
        CellsConfig { count: cells, rebalance_every: 4, imbalance_threshold: 1.5 },
        nservers,
    );
    let mut apps: BTreeMap<AppId, SchedApp> = (0..napps as u64)
        .map(|i| (AppId(i), cells_app(i, i as f64)))
        .collect();
    let mut next_id = napps as u64;
    let mut clock = napps as f64;

    let t0 = Instant::now();
    let upd = pol.on_change(&SchedCtx { now: clock, apps: &apps, capacities: &caps });
    let cold_us = t0.elapsed().as_secs_f64() * 1e6;
    if let Some(u) = &upd {
        apply_update(&mut apps, u);
    }

    let mut samples = Vec::with_capacity(events);
    for _ in 0..events {
        // complete the oldest running app, submit a fresh one
        if let Some(id) = apps.iter().find(|(_, a)| a.containers > 0).map(|(&id, _)| id) {
            apps.remove(&id);
        }
        clock += 1.0;
        apps.insert(AppId(next_id), cells_app(next_id, clock));
        next_id += 1;

        let t0 = Instant::now();
        let upd = pol.on_change(&SchedCtx { now: clock, apps: &apps, capacities: &caps });
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
        if let Some(u) = &upd {
            apply_update(&mut apps, u);
        }
    }
    (cold_us, samples)
}

/// Scales for the cells sweep: (apps, servers, churn events); every scale
/// runs at 1/2/4/8 cells on the same cluster.
fn cells_scales() -> Vec<(usize, usize, usize)> {
    match std::env::var("DORM_SCHED_SCALE").as_deref() {
        Ok("ci") => vec![(96, 32, 8)],
        _ => vec![(96, 32, 8), (240, 64, 6)],
    }
}

/// Sharded-vs-single decide latency at equal total load; returns the
/// JSON fragments for the "cells" array of BENCH_sched.json.
fn cells_sweep() -> Vec<String> {
    harness::banner("sharded scheduler — cells x apps sweep (fixed cluster)");
    const CELL_COUNTS: [usize; 4] = [1, 2, 4, 8];
    let mut rows = Vec::new();
    let mut json = Vec::new();
    for &(napps, nservers, events) in &cells_scales() {
        let mut p50_by_cells = Vec::new();
        for &cells in &CELL_COUNTS {
            let (cold_us, mut samples) = cells_run(cells, napps, nservers, events);
            samples.sort_by(|a, b| a.total_cmp(b));
            let (p50, p99) = (percentile(&samples, 0.5), percentile(&samples, 0.99));
            p50_by_cells.push((cells, p50));
            rows.push(vec![
                format!("{napps}x{nservers}"),
                format!("{cells}"),
                format!("{:.0}", cold_us),
                format!("{:.0}", p50),
                format!("{:.0}", p99),
            ]);
            json.push(format!(
                concat!(
                    "    {{\"cells\": {}, \"apps\": {}, \"servers\": {}, \"events\": {},\n",
                    "     \"cold_us\": {:.1}, \"p50_us\": {:.1}, \"p99_us\": {:.1}}}"
                ),
                cells, napps, nservers, events, cold_us, p50, p99,
            ));
            println!(
                "  {napps}x{nservers} @ {cells} cell(s): cold {:.0} us, \
                 p50 {:.0} us, p99 {:.0} us",
                cold_us, p50, p99
            );
        }
        // the point of sharding: at equal total load, parallel quarter-size
        // solves must not cost more per event than the single engine (the
        // 1.25 slack absorbs scatter/gather + thread-scope overhead on a
        // noisy CI box; the checked-in BENCH_baseline ceilings pin the
        // absolute numbers)
        let p50_1 = p50_by_cells[0].1;
        let p50_4 = p50_by_cells[2].1;
        assert!(
            p50_4 <= p50_1.max(50.0) * 1.25,
            "{napps}x{nservers}: 4-cell p50 {p50_4:.0} us regresses single-cell \
             p50 {p50_1:.0} us by more than 25%"
        );
    }
    println!(
        "{}",
        report::table(
            &["apps x servers", "cells", "cold (us)", "p50 (us)", "p99 (us)"],
            &rows
        )
    );
    json
}

fn main() {
    engine_resolve_bench();
    let churn_json = churn_sweep();
    let cells_json = cells_sweep();
    if let Ok(path) = std::env::var("DORM_BENCH_JSON") {
        let json = format!(
            "{{\n  \"bench\": \"sched_latency_churn\",\n  \"scales\": [\n{}\n  ],\n  \
             \"cells\": [\n{}\n  ]\n}}\n",
            churn_json.join(",\n"),
            cells_json.join(",\n")
        );
        std::fs::write(&path, json).expect("write BENCH json");
        println!("  wrote {path}");
    }

    harness::banner("§II-C — task-level scheduling latency vs cluster size");
    let mut rng = Rng::new(7);
    let sizes = [10usize, 25, 50, 75, 100, 150];
    let mut rows = Vec::new();
    let mut means = Vec::new();
    for &nodes in &sizes {
        let m = TaskLevelModel { nodes, ..Default::default() };
        let s = m.simulate(300, &mut rng);
        means.push((nodes as f64, s.mean_ms));
        rows.push(vec![
            format!("{nodes}"),
            format!("{:.2}", m.rho()),
            m.analytic_mean_ms()
                .map(|a| format!("{a:.0}"))
                .unwrap_or_else(|| "sat".into()),
            format!("{:.0}", s.mean_ms),
            format!("{:.0}", s.p50_ms),
            format!("{:.0}", s.p99_ms),
        ]);
    }
    println!(
        "{}",
        report::table(
            &["nodes", "offered load ρ", "M/M/1 (ms)", "mean (ms)", "p50", "p99"],
            &rows
        )
    );

    let hundred = means.iter().find(|(n, _)| *n == 100.0).unwrap().1;
    harness::paper_row(
        "mean scheduling latency per task, 100 nodes",
        "~430 ms",
        &format!("{hundred:.0} ms"),
    );
    harness::paper_row(
        "Dorm local task placement (§III-D)",
        "~0 (no petition)",
        &format!("{:.3} ms", dorm_local_placement_ms()),
    );
    harness::paper_row(
        "latency ratio (task-level / Dorm)",
        ">> 10^4",
        &format!("{:.0}x", hundred / dorm_local_placement_ms()),
    );

    println!("\nlatency vs cluster size:");
    println!("{}", report::ascii_chart(&[("mean ms", &means)], 10, 60));
}

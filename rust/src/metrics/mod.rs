//! Measured quantities of §IV-A as time series: resource utilization
//! (Eq. 1), fairness loss (Eq. 2) and cumulative resource-adjustment
//! overhead (Eq. 4), sampled by the master / simulator and consumed by the
//! figure benches.

use crate::util::stats;

/// A named step-function time series (time in hours, value).
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Series { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, t: f64, v: f64) {
        debug_assert!(
            self.points.last().map_or(true, |&(lt, _)| t >= lt),
            "time must be non-decreasing"
        );
        self.points.push((t, v));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|&(_, v)| v)
    }

    pub fn max(&self) -> f64 {
        self.points.iter().map(|&(_, v)| v).fold(0.0, f64::max)
    }

    /// Time-weighted mean over [t0, t1] (step-function semantics).
    pub fn mean_over(&self, t0: f64, t1: f64) -> f64 {
        stats::time_weighted_mean(&self.points, t0, t1)
    }

    /// Resample onto a uniform grid (for figure output).
    pub fn resample(&self, t0: f64, t1: f64, n: usize) -> Vec<(f64, f64)> {
        assert!(n >= 2);
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let t = t0 + (t1 - t0) * i as f64 / (n - 1) as f64;
            let idx = self.points.partition_point(|&(pt, _)| pt <= t);
            let v = if idx == 0 { 0.0 } else { self.points[idx - 1].1 };
            out.push((t, v));
        }
        out
    }
}

/// The three §IV-A metrics for one cluster-manager run.
#[derive(Clone, Debug)]
pub struct RunMetrics {
    /// Eq. 1 over time.
    pub utilization: Series,
    /// Eq. 2 over time.
    pub fairness_loss: Series,
    /// Eq. 4, cumulative count of adjusted (killed+resumed) apps.
    pub adjustments: Series,
    /// Per-adjustment-operation affected-app counts (Fig. 8's "at most N
    /// per operation" claim).
    pub adjustment_batch_sizes: Vec<u32>,
    /// (app tag, completion duration hours) per finished app (Fig. 9a).
    pub completions: Vec<(String, f64)>,
    /// Per-app completion durations keyed by workload index — used for the
    /// matched-pair speedup of Fig. 9a (same app under two systems).
    pub app_durations: std::collections::BTreeMap<u64, (String, f64)>,
    /// Cumulative work lost to server failures (`crate::fault`): progress
    /// since the last checkpoint, discarded at each server death.
    /// Work-hours in the DES, BSP steps on the live master.
    pub lost_work: Series,
    /// Sampled useful-progress rate summed over apps (work-units/hour;
    /// paused and recovering apps contribute zero).
    pub goodput: Series,
    /// One point per *completed* recovery — recorded once the re-placed
    /// app's restart pause has elapsed (or it completed), value = hours
    /// from server death until it was running again.
    pub recovery: Series,
}

impl RunMetrics {
    pub fn new(name: &str) -> Self {
        RunMetrics {
            utilization: Series::new(format!("{name}.utilization")),
            fairness_loss: Series::new(format!("{name}.fairness_loss")),
            adjustments: Series::new(format!("{name}.adjustments")),
            adjustment_batch_sizes: Vec::new(),
            completions: Vec::new(),
            app_durations: std::collections::BTreeMap::new(),
            lost_work: Series::new(format!("{name}.lost_work")),
            goodput: Series::new(format!("{name}.goodput")),
            recovery: Series::new(format!("{name}.recovery")),
        }
    }

    /// Mean recovery duration (hours from server death to running again);
    /// 0 when no recovery happened.
    pub fn mean_recovery_hours(&self) -> f64 {
        if self.recovery.points.is_empty() {
            return 0.0;
        }
        let vals: Vec<f64> = self.recovery.points.iter().map(|&(_, v)| v).collect();
        stats::mean(&vals)
    }

    /// Mean duration per app tag (the Fig. 9a aggregation).
    pub fn mean_duration_by_tag(&self) -> Vec<(String, f64)> {
        let mut tags: Vec<String> = self
            .completions
            .iter()
            .map(|(t, _)| t.clone())
            .collect();
        tags.sort();
        tags.dedup();
        tags.into_iter()
            .map(|tag| {
                let ds: Vec<f64> = self
                    .completions
                    .iter()
                    .filter(|(t, _)| *t == tag)
                    .map(|&(_, d)| d)
                    .collect();
                (tag, stats::mean(&ds))
            })
            .collect()
    }
}

/// Per-phase latency and scaling series from a trace replay against a
/// live master (`dorm replay --mode live|sweep`, DESIGN.md §13).  Time
/// axis is replayed trace hours; values are wall-clock measurements of
/// the control plane.
#[derive(Clone, Debug)]
pub struct ReplayMetrics {
    /// Submit RPC round-trip, milliseconds, one point per arrival.
    pub submit_ms: Series,
    /// Complete RPC round-trip, milliseconds, one point per retirement.
    pub complete_ms: Series,
    /// Scaling efficiency (achieved/offered rate) — one point per swept
    /// rate, time axis = offered arrivals/sec.
    pub efficiency: Series,
}

impl ReplayMetrics {
    pub fn new() -> Self {
        ReplayMetrics {
            submit_ms: Series::new("replay.submit_ms"),
            complete_ms: Series::new("replay.complete_ms"),
            efficiency: Series::new("replay.efficiency"),
        }
    }

    fn phase_percentile(s: &Series, p: f64) -> f64 {
        let vals: Vec<f64> = s.points.iter().map(|&(_, v)| v).collect();
        if vals.is_empty() {
            return 0.0;
        }
        stats::percentile(&vals, p)
    }

    pub fn submit_p50_ms(&self) -> f64 {
        Self::phase_percentile(&self.submit_ms, 50.0)
    }

    pub fn submit_p99_ms(&self) -> f64 {
        Self::phase_percentile(&self.submit_ms, 99.0)
    }

    pub fn complete_p50_ms(&self) -> f64 {
        Self::phase_percentile(&self.complete_ms, 50.0)
    }

    pub fn complete_p99_ms(&self) -> f64 {
        Self::phase_percentile(&self.complete_ms, 99.0)
    }
}

impl Default for ReplayMetrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_push_and_aggregates() {
        let mut s = Series::new("u");
        s.push(0.0, 1.0);
        s.push(1.0, 3.0);
        assert_eq!(s.last(), Some(3.0));
        assert_eq!(s.max(), 3.0);
        assert!((s.mean_over(0.0, 2.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn resample_step_semantics() {
        let mut s = Series::new("u");
        s.push(0.0, 1.0);
        s.push(10.0, 2.0);
        let r = s.resample(0.0, 20.0, 5);
        assert_eq!(r.len(), 5);
        assert_eq!(r[0].1, 1.0); // t=0
        assert_eq!(r[1].1, 1.0); // t=5
        assert_eq!(r[2].1, 2.0); // t=10
        assert_eq!(r[4].1, 2.0); // t=20
    }

    #[test]
    fn resample_before_first_point_is_zero() {
        let mut s = Series::new("u");
        s.push(5.0, 7.0);
        let r = s.resample(0.0, 10.0, 3);
        assert_eq!(r[0].1, 0.0);
        assert_eq!(r[1].1, 7.0);
    }

    #[test]
    fn replay_metrics_percentiles() {
        let mut m = ReplayMetrics::new();
        for i in 0..100 {
            m.submit_ms.push(i as f64, (i + 1) as f64);
        }
        assert!((m.submit_p50_ms() - 50.0).abs() <= 1.0, "{}", m.submit_p50_ms());
        assert!(m.submit_p99_ms() >= 99.0, "{}", m.submit_p99_ms());
        // empty phases don't panic
        assert_eq!(m.complete_p50_ms(), 0.0);
    }

    #[test]
    fn mean_duration_groups_by_tag() {
        let mut m = RunMetrics::new("x");
        m.completions.push(("lr".into(), 2.0));
        m.completions.push(("lr".into(), 4.0));
        m.completions.push(("mf".into(), 1.0));
        let by = m.mean_duration_by_tag();
        assert_eq!(by, vec![("lr".into(), 3.0), ("mf".into(), 1.0)]);
    }
}

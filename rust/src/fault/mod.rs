//! Fault tolerance and failure injection (DESIGN.md §8, §14).
//!
//! The paper assumes servers never fail; a production-scale CMS cannot.
//! This subsystem treats machine churn as a normal input to the
//! utilization–fairness optimizer, reusing the §III-C-2 adjustment
//! primitive (checkpoint → kill → resume) as the recovery mechanism.  It
//! has four parts, shared by the live [`crate::master::DormMaster`] and
//! the DES ([`crate::sim::run_sim_faulty`]) so recovery decisions are
//! backend-identical (`tests/fault.rs` pins the parity):
//!
//! * [`liveness`] — lease bookkeeping: slaves report heartbeats, the
//!   master expires stale leases and reclaims a dead server's capacity and
//!   containers.  Affected apps transition to `Degraded` and the
//!   allocation engine is re-driven with the shrunken capacity vector
//!   (its snapshot cache invalidated via
//!   [`crate::sched::CmsPolicy::on_capacity_change`]).
//! * [`recovery`] — lost-work accounting: affected apps resume from their
//!   latest [`crate::app::CheckpointStore`] snapshot at the newly solved
//!   scale; work since the last checkpoint (steps on the live master,
//!   work-hours in the DES) is recorded in a [`RecoveryLog`].
//! * [`model`] — failure injection: per-server exponential MTBF/MTTR
//!   traces, correlated whole-rack outages layered on that churn
//!   ([`FailureModel::Correlated`]), or scripted traces — fed into the
//!   simulator's event queue, or replayed against the live master through
//!   `DormMaster::fail_server`/`recover_server`.  Parameters validate to
//!   typed [`FaultError`]s instead of panicking.
//! * [`domains`] — the two-level failure-domain topology (rack → power
//!   domain) and the online [`MtbfEstimator`] whose per-rack risk
//!   estimates drive risk-aware placement (the
//!   [`crate::cluster::SpreadCtx`] tie-break) and cell routing.
//!
//! [`churn`] packages the evaluation: Dorm and all four baselines swept
//! over MTBF — plus the correlated-outage sweep (domain size × domain
//! MTBF, risk-aware vs. risk-blind) — reporting utilization, fairness
//! loss, lost work, recovery time and goodput through
//! [`crate::metrics`]/[`crate::report`].

pub mod churn;
pub mod domains;
pub mod liveness;
pub mod model;
pub mod recovery;

pub use churn::{
    churn_csv_columns, churn_sweep, churn_systems, churn_table, correlated_csv_columns,
    correlated_sweep, correlated_table, ChurnPoint, CorrelatedPoint,
};
pub use domains::{DomainTopology, MtbfEstimator};
pub use liveness::LeaseTable;
pub use model::{FailureEvent, FailureKind, FailureModel, FaultError};
pub use recovery::{RecoveryLog, RecoveryRecord};

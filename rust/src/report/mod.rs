//! Report emitters: ASCII tables and series plots for the figure benches,
//! plus CSV output for external plotting.

use std::fmt::Write as _;

/// Render a table with a header row.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        for w in &widths {
            let _ = write!(out, "+{}", "-".repeat(w + 2));
        }
        out.push_str("+\n");
    };
    sep(&mut out);
    for (i, h) in headers.iter().enumerate() {
        let _ = write!(out, "| {:w$} ", h, w = widths[i]);
    }
    out.push_str("|\n");
    sep(&mut out);
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            let _ = write!(out, "| {:w$} ", cell, w = widths[i]);
        }
        out.push_str("|\n");
    }
    sep(&mut out);
    out
}

/// ASCII line chart of one or more (label, series) over a shared x grid.
/// Series are (x, y) pairs; y is auto-scaled.
pub fn ascii_chart(series: &[(&str, &[(f64, f64)])], height: usize, width: usize) -> String {
    let mut out = String::new();
    if series.is_empty() || series.iter().all(|(_, s)| s.is_empty()) {
        return "(empty chart)\n".into();
    }
    let ymax = series
        .iter()
        .flat_map(|(_, s)| s.iter().map(|&(_, y)| y))
        .fold(f64::MIN, f64::max)
        .max(1e-12);
    let xmin = series
        .iter()
        .flat_map(|(_, s)| s.iter().map(|&(x, _)| x))
        .fold(f64::MAX, f64::min);
    let xmax = series
        .iter()
        .flat_map(|(_, s)| s.iter().map(|&(x, _)| x))
        .fold(f64::MIN, f64::max)
        .max(xmin + 1e-12);

    let marks = ['*', 'o', '+', 'x', '#', '@'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, s)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for &(x, y) in s.iter() {
            let col = ((x - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let row = ((1.0 - (y / ymax).clamp(0.0, 1.0)) * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][col.min(width - 1)] = mark;
        }
    }
    let _ = writeln!(out, "  ymax = {ymax:.3}");
    for row in &grid {
        let _ = writeln!(out, "  |{}", row.iter().collect::<String>());
    }
    let _ = writeln!(out, "  +{}", "-".repeat(width));
    let _ = writeln!(out, "   x: {xmin:.2} .. {xmax:.2}");
    for (si, (label, _)) in series.iter().enumerate() {
        let _ = writeln!(out, "   {} = {label}", marks[si % marks.len()]);
    }
    out
}

/// CSV with a header; columns are (name, values) of equal length.
pub fn csv(columns: &[(&str, Vec<f64>)]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{}",
        columns.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(",")
    );
    let nrows = columns.iter().map(|(_, v)| v.len()).max().unwrap_or(0);
    for r in 0..nrows {
        let row: Vec<String> = columns
            .iter()
            .map(|(_, v)| v.get(r).map(|x| format!("{x}")).unwrap_or_default())
            .collect();
        let _ = writeln!(out, "{}", row.join(","));
    }
    out
}

/// Write a CSV file under `reports/`, creating the directory.
pub fn write_csv(name: &str, columns: &[(&str, Vec<f64>)]) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("reports");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, csv(columns))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let t = table(
            &["name", "value"],
            &[
                vec!["utilization".into(), "2.32".into()],
                vec!["x".into(), "1".into()],
            ],
        );
        assert!(t.contains("| utilization | 2.32  |"));
        assert!(t.starts_with('+'));
    }

    #[test]
    fn chart_handles_empty_and_data() {
        assert_eq!(ascii_chart(&[], 5, 10), "(empty chart)\n");
        let s = [(0.0, 0.0), (1.0, 1.0), (2.0, 2.0)];
        let c = ascii_chart(&[("up", &s)], 5, 20);
        assert!(c.contains("ymax = 2.000"));
        assert!(c.contains("* = up"));
    }

    #[test]
    fn csv_round_numbers() {
        let c = csv(&[("t", vec![0.0, 1.0]), ("u", vec![2.5, 3.5])]);
        assert_eq!(c, "t,u\n0,2.5\n1,3.5\n");
    }
}

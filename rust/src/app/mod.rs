//! Applications: the paper's submission 6-tuple (§III-B), the lifecycle
//! state machine driven by the adjustment protocol (§III-C-2), and the
//! checkpoint store that makes kill/resume safe.

pub(crate) mod checkpoint;
mod spec;

pub use checkpoint::{Checkpoint, CheckpointStore};
pub use spec::{AppId, AppSpec, AppState, Engine};

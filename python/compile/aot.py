"""AOT pipeline: lower every model's init/grad/apply to HLO *text* artifacts.

HLO text — NOT ``lowered.compile().serialize()`` and NOT a serialized
HloModuleProto — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the xla crate's bundled XLA (xla_extension
0.5.1) rejects (``proto.id() <= INT_MAX``).  The HLO text parser reassigns
ids, so text round-trips cleanly.  See /opt/xla-example/README.md.

Outputs, per model M in ``model.default_models()``:

    artifacts/M_init.hlo.txt    (seed i32[])                    -> (params,)
    artifacts/M_grad.hlo.txt    (params, x, y)                  -> (loss, grads)
    artifacts/M_apply.hlo.txt   (params, gsum, count, lr)       -> (params,)
    artifacts/manifest.kv       flat key=value metadata for the Rust loader

Run once by ``make artifacts``; Python never runs on the request path.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_lib


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dt(tag):
    return jnp.int32 if tag == "i32" else jnp.float32


def lower_model(spec, outdir, manifest, verbose=True):
    n = spec.n_params
    params = _sds((n,), jnp.float32)
    x = _sds(spec.x_shape, _dt(spec.x_dtype))
    y = _sds(spec.y_shape, _dt(spec.y_dtype))
    scalar = _sds((), jnp.float32)
    seed = _sds((), jnp.int32)

    jobs = [
        ("init", spec.init, (seed,)),
        ("grad", spec.grad, (params, x, y)),
        ("apply", spec.apply, (params, params, scalar, scalar)),
    ]
    files = []
    for tag, fn, args in jobs:
        t0 = time.time()
        text = to_hlo_text(jax.jit(fn).lower(*args))
        fname = f"{spec.name}_{tag}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        files.append(fname)
        if verbose:
            print(f"  {fname}: {len(text)} chars ({time.time()-t0:.1f}s)",
                  flush=True)

    pfx = f"model.{spec.name}"
    manifest[f"{pfx}.params"] = str(n)
    manifest[f"{pfx}.x.shape"] = "x".join(map(str, spec.x_shape))
    manifest[f"{pfx}.x.dtype"] = spec.x_dtype
    manifest[f"{pfx}.y.shape"] = "x".join(map(str, spec.y_shape))
    manifest[f"{pfx}.y.dtype"] = spec.y_dtype
    for k, v in sorted(spec.meta.items()):
        manifest[f"{pfx}.meta.{k}"] = str(v)
    for tag, fname in zip(("init", "grad", "apply"), files):
        manifest[f"{pfx}.artifact.{tag}"] = fname


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="",
                    help="comma-separated subset (default: all)")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    specs = model_lib.default_models()
    if args.models:
        want = set(args.models.split(","))
        specs = [s for s in specs if s.name in want]
        missing = want - {s.name for s in specs}
        if missing:
            sys.exit(f"unknown models: {sorted(missing)}")

    manifest = {"manifest.version": "1",
                "manifest.models": ",".join(s.name for s in specs)}
    for spec in specs:
        print(f"lowering {spec.name} (n_params={spec.n_params}) ...", flush=True)
        lower_model(spec, args.out, manifest)

    with open(os.path.join(args.out, "manifest.kv"), "w") as f:
        for k in sorted(manifest):
            f.write(f"{k}={manifest[k]}\n")
    print(f"wrote {len(specs)} models -> {args.out}/manifest.kv")


if __name__ == "__main__":
    main()

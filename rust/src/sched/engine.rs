//! The shared allocation engine: Dorm's decision loop, extracted so the
//! live master and the simulator run byte-identical scheduling code.
//!
//! Responsibilities (§III-C Fig. 5 steps (1)–(2), §IV-B):
//!
//! 1. split the snapshot into carried (running) and pending apps, order
//!    pending FIFO by submission;
//! 2. admit the longest feasible FIFO prefix — on infeasibility the
//!    *newest* pending app is deferred first and the solve retried
//!    ("Dorm would keep existing resource allocations until more running
//!    applications finish");
//! 3. solve the count-aggregated P2 through [`Optimizer`] and return the
//!    [`Decision`] (counts + placement + adjusted set).
//!
//! Incremental re-solve state, per engine (DESIGN.md §10):
//!
//! * **snapshot cache** — the paper rebuilds and solves P2 on every event,
//!   but consecutive events frequently present an identical (apps,
//!   capacity) snapshot.  A cheap 64-bit FNV pre-key is folded over the
//!   snapshot first; only when it matches the cached entry is the exact
//!   bit-level comparison run — directly against the live snapshot, so
//!   neither path allocates a [`SnapshotKey`] (it is built once per
//!   *solve*, never per probe).  Hits return the cached [`Decision`]
//!   behind an [`Arc`] — O(1), no deep clone ([`SolveStats::cache_hit`]).
//! * **warm start** — the previous solution's counts seed the next solve
//!   as an incumbent ([`SolveStats::warm_start`]).
//! * **amortized admission** — the FIFO deferral loop solves over
//!   *slices* of one running+pending buffer (no per-prefix cloning), and
//!   an aggregate-capacity floor check binary-searches the longest
//!   admissible prefix up front, skipping the solves the old loop would
//!   have run and failed ([`EngineStats::admit_prefixes_skipped`]).
//! * **delta placement** — a persistent [`PackState`] rides along so the
//!   placement round moves only the apps whose counts changed
//!   ([`SolveStats::delta_path`], [`SolveStats::moved_containers`]).
//!
//! `benches/sched_latency.rs` measures the old-vs-new decision path over
//! a churn workload up to 1000 apps × 500 servers.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::app::AppId;
use crate::cluster::{PackState, ServerId, SpreadCtx};
use crate::config::DormConfig;
use crate::fault::{DomainTopology, MtbfEstimator};
use crate::optimizer::{Decision, OptApp, Optimizer, SolveMode};
use crate::resources::Res;

use super::policy::{AllocationUpdate, CmsPolicy, SchedApp, SchedCtx};

/// One application as the engine sees it: the optimizer row plus the FIFO
/// admission key.
#[derive(Clone, Debug)]
pub struct EngineApp {
    pub opt: OptApp,
    /// FIFO key; ties broken by [`AppId`] (submission order).
    pub submit: f64,
}

impl EngineApp {
    /// Build the engine row from a policy-level snapshot row.
    pub fn from_sched(a: &SchedApp) -> EngineApp {
        EngineApp {
            opt: OptApp {
                id: a.id,
                demand: a.demand.clone(),
                weight: a.weight,
                n_min: a.n_min,
                n_max: a.n_max,
                prev: (a.containers > 0).then_some(a.containers),
                current: a.placement.clone(),
            },
            submit: a.submit,
        }
    }
}

/// Engine-lifetime telemetry (cache + warm-start + incremental-path
/// effectiveness).
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    /// Decisions served by actually solving.
    pub solves: u64,
    /// Decisions served from the snapshot cache without solving.
    pub cache_hits: u64,
    /// Solves where the previous solution seeded a feasible incumbent.
    pub warm_start_hits: u64,
    /// Admission prefixes skipped by the aggregate-capacity floor check
    /// (each is a full solve the unamortized loop would have run and
    /// watched fail).
    pub admit_prefixes_skipped: u64,
    /// Decisions whose placement ran on the delta packer.
    pub delta_packs: u64,
    /// Decisions whose placement needed (or was configured as) a full
    /// BFD re-pack.
    pub full_repacks: u64,
}

/// Exact-input key for the snapshot cache: every field the solve depends
/// on, with floats compared by bit pattern (NaN-safe, no tolerance —
/// a near-identical snapshot must re-solve).  Built once per solve when
/// the cache entry is stored; probes compare field-by-field against the
/// live snapshot instead of constructing a key.
#[derive(Clone, Debug, PartialEq, Eq)]
struct SnapshotKey {
    apps: Vec<AppKey>,
    caps: Vec<Vec<u64>>,
}

#[derive(Clone, Debug, PartialEq, Eq)]
struct AppKey {
    id: u64,
    demand: Vec<u64>,
    weight: u64,
    n_min: u32,
    n_max: u32,
    prev: Option<u32>,
    current: Vec<(usize, u32)>,
}

fn res_bits(r: &Res) -> Vec<u64> {
    r.0.iter().map(|v| v.to_bits()).collect()
}

fn snapshot_key(apps: &[&EngineApp], capacities: &[Res]) -> SnapshotKey {
    SnapshotKey {
        apps: apps
            .iter()
            .map(|e| AppKey {
                id: e.opt.id.0,
                demand: res_bits(&e.opt.demand),
                weight: e.opt.weight.to_bits(),
                n_min: e.opt.n_min,
                n_max: e.opt.n_max,
                prev: e.opt.prev,
                current: e.opt.current.iter().map(|(s, &c)| (s.0, c)).collect(),
            })
            .collect(),
        caps: capacities.iter().map(res_bits).collect(),
    }
}

#[inline]
fn fnv_mix(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(0x100_0000_01b3)
}

/// Cheap 64-bit FNV-1a fold over exactly the fields [`snapshot_key`]
/// records — the allocation-free cache pre-key.
fn snapshot_prehash(apps: &[&EngineApp], capacities: &[Res]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    h = fnv_mix(h, apps.len() as u64);
    for e in apps {
        h = fnv_mix(h, e.opt.id.0);
        for v in &e.opt.demand.0 {
            h = fnv_mix(h, v.to_bits());
        }
        h = fnv_mix(h, e.opt.weight.to_bits());
        h = fnv_mix(h, e.opt.n_min as u64);
        h = fnv_mix(h, e.opt.n_max as u64);
        h = fnv_mix(h, e.opt.prev.map(|p| p as u64 + 1).unwrap_or(0));
        h = fnv_mix(h, e.opt.current.len() as u64);
        for (s, &c) in &e.opt.current {
            h = fnv_mix(h, s.0 as u64);
            h = fnv_mix(h, c as u64);
        }
    }
    h = fnv_mix(h, capacities.len() as u64);
    for cap in capacities {
        for v in &cap.0 {
            h = fnv_mix(h, v.to_bits());
        }
    }
    h
}

/// Exact comparison of a stored key against the live snapshot — no
/// allocation, early-out on first mismatch.
fn key_matches(key: &SnapshotKey, apps: &[&EngineApp], capacities: &[Res]) -> bool {
    key.apps.len() == apps.len()
        && key.caps.len() == capacities.len()
        && key.apps.iter().zip(apps).all(|(k, e)| {
            k.id == e.opt.id.0
                && k.n_min == e.opt.n_min
                && k.n_max == e.opt.n_max
                && k.prev == e.opt.prev
                && k.weight == e.opt.weight.to_bits()
                && k.demand.len() == e.opt.demand.0.len()
                && k.demand
                    .iter()
                    .zip(&e.opt.demand.0)
                    .all(|(b, v)| *b == v.to_bits())
                && k.current.len() == e.opt.current.len()
                && k.current
                    .iter()
                    .zip(&e.opt.current)
                    .all(|(kc, (s, &c))| kc.0 == s.0 && kc.1 == c)
        })
        && key.caps.iter().zip(capacities).all(|(kb, c)| {
            kb.len() == c.0.len() && kb.iter().zip(&c.0).all(|(b, v)| *b == v.to_bits())
        })
}

/// Largest pending-prefix length whose aggregate `n_min` floors — running
/// floors included — fit total capacity, found by binary search over the
/// (monotone) cumulative floor demand.  `None` when even the running
/// floors alone cannot fit: no prefix is solvable (every solver path
/// requires counts ≥ n_min within aggregate capacity), so the caller
/// returns "keep existing allocations" without solving at all.
fn feasible_floor_prefix(
    running: &[OptApp],
    pending: &[OptApp],
    capacities: &[Res],
) -> Option<usize> {
    let m = capacities.first().map(|c| c.m()).unwrap_or(0);
    let cap = capacities.iter().fold(Res::zeros(m), |mut acc, c| {
        acc += c;
        acc
    });
    let mut need = Res::zeros(m);
    for a in running {
        need += &a.demand.times(a.n_min);
    }
    if !need.fits_in(&cap) {
        return None;
    }
    let mut cum: Vec<Res> = Vec::with_capacity(pending.len());
    for a in pending {
        need += &a.demand.times(a.n_min);
        cum.push(need.clone());
    }
    // invariant: admitting `lo` floors fits; floors grow monotonically
    let (mut lo, mut hi) = (0usize, pending.len());
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        if cum[mid - 1].fits_in(&cap) {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    Some(lo)
}

struct CacheEntry {
    prehash: u64,
    key: SnapshotKey,
    /// Cached with `stats.cache_hit` already set, so hits are a pure
    /// `Arc::clone`.
    decision: Arc<Decision>,
}

/// The shared Dorm decision loop (see module docs).
pub struct AllocationEngine {
    optimizer: Optimizer,
    cache: Option<CacheEntry>,
    /// Counts of the last enforced decision, per app — the warm-start
    /// incumbent for the next solve.
    prev_counts: BTreeMap<AppId, u32>,
    /// Persistent delta-packer state (free vectors + committed rows).
    pack: PackState,
    /// Incremental hot path on (default).  Off = the pre-incremental
    /// decision loop — per-prefix buffer clones, no floor skip, full
    /// re-pack placement — kept so `benches/sched_latency.rs` can measure
    /// old-vs-new on the same workload.
    incremental: bool,
    stats: EngineStats,
}

impl AllocationEngine {
    pub fn new(cfg: DormConfig) -> Self {
        Self::with_mode(cfg, SolveMode::Heuristic)
    }

    pub fn with_mode(cfg: DormConfig, mode: SolveMode) -> Self {
        AllocationEngine {
            optimizer: Optimizer::with_mode(cfg, mode),
            cache: None,
            prev_counts: BTreeMap::new(),
            pack: PackState::default(),
            incremental: true,
            stats: EngineStats::default(),
        }
    }

    pub fn config(&self) -> &DormConfig {
        &self.optimizer.cfg
    }

    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// Toggle the incremental hot path (delta placement + amortized
    /// admission).  For benchmarking the legacy path; production callers
    /// leave it on.
    pub fn set_incremental(&mut self, on: bool) {
        self.incremental = on;
        if !on {
            self.pack.invalidate();
        }
    }

    pub fn incremental(&self) -> bool {
        self.incremental
    }

    /// Drop the cached solution, warm-start state and delta-packer books
    /// (e.g. after an out-of-band capacity change the caller knows
    /// invalidates them).  The failure-domain spread context survives (it
    /// describes the world, not the books).
    pub fn invalidate(&mut self) {
        self.cache = None;
        self.prev_counts.clear();
        self.pack.invalidate();
    }

    /// Install (or clear) the failure-domain tie-break context applied to
    /// every subsequent incremental placement round.  Callers must pair a
    /// *change* of context with [`AllocationEngine::invalidate`] — the
    /// snapshot cache does not key on it (in this codebase the context
    /// only changes on fail/recover events, which invalidate anyway).
    /// The legacy (non-incremental) path ignores it.
    pub fn set_spread(&mut self, spread: Option<SpreadCtx>) {
        self.pack.set_spread(spread);
    }

    /// The installed failure-domain context, if any.
    pub fn spread(&self) -> Option<&SpreadCtx> {
        self.pack.spread()
    }

    /// The shared loop: admission ordering, newest-first deferral, solve.
    /// `None` = no feasible allocation even with every pending app deferred
    /// — the backend keeps existing partitions (§IV-B).
    pub fn decide(&mut self, apps: &[EngineApp], capacities: &[Res]) -> Option<Arc<Decision>> {
        // carried apps first (input order), then pending FIFO by submit
        let running: Vec<&EngineApp> =
            apps.iter().filter(|e| e.opt.prev.is_some()).collect();
        let mut pending: Vec<&EngineApp> =
            apps.iter().filter(|e| e.opt.prev.is_none()).collect();
        pending.sort_by(|a, b| {
            a.submit.total_cmp(&b.submit).then(a.opt.id.cmp(&b.opt.id))
        });

        let ordered: Vec<&EngineApp> =
            running.iter().chain(pending.iter()).copied().collect();
        let prehash = snapshot_prehash(&ordered, capacities);
        if let Some(entry) = &self.cache {
            if entry.prehash == prehash && key_matches(&entry.key, &ordered, capacities) {
                self.stats.cache_hits += 1;
                return Some(Arc::clone(&entry.decision));
            }
        }

        self.stats.solves += 1;
        // snapshot the incumbent (cheap: one count per app) so the borrow
        // doesn't conflict with updating it on success
        let warm_counts = self.prev_counts.clone();
        let warm = (!warm_counts.is_empty()).then_some(&warm_counts);

        let decision = if self.incremental {
            self.decide_incremental(&running, &pending, capacities, warm)
        } else {
            self.decide_legacy(&running, &pending, capacities, warm)
        };

        let d = decision?;
        if d.stats.warm_start {
            self.stats.warm_start_hits += 1;
        }
        if d.stats.delta_path {
            self.stats.delta_packs += 1;
        } else {
            self.stats.full_repacks += 1;
        }
        self.prev_counts = d.counts.clone();
        let mut hit = d.clone();
        hit.stats.cache_hit = true;
        self.cache = Some(CacheEntry {
            prehash,
            key: snapshot_key(&ordered, capacities),
            decision: Arc::new(hit),
        });
        Some(Arc::new(d))
    }

    /// Amortized admission: one running+pending buffer, slice per prefix,
    /// floor-infeasible prefixes skipped by binary search, delta placement.
    fn decide_incremental(
        &mut self,
        running: &[&EngineApp],
        pending: &[&EngineApp],
        capacities: &[Res],
        warm: Option<&BTreeMap<AppId, u32>>,
    ) -> Option<Decision> {
        let mut all_opts: Vec<OptApp> = Vec::with_capacity(running.len() + pending.len());
        all_opts.extend(running.iter().map(|e| e.opt.clone()));
        let n_running = all_opts.len();
        all_opts.extend(pending.iter().map(|e| e.opt.clone()));

        let start = feasible_floor_prefix(
            &all_opts[..n_running],
            &all_opts[n_running..],
            capacities,
        )?;
        self.stats.admit_prefixes_skipped += (pending.len() - start) as u64;

        for admit in (0..=start).rev() {
            let try_apps = &all_opts[..n_running + admit];
            if let Some(d) = self.optimizer.allocate_incremental(
                try_apps,
                capacities,
                warm,
                Some(&mut self.pack),
            ) {
                return Some(d);
            }
        }
        None
    }

    /// The pre-incremental loop, kept verbatim for old-vs-new benching:
    /// clones the buffers per prefix, solves every prefix, full re-pack.
    fn decide_legacy(
        &mut self,
        running: &[&EngineApp],
        pending: &[&EngineApp],
        capacities: &[Res],
        warm: Option<&BTreeMap<AppId, u32>>,
    ) -> Option<Decision> {
        let running_opts: Vec<OptApp> =
            running.iter().map(|e| e.opt.clone()).collect();
        let pending_opts: Vec<OptApp> =
            pending.iter().map(|e| e.opt.clone()).collect();
        for admit in (0..=pending_opts.len()).rev() {
            let mut try_apps = running_opts.clone();
            try_apps.extend(pending_opts[..admit].iter().cloned());
            if let Some(d) = self.optimizer.allocate_warm(&try_apps, capacities, warm) {
                return Some(d);
            }
        }
        None
    }
}

/// Dorm as a [`CmsPolicy`]: a thin adapter over [`AllocationEngine`] —
/// usable unchanged by the live [`crate::master::DormMaster`] and the DES
/// ([`crate::sim::run_sim`]).
pub struct DormPolicy {
    pub engine: AllocationEngine,
    label: String,
    /// Online failure observer (risk-aware mode): feeds per-rack failure
    /// counts into the engine's [`SpreadCtx`] on every fail/recover event.
    estimator: Option<MtbfEstimator>,
}

impl DormPolicy {
    pub fn new(cfg: DormConfig) -> Self {
        Self::with_mode(cfg, SolveMode::Heuristic)
    }

    pub fn with_mode(cfg: DormConfig, mode: SolveMode) -> Self {
        DormPolicy {
            label: format!("dorm(t1={},t2={})", cfg.theta1, cfg.theta2),
            engine: AllocationEngine::with_mode(cfg, mode),
            estimator: None,
        }
    }

    /// Risk-aware mode (DESIGN.md §14): own an online
    /// [`MtbfEstimator`] over `topo` and keep the engine's placement
    /// tie-break pointed at its per-rack failure counts.  Counts (not
    /// time-based rates) keep decisions identical across backends whose
    /// clocks differ (DES hours vs. master event counter).
    pub fn with_domains(cfg: DormConfig, topo: DomainTopology) -> Self {
        let mut p = Self::new(cfg);
        p.enable_risk_aware(topo);
        p
    }

    /// Switch an existing policy into risk-aware mode (resets any prior
    /// estimator state).
    pub fn enable_risk_aware(&mut self, topo: DomainTopology) {
        self.label = format!("{}+risk", self.label);
        self.push_spread_from(&topo, &MtbfEstimator::new(topo.clone()));
        self.estimator = Some(MtbfEstimator::new(topo));
    }

    /// The online estimator, when risk-aware mode is on.
    pub fn estimator(&self) -> Option<&MtbfEstimator> {
        self.estimator.as_ref()
    }

    fn push_spread_from(&mut self, topo: &DomainTopology, est: &MtbfEstimator) {
        self.engine.set_spread(Some(SpreadCtx {
            domain_of: topo.rack_map().to_vec(),
            risk: est.rack_risks_by_count(),
        }));
    }

    /// Re-derive the spread context from the estimator's current counts.
    fn refresh_spread(&mut self) {
        let Some(est) = self.estimator.take() else { return };
        let topo = est.topology().clone();
        self.push_spread_from(&topo, &est);
        self.estimator = Some(est);
    }
}

impl CmsPolicy for DormPolicy {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn on_change(&mut self, ctx: &SchedCtx) -> Option<AllocationUpdate> {
        let apps: Vec<EngineApp> = ctx.apps.values().map(EngineApp::from_sched).collect();
        let d = self.engine.decide(&apps, ctx.capacities)?;
        Some(AllocationUpdate {
            // Arc clone: cache hits hand out the assignment in O(1)
            assignment: d.placement.assignment.clone(),
            adjusted: d.adjusted.clone(),
        })
    }

    /// A server died or recovered (`crate::fault`): the cached decision,
    /// the warm-start incumbent and the delta-packer free vectors were
    /// solved against a capacity vector that no longer exists — drop them
    /// so the next decide() is a cold solve.
    fn on_capacity_change(&mut self) {
        self.engine.invalidate();
    }

    /// Risk-aware mode: record the failure and refresh the placement
    /// tie-break.  The backend's `on_capacity_change` follows immediately,
    /// so the snapshot cache never serves a decision solved under the old
    /// risk vector.
    fn on_server_failed(&mut self, server: ServerId, now: f64) {
        if let Some(est) = self.estimator.as_mut() {
            est.observe_failure(server.0, now);
            self.refresh_spread();
        }
    }

    fn on_server_recovered(&mut self, server: ServerId, now: f64) {
        if let Some(est) = self.estimator.as_mut() {
            est.observe_repair(server.0, now);
            self.refresh_spread();
        }
    }

    fn engine_stats(&self) -> Option<EngineStats> {
        Some(self.engine.stats().clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerId;

    fn eapp(id: u64, cpu: f64, ram: f64, lo: u32, hi: u32, held: u32, submit: f64) -> EngineApp {
        let current: BTreeMap<ServerId, u32> = if held > 0 {
            [(ServerId(0), held)].into_iter().collect()
        } else {
            BTreeMap::new()
        };
        EngineApp {
            opt: OptApp {
                id: AppId(id),
                demand: Res(vec![cpu, ram]),
                weight: 1.0,
                n_min: lo,
                n_max: hi,
                prev: (held > 0).then_some(held),
                current,
            },
            submit,
        }
    }

    fn caps(n: usize, cpu: f64, ram: f64) -> Vec<Res> {
        (0..n).map(|_| Res(vec![cpu, ram])).collect()
    }

    #[test]
    fn identical_snapshot_is_served_from_cache() {
        let mut eng = AllocationEngine::new(DormConfig::DORM3);
        let apps = vec![eapp(1, 2.0, 8.0, 1, 10, 0, 0.0)];
        let capacities = caps(4, 12.0, 64.0);
        let d1 = eng.decide(&apps, &capacities).unwrap();
        assert!(!d1.stats.cache_hit);
        let d2 = eng.decide(&apps, &capacities).unwrap();
        assert!(d2.stats.cache_hit);
        assert_eq!(d1.counts, d2.counts);
        assert_eq!(eng.stats().solves, 1);
        assert_eq!(eng.stats().cache_hits, 1);
        // hits share one decision: no deep clone happened
        let d3 = eng.decide(&apps, &capacities).unwrap();
        assert!(Arc::ptr_eq(&d2, &d3), "cache hits must share the Arc");
    }

    #[test]
    fn changed_snapshot_resolves_with_warm_start() {
        let mut eng = AllocationEngine::new(DormConfig { theta1: 1.0, theta2: 1.0 });
        let capacities = caps(2, 20.0, 20.0);
        let a = eapp(1, 1.0, 1.0, 1, 40, 0, 0.0);
        let d1 = eng.decide(&[a.clone()], &capacities).unwrap();
        let held = d1.counts[&AppId(1)];
        assert!(held > 0);
        // second event: app 1 carried at its decided width, app 2 arrives
        let carried = eapp(1, 1.0, 1.0, 1, 40, held, 0.0);
        let arriving = eapp(2, 1.0, 1.0, 1, 40, 0, 1.0);
        let d2 = eng.decide(&[carried, arriving], &capacities).unwrap();
        assert!(!d2.stats.cache_hit);
        assert!(d2.stats.warm_start, "previous counts must seed the solve");
        assert_eq!(eng.stats().solves, 2);
        assert!(eng.stats().warm_start_hits >= 1);
        assert!(d2.counts[&AppId(2)] >= 1);
    }

    #[test]
    fn newest_pending_deferred_first() {
        let mut eng = AllocationEngine::new(DormConfig { theta1: 1.0, theta2: 1.0 });
        let capacities = caps(1, 10.0, 10.0);
        // each app floors at 3 containers of 2 CPUs: only one fits
        let old = eapp(1, 2.0, 1.0, 3, 5, 0, 0.0);
        let newer = eapp(2, 2.0, 1.0, 3, 5, 0, 1.0);
        let d = eng.decide(&[newer.clone(), old.clone()], &capacities).unwrap();
        assert!(d.counts.contains_key(&AppId(1)), "older app admitted");
        assert!(!d.counts.contains_key(&AppId(2)), "newest deferred first");
        // the floor check skipped the admit-both prefix without solving it
        assert_eq!(eng.stats().admit_prefixes_skipped, 1);
    }

    #[test]
    fn legacy_and_incremental_paths_agree() {
        // same scripted sequence through both paths: identical counts
        let capacities = caps(2, 12.0, 12.0);
        let events: Vec<Vec<EngineApp>> = vec![
            vec![eapp(1, 2.0, 2.0, 1, 8, 0, 0.0)],
            vec![eapp(1, 2.0, 2.0, 1, 8, 6, 0.0), eapp(2, 2.0, 2.0, 2, 8, 0, 1.0)],
            vec![
                eapp(1, 2.0, 2.0, 1, 8, 4, 0.0),
                eapp(2, 2.0, 2.0, 2, 8, 2, 1.0),
                eapp(3, 3.0, 1.0, 3, 8, 0, 2.0),
            ],
        ];
        let mut inc = AllocationEngine::new(DormConfig { theta1: 1.0, theta2: 1.0 });
        let mut leg = AllocationEngine::new(DormConfig { theta1: 1.0, theta2: 1.0 });
        leg.set_incremental(false);
        for ev in &events {
            let a = inc.decide(ev, &capacities).map(|d| d.counts.clone());
            let b = leg.decide(ev, &capacities).map(|d| d.counts.clone());
            assert_eq!(a, b, "paths diverged on {ev:?}");
        }
        assert!(inc.stats().delta_packs >= 1, "delta path must have run");
        assert_eq!(leg.stats().delta_packs, 0, "legacy path never delta-packs");
    }

    #[test]
    fn key_probe_matches_key_construction() {
        // key_matches/snapshot_prehash must stay field-equivalent to
        // snapshot_key: a solve-relevant field added to the key but missed
        // by the probe would silently serve stale cached decisions — this
        // test breaks instead.
        let a = eapp(1, 2.0, 8.0, 1, 10, 3, 0.5);
        let b = eapp(2, 1.0, 4.0, 2, 6, 0, 1.5);
        let capacities = caps(3, 12.0, 64.0);
        let apps: Vec<&EngineApp> = vec![&a, &b];
        let key = snapshot_key(&apps, &capacities);
        assert!(key_matches(&key, &apps, &capacities));

        let mut variants: Vec<EngineApp> = Vec::new();
        for f in [
            (|v: &mut EngineApp| v.opt.id = AppId(9)) as fn(&mut EngineApp),
            |v| v.opt.demand = Res(vec![2.0, 9.0]),
            |v| v.opt.weight = 2.0,
            |v| v.opt.n_min = 2,
            |v| v.opt.n_max = 11,
            |v| v.opt.prev = Some(4),
            |v| v.opt.current = [(ServerId(1), 3)].into_iter().collect(),
        ] {
            let mut v = a.clone();
            f(&mut v);
            variants.push(v);
        }
        for v in &variants {
            let mutated: Vec<&EngineApp> = vec![v, &b];
            assert!(
                !key_matches(&key, &mutated, &capacities),
                "probe missed a field change: {v:?}"
            );
            assert_ne!(snapshot_key(&mutated, &capacities), key);
            assert_ne!(
                snapshot_prehash(&mutated, &capacities),
                snapshot_prehash(&apps, &capacities),
                "pre-key missed a field change: {v:?}"
            );
        }
        assert!(!key_matches(&key, &apps, &caps(3, 12.0, 65.0)));
        assert!(!key_matches(&key, &apps, &caps(2, 12.0, 64.0)));
    }

    #[test]
    fn risk_aware_policy_steers_ties_away_from_failed_rack() {
        use super::super::policy::{CmsPolicy, SchedApp, SchedCtx};
        use crate::app::Engine as DcsEngine;
        use crate::cluster::ServerId;
        use crate::fault::DomainTopology;

        let capacities: Vec<Res> = (0..4).map(|_| Res(vec![4.0, 4.0])).collect();
        let sched_app = |id: u64| SchedApp {
            id: AppId(id),
            demand: Res(vec![3.0, 3.0]), // one container per server
            weight: 1.0,
            n_min: 1,
            n_max: 1,
            containers: 0,
            placement: BTreeMap::new(),
            submit: 0.0,
            baseline_n: 1,
            engine: DcsEngine::MxNet,
        };
        let apps: BTreeMap<AppId, SchedApp> =
            [(AppId(1), sched_app(1))].into_iter().collect();
        let ctx = SchedCtx { now: 2.0, apps: &apps, capacities: &capacities };

        // risk-blind: equal-slack tie goes to the lowest index (server 0)
        let mut blind = DormPolicy::new(DormConfig { theta1: 1.0, theta2: 1.0 });
        let ub = blind.on_change(&ctx).unwrap();
        assert_eq!(ub.assignment[&AppId(1)][&ServerId(0)], 1);

        // risk-aware: rack 0 = {s0, s1} observed failing once — the same
        // tie must land in rack 1 instead
        let mut aware = DormPolicy::with_domains(
            DormConfig { theta1: 1.0, theta2: 1.0 },
            DomainTopology::grouped(4, 2, 1),
        );
        assert!(aware.name().ends_with("+risk"));
        aware.on_server_failed(ServerId(0), 1.0);
        aware.on_server_failed(ServerId(1), 1.0);
        aware.on_capacity_change();
        aware.on_server_recovered(ServerId(0), 1.5);
        aware.on_server_recovered(ServerId(1), 1.5);
        aware.on_capacity_change();
        assert_eq!(aware.estimator().unwrap().rack_failure_count(0), 2);
        let ua = aware.on_change(&ctx).unwrap();
        assert_eq!(ua.assignment[&AppId(1)].get(&ServerId(0)), None);
        assert_eq!(ua.assignment[&AppId(1)][&ServerId(2)], 1, "tie steered to rack 1");
        // totals are untouched by the tie-break
        let tb: u32 = ub.assignment[&AppId(1)].values().sum();
        let ta: u32 = ua.assignment[&AppId(1)].values().sum();
        assert_eq!(tb, ta);
    }

    #[test]
    fn explicit_invalidate_forces_cold_resolve() {
        use super::super::policy::CmsPolicy;
        let mut pol = DormPolicy::new(DormConfig::DORM3);
        let apps = vec![eapp(1, 2.0, 8.0, 1, 10, 0, 0.0)];
        let capacities = caps(4, 12.0, 64.0);
        let d1 = pol.engine.decide(&apps, &capacities).unwrap();
        pol.on_capacity_change();
        // identical snapshot, but the fault path dropped the cache: the
        // engine must solve again (and reproduce the same counts)
        let d2 = pol.engine.decide(&apps, &capacities).unwrap();
        assert!(!d2.stats.cache_hit, "invalidate must force a re-solve");
        assert_eq!(d1.counts, d2.counts);
        assert_eq!(pol.engine.stats().solves, 2);
    }

    #[test]
    fn cache_invalidated_by_capacity_change() {
        let mut eng = AllocationEngine::new(DormConfig::DORM3);
        let apps = vec![eapp(1, 2.0, 8.0, 1, 10, 0, 0.0)];
        let d1 = eng.decide(&apps, &caps(4, 12.0, 64.0)).unwrap();
        let d2 = eng.decide(&apps, &caps(2, 12.0, 64.0)).unwrap();
        assert!(!d2.stats.cache_hit, "smaller cluster must re-solve");
        assert!(d2.counts[&AppId(1)] <= d1.counts[&AppId(1)]);
        assert_eq!(eng.stats().solves, 2);
    }
}

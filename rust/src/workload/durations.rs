//! Fig. 1 duration model.
//!
//! The paper's production-trace CDFs are proprietary; §I states the two
//! anchor quantiles — **about 90 % of distributed-ML applications run more
//! than 6 hours** and **about 50 % of tasks take less than 1.5 s** — and
//! Fig. 1 shows smooth log-normal-looking CDFs.  We therefore fit
//! log-normal distributions through those anchors (DESIGN.md §1):
//!
//! * app duration: P(X > 6 h) = 0.9 with shape σ = 0.6
//!   ⇒ μ = ln 6 + 0.6·z₀.₉ = ln 6 + 0.6·1.2816 (median ≈ 12.9 h);
//! * task duration: median 1.5 s with shape σ = 1.2 (short tasks with a
//!   heavy right tail, matching the "very short task" observation).

use crate::util::Rng;

/// z-score of the 90th percentile of the standard normal.
const Z90: f64 = 1.2815515655446004;

/// Log-normal parameters for app and task durations.
#[derive(Clone, Debug)]
pub struct DurationModel {
    pub app_mu: f64,
    pub app_sigma: f64,
    pub task_mu: f64,
    pub task_sigma: f64,
}

impl Default for DurationModel {
    fn default() -> Self {
        Self::production()
    }
}

impl DurationModel {
    /// The Fig. 1 production-trace fit (90 % of apps > 6 h).
    pub fn production() -> Self {
        let app_sigma = 0.6;
        let task_sigma = 1.2;
        DurationModel {
            // P(X > 6) = 0.9  <=>  (ln 6 - mu)/sigma = -z90
            app_mu: 6.0f64.ln() + app_sigma * Z90,
            app_sigma,
            // median 1.5 s
            task_mu: 1.5f64.ln(),
            task_sigma,
        }
    }

    /// The §V synthetic-evaluation workload.  The paper never states the
    /// durations of the 50 synthetic apps; the published outcomes pin them
    /// instead — the baseline "can only handle the first 15 submitted
    /// applications" in 5 h and Dorm speeds apps up ~2.7× (close to the
    /// speed(n_max)/speed(baseline) ceiling), which requires a moderately
    /// loaded cluster with a persistent backlog, i.e. median ≈ 9 h (see
    /// EXPERIMENTS.md §Calib for the sweep that pins this).
    pub fn synthetic_eval() -> Self {
        DurationModel {
            app_mu: 9.0f64.ln(),
            app_sigma: 0.5,
            task_mu: 1.5f64.ln(),
            task_sigma: 1.2,
        }
    }
}

/// Sample an application duration in hours.
pub fn app_duration_hours(model: &DurationModel, rng: &mut Rng) -> f64 {
    rng.log_normal(model.app_mu, model.app_sigma)
}

/// Sample a task duration in seconds.
pub fn task_duration_secs(model: &DurationModel, rng: &mut Rng) -> f64 {
    rng.log_normal(model.task_mu, model.task_sigma)
}

/// Standard normal CDF (Abramowitz–Stegun 7.1.26 erf approximation),
/// used to evaluate the model CDF analytically for Fig. 1.
pub fn normal_cdf(z: f64) -> f64 {
    let t = 1.0 / (1.0 + 0.3275911 * z.abs() / std::f64::consts::SQRT_2);
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    let erf = 1.0 - poly * (-z * z / 2.0).exp();
    if z >= 0.0 {
        0.5 * (1.0 + erf)
    } else {
        0.5 * (1.0 - erf)
    }
}

impl DurationModel {
    /// Analytic CDF of app duration at `hours`.
    pub fn app_cdf(&self, hours: f64) -> f64 {
        if hours <= 0.0 {
            return 0.0;
        }
        normal_cdf((hours.ln() - self.app_mu) / self.app_sigma)
    }

    /// Analytic CDF of task duration at `secs`.
    pub fn task_cdf(&self, secs: f64) -> f64 {
        if secs <= 0.0 {
            return 0.0;
        }
        normal_cdf((secs.ln() - self.task_mu) / self.task_sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn anchors_hold_analytically() {
        let m = DurationModel::default();
        // 90% of apps run longer than 6h
        assert!((m.app_cdf(6.0) - 0.10).abs() < 0.005, "{}", m.app_cdf(6.0));
        // 50% of tasks under 1.5s
        assert!((m.task_cdf(1.5) - 0.50).abs() < 0.005, "{}", m.task_cdf(1.5));
    }

    #[test]
    fn anchors_hold_empirically() {
        let m = DurationModel::default();
        let mut rng = Rng::new(99);
        let apps: Vec<f64> = (0..40_000).map(|_| app_duration_hours(&m, &mut rng)).collect();
        let frac_over_6h = apps.iter().filter(|&&d| d > 6.0).count() as f64 / apps.len() as f64;
        assert!((frac_over_6h - 0.9).abs() < 0.01, "{frac_over_6h}");

        let tasks: Vec<f64> = (0..40_000).map(|_| task_duration_secs(&m, &mut rng)).collect();
        let frac_under = tasks.iter().filter(|&&d| d < 1.5).count() as f64 / tasks.len() as f64;
        assert!((frac_under - 0.5).abs() < 0.01, "{frac_under}");
    }

    #[test]
    fn empirical_matches_analytic_cdf() {
        let m = DurationModel::default();
        let mut rng = Rng::new(4);
        let apps: Vec<f64> = (0..20_000).map(|_| app_duration_hours(&m, &mut rng)).collect();
        for h in [2.0, 6.0, 12.0, 24.0] {
            let emp = stats::ecdf(&apps, &[h])[0];
            let ana = m.app_cdf(h);
            assert!((emp - ana).abs() < 0.02, "h={h}: emp {emp} vs ana {ana}");
        }
    }

    #[test]
    fn normal_cdf_sane() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-6);
        assert!((normal_cdf(Z90) - 0.9).abs() < 1e-4);
        assert!(normal_cdf(-8.0) < 1e-6);
        assert!(normal_cdf(8.0) > 1.0 - 1e-6);
    }
}

//! Trace export: write any synthesized workload out in the native CSV
//! schema, losslessly.
//!
//! The guarantee (pinned by `tests/trace.rs`): synthesize → export →
//! re-read through [`super::TraceReader`] → replay gives the *identical*
//! DES event sequence as replaying the in-memory workload directly.
//! Floats are printed with Rust's shortest-round-trip `Display`, so the
//! re-parsed values are bit-equal to the originals.

use std::io::{self, Write};

use crate::workload::{Table2Row, WorkloadApp};

use super::schema::TraceRecord;

/// The native header, matched (by name, order-independently) by
/// [`super::SchemaAdapter::detect`].
pub const DORM_HEADER: &str =
    "submit_hours,model,engine,cpus,gpus,ram_gb,weight,n_min,n_max,baseline_n,duration_hours";

/// One CSV row for a record (no trailing newline).
pub fn record_line(r: &TraceRecord) -> String {
    let d = &r.demand.0;
    let (cpu, gpu, ram) = (
        d.first().copied().unwrap_or(0.0),
        d.get(1).copied().unwrap_or(0.0),
        d.get(2).copied().unwrap_or(0.0),
    );
    format!(
        "{},{},{},{},{},{},{},{},{},{},{}",
        r.submit_hours,
        r.tag,
        r.engine.name(),
        cpu,
        gpu,
        ram,
        r.weight,
        r.n_min,
        r.n_max,
        r.baseline_n,
        r.duration_hours
    )
}

/// Lift one synthesized [`WorkloadApp`] (+ its Table-II row) into the
/// schema-independent record — the same demand/weight/width fields
/// `SliceSource` feeds the DES, so export loses nothing the runner sees.
pub fn record_of(rows: &[Table2Row], w: &WorkloadApp) -> TraceRecord {
    let row = &rows[w.row];
    TraceRecord {
        submit_hours: w.submit_hours,
        tag: w.tag.clone(),
        engine: row.engine,
        demand: row.demand.clone(),
        weight: row.weight as f64,
        n_min: row.n_min,
        n_max: row.n_max,
        baseline_n: w.baseline_n,
        duration_hours: w.duration_at_baseline_hours,
        priority: None,
        user: None,
    }
}

/// Stream records out as native CSV.  Works for any iterator, so million
/// -arrival exports never materialize (pair it with
/// [`crate::workload::WorkloadSpec::stream`]).
pub fn write_records<W: Write>(
    out: &mut W,
    records: impl Iterator<Item = TraceRecord>,
) -> io::Result<u64> {
    writeln!(out, "{DORM_HEADER}")?;
    let mut n = 0u64;
    for r in records {
        writeln!(out, "{}", record_line(&r))?;
        n += 1;
    }
    Ok(n)
}

/// Export a materialized synthesized workload.
pub fn export_workload<W: Write>(
    out: &mut W,
    rows: &[Table2Row],
    workload: &[WorkloadApp],
) -> io::Result<u64> {
    write_records(out, workload.iter().map(|w| record_of(rows, w)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use crate::workload::trace::TraceReader;
    use crate::workload::{table2_rows, WorkloadGen};
    use std::io::Cursor;

    #[test]
    fn export_reads_back_bit_equal() {
        let rows = table2_rows();
        let gen = WorkloadGen::default();
        let mut rng = Rng::new(21);
        let wl = gen.generate(&mut rng);
        let mut buf = Vec::new();
        let n = export_workload(&mut buf, &rows, &wl).unwrap();
        assert_eq!(n, wl.len() as u64);
        let reader = TraceReader::new(Cursor::new(&buf)).unwrap();
        let back: Vec<_> = reader.map(|r| r.unwrap()).collect();
        assert_eq!(back.len(), wl.len());
        for (w, r) in wl.iter().zip(&back) {
            let orig = record_of(&rows, w);
            assert_eq!(&orig, r, "round-trip must be lossless");
            // bit-equality of the floats specifically
            assert_eq!(w.submit_hours.to_bits(), r.submit_hours.to_bits());
            assert_eq!(
                w.duration_at_baseline_hours.to_bits(),
                r.duration_hours.to_bits()
            );
        }
    }
}

//! Fault-subsystem integration: master↔sim recovery parity and
//! checkpoint-driven recovery semantics (`crate::fault`, DESIGN.md §8).
//!
//! The key invariant extends `tests/parity.rs` to server churn: on one
//! scripted failure trace, the live `DormMaster` (driven through
//! `fail_server`/`recover_server`) and the DES (`run_sim_faulty`) must
//! produce the *same allocation/recovery sequence* event by event — both
//! run the shared `sched::AllocationEngine`, both reclaim a dead server's
//! capacity the same way, and both drop the engine's capacity-derived
//! caches at the same points (`CmsPolicy::on_capacity_change`).

use std::collections::BTreeMap;

use dorm::app::{AppId, AppSpec, AppState, CheckpointStore, Engine};
use dorm::config::{ClusterConfig, DormConfig, FaultConfig, SimConfig};
use dorm::fault::{FailureEvent, FailureModel};
use dorm::master::DormMaster;
use dorm::resources::Res;
use dorm::sched::{AllocationUpdate, CmsPolicy, DormPolicy, SchedCtx};
use dorm::sim::{run_sim_faulty, PerfModel};
use dorm::workload::{Table2Row, WorkloadApp};

/// One synthetic application type, shared by both backends.
struct Spec {
    demand: Res,
    weight: u32,
    n_min: u32,
    n_max: u32,
    submit_hours: f64,
    duration_at_baseline_hours: f64,
}

fn trace() -> Vec<Spec> {
    vec![
        // grabs the whole cluster, then shrinks as others arrive
        Spec {
            demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0),
            weight: 1,
            n_min: 1,
            n_max: 24,
            submit_hours: 0.0,
            duration_at_baseline_hours: 1.0,
        },
        Spec {
            demand: Res::cpu_gpu_ram(2.0, 0.0, 6.0),
            weight: 2,
            n_min: 1,
            n_max: 24,
            submit_hours: 0.3,
            duration_at_baseline_hours: 2.0,
        },
        Spec {
            demand: Res::cpu_gpu_ram(4.0, 0.0, 6.0),
            weight: 1,
            n_min: 1,
            n_max: 8,
            submit_hours: 0.7,
            duration_at_baseline_hours: 1.5,
        },
    ]
}

/// Server 0 dies mid-run (while partitions are spread over the whole
/// cluster) and rejoins later.
fn failures() -> Vec<FailureEvent> {
    vec![FailureEvent::kill(1.1, 0), FailureEvent::recover(2.5, 0)]
}

fn cluster() -> ClusterConfig {
    ClusterConfig::uniform(4, Res::cpu_gpu_ram(12.0, 0.0, 64.0))
}

const CFG: DormConfig = DormConfig { theta1: 0.3, theta2: 0.34 };

fn store(tag: &str) -> CheckpointStore {
    let d = std::env::temp_dir().join(format!("dorm_fault_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    CheckpointStore::new(d).unwrap()
}

/// Wraps the shared policy and records, after every event, the decided
/// container count of every active app (current count when the policy
/// keeps allocations).  Forwards the capacity-change hook — the DES side
/// must drop the engine caches exactly where the live master does.
struct Recording {
    inner: DormPolicy,
    log: Vec<BTreeMap<AppId, u32>>,
}

impl CmsPolicy for Recording {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn on_change(&mut self, ctx: &SchedCtx) -> Option<AllocationUpdate> {
        let update = self.inner.on_change(ctx);
        let counts: BTreeMap<AppId, u32> = ctx
            .apps
            .values()
            .map(|a| {
                let c = match &update {
                    Some(u) => u
                        .assignment
                        .get(&a.id)
                        .map(|row| row.values().sum())
                        .unwrap_or(0),
                    None => a.containers,
                };
                (a.id, c)
            })
            .collect();
        self.log.push(counts);
        update
    }

    fn on_capacity_change(&mut self) {
        self.inner.on_capacity_change();
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Ev {
    Arrival(usize),
    Completion(usize),
    Kill(usize),
    Recover(usize),
}

#[test]
fn master_and_sim_replay_identical_recovery_sequences() {
    let specs = trace();
    let faults = failures();

    // ---- DES side -------------------------------------------------------
    let rows: Vec<Table2Row> = specs
        .iter()
        .map(|s| Table2Row {
            engine: Engine::MxNet,
            dataset: "synthetic",
            model: "fault",
            demand: s.demand.clone(),
            weight: s.weight,
            n_max: s.n_max,
            n_min: s.n_min,
            num: 1,
            baseline_containers: 8,
            duration_median_hours: s.duration_at_baseline_hours,
        })
        .collect();
    let workload: Vec<WorkloadApp> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| WorkloadApp {
            row: i,
            tag: format!("app{i}"),
            submit_hours: s.submit_hours,
            duration_at_baseline_hours: s.duration_at_baseline_hours,
            baseline_n: 8,
        })
        .collect();
    let sim = SimConfig { horizon_hours: 24.0, ..Default::default() };
    let mut pol = Recording { inner: DormPolicy::new(CFG), log: Vec::new() };
    let out = run_sim_faulty(
        &mut pol,
        &rows,
        &workload,
        &cluster(),
        &sim,
        &PerfModel::default(),
        &faults,
    );
    assert_eq!(out.completed, specs.len(), "trace must fully drain");

    // reconstruct the event order the DES processed: arrivals at their
    // submission times, completions at their simulated times, churn at the
    // scripted times
    let mut events: Vec<(f64, Ev)> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| (s.submit_hours, Ev::Arrival(i)))
        .collect();
    for (id, app) in &out.apps {
        let t = app.completed_at.expect("all apps completed");
        events.push((t, Ev::Completion(id.0 as usize)));
    }
    for f in &faults {
        let ev = match f.kind {
            dorm::fault::FailureKind::Kill => Ev::Kill(f.server),
            dorm::fault::FailureKind::Recover => Ev::Recover(f.server),
            // this parity trace scripts server churn only; master outages
            // have their own coverage in sim::runner + tests/ha.rs
            other => unreachable!("unexpected {other:?} in server-churn trace"),
        };
        events.push((f.time, ev));
    }
    events.sort_by(|a, b| a.0.total_cmp(&b.0));
    assert_eq!(pol.log.len(), events.len(), "one decision per event");

    // sim allocation sequence, by workload index
    let sim_seq: Vec<Vec<u32>> = pol
        .log
        .iter()
        .map(|m| {
            (0..specs.len())
                .map(|i| m.get(&AppId(i as u64)).copied().unwrap_or(0))
                .collect()
        })
        .collect();

    // the failure actually hit someone: at least one app went through a
    // recovery cycle in the DES
    let sim_victims: Vec<u64> = out
        .apps
        .values()
        .filter(|a| a.recoveries > 0)
        .map(|a| a.id.0)
        .collect();
    assert!(!sim_victims.is_empty(), "kill at t=1.1 must break a partition");
    assert!(
        out.metrics.lost_work.last().unwrap_or(0.0) >= 0.0
            && !out.metrics.recovery.points.is_empty(),
        "fault metrics must be emitted"
    );

    // ---- live-master side ----------------------------------------------
    let mut master = DormMaster::new(&cluster(), CFG, store("parity"));
    let mut ids: BTreeMap<usize, AppId> = BTreeMap::new();
    let mut master_seq: Vec<Vec<u32>> = Vec::new();
    for &(_, ev) in &events {
        match ev {
            Ev::Arrival(i) => {
                let s = &specs[i];
                let id = master
                    .submit(AppSpec {
                        executor: Engine::MxNet,
                        demand: s.demand.clone(),
                        weight: s.weight,
                        n_max: s.n_max,
                        n_min: s.n_min,
                        cmd: ["fault".into(), "fault".into()],
                    })
                    .unwrap();
                ids.insert(i, id);
            }
            Ev::Completion(i) => {
                master.complete(ids[&i]).unwrap();
            }
            Ev::Kill(j) => {
                master.fail_server(j).unwrap();
            }
            Ev::Recover(j) => {
                master.recover_server(j).unwrap();
            }
        }
        master_seq.push(
            (0..specs.len())
                .map(|i| ids.get(&i).map(|&id| master.containers_of(id)).unwrap_or(0))
                .collect(),
        );
    }

    // ---- the invariant --------------------------------------------------
    assert_eq!(
        sim_seq, master_seq,
        "live master and DES must produce identical allocation/recovery \
         sequences\nevents: {events:?}"
    );

    // both backends agree on who a server death affected
    let master_victims: Vec<u64> = (0..specs.len())
        .filter(|i| master.app(ids[i]).map_or(0, |a| a.recoveries) > 0)
        .map(|i| ids[&i].0 - 1) // master ids are 1-based submission order
        .collect();
    assert_eq!(master_victims, sim_victims, "same apps recovered");
    assert_eq!(
        master.recovery_log().len(),
        master_victims.len(),
        "one recovery record per victim"
    );
    // nothing may sit on the dead server between kill and recover
    assert!(master.total_recoveries >= 1);
}

/// Acceptance: an app affected by a server death resumes from its latest
/// checkpoint at the newly solved scale, and the reported lost work is
/// exactly the steps since that checkpoint.
#[test]
fn recovery_resumes_from_latest_checkpoint_with_exact_lost_work() {
    let mut master = DormMaster::new(&cluster(), CFG, store("lostwork"));
    let a = master
        .submit(AppSpec {
            executor: Engine::MxNet,
            demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0),
            weight: 1,
            n_max: 24,
            n_min: 1,
            cmd: ["fault".into(), "fault".into()],
        })
        .unwrap();
    assert_eq!(master.containers_of(a), 24, "lone app spans the cluster");

    master.advance_steps(a, 500).unwrap();
    master.checkpoint_app(a).unwrap();
    master.advance_steps(a, 123).unwrap(); // work that the failure will eat

    let victims = master.fail_server(0).unwrap();
    assert_eq!(victims, vec![a]);

    // resumed from the latest checkpoint ...
    let ckpt = master.store().load_latest(a).unwrap().unwrap();
    assert_eq!(ckpt.step, 500);
    assert_eq!(master.steps_of(a), 500, "progress rolled back to the checkpoint");
    assert_eq!(master.app_state(a), Some(AppState::Running));

    // ... at the newly solved scale (3 servers x 12 CPU / 2 CPU demand)
    let held = master.containers_of(a);
    assert_eq!(held, 18, "re-solved scale on the shrunken cluster");
    let rec = &master.recovery_log().records()[0];
    assert_eq!(rec.resumed_scale, held);
    assert_eq!(rec.server, 0);

    // ... and lost work == steps since the checkpoint
    assert_eq!(rec.lost_work, 123.0);
    assert_eq!(master.recovery_log().total_lost_work(), 123.0);
}

/// When the latest checkpoint file is corrupt on disk, failure rollback
/// must land on the newest *restorable* snapshot and the lost-work report
/// must charge the extra distance — the cursor alone is not the truth.
#[test]
fn corrupt_checkpoint_rolls_recovery_back_to_previous_good() {
    let mut master = DormMaster::new(&cluster(), CFG, store("corrupt_roll"));
    let a = master
        .submit(AppSpec {
            executor: Engine::MxNet,
            demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0),
            weight: 1,
            n_max: 24,
            n_min: 1,
            cmd: ["fault".into(), "fault".into()],
        })
        .unwrap();
    master.advance_steps(a, 100).unwrap();
    master.checkpoint_app(a).unwrap(); // step 100, stays good
    master.advance_steps(a, 100).unwrap();
    master.checkpoint_app(a).unwrap(); // step 200, about to rot
    master.advance_steps(a, 50).unwrap(); // steps_done = 250

    // corrupt the newest checkpoint file
    let files = master.store().files_of(a).unwrap();
    let newest = files.last().unwrap();
    let mut bytes = std::fs::read(newest).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x5A;
    std::fs::write(newest, bytes).unwrap();

    let victims = master.fail_server(0).unwrap();
    assert_eq!(victims, vec![a]);
    assert_eq!(
        master.steps_of(a),
        100,
        "rolled back to the newest GOOD snapshot, not the corrupt cursor"
    );
    assert_eq!(master.recovery_log().records()[0].lost_work, 150.0);
    assert_eq!(master.app_state(a), Some(AppState::Running));
    // and what load_latest restores agrees with the rolled-back cursor
    assert_eq!(master.store().load_latest(a).unwrap().unwrap().step, 100);
}

/// Correlated outages (DESIGN.md §14): a scripted whole-rack trace — every
/// server of the rack dying at the *same* timestamp — must replay as ONE
/// batch on both backends.  The DES drains the simultaneous `ServerFail`
/// events into a single capacity invalidation + re-solve, the live
/// master's lease sweep expires the rack through the same batched
/// `fail_servers` path, the two allocation sequences stay identical event
/// for event, and the master charges each victim exactly the steps it ran
/// past its last checkpoint.
#[test]
fn whole_rack_outage_is_one_batch_on_both_backends() {
    let specs = trace();
    // rack A = servers {0,1}, rack B = {2,3}; rack A dies at t=1.1 in one
    // batch and rejoins (server by server) at t=2.5
    let faults = vec![
        FailureEvent::kill(1.1, 0),
        FailureEvent::kill(1.1, 1),
        FailureEvent::recover(2.5, 0),
        FailureEvent::recover(2.5, 1),
    ];

    // ---- DES side -------------------------------------------------------
    let rows: Vec<Table2Row> = specs
        .iter()
        .map(|s| Table2Row {
            engine: Engine::MxNet,
            dataset: "synthetic",
            model: "fault",
            demand: s.demand.clone(),
            weight: s.weight,
            n_max: s.n_max,
            n_min: s.n_min,
            num: 1,
            baseline_containers: 8,
            duration_median_hours: s.duration_at_baseline_hours,
        })
        .collect();
    let workload: Vec<WorkloadApp> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| WorkloadApp {
            row: i,
            tag: format!("app{i}"),
            submit_hours: s.submit_hours,
            duration_at_baseline_hours: s.duration_at_baseline_hours,
            baseline_n: 8,
        })
        .collect();
    let sim = SimConfig { horizon_hours: 24.0, ..Default::default() };
    let mut pol = Recording { inner: DormPolicy::new(CFG), log: Vec::new() };
    let out = run_sim_faulty(
        &mut pol,
        &rows,
        &workload,
        &cluster(),
        &sim,
        &PerfModel::default(),
        &faults,
    );
    assert_eq!(out.completed, specs.len(), "trace must fully drain");

    // logical event order: the two t=1.1 kills are ONE event
    #[derive(Debug, Clone, Copy)]
    enum Rv {
        Arrival(usize),
        Completion(usize),
        RackKill,
        Recover(usize),
    }
    let mut events: Vec<(f64, Rv)> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| (s.submit_hours, Rv::Arrival(i)))
        .collect();
    for (id, app) in &out.apps {
        let t = app.completed_at.expect("all apps completed");
        events.push((t, Rv::Completion(id.0 as usize)));
    }
    events.push((1.1, Rv::RackKill));
    events.push((2.5, Rv::Recover(0)));
    events.push((2.5, Rv::Recover(1)));
    events.sort_by(|a, b| a.0.total_cmp(&b.0));
    // 3 arrivals + 3 completions + 1 batched kill + 2 recoveries = 9
    // decisions; separate per-server re-solves at t=1.1 would make it 10
    assert_eq!(
        pol.log.len(),
        events.len(),
        "a whole-rack kill must cost exactly one re-solve"
    );
    let sim_seq: Vec<Vec<u32>> = pol
        .log
        .iter()
        .map(|m| {
            (0..specs.len())
                .map(|i| m.get(&AppId(i as u64)).copied().unwrap_or(0))
                .collect()
        })
        .collect();

    // ---- live-master side (lease expiry drives the batch) ---------------
    let mut master = DormMaster::new(&cluster(), CFG, store("rack_batch"))
        .with_fault(&FaultConfig { lease_timeout_hours: 1.0, ..Default::default() });
    let mut ids: BTreeMap<usize, AppId> = BTreeMap::new();
    let mut master_seq: Vec<Vec<u32>> = Vec::new();
    // steps each app runs past its last checkpoint before the outage
    let unsynced = |i: usize| 7 * (i as u64 + 1);
    for &(_, ev) in &events {
        match ev {
            Rv::Arrival(i) => {
                let s = &specs[i];
                let id = master
                    .submit(AppSpec {
                        executor: Engine::MxNet,
                        demand: s.demand.clone(),
                        weight: s.weight,
                        n_max: s.n_max,
                        n_min: s.n_min,
                        cmd: ["fault".into(), "fault".into()],
                    })
                    .unwrap();
                ids.insert(i, id);
            }
            Rv::Completion(i) => {
                master.complete(ids[&i]).unwrap();
            }
            Rv::RackKill => {
                // known progress + an uncheckpointed tail per running app,
                // so the lost-work accounting below is exact
                for (&i, &id) in &ids {
                    if master.app_state(id) == Some(AppState::Running) {
                        master.advance_steps(id, 100).unwrap();
                        master.checkpoint_app(id).unwrap();
                        master.advance_steps(id, unsynced(i)).unwrap();
                    }
                }
                // rack B renews; rack A has been silent since t=0
                master.heartbeat(2, 1.0).unwrap();
                master.heartbeat(3, 1.0).unwrap();
                let dead = master.expire_leases(1.1).unwrap();
                assert_eq!(dead, vec![0, 1], "rack A expires as one batch");
            }
            Rv::Recover(j) => {
                master.recover_server_at(j, 2.5).unwrap();
            }
        }
        master_seq.push(
            (0..specs.len())
                .map(|i| ids.get(&i).map(|&id| master.containers_of(id)).unwrap_or(0))
                .collect(),
        );
    }

    // ---- the invariants -------------------------------------------------
    assert_eq!(
        sim_seq, master_seq,
        "whole-rack outage: master and DES allocation sequences diverged\n\
         events: {events:?}"
    );

    let recs = master.recovery_log().records();
    assert!(!recs.is_empty(), "the outage must break at least one app");
    let t0 = recs[0].failed_at;
    for r in recs {
        assert_eq!(r.failed_at, t0, "one batch ⇒ one failure timestamp");
        // master ids are 1-based submission order = workload index + 1
        let i = (r.app.0 - 1) as usize;
        assert_eq!(
            r.lost_work,
            unsynced(i) as f64,
            "lost work must equal the steps since {:?}'s checkpoint",
            r.app
        );
    }
    // both backends agree on who the rack outage hit
    let mut sim_victims: Vec<u64> = out
        .apps
        .values()
        .filter(|a| a.recoveries > 0)
        .map(|a| a.id.0)
        .collect();
    let mut master_victims: Vec<u64> = recs.iter().map(|r| r.app.0 - 1).collect();
    sim_victims.sort_unstable();
    master_victims.sort_unstable();
    assert_eq!(master_victims, sim_victims, "same victims on both backends");
}

/// A scripted exponential model and the scripted trace drive the same
/// machinery: the DES under generated churn keeps its invariants and
/// emits the recovery metrics.
#[test]
fn generated_churn_trace_drives_the_sim() {
    let specs = trace();
    let rows: Vec<Table2Row> = specs
        .iter()
        .map(|s| Table2Row {
            engine: Engine::MxNet,
            dataset: "synthetic",
            model: "fault",
            demand: s.demand.clone(),
            weight: s.weight,
            n_max: s.n_max,
            n_min: s.n_min,
            num: 1,
            baseline_containers: 8,
            duration_median_hours: s.duration_at_baseline_hours,
        })
        .collect();
    let workload: Vec<WorkloadApp> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| WorkloadApp {
            row: i,
            tag: format!("app{i}"),
            submit_hours: s.submit_hours,
            duration_at_baseline_hours: s.duration_at_baseline_hours,
            baseline_n: 8,
        })
        .collect();
    let model = FailureModel::Exponential { mtbf_hours: 3.0, mttr_hours: 0.5, seed: 41 };
    let faults = model.trace(4, 24.0).unwrap();
    assert!(!faults.is_empty());
    let sim = SimConfig { horizon_hours: 24.0, ..Default::default() };
    let mut pol = DormPolicy::new(CFG);
    let out = run_sim_faulty(
        &mut pol,
        &rows,
        &workload,
        &cluster(),
        &sim,
        &PerfModel { ckpt_period_hours: 0.25, ..Default::default() },
        &faults,
    );
    // under 3h-MTBF churn with periodic checkpoints the workload still
    // drains (24h horizon vs ~4.5h of work)
    assert!(out.completed >= 2, "completed {}", out.completed);
    assert!(out.metrics.utilization.max() <= 3.0 + 1e-9);
    let lost = out.metrics.lost_work.last().unwrap_or(0.0);
    assert!(lost >= 0.0);
    for app in out.apps.values() {
        assert!(
            app.work_remaining >= 0.0 && app.work_remaining.is_finite(),
            "work_remaining went bad: {}",
            app.work_remaining
        );
    }
}

//! Dorm as a simulation policy: the utilization–fairness optimizer driving
//! the dynamically-partitioned mechanism (§III + §IV) inside the DES.
//!
//! On every arrival/completion the policy rebuilds the optimizer input from
//! the live cluster state and asks for a new allocation.  If P2 is
//! infeasible with every pending app admitted (the Σ n_min floors can
//! exceed capacity), pending apps are deferred newest-first and the solve
//! retried — "Dorm would keep existing resource allocations until more
//! running applications finish" (§IV-B).

use crate::config::DormConfig;
use crate::optimizer::{OptApp, Optimizer, SolveMode};

use super::runner::{AllocationUpdate, CmsPolicy, SimCtx};

/// Dorm under simulation.
#[derive(Debug)]
pub struct DormPolicy {
    pub optimizer: Optimizer,
    label: String,
}

impl DormPolicy {
    pub fn new(cfg: DormConfig) -> Self {
        Self::with_mode(cfg, SolveMode::Heuristic)
    }

    pub fn with_mode(cfg: DormConfig, mode: SolveMode) -> Self {
        DormPolicy {
            label: format!("dorm(t1={},t2={})", cfg.theta1, cfg.theta2),
            optimizer: Optimizer::with_mode(cfg, mode),
        }
    }
}

impl CmsPolicy for DormPolicy {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn on_change(&mut self, ctx: &SimCtx) -> Option<AllocationUpdate> {
        let capacities: Vec<_> = ctx
            .cluster
            .servers
            .iter()
            .map(|s| s.capacity.clone())
            .collect();

        // running first, then pending in submission order — the deferral
        // order drops the *newest* pending app first
        let mut running: Vec<OptApp> = Vec::new();
        let mut pending: Vec<OptApp> = Vec::new();
        let mut pending_order: Vec<(f64, usize)> = Vec::new();
        for app in ctx.apps.values() {
            let opt = OptApp {
                id: app.id,
                demand: app.demand.clone(),
                weight: app.weight,
                n_min: app.n_min,
                n_max: app.n_max,
                prev: (app.containers > 0).then_some(app.containers),
                current: ctx.cluster.placement_of(app.id),
            };
            if app.containers > 0 {
                running.push(opt);
            } else {
                pending_order.push((app.submit, pending.len()));
                pending.push(opt);
            }
        }
        pending_order.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let ordered_pending: Vec<OptApp> = pending_order
            .iter()
            .map(|&(_, i)| pending[i].clone())
            .collect();

        // admit as many pending apps (FIFO) as stay feasible
        for admit in (0..=ordered_pending.len()).rev() {
            let mut apps = running.clone();
            apps.extend(ordered_pending[..admit].iter().cloned());
            if let Some(decision) = self.optimizer.allocate(&apps, &capacities) {
                return Some(AllocationUpdate {
                    assignment: decision.placement.assignment,
                    adjusted: decision.adjusted,
                });
            }
        }
        None // keep existing allocations
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, SimConfig};
    use crate::sim::{run_sim, PerfModel};
    use crate::workload::{table2_rows, WorkloadApp};

    fn lr(submit: f64, dur: f64) -> WorkloadApp {
        WorkloadApp {
            row: 0,
            tag: "LR".into(),
            submit_hours: submit,
            duration_at_baseline_hours: dur,
            baseline_n: 8,
        }
    }

    #[test]
    fn lone_app_scales_beyond_baseline_and_finishes_faster() {
        let rows = table2_rows();
        let wl = vec![lr(0.0, 4.0)]; // 4h at 8 containers
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 10.0, ..Default::default() };
        let pm = PerfModel::default();
        let mut pol = DormPolicy::new(DormConfig::DORM3);
        let out = run_sim(&mut pol, &rows, &wl, &cfg, &sim, &pm);
        assert_eq!(out.completed, 1);
        let dur = out.metrics.completions[0].1;
        // LR n_max = 32: Dorm runs it at 32 containers
        let expect = 4.0 / pm.speedup(32, 8);
        assert!((dur - expect).abs() < 0.05, "dur {dur} vs expected {expect}");
        assert!(dur < 4.0 * 0.6, "should be much faster than baseline");
    }

    #[test]
    fn scale_down_on_arrival_counts_as_adjustment() {
        let rows = table2_rows();
        // 5 LR apps arriving faster than they finish: CPU capacity holds
        // 120 containers, so by the 4th arrival the earlier apps (at
        // n_max = 32) must be scaled down.
        let wl: Vec<WorkloadApp> = (0..5).map(|i| lr(i as f64 * 0.5, 8.0)).collect();
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 12.0, ..Default::default() };
        let pm = PerfModel::default();
        let mut pol = DormPolicy::new(DormConfig::DORM1);
        let out = run_sim(&mut pol, &rows, &wl, &cfg, &sim, &pm);
        assert_eq!(out.completed, 5);
        // earlier apps were scaled down as later ones arrived
        assert!(out.metrics.adjustments.last().unwrap() >= 1.0);
    }

    #[test]
    fn fairness_loss_bounded_by_theta1() {
        let rows = table2_rows();
        let wl: Vec<WorkloadApp> = (0..6).map(|i| lr(i as f64 * 0.3, 6.0)).collect();
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 8.0, ..Default::default() };
        let mut pol = DormPolicy::new(DormConfig::DORM3);
        let out = run_sim(&mut pol, &rows, &wl, &cfg, &sim, &PerfModel::default());
        // Eq. 15 bound: ceil(0.1 * 2 * 3) = 1... but transient samples right
        // after arrival (before the next solve lands) may exceed; the
        // *decision-time* bound is ceil(theta1 * 2m) = 1. Allow transients.
        let bound = (0.1f64 * 6.0).ceil();
        let viol = out
            .metrics
            .fairness_loss
            .points
            .iter()
            .filter(|&&(_, v)| v > bound + 1e-6)
            .count();
        let frac = viol as f64 / out.metrics.fairness_loss.points.len() as f64;
        assert!(frac < 0.35, "fairness bound violated in {frac} of samples");
    }
}

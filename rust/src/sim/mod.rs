//! Discrete-event simulator of the paper's testbed experiments (§V).
//!
//! The 24-hour evaluations (Figs 6–9) are functions of the allocator and
//! the workload, not of the hardware (DESIGN.md §1), so they run here in
//! simulated time: the same [`crate::optimizer`] the live master uses makes
//! every decision, the same [`crate::cluster::ClusterState`] bookkeeping
//! tracks placements, and the same [`crate::metrics`] series are sampled.
//!
//! * [`engine`] — the event queue (time-ordered heap with cancellation).
//! * [`perf_model`] — iterative-training progress: speedup vs container
//!   count, checkpoint/kill/resume pauses.
//! * [`runner`] — drives a [`CmsPolicy`] over a workload and collects
//!   [`crate::metrics::RunMetrics`]; policies are Dorm (θ-configured) and
//!   the baselines in [`crate::baselines`].

pub mod dorm_policy;
pub mod engine;
pub mod experiment;
pub mod perf_model;
pub mod runner;

pub use dorm_policy::DormPolicy;
pub use experiment::{fairness_reduction, headline_over_seeds, matched_speedups, mean_speedup, speedup_by_tag, utilization_ratio, Experiment, SystemRun};
pub use engine::{EventQueue, SimTime};
pub use perf_model::PerfModel;
pub use runner::{run_sim, AllocationUpdate, CmsPolicy, SimApp, SimCtx, SimOutcome};

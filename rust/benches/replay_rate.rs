//! Trace-replay throughput (DESIGN.md §13): how fast recorded arrivals
//! move through the two replay paths.
//!
//! * **DES streaming throughput** — closed-loop replay of a generated
//!   trace through the simulator behind the bounded [`TraceSource`]
//!   buffer; reports arrivals/sec of wall time and the buffer high-water
//!   mark (the O(buffer) guarantee, asserted here too).
//! * **Live rate sweep** — the `dorm replay --mode sweep` measurement:
//!   offered arrivals/sec ramped against a fresh in-process master until
//!   admission saturates, reporting scaling efficiency and per-phase
//!   submit latency percentiles.
//!
//! Set `DORM_SCHED_SCALE=ci` for the reduced sweep and
//! `DORM_BENCH_JSON=<path>` to splice a `"replay"` series into
//! `BENCH_sched.json` (`scripts/bench_sched.sh` wires both; the
//! `sched_latency` bench runs first and writes the file whole, this bench
//! re-reads it and replaces only its own series).

#[path = "harness/mod.rs"]
mod harness;

use std::time::Instant;

use anyhow::Result;
use dorm::app::{CheckpointStore, Engine};
use dorm::baselines::StaticPolicy;
use dorm::config::{ClusterConfig, DormConfig, SimConfig};
use dorm::master::DormMaster;
use dorm::net::{ControlPlane, LocalTransport};
use dorm::report;
use dorm::resources::Res;
use dorm::sim::PerfModel;
use dorm::workload::trace::{rate_sweep, replay_des, RatePoint, ReplayOpts, TraceRecord};

fn ci_scale() -> bool {
    matches!(std::env::var("DORM_SCHED_SCALE").as_deref(), Ok("ci"))
}

/// Uniform tiny jobs: replay throughput is about the event loop and the
/// control plane, not about how long the recorded jobs trained for.
fn flat_records(n: usize) -> Vec<TraceRecord> {
    (0..n)
        .map(|i| TraceRecord {
            submit_hours: 0.0,
            tag: format!("j{i}"),
            engine: Engine::MxNet,
            demand: Res::cpu_gpu_ram(1.0, 0.0, 1.0),
            weight: 1.0,
            n_min: 1,
            n_max: 1,
            baseline_n: 1,
            duration_hours: 0.001,
            priority: None,
            user: None,
        })
        .collect()
}

fn des_throughput() -> f64 {
    harness::banner("DES streaming throughput — closed-loop replay");
    let n: usize = if ci_scale() { 20_000 } else { 100_000 };
    let rate_per_hour = 50_000.0;
    let cluster = ClusterConfig::uniform(4, Res::cpu_gpu_ram(16.0, 0.0, 64.0));
    let sim = SimConfig {
        horizon_hours: n as f64 / rate_per_hour + 1.0,
        sample_period_min: 60.0,
        ..Default::default()
    };
    let mut pol = StaticPolicy::new();
    let buffer = 256;
    let t0 = Instant::now();
    let rep = replay_des(
        &mut pol,
        flat_records(n).into_iter().map(Ok),
        ReplayOpts { buffer, rate_per_hour, ..Default::default() },
        &cluster,
        &sim,
        &PerfModel::default(),
    )
    .expect("clean generated trace");
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(rep.records_read, n as u64);
    assert!(rep.max_buffered <= buffer, "O(buffer) guarantee: {}", rep.max_buffered);
    let per_sec = n as f64 / wall.max(1e-9);
    println!(
        "  {n} arrivals in {wall:.2} s -> {per_sec:.0} arrivals/s \
         (buffer high-water {} of {buffer}, {} completed)",
        rep.max_buffered, rep.outcome.completed
    );
    per_sec
}

fn bench_store(tag: &str) -> CheckpointStore {
    let d = std::env::temp_dir().join(format!("dorm_replay_bench_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    CheckpointStore::new(d).expect("temp checkpoint store")
}

fn live_sweep() -> Vec<RatePoint> {
    harness::banner("live rate sweep — offered arrivals/sec vs admission");
    let (rates, per_rate): (Vec<f64>, usize) = if ci_scale() {
        (vec![200.0, 1_000.0, 5_000.0], 60)
    } else {
        (vec![100.0, 400.0, 1_600.0, 6_400.0, 25_600.0], 200)
    };
    let cluster = ClusterConfig::uniform(8, Res::cpu_gpu_ram(16.0, 0.0, 64.0));
    let mut n = 0usize;
    let mut mk = || -> Result<Box<dyn ControlPlane>> {
        n += 1;
        let store = bench_store(&format!("r{n}"));
        Ok(Box::new(LocalTransport::new(DormMaster::new(
            &cluster,
            DormConfig::DORM3,
            store,
        ))))
    };
    let pool = flat_records(per_rate);
    let mut recs = |_rate: f64| pool.clone();
    let points = rate_sweep(&mut mk, &mut recs, &rates, 16, 0.0).expect("sweep");

    let mut rows = Vec::new();
    for p in &points {
        assert!(p.efficiency > 0.0 && p.efficiency <= 1.0, "{p:?}");
        assert!(p.p99_submit_us >= p.p50_submit_us, "{p:?}");
        rows.push(vec![
            format!("{:.0}", p.offered_per_sec),
            format!("{:.0}", p.achieved_per_sec),
            format!("{:.2}", p.efficiency),
            format!("{:.0}", p.p50_submit_us),
            format!("{:.0}", p.p99_submit_us),
            p.rejected.to_string(),
        ]);
    }
    println!(
        "{}",
        report::table(
            &["offered/s", "achieved/s", "efficiency", "p50 submit (us)", "p99", "rejected"],
            &rows
        )
    );
    // the lowest offered rate must be comfortably sustainable on any box
    assert!(
        points[0].efficiency > 0.3,
        "master cannot keep up with {} arrivals/s: {:?}",
        points[0].offered_per_sec,
        points[0]
    );
    let knee = points.iter().find(|p| p.efficiency < 0.9);
    match knee {
        Some(p) => println!("  admission knee: efficiency {:.2} at {:.0}/s", p.efficiency, p.offered_per_sec),
        None => println!("  no saturation up to {:.0}/s", points.last().unwrap().offered_per_sec),
    }
    points
}

/// Splice the `"replay"` series into the `BENCH_sched.json` the
/// `sched_latency` bench already wrote (or start a fresh document).
fn write_json(path: &str, des_per_sec: f64, points: &[RatePoint]) {
    let mut text = std::fs::read_to_string(path)
        .unwrap_or_else(|_| "{\n  \"bench\": \"sched_latency_churn\"\n}\n".to_string());
    if let Some(i) = text.find(",\n  \"replay\"") {
        // a previous replay splice: drop it and close the object again
        text.truncate(i);
        text.push_str("\n}\n");
    }
    let end = match text.rfind('}') {
        Some(e) => e,
        None => {
            eprintln!("  {path} is not a JSON object; skipping splice");
            return;
        }
    };
    let mut out = text[..end].trim_end().to_string();
    let frags: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                concat!(
                    "    {{\"rate_per_sec\": {:.1}, \"achieved_per_sec\": {:.1}, ",
                    "\"efficiency\": {:.3}, \"p50_submit_us\": {:.1}, ",
                    "\"p99_submit_us\": {:.1}, \"rejected\": {}}}"
                ),
                p.offered_per_sec,
                p.achieved_per_sec,
                p.efficiency,
                p.p50_submit_us,
                p.p99_submit_us,
                p.rejected
            )
        })
        .collect();
    out.push_str(&format!(
        ",\n  \"replay\": {{\n    \"des_arrivals_per_sec\": {:.1},\n    \"rates\": [\n{}\n    ]\n  }}\n}}\n",
        des_per_sec,
        frags.join(",\n")
    ));
    std::fs::write(path, out).expect("write BENCH json");
    println!("  spliced replay series into {path}");
}

fn main() {
    let des_per_sec = des_throughput();
    let points = live_sweep();
    if let Ok(path) = std::env::var("DORM_BENCH_JSON") {
        write_json(&path, des_per_sec, &points);
    }
    harness::paper_row(
        "trace replay (streaming, O(buffer) memory)",
        "n/a (new in this repo)",
        &format!("{des_per_sec:.0} DES arrivals/s"),
    );
}

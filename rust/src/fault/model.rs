//! Failure injection: when and which servers die and come back.
//!
//! Three generators behind one interface: per-server exponential MTBF/MTTR
//! (the standard machine-churn model, deterministic via
//! [`crate::util::Rng`]), correlated domain outages layered on top of that
//! churn (whole racks die in one batch — [`FailureModel::Correlated`]),
//! and scripted traces (tests, replay, the master↔sim parity suite).  A
//! trace is a time-sorted list of [`FailureEvent`]s the DES feeds into its
//! event queue and a live-master harness replays through
//! `fail_server`/`recover_server` — same-timestamp kills ride the batched
//! lease-expiry path on both backends (one re-solve per batch).
//!
//! Model parameters are validated with typed [`FaultError`]s (not
//! asserts), so a hostile `[fault]` section fails cleanly from the CLI.

use crate::util::Rng;

/// A `[fault]`/`[fault.domains]` parameter violation — typed so config
/// ingestion and trace generation fail cleanly instead of panicking.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultError {
    /// The field must be strictly positive (and finite).
    NonPositive { field: String, got: f64 },
    /// The field must be non-negative (and finite).
    Negative { field: String, got: f64 },
    /// The field must be at least `min`.
    BelowMin { field: String, got: f64, min: f64 },
}

impl std::fmt::Display for FaultError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultError::NonPositive { field, got } => {
                write!(f, "{field} must be > 0 and finite, got {got}")
            }
            FaultError::Negative { field, got } => {
                write!(f, "{field} must be >= 0 and finite, got {got}")
            }
            FaultError::BelowMin { field, got, min } => {
                write!(f, "{field} must be >= {min}, got {got}")
            }
        }
    }
}

impl std::error::Error for FaultError {}

/// `field > 0` and finite, or a typed error.
pub(crate) fn require_positive(field: &str, got: f64) -> Result<(), FaultError> {
    if got > 0.0 && got.is_finite() {
        Ok(())
    } else {
        Err(FaultError::NonPositive { field: field.to_string(), got })
    }
}

/// `field >= 0` and finite, or a typed error.
pub(crate) fn require_non_negative(field: &str, got: f64) -> Result<(), FaultError> {
    if got >= 0.0 && got.is_finite() {
        Ok(())
    } else {
        Err(FaultError::Negative { field: field.to_string(), got })
    }
}

/// `field >= min`, or a typed error.
pub(crate) fn require_at_least(field: &str, got: f64, min: f64) -> Result<(), FaultError> {
    if got >= min && got.is_finite() {
        Ok(())
    } else {
        Err(FaultError::BelowMin { field: field.to_string(), got, min })
    }
}

/// A server goes down or comes back — or the *master* does (control-plane
/// failover, DESIGN.md §11).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FailureKind {
    /// The server dies: capacity and containers are lost.
    Kill,
    /// The server rejoins with its original capacity (empty).
    Recover,
    /// The CMS master dies.  Running partitions keep computing (§III-D:
    /// apps launch tasks locally), but no allocation decisions happen
    /// until a standby takes over.
    MasterKill,
    /// A standby master finished taking over; deferred allocation work
    /// (arrivals, completions, failures seen during the outage) is
    /// reconciled in one catch-up solve.
    MasterRecover,
}

impl FailureKind {
    /// Does this event name a specific server (vs the master)?
    pub fn is_server_event(self) -> bool {
        matches!(self, FailureKind::Kill | FailureKind::Recover)
    }
}

/// One churn event in a trace.
#[derive(Clone, Debug, PartialEq)]
pub struct FailureEvent {
    /// Hours from run start.
    pub time: f64,
    /// Server index (`crate::cluster::ServerId` ordinate); meaningless
    /// (`usize::MAX`) for master events.
    pub server: usize,
    pub kind: FailureKind,
}

impl FailureEvent {
    pub fn kill(time: f64, server: usize) -> Self {
        FailureEvent { time, server, kind: FailureKind::Kill }
    }

    pub fn recover(time: f64, server: usize) -> Self {
        FailureEvent { time, server, kind: FailureKind::Recover }
    }

    /// The CMS master dies at `time`.
    pub fn master_kill(time: f64) -> Self {
        FailureEvent { time, server: usize::MAX, kind: FailureKind::MasterKill }
    }

    /// A standby finishes taking over at `time`.
    pub fn master_recover(time: f64) -> Self {
        FailureEvent { time, server: usize::MAX, kind: FailureKind::MasterRecover }
    }
}

/// Trace generator.
#[derive(Clone, Debug)]
pub enum FailureModel {
    /// No churn (the paper's implicit assumption).
    None,
    /// Each server independently alternates up-time ~ Exp(mtbf) and
    /// down-time ~ Exp(mttr).  Deterministic for a given seed; each server
    /// draws from its own forked stream so traces are stable under
    /// cluster-size changes.
    Exponential { mtbf_hours: f64, mttr_hours: f64, seed: u64 },
    /// Independent per-server churn *plus* correlated rack outages: the
    /// servers are grouped into contiguous racks of `domain_size`, and
    /// each rack alternates up-time ~ Exp(domain MTBF) and down-time ~
    /// Exp(domain MTTR), every member dying (and later rejoining) at the
    /// same timestamp — one batch through the master's lease-expiry path
    /// and the DES's same-time fail handler.  Rack 0 fails `hot_factor`
    /// times more often than the rest (heterogeneous domain reliability —
    /// the flaky power feed every real cluster has), which is what gives
    /// an online risk estimator something to learn.
    Correlated {
        server_mtbf_hours: f64,
        server_mttr_hours: f64,
        domain_size: usize,
        domain_mtbf_hours: f64,
        domain_mttr_hours: f64,
        hot_factor: f64,
        seed: u64,
    },
    /// Replay exactly these events (times need not be sorted).
    Scripted(Vec<FailureEvent>),
}

impl FailureModel {
    /// The model a `[fault]` config section asks for: correlated churn
    /// when `[fault.domains]` is enabled, plain exponential churn when
    /// only `[fault]` is, [`FailureModel::None`] otherwise.
    pub fn from_config(cfg: &crate::config::FaultConfig) -> FailureModel {
        if !cfg.enabled {
            return FailureModel::None;
        }
        if cfg.domains.enabled {
            return FailureModel::Correlated {
                server_mtbf_hours: cfg.mtbf_hours,
                server_mttr_hours: cfg.mttr_hours,
                domain_size: cfg.domains.domain_size,
                domain_mtbf_hours: cfg.domains.domain_mtbf_hours,
                domain_mttr_hours: cfg.domains.domain_mttr_hours,
                hot_factor: cfg.domains.hot_factor,
                seed: cfg.seed,
            };
        }
        FailureModel::Exponential {
            mtbf_hours: cfg.mtbf_hours,
            mttr_hours: cfg.mttr_hours,
            seed: cfg.seed,
        }
    }

    /// Validate the model's parameters (the checks that used to be
    /// `assert!`s in [`FailureModel::trace`]).
    pub fn validate(&self) -> Result<(), FaultError> {
        match self {
            FailureModel::None | FailureModel::Scripted(_) => Ok(()),
            FailureModel::Exponential { mtbf_hours, mttr_hours, .. } => {
                require_positive("[fault].mtbf_hours", *mtbf_hours)?;
                require_non_negative("[fault].mttr_hours", *mttr_hours)
            }
            FailureModel::Correlated {
                server_mtbf_hours,
                server_mttr_hours,
                domain_size,
                domain_mtbf_hours,
                domain_mttr_hours,
                hot_factor,
                ..
            } => {
                require_positive("[fault].mtbf_hours", *server_mtbf_hours)?;
                require_non_negative("[fault].mttr_hours", *server_mttr_hours)?;
                require_at_least("[fault.domains].domain_size", *domain_size as f64, 1.0)?;
                require_positive("[fault.domains].domain_mtbf_hours", *domain_mtbf_hours)?;
                require_non_negative(
                    "[fault.domains].domain_mttr_hours",
                    *domain_mttr_hours,
                )?;
                require_at_least("[fault.domains].hot_factor", *hot_factor, 1.0)
            }
        }
    }

    /// Independent per-server alternating kill/recover events — the
    /// shared core of [`FailureModel::Exponential`] and the churn half of
    /// [`FailureModel::Correlated`].
    fn server_churn(
        events: &mut Vec<FailureEvent>,
        n_servers: usize,
        horizon_hours: f64,
        mtbf_hours: f64,
        mttr_hours: f64,
        seed: u64,
    ) {
        let mut base = Rng::new(seed ^ 0xFA17_70DE);
        for server in 0..n_servers {
            let mut rng = base.fork(server as u64 + 1);
            let mut t = rng.exponential(mtbf_hours);
            while t <= horizon_hours {
                events.push(FailureEvent::kill(t, server));
                t += rng.exponential(mttr_hours.max(1e-6));
                if t > horizon_hours {
                    break;
                }
                events.push(FailureEvent::recover(t, server));
                t += rng.exponential(mtbf_hours);
            }
        }
    }

    /// Materialize the trace for `n_servers` over `[0, horizon_hours]`,
    /// sorted by (time, server) — so a rack batch is a run of consecutive
    /// same-time events.  Scripted events outside the horizon or naming
    /// unknown servers are dropped.  Invalid parameters return a typed
    /// [`FaultError`] instead of panicking.
    pub fn trace(
        &self,
        n_servers: usize,
        horizon_hours: f64,
    ) -> Result<Vec<FailureEvent>, FaultError> {
        self.validate()?;
        let mut out = match self {
            FailureModel::None => Vec::new(),
            FailureModel::Scripted(events) => events
                .iter()
                .filter(|e| {
                    e.time <= horizon_hours
                        && (!e.kind.is_server_event() || e.server < n_servers)
                })
                .cloned()
                .collect(),
            FailureModel::Exponential { mtbf_hours, mttr_hours, seed } => {
                let mut events = Vec::new();
                Self::server_churn(
                    &mut events,
                    n_servers,
                    horizon_hours,
                    *mtbf_hours,
                    *mttr_hours,
                    *seed,
                );
                events
            }
            FailureModel::Correlated {
                server_mtbf_hours,
                server_mttr_hours,
                domain_size,
                domain_mtbf_hours,
                domain_mttr_hours,
                hot_factor,
                seed,
            } => {
                let mut events = Vec::new();
                // same forks as Exponential: the independent component of
                // a correlated trace matches the plain trace bit-for-bit,
                // so sweeps compare like against like
                Self::server_churn(
                    &mut events,
                    n_servers,
                    horizon_hours,
                    *server_mtbf_hours,
                    *server_mttr_hours,
                    *seed,
                );
                let topo = super::domains::DomainTopology::grouped(
                    n_servers,
                    *domain_size,
                    usize::MAX,
                );
                let mut base = Rng::new(seed ^ 0xD0_3417_D00D);
                for r in 0..topo.n_racks() {
                    let members = topo.rack_members(r);
                    // rack-index fork offset past the per-server streams
                    let mut rng = base.fork(n_servers as u64 + r as u64 + 1);
                    let eff_mtbf = if r == 0 {
                        domain_mtbf_hours / hot_factor
                    } else {
                        *domain_mtbf_hours
                    };
                    let mut t = rng.exponential(eff_mtbf);
                    while t <= horizon_hours {
                        for &j in &members {
                            events.push(FailureEvent::kill(t, j));
                        }
                        let back = t + rng.exponential(domain_mttr_hours.max(1e-6));
                        if back > horizon_hours {
                            break;
                        }
                        for &j in &members {
                            events.push(FailureEvent::recover(back, j));
                        }
                        t = back + rng.exponential(eff_mtbf);
                    }
                }
                events
            }
        };
        out.sort_by(|a, b| a.time.total_cmp(&b.time).then(a.server.cmp(&b.server)));
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponential_trace_is_deterministic_and_alternating() {
        let m = FailureModel::Exponential { mtbf_hours: 2.0, mttr_hours: 0.5, seed: 7 };
        let a = m.trace(5, 100.0).unwrap();
        let b = m.trace(5, 100.0).unwrap();
        assert_eq!(a, b, "same seed must replay identically");
        assert!(!a.is_empty(), "2h MTBF over 100h must produce failures");
        // per server: strictly alternating Kill / Recover, times increasing
        for j in 0..5 {
            let evs: Vec<&FailureEvent> = a.iter().filter(|e| e.server == j).collect();
            for (i, e) in evs.iter().enumerate() {
                let want = if i % 2 == 0 { FailureKind::Kill } else { FailureKind::Recover };
                assert_eq!(e.kind, want, "server {j} event {i}");
                if i > 0 {
                    assert!(e.time >= evs[i - 1].time);
                }
            }
        }
        // globally time-sorted
        for w in a.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
    }

    #[test]
    fn exponential_rates_roughly_match_mtbf() {
        let m = FailureModel::Exponential { mtbf_hours: 10.0, mttr_hours: 1.0, seed: 3 };
        let trace = m.trace(20, 1000.0).unwrap();
        let kills = trace.iter().filter(|e| e.kind == FailureKind::Kill).count();
        // each server is up ~10/11 of the time -> ~91 kills per server per
        // 1000h/11h cycle; loose 2x bounds on the aggregate
        let expected = 20.0 * 1000.0 / 11.0;
        assert!(
            (kills as f64) > expected * 0.5 && (kills as f64) < expected * 2.0,
            "kills {kills} vs expected ~{expected:.0}"
        );
    }

    #[test]
    fn from_config_respects_the_enabled_switch() {
        use crate::config::FaultConfig;
        let off = FaultConfig::default();
        assert!(FailureModel::from_config(&off).trace(8, 100.0).unwrap().is_empty());
        let on = FaultConfig {
            enabled: true,
            mtbf_hours: 4.0,
            mttr_hours: 0.5,
            seed: 9,
            ..Default::default()
        };
        let t = FailureModel::from_config(&on).trace(8, 100.0).unwrap();
        assert!(!t.is_empty());
        // same knobs, same trace (seed flows through)
        assert_eq!(
            t,
            FailureModel::Exponential { mtbf_hours: 4.0, mttr_hours: 0.5, seed: 9 }
                .trace(8, 100.0)
                .unwrap()
        );
    }

    #[test]
    fn scripted_trace_filters_and_sorts() {
        let m = FailureModel::Scripted(vec![
            FailureEvent::recover(5.0, 1),
            FailureEvent::kill(1.0, 1),
            FailureEvent::kill(2.0, 9), // unknown server: dropped
            FailureEvent::kill(99.0, 0), // past horizon: dropped
        ]);
        let t = m.trace(4, 10.0).unwrap();
        assert_eq!(t, vec![FailureEvent::kill(1.0, 1), FailureEvent::recover(5.0, 1)]);
        assert!(FailureModel::None.trace(4, 10.0).unwrap().is_empty());
    }

    #[test]
    fn invalid_parameters_are_typed_errors_not_panics() {
        let bad_mtbf = FailureModel::Exponential { mtbf_hours: 0.0, mttr_hours: 0.5, seed: 1 };
        match bad_mtbf.trace(4, 10.0) {
            Err(FaultError::NonPositive { field, got }) => {
                assert_eq!(field, "[fault].mtbf_hours");
                assert_eq!(got, 0.0);
            }
            other => panic!("expected NonPositive, got {other:?}"),
        }
        let bad_mttr = FailureModel::Exponential { mtbf_hours: 2.0, mttr_hours: -1.0, seed: 1 };
        assert!(matches!(bad_mttr.trace(4, 10.0), Err(FaultError::Negative { .. })));
        let bad_hot = FailureModel::Correlated {
            server_mtbf_hours: 100.0,
            server_mttr_hours: 0.5,
            domain_size: 4,
            domain_mtbf_hours: 8.0,
            domain_mttr_hours: 0.5,
            hot_factor: 0.5,
            seed: 1,
        };
        assert!(matches!(bad_hot.trace(8, 10.0), Err(FaultError::BelowMin { .. })));
        // the Display impl names the offending field
        let msg = bad_hot.validate().unwrap_err().to_string();
        assert!(msg.contains("[fault.domains].hot_factor"), "{msg}");
    }

    #[test]
    fn correlated_trace_batches_whole_racks_at_one_timestamp() {
        let m = FailureModel::Correlated {
            server_mtbf_hours: 1e9, // effectively no independent churn
            server_mttr_hours: 0.5,
            domain_size: 4,
            domain_mtbf_hours: 10.0,
            domain_mttr_hours: 0.5,
            hot_factor: 1.0,
            seed: 11,
        };
        let t = m.trace(8, 200.0).unwrap();
        assert!(!t.is_empty(), "10h domain MTBF over 200h must fire");
        // every kill timestamp covers a whole rack: exactly 4 events,
        // consecutive in the sorted trace, servers = one rack's members
        let mut i = 0;
        while i < t.len() {
            let t0 = t[i].time;
            let batch: Vec<&FailureEvent> =
                t.iter().filter(|e| e.time == t0).collect();
            assert_eq!(batch.len(), 4, "rack batch at {t0}");
            let rack = batch[0].server / 4;
            assert!(batch.iter().all(|e| e.server / 4 == rack));
            assert!(batch.iter().all(|e| e.kind == batch[0].kind));
            i += batch.len();
        }
        // determinism
        assert_eq!(t, m.trace(8, 200.0).unwrap());
    }

    #[test]
    fn hot_rack_fails_more_often_than_the_rest() {
        let m = FailureModel::Correlated {
            server_mtbf_hours: 1e9,
            server_mttr_hours: 0.5,
            domain_size: 4,
            domain_mtbf_hours: 40.0,
            domain_mttr_hours: 0.5,
            hot_factor: 8.0,
            seed: 5,
        };
        let t = m.trace(8, 2000.0).unwrap();
        let kills_rack0 = t
            .iter()
            .filter(|e| e.kind == FailureKind::Kill && e.server < 4)
            .count();
        let kills_rack1 = t
            .iter()
            .filter(|e| e.kind == FailureKind::Kill && e.server >= 4)
            .count();
        assert!(
            kills_rack0 > kills_rack1 * 2,
            "hot rack must dominate: {kills_rack0} vs {kills_rack1}"
        );
    }
}

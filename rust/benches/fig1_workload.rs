//! Fig. 1 reproduction: CDFs of distributed-ML application and task
//! duration from the fitted production-trace model.
//!
//! Paper anchors (§I): ~90 % of applications run > 6 h; ~50 % of tasks
//! take < 1.5 s.

#[path = "harness/mod.rs"]
mod harness;

use dorm::report;
use dorm::util::{stats, Rng};
use dorm::workload::{app_duration_hours, task_duration_secs, DurationModel};

fn main() {
    harness::banner("Fig. 1 — duration CDFs (production-trace model)");
    let model = DurationModel::production();
    let mut rng = Rng::new(1);
    let n = 50_000;
    let apps: Vec<f64> = (0..n).map(|_| app_duration_hours(&model, &mut rng)).collect();
    let tasks: Vec<f64> = (0..n).map(|_| task_duration_secs(&model, &mut rng)).collect();

    let hours = [0.5, 1.0, 2.0, 4.0, 6.0, 9.0, 12.0, 18.0, 24.0, 48.0];
    let secs = [0.2, 0.5, 1.0, 1.5, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0];
    let app_cdf = stats::ecdf(&apps, &hours);
    let task_cdf = stats::ecdf(&tasks, &secs);

    let rows: Vec<Vec<String>> = hours
        .iter()
        .zip(&app_cdf)
        .zip(secs.iter().zip(&task_cdf))
        .map(|((h, a), (s, t))| {
            vec![
                format!("{h}"),
                format!("{a:.3}"),
                format!("{}", model.app_cdf(*h)).chars().take(5).collect(),
                format!("{s}"),
                format!("{t:.3}"),
                format!("{}", model.task_cdf(*s)).chars().take(5).collect(),
            ]
        })
        .collect();
    println!(
        "{}",
        report::table(
            &["app h", "CDF emp", "CDF fit", "task s", "CDF emp", "CDF fit"],
            &rows
        )
    );

    harness::paper_row(
        "P(app duration > 6 h)",
        "~0.90",
        &format!("{:.3}", 1.0 - app_cdf[4]),
    );
    harness::paper_row(
        "P(task duration < 1.5 s)",
        "~0.50",
        &format!("{:.3}", task_cdf[3]),
    );

    let series_a: Vec<(f64, f64)> = hours.iter().zip(&app_cdf).map(|(&h, &c)| (h, c)).collect();
    println!("\napp-duration CDF:\n{}", report::ascii_chart(&[("apps", &series_a)], 10, 60));

    let _ = report::write_csv(
        "fig1_app_cdf.csv",
        &[("hours", hours.to_vec()), ("cdf", app_cdf)],
    );
    harness::bench_micro("sample 1k app durations", 3, 30, || {
        let mut r = Rng::new(9);
        let _: f64 = (0..1000).map(|_| app_duration_hours(&model, &mut r)).sum();
    });
}

//! Simulation runner: drives a [`CmsPolicy`] over a workload trace,
//! tracking progress, adjustments, server churn and the §IV-A metrics.
//!
//! The runner owns the ground truth ([`crate::cluster::ClusterState`] +
//! per-app progress); policies only *decide* assignments, through the same
//! backend-neutral [`CmsPolicy`]/[`crate::sched::SchedCtx`] interface the
//! live master drives (`crate::sched`) — on every arrival/completion the
//! runner snapshots its state into [`crate::sched::SchedApp`] rows and
//! applies the returned update through create/destroy diffs so the
//! capacity invariants are checked on every event (`debug_assert` +
//! explicit check in tests).
//!
//! **Arrivals are pulled, not pre-scheduled** (DESIGN.md §13): the run
//! loop draws [`SimArrival`]s one at a time from an [`ArrivalSource`] and
//! holds exactly one pending arrival *outside* the event heap, processing
//! it whenever it is due at or before the heap's next event.  Among equal
//! times this gives arrivals the same priority a heap full of
//! pre-scheduled arrivals (lowest sequence numbers) used to give them, so
//! a streaming source — e.g. a trace file read line-by-line through
//! [`crate::workload::trace`] — replays event-for-event identically to a
//! fully materialized workload slice, while the runner itself holds O(1)
//! arrival state however long the trace is.
//!
//! Failure injection (`crate::fault`, DESIGN.md §8): [`run_sim_faulty`]
//! additionally replays a churn trace.  A server death zeroes its
//! capacity, tears down every partition it hosted (BSP cannot continue
//! with lost workers), rolls the affected apps back to their last
//! checkpoint — the discarded progress is the *lost work* series — and
//! re-drives the policy against the shrunken capacity vector (stateful
//! policies drop their solve caches via
//! [`CmsPolicy::on_capacity_change`]).  Recovery completes when the app
//! holds containers again and its restart pause has elapsed; the paper's
//! checkpoint-on-adjustment plus an optional periodic cadence
//! ([`PerfModel::ckpt_period_hours`]) decide how much work a death costs.

use std::collections::{BTreeMap, BTreeSet};

use crate::app::{AppId, Engine};
use crate::cluster::{ClusterState, ServerId};
use crate::config::{ClusterConfig, SimConfig};
use crate::drf::{drf_allocate, fairness_loss, DrfApp};
use crate::fault::{FailureEvent, FailureKind, LeaseTable};
use crate::metrics::RunMetrics;
use crate::resources::Res;
use crate::sched::{CmsPolicy, SchedApp, SchedCtx};
use crate::workload::{Table2Row, WorkloadApp};

use super::engine::EventQueue;
use super::perf_model::PerfModel;

/// One application inside the simulation.
#[derive(Clone, Debug)]
pub struct SimApp {
    pub id: AppId,
    pub tag: String,
    /// Requested DCS engine (carried on the arrival; the IaaS baseline
    /// partitions servers by it).
    pub engine: Engine,
    pub demand: Res,
    pub weight: f64,
    pub n_min: u32,
    pub n_max: u32,
    /// Static count the baseline policies use.
    pub baseline_n: u32,
    pub submit: f64,
    pub work_total: f64,
    pub work_remaining: f64,
    pub containers: u32,
    /// Last time progress was settled.
    pub last_settle: f64,
    /// No progress before this time (checkpoint/kill/resume pause).
    pub paused_until: f64,
    /// Times this app was killed+resumed (Fig. 9b bookkeeping).
    pub kills: u32,
    /// Work completed at the last checkpoint — a server death rolls
    /// progress back to this (§III-C-2 resumes from reliable storage).
    pub ckpt_work: f64,
    /// Set while the app is down from a server death (recovery pending).
    pub failed_at: Option<f64>,
    /// Re-placed after a failure but the restart pause has not elapsed:
    /// (failure time, pause end).  The recovery only counts as complete —
    /// and lands in the metrics — once the app has actually run; a second
    /// failure during the pause reopens the original outage instead.
    pub recovery_due: Option<(f64, f64)>,
    /// Completed failure-recovery cycles (distinct from voluntary kills).
    pub recoveries: u32,
    /// Completion-event version (lazy cancellation).
    pub version: u64,
    pub completed_at: Option<f64>,
}

impl SimApp {
    fn work_done(&self) -> f64 {
        self.work_total - self.work_remaining
    }

    /// Settle progress up to `now` given the perf model and the policy's
    /// progress factor.
    fn settle(&mut self, now: f64, pm: &PerfModel, pf: f64) {
        // active interval is [max(last_settle, paused_until), now]
        let active_from = self.last_settle.max(self.paused_until);
        if now > active_from && self.containers > 0 {
            let dt = now - active_from;
            self.work_remaining =
                (self.work_remaining - dt * pf * pm.speed(self.containers)).max(0.0);
        }
        self.last_settle = now;
    }

    /// Absolute completion time if the allocation stays as-is.
    fn eta(&self, now: f64, pm: &PerfModel, pf: f64) -> Option<f64> {
        if self.containers == 0 {
            return None;
        }
        let start = now.max(self.paused_until);
        Some(start + self.work_remaining / (pf * pm.speed(self.containers)))
    }
}

/// One arrival, fully self-describing: everything the runner needs to
/// admit the app travels on the record itself (no side table of
/// [`Table2Row`]s), which is what lets recorded traces with arbitrary
/// demand vectors drive the same loop as the synthesized workload.
#[derive(Clone, Debug, PartialEq)]
pub struct SimArrival {
    /// Short tag like "LR" / "VGG-16" (Fig. 9a grouping).
    pub tag: String,
    pub engine: Engine,
    /// Per-container demand vector.
    pub demand: Res,
    pub weight: f64,
    pub n_min: u32,
    pub n_max: u32,
    /// Static count the baseline policies pin this app at.
    pub baseline_n: u32,
    /// Submission time, hours from experiment start.  Sources must yield
    /// non-decreasing times (enforced by `debug_assert` in the run loop;
    /// the trace reader turns violations into typed errors upstream).
    pub submit_hours: f64,
    /// Duration at `baseline_n` containers; the perf model converts it to
    /// total work via its speedup curve.
    pub duration_at_baseline_hours: f64,
}

/// A pull-based stream of arrivals in non-decreasing submission order.
/// Implementations range from a materialized slice ([`SliceSource`]) to a
/// bounded-buffer trace reader that never holds the full trace
/// ([`crate::workload::trace::TraceSource`]).
pub trait ArrivalSource {
    /// The next arrival, or `None` when the stream is exhausted (or has
    /// failed — streaming sources report the error out-of-band after the
    /// run, since the DES cannot unwind mid-flight).
    fn next_arrival(&mut self) -> Option<SimArrival>;
}

/// [`ArrivalSource`] over a materialized `(rows, workload)` pair — the
/// adapter that keeps [`run_sim`]/[`run_sim_faulty`] signatures intact.
pub struct SliceSource<'a> {
    rows: &'a [Table2Row],
    workload: &'a [WorkloadApp],
    next: usize,
}

impl<'a> SliceSource<'a> {
    pub fn new(rows: &'a [Table2Row], workload: &'a [WorkloadApp]) -> Self {
        SliceSource { rows, workload, next: 0 }
    }
}

impl ArrivalSource for SliceSource<'_> {
    fn next_arrival(&mut self) -> Option<SimArrival> {
        let w = self.workload.get(self.next)?;
        self.next += 1;
        let row = &self.rows[w.row];
        Some(SimArrival {
            tag: w.tag.clone(),
            engine: row.engine,
            demand: row.demand.clone(),
            weight: row.weight as f64,
            n_min: row.n_min,
            n_max: row.n_max,
            baseline_n: w.baseline_n,
            submit_hours: w.submit_hours,
            duration_at_baseline_hours: w.duration_at_baseline_hours,
        })
    }
}

#[derive(Clone, Debug, PartialEq)]
enum Event {
    Completion { app: AppId, version: u64 },
    Sample,
    /// Server dies: capacity + hosted partitions lost (`crate::fault`).
    ServerFail(usize),
    /// Server rejoins empty with its original capacity.
    ServerRecover(usize),
    /// Periodic checkpoint tick ([`PerfModel::ckpt_period_hours`]).
    CkptTick,
    /// The CMS master dies (DESIGN.md §11): partitions keep computing
    /// (§III-D — apps launch tasks locally), checkpoints keep landing on
    /// reliable storage, but every allocation decision is deferred.
    MasterFail,
    /// The standby finished taking over: one catch-up solve reconciles
    /// everything deferred during the outage.
    MasterRecover,
}

/// Everything a run produces.
pub struct SimOutcome {
    pub metrics: RunMetrics,
    /// All apps (completed and not) at horizon end.
    pub apps: BTreeMap<AppId, SimApp>,
    /// Completed fraction.
    pub completed: usize,
    /// Arrivals actually admitted into the run (those with
    /// `submit <= horizon`); the app-id space is `0..arrivals`.
    pub arrivals: usize,
    /// Allocation decisions deferred by master outages (arrivals,
    /// completions, server churn seen while no master was serving) —
    /// the "lost adjustments" a takeover costs.
    pub deferred_allocations: usize,
    /// Total hours with no serving master.
    pub master_outage_hours: f64,
}

/// Run `policy` over `workload` on `cluster_cfg` for `sim.horizon_hours`
/// with no server churn (the paper's assumption).
pub fn run_sim(
    policy: &mut dyn CmsPolicy,
    rows: &[Table2Row],
    workload: &[WorkloadApp],
    cluster_cfg: &ClusterConfig,
    sim: &SimConfig,
    pm: &PerfModel,
) -> SimOutcome {
    run_sim_faulty(policy, rows, workload, cluster_cfg, sim, pm, &[])
}

/// [`run_sim`] plus an injected failure trace (see module docs).
pub fn run_sim_faulty(
    policy: &mut dyn CmsPolicy,
    rows: &[Table2Row],
    workload: &[WorkloadApp],
    cluster_cfg: &ClusterConfig,
    sim: &SimConfig,
    pm: &PerfModel,
    faults: &[FailureEvent],
) -> SimOutcome {
    let mut source = SliceSource::new(rows, workload);
    run_core(policy, &mut source, cluster_cfg, sim, pm, faults, None)
}

/// Run `policy` over an arbitrary [`ArrivalSource`] — the entry point the
/// trace-replay driver uses (`dorm replay --mode des`).
pub fn run_sim_stream(
    policy: &mut dyn CmsPolicy,
    source: &mut dyn ArrivalSource,
    cluster_cfg: &ClusterConfig,
    sim: &SimConfig,
    pm: &PerfModel,
    faults: &[FailureEvent],
) -> SimOutcome {
    run_core(policy, source, cluster_cfg, sim, pm, faults, None)
}

/// [`run_sim_stream`] that additionally records one line per processed
/// DES event (`"<time>|<kind>|<detail>"`).  The streaming-vs-materialized
/// parity property (`tests/trace.rs`) compares these logs byte-for-byte —
/// the strongest observable statement that two sources drove the exact
/// same event sequence.  Costs O(events) memory; test/diagnostic use only.
pub fn run_sim_stream_traced(
    policy: &mut dyn CmsPolicy,
    source: &mut dyn ArrivalSource,
    cluster_cfg: &ClusterConfig,
    sim: &SimConfig,
    pm: &PerfModel,
    faults: &[FailureEvent],
) -> (SimOutcome, Vec<String>) {
    let mut log = Vec::new();
    let out = run_core(policy, source, cluster_cfg, sim, pm, faults, Some(&mut log));
    (out, log)
}

/// The single event loop behind every `run_sim*` entry point.
#[allow(clippy::too_many_arguments)]
fn run_core(
    policy: &mut dyn CmsPolicy,
    source: &mut dyn ArrivalSource,
    cluster_cfg: &ClusterConfig,
    sim: &SimConfig,
    pm: &PerfModel,
    faults: &[FailureEvent],
    mut log: Option<&mut Vec<String>>,
) -> SimOutcome {
    let mut cluster = ClusterState::new(cluster_cfg);
    let saved_caps: Vec<Res> = cluster.servers.iter().map(|s| s.capacity.clone()).collect();
    // the DES drives deaths by injected events, not missed heartbeats
    let mut lease = LeaseTable::new(cluster.servers.len(), f64::INFINITY);
    let pf = policy.progress_factor();
    let mut metrics = RunMetrics::new(&policy.name());
    let mut q: EventQueue<Event> = EventQueue::new();
    let mut apps: BTreeMap<AppId, SimApp> = BTreeMap::new();
    let mut done: BTreeMap<AppId, SimApp> = BTreeMap::new();
    let mut total_adjusted = 0u32;
    let mut lost_work = 0.0f64;
    // master-failover bookkeeping (DESIGN.md §11): while no master serves,
    // allocation decisions are deferred — not lost — and reconciled by one
    // catch-up solve at takeover, mirroring the live standby promotion
    let mut master_up = true;
    let mut master_down_at = 0.0f64;
    let mut master_outage_hours = 0.0f64;
    let mut deferred_allocations = 0usize;
    let mut pending_realloc = false;

    q.schedule(0.0, Event::Sample);
    for f in faults {
        if f.time > sim.horizon_hours {
            continue;
        }
        if f.kind.is_server_event() && f.server >= cluster.servers.len() {
            continue;
        }
        let ev = match f.kind {
            FailureKind::Kill => Event::ServerFail(f.server),
            FailureKind::Recover => Event::ServerRecover(f.server),
            FailureKind::MasterKill => Event::MasterFail,
            FailureKind::MasterRecover => Event::MasterRecover,
        };
        q.schedule(f.time, ev);
    }
    if pm.ckpt_period_hours > 0.0 {
        q.schedule(pm.ckpt_period_hours, Event::CkptTick);
    }

    // exactly one pending arrival lives outside the heap (module docs)
    let mut pending: Option<SimArrival> = source.next_arrival();
    let mut arrivals = 0usize;
    let mut last_submit = f64::NEG_INFINITY;

    loop {
        // among equal times the pending arrival runs before any heap
        // event — the priority pre-scheduled arrivals used to get from
        // their low FIFO sequence numbers
        while let Some(arr) = pending.take() {
            let due = match q.peek_time() {
                Some(t) => arr.submit_hours <= t,
                None => true,
            };
            if !due {
                pending = Some(arr);
                break;
            }
            if arr.submit_hours > sim.horizon_hours {
                // monotone source: every later arrival is out too
                break;
            }
            debug_assert!(
                arr.submit_hours >= last_submit,
                "arrival source went backwards: {} < {last_submit}",
                arr.submit_hours
            );
            last_submit = last_submit.max(arr.submit_hours);
            let now = arr.submit_hours;
            let id = AppId(arrivals as u64);
            arrivals += 1;
            if let Some(l) = log.as_deref_mut() {
                l.push(format!(
                    "{now:.9}|arrival|{}|{}|{}|{:?}",
                    id.0, arr.tag, arr.baseline_n, arr.demand.0
                ));
            }
            let app = SimApp {
                id,
                tag: arr.tag,
                engine: arr.engine,
                demand: arr.demand,
                weight: arr.weight,
                n_min: arr.n_min,
                n_max: arr.n_max,
                baseline_n: arr.baseline_n,
                submit: now,
                work_total: pm.work_for(arr.duration_at_baseline_hours, arr.baseline_n),
                work_remaining: pm.work_for(arr.duration_at_baseline_hours, arr.baseline_n),
                containers: 0,
                last_settle: now,
                paused_until: now + policy.admission_latency_hours(),
                kills: 0,
                ckpt_work: 0.0,
                failed_at: None,
                recovery_due: None,
                recoveries: 0,
                version: 0,
                completed_at: None,
            };
            cluster.register_app(id, app.demand.clone());
            apps.insert(id, app);
            if master_up {
                reallocate(policy, &mut apps, &mut cluster, &mut q, now, pm, pf,
                           &mut metrics, &mut total_adjusted);
            } else {
                deferred_allocations += 1;
                pending_realloc = true;
            }
            sample(&mut metrics, now, &apps, &cluster, total_adjusted, lost_work, pm, pf);
            pending = source.next_arrival();
        }
        let Some(ev) = q.pop() else {
            break;
        };
        let now = ev.time;
        if now > sim.horizon_hours {
            break;
        }
        if let Some(l) = log.as_deref_mut() {
            let line = match &ev.event {
                Event::Completion { app, version } => {
                    format!("{now:.9}|completion|{}|{version}", app.0)
                }
                Event::Sample => format!("{now:.9}|sample"),
                Event::ServerFail(j) => format!("{now:.9}|server_fail|{j}"),
                Event::ServerRecover(j) => format!("{now:.9}|server_recover|{j}"),
                Event::CkptTick => format!("{now:.9}|ckpt_tick"),
                Event::MasterFail => format!("{now:.9}|master_fail"),
                Event::MasterRecover => format!("{now:.9}|master_recover"),
            };
            l.push(line);
        }
        match ev.event {
            Event::Completion { app: id, version } => {
                let Some(app) = apps.get_mut(&id) else { continue };
                if app.version != version {
                    continue; // stale event
                }
                app.settle(now, pm, pf);
                debug_assert!(app.work_remaining <= 1e-6, "{}", app.work_remaining);
                // completing implies the restart pause elapsed: close any
                // recovery still pending its pause
                if let Some((t0, due)) = app.recovery_due.take() {
                    metrics.recovery.push(now, due - t0);
                    app.recoveries += 1;
                }
                app.completed_at = Some(now);
                metrics
                    .completions
                    .push((app.tag.clone(), now - app.submit));
                metrics
                    .app_durations
                    .insert(id.0, (app.tag.clone(), now - app.submit));
                let finished = apps.remove(&id).unwrap();
                cluster.remove_app(id);
                done.insert(id, finished);
                if master_up {
                    reallocate(policy, &mut apps, &mut cluster, &mut q, now, pm, pf,
                               &mut metrics, &mut total_adjusted);
                } else {
                    deferred_allocations += 1;
                    pending_realloc = true;
                }
                sample(&mut metrics, now, &apps, &cluster, total_adjusted, lost_work, pm, pf);
            }
            Event::Sample => {
                sample(&mut metrics, now, &apps, &cluster, total_adjusted, lost_work, pm, pf);
                let next = now + sim.sample_period_min / 60.0;
                if next <= sim.horizon_hours {
                    q.schedule(next, Event::Sample);
                }
            }
            Event::ServerFail(j) => {
                // Drain every same-time ServerFail into one batch: a
                // correlated domain outage (DESIGN.md §14) kills a whole
                // rack at one instant, and the live master's lease sweep
                // expires those slaves as ONE batch — one rollback per
                // victim app, one re-solve.  The DES must consume them in
                // one pass to stay decision-identical (`tests/fault.rs`).
                let mut batch = vec![j];
                while let Some(s) =
                    q.pop_if(|s| s.time == now && matches!(s.event, Event::ServerFail(_)))
                {
                    if let Event::ServerFail(k) = s.event {
                        if let Some(l) = log.as_deref_mut() {
                            l.push(format!("{now:.9}|server_fail|{k}"));
                        }
                        batch.push(k);
                    }
                }
                batch.sort_unstable();
                batch.dedup();
                batch.retain(|&k| lease.is_alive(k)); // double kills in the trace
                if batch.is_empty() {
                    continue;
                }
                for app in apps.values_mut() {
                    app.settle(now, pm, pf);
                }
                // every partition with a container on a dead server is
                // broken: reclaim it everywhere and roll the app back to
                // its checkpoint — once per app, however many servers of
                // its footprint the batch took
                let mut victims: BTreeSet<AppId> = BTreeSet::new();
                for &k in &batch {
                    lease.mark_dead(k);
                    victims.extend(cluster.servers[k].containers.keys().copied());
                }
                for id in &victims {
                    let placement = cluster.placement_of(*id);
                    for (&sid, &cnt) in &placement {
                        cluster
                            .destroy_containers(*id, sid, cnt)
                            .expect("destroy within bookkeeping");
                    }
                    let app = apps.get_mut(id).expect("victim is active");
                    lost_work += (app.work_done() - app.ckpt_work).max(0.0);
                    app.work_remaining = app.work_total - app.ckpt_work;
                    app.containers = 0;
                    app.version += 1; // cancel any in-flight completion
                    if let Some((t0, due)) = app.recovery_due.take() {
                        if now < due {
                            // re-placed but never ran: that recovery never
                            // completed — the original outage continues
                            app.failed_at = Some(t0);
                        } else {
                            // the pause elapsed while it ran, the cycle
                            // just was never closed by an intervening
                            // event: record it, then open a fresh outage
                            metrics.recovery.push(now, due - t0);
                            app.recoveries += 1;
                            app.failed_at = Some(now);
                        }
                    } else if app.failed_at.is_none() {
                        app.failed_at = Some(now);
                    }
                    // the restart penalty is charged when the app is
                    // re-placed (see reallocate); while down it simply
                    // holds no containers and makes no progress
                }
                for &k in &batch {
                    cluster.servers[k].capacity = Res::zeros(saved_caps[k].m());
                    policy.on_server_failed(ServerId(k), now);
                }
                policy.on_capacity_change();
                // the teardown above is slave-local (the machine is gone
                // either way); only the *decision* needs a live master
                if master_up {
                    reallocate(policy, &mut apps, &mut cluster, &mut q, now, pm, pf,
                               &mut metrics, &mut total_adjusted);
                } else {
                    deferred_allocations += 1;
                    pending_realloc = true;
                }
                sample(&mut metrics, now, &apps, &cluster, total_adjusted, lost_work, pm, pf);
            }
            Event::ServerRecover(j) => {
                if lease.is_alive(j) {
                    continue; // double recover in the trace
                }
                for app in apps.values_mut() {
                    app.settle(now, pm, pf);
                }
                lease.mark_alive(j, now);
                cluster.servers[j].capacity = saved_caps[j].clone();
                policy.on_server_recovered(ServerId(j), now);
                policy.on_capacity_change();
                if master_up {
                    reallocate(policy, &mut apps, &mut cluster, &mut q, now, pm, pf,
                               &mut metrics, &mut total_adjusted);
                } else {
                    deferred_allocations += 1;
                    pending_realloc = true;
                }
                sample(&mut metrics, now, &apps, &cluster, total_adjusted, lost_work, pm, pf);
            }
            Event::MasterFail => {
                if master_up {
                    master_up = false;
                    master_down_at = now;
                }
            }
            Event::MasterRecover => {
                if !master_up {
                    master_up = true;
                    master_outage_hours += now - master_down_at;
                    if pending_realloc {
                        // the promoted standby's catch-up solve: engine
                        // caches are stale across the restore
                        pending_realloc = false;
                        policy.on_capacity_change();
                        reallocate(policy, &mut apps, &mut cluster, &mut q, now, pm, pf,
                                   &mut metrics, &mut total_adjusted);
                        sample(&mut metrics, now, &apps, &cluster, total_adjusted, lost_work,
                               pm, pf);
                    }
                }
            }
            Event::CkptTick => {
                for app in apps.values_mut() {
                    app.settle(now, pm, pf);
                    if app.containers > 0 && now >= app.paused_until {
                        app.ckpt_work = app.work_done();
                    }
                }
                let next = now + pm.ckpt_period_hours;
                if next <= sim.horizon_hours {
                    q.schedule(next, Event::CkptTick);
                }
            }
        }
    }

    // a master still down at horizon end charges the tail of the outage
    if !master_up {
        master_outage_hours += sim.horizon_hours - master_down_at;
    }

    // merge remaining active apps into the report
    let completed = done.len();
    for (id, app) in apps {
        done.insert(id, app);
    }
    SimOutcome {
        metrics,
        apps: done,
        completed,
        arrivals,
        deferred_allocations,
        master_outage_hours,
    }
}

/// Ask the policy for a new assignment and apply it.
#[allow(clippy::too_many_arguments)]
fn reallocate(
    policy: &mut dyn CmsPolicy,
    apps: &mut BTreeMap<AppId, SimApp>,
    cluster: &mut ClusterState,
    q: &mut EventQueue<Event>,
    now: f64,
    pm: &PerfModel,
    pf: f64,
    metrics: &mut RunMetrics,
    total_adjusted: &mut u32,
) {
    // settle everyone before the allocation changes
    for app in apps.values_mut() {
        app.settle(now, pm, pf);
    }
    // snapshot into the backend-neutral view the live master also produces
    let snapshot: BTreeMap<AppId, SchedApp> = apps
        .iter()
        .map(|(id, a)| {
            (
                *id,
                SchedApp {
                    id: *id,
                    demand: a.demand.clone(),
                    weight: a.weight,
                    n_min: a.n_min,
                    n_max: a.n_max,
                    containers: a.containers,
                    placement: cluster.placement_of(*id),
                    submit: a.submit,
                    baseline_n: a.baseline_n,
                    engine: a.engine,
                },
            )
        })
        .collect();
    let capacities: Vec<Res> = cluster
        .servers
        .iter()
        .map(|s| s.capacity.clone())
        .collect();
    let update = {
        let ctx = SchedCtx { now, apps: &snapshot, capacities: &capacities };
        policy.on_change(&ctx)
    };
    let Some(update) = update else { return };

    // apply diffs: ALL destroys first (shrinking apps free the space the
    // growing ones move into), then all creates.
    let mut changed: Vec<AppId> = Vec::new();
    for (id, _) in apps.iter() {
        let target = update.assignment.get(id).cloned().unwrap_or_default();
        let current = cluster.placement_of(*id);
        if target == current {
            continue;
        }
        changed.push(*id);
        for (&sid, &cnt) in &current {
            cluster
                .destroy_containers(*id, sid, cnt)
                .expect("destroy within bookkeeping");
        }
    }
    for id in &changed {
        let target = update.assignment.get(id).cloned().unwrap_or_default();
        for (&sid, &cnt) in &target {
            if let Err(e) = cluster.create_containers(*id, sid, cnt) {
                panic!("policy {} produced invalid placement: {e}", policy.name());
            }
        }
        if let Some(app) = apps.get_mut(id) {
            app.containers = target.values().sum();
        }
    }

    // pauses + reschedules; adjusted apps checkpoint before the kill
    // (§III-C-2: save -> kill -> resume), so an adjustment loses nothing
    let adjusted: Vec<AppId> = update.adjusted.clone();
    for id in &adjusted {
        if let Some(app) = apps.get_mut(id) {
            app.ckpt_work = app.work_done();
            app.paused_until = now + pm.adjust_pause_hours();
            app.kills += 1;
        }
    }
    if !adjusted.is_empty() {
        *total_adjusted += adjusted.len() as u32;
        metrics.adjustment_batch_sizes.push(adjusted.len() as u32);
    }
    // a failed app re-placed by this decision pays the restart pause
    // (kill already happened; no save — the checkpoint predates the
    // failure); the recovery completes — and is recorded — only once
    // that pause has elapsed, so a death during the pause cannot leave a
    // phantom "completed" recovery behind
    for app in apps.values_mut() {
        if let Some(t0) = app.failed_at {
            if app.containers > 0 {
                app.paused_until = (now + pm.restart_hours).max(app.paused_until);
                app.failed_at = None;
                app.recovery_due = Some((t0, app.paused_until));
            }
        }
        match app.recovery_due {
            // pause elapsed while it held containers: it ran — complete
            // (even if this very solve just deferred it again)
            Some((t0, due)) if now >= due => {
                metrics.recovery.push(now, due - t0);
                app.recoveries += 1;
                app.recovery_due = None;
            }
            // stripped back to zero containers before the pause ended:
            // it never ran, so the original outage continues
            Some((t0, _)) if app.containers == 0 => {
                app.recovery_due = None;
                app.failed_at = Some(t0);
            }
            _ => {}
        }
    }
    for app in apps.values_mut() {
        app.version += 1;
        if let Some(eta) = app.eta(now, pm, pf) {
            q.schedule(eta, Event::Completion { app: app.id, version: app.version });
        }
    }
    debug_assert!(cluster.check_invariants().is_ok());
}

/// Record the §IV-A metrics (+ the fault series) at `now`.
#[allow(clippy::too_many_arguments)]
fn sample(
    metrics: &mut RunMetrics,
    now: f64,
    apps: &BTreeMap<AppId, SimApp>,
    cluster: &ClusterState,
    total_adjusted: u32,
    lost_work: f64,
    pm: &PerfModel,
    pf: f64,
) {
    metrics.utilization.push(now, cluster.utilization());
    // fairness loss (Eq. 2) over the active set
    let cap = cluster.total_capacity();
    let drf_apps: Vec<DrfApp> = apps
        .values()
        .map(|a| DrfApp {
            demand: a.demand.clone(),
            weight: a.weight,
            n_min: a.n_min.min(a.n_max),
            n_max: a.n_max,
        })
        .collect();
    let shat = if drf_apps.is_empty() {
        vec![]
    } else {
        drf_allocate(&drf_apps, &cap).shares
    };
    let actual: Vec<f64> = apps
        .values()
        .map(|a| a.demand.times(a.containers).dominant_share(&cap))
        .collect();
    metrics.fairness_loss.push(now, fairness_loss(&actual, &shat));
    metrics.adjustments.push(now, total_adjusted as f64);
    metrics.lost_work.push(now, lost_work);
    let goodput: f64 = apps
        .values()
        .filter(|a| a.containers > 0 && now >= a.paused_until)
        .map(|a| pf * pm.speed(a.containers))
        .sum();
    metrics.goodput.push(now, goodput);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::StaticPolicy;
    use crate::fault::FailureEvent;
    use crate::workload::{table2_rows, WorkloadGen};
    use crate::util::Rng;

    fn tiny_workload() -> (Vec<Table2Row>, Vec<WorkloadApp>) {
        let rows = table2_rows();
        let apps = vec![
            WorkloadApp { row: 0, tag: "LR".into(), submit_hours: 0.0,
                duration_at_baseline_hours: 2.0, baseline_n: 8 },
            WorkloadApp { row: 1, tag: "MF".into(), submit_hours: 0.5,
                duration_at_baseline_hours: 3.0, baseline_n: 8 },
        ];
        (rows, apps)
    }

    #[test]
    fn static_policy_runs_apps_at_fixed_duration() {
        let (rows, wl) = tiny_workload();
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 12.0, ..Default::default() };
        let pm = PerfModel::default();
        let mut pol = StaticPolicy::new();
        let out = run_sim(&mut pol, &rows, &wl, &cfg, &sim, &pm);
        assert_eq!(out.completed, 2);
        assert_eq!(out.arrivals, 2);
        // static baseline runs each app at exactly its baseline count ->
        // duration equals the sampled duration
        let lr_dur = out.metrics.completions.iter()
            .find(|(t, _)| t == "LR").unwrap().1;
        assert!((lr_dur - 2.0).abs() < 1e-6, "{lr_dur}");
    }

    #[test]
    fn full_table2_workload_static_completes_some() {
        let rows = table2_rows();
        let gen = WorkloadGen::default();
        let mut rng = Rng::new(5);
        let wl = gen.generate(&mut rng);
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 24.0, ..Default::default() };
        let mut pol = StaticPolicy::new();
        let out = run_sim(&mut pol, &rows, &wl, &cfg, &sim, &pm_fast());
        assert!(out.completed > 0);
        // utilization sampled and bounded by m = 3
        assert!(out.metrics.utilization.max() <= 3.0 + 1e-9);
        assert!(out.metrics.utilization.max() > 0.0);
    }

    fn pm_fast() -> PerfModel {
        PerfModel::default()
    }

    /// The refactor's load-bearing invariant: running the same workload
    /// through the slice adapter twice — once via [`run_sim`], once via
    /// the traced stream entry point — produces identical outcomes, and
    /// arrivals beyond the horizon neither run nor shift app ids.
    #[test]
    fn slice_source_and_run_sim_agree() {
        let rows = table2_rows();
        let gen = WorkloadGen::default();
        let mut rng = Rng::new(9);
        let wl = gen.generate(&mut rng);
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 6.0, ..Default::default() };
        let pm = PerfModel::default();
        let mut p1 = StaticPolicy::new();
        let a = run_sim(&mut p1, &rows, &wl, &cfg, &sim, &pm);
        let mut p2 = StaticPolicy::new();
        let mut src = SliceSource::new(&rows, &wl);
        let (b, log) = run_sim_stream_traced(&mut p2, &mut src, &cfg, &sim, &pm, &[]);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.arrivals, b.arrivals);
        assert!(a.arrivals <= wl.len());
        assert!(a.arrivals >= 1);
        assert_eq!(a.metrics.utilization.points, b.metrics.utilization.points);
        assert_eq!(a.metrics.completions, b.metrics.completions);
        assert!(!log.is_empty());
        // ids are dense over the admitted prefix
        for i in 0..a.arrivals {
            assert!(b.apps.contains_key(&AppId(i as u64)));
        }
    }

    /// Equal-time tie order: an arrival at exactly t=0 must run before
    /// the Sample event at t=0 (pre-refactor, its lower heap sequence
    /// guaranteed this; now the held-out pending arrival does).
    #[test]
    fn arrival_beats_sample_at_equal_time() {
        let (rows, wl) = tiny_workload();
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 4.0, ..Default::default() };
        let pm = PerfModel::default();
        let mut pol = StaticPolicy::new();
        let mut src = SliceSource::new(&rows, &wl);
        let (_, log) = run_sim_stream_traced(&mut pol, &mut src, &cfg, &sim, &pm, &[]);
        let first_arrival = log.iter().position(|l| l.contains("|arrival|")).unwrap();
        let first_sample = log.iter().position(|l| l.contains("|sample")).unwrap();
        assert!(first_arrival < first_sample, "{log:?}");
    }

    /// Single app on a 2-server cluster, periodic checkpoints every 0.5 h,
    /// server 0 dies at t = 0.75: the app loses exactly the work done in
    /// [0.5, 0.75], recovers on server 1, and still completes.
    #[test]
    fn server_death_loses_work_since_checkpoint() {
        let rows = table2_rows();
        // LR: 8 containers of <2 CPU, 0 GPU, 8 GB>; one server can host it
        let wl = vec![WorkloadApp {
            row: 0,
            tag: "LR".into(),
            submit_hours: 0.0,
            duration_at_baseline_hours: 1.0,
            baseline_n: 8,
        }];
        let cfg = ClusterConfig::uniform(
            2,
            crate::resources::Res::cpu_gpu_ram(16.0, 0.0, 64.0),
        );
        let sim = SimConfig { horizon_hours: 8.0, ..Default::default() };
        let pm = PerfModel { ckpt_period_hours: 0.5, ..Default::default() };
        let faults = vec![FailureEvent::kill(0.75, 0)];
        let mut pol = StaticPolicy::new();
        let out = run_sim_faulty(&mut pol, &rows, &wl, &cfg, &sim, &pm, &faults);
        assert_eq!(out.completed, 1);
        let app = out.apps.values().next().unwrap();
        let lost = out.metrics.lost_work.last().unwrap();
        if app.recoveries == 1 {
            // the app sat on server 0 and was rolled back 0.25 h of progress
            let expect = 0.25 * pm.speed(8);
            assert!((lost - expect).abs() < 1e-6, "lost {lost} vs {expect}");
            let dur = out.metrics.completions[0].1;
            // 1 h of work + 0.25 h redone + restart pause
            let expect_dur = 1.0 + 0.25 + pm.restart_hours;
            assert!((dur - expect_dur).abs() < 1e-6, "dur {dur} vs {expect_dur}");
            assert_eq!(out.metrics.recovery.points.len(), 1);
            let (_, rec) = out.metrics.recovery.points[0];
            assert!((rec - pm.restart_hours).abs() < 1e-9, "recovery {rec}");
        } else {
            // placement put the app on server 1: the death must be free
            assert_eq!(app.recoveries, 0);
            assert_eq!(lost, 0.0);
            let dur = out.metrics.completions[0].1;
            assert!((dur - 1.0).abs() < 1e-6, "{dur}");
        }
    }

    /// Master dies at 0.4 h, standby takes over at 1.0 h: the MF app
    /// arriving at 0.5 h (mid-outage) gets no allocation until the
    /// catch-up solve, so its duration stretches by exactly the wait;
    /// the already-running LR app is untouched (§III-D: partitions keep
    /// computing without a master).
    #[test]
    fn master_outage_defers_allocations_until_takeover() {
        let (rows, wl) = tiny_workload();
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 12.0, ..Default::default() };
        let pm = PerfModel::default();
        let faults = vec![FailureEvent::master_kill(0.4), FailureEvent::master_recover(1.0)];
        let mut pol = StaticPolicy::new();
        let out = run_sim_faulty(&mut pol, &rows, &wl, &cfg, &sim, &pm, &faults);
        assert_eq!(out.completed, 2);
        assert!(out.deferred_allocations >= 1, "MF arrival must be deferred");
        assert!((out.master_outage_hours - 0.6).abs() < 1e-9);
        let lr_dur = out.metrics.completions.iter().find(|(t, _)| t == "LR").unwrap().1;
        assert!((lr_dur - 2.0).abs() < 1e-6, "running app untouched: {lr_dur}");
        // MF submitted at 0.5, allocated at the 1.0 takeover: 3 h of work
        // finish at 4.0, a 3.5 h duration — the 0.5 h takeover tax
        let mf_dur = out.metrics.completions.iter().find(|(t, _)| t == "MF").unwrap().1;
        assert!((mf_dur - 3.5).abs() < 1e-6, "deferred app pays the wait: {mf_dur}");
        // no outage, no tax: same trace minus the master events
        let mut pol = StaticPolicy::new();
        let base = run_sim_faulty(&mut pol, &rows, &wl, &cfg, &sim, &pm, &[]);
        assert_eq!(base.deferred_allocations, 0);
        assert_eq!(base.master_outage_hours, 0.0);
    }

    /// A death and recovery with no apps on the dead server must not
    /// disturb anyone; goodput tracks running width.
    #[test]
    fn unrelated_failures_are_free() {
        let (rows, wl) = tiny_workload();
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 12.0, ..Default::default() };
        let pm = PerfModel::default();
        // server 19 carries nothing under best-fit-decreasing for this load
        let faults = vec![FailureEvent::kill(0.1, 19), FailureEvent::recover(1.0, 19)];
        let mut pol = StaticPolicy::new();
        let out = run_sim_faulty(&mut pol, &rows, &wl, &cfg, &sim, &pm, &faults);
        assert_eq!(out.completed, 2);
        assert!(out.metrics.goodput.max() > 0.0);
        for app in out.apps.values() {
            if app.recoveries == 0 {
                assert!(app.failed_at.is_none());
            }
        }
    }
}

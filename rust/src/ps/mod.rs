//! Parameter-server training runtime — the "distributed ML system"
//! substrate standing in for MxNet / TensorFlow / Petuum / MPI-Caffe
//! (DESIGN.md §1).
//!
//! Implements the PS framework of the paper's Fig. 2 in its BSP variant:
//! the server holds the flat parameter vector; each *worker slot* (one per
//! container of the application's partition) computes the gradient of its
//! own data shard through the PJRT compute service; the server averages
//! and applies.  On this 1-core image worker slots execute sequentially —
//! the sharding semantics (and therefore the checkpoint/rescale math) are
//! identical to a multi-node deployment, which is what Dorm's adjustment
//! protocol needs: `test_data_parallel_equivalence` (python) and the
//! trainer tests pin that invariant.

mod data;
mod trainer;

pub use data::ShardGen;
pub use trainer::{StepLog, SyncMode, Trainer, TrainerConfig};

//! The streaming replay driver: feed a trace through the DES or a live
//! master without ever materializing it.
//!
//! * [`TraceSource`] — a bounded-buffer [`ArrivalSource`] over any record
//!   iterator.  It reads at most `buffer` records ahead (chunked refill),
//!   tracks its high-water mark ([`TraceSource::max_buffered`], the
//!   O(buffer) guarantee the tests assert), and applies the replay-time
//!   transform: open-loop (recorded timestamps × `time_scale`) or
//!   closed-loop (a sustained `rate_per_hour`, recorded times ignored).
//! * [`replay_des`] — drive a [`CmsPolicy`] in the DES from a streaming
//!   source; a trace error surfaces as a typed failure after the clean
//!   prefix has run.
//! * [`replay_live`] — drive a live master through any
//!   [`ControlPlane`] (in-process or TCP), submitting per the replayed
//!   clock and completing jobs as their recorded durations elapse,
//!   recording per-phase (submit/complete) RPC latency series.
//! * [`rate_sweep`] — ramp offered arrivals/sec against fresh masters
//!   until admission saturates; emits the scaling-efficiency series the
//!   `replay` bench gates.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::app::{AppId, AppSpec};
use crate::config::TraceConfig;
use crate::metrics::ReplayMetrics;
use crate::net::ControlPlane;
use crate::proto::{Request, Response};
use crate::sched::CmsPolicy;
use crate::sim::{run_sim_stream, ArrivalSource, SimArrival, SimOutcome};
use crate::util::stats;

use super::schema::{TraceError, TraceRecord};

/// Replay-time knobs (a subset of the `[trace]` config section).
#[derive(Clone, Debug)]
pub struct ReplayOpts {
    /// Bounded look-ahead buffer, records (>= 1).
    pub buffer: usize,
    /// Open-loop: multiply recorded timestamps (0.5 = replay 2× faster).
    pub time_scale: f64,
    /// Closed-loop: > 0 replaces recorded times with a sustained rate of
    /// `rate_per_hour` arrivals per simulated hour.
    pub rate_per_hour: f64,
}

impl Default for ReplayOpts {
    fn default() -> Self {
        ReplayOpts { buffer: 4096, time_scale: 1.0, rate_per_hour: 0.0 }
    }
}

impl ReplayOpts {
    pub fn from_config(cfg: &TraceConfig) -> Self {
        ReplayOpts {
            buffer: cfg.buffer,
            time_scale: cfg.time_scale,
            rate_per_hour: cfg.rate_per_hour,
        }
    }
}

/// Bounded-buffer streaming adapter: record iterator → [`ArrivalSource`].
pub struct TraceSource<I: Iterator<Item = Result<TraceRecord, TraceError>>> {
    inner: I,
    buf: VecDeque<SimArrival>,
    opts: ReplayOpts,
    max_buffered: usize,
    records_read: u64,
    error: Option<TraceError>,
    exhausted: bool,
}

impl<I: Iterator<Item = Result<TraceRecord, TraceError>>> TraceSource<I> {
    pub fn new(inner: I, opts: ReplayOpts) -> Self {
        TraceSource {
            inner,
            buf: VecDeque::new(),
            opts: ReplayOpts { buffer: opts.buffer.max(1), ..opts },
            max_buffered: 0,
            records_read: 0,
            error: None,
            exhausted: false,
        }
    }

    /// Chunked refill: one pass pulls up to `buffer` records, so the
    /// underlying reader sees batched sequential reads while the driver
    /// never holds more than `buffer` arrivals.
    fn refill(&mut self) {
        if self.exhausted {
            return;
        }
        while self.buf.len() < self.opts.buffer {
            match self.inner.next() {
                Some(Ok(rec)) => {
                    let mut arr = rec.to_arrival();
                    self.records_read += 1;
                    arr.submit_hours = if self.opts.rate_per_hour > 0.0 {
                        // closed loop: sustained rate, recorded times ignored
                        (self.records_read - 1) as f64 / self.opts.rate_per_hour
                    } else {
                        arr.submit_hours * self.opts.time_scale
                    };
                    self.buf.push_back(arr);
                }
                Some(Err(e)) => {
                    self.error = Some(e);
                    self.exhausted = true;
                    break;
                }
                None => {
                    self.exhausted = true;
                    break;
                }
            }
        }
        self.max_buffered = self.max_buffered.max(self.buf.len());
    }

    /// High-water mark of buffered arrivals — by construction
    /// `<= buffer`, however long the trace (the streaming guarantee).
    pub fn max_buffered(&self) -> usize {
        self.max_buffered
    }

    pub fn records_read(&self) -> u64 {
        self.records_read
    }

    /// The error that fused the stream, if any (checked after a run).
    pub fn error(&self) -> Option<&TraceError> {
        self.error.as_ref()
    }
}

impl<I: Iterator<Item = Result<TraceRecord, TraceError>>> ArrivalSource for TraceSource<I> {
    fn next_arrival(&mut self) -> Option<SimArrival> {
        if self.buf.is_empty() {
            self.refill();
        }
        self.buf.pop_front()
    }
}

/// What a DES replay produced.
pub struct DesReplayReport {
    pub outcome: SimOutcome,
    pub records_read: u64,
    pub max_buffered: usize,
}

/// Stream `records` through the DES under `policy`.  Trace errors are
/// typed failures: the clean prefix runs, then the error is reported
/// (with how far the replay got) instead of a partial result.
pub fn replay_des(
    policy: &mut dyn CmsPolicy,
    records: impl Iterator<Item = Result<TraceRecord, TraceError>>,
    opts: ReplayOpts,
    cluster: &crate::config::ClusterConfig,
    sim: &crate::config::SimConfig,
    pm: &crate::sim::PerfModel,
) -> Result<DesReplayReport> {
    let mut source = TraceSource::new(records, opts);
    let outcome = run_sim_stream(policy, &mut source, cluster, sim, pm, &[]);
    if let Some(e) = source.error() {
        bail!("trace failed after {} records: {e}", source.records_read());
    }
    Ok(DesReplayReport {
        outcome,
        records_read: source.records_read(),
        max_buffered: source.max_buffered(),
    })
}

/// Live-replay knobs.
#[derive(Clone, Debug)]
pub struct LiveOpts {
    /// Wall-clock pacing: milliseconds of real time per replayed hour
    /// (0 = as fast as the master admits).
    pub ms_per_hour: f64,
    /// In-flight window: submitting past this many active apps first
    /// completes the oldest — keeps the master's solve set (and the
    /// replay's memory) bounded on arbitrarily long traces.
    pub window: usize,
    /// Stop after this many submissions (0 = the whole trace).
    pub max_apps: u64,
}

impl Default for LiveOpts {
    fn default() -> Self {
        LiveOpts { ms_per_hour: 0.0, window: 64, max_apps: 0 }
    }
}

impl LiveOpts {
    pub fn from_config(cfg: &TraceConfig) -> Self {
        LiveOpts { ms_per_hour: cfg.ms_per_hour, window: cfg.window, max_apps: 0 }
    }
}

/// What a live replay produced.
pub struct LiveReplayReport {
    pub submitted: u64,
    pub completed: u64,
    /// Submissions the master refused (admission saturation / invalid).
    pub rejected: u64,
    pub records_read: u64,
    pub max_buffered: usize,
    /// Per-phase RPC latency + efficiency series.
    pub metrics: ReplayMetrics,
    pub wall: Duration,
}

/// Replay a record stream against a live master through `transport`,
/// open- or closed-loop per `opts`: submit each arrival at its replayed
/// time, complete it once its recorded duration has elapsed on the
/// replayed clock.  Widths the master assigns do not feed back into the
/// replayed durations (the DES owns that model); the live path measures
/// the *control plane* — admission latency, completion latency, and how
/// submission rate scales — on real RPCs.
pub fn replay_live(
    transport: &mut dyn ControlPlane,
    records: impl Iterator<Item = Result<TraceRecord, TraceError>>,
    opts: ReplayOpts,
    live: &LiveOpts,
) -> Result<LiveReplayReport> {
    let mut source = TraceSource::new(records, opts);
    let mut metrics = ReplayMetrics::new();
    let mut submitted = 0u64;
    let mut completed = 0u64;
    let mut rejected = 0u64;
    // in-flight apps keyed by (completion-time bits, app id): f64 bit
    // order == numeric order for the non-negative times the reader admits
    let mut inflight: BTreeMap<(u64, u64), AppId> = BTreeMap::new();
    let t0 = Instant::now();

    fn complete_one(
        transport: &mut dyn ControlPlane,
        id: AppId,
        at_hours: f64,
        metrics: &mut ReplayMetrics,
        completed: &mut u64,
    ) -> Result<()> {
        let s = Instant::now();
        let resp = transport.call(Request::Complete { app: id })?;
        let ms = s.elapsed().as_secs_f64() * 1e3;
        if matches!(resp, Response::Ok) {
            *completed += 1;
        }
        metrics.complete_ms.push(at_hours, ms);
        Ok(())
    }

    while let Some(arr) = source.next_arrival() {
        if live.max_apps > 0 && submitted >= live.max_apps {
            break;
        }
        let v_hours = arr.submit_hours;
        // retire everything whose replayed duration has elapsed
        while let Some((&key, &id)) = inflight.iter().next() {
            let due = f64::from_bits(key.0);
            if due > v_hours && inflight.len() < live.window.max(1) {
                break;
            }
            inflight.remove(&key);
            complete_one(&mut *transport, id, v_hours, &mut metrics, &mut completed)?;
        }
        // wall pacing (open-loop live replay at a chosen speed)
        if live.ms_per_hour > 0.0 {
            let due = Duration::from_secs_f64(v_hours * live.ms_per_hour / 1e3);
            let elapsed = t0.elapsed();
            if due > elapsed {
                std::thread::sleep(due - elapsed);
            }
        }
        let spec = AppSpec {
            executor: arr.engine,
            demand: arr.demand.clone(),
            weight: (arr.weight.round() as u32).max(1),
            n_min: arr.n_min.max(1),
            n_max: arr.n_max.max(arr.n_min.max(1)),
            cmd: [arr.tag.clone(), arr.tag.clone()],
        };
        let s = Instant::now();
        let resp = transport.call(Request::Submit { spec })?;
        let ms = s.elapsed().as_secs_f64() * 1e3;
        metrics.submit_ms.push(v_hours, ms);
        submitted += 1;
        match resp {
            Response::Submitted { app } => {
                let done_at = v_hours + arr.duration_at_baseline_hours;
                inflight.insert((done_at.to_bits(), app.0), app);
            }
            _ => rejected += 1,
        }
    }
    if let Some(e) = source.error() {
        bail!("trace failed after {} records: {e}", source.records_read());
    }
    // drain the tail
    let tail_at = metrics.submit_ms.points.last().map(|&(t, _)| t).unwrap_or(0.0);
    let leftover: Vec<_> = std::mem::take(&mut inflight).into_iter().collect();
    for (_key, id) in leftover {
        complete_one(&mut *transport, id, tail_at, &mut metrics, &mut completed)?;
    }
    Ok(LiveReplayReport {
        submitted,
        completed,
        rejected,
        records_read: source.records_read(),
        max_buffered: source.max_buffered(),
        metrics,
        wall: t0.elapsed(),
    })
}

/// One point of the sustained-rate sweep.
#[derive(Clone, Debug)]
pub struct RatePoint {
    /// Offered arrivals per wall-second.
    pub offered_per_sec: f64,
    /// Arrivals the master actually absorbed per wall-second.
    pub achieved_per_sec: f64,
    /// achieved / offered, capped at 1 — the scaling-efficiency series.
    pub efficiency: f64,
    pub p50_submit_us: f64,
    pub p99_submit_us: f64,
    pub rejected: u64,
}

/// Ramp offered arrivals/sec until admission saturates: each rate gets a
/// fresh master from `make_transport` and `apps_per_rate` closed-loop
/// submissions paced at the offered rate (a sliding `window` keeps the
/// active set steady-state).  Saturation = the first rate whose
/// efficiency drops below `stop_below` (the sweep stops one point after,
/// so the knee is visible); `stop_below <= 0` sweeps every rate.
pub fn rate_sweep(
    make_transport: &mut dyn FnMut() -> Result<Box<dyn ControlPlane>>,
    records_for_rate: &mut dyn FnMut(f64) -> Vec<TraceRecord>,
    rates: &[f64],
    window: usize,
    stop_below: f64,
) -> Result<Vec<RatePoint>> {
    let mut out = Vec::new();
    for &rate in rates {
        let mut transport = make_transport()?;
        let records = records_for_rate(rate);
        let n = records.len() as u64;
        let mut submit_us: Vec<f64> = Vec::with_capacity(records.len());
        let mut inflight: VecDeque<AppId> = VecDeque::new();
        let mut rejected = 0u64;
        let t0 = Instant::now();
        for (i, rec) in records.into_iter().enumerate() {
            // open-loop offered clock: arrival i is due at i/rate seconds
            let due = Duration::from_secs_f64(i as f64 / rate);
            let elapsed = t0.elapsed();
            if due > elapsed {
                std::thread::sleep(due - elapsed);
            }
            let arr = rec.to_arrival();
            let spec = AppSpec {
                executor: arr.engine,
                demand: arr.demand.clone(),
                weight: (arr.weight.round() as u32).max(1),
                n_min: arr.n_min.max(1),
                n_max: arr.n_max.max(arr.n_min.max(1)),
                cmd: [arr.tag.clone(), arr.tag.clone()],
            };
            let s = Instant::now();
            let resp = transport.call(Request::Submit { spec })?;
            submit_us.push(s.elapsed().as_secs_f64() * 1e6);
            match resp {
                Response::Submitted { app } => inflight.push_back(app),
                _ => rejected += 1,
            }
            while inflight.len() > window.max(1) {
                let app = inflight.pop_front().unwrap();
                transport.call(Request::Complete { app })?;
            }
        }
        let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
        let achieved = n as f64 / elapsed;
        let efficiency = (achieved / rate).min(1.0);
        let (p50, p99) = if submit_us.is_empty() {
            (0.0, 0.0)
        } else {
            (stats::percentile(&submit_us, 50.0), stats::percentile(&submit_us, 99.0))
        };
        out.push(RatePoint {
            offered_per_sec: rate,
            achieved_per_sec: achieved,
            efficiency,
            p50_submit_us: p50,
            p99_submit_us: p99,
            rejected,
        });
        if stop_below > 0.0 && efficiency < stop_below {
            break; // admission saturated: the ramp has found the knee
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::CheckpointStore;
    use crate::baselines::StaticPolicy;
    use crate::config::{ClusterConfig, DormConfig, SimConfig};
    use crate::master::DormMaster;
    use crate::net::LocalTransport;
    use crate::resources::Res;
    use crate::sim::PerfModel;

    fn mk_records(n: usize, gap_hours: f64, dur_hours: f64) -> Vec<TraceRecord> {
        (0..n)
            .map(|i| TraceRecord {
                submit_hours: i as f64 * gap_hours,
                tag: format!("j{i}"),
                engine: crate::app::Engine::MxNet,
                demand: Res::cpu_gpu_ram(1.0, 0.0, 1.0),
                weight: 1.0,
                n_min: 1,
                n_max: 1,
                baseline_n: 1,
                duration_hours: dur_hours,
                priority: None,
                user: None,
            })
            .collect()
    }

    #[test]
    fn source_buffer_is_bounded_and_complete() {
        let recs = mk_records(1000, 0.001, 0.01);
        let mut src = TraceSource::new(
            recs.clone().into_iter().map(Ok),
            ReplayOpts { buffer: 16, ..Default::default() },
        );
        let mut n = 0;
        while let Some(a) = src.next_arrival() {
            assert_eq!(a.tag, format!("j{n}"));
            n += 1;
        }
        assert_eq!(n, 1000);
        assert_eq!(src.records_read(), 1000);
        assert!(src.max_buffered() <= 16, "{}", src.max_buffered());
        assert!(src.error().is_none());
    }

    #[test]
    fn closed_loop_respaces_arrivals() {
        let recs = mk_records(10, 1.0, 0.01); // recorded: 1 h apart
        let mut src = TraceSource::new(
            recs.into_iter().map(Ok),
            ReplayOpts { rate_per_hour: 100.0, ..Default::default() },
        );
        let mut times = Vec::new();
        while let Some(a) = src.next_arrival() {
            times.push(a.submit_hours);
        }
        for (i, t) in times.iter().enumerate() {
            assert!((t - i as f64 / 100.0).abs() < 1e-12, "{times:?}");
        }
    }

    #[test]
    fn open_loop_time_scale_compresses() {
        let recs = mk_records(3, 2.0, 0.01);
        let mut src = TraceSource::new(
            recs.into_iter().map(Ok),
            ReplayOpts { time_scale: 0.5, ..Default::default() },
        );
        assert_eq!(src.next_arrival().unwrap().submit_hours, 0.0);
        assert_eq!(src.next_arrival().unwrap().submit_hours, 1.0);
        assert_eq!(src.next_arrival().unwrap().submit_hours, 2.0);
    }

    #[test]
    fn replay_des_runs_and_reports_errors() {
        let cluster = ClusterConfig::uniform(4, Res::cpu_gpu_ram(8.0, 0.0, 32.0));
        let sim = SimConfig { horizon_hours: 2.0, ..Default::default() };
        let pm = PerfModel::default();
        let mut pol = StaticPolicy::new();
        let recs = mk_records(20, 0.01, 0.05);
        let rep = replay_des(
            &mut pol,
            recs.into_iter().map(Ok),
            ReplayOpts { buffer: 4, ..Default::default() },
            &cluster,
            &sim,
            &pm,
        )
        .unwrap();
        assert_eq!(rep.records_read, 20);
        assert!(rep.max_buffered <= 4);
        assert_eq!(rep.outcome.arrivals, 20);
        assert!(rep.outcome.completed > 0);
        // an error mid-stream surfaces typed, after the clean prefix
        let mut pol = StaticPolicy::new();
        let recs = mk_records(5, 0.01, 0.05);
        let bad = recs
            .into_iter()
            .map(Ok)
            .chain(std::iter::once(Err(TraceError::NonMonotone {
                line: 7,
                prev_hours: 1.0,
                now_hours: 0.0,
            })));
        let err = replay_des(
            &mut pol,
            bad,
            ReplayOpts::default(),
            &cluster,
            &sim,
            &pm,
        )
        .err()
        .expect("bad trace must fail the replay");
        assert!(err.to_string().contains("after 5 records"), "{err}");
        assert!(err.to_string().contains("backwards"), "{err}");
    }

    fn local_master(slaves: usize, tag: &str) -> LocalTransport {
        let d = std::env::temp_dir().join(format!("dorm_replay_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        let cluster = ClusterConfig::uniform(slaves, Res::cpu_gpu_ram(16.0, 0.0, 64.0));
        let store = CheckpointStore::new(d).unwrap();
        LocalTransport::new(DormMaster::new(&cluster, DormConfig::DORM3, store))
    }

    #[test]
    fn live_replay_submits_and_completes() {
        let mut t = local_master(4, "live");
        let recs = mk_records(12, 0.05, 0.1);
        let rep = replay_live(
            &mut t,
            recs.into_iter().map(Ok),
            ReplayOpts { buffer: 4, ..Default::default() },
            &LiveOpts { window: 4, ..Default::default() },
        )
        .unwrap();
        assert_eq!(rep.submitted, 12);
        assert_eq!(rep.completed, 12, "window + drain must complete everything");
        assert_eq!(rep.rejected, 0);
        assert!(rep.max_buffered <= 4);
        assert_eq!(rep.metrics.submit_ms.points.len(), 12);
        assert!(rep.metrics.submit_p50_ms() >= 0.0);
        // nothing left active on the master
        let Response::State(v) =
            t.call(Request::QueryState { app: None }).unwrap()
        else {
            panic!("state");
        };
        assert_eq!(v.active_apps, 0);
    }

    #[test]
    fn rate_sweep_reports_efficiency_per_rate() {
        let mut mk =
            || -> Result<Box<dyn ControlPlane>> { Ok(Box::new(local_master(4, "sweep"))) };
        let mut recs = |_rate: f64| mk_records(30, 0.0, 0.1);
        // absurdly high offered rates saturate; efficiency stays (0, 1]
        let points = rate_sweep(&mut mk, &mut recs, &[50.0, 1e9], 8, 0.0).unwrap();
        assert_eq!(points.len(), 2);
        for p in &points {
            assert!(p.efficiency > 0.0 && p.efficiency <= 1.0, "{p:?}");
            assert!(p.p50_submit_us >= 0.0);
            assert!(p.p99_submit_us >= p.p50_submit_us);
        }
        // an offered rate of 1e9/s cannot be achieved: the sweep reports
        // the saturation honestly
        assert!(points[1].efficiency < 1.0, "{points:?}");
    }
}

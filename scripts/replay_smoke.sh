#!/usr/bin/env bash
# Trace-replay smoke (DESIGN.md §13): exercise every `dorm replay` path
# end to end — schema-detected DES replay of the shipped sample traces,
# a generate -> export -> re-read round trip with a tight streaming
# buffer, a live replay against a real TCP master, and a one-point rate
# sweep.  Run from the repo root after `cargo build --release`; exits
# non-zero on any failed step.
set -euo pipefail

BIN=${BIN:-rust/target/release/dorm}
PORT=${PORT:-46013}
ADDR=127.0.0.1:$PORT
STORE=$(mktemp -d)
LOG=$(mktemp -d)
MASTER_PID=

cleanup() {
  [ -n "$MASTER_PID" ] && kill "$MASTER_PID" 2>/dev/null || true
  rm -rf "$STORE" "$LOG"
}
trap cleanup EXIT

fail() {
  echo "SMOKE FAIL: $1" >&2
  for f in "$LOG"/*.log; do
    [ -f "$f" ] || continue
    echo "--- $f ---" >&2; cat "$f" >&2
  done
  exit 1
}

echo "== DES replay of the shipped sample traces (schema detection)"
OUT=$("$BIN" replay --trace examples/traces/table2_sample.csv --mode des) \
  || fail "sample des replay exited non-zero: $OUT"
echo "$OUT" | grep -q "dorm schema" || fail "native schema not detected: $OUT"
echo "$OUT" | grep -q "16 records read" || fail "expected 16 records: $OUT"
echo "$OUT" | grep -Eq "[1-9][0-9]* completed" || fail "nothing completed: $OUT"

OUT=$("$BIN" replay --trace examples/traces/alibaba_mini.csv --mode des) \
  || fail "alibaba des replay exited non-zero: $OUT"
echo "$OUT" | grep -q "alibaba schema" || fail "alibaba schema not detected: $OUT"
echo "$OUT" | grep -q "8 records read" || fail "expected 8 records: $OUT"

echo "== generate -> export -> re-read round trip, tight buffer"
TRACE="$LOG/gen.csv"
OUT=$("$BIN" replay --gen 40 --seed 17 --export "$TRACE") || fail "export: $OUT"
echo "$OUT" | grep -q "wrote 40 records" || fail "export count: $OUT"
OUT=$("$BIN" replay --trace "$TRACE" --mode des --buffer 8) \
  || fail "re-read des replay: $OUT"
echo "$OUT" | grep -q "dorm schema" || fail "exported trace must be native: $OUT"
echo "$OUT" | grep -q "40 records read" || fail "expected 40 records back: $OUT"
# the O(buffer) guarantee, as printed: "streaming: max N records buffered (cap 8)"
MAXBUF=$(echo "$OUT" | sed -n 's/.*streaming: max \([0-9]*\) records buffered.*/\1/p')
[ -n "$MAXBUF" ] || fail "no streaming line in: $OUT"
[ "$MAXBUF" -le 8 ] || fail "buffer cap violated: $MAXBUF > 8"

echo "== hostile trace is a typed error, not a panic"
printf 'start_time,job_name,plan_cpu,plan_mem,duration\n0,a,100,4,60\n10,b,NaN,4,60\n' \
  > "$LOG/bad.csv"
if OUT=$("$BIN" replay --trace "$LOG/bad.csv" --mode des 2>&1); then
  fail "hostile trace accepted: $OUT"
fi
echo "$OUT" | grep -q "after 1 records" || fail "no typed trace error: $OUT"

echo "== live replay against a real TCP master"
"$BIN" master --bind "$ADDR" --slaves 8 --cpu 16 --gpu 2 --ram 64 \
  --store "$STORE" >"$LOG/master.log" 2>&1 &
MASTER_PID=$!
for _ in $(seq 1 50); do
  grep -q "listening" "$LOG/master.log" 2>/dev/null && break
  kill -0 "$MASTER_PID" 2>/dev/null || fail "master died during startup"
  sleep 0.1
done
grep -q "listening" "$LOG/master.log" || fail "master never started listening"

OUT=$("$BIN" replay --gen 30 --seed 17 --mode live --connect "$ADDR" --window 8) \
  || fail "live replay: $OUT"
echo "$OUT" | grep -q "30 submitted" || fail "expected 30 submissions: $OUT"
echo "$OUT" | grep -q "30 completed" || fail "window + drain must complete all 30: $OUT"
echo "$OUT" | grep -q " 0 rejected" || fail "master rejected submissions: $OUT"

"$BIN" ctl --connect "$ADDR" shutdown | grep -q ok || fail "shutdown not acknowledged"
for _ in $(seq 1 100); do
  kill -0 "$MASTER_PID" 2>/dev/null || break
  sleep 0.1
done
kill -0 "$MASTER_PID" 2>/dev/null && fail "master still running after shutdown"
wait "$MASTER_PID" 2>/dev/null || fail "master exited non-zero"
MASTER_PID=

echo "== one-point rate sweep (in-process master)"
OUT=$("$BIN" replay --gen 20 --seed 17 --mode sweep --rates 200 \
  --apps-per-rate 20 --window 8) || fail "rate sweep: $OUT"
echo "$OUT" | grep -q "rate sweep: 20 jobs per rate" || fail "sweep header: $OUT"
echo "$OUT" | grep -q "offered/s" || fail "sweep table missing: $OUT"

echo "SMOKE PASS: des + export round-trip + live + sweep all clean"

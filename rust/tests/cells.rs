//! Sharded-scheduler invariants (DESIGN.md §12).
//!
//! Three guarantees pin the `sched::cells` layer to the single-engine
//! semantics it wraps:
//!
//! 1. **cells = 1 parity** — a one-cell `CellScheduler` replays a
//!    scripted fault workload (kills + recoveries mid-run) with the
//!    *identical allocation sequence* to the plain `DormPolicy`.  The
//!    fast path is the old code path; this test breaks if it drifts.
//! 2. **scatter/gather totals** — for any cell count, the gathered
//!    per-cell [`CellView`]s sum to the cluster totals (capacity, usage,
//!    app count) a single view would report.
//! 3. **rebalance safety** — an aggressively-rebalancing configuration
//!    (every event, threshold 1.0) never produces a placement that
//!    overflows any server's capacity.

use std::collections::BTreeMap;

use dorm::app::{AppId, AppSpec, CheckpointStore, Engine};
use dorm::config::{CellsConfig, ClusterConfig, DormConfig, SimConfig};
use dorm::fault::FailureEvent;
use dorm::master::DormMaster;
use dorm::proto::Request;
use dorm::resources::Res;
use dorm::sched::{AllocationUpdate, CellScheduler, CmsPolicy, DormPolicy, SchedCtx};
use dorm::sim::{run_sim_faulty, PerfModel};
use dorm::util::prop;
use dorm::workload::{Table2Row, WorkloadApp};

const CFG: DormConfig = DormConfig { theta1: 0.3, theta2: 0.34 };

fn store(tag: &str) -> CheckpointStore {
    let d = std::env::temp_dir().join(format!("dorm_cells_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    CheckpointStore::new(d).unwrap()
}

fn spec(cpu: f64, ram: f64, weight: u32, n_min: u32, n_max: u32) -> AppSpec {
    AppSpec {
        executor: Engine::MxNet,
        demand: Res::cpu_gpu_ram(cpu, 0.0, ram),
        weight,
        n_max,
        n_min,
        cmd: ["cells".into(), "cells".into()],
    }
}

// ---- 1. cells=1 parity on a scripted fault workload ---------------------

/// Wraps a policy and records every event's decided container counts
/// (mirrors tests/parity.rs, but forwards the capacity-change hook so the
/// fault trace exercises cache invalidation identically on both sides).
struct Recording {
    inner: Box<dyn CmsPolicy>,
    log: Vec<BTreeMap<AppId, u32>>,
}

impl CmsPolicy for Recording {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn on_change(&mut self, ctx: &SchedCtx) -> Option<AllocationUpdate> {
        let update = self.inner.on_change(ctx);
        let counts: BTreeMap<AppId, u32> = ctx
            .apps
            .values()
            .map(|a| {
                let c = match &update {
                    Some(u) => u
                        .assignment
                        .get(&a.id)
                        .map(|row| row.values().sum())
                        .unwrap_or(0),
                    None => a.containers,
                };
                (a.id, c)
            })
            .collect();
        self.log.push(counts);
        update
    }

    fn on_capacity_change(&mut self) {
        self.inner.on_capacity_change();
    }

    fn admission_latency_hours(&self) -> f64 {
        self.inner.admission_latency_hours()
    }

    fn progress_factor(&self) -> f64 {
        self.inner.progress_factor()
    }
}

/// Drive the scripted fault workload through `policy`, returning the
/// per-event allocation log.
fn fault_run(policy: Box<dyn CmsPolicy>) -> Vec<BTreeMap<AppId, u32>> {
    let shapes = [
        (2.0, 8.0, 1, 1, 24, 0.0, 1.0),
        (2.0, 6.0, 2, 1, 24, 0.3, 2.0),
        (4.0, 6.0, 1, 1, 8, 0.7, 1.5),
        (2.0, 8.0, 1, 1, 24, 4.0, 1.0),
    ];
    let rows: Vec<Table2Row> = shapes
        .iter()
        .map(|&(cpu, ram, weight, n_min, n_max, _, dur)| Table2Row {
            engine: Engine::MxNet,
            dataset: "synthetic",
            model: "cells",
            demand: Res::cpu_gpu_ram(cpu, 0.0, ram),
            weight,
            n_max,
            n_min,
            num: 1,
            baseline_containers: 8,
            duration_median_hours: dur,
        })
        .collect();
    let workload: Vec<WorkloadApp> = shapes
        .iter()
        .enumerate()
        .map(|(i, &(.., submit, dur))| WorkloadApp {
            row: i,
            tag: format!("app{i}"),
            submit_hours: submit,
            duration_at_baseline_hours: dur,
            baseline_n: 8,
        })
        .collect();
    // two kill/recover pairs straddling the arrivals: capacity shrinks,
    // apps are displaced and re-placed, then capacity returns
    let faults = [
        FailureEvent::kill(1.0, 1),
        FailureEvent::recover(2.0, 1),
        FailureEvent::kill(3.0, 3),
        FailureEvent::recover(4.5, 3),
    ];
    let cluster = ClusterConfig::uniform(4, Res::cpu_gpu_ram(12.0, 0.0, 64.0));
    let sim = SimConfig { horizon_hours: 24.0, ..Default::default() };
    let mut pol = Recording { inner: policy, log: Vec::new() };
    let out = run_sim_faulty(
        &mut pol,
        &rows,
        &workload,
        &cluster,
        &sim,
        &PerfModel::default(),
        &faults,
    );
    assert_eq!(out.completed, shapes.len(), "fault trace must fully drain");
    pol.log
}

#[test]
fn one_cell_replays_fault_workload_identically_to_single_engine() {
    let single = fault_run(Box::new(DormPolicy::new(CFG)));
    let one_cell = fault_run(Box::new(CellScheduler::new(
        CFG,
        CellsConfig { count: 1, ..CellsConfig::default() },
        4,
    )));
    assert_eq!(
        single.len(),
        one_cell.len(),
        "both backends must see the same event count"
    );
    for (ev, (a, b)) in single.iter().zip(&one_cell).enumerate() {
        assert_eq!(a, b, "allocation diverged at event {ev}");
    }
}

/// Multi-cell smoke on the same trace: a 2-cell scheduler must also fully
/// drain the fault workload (allocations may differ from the single
/// engine — only liveness is pinned here; `fault_run` asserts the drain).
#[test]
fn two_cells_drain_the_fault_workload() {
    fault_run(Box::new(CellScheduler::new(
        CFG,
        CellsConfig { count: 2, ..CellsConfig::default() },
        4,
    )));
}

// ---- 2. gathered views sum to the single-view totals --------------------

#[test]
fn gathered_cell_views_total_to_cluster_state() {
    let case = std::sync::atomic::AtomicU64::new(0);
    prop::check(25, |rng| {
        let tag = case.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let n_servers = rng.range_u64(2, 8) as usize;
        let count = rng.range_u64(1, 4) as usize;
        let cap = Res::cpu_gpu_ram(16.0, 0.0, 64.0);
        let cells = CellsConfig {
            count,
            rebalance_every: rng.range_u64(1, 6),
            imbalance_threshold: 1.0 + rng.f64(),
        };
        let mut m = DormMaster::with_cells(
            &ClusterConfig::uniform(n_servers, cap.clone()),
            CFG,
            &cells,
            store(&format!("views{tag}")),
        );
        // integer-valued demands keep the f64 totals exactly summable
        let napps = rng.range_u64(1, 6);
        let mut demands: BTreeMap<AppId, Res> = BTreeMap::new();
        for _ in 0..napps {
            let d = Res::cpu_gpu_ram(
                rng.range_u64(1, 3) as f64,
                0.0,
                rng.range_u64(2, 8) as f64,
            );
            let id = m
                .submit(AppSpec {
                    executor: Engine::MxNet,
                    demand: d.clone(),
                    weight: rng.range_u64(1, 3) as u32,
                    n_max: rng.range_u64(2, 8) as u32,
                    n_min: 1,
                    cmd: ["cells".into(), "cells".into()],
                })
                .map_err(|e| format!("submit refused: {e:#}"))?;
            demands.insert(id, d);
        }
        // one no-op event so the views reflect the *applied* allocation
        // (views are captured at decision time, one event behind)
        m.dispatch(Request::Reallocate);
        let views = m.cell_views().expect("sharded master exposes views");
        if views.len() != count.min(n_servers) {
            return Err(format!("{} views for {count} cells", views.len()));
        }
        let mut cap_total = Res::zeros(3);
        let mut used_total = Res::zeros(3);
        let mut apps_total = 0u32;
        for v in &views {
            cap_total += &v.capacity;
            used_total += &v.used;
            apps_total += v.apps;
        }
        let want_cap = cap.times(n_servers as u32);
        if cap_total != want_cap {
            return Err(format!("capacity {cap_total:?} != {want_cap:?}"));
        }
        if apps_total as u64 != napps {
            return Err(format!("{apps_total} routed apps != {napps} submitted"));
        }
        let mut want_used = Res::zeros(3);
        for (&id, d) in &demands {
            want_used += &d.times(m.containers_of(id));
        }
        if used_total != want_used {
            return Err(format!("usage {used_total:?} != {want_used:?}"));
        }
        Ok(())
    });
}

// ---- 3. rebalance never overflows a server ------------------------------

#[test]
fn aggressive_rebalance_never_violates_capacity() {
    let case = std::sync::atomic::AtomicU64::new(0);
    prop::check(10, |rng| {
        let tag = case.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let n_servers = 8;
        let cells = CellsConfig {
            count: rng.range_u64(2, 4) as usize,
            rebalance_every: 1,     // consider migrating on every event
            imbalance_threshold: 1.0, // any imbalance triggers it
        };
        let mut m = DormMaster::with_cells(
            &ClusterConfig::uniform(n_servers, Res::cpu_gpu_ram(12.0, 0.0, 64.0)),
            CFG,
            &cells,
            store(&format!("rebal{tag}")),
        );
        let mut live: Vec<AppId> = Vec::new();
        for _ in 0..30 {
            if live.is_empty() || rng.f64() < 0.7 {
                let id = m
                    .submit(spec(
                        rng.range_u64(1, 3) as f64,
                        rng.range_u64(2, 8) as f64,
                        1,
                        1,
                        rng.range_u64(2, 12) as u32,
                    ))
                    .map_err(|e| format!("submit refused: {e:#}"))?;
                live.push(id);
            } else {
                let i = rng.below(live.len() as u64) as usize;
                let id = live.swap_remove(i);
                m.complete(id).map_err(|e| format!("complete failed: {e:#}"))?;
            }
            for s in &m.slaves {
                if !s.used().fits_in(s.capacity()) {
                    return Err(format!(
                        "server {} overflows: used {:?} capacity {:?}",
                        s.name,
                        s.used(),
                        s.capacity()
                    ));
                }
            }
        }
        Ok(())
    });
}

//! # Dorm — dynamically-partitioned cluster management for distributed ML
//!
//! Reproduction of Sun et al., *"Towards Distributed Machine Learning in
//! Shared Clusters: A Dynamically-Partitioned Approach"* (IEEE SMARTCOMP
//! 2017).  See `DESIGN.md` (repo root) for the system inventory and design
//! notes, and `ROADMAP.md` for the growth plan and open items.
//!
//! The crate is the L3 (coordination) layer of a three-layer stack:
//!
//! * **L3 (this crate)** — DormMaster/DormSlave cluster manager, the
//!   utilization–fairness optimizer (our own simplex + branch-and-bound MILP
//!   solver standing in for CPLEX), the checkpoint-based resource-adjustment
//!   protocol, a parameter-server training runtime, the baseline CMSs, and a
//!   discrete-event simulator that regenerates every figure of the paper.
//! * **L2 (python/compile/model.py, build-time)** — the hosted ML models
//!   (LR / MF / transformer LM) as flat-parameter `init/grad/apply` JAX
//!   functions, AOT-lowered to HLO text in `artifacts/`.
//! * **L1 (python/compile/kernels/, build-time)** — Pallas kernels (tiled
//!   fused matmul, flash attention) called from L2.
//!
//! Python never runs at request time: [`runtime`] loads the HLO artifacts
//! through the PJRT C API (`xla` crate) and [`ps`] trains with them.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`resources`] | m-typed resource algebra (Eqs 1–2 foundations) |
//! | [`drf`] | dominant-resource-fairness progressive filling (ŝᵢ) |
//! | [`solver`] | simplex LP + branch-and-bound MILP + heuristic |
//! | [`optimizer`] | builds the paper's P2 from cluster state, solves it |
//! | [`sched`] | shared allocation engine + policy interface (master ∩ sim), cached/warm-started re-solves; `sched::cells` = sharded multi-cell scheduler, parallel per-cell solves behind a scatter/gather root (DESIGN.md §12) |
//! | [`cluster`] | servers, partitions, containers; delta-aware packer + slack-indexed best fit (DESIGN.md §10) |
//! | [`app`] | application 6-tuple, lifecycle, checkpoints |
//! | [`master`] / [`slave`] | the Dorm control plane; `master::ha` = master self-checkpoints + WAL + epoch-fenced takeover (DESIGN.md §11) |
//! | [`proto`] | versioned control-plane protocol: typed Request/Response + wire format, epoch-stamped responses |
//! | [`net`] | transports: in-process dispatch, multiplexed TCP server (sharded worker pool, coalesced heartbeats; thread-per-connection `serve_legacy` baseline retained, DESIGN.md §15), TCP/failover clients (candidate re-dial + stale-epoch fencing), slave agent loop, standby watcher, closed-loop load generator |
//! | [`fault`] | server liveness (leases), failure injection (server + master outages), checkpoint-driven recovery, churn experiments; `fault::domains` = rack/power failure-domain topology + online MTBF estimation feeding risk-aware placement (DESIGN.md §14) |
//! | [`ps`] | BSP parameter-server runtime (the "MxNet" stand-in) |
//! | [`runtime`] | PJRT executor service for `artifacts/*.hlo.txt` |
//! | [`sim`] | discrete-event simulator (Figs 6–9) |
//! | [`workload`] | Table II + Fig 1 workload models; `workload::trace` streams recorded traces through the DES and the live master (DESIGN.md §13) |
//! | [`baselines`] | static (Swarm) and two-level (Mesos) comparators |
//! | [`metrics`] | utilization / fairness-loss / adjustment time series |
//! | [`config`] | TOML-subset config system (no serde in this image) |
//! | [`report`] | ASCII tables + CSV emitters for the benches |
//! | [`util`] | PRNG, stats, property-testing mini-framework, logging |

pub mod app;
pub mod baselines;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod drf;
pub mod fault;
pub mod master;
pub mod metrics;
pub mod net;
pub mod optimizer;
pub mod proto;
pub mod ps;
pub mod report;
pub mod resources;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod slave;
pub mod solver;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

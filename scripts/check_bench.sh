#!/usr/bin/env bash
# Bench regression gate: diff a fresh BENCH_sched.json (emitted by
# `scripts/bench_sched.sh`) against the checked-in baseline in
# BENCH_baseline/, failing on a >25% latency regression of the
# incremental decision path at any sweep point.
#
# Usage, from the repo root:
#   bash scripts/check_bench.sh                 # compare (CI gate)
#   bash scripts/check_bench.sh --update        # bless the fresh numbers
#
# Knobs: DORM_BENCH_JSON (fresh file, default ./BENCH_sched.json),
#        DORM_BENCH_TOLERANCE (ratio, default 1.25).
#
# The baseline records new.p50_us per (apps, servers) scale, plus p50_us
# per (cells, apps, servers) point of the sharded-scheduler sweep, plus
# p50_submit_us and efficiency per offered rate of the trace-replay sweep
# (the "replay" series from benches/replay_rate.rs), plus p50_us and
# req_per_sec per (server, clients) point of the control-plane saturation
# sweep (the "rpc" series from benches/rpc_throughput.rs).  p50 is the
# gated statistic — p99 on shared CI runners is too noisy to gate on and
# is reported for information only; replay efficiency is gated on an
# absolute 0.25 slide rather than a ratio; rpc req/s is gated as a floor
# (fresh >= baseline / tolerance) and the mux-vs-legacy speedup must stay
# above a conservative 1.2x (the full 4x headline is asserted by the
# bench itself under DORM_RPC_ENFORCE=1, where the runner is quiet enough
# to trust a fixed multiplier).  Sweep points present in only one of the
# two files are reported and skipped, so changing the sweep scales does
# not wedge the gate (refresh the baseline in the same PR instead).
set -euo pipefail
cd "$(dirname "$0")/.."

FRESH="${DORM_BENCH_JSON:-$PWD/BENCH_sched.json}"
BASELINE="BENCH_baseline/BENCH_sched.json"

if [ "${1:-}" = "--update" ]; then
  [ -f "$FRESH" ] || { echo "no fresh $FRESH to bless; run scripts/bench_sched.sh first" >&2; exit 2; }
  mkdir -p BENCH_baseline
  cp "$FRESH" "$BASELINE"
  echo "blessed $FRESH -> $BASELINE"
  exit 0
fi

[ -f "$FRESH" ] || { echo "fresh $FRESH missing; run scripts/bench_sched.sh first" >&2; exit 2; }
[ -f "$BASELINE" ] || { echo "baseline $BASELINE missing" >&2; exit 2; }

python3 - "$FRESH" "$BASELINE" "${DORM_BENCH_TOLERANCE:-1.25}" <<'PY'
import json, sys

fresh_path, base_path, tol = sys.argv[1], sys.argv[2], float(sys.argv[3])
fresh = json.load(open(fresh_path))
base = json.load(open(base_path))

def points(doc):
    return {(s["apps"], s["servers"]): s for s in doc.get("scales", [])}

fp, bp = points(fresh), points(base)
failures, compared = [], 0
for key in sorted(fp):
    if key not in bp:
        print(f"  note: scale {key[0]}x{key[1]} has no baseline; skipped")
        continue
    compared += 1
    got = fp[key]["new"]["p50_us"]
    ref = bp[key]["new"]["p50_us"]
    ratio = got / ref if ref > 0 else float("inf")
    verdict = "OK" if ratio <= tol else "REGRESSION"
    print(f"  {key[0]}x{key[1]}: new p50 {got:.1f} us vs baseline {ref:.1f} us "
          f"({ratio:.2f}x, tolerance {tol:.2f}x) {verdict}")
    p99g, p99r = fp[key]["new"].get("p99_us"), bp[key]["new"].get("p99_us")
    if p99g is not None and p99r is not None and p99r > 0:
        print(f"      (p99 {p99g:.1f} vs {p99r:.1f} us, informational)")
    if ratio > tol:
        failures.append(key)
for key in sorted(set(bp) - set(fp)):
    print(f"  note: baseline scale {key[0]}x{key[1]} not in fresh run; skipped")

def replay_points(doc):
    return {p["rate_per_sec"]: p for p in doc.get("replay", {}).get("rates", [])}

fr, br = replay_points(fresh), replay_points(base)
for rate in sorted(fr):
    label = f"replay@{rate:.0f}/s"
    if rate not in br:
        print(f"  note: {label} has no baseline; skipped")
        continue
    compared += 1
    got, ref = fr[rate]["p50_submit_us"], br[rate]["p50_submit_us"]
    ratio = got / ref if ref > 0 else float("inf")
    verdict = "OK" if ratio <= tol else "REGRESSION"
    print(f"  {label}: submit p50 {got:.1f} us vs baseline {ref:.1f} us "
          f"({ratio:.2f}x, tolerance {tol:.2f}x) {verdict}")
    if ratio > tol:
        failures.append((label, 0))
    # efficiency is a floor, not a latency: gate on an absolute slide so
    # noise near 1.0 never trips it, a saturation collapse always does
    eg, er = fr[rate]["efficiency"], br[rate]["efficiency"]
    if eg < er - 0.25:
        print(f"  {label}: efficiency {eg:.3f} collapsed vs baseline {er:.3f} REGRESSION")
        failures.append((f"{label}-efficiency", 0))
    else:
        print(f"      (efficiency {eg:.3f} vs baseline {er:.3f})")
for rate in sorted(set(br) - set(fr)):
    print(f"  note: baseline replay rate {rate:.0f}/s not in fresh run; skipped")

def cell_points(doc):
    return {(s["cells"], s["apps"], s["servers"]): s for s in doc.get("cells", [])}

fc, bc = cell_points(fresh), cell_points(base)
for key in sorted(fc):
    cells, apps, servers = key
    label = f"{apps}x{servers}@{cells}c"
    if key not in bc:
        print(f"  note: cells point {label} has no baseline; skipped")
        continue
    compared += 1
    got = fc[key]["p50_us"]
    ref = bc[key]["p50_us"]
    ratio = got / ref if ref > 0 else float("inf")
    verdict = "OK" if ratio <= tol else "REGRESSION"
    print(f"  {label}: p50 {got:.1f} us vs baseline {ref:.1f} us "
          f"({ratio:.2f}x, tolerance {tol:.2f}x) {verdict}")
    if ratio > tol:
        failures.append((f"{apps}@{cells}c", servers))
for key in sorted(set(bc) - set(fc)):
    print(f"  note: baseline cells point {key[1]}x{key[2]}@{key[0]}c not in fresh run; skipped")

def rpc_points(doc):
    return {(p["server"], p["clients"]): p for p in doc.get("rpc", {}).get("points", [])}

fq, bq = rpc_points(fresh), rpc_points(base)
for key in sorted(fq):
    server, clients = key
    label = f"rpc:{server}@{clients}"
    if key not in bq:
        print(f"  note: {label} has no baseline; skipped")
        continue
    compared += 1
    got, ref = fq[key]["p50_us"], bq[key]["p50_us"]
    ratio = got / ref if ref > 0 else float("inf")
    verdict = "OK" if ratio <= tol else "REGRESSION"
    print(f"  {label}: p50 {got:.1f} us vs baseline {ref:.1f} us "
          f"({ratio:.2f}x, tolerance {tol:.2f}x) {verdict}")
    if ratio > tol:
        failures.append((label, 0))
    # throughput is a floor, not a latency: the fresh run must sustain at
    # least baseline/tolerance req/s at the same point
    gr, rr = fq[key]["req_per_sec"], bq[key]["req_per_sec"]
    floor = rr / tol
    if gr < floor:
        print(f"  {label}: {gr:.0f} req/s fell past the {floor:.0f} req/s floor "
              f"(baseline {rr:.0f}) REGRESSION")
        failures.append((f"{label}-throughput", 0))
    else:
        print(f"      ({gr:.0f} req/s vs baseline {rr:.0f}, floor {floor:.0f})")
for key in sorted(set(bq) - set(fq)):
    print(f"  note: baseline rpc point {key[0]}@{key[1]} not in fresh run; skipped")

fs = fresh.get("rpc", {}).get("speedup_mux_vs_legacy")
bs = base.get("rpc", {}).get("speedup_mux_vs_legacy")
if fs is not None:
    compared += 1
    # the headline: the multiplexed server must actually beat the
    # thread-per-connection baseline.  A conservative 1.2x floor is gated
    # here (shared runners); the full 4x claim is asserted by the bench
    # itself under DORM_RPC_ENFORCE=1 on a quiet machine.
    base_note = f" (baseline {bs:.2f}x)" if bs is not None else ""
    if fs < 1.2:
        print(f"  rpc: mux/legacy speedup {fs:.2f}x{base_note} fell below the "
              f"1.2x floor REGRESSION")
        failures.append(("rpc-speedup", 0))
    else:
        print(f"  rpc: mux/legacy sustained speedup {fs:.2f}x{base_note} OK")

if compared == 0:
    print("no comparable sweep points between fresh and baseline", file=sys.stderr)
    sys.exit(2)
if failures:
    scales = ", ".join(f"{a}x{s}" if s else str(a) for a, s in failures)
    print(f"bench gate FAILED at {scales}: latency/throughput regressed past "
          f"the {tol:.2f}x tolerance envelope.", file=sys.stderr)
    print("If the regression is intended (or the baseline is stale), refresh it:\n"
          "  bash scripts/bench_sched.sh ci && bash scripts/check_bench.sh --update",
          file=sys.stderr)
    sys.exit(1)
print("bench gate passed")
PY

//! Trace subsystem end-to-end tests (DESIGN.md §13).
//!
//! The two acceptance properties of the streaming replay driver:
//!
//! 1. **Parity** — replaying a workload through the streaming path
//!    (export → CSV → `TraceReader` → bounded `TraceSource`) drives the
//!    *byte-identical* DES event sequence as the materialized
//!    `SliceSource` path, across random seeds and workload sizes.
//! 2. **Bounded memory** — a 100 000-arrival trace streams through the
//!    DES while the driver's buffer high-water mark stays at the
//!    configured cap (the O(buffer) guarantee).
//!
//! Plus the hostile-input contract: malformed traces (missing columns,
//! non-monotone timestamps, NaN/negative demands, truncated rows) are
//! typed [`TraceError`]s, never panics.

use std::io::Cursor;

use dorm::app::Engine;
use dorm::baselines::StaticPolicy;
use dorm::config::{ClusterConfig, SimConfig};
use dorm::resources::Res;
use dorm::sim::{run_sim_stream_traced, PerfModel, SliceSource};
use dorm::util::prop;
use dorm::workload::trace::{
    export_workload, replay_des, ReplayOpts, TraceError, TraceReader, TraceRecord, TraceSchema,
    TraceSource,
};
use dorm::workload::WorkloadSpec;

/// Streaming replay ≡ materialized replay, byte for byte.  The workload
/// is synthesized from a random seed, exported as CSV, re-read through
/// the schema-detecting reader, and streamed through a deliberately tiny
/// buffer; the traced event logs of both runs must match exactly — same
/// events, same order, same times, same app ids.
#[test]
fn streaming_replay_matches_materialized_byte_for_byte() {
    let cfg = ClusterConfig::paper_testbed();
    let pm = PerfModel::default();
    prop::check(6, |rng| {
        let spec = WorkloadSpec {
            napps: 8 + rng.below(16) as usize,
            ..WorkloadSpec::paper(rng.below(1_000))
        };
        let rows = spec.rows();
        let wl = spec.generate();
        // short horizon so some arrivals fall beyond it: the streaming
        // path must drop the same suffix the materialized path drops
        let sim = SimConfig { horizon_hours: 5.0, seed: spec.seed, ..Default::default() };

        let mut p1 = StaticPolicy::new();
        let mut materialized = SliceSource::new(&rows, &wl);
        let (a, log_a) =
            run_sim_stream_traced(&mut p1, &mut materialized, &cfg, &sim, &pm, &[]);

        let mut csv = Vec::new();
        export_workload(&mut csv, &rows, &wl).map_err(|e| e.to_string())?;
        let reader = TraceReader::new(Cursor::new(&csv)).map_err(|e| e.to_string())?;
        if reader.schema() != TraceSchema::Dorm {
            return Err("export must emit the native schema".into());
        }
        let mut streamed = TraceSource::new(reader, ReplayOpts { buffer: 3, ..Default::default() });
        let mut p2 = StaticPolicy::new();
        let (b, log_b) = run_sim_stream_traced(&mut p2, &mut streamed, &cfg, &sim, &pm, &[]);

        if streamed.error().is_some() {
            return Err(format!("clean trace errored: {:?}", streamed.error()));
        }
        if streamed.max_buffered() > 3 {
            return Err(format!("buffer cap violated: {}", streamed.max_buffered()));
        }
        if log_a.join("\n") != log_b.join("\n") {
            let diff = log_a
                .iter()
                .zip(log_b.iter())
                .position(|(x, y)| x != y)
                .map(|i| format!("first divergence at event {i}: {:?} vs {:?}", log_a[i], log_b[i]))
                .unwrap_or_else(|| format!("lengths differ: {} vs {}", log_a.len(), log_b.len()));
            return Err(format!("event logs diverge (seed {}): {diff}", spec.seed));
        }
        if a.completed != b.completed || a.arrivals != b.arrivals {
            return Err(format!(
                "outcomes diverge: {}/{} vs {}/{}",
                a.completed, a.arrivals, b.completed, b.arrivals
            ));
        }
        Ok(())
    });
}

fn flat_record(duration_hours: f64) -> TraceRecord {
    TraceRecord {
        submit_hours: 0.0, // closed-loop replay assigns the times
        tag: "j".into(),
        engine: Engine::MxNet,
        demand: Res::cpu_gpu_ram(1.0, 0.0, 1.0),
        weight: 1.0,
        n_min: 1,
        n_max: 1,
        baseline_n: 1,
        duration_hours,
        priority: None,
        user: None,
    }
}

/// The ISSUE acceptance test: 100k arrivals stream through the DES from
/// a generator (never materialized anywhere), and the driver's buffer
/// high-water mark stays at the configured cap.
#[test]
fn hundred_k_arrivals_stream_in_bounded_memory() {
    const N: usize = 100_000;
    const BUFFER: usize = 256;
    // sustained 50k arrivals/hour of tiny one-container jobs: the active
    // set stays ~10 apps, so the whole trace both fits the horizon and
    // drains — what makes O(N) DES work feasible behind an O(1) driver
    let records = (0..N).map(|_| Ok(flat_record(0.0002)));
    let opts = ReplayOpts { buffer: BUFFER, rate_per_hour: 50_000.0, ..Default::default() };
    let cluster = ClusterConfig::uniform(4, Res::cpu_gpu_ram(16.0, 0.0, 64.0));
    let sim = SimConfig { horizon_hours: 3.0, sample_period_min: 60.0, ..Default::default() };
    let pm = PerfModel::default();
    let mut pol = StaticPolicy::new();
    let rep = replay_des(&mut pol, records, opts, &cluster, &sim, &pm).unwrap();
    assert_eq!(rep.records_read, N as u64);
    assert_eq!(rep.outcome.arrivals, N, "every arrival fits the horizon");
    assert!(
        rep.max_buffered <= BUFFER,
        "driver must hold O(buffer) records, saw {} > {BUFFER}",
        rep.max_buffered
    );
    assert!(
        rep.outcome.completed > N - 100,
        "tiny jobs should drain: completed {}",
        rep.outcome.completed
    );
}

/// Export → reader round trip at the integration level: the sample trace
/// shipped in `examples/traces/` parses as the native schema and replays.
#[test]
fn shipped_sample_trace_replays() {
    let text = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .unwrap()
            .join("examples/traces/table2_sample.csv"),
    )
    .expect("examples/traces/table2_sample.csv ships with the repo");
    let reader = TraceReader::new(Cursor::new(text.as_bytes())).unwrap();
    assert_eq!(reader.schema(), TraceSchema::Dorm);
    let cluster = ClusterConfig::paper_testbed();
    let sim = SimConfig { horizon_hours: 24.0, ..Default::default() };
    let mut pol = StaticPolicy::new();
    let rep = replay_des(
        &mut pol,
        reader,
        ReplayOpts { buffer: 4, ..Default::default() },
        &cluster,
        &sim,
        &PerfModel::default(),
    )
    .unwrap();
    assert!(rep.records_read >= 10, "{}", rep.records_read);
    assert!(rep.outcome.completed > 0);
    assert!(rep.max_buffered <= 4);
}

/// Hostile inputs are typed errors — at the reader layer and surfaced
/// through a full DES replay — never panics, and never partial results
/// passed off as complete.
#[test]
fn hostile_traces_give_typed_errors_never_panics() {
    // no header at all
    assert_eq!(TraceReader::new(Cursor::new("")).err(), Some(TraceError::EmptyTrace));
    // unknown layout
    let e = TraceReader::new(Cursor::new("foo,bar,baz\n1,2,3\n")).err().unwrap();
    assert!(matches!(e, TraceError::UnknownSchema { .. }), "{e:?}");
    // missing required column (alibaba without plan_mem)
    let e = TraceReader::new(Cursor::new("start_time,job_name,plan_cpu,duration\n"))
        .err()
        .unwrap();
    assert_eq!(e, TraceError::MissingColumn { schema: "alibaba", column: "plan_mem" });

    let cluster = ClusterConfig::uniform(4, Res::cpu_gpu_ram(16.0, 0.0, 64.0));
    let sim = SimConfig::default();
    let pm = PerfModel::default();
    let run = |text: &str| -> anyhow::Error {
        let reader = TraceReader::new(Cursor::new(text.to_string())).unwrap();
        let mut pol = StaticPolicy::new();
        replay_des(&mut pol, reader, ReplayOpts::default(), &cluster, &sim, &pm)
            .err()
            .expect("hostile trace must fail the replay")
    };
    const HDR: &str = "start_time,job_name,plan_cpu,plan_mem,duration\n";
    // NaN demand
    let e = run(&format!("{HDR}0,a,100,4,60\n10,b,NaN,4,60\n"));
    assert!(e.to_string().contains("after 1 records"), "{e}");
    assert!(e.to_string().contains("not finite"), "{e}");
    // negative demand
    let e = run(&format!("{HDR}0,a,-100,4,60\n"));
    assert!(e.to_string().contains("must be >= 0"), "{e}");
    // non-monotone timestamps
    let e = run(&format!("{HDR}3600,a,100,4,60\n0,b,100,4,60\n"));
    assert!(e.to_string().contains("went backwards"), "{e}");
    // truncated row
    let e = run(&format!("{HDR}0,a,100,4,60\n10,b,100\n"));
    assert!(e.to_string().contains("expected 5 fields, got 3"), "{e}");
    // zero duration
    let e = run(&format!("{HDR}0,a,100,4,0\n"));
    assert!(e.to_string().contains("must be > 0"), "{e}");
}

/// The one-seed guarantee: the same `--seed` reproduces the same trace
/// whether materialized, streamed, or exported and re-read.
#[test]
fn single_seed_reproduces_trace_everywhere() {
    let spec = WorkloadSpec::paper(42);
    let a: Vec<_> = spec.stream().take(500).collect();
    let b: Vec<_> = spec.stream().take(500).collect();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.submit_hours.to_bits(), y.submit_hours.to_bits());
        assert_eq!(
            x.duration_at_baseline_hours.to_bits(),
            y.duration_at_baseline_hours.to_bits()
        );
        assert_eq!(x.row, y.row);
    }
    // and the materialized path is independent of the streaming fork
    let m1 = spec.generate();
    let m2 = WorkloadSpec::paper(42).generate();
    assert_eq!(m1.len(), m2.len());
    for (x, y) in m1.iter().zip(&m2) {
        assert_eq!(x.submit_hours.to_bits(), y.submit_hours.to_bits());
    }
}

//! The application 6-tuple and lifecycle state machine.
//!
//! §III-B: a submission is `(executor, d, w, n_max, n_min, cmd)`.  Here
//! `executor` is an [`Engine`] (the distributed-ML system the app runs on —
//! in this repo every engine is served by the in-crate PS runtime, see
//! DESIGN.md §1), `d` a per-container [`Res`] demand, `w` an integer
//! weight, and `cmd` names the model artifact to start/resume with.

use std::fmt;

use anyhow::{bail, Result};

use crate::resources::Res;

/// Opaque application identifier assigned by the master at submission.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct AppId(pub u64);

impl fmt::Display for AppId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "app{}", self.0)
    }
}

/// The computation engine requested by the user (paper Table II column 1).
/// All four production systems are substituted by the in-crate PS runtime;
/// the enum is kept so workloads round-trip the paper's submission tuples.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Engine {
    MxNet,
    TensorFlow,
    Petuum,
    MpiCaffe,
}

impl Engine {
    pub fn parse(s: &str) -> Result<Engine> {
        Ok(match s {
            "MxNet" | "mxnet" => Engine::MxNet,
            "TensorFlow" | "tensorflow" => Engine::TensorFlow,
            "Petuum" | "petuum" => Engine::Petuum,
            "MPI-Caffe" | "mpi-caffe" | "caffe" => Engine::MpiCaffe,
            other => bail!("unknown engine {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Engine::MxNet => "MxNet",
            Engine::TensorFlow => "TensorFlow",
            Engine::Petuum => "Petuum",
            Engine::MpiCaffe => "MPI-Caffe",
        }
    }
}

/// The §III-B submission 6-tuple.
#[derive(Clone, Debug, PartialEq)]
pub struct AppSpec {
    pub executor: Engine,
    /// Per-container resource demand `d`.
    pub demand: Res,
    /// Weight `w` (≥ 1).
    pub weight: u32,
    pub n_max: u32,
    pub n_min: u32,
    /// `cmd`: [start, resume] — here the model name in `artifacts/manifest.kv`
    /// plus free-form args (the PS runtime interprets them).
    pub cmd: [String; 2],
}

impl AppSpec {
    /// Validate the tuple the way DormMaster does at submission time.
    pub fn validate(&self) -> Result<()> {
        if self.n_min == 0 {
            bail!("n_min must be >= 1 (an admitted app needs a partition)");
        }
        if self.n_min > self.n_max {
            bail!("n_min {} > n_max {}", self.n_min, self.n_max);
        }
        if self.weight == 0 {
            bail!("weight must be >= 1");
        }
        if self.demand.is_zero() {
            bail!("demand must be non-zero");
        }
        if self.demand.0.iter().any(|&d| d < 0.0) {
            bail!("demand must be non-negative");
        }
        if self.demand.0.iter().any(|&d| !d.is_finite()) {
            bail!("demand must be finite");
        }
        Ok(())
    }
}

/// Lifecycle states (§III-C-2 adjustment protocol + Fig. 5, extended with
/// the fault path of `crate::fault`).
///
/// ```text
/// Submitted -> Pending -> Running <-> Checkpointing -> Killed -> Resuming -> Running
///                             \-> Completed
///                             \-> Degraded -> Recovering -> Running   (server death)
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppState {
    /// Accepted, waiting for the optimizer to admit it.
    Pending,
    /// Tasks executing on its partition.
    Running,
    /// State being saved to reliable storage prior to a kill.
    Checkpointing,
    /// Containers destroyed; state lives only in the checkpoint store.
    Killed,
    /// Containers recreated; restoring from checkpoint.
    Resuming,
    /// A server death broke the partition: containers reclaimed, progress
    /// since the last checkpoint lost, waiting for the optimizer to
    /// re-place the app.  Unlike [`AppState::Killed`] nothing was saved
    /// first — the failure decides the timing, not the protocol.
    Degraded,
    /// Re-placed after a failure; restoring from the latest good
    /// checkpoint at the newly solved scale.
    Recovering,
    Completed,
    /// Terminal failure (checkpoint corruption, repeated crashes).
    Failed,
}

impl AppState {
    /// Legal transitions of the lifecycle state machine; the master refuses
    /// anything else (tested below and fuzzed in the master tests).
    pub fn can_transition(self, to: AppState) -> bool {
        use AppState::*;
        matches!(
            (self, to),
            (Pending, Running)
                | (Pending, Failed)
                | (Running, Checkpointing)
                | (Running, Completed)
                | (Running, Failed)
                | (Checkpointing, Killed)
                | (Checkpointing, Failed)
                | (Killed, Resuming)
                | (Killed, Failed)
                | (Resuming, Running)
                | (Resuming, Failed)
                // fault path: a server death can hit any resource-holding
                // state; recovery re-enters Running through Recovering
                | (Running, Degraded)
                | (Checkpointing, Degraded)
                | (Resuming, Degraded)
                | (Recovering, Degraded)
                | (Degraded, Recovering)
                | (Degraded, Failed)
                | (Recovering, Running)
                | (Recovering, Failed)
        )
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, AppState::Completed | AppState::Failed)
    }

    /// Does the app currently hold cluster resources?
    pub fn holds_resources(self) -> bool {
        matches!(
            self,
            AppState::Running
                | AppState::Checkpointing
                | AppState::Resuming
                | AppState::Recovering
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> AppSpec {
        AppSpec {
            executor: Engine::MpiCaffe,
            demand: Res::cpu_gpu_ram(1.0, 1.0, 8.0),
            weight: 2,
            n_max: 5,
            n_min: 1,
            cmd: ["start.sh".into(), "resume.sh".into()],
        }
    }

    #[test]
    fn paper_example_tuple_validates() {
        // §III-C-3 example: MPI-Caffe, ⟨1 CPU, 1 GPU, 8GB⟩, w=2, max 5, min 1
        assert!(spec().validate().is_ok());
    }

    #[test]
    fn invalid_tuples_rejected() {
        let mut s = spec();
        s.n_min = 0;
        assert!(s.validate().is_err());
        let mut s = spec();
        s.n_min = 6;
        assert!(s.validate().is_err());
        let mut s = spec();
        s.weight = 0;
        assert!(s.validate().is_err());
        let mut s = spec();
        s.demand = Res::zeros(3);
        assert!(s.validate().is_err());
        let mut s = spec();
        s.demand = Res(vec![-1.0, 0.0, 8.0]);
        assert!(s.validate().is_err());
        let mut s = spec();
        s.demand = Res(vec![f64::NAN, 1.0, 8.0]);
        assert!(s.validate().is_err(), "NaN demand rejected");
        let mut s = spec();
        s.demand = Res(vec![f64::INFINITY, 1.0, 8.0]);
        assert!(s.validate().is_err(), "infinite demand rejected");
    }

    #[test]
    fn engine_parse_roundtrip() {
        for e in [Engine::MxNet, Engine::TensorFlow, Engine::Petuum, Engine::MpiCaffe] {
            assert_eq!(Engine::parse(e.name()).unwrap(), e);
        }
        assert!(Engine::parse("Spark").is_err());
    }

    #[test]
    fn lifecycle_legal_paths() {
        use AppState::*;
        // the Fig. 5 adjustment cycle
        let cycle = [Pending, Running, Checkpointing, Killed, Resuming, Running, Completed];
        for w in cycle.windows(2) {
            assert!(w[0].can_transition(w[1]), "{:?} -> {:?}", w[0], w[1]);
        }
        // the fault cycle: server death -> re-placed -> running again
        let fault_cycle = [Running, Degraded, Recovering, Running];
        for w in fault_cycle.windows(2) {
            assert!(w[0].can_transition(w[1]), "{:?} -> {:?}", w[0], w[1]);
        }
        // illegal jumps
        assert!(!Pending.can_transition(Killed));
        assert!(!Running.can_transition(Resuming));
        assert!(!Completed.can_transition(Running));
        assert!(!Killed.can_transition(Running));
        assert!(!Pending.can_transition(Degraded), "pending holds nothing to lose");
        assert!(!Degraded.can_transition(Running), "recovery must restore first");
        assert!(!Killed.can_transition(Recovering), "voluntary kills resume, not recover");
    }

    #[test]
    fn terminal_and_resource_holding() {
        use AppState::*;
        assert!(Completed.is_terminal() && Failed.is_terminal());
        assert!(!Killed.holds_resources());
        assert!(!Degraded.holds_resources());
        assert!(Running.holds_resources() && Checkpointing.holds_resources());
        assert!(Recovering.holds_resources());
    }
}

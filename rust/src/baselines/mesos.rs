//! Two-level (Mesos-like) baseline in **app-level** sharing mode (§II-C):
//! resource offers negotiated at admission, allocations static afterwards.
//!
//! Functionally this behaves like the Swarm baseline — the paper's point is
//! precisely that app-level two-level sharing cannot adjust allocations —
//! plus a non-zero admission latency for the offer round-trips.  The
//! interesting two-level pathology (per-task scheduling latency) lives in
//! [`super::tasklevel`].

use crate::sched::{AllocationUpdate, CmsPolicy, SchedCtx};

use super::static_alloc::StaticPolicy;

/// Mesos-like app-level offers: static allocations + admission latency.
#[derive(Debug)]
pub struct MesosAppLevelPolicy {
    inner: StaticPolicy,
    /// Offer negotiation rounds × round-trip latency, in hours.
    pub admission_latency_hours: f64,
}

impl MesosAppLevelPolicy {
    /// Default: 3 offer rounds × ~0.5 s ≈ 1.5 s of negotiation.
    pub fn new() -> Self {
        MesosAppLevelPolicy {
            inner: StaticPolicy::new(),
            admission_latency_hours: 1.5 / 3600.0,
        }
    }
}

impl Default for MesosAppLevelPolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl CmsPolicy for MesosAppLevelPolicy {
    fn name(&self) -> String {
        "mesos-app".into()
    }

    fn on_change(&mut self, ctx: &SchedCtx) -> Option<AllocationUpdate> {
        self.inner.on_change(ctx)
    }

    fn admission_latency_hours(&self) -> f64 {
        self.admission_latency_hours
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, SimConfig};
    use crate::sim::{run_sim, PerfModel};
    use crate::workload::{table2_rows, WorkloadApp};

    #[test]
    fn behaves_like_static_plus_latency() {
        let rows = table2_rows();
        let wl = vec![WorkloadApp {
            row: 0,
            tag: "LR".into(),
            submit_hours: 0.0,
            duration_at_baseline_hours: 1.0,
            baseline_n: 8,
        }];
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 3.0, ..Default::default() };
        let mut pol = MesosAppLevelPolicy::new();
        let out = run_sim(&mut pol, &rows, &wl, &cfg, &sim, &PerfModel::default());
        assert_eq!(out.completed, 1);
        let dur = out.metrics.completions[0].1;
        // 1h of work + ~1.5s admission latency
        assert!(dur > 1.0 && dur < 1.001, "{dur}");
    }
}

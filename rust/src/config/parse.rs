//! TOML-subset / key-value parser (see module docs in `mod.rs`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u32(&self) -> Option<u32> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u32::MAX as f64 {
                Some(n as u32)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed document: `section -> key -> value`. Keys outside any section
/// land in the "" section.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl TomlDoc {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    /// Typed getters with config-style error messages.
    pub fn str_of(&self, section: &str, key: &str) -> Result<&str> {
        self.get(section, key)
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow!("missing string [{section}].{key}"))
    }

    pub fn f64_of(&self, section: &str, key: &str) -> Result<f64> {
        self.get(section, key)
            .and_then(Value::as_f64)
            .ok_or_else(|| anyhow!("missing number [{section}].{key}"))
    }

    pub fn u32_of(&self, section: &str, key: &str) -> Result<u32> {
        self.get(section, key)
            .and_then(Value::as_u32)
            .ok_or_else(|| anyhow!("missing integer [{section}].{key}"))
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn u32_or(&self, section: &str, key: &str, default: u32) -> u32 {
        self.get(section, key).and_then(Value::as_u32).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(Value::as_str).unwrap_or(default)
    }
}

fn parse_scalar(raw: &str) -> Result<Value> {
    let s = raw.trim();
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(stripped) = s.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string: {raw}"))?;
        // minimal escapes
        let un = inner.replace("\\\"", "\"").replace("\\\\", "\\");
        return Ok(Value::Str(un));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array: {raw}"))?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_scalar(&part)?);
            }
        }
        return Ok(Value::Array(items));
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| anyhow!("unparseable value: {raw}"))
}

/// Split an array body on commas that are not inside quotes.
fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for ch in s.chars() {
        match ch {
            '"' => {
                in_str = !in_str;
                cur.push(ch);
            }
            ',' if !in_str => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(ch),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

/// Strip a trailing `#` comment that is not inside a string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse TOML-subset text.
pub fn parse_toml(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    doc.sections.entry(section.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let name = inner
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: bad section header", lineno + 1))?;
            section = name.trim().to_string();
            doc.sections.entry(section.clone()).or_default();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        let value = parse_scalar(v)
            .with_context(|| format!("line {}: key {}", lineno + 1, k.trim()))?;
        doc.sections
            .get_mut(&section)
            .unwrap()
            .insert(k.trim().to_string(), value);
    }
    Ok(doc)
}

/// Parse a flat `key=value` file (the artifact manifest format); values stay
/// raw strings.
pub fn parse_kv_file(path: &Path) -> Result<BTreeMap<String, String>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut out = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("{}:{}: expected key=value", path.display(), lineno + 1))?;
        out.insert(k.trim().to_string(), v.trim().to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_scalars_arrays() {
        let doc = parse_toml(
            r#"
            top = 1
            [cluster]
            slaves = 20            # trailing comment
            name = "testbed #1"
            caps = [240, 5, 2560]
            gpus_enabled = true
            tags = ["a", "b"]
            "#,
        )
        .unwrap();
        assert_eq!(doc.f64_of("", "top").unwrap(), 1.0);
        assert_eq!(doc.u32_of("cluster", "slaves").unwrap(), 20);
        assert_eq!(doc.str_of("cluster", "name").unwrap(), "testbed #1");
        let caps = doc.get("cluster", "caps").unwrap().as_array().unwrap();
        assert_eq!(caps.len(), 3);
        assert_eq!(caps[2].as_f64().unwrap(), 2560.0);
        assert_eq!(doc.get("cluster", "gpus_enabled").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn defaults_and_missing() {
        let doc = parse_toml("[a]\nx = 2").unwrap();
        assert_eq!(doc.f64_or("a", "x", 9.0), 2.0);
        assert_eq!(doc.f64_or("a", "y", 9.0), 9.0);
        assert!(doc.f64_of("a", "y").is_err());
        assert!(doc.str_of("b", "z").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_toml("[unclosed").is_err());
        assert!(parse_toml("novalue").is_err());
        assert!(parse_toml("x = [1, 2").is_err());
        assert!(parse_toml("x = \"unterminated").is_err());
    }

    #[test]
    fn u32_rejects_fractional_and_negative() {
        let doc = parse_toml("x = 1.5\ny = -2").unwrap();
        assert!(doc.u32_of("", "x").is_err());
        assert!(doc.u32_of("", "y").is_err());
    }

    #[test]
    fn kv_file_roundtrip() {
        let dir = std::env::temp_dir().join("dorm_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.kv");
        std::fs::write(&p, "# comment\na.b=1\nmodel.lr.x.shape=256x64\n").unwrap();
        let kv = parse_kv_file(&p).unwrap();
        assert_eq!(kv["a.b"], "1");
        assert_eq!(kv["model.lr.x.shape"], "256x64");
    }
}

//! The churn experiment: what machine failure does to each CMS.
//!
//! An evaluation axis the paper never had: sweep per-server MTBF and run
//! Dorm and all four baselines (static/Swarm, Mesos app-level, IaaS
//! engine-partitioned, task-level) over the same workload and failure
//! trace, reporting mean utilization, fairness loss, cumulative lost work,
//! mean recovery time and goodput through [`crate::metrics`].  Exposed on
//! the CLI as `dorm churn`; `report::write_csv` emits per-system series
//! for external plotting.

use crate::baselines::{IaasPolicy, MesosAppLevelPolicy, StaticPolicy, TaskLevelPolicy};
use crate::config::{DormConfig, FaultConfig};
use crate::report;
use crate::sched::CmsPolicy;
use crate::sim::{DormPolicy, Experiment, SystemRun};

/// One (system, MTBF) cell of the sweep.
#[derive(Clone, Debug)]
pub struct ChurnPoint {
    pub system: String,
    pub mtbf_hours: f64,
    /// Mean Eq. 1 utilization over the horizon.
    pub mean_utilization: f64,
    /// Mean Eq. 2 fairness loss over the horizon.
    pub mean_fairness_loss: f64,
    /// Cumulative work-hours discarded by server deaths.
    pub lost_work: f64,
    /// Mean hours from server death to the affected app running again.
    pub mean_recovery_hours: f64,
    /// Mean sampled useful-progress rate (work-units/hour).
    pub mean_goodput: f64,
    pub completed: usize,
    /// Allocation decisions deferred by a master outage (0 when
    /// `[fault].master_fail_at_hours` is off) — the takeover's "lost
    /// adjustments" cost, DESIGN.md §11.
    pub deferred_allocs: usize,
}

impl ChurnPoint {
    fn from_run(run: &SystemRun, mtbf_hours: f64, horizon: f64) -> Self {
        let m = run.metrics();
        ChurnPoint {
            system: run.label.clone(),
            mtbf_hours,
            mean_utilization: m.utilization.mean_over(0.0, horizon),
            mean_fairness_loss: m.fairness_loss.mean_over(0.0, horizon),
            lost_work: m.lost_work.last().unwrap_or(0.0),
            mean_recovery_hours: m.mean_recovery_hours(),
            mean_goodput: m.goodput.mean_over(0.0, horizon),
            completed: run.outcome.completed,
            deferred_allocs: run.outcome.deferred_allocations,
        }
    }
}

/// Dorm (three θ configs) + the four baselines, freshly constructed per
/// run (policies are stateful).
fn systems(n_servers: usize) -> Vec<Box<dyn CmsPolicy>> {
    vec![
        Box::new(DormPolicy::new(DormConfig::DORM1)),
        Box::new(DormPolicy::new(DormConfig::DORM2)),
        Box::new(DormPolicy::new(DormConfig::DORM3)),
        Box::new(StaticPolicy::new()),
        Box::new(MesosAppLevelPolicy::new()),
        Box::new(IaasPolicy::proportional(n_servers)),
        Box::new(TaskLevelPolicy::new()),
    ]
}

/// Sweep MTBF over the scaled §V experiment.  `base` supplies every
/// `[fault]` knob except `mtbf_hours` (MTTR, failure seed, periodic
/// checkpoint cadence); each sweep point overrides the MTBF and forces
/// `enabled`.  Every system sees the same workload and the same failure
/// trace per MTBF; the paper's original no-churn world is recoverable by
/// adding a very large MTBF to the sweep.  When
/// `base.master_fail_at_hours > 0` the trace additionally kills the CMS
/// master at that hour, with the standby takeover completing
/// `master_takeover_hours` later — so Fig-style experiments can quantify
/// takeover latency and lost adjustments (DESIGN.md §11).
pub fn churn_sweep(
    base: &FaultConfig,
    seed: u64,
    horizon_hours: f64,
    napps: usize,
    mtbfs: &[f64],
) -> Vec<ChurnPoint> {
    use crate::fault::FailureEvent;
    let mut out = Vec::new();
    for &mtbf in mtbfs {
        let mut exp = Experiment::scaled(seed, horizon_hours, napps);
        let n_servers = exp.cluster.servers.len();
        let cfg = FaultConfig { enabled: true, mtbf_hours: mtbf, ..base.clone() };
        let mut trace = exp.apply_fault(&cfg);
        if base.master_fail_at_hours > 0.0 {
            trace.push(FailureEvent::master_kill(base.master_fail_at_hours));
            trace.push(FailureEvent::master_recover(
                base.master_fail_at_hours + base.master_takeover_hours,
            ));
        }
        for mut policy in systems(n_servers) {
            let run = exp.run_with_faults(policy.as_mut(), &trace);
            out.push(ChurnPoint::from_run(&run, mtbf, horizon_hours));
        }
    }
    out
}

/// ASCII table of a sweep, one row per (system, MTBF).
pub fn churn_table(points: &[ChurnPoint]) -> String {
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.system.clone(),
                format!("{:.1}", p.mtbf_hours),
                format!("{:.3}", p.mean_utilization),
                format!("{:.3}", p.mean_fairness_loss),
                format!("{:.2}", p.lost_work),
                format!("{:.3}", p.mean_recovery_hours),
                format!("{:.1}", p.mean_goodput),
                format!("{}", p.completed),
                format!("{}", p.deferred_allocs),
            ]
        })
        .collect();
    report::table(
        &[
            "system",
            "mtbf_h",
            "mean util",
            "fairness loss",
            "lost work",
            "recovery_h",
            "goodput",
            "completed",
            "deferred",
        ],
        &rows,
    )
}

/// Per-system CSV columns (mtbf, util, fairness, lost work, recovery,
/// goodput) for [`crate::report::write_csv`].
pub fn churn_csv_columns(
    points: &[ChurnPoint],
    system: &str,
) -> Vec<(&'static str, Vec<f64>)> {
    let rows: Vec<&ChurnPoint> = points.iter().filter(|p| p.system == system).collect();
    vec![
        ("mtbf_hours", rows.iter().map(|p| p.mtbf_hours).collect()),
        ("mean_utilization", rows.iter().map(|p| p.mean_utilization).collect()),
        ("mean_fairness_loss", rows.iter().map(|p| p.mean_fairness_loss).collect()),
        ("lost_work", rows.iter().map(|p| p.lost_work).collect()),
        ("mean_recovery_hours", rows.iter().map(|p| p.mean_recovery_hours).collect()),
        ("mean_goodput", rows.iter().map(|p| p.mean_goodput).collect()),
        ("completed", rows.iter().map(|p| p.completed as f64).collect()),
        ("deferred_allocs", rows.iter().map(|p| p.deferred_allocs as f64).collect()),
    ]
}

/// Distinct system labels in sweep order.
pub fn churn_systems(points: &[ChurnPoint]) -> Vec<String> {
    let mut labels: Vec<String> = Vec::new();
    for p in points {
        if !labels.contains(&p.system) {
            labels.push(p.system.clone());
        }
    }
    labels
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smoke the whole sweep at a small scale: every system runs under
    /// churn, emits the fault metrics, and the harsher MTBF loses at least
    /// as much work as the milder one for the same system.
    #[test]
    fn sweep_covers_dorm_and_all_four_baselines() {
        let base = FaultConfig {
            mttr_hours: 0.25,
            ckpt_period_hours: 0.5,
            seed: 11,
            ..Default::default()
        };
        let points = churn_sweep(&base, 11, 4.0, 6, &[1.0, 16.0]);
        let labels = churn_systems(&points);
        for want in ["dorm(t1=0.2,t2=0.1)", "static", "mesos-app", "iaas", "task-level"] {
            assert!(
                labels.iter().any(|l| l == want),
                "system {want} missing from {labels:?}"
            );
        }
        assert_eq!(points.len(), 2 * 7, "7 systems x 2 MTBFs");
        for p in &points {
            assert!(p.mean_utilization >= 0.0);
            assert!(p.lost_work >= 0.0);
            assert!(p.mean_recovery_hours >= 0.0);
        }
        let table = churn_table(&points);
        assert!(table.contains("mtbf_h"));
        let cols = churn_csv_columns(&points, "static");
        assert_eq!(cols[0].1.len(), 2);
        // no master outage configured: nothing deferred anywhere
        assert!(points.iter().all(|p| p.deferred_allocs == 0));
    }

    /// With a master outage injected mid-run, every system records the
    /// allocation work it had to defer until the standby took over.
    #[test]
    fn master_outage_sweeps_report_deferred_allocations() {
        let base = FaultConfig {
            mttr_hours: 0.25,
            ckpt_period_hours: 0.5,
            seed: 11,
            master_fail_at_hours: 1.0,
            master_takeover_hours: 1.0,
            ..Default::default()
        };
        let points = churn_sweep(&base, 11, 4.0, 6, &[1.0]);
        assert_eq!(points.len(), 7, "7 systems x 1 MTBF");
        assert!(
            points.iter().any(|p| p.deferred_allocs > 0),
            "a 1 h outage over a 4 h run must defer something: {points:?}"
        );
        assert!(churn_table(&points).contains("deferred"));
    }
}

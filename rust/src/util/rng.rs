//! Deterministic PRNG: splitmix64 seeding + xoshiro256++ core, plus the
//! distribution samplers the workload model needs (uniform, exponential,
//! normal, log-normal, Poisson process arrivals).

/// xoshiro256++ PRNG. Deterministic, seedable, `Clone` for replay.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-component RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection to avoid modulo bias.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Exponential with the given mean (inter-arrival sampling).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given *underlying* mu/sigma.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_coverage() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let sum: f64 = (0..n).map(|_| r.exponential(20.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 20.0).abs() < 0.5, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(9);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}

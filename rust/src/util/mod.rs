//! Shared utilities: deterministic PRNG, statistics helpers, a tiny
//! property-testing framework, and a stderr logger.
//!
//! These exist because the vendored crate registry on this image has no
//! `rand`, `proptest` or `env_logger`; they are small, fully tested, and
//! deterministic (every experiment in EXPERIMENTS.md is reproducible from a
//! seed).

pub mod logger;
pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::Rng;

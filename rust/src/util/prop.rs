//! Property-testing mini-framework (proptest is not in the vendored
//! registry).  Deterministic: each case is generated from a seeded [`Rng`];
//! on failure the framework reports the case index and seed so the exact
//! input is reproducible, and performs a simple halving "shrink" pass for
//! `Vec`-shaped inputs via [`check_shrink`].
//!
//! ```ignore
//! prop::check(100, |rng| {
//!     let n = rng.range_u64(1, 50) as usize;
//!     // ... generate input, return Err(msg) on property violation
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Run `cases` generated property checks. Panics with the failing seed and
/// case index on the first violation.
pub fn check<F>(cases: u32, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check_seeded(0xD0B5_EED5, cases, &mut property);
}

/// As [`check`] but with an explicit base seed (used to reproduce failures).
pub fn check_seeded<F>(base_seed: u64, cases: u32, property: &mut F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property failed at case {case} (reproduce with seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Property check over a generated `Vec<T>` input with halving shrink: when
/// a case fails, successively smaller prefixes/suffixes are retried and the
/// smallest failing input is reported.
pub fn check_shrink<T, G, P>(cases: u32, mut generate: G, mut property: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> Vec<T>,
    P: FnMut(&[T]) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5EED_0000_0000_0000 ^ (case as u64).wrapping_mul(0x9E37_79B9);
        let mut rng = Rng::new(seed);
        let input = generate(&mut rng);
        if let Err(msg) = property(&input) {
            // shrink: try halves repeatedly while they still fail
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut changed = true;
            while changed && best.len() > 1 {
                changed = false;
                let half = best.len() / 2;
                let halves = [best[..half].to_vec(), best[half..].to_vec()];
                for cand in halves {
                    if let Err(m) = property(&cand) {
                        best = cand;
                        best_msg = m;
                        changed = true;
                        break;
                    }
                }
            }
            panic!(
                "property failed at case {case} (seed {seed:#x});\n  \
                 shrunk input ({} elems): {best:?}\n  violation: {best_msg}",
                best.len()
            );
        }
    }
}

/// Assert two floats are within `tol` (absolute) — helper for properties.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{a} != {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(50, |rng| {
            count += 1;
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(10, |rng| {
            if rng.f64() < 2.0 {
                Err("always fails".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    #[should_panic(expected = "shrunk input (1 elems)")]
    fn shrink_reduces_to_minimal() {
        // property: no element equals 7 — generator always plants one.
        check_shrink(
            1,
            |rng| {
                let mut v: Vec<u64> = (0..16).map(|_| rng.below(5)).collect();
                v[3] = 7;
                v
            },
            |xs| {
                if xs.contains(&7) {
                    Err("contains 7".into())
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn close_helper() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6).is_ok());
        assert!(close(1.0, 2.0, 1e-6).is_err());
    }
}

//! Translate a [`CountProblem`] into the paper's P2 MILP (Eqs 10–18),
//! count-aggregated.
//!
//! Variable layout (n = |A| apps, c = carried-over apps):
//!
//! ```text
//! [ n_0 .. n_{A-1} | l_0 .. l_{A-1} | r_{k_0} .. r_{k_{c-1}} ]
//!    integer counts   continuous loss   binary adjust flags
//! ```
//!
//! * objective (Eq. 10): max Σᵢ nᵢ · (Σₖ dᵢₖ/Cₖ)
//! * capacity (Eq. 6, aggregated): Σᵢ nᵢ dᵢₖ ≤ Cₖ ∀k
//! * bounds (Eqs 7–8): n_min ≤ nᵢ ≤ n_max
//! * |·| linearization (Eqs 11–12): lᵢ ≥ ±(nᵢ·dsᵢ − ŝᵢ)
//! * adjustment linearization (Eqs 13–14): M·rᵢ ≥ ±(nᵢ − prevᵢ), M = n_max
//! * budgets (Eqs 15–16): Σ lᵢ ≤ ⌈θ₁·2m⌉, Σ rᵢ ≤ ⌈θ₂·|carried|⌉

use crate::solver::heuristic::CountProblem;
use crate::solver::{Cmp, Constraint, Lp, Milp};

/// Build the exact count-aggregated P2.
pub fn build_count_milp(p: &CountProblem) -> Milp {
    let a = p.apps.len();
    let m = p.cap.m();
    let carried: Vec<usize> = (0..a).filter(|&i| p.apps[i].prev.is_some()).collect();
    let nvars = 2 * a + carried.len();

    // dominant share of one container of app i
    let ds: Vec<f64> = p
        .apps
        .iter()
        .map(|ap| ap.demand.dominant_share(&p.cap))
        .collect();

    // objective: utilization density per container for n_i; 0 for l, r
    let mut objective = vec![0.0; nvars];
    for i in 0..a {
        objective[i] = p.apps[i].demand.utilization_sum(&p.cap);
    }

    let mut cons: Vec<Constraint> = Vec::new();

    // Eq. 6 (aggregated capacity) per resource type
    for k in 0..m {
        if p.cap[k] <= 0.0 {
            // zero-capacity type: demands on it must be zero to fit at all
            continue;
        }
        let coeffs: Vec<(usize, f64)> = (0..a)
            .filter(|&i| p.apps[i].demand[k] != 0.0)
            .map(|i| (i, p.apps[i].demand[k]))
            .collect();
        if !coeffs.is_empty() {
            cons.push(Constraint::new(coeffs, Cmp::Le, p.cap[k]));
        }
    }

    // Eqs 7-8 bounds
    for i in 0..a {
        cons.push(Constraint::new(vec![(i, 1.0)], Cmp::Le, p.apps[i].n_max as f64));
        cons.push(Constraint::new(vec![(i, 1.0)], Cmp::Ge, p.apps[i].n_min as f64));
    }

    // Eqs 11-12: l_i >= |n_i*ds_i - shat_i|
    for i in 0..a {
        let l = a + i;
        // n_i*ds_i - l_i <= shat_i
        cons.push(Constraint::new(
            vec![(i, ds[i]), (l, -1.0)],
            Cmp::Le,
            p.shares_hat[i],
        ));
        // -n_i*ds_i - l_i <= -shat_i
        cons.push(Constraint::new(
            vec![(i, -ds[i]), (l, -1.0)],
            Cmp::Le,
            -p.shares_hat[i],
        ));
    }

    // Eqs 13-14: M r >= |n_i - prev_i| for carried apps; r binary
    for (ri, &i) in carried.iter().enumerate() {
        let r = 2 * a + ri;
        let prev = p.apps[i].prev.unwrap() as f64;
        let big_m = (p.apps[i].n_max as f64).max(prev) + 1.0;
        cons.push(Constraint::new(vec![(i, 1.0), (r, -big_m)], Cmp::Le, prev));
        cons.push(Constraint::new(vec![(i, -1.0), (r, -big_m)], Cmp::Le, -prev));
        cons.push(Constraint::new(vec![(r, 1.0)], Cmp::Le, 1.0));
    }

    // Eq. 15: Σ l_i <= ceil(theta1 * 2m)
    cons.push(Constraint::new(
        (0..a).map(|i| (a + i, 1.0)).collect(),
        Cmp::Le,
        p.fairness_bound(),
    ));

    // Eq. 16: Σ r_i <= ceil(theta2 * |carried|)
    if !carried.is_empty() {
        cons.push(Constraint::new(
            (0..carried.len()).map(|ri| (2 * a + ri, 1.0)).collect(),
            Cmp::Le,
            p.adjust_bound() as f64,
        ));
    }

    let mut integer = vec![false; nvars];
    for v in integer.iter_mut().take(a) {
        *v = true; // counts (Eq. 9)
    }
    for v in integer.iter_mut().skip(2 * a) {
        *v = true; // adjust flags (Eq. 18)
    }

    Milp {
        lp: Lp { n: nvars, objective, maximize: true, constraints: cons },
        integer,
    }
}

/// Lift a heuristic `counts` vector to a full variable-space point usable as
/// a branch-and-bound warm start (fills in the implied lᵢ and rᵢ).
pub fn counts_to_point(p: &CountProblem, counts: &[u32]) -> Vec<f64> {
    let a = p.apps.len();
    let carried: Vec<usize> = (0..a).filter(|&i| p.apps[i].prev.is_some()).collect();
    let mut x = vec![0.0; 2 * a + carried.len()];
    for i in 0..a {
        x[i] = counts[i] as f64;
        let s = p.apps[i].demand.times(counts[i]).dominant_share(&p.cap);
        x[a + i] = (s - p.shares_hat[i]).abs();
    }
    for (ri, &i) in carried.iter().enumerate() {
        x[2 * a + ri] = if p.apps[i].prev.unwrap() != counts[i] { 1.0 } else { 0.0 };
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::Res;
    use crate::solver::heuristic::{heuristic_solve, CountApp};
    use crate::solver::{milp, MilpOptions};

    fn problem() -> CountProblem {
        CountProblem::new(
            vec![
                CountApp {
                    demand: Res(vec![2.0, 8.0]),
                    weight: 1.0,
                    n_min: 1,
                    n_max: 10,
                    prev: Some(2),
                },
                CountApp {
                    demand: Res(vec![4.0, 4.0]),
                    weight: 2.0,
                    n_min: 1,
                    n_max: 10,
                    prev: None,
                },
            ],
            Res(vec![24.0, 96.0]),
            0.3,
            0.5,
        )
    }

    #[test]
    fn milp_solution_is_problem_feasible() {
        let p = problem();
        let m = build_count_milp(&p);
        let out = milp::solve(&m, &MilpOptions::default());
        let (x, _) = out.solution().expect("feasible");
        let counts: Vec<u32> = (0..2).map(|i| x[i].round() as u32).collect();
        assert!(p.is_feasible(&counts), "{counts:?}");
    }

    #[test]
    fn warm_start_point_is_feasible_in_milp() {
        let p = problem();
        let counts = heuristic_solve(&p).unwrap();
        let point = counts_to_point(&p, &counts);
        let m = build_count_milp(&p);
        // every constraint must hold at the lifted point
        for (ci, c) in m.lp.constraints.iter().enumerate() {
            let lhs: f64 = c.coeffs.iter().map(|&(j, v)| v * point[j]).sum();
            let ok = match c.cmp {
                Cmp::Le => lhs <= c.rhs + 1e-6,
                Cmp::Ge => lhs >= c.rhs - 1e-6,
                Cmp::Eq => (lhs - c.rhs).abs() <= 1e-6,
            };
            assert!(ok, "constraint {ci} violated at warm start: {lhs} vs {}", c.rhs);
        }
    }

    #[test]
    fn variable_layout_sizes() {
        let p = problem();
        let m = build_count_milp(&p);
        // 2 counts + 2 losses + 1 carried flag
        assert_eq!(m.lp.n, 5);
        assert_eq!(m.integer, vec![true, true, false, false, true]);
    }

    #[test]
    fn milp_beats_or_ties_heuristic() {
        let p = problem();
        let h = heuristic_solve(&p).unwrap();
        let m = build_count_milp(&p);
        let out = milp::solve(
            &m,
            &MilpOptions { warm_start: Some(counts_to_point(&p, &h)), ..Default::default() },
        );
        let (x, _) = out.solution().unwrap();
        let counts: Vec<u32> = (0..2).map(|i| x[i].round() as u32).collect();
        assert!(p.utilization(&counts) >= p.utilization(&h) - 1e-9);
    }
}

//! End-to-end driver (DESIGN.md deliverable): train a transformer LM under
//! Dorm for a few hundred steps, exercising every layer of the stack —
//!
//!   L1 Pallas kernels (fused matmul + flash attention, inside the HLO)
//!   L2 JAX model (AOT'd to artifacts/tfm_e2e_*.hlo.txt)
//!   L3 Rust: DormMaster allocation -> PS trainer -> PJRT compute service
//!
//! — including one mid-training elastic rescale through the checkpoint
//! protocol. Logs the loss curve; EXPERIMENTS.md §E2E records a run.
//!
//! ```bash
//! cargo run --release --example e2e_train -- [--steps N] [--model tfm|tfm_e2e]
//! ```

use dorm::app::{AppSpec, CheckpointStore, Engine};
use dorm::config::{ClusterConfig, DormConfig};
use dorm::master::DormMaster;
use dorm::resources::Res;
use dorm::runtime::{ComputeService, Manifest};

fn arg(name: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| default.to_string())
}

fn main() -> anyhow::Result<()> {
    dorm::util::logger::init();
    let steps: u64 = arg("--steps", "200").parse()?;
    let model = arg("--model", "tfm");
    let log_every: u64 = arg("--log-every", "10").parse()?;

    let manifest = Manifest::load("artifacts")?;
    let meta = manifest.model(&model)?.clone();
    println!(
        "== e2e: training {model} ({} params, batch {}x{}) for {steps} steps ==",
        meta.n_params,
        meta.x_shape[0],
        meta.x_shape.get(1).copied().unwrap_or(1)
    );
    let t0 = std::time::Instant::now();
    let service = ComputeService::start_filtered(&manifest, Some(&[model.as_str()]))?;
    println!("pjrt compile: {:.1?}", t0.elapsed());

    let cluster = ClusterConfig::uniform(4, Res::cpu_gpu_ram(12.0, 0.0, 64.0));
    let store = CheckpointStore::new(std::env::temp_dir().join("dorm_e2e"))?;
    let mut master = DormMaster::new(&cluster, DormConfig::DORM1, store)
        .with_compute(service.handle(), manifest);

    let app = master.submit(AppSpec {
        executor: Engine::TensorFlow,
        demand: Res::cpu_gpu_ram(4.0, 0.0, 16.0),
        weight: 1,
        n_max: 8,
        n_min: 1,
        cmd: [model.clone(), model.clone()],
    })?;
    println!("{app} running with {} containers (worker slots)", master.containers_of(app));

    let train_start = std::time::Instant::now();
    let mut curve: Vec<(u64, f32)> = Vec::new();
    let rescale_at = steps / 2;
    let mut done = 0;
    while done < steps {
        let chunk = log_every.min(steps - done);
        let logs = master.train_round(chunk)?;
        done += chunk;
        for (id, step, loss) in &logs {
            if *id != app {
                continue; // track the primary app's curve only
            }
            curve.push((*step, *loss));
            println!("step {step:4}  loss {loss:.4}  ({:.1} ms/step avg)",
                     train_start.elapsed().as_millis() as f64 / done as f64);
        }
        // mid-training: force the Fig. 5 adjustment by submitting a
        // second app, which shrinks the first one's partition
        if done >= rescale_at && master.active_apps() == 1 {
            let second = master.submit(AppSpec {
                executor: Engine::MxNet,
                demand: Res::cpu_gpu_ram(4.0, 0.0, 16.0),
                weight: 1,
                n_max: 8,
                n_min: 1,
                cmd: [model.clone(), model.clone()],
            })?;
            println!(
                "-- rescale: submitted {second}; {app} now has {} containers \
                 ({} adjustment(s) so far) --",
                master.containers_of(app),
                master.total_adjustments
            );
        }
    }

    let first = curve.first().map(|&(_, l)| l).unwrap_or(f32::NAN);
    let last = curve.last().map(|&(_, l)| l).unwrap_or(f32::NAN);
    println!(
        "== done: {} steps in {:.1?} ({:.0} ms/step); loss {first:.4} -> {last:.4} ==",
        curve.last().map(|&(s, _)| s).unwrap_or(0),
        train_start.elapsed(),
        train_start.elapsed().as_millis() as f64 / steps as f64,
    );
    // CSV for EXPERIMENTS.md
    let cols = [
        ("step", curve.iter().map(|&(s, _)| s as f64).collect::<Vec<_>>()),
        ("loss", curve.iter().map(|&(_, l)| l as f64).collect::<Vec<_>>()),
    ];
    let path = dorm::report::write_csv("e2e_loss_curve.csv", &cols)?;
    println!("loss curve -> {}", path.display());
    assert!(last < first, "training must reduce loss");
    Ok(())
}

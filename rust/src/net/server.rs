//! The master side of the TCP control plane.
//!
//! [`serve`] binds a listener, moves the [`DormMaster`] behind a mutex,
//! and runs an accept loop on a background thread; each connection gets
//! its own handler thread.  Design points:
//!
//! * **Handshake first.**  The first frame of every connection must be
//!   [`Request::Hello`]; version mismatches and pre-handshake requests
//!   are answered with a typed error and the connection is closed.
//! * **Errors are answers.**  An unknown request tag or an undecodable
//!   payload produces a decodable [`Response::Error`] and the connection
//!   *survives* (framing is intact — the whole frame was consumed).
//!   Only unrecoverable conditions close it: an oversized frame (framing
//!   cannot resync past an unread body), an IO error, or a read timeout
//!   on a half-sent frame — so a stalled or malicious peer cannot wedge
//!   a handler thread.
//! * **The server owns wall time.**  Heartbeats/expiries carrying a
//!   non-finite `now_hours` are stamped with hours since server start —
//!   one clock domain for the whole lease table, no cross-process clock
//!   agreement needed.  When `NetConfig::lease_sweep_ms > 0` the accept
//!   loop also drives [`Request::ExpireLeases`] itself, which is what
//!   makes lease expiry reflect *real missed packets* in the two-process
//!   demo.

use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::NetConfig;
use crate::master::DormMaster;
use crate::proto::{wire, ErrorCode, ProtoError, Request, Response};

/// Running server: address, shared master, and the accept-thread handle.
pub struct ServerHandle {
    addr: SocketAddr,
    master: Arc<Mutex<DormMaster>>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (resolves `:0` ephemeral ports for tests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared master, e.g. for in-process inspection in tests.
    pub fn master(&self) -> Arc<Mutex<DormMaster>> {
        Arc::clone(&self.master)
    }

    /// Has a [`Request::Shutdown`] (or [`ServerHandle::stop`]) landed?
    pub fn is_stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Ask the accept loop to exit without waiting for it.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// Block until the accept loop exits (a client sent Shutdown, or
    /// [`ServerHandle::stop`] was called).
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// Serve `master` on `cfg.bind_addr` until a shutdown request arrives.
pub fn serve(master: DormMaster, cfg: &NetConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.bind_addr)
        .with_context(|| format!("bind {}", cfg.bind_addr))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let master = Arc::new(Mutex::new(master));
    let stop = Arc::new(AtomicBool::new(false));
    let wall_epoch = Instant::now();

    let accept = {
        let master = Arc::clone(&master);
        let stop = Arc::clone(&stop);
        let cfg = cfg.clone();
        std::thread::spawn(move || accept_loop(listener, master, stop, cfg, wall_epoch))
    };
    Ok(ServerHandle { addr, master, stop, accept: Some(accept) })
}

fn hours_since(wall_epoch: Instant) -> f64 {
    wall_epoch.elapsed().as_secs_f64() / 3600.0
}

fn lock_master(m: &Mutex<DormMaster>) -> std::sync::MutexGuard<'_, DormMaster> {
    // a handler that panicked mid-dispatch poisons the lock; the master's
    // state is still the best available, so serving beats aborting
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn accept_loop(
    listener: TcpListener,
    master: Arc<Mutex<DormMaster>>,
    stop: Arc<AtomicBool>,
    cfg: NetConfig,
    wall_epoch: Instant,
) {
    let sweep_every = (cfg.lease_sweep_ms > 0).then(|| Duration::from_millis(cfg.lease_sweep_ms));
    let mut last_sweep = Instant::now();
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                log::debug!("control-plane connection from {peer}");
                let master = Arc::clone(&master);
                let stop = Arc::clone(&stop);
                let cfg = cfg.clone();
                std::thread::spawn(move || handle_conn(stream, master, stop, cfg, wall_epoch));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                if let Some(period) = sweep_every {
                    if last_sweep.elapsed() >= period {
                        last_sweep = Instant::now();
                        let now = hours_since(wall_epoch);
                        let rsp = lock_master(&master)
                            .dispatch(Request::ExpireLeases { now_hours: now });
                        if let Response::Expired { dead } = rsp {
                            if !dead.is_empty() {
                                log::warn!("lease sweep at {now:.5} h: servers {dead:?} expired");
                            }
                        }
                    }
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                log::warn!("accept failed: {e}; retrying");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Substitute the server's wall clock for "stamp at arrival" markers.
fn stamp(req: Request, wall_epoch: Instant) -> Request {
    match req {
        Request::Heartbeat { server, now_hours, report, acks } if !now_hours.is_finite() => {
            Request::Heartbeat { server, now_hours: hours_since(wall_epoch), report, acks }
        }
        Request::ExpireLeases { now_hours } if !now_hours.is_finite() => {
            Request::ExpireLeases { now_hours: hours_since(wall_epoch) }
        }
        Request::RecoverServer { server, now_hours } if !now_hours.is_finite() => {
            Request::RecoverServer { server, now_hours: hours_since(wall_epoch) }
        }
        other => other,
    }
}

/// Write one response frame, trailed by the serving master's `epoch`
/// (proto v1.1 split-brain fencing).  A response that would itself exceed
/// the frame limit (e.g. a `StateView` over a very large app population)
/// is replaced by an in-band typed error rather than silently dropping
/// the connection — errors are answers here too.
fn send(stream: &mut TcpStream, rsp: &Response, max: usize, epoch: u64) -> bool {
    let mut payload = wire::encode_response_ep(rsp, epoch);
    if payload.len() > max {
        // progressively shorter details so the substitute itself fits
        // even a pathologically small (but legal, >= 64 B) frame limit
        let full = format!(
            "response of {} B exceeds the {max} B frame limit; \
             narrow the query or raise [net].max_frame_bytes",
            payload.len()
        );
        for detail in [full.as_str(), "response too large", ""] {
            let sub = wire::encode_response_ep(
                &Response::Error(ProtoError::new(ErrorCode::FrameTooLarge, detail)),
                epoch,
            );
            if sub.len() <= max {
                payload = sub;
                break;
            }
        }
    }
    wire::write_frame(stream, &payload, max).is_ok()
}

/// Read exactly `buf.len()` bytes in ~100 ms polls.  While no byte of
/// `buf` has arrived and `idle_ok` holds, waiting is healthy (a control
/// connection between commands) and continues indefinitely; once a frame
/// is partially read — or for a frame body — a peer silent for `stall`
/// is stalled and the read fails so the handler can reap the connection.
/// Checks `stop` between polls.  `Ok(false)` = clean EOF before byte 0.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    idle_ok: bool,
    stall: Option<Duration>,
) -> std::result::Result<bool, ()> {
    use std::io::Read;
    let mut pos = 0;
    let mut quiet_since: Option<Instant> = None;
    while pos < buf.len() {
        if stop.load(Ordering::SeqCst) {
            return Ok(false);
        }
        match stream.read(&mut buf[pos..]) {
            Ok(0) => return if pos == 0 { Ok(false) } else { Err(()) },
            Ok(n) => {
                pos += n;
                quiet_since = None;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                if idle_ok && pos == 0 {
                    continue;
                }
                let since = *quiet_since.get_or_insert_with(Instant::now);
                if let Some(stall) = stall {
                    if since.elapsed() >= stall {
                        return Err(());
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return Err(()),
        }
    }
    Ok(true)
}

fn handle_conn(
    mut stream: TcpStream,
    master: Arc<Mutex<DormMaster>>,
    stop: Arc<AtomicBool>,
    cfg: NetConfig,
    wall_epoch: Instant,
) {
    stream.set_nodelay(true).ok();
    // the listener is nonblocking and some platforms let accepted sockets
    // inherit that flag, which would turn the timeout reads below into a
    // busy spin and make mid-frame writes fail spuriously — clear it
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    // ~100 ms poll quantum: reads wake often enough to observe `stop` and
    // to enforce the mid-frame stall deadline without busy-waiting
    if stream.set_read_timeout(Some(Duration::from_millis(100))).is_err() {
        return;
    }
    let stall = (cfg.io_timeout_ms > 0).then(|| Duration::from_millis(cfg.io_timeout_ms));
    if stream.set_write_timeout(stall).is_err() {
        return;
    }
    let max = cfg.max_frame_bytes;
    let mut negotiated = false;
    // the serving epoch, refreshed after every dispatch (it changes only
    // on promotion, but the cache spares a lock on pre-dispatch errors)
    let mut cur_epoch = lock_master(&master).epoch();
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        // header: idle waiting is healthy between commands
        let mut hdr = [0u8; wire::FRAME_HEADER];
        match read_full(&mut stream, &mut hdr, &stop, true, stall) {
            Ok(true) => {}
            _ => return, // EOF, stop, or a peer stalled mid-header
        }
        let len = u32::from_be_bytes(hdr) as usize;
        if len > max {
            // framing cannot resync past an unread body: answer, close
            let e = ProtoError::new(
                ErrorCode::FrameTooLarge,
                format!("frame of {len} B exceeds the {max} B limit"),
            );
            send(&mut stream, &Response::Error(e), max, cur_epoch);
            return;
        }
        // body: a silent peer mid-frame is stalled — reap, never hang
        let mut payload = vec![0u8; len];
        match read_full(&mut stream, &mut payload, &stop, false, stall) {
            Ok(true) => {}
            _ => return,
        }
        let (req, rid) = match wire::decode_request_rid(&payload) {
            Ok(r) => r,
            Err(wire::WireError::UnknownRequestTag(t)) => {
                // a newer peer's message: typed refusal, connection lives
                let e = ProtoError::new(
                    ErrorCode::UnsupportedRequest,
                    format!("request tag {t:#04x} is not known to protocol v{}.{}",
                        crate::proto::PROTO_MAJOR, crate::proto::PROTO_MINOR),
                );
                if !send(&mut stream, &Response::Error(e), max, cur_epoch) {
                    return;
                }
                continue;
            }
            Err(e) => {
                let e = ProtoError::new(ErrorCode::MalformedFrame, e);
                if !send(&mut stream, &Response::Error(e), max, cur_epoch) {
                    return;
                }
                continue;
            }
        };
        if !negotiated {
            match req {
                Request::Hello { .. } => {
                    let rsp = {
                        let mut m = lock_master(&master);
                        let r = m.dispatch(req);
                        cur_epoch = m.epoch();
                        r
                    };
                    let ok = matches!(rsp, Response::HelloAck { .. });
                    if !send(&mut stream, &rsp, max, cur_epoch) || !ok {
                        return; // version rejected: typed error then close
                    }
                    negotiated = true;
                    continue;
                }
                _ => {
                    let e = ProtoError::new(
                        ErrorCode::HandshakeRequired,
                        "first frame on a connection must be Hello",
                    );
                    send(&mut stream, &Response::Error(e), max, cur_epoch);
                    return;
                }
            }
        }
        let shutdown = req == Request::Shutdown;
        let rsp = {
            let mut m = lock_master(&master);
            // v1.3: the trailing retry id (when the client stamped one)
            // makes a re-sent Submit/Complete answer from the dedupe
            // cache instead of double-applying after a re-dial
            let r = m.dispatch_rid(stamp(req, wall_epoch), rid);
            cur_epoch = m.epoch();
            r
        };
        let sent = send(&mut stream, &rsp, max, cur_epoch);
        if shutdown {
            stop.store(true, Ordering::SeqCst);
            return;
        }
        if !sent {
            return;
        }
    }
}

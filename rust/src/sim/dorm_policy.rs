//! Dorm under simulation — a re-export of the shared policy.
//!
//! The admission/deferral/solve loop that used to live here moved to
//! [`crate::sched::AllocationEngine`] so the DES and the live
//! [`crate::master::DormMaster`] run byte-identical scheduling code (the
//! `tests/parity.rs` golden test pins that invariant).  [`DormPolicy`] is
//! the thin [`crate::sched::CmsPolicy`] adapter over that engine; this
//! module keeps the simulation-level behaviour tests.

pub use crate::sched::DormPolicy;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, DormConfig, SimConfig};
    use crate::sim::{run_sim, PerfModel};
    use crate::workload::{table2_rows, WorkloadApp};

    fn lr(submit: f64, dur: f64) -> WorkloadApp {
        WorkloadApp {
            row: 0,
            tag: "LR".into(),
            submit_hours: submit,
            duration_at_baseline_hours: dur,
            baseline_n: 8,
        }
    }

    #[test]
    fn lone_app_scales_beyond_baseline_and_finishes_faster() {
        let rows = table2_rows();
        let wl = vec![lr(0.0, 4.0)]; // 4h at 8 containers
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 10.0, ..Default::default() };
        let pm = PerfModel::default();
        let mut pol = DormPolicy::new(DormConfig::DORM3);
        let out = run_sim(&mut pol, &rows, &wl, &cfg, &sim, &pm);
        assert_eq!(out.completed, 1);
        let dur = out.metrics.completions[0].1;
        // LR n_max = 32: Dorm runs it at 32 containers
        let expect = 4.0 / pm.speedup(32, 8);
        assert!((dur - expect).abs() < 0.05, "dur {dur} vs expected {expect}");
        assert!(dur < 4.0 * 0.6, "should be much faster than baseline");
    }

    #[test]
    fn scale_down_on_arrival_counts_as_adjustment() {
        let rows = table2_rows();
        // 5 LR apps arriving faster than they finish: CPU capacity holds
        // 120 containers, so by the 4th arrival the earlier apps (at
        // n_max = 32) must be scaled down.
        let wl: Vec<WorkloadApp> = (0..5).map(|i| lr(i as f64 * 0.5, 8.0)).collect();
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 12.0, ..Default::default() };
        let pm = PerfModel::default();
        let mut pol = DormPolicy::new(DormConfig::DORM1);
        let out = run_sim(&mut pol, &rows, &wl, &cfg, &sim, &pm);
        assert_eq!(out.completed, 5);
        // earlier apps were scaled down as later ones arrived
        assert!(out.metrics.adjustments.last().unwrap() >= 1.0);
    }

    #[test]
    fn fairness_loss_bounded_by_theta1() {
        let rows = table2_rows();
        let wl: Vec<WorkloadApp> = (0..6).map(|i| lr(i as f64 * 0.3, 6.0)).collect();
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 8.0, ..Default::default() };
        let mut pol = DormPolicy::new(DormConfig::DORM3);
        let out = run_sim(&mut pol, &rows, &wl, &cfg, &sim, &PerfModel::default());
        // Eq. 15 bound: ceil(0.1 * 2 * 3) = 1... but transient samples right
        // after arrival (before the next solve lands) may exceed; the
        // *decision-time* bound is ceil(theta1 * 2m) = 1. Allow transients.
        let bound = (0.1f64 * 6.0).ceil();
        let viol = out
            .metrics
            .fairness_loss
            .points
            .iter()
            .filter(|&&(_, v)| v > bound + 1e-6)
            .count();
        let frac = viol as f64 / out.metrics.fairness_loss.points.len() as f64;
        assert!(frac < 0.35, "fairness bound violated in {frac} of samples");
    }

    #[test]
    fn engine_cache_and_warm_start_are_exercised_by_a_run() {
        let rows = table2_rows();
        let wl: Vec<WorkloadApp> = (0..4).map(|i| lr(i as f64 * 0.5, 3.0)).collect();
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 12.0, ..Default::default() };
        let mut pol = DormPolicy::new(DormConfig::DORM1);
        let out = run_sim(&mut pol, &rows, &wl, &cfg, &sim, &PerfModel::default());
        assert_eq!(out.completed, 4);
        let stats = pol.engine.stats().clone();
        // every arrival/completion event asked the engine ...
        assert!(stats.solves + stats.cache_hits >= 8);
        // ... and once carried state exists the previous solution seeds
        // each re-solve
        assert!(stats.warm_start_hits >= 1, "{stats:?}");
        // the placement rounds ran on the incremental delta packer
        assert!(stats.delta_packs >= 1, "{stats:?}");
    }
}

//! Fig. 9(b) reproduction: Dorm's sharing overhead vs application duration.
//!
//! Paper methodology (§V-B-5): same app on a dedicated cluster vs on Dorm
//! with 2 random kill/resume cycles; overhead = duration inflation.
//! Headline: ≈ 5 % for apps ≥ 3 h.
//!
//! Reproduced two ways: (a) the checkpoint-cost model over the paper's
//! duration axis, and (b) `examples/sharing_overhead.rs` measures the
//! protocol on a real PJRT training job.

#[path = "harness/mod.rs"]
mod harness;

use dorm::report;
use dorm::sim::PerfModel;

fn main() {
    harness::banner("Fig. 9b — sharing overhead vs application duration (2 kill/resumes)");
    let pm = PerfModel::default();
    let kills = 2.0;

    let durations = [0.5, 1.0, 2.0, 3.0, 4.0, 6.0, 9.0, 12.0, 18.0, 24.0];
    let overheads: Vec<f64> = durations
        .iter()
        .map(|d| kills * pm.adjust_pause_hours() / d * 100.0)
        .collect();

    let rows: Vec<Vec<String>> = durations
        .iter()
        .zip(&overheads)
        .map(|(d, o)| {
            vec![
                format!("{d}"),
                format!("{:.2}", d * (1.0 + o / 100.0)),
                format!("{o:.1}%"),
            ]
        })
        .collect();
    println!(
        "{}",
        report::table(&["app duration (h)", "duration on Dorm (h)", "overhead"], &rows)
    );

    let at3h = kills * pm.adjust_pause_hours() / 3.0 * 100.0;
    harness::paper_row("overhead at 3 h (2 adjustments)", "~5%", &format!("{at3h:.1}%"));
    harness::paper_row(
        "overhead for apps >= 3 h",
        "<= ~5%",
        if durations
            .iter()
            .zip(&overheads)
            .filter(|(d, _)| **d >= 3.0)
            .all(|(_, o)| *o <= 5.5)
        {
            "<= 5.5%"
        } else {
            "exceeded"
        },
    );
    println!(
        "\n(real-job measurement of the same protocol: `cargo run --release \
         --example sharing_overhead` — checkpoint+resume on actual PJRT training)"
    );

    let series: Vec<(f64, f64)> = durations.iter().zip(&overheads).map(|(&d, &o)| (d, o)).collect();
    println!("{}", report::ascii_chart(&[("overhead %", &series)], 10, 60));
    let _ = report::write_csv(
        "fig9b_overhead.csv",
        &[("duration_h", durations.to_vec()), ("overhead_pct", overheads)],
    );
}

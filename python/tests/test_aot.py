"""AOT pipeline tests: manifest integrity and HLO-text invariants."""

import os

import pytest

from compile import aot
from compile.model import make_lr

import jax
import jax.numpy as jnp


def test_to_hlo_text_structure():
    spec = make_lr(d=4, batch=8)
    seed = jax.ShapeDtypeStruct((), jnp.int32)
    text = aot.to_hlo_text(jax.jit(spec.init).lower(seed))
    assert "HloModule" in text
    assert "ENTRY" in text
    # tuple-rooted (return_tuple=True) so the Rust side can to_tuple1()
    assert "(f32[9]" in text.replace(" ", "")[:20000] or "tuple" in text


def test_lower_model_writes_artifacts(tmp_path):
    spec = make_lr(d=4, batch=8)
    manifest = {}
    aot.lower_model(spec, str(tmp_path), manifest, verbose=False)
    for tag in ("init", "grad", "apply"):
        fname = manifest[f"model.lr.artifact.{tag}"]
        path = tmp_path / fname
        assert path.exists() and path.stat().st_size > 0
        assert "HloModule" in path.read_text()[:200]
    assert manifest["model.lr.params"] == "5"
    assert manifest["model.lr.x.shape"] == "8x4"
    assert manifest["model.lr.x.dtype"] == "f32"
    assert manifest["model.lr.meta.d"] == "4"


def test_main_subset_and_manifest(tmp_path, monkeypatch):
    import compile.model as m

    monkeypatch.setattr(m, "default_models", lambda: [make_lr(d=4, batch=8)])
    aot.main(["--out", str(tmp_path), "--models", "lr"])
    kv = dict(line.strip().split("=", 1)
              for line in open(tmp_path / "manifest.kv"))
    assert kv["manifest.models"] == "lr"
    assert kv["model.lr.artifact.grad"] == "lr_grad.hlo.txt"


def test_main_rejects_unknown_model(tmp_path):
    with pytest.raises(SystemExit):
        aot.main(["--out", str(tmp_path), "--models", "nope"])

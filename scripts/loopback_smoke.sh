#!/usr/bin/env bash
# Loopback smoke test for the two-process control plane (DESIGN.md §9):
# start `dorm master` and one `dorm slave` as real processes on
# 127.0.0.1, drive a submit → resize → complete cycle with `dorm ctl`,
# and assert a clean shutdown.  Run from the repo root after
# `cargo build --release`; exits non-zero on any failed step.
set -euo pipefail

BIN=${BIN:-rust/target/release/dorm}
PORT=${PORT:-46011}
ADDR=127.0.0.1:$PORT
STORE=$(mktemp -d)
LOG=$(mktemp -d)
MASTER_PID=
SLAVE_PID=

cleanup() {
  [ -n "$SLAVE_PID" ] && kill "$SLAVE_PID" 2>/dev/null || true
  [ -n "$MASTER_PID" ] && kill "$MASTER_PID" 2>/dev/null || true
  rm -rf "$STORE" "$LOG"
}
trap cleanup EXIT

fail() {
  echo "SMOKE FAIL: $1" >&2
  echo "--- master log ---" >&2; cat "$LOG/master.log" >&2 || true
  echo "--- slave log ---" >&2; cat "$LOG/slave.log" >&2 || true
  exit 1
}

# one control-plane request (the master is confirmed listening below
# before the first call, so no connect retries are needed)
ctl() {
  "$BIN" ctl --connect "$ADDR" "$@"
}

echo "== starting master ($ADDR, 2 slaves) and one slave agent"
# θ = 0.5/0.5: generous adjustment budget so the resize step below is a
# guaranteed shrink (same configuration the master unit tests pin)
"$BIN" master --bind "$ADDR" --slaves 2 --theta1 0.5 --theta2 0.5 \
  --store "$STORE" >"$LOG/master.log" 2>&1 &
MASTER_PID=$!
for _ in $(seq 1 50); do
  grep -q "listening" "$LOG/master.log" 2>/dev/null && break
  kill -0 "$MASTER_PID" 2>/dev/null || fail "master died during startup"
  sleep 0.1
done
grep -q "listening" "$LOG/master.log" || fail "master never started listening"

"$BIN" slave --connect "$ADDR" --index 0 --period-ms 100 >"$LOG/slave.log" 2>&1 &
SLAVE_PID=$!

echo "== submit: lone app takes the whole 2-server cluster"
OUT=$(ctl submit --cpu 2 --ram 8 --nmax 12) || fail "submit app1: $OUT"
echo "$OUT" | grep -q "submitted app1" || fail "unexpected submit output: $OUT"
ctl query | grep -q "app1 Running containers=12" \
  || fail "app1 should hold 12 containers: $(ctl query)"

echo "== resize: second submission shrinks the first"
OUT=$(ctl submit --cpu 2 --ram 8 --nmax 12) || fail "submit app2: $OUT"
echo "$OUT" | grep -q "submitted app2" || fail "unexpected submit output: $OUT"
Q=$(ctl query)
echo "$Q" | grep -q "app2 Running" || fail "app2 not admitted: $Q"
echo "$Q" | grep -q "app1 Running containers=12" \
  && fail "app1 failed to shrink: $Q" || true

echo "== slave agent converges on the master book"
CONVERGED=
for _ in $(seq 1 50); do
  if grep -q "applied" "$LOG/slave.log" 2>/dev/null; then CONVERGED=1; break; fi
  sleep 0.1
done
[ -n "$CONVERGED" ] || fail "slave never applied reconciliation directives"

echo "== complete both; cluster drains"
ctl complete --app 1 | grep -q ok || fail "complete app1"
ctl complete --app 2 | grep -q ok || fail "complete app2"
ctl query | grep -q "active=0" || fail "apps did not drain: $(ctl query)"

echo "== shutdown: master exits cleanly, slave notices and exits"
ctl shutdown | grep -q ok || fail "shutdown not acknowledged"
for _ in $(seq 1 100); do
  kill -0 "$MASTER_PID" 2>/dev/null || break
  sleep 0.1
done
if kill -0 "$MASTER_PID" 2>/dev/null; then
  fail "master still running after shutdown"
fi
wait "$MASTER_PID" 2>/dev/null || fail "master exited non-zero"
MASTER_PID=
# the slave exits on its own once its heartbeats start failing
for _ in $(seq 1 100); do
  kill -0 "$SLAVE_PID" 2>/dev/null || break
  sleep 0.1
done
if kill -0 "$SLAVE_PID" 2>/dev/null; then
  fail "slave still running after master shutdown"
fi
SLAVE_PID=

echo "SMOKE PASS: submit -> resize -> complete -> shutdown all clean"

//! DRF-guided greedy + local-search heuristic for the count-aggregated P2.
//!
//! The optimizer (DESIGN.md §6) exploits the paper's uniform-container
//! observation (§III-A-4) to solve for per-application container *counts*
//! nᵢ = Σⱼ xᵢⱼ against aggregate capacity, then runs a placement round.
//! [`CountProblem`] is that aggregated problem; this module provides the
//! fast heuristic solver, and [`crate::optimizer`] builds the equivalent
//! exact MILP whose solutions the tests cross-validate against.
//!
//! Pipeline: DRF seed → greedy utilization climb under the fairness bound →
//! adjustment repair under the θ₂ bound → 1-swap local search.

use crate::drf::{drf_allocate, fairness_loss, DrfApp};
use crate::resources::Res;

/// One application in the count-aggregated allocation problem.
#[derive(Clone, Debug)]
pub struct CountApp {
    pub demand: Res,
    pub weight: f64,
    pub n_min: u32,
    pub n_max: u32,
    /// Containers at t−1; `None` for newly submitted apps (not counted in
    /// the adjustment overhead, Eq. 4).
    pub prev: Option<u32>,
}

/// The count-aggregated utilization–fairness problem (paper P2, §IV-B).
#[derive(Clone, Debug)]
pub struct CountProblem {
    pub apps: Vec<CountApp>,
    pub cap: Res,
    /// θ₁ ∈ [0,1]: fairness-loss threshold (Eq. 15 bound = ⌈θ₁ · 2m⌉).
    pub theta1: f64,
    /// θ₂ ∈ [0,1]: adjustment threshold (Eq. 16 bound = ⌈θ₂ · |Aᵗ∩Aᵗ⁻¹|⌉).
    pub theta2: f64,
    /// Theoretical DRF shares ŝᵢ (computed by [`CountProblem::new`]).
    pub shares_hat: Vec<f64>,
}

impl CountProblem {
    /// Build the problem; ŝᵢ comes from weighted DRF progressive filling.
    pub fn new(apps: Vec<CountApp>, cap: Res, theta1: f64, theta2: f64) -> Self {
        let drf_apps: Vec<DrfApp> = apps
            .iter()
            .map(|a| DrfApp {
                demand: a.demand.clone(),
                weight: a.weight,
                n_min: a.n_min,
                n_max: a.n_max,
            })
            .collect();
        let shares_hat = drf_allocate(&drf_apps, &cap).shares;
        CountProblem { apps, cap, theta1, theta2, shares_hat }
    }

    /// Eq. 15 RHS.  The published formula is ⌈θ₁ × 2m⌉, but the paper's
    /// own Fig. 7 shows Dorm-3 (θ₁ = 0.1, m = 3) bounded by 0.6 = θ₁·2m —
    /// the ceiling would allow 1.0 — so we use the un-ceiled bound, which
    /// matches the measured behaviour (documented in DESIGN.md §6).
    pub fn fairness_bound(&self) -> f64 {
        self.theta1 * 2.0 * self.cap.m() as f64
    }

    /// Eq. 16 RHS: ⌈θ₂ × |Aᵗ ∩ Aᵗ⁻¹|⌉.
    pub fn adjust_bound(&self) -> u32 {
        let carry = self.apps.iter().filter(|a| a.prev.is_some()).count();
        (self.theta2 * carry as f64).ceil() as u32
    }

    /// Eq. 10 objective: Σₖ Σᵢ nᵢ·dᵢₖ / Cₖ.
    pub fn utilization(&self, counts: &[u32]) -> f64 {
        let mut used = Res::zeros(self.cap.m());
        for (a, &c) in self.apps.iter().zip(counts) {
            used += &a.demand.times(c);
        }
        used.utilization_sum(&self.cap)
    }

    /// Eq. 2: Σᵢ |sᵢ − ŝᵢ| for the given counts.
    pub fn fairness_loss_of(&self, counts: &[u32]) -> f64 {
        let actual: Vec<f64> = self
            .apps
            .iter()
            .zip(counts)
            .map(|(a, &c)| a.demand.times(c).dominant_share(&self.cap))
            .collect();
        fairness_loss(&actual, &self.shares_hat)
    }

    /// Eq. 4: number of carried-over apps whose count changed.
    pub fn adjustments(&self, counts: &[u32]) -> u32 {
        self.apps
            .iter()
            .zip(counts)
            .filter(|(a, &c)| a.prev.map_or(false, |p| p != c))
            .count() as u32
    }

    /// Aggregate usage vector at the given counts.
    pub fn used_of(&self, counts: &[u32]) -> Res {
        let mut used = Res::zeros(self.cap.m());
        for (a, &c) in self.apps.iter().zip(counts) {
            used += &a.demand.times(c);
        }
        used
    }

    /// Full feasibility: capacity + bounds + both θ constraints.
    pub fn is_feasible(&self, counts: &[u32]) -> bool {
        counts.len() == self.apps.len()
            && self
                .apps
                .iter()
                .zip(counts)
                .all(|(a, &c)| c >= a.n_min && c <= a.n_max)
            && self.used_of(counts).fits_in(&self.cap)
            && self.fairness_loss_of(counts) <= self.fairness_bound() + 1e-9
            && self.adjustments(counts) <= self.adjust_bound()
    }
}

/// Heuristic solve. Returns `None` when no feasible point is found — the
/// master then keeps existing allocations (paper §IV-B last paragraph).
///
/// Runs two pipelines and returns the better feasible result:
/// * **DRF-seeded**: fairness-first, then utilization climb, then
///   adjustment repair — strongest when the θ₂ budget is loose;
/// * **prev-anchored**: start from the incumbent allocation (θ₂-free by
///   construction), spend the adjustment budget only where it buys
///   capacity for new arrivals or utilization — this is the pipeline that
///   handles the paper's core scenario of shrinking one running app to
///   admit a newcomer (Fig. 5).
pub fn heuristic_solve(p: &CountProblem) -> Option<Vec<u32>> {
    let n = p.apps.len();
    if n == 0 {
        return Some(vec![]);
    }

    let drf_based = drf_pipeline(p);
    let anchored = prev_anchored_pipeline(p);
    match (drf_based, anchored) {
        (Some(a), Some(b)) => {
            Some(if p.utilization(&a) >= p.utilization(&b) { a } else { b })
        }
        (a, b) => a.or(b),
    }
}

/// Best-effort solve when the full P2 is infeasible: honor capacity,
/// bounds and the θ₂ budget, and *minimize* fairness loss instead of
/// bounding it.  Freezing allocations whenever the fairness bound is
/// unreachable lets the loss plateau for hours (the failure mode the
/// paper's Fig. 7 does not show); converging toward DRF as fast as the
/// adjustment budget allows is the faithful reading of "keep high resource
/// utilization and low fairness loss" (§IV-A).  The optimizer uses this as
/// a fallback and reports it in its stats.
pub fn heuristic_solve_relaxed(p: &CountProblem) -> Option<Vec<u32>> {
    let n = p.apps.len();
    if n == 0 {
        return Some(vec![]);
    }
    // prev-anchored base with capacity repair (as in the strict pipeline)
    let mut counts: Vec<u32> = p
        .apps
        .iter()
        .map(|a| a.prev.map(|v| v.clamp(a.n_min, a.n_max)).unwrap_or(a.n_min))
        .collect();
    shrink_to_fit(p, &mut counts)?;
    if p.adjustments(&counts) > p.adjust_bound() {
        return None;
    }

    // steepest-descent on fairness loss (ties: utilization), spending the
    // remaining θ₂ budget one container move at a time
    let mut guard = 0;
    loop {
        guard += 1;
        if guard > 100_000 {
            break;
        }
        let cur_loss = p.fairness_loss_of(&counts);
        let cur_util = p.utilization(&counts);
        let mut best: Option<(usize, i32, f64, f64)> = None; // (app, delta, loss, util)
        for i in 0..n {
            for delta in [1i32, -1] {
                let nc = counts[i] as i64 + delta as i64;
                if nc < p.apps[i].n_min as i64 || nc > p.apps[i].n_max as i64 {
                    continue;
                }
                counts[i] = nc as u32;
                let ok = p.used_of(&counts).fits_in(&p.cap)
                    && p.adjustments(&counts) <= p.adjust_bound();
                let (loss, util) = if ok {
                    (p.fairness_loss_of(&counts), p.utilization(&counts))
                } else {
                    (f64::INFINITY, 0.0)
                };
                counts[i] = (nc - delta as i64) as u32;
                if !ok {
                    continue;
                }
                let improves = loss < cur_loss - 1e-12
                    || (loss <= cur_loss + 1e-12 && util > cur_util + 1e-12);
                if improves {
                    match &best {
                        Some((_, _, bl, bu))
                            if (*bl, -*bu) <= (loss, -util) => {}
                        _ => best = Some((i, delta, loss, util)),
                    }
                }
            }
        }
        match best {
            Some((i, delta, _, _)) => {
                counts[i] = (counts[i] as i64 + delta as i64) as u32;
            }
            None => break,
        }
    }

    // relaxed feasibility: everything but the fairness bound
    let ok = p
        .apps
        .iter()
        .zip(&counts)
        .all(|(a, &c)| c >= a.n_min && c <= a.n_max)
        && p.used_of(&counts).fits_in(&p.cap)
        && p.adjustments(&counts) <= p.adjust_bound();
    ok.then_some(counts)
}

/// Pipeline 1: DRF seed -> greedy fill -> adjustment repair -> local search.
fn drf_pipeline(p: &CountProblem) -> Option<Vec<u32>> {
    let drf_apps: Vec<DrfApp> = p
        .apps
        .iter()
        .map(|a| DrfApp {
            demand: a.demand.clone(),
            weight: a.weight,
            n_min: a.n_min,
            n_max: a.n_max,
        })
        .collect();
    let mut counts = drf_allocate(&drf_apps, &p.cap).containers;
    greedy_fill(p, &mut counts);
    if p.adjustments(&counts) > p.adjust_bound() {
        repair_adjustments(p, &mut counts);
    }
    local_search(p, &mut counts);
    p.is_feasible(&counts).then_some(counts)
}

/// Pipeline 2: anchor on the incumbent allocation and spend the θ₂ budget
/// deliberately.
fn prev_anchored_pipeline(p: &CountProblem) -> Option<Vec<u32>> {
    // base: carried apps keep prev (clamped), new apps start at n_min
    let counts: Vec<u32> = p
        .apps
        .iter()
        .map(|a| {
            a.prev
                .map(|v| v.clamp(a.n_min, a.n_max))
                .unwrap_or(a.n_min)
        })
        .collect();
    anchored_solve(p, counts)
}

/// Warm-started solve: anchor on an arbitrary `warm` counts vector (the
/// previous solution an [`crate::sched::AllocationEngine`] cached) instead
/// of the per-app `prev` fields.  The optimizer runs this as an extra
/// candidate pipeline and keeps the best feasible result, so a warm start
/// can only improve (or tie) the cold heuristic.
pub fn heuristic_solve_from(p: &CountProblem, warm: &[u32]) -> Option<Vec<u32>> {
    if warm.len() != p.apps.len() {
        return None;
    }
    if p.apps.is_empty() {
        return Some(vec![]);
    }
    let counts: Vec<u32> = p
        .apps
        .iter()
        .zip(warm)
        .map(|(a, &w)| w.clamp(a.n_min, a.n_max))
        .collect();
    anchored_solve(p, counts)
}

/// Shared tail of the anchored pipelines: capacity repair, θ₂ check,
/// budget-aware growth, local search, feasibility gate.
fn anchored_solve(p: &CountProblem, mut counts: Vec<u32>) -> Option<Vec<u32>> {
    shrink_to_fit(p, &mut counts)?;
    if p.adjustments(&counts) > p.adjust_bound() {
        return None; // the anchor's floors alone blew the budget
    }
    grow_within_budget(p, &mut counts);
    local_search(p, &mut counts);
    p.is_feasible(&counts).then_some(counts)
}

/// Capacity repair: shrink one container at a time until the aggregate
/// usage fits, preferring apps that are already adjusted (or new), then
/// the lowest-density carried app — each first shrink of a pristine
/// carried app spends one unit of θ₂ budget.  `None` when nothing can
/// shrink (all apps at their floors).
fn shrink_to_fit(p: &CountProblem, counts: &mut [u32]) -> Option<()> {
    let n = p.apps.len();
    let mut guard = 0;
    while !p.used_of(counts).fits_in(&p.cap) {
        guard += 1;
        if guard > 100_000 {
            return None;
        }
        let mut cand: Option<(usize, (u8, f64))> = None;
        for i in 0..n {
            if counts[i] > p.apps[i].n_min {
                let pristine =
                    p.apps[i].prev.map_or(false, |prev| prev == counts[i]);
                let class = u8::from(pristine); // adjusted/new first
                let density = p.apps[i].demand.utilization_sum(&p.cap);
                let key = (class, density);
                match &cand {
                    Some((_, bk)) if *bk <= key => {}
                    _ => cand = Some((i, key)),
                }
            }
        }
        let (i, _) = cand?;
        counts[i] -= 1;
    }
    Some(())
}

/// Growth: spend spare capacity on free apps first (new or already
/// adjusted), then on pristine carried apps while θ₂ budget remains,
/// never crossing the fairness bound.
fn grow_within_budget(p: &CountProblem, counts: &mut [u32]) {
    let n = p.apps.len();
    let fb = p.fairness_bound();
    let mut guard = 0;
    loop {
        guard += 1;
        if guard > 100_000 {
            break;
        }
        let used = p.used_of(counts);
        let budget_left = p.adjust_bound().saturating_sub(p.adjustments(counts));
        let mut best: Option<(usize, (u8, f64))> = None;
        for i in 0..n {
            let a = &p.apps[i];
            if counts[i] >= a.n_max {
                continue;
            }
            let pristine = a.prev.map_or(false, |prev| prev == counts[i]);
            if pristine && budget_left == 0 {
                continue;
            }
            if !(used.clone() + a.demand.clone()).fits_in(&p.cap) {
                continue;
            }
            counts[i] += 1;
            let fair_ok = p.fairness_loss_of(counts) <= fb + 1e-9;
            counts[i] -= 1;
            if !fair_ok {
                continue;
            }
            // prefer free growth (class 0), then highest utilization gain
            // (min-select on (class, -gain))
            let key = (u8::from(pristine), -a.demand.utilization_sum(&p.cap));
            match &best {
                Some((_, bk)) if *bk <= key => {}
                _ => best = Some((i, key)),
            }
        }
        match best {
            Some((i, _)) => counts[i] += 1,
            None => break,
        }
    }
}

/// Repeatedly add the container with the best marginal utilization gain
/// while capacity, n_max and the fairness bound allow.
fn greedy_fill(p: &CountProblem, counts: &mut Vec<u32>) {
    let fb = p.fairness_bound();
    let mut used = p.used_of(counts);
    loop {
        let mut best: Option<(usize, f64)> = None;
        for (i, a) in p.apps.iter().enumerate() {
            if counts[i] >= a.n_max {
                continue;
            }
            let next_used = used.clone() + a.demand.clone();
            if !next_used.fits_in(&p.cap) {
                continue;
            }
            counts[i] += 1;
            let ok = p.fairness_loss_of(counts) <= fb + 1e-9;
            counts[i] -= 1;
            if !ok {
                continue;
            }
            let gain = a.demand.utilization_sum(&p.cap);
            match best {
                Some((_, bg)) if bg >= gain => {}
                _ => best = Some((i, gain)),
            }
        }
        match best {
            Some((i, _)) => {
                used += &p.apps[i].demand;
                counts[i] += 1;
            }
            None => break,
        }
    }
}

/// Revert changed carried-over apps back to their previous counts, cheapest
/// utilization loss first, until the adjustment bound holds.
fn repair_adjustments(p: &CountProblem, counts: &mut Vec<u32>) {
    let bound = p.adjust_bound();
    // candidates: carried-over apps whose count differs from prev
    let mut cands: Vec<(usize, f64)> = p
        .apps
        .iter()
        .enumerate()
        .filter_map(|(i, a)| {
            let prev = a.prev?;
            if prev == counts[i] {
                return None;
            }
            let delta = counts[i] as f64 - prev as f64;
            // cost of reverting = lost utilization (can be negative = gain)
            let cost = delta * p.apps[i].demand.utilization_sum(&p.cap);
            Some((i, cost))
        })
        .collect();
    cands.sort_by(|a, b| a.1.total_cmp(&b.1));

    for (i, _) in cands {
        if p.adjustments(counts) <= bound {
            break;
        }
        let prev = p.apps[i].prev.unwrap().clamp(p.apps[i].n_min, p.apps[i].n_max);
        let saved = counts[i];
        counts[i] = prev;
        // reverting upward may break capacity — undo if so
        if !p.used_of(counts).fits_in(&p.cap) {
            counts[i] = saved;
        }
    }
}

/// Single-container moves and pairwise swaps that improve the objective
/// while staying feasible.
fn local_search(p: &CountProblem, counts: &mut Vec<u32>) {
    let n = p.apps.len();
    let mut improved = true;
    let mut guard = 0;
    while improved && guard < 10_000 {
        improved = false;
        guard += 1;
        let base_util = p.utilization(counts);
        // try +1 moves
        for i in 0..n {
            if counts[i] < p.apps[i].n_max {
                counts[i] += 1;
                if p.is_feasible(counts) && p.utilization(counts) > base_util + 1e-12 {
                    improved = true;
                    break;
                }
                counts[i] -= 1;
            }
        }
        if improved {
            continue;
        }
        // try -1/+1 swaps (move a container's worth between apps)
        'outer: for i in 0..n {
            if counts[i] <= p.apps[i].n_min {
                continue;
            }
            for j in 0..n {
                if i == j || counts[j] >= p.apps[j].n_max {
                    continue;
                }
                counts[i] -= 1;
                counts[j] += 1;
                if p.is_feasible(counts) && p.utilization(counts) > base_util + 1e-12 {
                    improved = true;
                    break 'outer;
                }
                counts[i] += 1;
                counts[j] -= 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    fn capp(cpu: f64, ram: f64, w: f64, lo: u32, hi: u32, prev: Option<u32>) -> CountApp {
        CountApp {
            demand: Res(vec![cpu, ram]),
            weight: w,
            n_min: lo,
            n_max: hi,
            prev,
        }
    }

    #[test]
    fn fills_idle_capacity() {
        // one app alone in the cluster should scale to its max (the Dorm
        // behaviour the paper's Fig 6 relies on).
        let p = CountProblem::new(
            vec![capp(2.0, 8.0, 1.0, 1, 32, None)],
            Res(vec![240.0, 2560.0]),
            0.1,
            0.1,
        );
        let counts = heuristic_solve(&p).unwrap();
        assert_eq!(counts, vec![32]);
    }

    #[test]
    fn respects_capacity_with_two_apps() {
        let p = CountProblem::new(
            vec![
                capp(4.0, 8.0, 1.0, 1, 100, None),
                capp(4.0, 8.0, 1.0, 1, 100, None),
            ],
            Res(vec![40.0, 400.0]),
            0.5,
            1.0,
        );
        let counts = heuristic_solve(&p).unwrap();
        assert!(counts.iter().sum::<u32>() <= 10);
        assert!(counts.iter().sum::<u32>() >= 9); // near-full utilization
    }

    #[test]
    fn adjustment_bound_limits_churn() {
        // 4 carried-over apps at 5 containers each; θ₂ = 0.25 allows only
        // ⌈0.25·4⌉ = 1 app to change.
        let apps: Vec<CountApp> =
            (0..4).map(|_| capp(1.0, 1.0, 1.0, 1, 100, Some(5))).collect();
        let p = CountProblem::new(apps, Res(vec![100.0, 100.0]), 1.0, 0.25);
        let counts = heuristic_solve(&p).unwrap();
        assert!(p.adjustments(&counts) <= 1, "{counts:?}");
    }

    #[test]
    fn zero_theta2_freezes_carried_apps() {
        let apps = vec![
            capp(1.0, 1.0, 1.0, 1, 100, Some(3)),
            capp(1.0, 1.0, 1.0, 1, 100, None), // new arrival may still grow
        ];
        let p = CountProblem::new(apps, Res(vec![50.0, 50.0]), 1.0, 0.0);
        let counts = heuristic_solve(&p).unwrap();
        assert_eq!(counts[0], 3, "carried app must not change, got {counts:?}");
        assert!(counts[1] >= 1);
    }

    #[test]
    fn infeasible_returns_none() {
        // n_min floors alone exceed capacity -> no feasible point
        let apps = vec![capp(10.0, 10.0, 1.0, 3, 5, None)];
        let p = CountProblem::new(apps, Res(vec![10.0, 10.0]), 1.0, 1.0);
        assert!(heuristic_solve(&p).is_none());
    }

    #[test]
    fn bounds_formulas_match_paper() {
        let p = CountProblem::new(
            vec![
                capp(1.0, 1.0, 1.0, 1, 2, Some(1)),
                capp(1.0, 1.0, 1.0, 1, 2, Some(1)),
                capp(1.0, 1.0, 1.0, 1, 2, None),
            ],
            Res(vec![10.0, 10.0]),
            0.2,
            0.6,
        );
        // m = 2: 0.2·4 = 0.8 (un-ceiled, see fairness_bound docs);
        // carried = 2: ⌈0.6·2⌉ = 2
        assert!((p.fairness_bound() - 0.8).abs() < 1e-12);
        assert_eq!(p.adjust_bound(), 2);
    }

    #[test]
    fn warm_anchor_preserves_previous_solution_shape() {
        // carried app at 5, newcomer: warm-starting from the previous
        // solution must produce a feasible point that keeps the carried
        // app's count when the budget forbids changing it.
        let apps = vec![
            capp(1.0, 1.0, 1.0, 1, 100, Some(5)),
            capp(1.0, 1.0, 1.0, 1, 100, None),
        ];
        let p = CountProblem::new(apps, Res(vec![50.0, 50.0]), 1.0, 0.0);
        let counts = heuristic_solve_from(&p, &[5, 1]).unwrap();
        assert!(p.is_feasible(&counts), "{counts:?}");
        assert_eq!(counts[0], 5, "θ₂ = 0 freezes the carried app");
        assert!(counts[1] >= 1);
    }

    #[test]
    fn prop_warm_anchor_always_feasible() {
        prop::check(120, |rng: &mut Rng| {
            let p = random_problem(rng);
            let warm: Vec<u32> = p
                .apps
                .iter()
                .map(|_| rng.range_u64(0, 10) as u32)
                .collect();
            if let Some(counts) = heuristic_solve_from(&p, &warm) {
                if !p.is_feasible(&counts) {
                    return Err(format!("infeasible warm output {counts:?} for {p:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_heuristic_solutions_always_feasible() {
        prop::check(120, |rng: &mut Rng| {
            let p = random_problem(rng);
            if let Some(counts) = heuristic_solve(&p) {
                if !p.is_feasible(&counts) {
                    return Err(format!("infeasible output {counts:?} for {p:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_heuristic_at_least_drf_utilization() {
        // the heuristic must never do worse than its DRF seed when feasible
        prop::check(80, |rng: &mut Rng| {
            let mut p = random_problem(rng);
            // no carried-over apps -> adjustment constraint vacuous
            for a in &mut p.apps {
                a.prev = None;
            }
            let drf_apps: Vec<DrfApp> = p
                .apps
                .iter()
                .map(|a| DrfApp {
                    demand: a.demand.clone(),
                    weight: a.weight,
                    n_min: a.n_min,
                    n_max: a.n_max,
                })
                .collect();
            let seed = drf_allocate(&drf_apps, &p.cap).containers;
            match heuristic_solve(&p) {
                Some(counts) => {
                    if p.utilization(&counts) + 1e-9 < p.utilization(&seed)
                        && p.fairness_loss_of(&seed) <= p.fairness_bound()
                    {
                        return Err(format!(
                            "heuristic {counts:?} worse than DRF seed {seed:?}"
                        ));
                    }
                    Ok(())
                }
                None => Ok(()), // feasibility can genuinely fail (floors)
            }
        });
    }

    pub(crate) fn random_problem(rng: &mut Rng) -> CountProblem {
        let m = rng.range_u64(2, 3) as usize;
        let cap = Res((0..m).map(|_| rng.range_f64(20.0, 120.0)).collect());
        let napps = rng.range_u64(1, 7) as usize;
        let apps: Vec<CountApp> = (0..napps)
            .map(|_| {
                let lo = rng.range_u64(0, 2) as u32;
                CountApp {
                    demand: Res((0..m).map(|_| rng.range_f64(0.5, 6.0)).collect()),
                    weight: rng.range_f64(0.5, 4.0),
                    n_min: lo,
                    n_max: lo + rng.range_u64(1, 12) as u32,
                    prev: if rng.f64() < 0.5 {
                        Some(rng.range_u64(0, 8) as u32)
                    } else {
                        None
                    },
                }
            })
            .collect();
        CountProblem::new(
            apps,
            cap,
            rng.range_f64(0.05, 0.5),
            rng.range_f64(0.0, 0.5),
        )
    }
}

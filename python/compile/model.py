"""L2: the distributed-ML applications Dorm hosts, written in JAX.

The paper evaluates Dorm on LR (Criteo), MF (MovieLens) and CNN image models
(CaffeNet / VGG-16 / GoogLeNet / AlexNet / ResNet-50) running on MxNet /
TensorFlow / Petuum / MPI-Caffe.  Here the same roles are filled by three
model families implemented directly in JAX (DESIGN.md §1 substitution table):

* ``lr``   — logistic regression (the Criteo-Log row of Table II),
* ``mf``   — matrix factorization (the MovieLens row),
* ``tfm``  — a decoder-only transformer LM standing in for the deep image
             models (iterative, compute-bound, parameter-heavy).

Every model follows the **flat-parameter convention** so the Rust parameter
server is model-agnostic (DESIGN.md §5):

    init(seed)                       -> params[N] f32
    grad(params, x, y)               -> (loss scalar f32, grads[N] f32)
    apply(params, gsum, count, lr)   -> params[N] f32   (SGD over summed grads)

``grad`` computes the *sum-of-gradients scaled by local batch*, i.e. plain
mean over the local batch; data-parallel workers each call ``grad`` on their
shard and the PS averages with ``apply`` (gsum = sum of worker grads, count =
number of workers).  The hot matmuls and the attention go through the L1
Pallas kernels.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels.matmul import fused_matmul
from .kernels.attention import causal_attention


# --------------------------------------------------------------------------
# Generic plumbing: pytree model -> flat-parameter init/grad/apply.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Everything the AOT pipeline and the Rust PS need to know."""
    name: str
    init: Callable          # (seed int32 scalar) -> params[N]
    grad: Callable           # (params[N], x, y) -> (loss, grads[N])
    apply: Callable           # (params[N], gsum[N], count, lr) -> params[N]
    n_params: int
    x_shape: tuple
    x_dtype: str              # "f32" | "i32"
    y_shape: tuple
    y_dtype: str
    meta: dict = dataclasses.field(default_factory=dict)


def _flatten_model(name, init_pytree, loss_fn, example_x, example_y, meta=None):
    """Wrap a pytree-params model into the flat-parameter convention."""
    params0 = init_pytree(jax.random.PRNGKey(0))
    flat0, unravel = ravel_pytree(params0)
    n = flat0.shape[0]

    def init(seed):
        p = init_pytree(jax.random.PRNGKey(seed))
        flat, _ = ravel_pytree(p)
        return flat.astype(jnp.float32)

    def grad(params, x, y):
        def f(flat):
            return loss_fn(unravel(flat), x, y)
        loss, g = jax.value_and_grad(f)(params)
        return loss.astype(jnp.float32), g.astype(jnp.float32)

    def apply(params, gsum, count, lr):
        return (params - lr * gsum / count).astype(jnp.float32)

    return ModelSpec(
        name=name, init=init, grad=grad, apply=apply, n_params=int(n),
        x_shape=tuple(example_x.shape),
        x_dtype="i32" if example_x.dtype == jnp.int32 else "f32",
        y_shape=tuple(example_y.shape),
        y_dtype="i32" if example_y.dtype == jnp.int32 else "f32",
        meta=dict(meta or {}),
    )


# --------------------------------------------------------------------------
# Logistic regression (Table II row 1: MxNet / Criteo-Log / LR).
# --------------------------------------------------------------------------

def make_lr(d: int = 64, batch: int = 256) -> ModelSpec:
    """Binary logistic regression over dense features.

    The forward matmul runs on the L1 fused-matmul kernel (activation fused
    at the kernel level would skip the numerically-stable xent path, so the
    kernel emits logits and the loss uses log-sigmoid directly).
    """

    def init_pytree(key):
        kw, = jax.random.split(key, 1)
        return {
            "w": jax.random.normal(kw, (d, 1), jnp.float32) * 0.01,
            "b": jnp.zeros((1,), jnp.float32),
        }

    def loss_fn(p, x, y):
        logits = fused_matmul(x, p["w"], p["b"], "linear")[:, 0]
        # mean binary cross-entropy, stable form
        return jnp.mean(jnp.maximum(logits, 0) - logits * y +
                        jnp.log1p(jnp.exp(-jnp.abs(logits))))

    ex_x = jnp.zeros((batch, d), jnp.float32)
    ex_y = jnp.zeros((batch,), jnp.float32)
    return _flatten_model("lr", init_pytree, loss_fn, ex_x, ex_y,
                          meta={"d": d, "batch": batch})


# --------------------------------------------------------------------------
# Matrix factorization (Table II row 2: TensorFlow / MovieLens / MF).
# --------------------------------------------------------------------------

def make_mf(n_users: int = 512, n_items: int = 256, k: int = 16,
            batch: int = 256, reg: float = 1e-4) -> ModelSpec:
    """Rating-prediction MF: r_ui ~ <U_u, V_i> + bias terms, L2-regularized
    squared error.  Gradients w.r.t. the embedding tables flow through
    gather -> autodiff emits the scatter-add the PS framework expects."""

    def init_pytree(key):
        ku, ki = jax.random.split(key)
        return {
            "u": jax.random.normal(ku, (n_users, k), jnp.float32) * 0.1,
            "v": jax.random.normal(ki, (n_items, k), jnp.float32) * 0.1,
            "bu": jnp.zeros((n_users,), jnp.float32),
            "bv": jnp.zeros((n_items,), jnp.float32),
            "mu": jnp.zeros((), jnp.float32),
        }

    def loss_fn(p, x, y):
        uu = jnp.take(p["u"], x[:, 0], axis=0)
        vv = jnp.take(p["v"], x[:, 1], axis=0)
        pred = (uu * vv).sum(-1) + jnp.take(p["bu"], x[:, 0]) \
            + jnp.take(p["bv"], x[:, 1]) + p["mu"]
        mse = jnp.mean((pred - y) ** 2)
        l2 = reg * ((uu ** 2).sum(-1).mean() + (vv ** 2).sum(-1).mean())
        return mse + l2

    ex_x = jnp.zeros((batch, 2), jnp.int32)
    ex_y = jnp.zeros((batch,), jnp.float32)
    return _flatten_model("mf", init_pytree, loss_fn, ex_x, ex_y,
                          meta={"n_users": n_users, "n_items": n_items,
                                "k": k, "batch": batch})


# --------------------------------------------------------------------------
# Transformer LM (stand-in for the deep image models of Table II).
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TfmConfig:
    vocab: int = 1024
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    seq: int = 64
    batch: int = 8

    @property
    def d_head(self):
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self):
        return 4 * self.d_model


def make_tfm(cfg: TfmConfig = TfmConfig(), name: str = "tfm") -> ModelSpec:
    """Pre-LN decoder-only LM.  QKV/out/MLP projections run on the L1 fused
    matmul kernel; attention runs on the L1 flash kernel."""

    def init_pytree(key):
        keys = jax.random.split(key, 3 + 6 * cfg.n_layers)
        it = iter(keys)
        s = 0.02
        p = {
            "embed": jax.random.normal(next(it), (cfg.vocab, cfg.d_model)) * s,
            "pos": jax.random.normal(next(it), (cfg.seq, cfg.d_model)) * s,
            "unembed": jax.random.normal(next(it), (cfg.d_model, cfg.vocab)) * s,
            "lnf": {"g": jnp.ones((cfg.d_model,)), "b": jnp.zeros((cfg.d_model,))},
            "layers": [],
        }
        for _ in range(cfg.n_layers):
            p["layers"].append({
                "ln1": {"g": jnp.ones((cfg.d_model,)), "b": jnp.zeros((cfg.d_model,))},
                "wqkv": jax.random.normal(next(it), (cfg.d_model, 3 * cfg.d_model)) * s,
                "bqkv": jnp.zeros((3 * cfg.d_model,)),
                "wo": jax.random.normal(next(it), (cfg.d_model, cfg.d_model)) * s,
                "bo": jnp.zeros((cfg.d_model,)),
                "ln2": {"g": jnp.ones((cfg.d_model,)), "b": jnp.zeros((cfg.d_model,))},
                "w1": jax.random.normal(next(it), (cfg.d_model, cfg.d_ff)) * s,
                "b1": jnp.zeros((cfg.d_ff,)),
                "w2": jax.random.normal(next(it), (cfg.d_ff, cfg.d_model)) * s,
                "b2": jnp.zeros((cfg.d_model,)),
            })
        return jax.tree.map(lambda a: a.astype(jnp.float32), p)

    def layernorm(h, ln):
        mu = h.mean(-1, keepdims=True)
        var = ((h - mu) ** 2).mean(-1, keepdims=True)
        return (h - mu) * jax.lax.rsqrt(var + 1e-5) * ln["g"] + ln["b"]

    def block(h, lp):
        b, s, d = h.shape
        x = layernorm(h, lp["ln1"])
        qkv = fused_matmul(x.reshape(b * s, d), lp["wqkv"], lp["bqkv"], "linear")
        qkv = qkv.reshape(b, s, 3, cfg.n_heads, cfg.d_head)
        q, k, v = (qkv[:, :, i].transpose(0, 2, 1, 3) for i in range(3))
        attn = causal_attention(q, k, v)                # [b, h, s, dh]
        attn = attn.transpose(0, 2, 1, 3).reshape(b * s, d)
        h = h + fused_matmul(attn, lp["wo"], lp["bo"], "linear").reshape(b, s, d)
        x = layernorm(h, lp["ln2"])
        y = fused_matmul(x.reshape(b * s, d), lp["w1"], lp["b1"], "gelu")
        y = fused_matmul(y, lp["w2"], lp["b2"], "linear")
        return h + y.reshape(b, s, d)

    def loss_fn(p, x, y):
        b, s = x.shape
        h = jnp.take(p["embed"], x, axis=0) + p["pos"][None, :s]
        for lp in p["layers"]:
            h = block(h, lp)
        h = layernorm(h, p["lnf"])
        logits = fused_matmul(h.reshape(b * s, cfg.d_model), p["unembed"],
                              jnp.zeros((cfg.vocab,), jnp.float32), "linear")
        logits = logits.reshape(b, s, cfg.vocab)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
        return nll.mean()

    ex_x = jnp.zeros((cfg.batch, cfg.seq), jnp.int32)
    ex_y = jnp.zeros((cfg.batch, cfg.seq), jnp.int32)
    return _flatten_model(name, init_pytree, loss_fn, ex_x, ex_y,
                          meta={"vocab": cfg.vocab, "d_model": cfg.d_model,
                                "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
                                "seq": cfg.seq, "batch": cfg.batch})


# --------------------------------------------------------------------------
# Registry used by aot.py and the tests.
# --------------------------------------------------------------------------

def default_models() -> list:
    """The artifact set built by `make artifacts`."""
    return [
        make_lr(),
        make_mf(),
        make_tfm(TfmConfig(), "tfm"),
        # The E2E driver's model: largest LM that trains a few hundred steps
        # in minutes on this 1-core image. Scales to 100M+ by editing the
        # config; see EXPERIMENTS.md §E2E.
        make_tfm(TfmConfig(vocab=4096, d_model=256, n_layers=4, n_heads=8,
                           seq=64, batch=8), "tfm_e2e"),
    ]

//! Table II, encoded verbatim, plus the §V-A-3 online workload generator:
//! 50 applications drawn from the 7 rows, submitted as a Poisson process
//! with 20-minute mean inter-arrival time.

use crate::app::Engine;
use crate::resources::Res;
use crate::util::Rng;

use super::durations::DurationModel;

/// One row of Table II.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub engine: Engine,
    pub dataset: &'static str,
    pub model: &'static str,
    /// ⟨CPUs, GPUs, RAM GB⟩ per container.
    pub demand: Res,
    pub weight: u32,
    pub n_max: u32,
    pub n_min: u32,
    /// Number of applications of this type in the 50-app workload.
    pub num: u32,
    /// Static container count the Swarm baseline gives this type (§V-A-4:
    /// "8, 8, 4, 2, 2, 2, 3").
    pub baseline_containers: u32,
    /// Median training duration at the baseline container count, hours.
    /// The paper does not state per-type durations; these are calibrated
    /// from what the models actually cost (LR on Criteo / MF on MovieLens
    /// ≈ an hour; CaffeNet on CIFAR-10 a few hours; the ImageNet models a
    /// day or more) and validated against the §V headline factors
    /// (EXPERIMENTS.md §Calib).
    pub duration_median_hours: f64,
}

/// The literal Table II (plus the §V-A-4 baseline column).
pub fn table2_rows() -> Vec<Table2Row> {
    use Engine::*;
    vec![
        Table2Row { engine: MxNet, dataset: "Criteo-Log", model: "LR",
            demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0), weight: 1, n_max: 32, n_min: 1,
            num: 20, baseline_containers: 8, duration_median_hours: 1.2 },
        Table2Row { engine: TensorFlow, dataset: "MovieLens", model: "MF",
            demand: Res::cpu_gpu_ram(2.0, 0.0, 6.0), weight: 2, n_max: 32, n_min: 1,
            num: 20, baseline_containers: 8, duration_median_hours: 1.2 },
        Table2Row { engine: MpiCaffe, dataset: "CIFAR-10", model: "CaffeNet",
            demand: Res::cpu_gpu_ram(4.0, 0.0, 6.0), weight: 4, n_max: 8, n_min: 1,
            num: 6, baseline_containers: 4, duration_median_hours: 3.0 },
        Table2Row { engine: MxNet, dataset: "ImageNet", model: "VGG-16",
            demand: Res::cpu_gpu_ram(4.0, 1.0, 32.0), weight: 1, n_max: 5, n_min: 1,
            num: 1, baseline_containers: 2, duration_median_hours: 30.0 },
        Table2Row { engine: TensorFlow, dataset: "ImageNet", model: "GoogLeNet",
            demand: Res::cpu_gpu_ram(6.0, 1.0, 16.0), weight: 1, n_max: 5, n_min: 1,
            num: 1, baseline_containers: 2, duration_median_hours: 24.0 },
        Table2Row { engine: Petuum, dataset: "ImageNet", model: "AlexNet",
            demand: Res::cpu_gpu_ram(6.0, 1.0, 16.0), weight: 2, n_max: 5, n_min: 1,
            num: 1, baseline_containers: 2, duration_median_hours: 24.0 },
        Table2Row { engine: MpiCaffe, dataset: "ImageNet", model: "ResNet-50",
            demand: Res::cpu_gpu_ram(4.0, 1.0, 32.0), weight: 4, n_max: 5, n_min: 1,
            num: 1, baseline_containers: 3, duration_median_hours: 36.0 },
    ]
}

/// One generated application instance of the online workload.
#[derive(Clone, Debug)]
pub struct WorkloadApp {
    /// Index into [`table2_rows`].
    pub row: usize,
    /// Short tag like "LR" / "VGG-16" (Fig. 9a grouping).
    pub tag: String,
    /// Submission time, hours from experiment start.
    pub submit_hours: f64,
    /// Duration the app would take at its type's baseline container count
    /// (sampled from the Fig. 1 CDF).  The sim's perf model converts this
    /// to total work via its speedup curve, so the baseline run reproduces
    /// the Fig. 1 durations exactly and Dorm's speedup comes from scaling
    /// beyond the baseline count.
    pub duration_at_baseline_hours: f64,
    /// The type's baseline container count (the §V-A-4 static allocation).
    pub baseline_n: u32,
}

/// The §V-A-3 online workload generator.
#[derive(Clone, Debug)]
pub struct WorkloadGen {
    pub rows: Vec<Table2Row>,
    pub mean_interarrival_min: f64,
    pub duration_model: DurationModel,
}

impl Default for WorkloadGen {
    fn default() -> Self {
        WorkloadGen {
            rows: table2_rows(),
            mean_interarrival_min: 20.0,
            duration_model: DurationModel::synthetic_eval(),
        }
    }
}

impl WorkloadGen {
    /// Generate the 50-app workload: the Table II type counts, shuffled
    /// into random submission order, Poisson arrivals.
    pub fn generate(&self, rng: &mut Rng) -> Vec<WorkloadApp> {
        // expand type indices per Table II "Num" column
        let mut types: Vec<usize> = Vec::new();
        for (i, row) in self.rows.iter().enumerate() {
            for _ in 0..row.num {
                types.push(i);
            }
        }
        rng.shuffle(&mut types);

        let mut out = Vec::with_capacity(types.len());
        let mut t_hours = 0.0;
        for row_idx in types {
            t_hours += rng.exponential(self.mean_interarrival_min) / 60.0;
            let row = &self.rows[row_idx];
            // Sample the app's *duration at its baseline container count*:
            // log-normal around the row's median (sigma from the synthetic
            // model), so the mix of short LR/MF and day-long ImageNet jobs
            // reproduces both the §V backlog and the §V speedups.
            let dur = row.duration_median_hours
                * rng.log_normal(0.0, self.duration_model.app_sigma);
            out.push(WorkloadApp {
                row: row_idx,
                tag: row.model.to_string(),
                submit_hours: t_hours,
                duration_at_baseline_hours: dur,
                baseline_n: row.baseline_containers.max(1),
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper() {
        let rows = table2_rows();
        assert_eq!(rows.len(), 7);
        let total: u32 = rows.iter().map(|r| r.num).sum();
        assert_eq!(total, 50, "paper: 50 applications");
        // §V-A-4 baseline container counts
        let base: Vec<u32> = rows.iter().map(|r| r.baseline_containers).collect();
        assert_eq!(base, vec![8, 8, 4, 2, 2, 2, 3]);
        // spot-check two rows against the printed table
        assert_eq!(rows[0].demand, Res::cpu_gpu_ram(2.0, 0.0, 8.0));
        assert_eq!(rows[6].model, "ResNet-50");
        assert_eq!(rows[6].weight, 4);
        assert_eq!(rows[3].n_max, 5);
    }

    #[test]
    fn generator_produces_50_sorted_arrivals() {
        let gen = WorkloadGen::default();
        let mut rng = Rng::new(1);
        let apps = gen.generate(&mut rng);
        assert_eq!(apps.len(), 50);
        for w in apps.windows(2) {
            assert!(w[0].submit_hours <= w[1].submit_hours);
        }
        // mean inter-arrival ≈ 20 min over many seeds
        let mut total = 0.0;
        let n_seeds = 40;
        for seed in 0..n_seeds {
            let mut rng = Rng::new(seed);
            let apps = gen.generate(&mut rng);
            total += apps.last().unwrap().submit_hours / 49.0;
        }
        let mean_hours = total / n_seeds as f64;
        assert!((mean_hours - 20.0 / 60.0).abs() < 0.05, "mean {mean_hours}");
    }

    #[test]
    fn type_mix_matches_counts() {
        let gen = WorkloadGen::default();
        let mut rng = Rng::new(7);
        let apps = gen.generate(&mut rng);
        for (i, row) in gen.rows.iter().enumerate() {
            let n = apps.iter().filter(|a| a.row == i).count() as u32;
            assert_eq!(n, row.num, "row {}", row.model);
        }
    }

    #[test]
    fn durations_positive_and_baseline_n_matches_row() {
        let gen = WorkloadGen::default();
        let mut rng = Rng::new(3);
        for a in gen.generate(&mut rng) {
            assert!(a.duration_at_baseline_hours > 0.0);
            assert_eq!(a.baseline_n, gen.rows[a.row].baseline_containers);
        }
    }
}

//! The `dorm master --standby` body: watch the primary, promote on death.
//!
//! A standby is a process holding nothing but a probe loop and the shared
//! [`CheckpointStore`] directory (the paper's "reliable storage system" —
//! the same place app checkpoints live).  It watches the primary with the
//! exact lease discipline slaves live under ([`crate::fault::LeaseTable`]
//! semantics, one entry): every successful probe renews the lease, and
//! when the lease has not been renewed for `master_lease`, the primary is
//! declared dead.  Takeover then is:
//!
//! 1. [`crate::master::ha::load_master`] — newest digest-valid
//!    [`MasterCheckpoint`](crate::master::ha::MasterCheckpoint) plus the
//!    same-epoch WAL tail;
//! 2. re-arm self-checkpointing (`with_ha`, continuing the sequence);
//! 3. [`DormMaster::promote`] — `epoch + 1`, leases re-anchored into this
//!    process's clock domain, a fresh snapshot at the new epoch fencing
//!    off any stale WAL appends from the deposed primary;
//! 4. serve on this process's bind address.  Slaves and `dorm ctl`
//!    re-dial the candidate list ([`super::FailoverTransport`]) and
//!    reconcile their books against the restored desired state through
//!    the ordinary heartbeat exchange.
//!
//! Split-brain: a deposed primary that is merely *partitioned* (not dead)
//! keeps serving its old epoch, but every write path is fenced — slaves
//! refuse its directives, `ctl --min-epoch` refuses to submit to it, and
//! its WAL appends are refused at the next recovery.  What this PR does
//! not provide is consensus on *who* promotes (one standby assumed; see
//! ROADMAP follow-ups).

use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::app::CheckpointStore;
use crate::config::NetConfig;
use crate::master::ha;
use crate::proto::Request;

use super::{serve, ControlPlane, ServerHandle, TcpTransport};

/// Standby behaviour knobs (`[ha]` config + `dorm master --standby` flags).
#[derive(Clone, Debug)]
pub struct StandbyOpts {
    /// Primary address to watch.
    pub watch: String,
    /// Declare the primary dead after this long without a good probe.
    pub master_lease: Duration,
    /// Probe cadence.
    pub probe_period: Duration,
    /// Self-checkpoint cadence once promoted (`DormMaster::with_ha`).
    pub snapshot_every: u64,
    /// Master snapshot files retained.
    pub snapshots_retain: usize,
}

/// One probe: connect + handshake (the handshake already proves the
/// master serves and reports its epoch).  The TCP connect is bounded by
/// `connect_timeout`: a powered-off or blackholed primary must fail the
/// probe within the lease window, not sit in SYN retries for minutes.
fn probe(addr: &str, cfg: &NetConfig, connect_timeout: Duration) -> Result<u64> {
    let mut t = TcpTransport::connect_with_timeout(addr, cfg, connect_timeout)?;
    // a cheap read keeps the probe honest beyond the TCP accept
    t.call(Request::QueryState { app: None })?;
    Ok(t.last_epoch().unwrap_or(0))
}

/// Watch the primary until its lease lapses, then promote the
/// checkpointed master state and serve it on `net.bind_addr`.  Blocks for
/// the whole watch phase; returns the serving handle once promoted.
pub fn run_standby(
    store: CheckpointStore,
    net: &NetConfig,
    opts: &StandbyOpts,
) -> Result<ServerHandle> {
    // probes must not hang past the lease window on a half-dead primary
    // (io_timeout 0 = block forever is capped at the lease here)
    let lease_ms = (opts.master_lease.as_millis() as u64).max(1);
    let probe_cfg = NetConfig {
        io_timeout_ms: if net.io_timeout_ms == 0 {
            lease_ms
        } else {
            net.io_timeout_ms.min(lease_ms)
        },
        ..net.clone()
    };
    log::info!(
        "standby: watching {} (lease {:?}, probing every {:?})",
        opts.watch,
        opts.master_lease,
        opts.probe_period
    );
    let connect_timeout = Duration::from_millis(probe_cfg.io_timeout_ms.max(1));
    let mut renewed = Instant::now();
    let mut last_epoch = 0u64;
    loop {
        match probe(&opts.watch, &probe_cfg, connect_timeout) {
            Ok(epoch) => {
                renewed = Instant::now();
                if epoch != last_epoch {
                    log::info!("standby: primary {} serves epoch {epoch}", opts.watch);
                    last_epoch = epoch;
                }
            }
            Err(e) => {
                let silent = renewed.elapsed();
                log::debug!("standby: probe failed ({e:#}); silent for {silent:?}");
                if silent >= opts.master_lease {
                    log::warn!(
                        "standby: primary {} lease lapsed ({silent:?} > {:?}); taking over",
                        opts.watch,
                        opts.master_lease
                    );
                    break;
                }
            }
        }
        std::thread::sleep(opts.probe_period);
    }

    let Some((master, seq)) = ha::load_master(&store)
        .with_context(|| format!("loading master state from {}", store.dir().display()))?
    else {
        bail!(
            "no master snapshot in {} — the primary must run with HA enabled \
             (`dorm master --ha`) for a standby to take over",
            store.dir().display()
        );
    };
    let mut master = master.with_ha(opts.snapshot_every, opts.snapshots_retain, seq)?;
    let epoch = master.promote()?;
    let view = master.state_view(None);
    log::info!(
        "standby: restored clock {} / {} app(s); promoted to epoch {epoch}",
        view.clock,
        view.apps.len()
    );
    serve(master, net)
}

//! PJRT runtime: load the AOT'd HLO-text artifacts and execute them from
//! the L3 hot path.
//!
//! Build-time Python (`python/compile/aot.py`) writes one HLO text file per
//! model function (`{model}_{init,grad,apply}.hlo.txt`) plus `manifest.kv`;
//! this module parses the manifest ([`Manifest`]), compiles the programs on
//! the PJRT CPU client (`xla` crate) and serves execute requests.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so a single
//! **compute-service thread** owns the client and all compiled executables;
//! the rest of the system talks to it through an mpsc request channel via
//! the cloneable [`ComputeHandle`].  On this 1-core image that serialization
//! costs nothing and it keeps the unsafe surface at zero.

mod manifest;
mod service;

pub use manifest::{Dtype, Manifest, ModelMeta};
pub use service::{ComputeHandle, ComputeService, GradOut, TensorData};

//! Master-failover integration tests (DESIGN.md §11), socket-free:
//!
//! * checkpoint round-trip equivalence — a dispatch trace on the original
//!   master and on its restored twin produces identical observable state;
//! * corrupt / truncated master snapshots fall back to the previous good
//!   one (mirroring the PR 2 app-checkpoint fallback tests);
//! * epoch fencing — a slave agent that has obeyed an epoch-2 master
//!   refuses a deposed epoch-1 master's directives; a deposed primary's
//!   WAL appends are refused at recovery time.

use std::cell::Cell;
use std::rc::Rc;

use dorm::app::{AppId, AppSpec, CheckpointStore, Engine};
use dorm::config::{ClusterConfig, DormConfig};
use dorm::master::{ha, DormMaster};
use dorm::net::{ControlPlane, LocalTransport, SlaveAgent};
use dorm::proto::{Request, Response};
use dorm::resources::Res;
use dorm::slave::DormSlave;

fn store(tag: &str) -> CheckpointStore {
    let d = std::env::temp_dir().join(format!("dorm_ha_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    CheckpointStore::new(d).unwrap()
}

fn spec(cpu: f64, n_min: u32, n_max: u32) -> AppSpec {
    AppSpec {
        executor: Engine::MxNet,
        demand: Res::cpu_gpu_ram(cpu, 0.0, 8.0),
        weight: 1,
        n_max,
        n_min,
        cmd: ["lr".into(), "lr".into()],
    }
}

fn master_with_store(s: CheckpointStore) -> DormMaster {
    DormMaster::new(
        &ClusterConfig::uniform(4, Res::cpu_gpu_ram(12.0, 0.0, 64.0)),
        DormConfig { theta1: 0.5, theta2: 0.5 },
        s,
    )
}

/// Drive one mixed mutating trace through `dispatch` (submissions,
/// progress, checkpoints, a completion, heartbeats, a server death and
/// recovery — every HA action class: Append and Barrier).
fn drive_trace(m: &mut DormMaster) -> Vec<AppId> {
    let mut ids = Vec::new();
    for sp in [spec(2.0, 1, 12), spec(2.0, 1, 8), spec(3.0, 1, 4)] {
        match m.dispatch(Request::Submit { spec: sp }) {
            Response::Submitted { app } => ids.push(app),
            other => panic!("submit answered {other:?}"),
        }
    }
    assert_eq!(m.dispatch(Request::AdvanceSteps { app: ids[0], steps: 120 }), Response::Ok);
    assert_eq!(m.dispatch(Request::CheckpointApp { app: ids[0] }), Response::Ok);
    assert_eq!(m.dispatch(Request::AdvanceSteps { app: ids[0], steps: 30 }), Response::Ok);
    assert_eq!(m.dispatch(Request::Complete { app: ids[2] }), Response::Ok);
    for j in 0..2 {
        let rsp = m.dispatch(Request::Heartbeat {
            server: j,
            now_hours: 1.0,
            report: None,
            acks: vec![],
        });
        assert!(matches!(rsp, Response::HeartbeatAck { .. }), "{rsp:?}");
    }
    // a barrier event: fail_server reads the store, so it snapshots
    match m.dispatch(Request::FailServer { server: 3 }) {
        Response::Affected { .. } => {}
        other => panic!("fail answered {other:?}"),
    }
    assert_eq!(
        m.dispatch(Request::RecoverServer { server: 3, now_hours: 2.0 }),
        Response::Ok
    );
    ids
}

/// The ISSUE's round-trip pin: drive a trace on an HA-armed master,
/// rebuild a twin with `load_master`, then drive an *identical further
/// trace* on both — the observable state must stay equal step for step.
#[test]
fn checkpoint_roundtrip_dispatch_equivalence() {
    let s = store("equiv");
    let mut original = master_with_store(s.clone()).with_ha(4, 8, 0).unwrap();
    let ids = drive_trace(&mut original);

    let (mut restored, seq) = ha::load_master(&s).unwrap().expect("snapshot exists");
    assert!(seq >= 1, "mutating events must have advanced the journal");
    assert_eq!(restored.state_view(None), original.state_view(None));
    assert_eq!(restored.epoch(), original.epoch(), "restore does not bump the epoch");

    // a slave reporting the pre-restore book is already converged: the
    // restored desired state matches what the cluster is actually running
    let report = original.slaves[0].report();
    let (alive, directives) = restored
        .heartbeat_report(0, 3.0, Some(&report))
        .unwrap();
    assert!(alive);
    assert!(directives.is_empty(), "restored book must be converged: {directives:?}");

    // identical further traffic on both masters: lockstep equality.
    // (The new app's n_max exactly fills the free capacity — 12 + 8 of
    // 24 container slots held — so the optimum is unique and the
    // original's warm-start state cannot pick a different-but-equal
    // placement than the restored master's cold solve.)
    for m in [&mut original, &mut restored] {
        match m.dispatch(Request::Submit { spec: spec(2.0, 1, 4) }) {
            Response::Submitted { .. } => {}
            other => panic!("submit answered {other:?}"),
        }
        assert_eq!(m.dispatch(Request::AdvanceSteps { app: ids[1], steps: 9 }), Response::Ok);
        assert_eq!(m.dispatch(Request::Reallocate), Response::Ok);
    }
    assert_eq!(restored.state_view(None), original.state_view(None));
    for (a, b) in original.slaves.iter().zip(&restored.slaves) {
        assert_eq!(a.inventory(), b.inventory(), "{} book differs", a.name);
    }
}

/// Everything after the seed snapshot rides the WAL (cadence never
/// reached): the tail must replay to the same state.
#[test]
fn wal_tail_replays_to_identical_state() {
    let s = store("wal_tail");
    let mut m = master_with_store(s.clone()).with_ha(10_000, 3, 0).unwrap();
    let id = match m.dispatch(Request::Submit { spec: spec(2.0, 1, 10) }) {
        Response::Submitted { app } => app,
        other => panic!("{other:?}"),
    };
    assert_eq!(m.dispatch(Request::AdvanceSteps { app: id, steps: 77 }), Response::Ok);
    assert_eq!(m.dispatch(Request::CheckpointApp { app: id }), Response::Ok);
    // only the seed snapshot exists; the three events live in the WAL
    assert_eq!(s.master_files().unwrap().len(), 1);
    assert!(!ha::read_wal(&s).unwrap().is_empty());

    let (restored, seq) = ha::load_master(&s).unwrap().expect("snapshot exists");
    assert_eq!(seq, 3, "three mutating events replayed");
    assert_eq!(restored.state_view(None), m.state_view(None));
    assert_eq!(restored.steps_of(id), 77);
}

/// A corrupt (bit-flipped) or truncated newest master snapshot must fall
/// back to the previous good one, not fail the takeover.
#[test]
fn corrupt_master_snapshot_falls_back_to_previous_good() {
    let s = store("fallback");
    // snapshot_every = 1: every mutating dispatch writes a full snapshot
    let mut m = master_with_store(s.clone()).with_ha(1, 8, 0).unwrap();
    match m.dispatch(Request::Submit { spec: spec(2.0, 1, 12) }) {
        Response::Submitted { .. } => {}
        other => panic!("{other:?}"),
    }
    let view_one_app = m.state_view(None);
    match m.dispatch(Request::Submit { spec: spec(2.0, 1, 8) }) {
        Response::Submitted { .. } => {}
        other => panic!("{other:?}"),
    }
    let files = s.master_files().unwrap();
    assert!(files.len() >= 3, "seed + one per submit: {files:?}");

    // bit-flip the newest snapshot
    let newest = files.last().unwrap();
    let mut bytes = std::fs::read(newest).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(newest, &bytes).unwrap();

    let (restored, _) = ha::load_master(&s).unwrap().expect("fallback snapshot");
    assert_eq!(
        restored.state_view(None),
        view_one_app,
        "fallback must serve the previous good snapshot's state"
    );

    // truncate it instead: same fallback
    std::fs::write(newest, &bytes[..bytes.len() / 3]).unwrap();
    let (restored, _) = ha::load_master(&s).unwrap().expect("fallback snapshot");
    assert_eq!(restored.state_view(None), view_one_app);
}

/// Falling back past a corrupt newest snapshot must NOT splice the
/// surviving WAL tail (which continues from the *corrupt* snapshot's
/// sequence) onto the older state — that would fabricate a history that
/// never existed.  Replay stops at the first non-contiguous record.
#[test]
fn fallback_refuses_non_contiguous_wal_tail() {
    let s = store("gap");
    // cadence 2: odd events ride the WAL, even events snapshot + reset it
    let mut m = master_with_store(s.clone()).with_ha(2, 8, 0).unwrap();
    // seq 1 rides the WAL
    let id = match m.dispatch(Request::Submit { spec: spec(2.0, 1, 12) }) {
        Response::Submitted { app } => app,
        other => panic!("{other:?}"),
    };
    let advance = |m: &mut DormMaster| {
        assert_eq!(m.dispatch(Request::AdvanceSteps { app: id, steps: 10 }), Response::Ok);
    };
    advance(&mut m); // seq 2: snapshot (cadence rollover)
    let view_at_snapshot = m.state_view(None);
    advance(&mut m); // seq 3: WAL
    advance(&mut m); // seq 4: snapshot (resets the WAL)
    advance(&mut m); // seq 5: WAL

    // corrupt the seq-4 snapshot: restore falls back to seq 2, and the
    // WAL's seq-5 record (contiguous only with seq 4) must be refused
    let files = s.master_files().unwrap();
    let newest = files.last().unwrap();
    let mut bytes = std::fs::read(newest).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(newest, &bytes).unwrap();

    let (restored, seq) = ha::load_master(&s).unwrap().expect("fallback snapshot");
    assert_eq!(seq, 2, "replay must stop at the restored snapshot");
    assert_eq!(restored.state_view(None), view_at_snapshot);
    assert_eq!(restored.steps_of(id), 10, "the seq-5 advance must not apply over seq-2 state");
}

/// Promotion is the only epoch bump: same state, term + 1, and the
/// promoted master re-snapshots so recovery starts from the new epoch.
#[test]
fn promote_bumps_epoch_and_persists_it() {
    let s = store("promote");
    let mut m = master_with_store(s.clone()).with_ha(64, 4, 0).unwrap();
    drive_trace(&mut m);
    let before = m.state_view(None);
    let (mut standby, seq) = ha::load_master(&s).unwrap().unwrap();
    standby = standby.with_ha(64, 4, seq).unwrap();
    let new_epoch = standby.promote().unwrap();
    assert_eq!(new_epoch, before.epoch + 1);
    let mut after = standby.state_view(None);
    assert_eq!(after.epoch, before.epoch + 1);
    after.epoch = before.epoch;
    assert_eq!(after, before, "promotion changes the term, not the state");
    // the new epoch is durable: a later recovery restores epoch + 1
    let (recovered, _) = ha::load_master(&s).unwrap().unwrap();
    assert_eq!(recovered.epoch(), new_epoch);
}

/// A transport that routes to one of two in-process masters — the
/// socket-free stand-in for "the slave dialed the wrong (deposed)
/// master after a takeover".
struct FlipTransport {
    old_primary: LocalTransport,
    new_primary: LocalTransport,
    use_new: Rc<Cell<bool>>,
}

impl ControlPlane for FlipTransport {
    fn call(&mut self, req: Request) -> anyhow::Result<Response> {
        if self.use_new.get() {
            self.new_primary.call(req)
        } else {
            self.old_primary.call(req)
        }
    }

    fn last_epoch(&self) -> Option<u64> {
        if self.use_new.get() {
            self.new_primary.last_epoch()
        } else {
            self.old_primary.last_epoch()
        }
    }
}

/// The ISSUE's fencing unit: two masters, and the lower epoch's
/// directives are rejected wholesale by a slave that has already obeyed
/// the higher epoch.
#[test]
fn deposed_masters_directives_are_fenced() {
    // the new primary (epoch 2) wants 12 containers of app1 on its books
    let mut new_primary = master_with_store(store("fence_new")).with_epoch(2);
    let id = new_primary.submit(spec(2.0, 1, 12)).unwrap();
    assert_eq!(new_primary.containers_of(id), 12);
    // the deposed primary (epoch 1) manages nothing: its reconciliation
    // would order the slave to destroy everything it holds
    let old_primary = master_with_store(store("fence_old"));
    assert_eq!(old_primary.epoch(), 1);

    let use_new = Rc::new(Cell::new(true));
    let transport = FlipTransport {
        old_primary: LocalTransport::new(old_primary),
        new_primary: LocalTransport::new(new_primary),
        use_new: Rc::clone(&use_new),
    };
    let local = DormSlave::new("slave00", Res::cpu_gpu_ram(12.0, 0.0, 64.0));
    let mut agent = SlaveAgent::new(local, 0, transport);

    // obey the epoch-2 master: the book converges on its desired state
    let out = agent.step(1.0).unwrap();
    assert!(!out.fenced);
    assert!(out.applied >= 1);
    assert_eq!(agent.max_epoch(), 2);
    let held = agent.local().count_for(id);
    assert!(held > 0, "epoch-2 placement landed");

    // now the slave reaches the deposed epoch-1 master instead
    use_new.set(false);
    let out = agent.step(2.0).unwrap();
    assert!(out.fenced, "stale-epoch answer must be fenced");
    assert!(out.directives >= 1, "the deposed master did try to issue writes");
    assert_eq!(out.applied, 0, "none of them may apply");
    assert_eq!(agent.local().count_for(id), held, "book untouched");
    assert_eq!(agent.max_epoch(), 2, "fence holds");

    // back on the real primary: business as usual
    use_new.set(true);
    let out = agent.step(3.0).unwrap();
    assert!(!out.fenced);
}

/// Store-level fencing: WAL records a deposed primary appends after the
/// standby promoted (and re-snapshotted at epoch + 1) are refused by the
/// next recovery.
#[test]
fn deposed_primary_wal_appends_are_refused() {
    let s = store("deposed_wal");
    // primary at epoch 1, everything in the WAL after the seed snapshot
    let mut deposed = master_with_store(s.clone()).with_ha(10_000, 8, 0).unwrap();
    match deposed.dispatch(Request::Submit { spec: spec(2.0, 1, 12) }) {
        Response::Submitted { .. } => {}
        other => panic!("{other:?}"),
    }

    // standby takes over: restore (replays app1), re-arm, promote
    let (standby, seq) = ha::load_master(&s).unwrap().unwrap();
    let mut standby = standby.with_ha(10_000, 8, seq).unwrap();
    standby.promote().unwrap();
    assert_eq!(standby.epoch(), 2);
    assert_eq!(standby.active_apps(), 1);
    let promoted_view = standby.state_view(None);

    // the deposed primary, unaware, keeps writing at epoch 1
    match deposed.dispatch(Request::Submit { spec: spec(2.0, 1, 4) }) {
        Response::Submitted { .. } => {}
        other => panic!("{other:?}"),
    }
    assert_eq!(deposed.active_apps(), 2, "the deposed fork diverged locally");
    assert!(!ha::read_wal(&s).unwrap().is_empty(), "its append landed in the WAL");

    // recovery sees the epoch-2 snapshot and refuses the epoch-1 record
    let (recovered, _) = ha::load_master(&s).unwrap().unwrap();
    assert_eq!(recovered.epoch(), 2);
    assert_eq!(recovered.active_apps(), 1, "deposed write fenced out of history");
    assert_eq!(recovered.state_view(None), promoted_view);
}

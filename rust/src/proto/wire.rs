//! Binary wire format for the control-plane protocol (DESIGN.md §9).
//!
//! serde is not in this image's vendored registry (same constraint as
//! [`crate::config`]), so the encoding is hand-rolled and deliberately
//! boring:
//!
//! * **Frame**: `u32` big-endian payload length, then the payload.  A
//!   receiver enforces a configurable length limit *before* allocating
//!   ([`read_frame`]); an oversized frame is fatal to the connection
//!   (framing cannot be resynchronized past an unread body).
//! * **Payload**: one tag byte selecting the message, then its fields.
//!   Integers are big-endian; `f64` travels as its IEEE-754 bits (NaN
//!   round-trips — the protocol uses non-finite times as "stamp at
//!   arrival" markers); strings are `u32` length + UTF-8; options are a
//!   `0/1` byte; vectors/maps are a `u32` count + elements.
//! * **Evolution**: decoders read the fields they know and ignore any
//!   trailing bytes, which is the extension room for same-major additions;
//!   an unknown *tag* is a typed [`WireError::UnknownRequestTag`] /
//!   [`WireError::UnknownResponseTag`] so the server can answer with a
//!   decodable [`ErrorCode::UnsupportedRequest`] instead of hanging up.
//!
//! Everything here is pure bytes↔types; sockets live in [`crate::net`].

use std::collections::BTreeMap;
use std::fmt;
use std::io::{Read, Write};

use crate::app::{AppId, AppSpec, AppState, Engine};
use crate::proto::{
    AckKind, AppView, Directive, DirectiveAck, ErrorCode, ProtoError, Request, Response,
    StateView,
};
use crate::resources::Res;
use crate::slave::SlaveReport;

/// Frame header size: the `u32` payload length.
pub const FRAME_HEADER: usize = 4;

/// Decode/IO failure. IO errors only arise from the framing helpers.
#[derive(Debug)]
pub enum WireError {
    /// Payload ended before a field was complete.
    Truncated,
    /// First payload byte is no request this version knows.
    UnknownRequestTag(u8),
    /// First payload byte is no response this version knows.
    UnknownResponseTag(u8),
    /// A field decoded to an out-of-domain value (bad UTF-8, bad enum...).
    Malformed(String),
    /// Declared frame length exceeds the configured limit.
    FrameTooLarge { len: usize, max: usize },
    /// Socket/stream failure while framing (never from pure decoding).
    Io(std::io::Error),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated payload"),
            WireError::UnknownRequestTag(t) => write!(f, "unknown request tag {t:#04x}"),
            WireError::UnknownResponseTag(t) => write!(f, "unknown response tag {t:#04x}"),
            WireError::Malformed(m) => write!(f, "malformed payload: {m}"),
            WireError::FrameTooLarge { len, max } => {
                write!(f, "frame of {len} B exceeds the {max} B limit")
            }
            WireError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

// ---- framing ------------------------------------------------------------

/// Write one `len || payload` frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8], max: usize) -> Result<(), WireError> {
    if payload.len() > max {
        return Err(WireError::FrameTooLarge { len: payload.len(), max });
    }
    w.write_all(&(payload.len() as u32).to_be_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame, enforcing `max` before the body is allocated.
pub fn read_frame(r: &mut impl Read, max: usize) -> Result<Vec<u8>, WireError> {
    let mut hdr = [0u8; FRAME_HEADER];
    r.read_exact(&mut hdr)?;
    let len = u32::from_be_bytes(hdr) as usize;
    if len > max {
        return Err(WireError::FrameTooLarge { len, max });
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(payload)
}

// ---- primitive readers --------------------------------------------------

/// Bounds-checked reader over a decoded payload.  `pub(crate)` so the
/// master self-checkpoint format (`crate::master::ha`) reuses the same
/// hostile-input discipline instead of re-deriving it.
pub(crate) struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Cur { buf, pos: 0 }
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.buf.len() {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Bytes not yet consumed (trailing extension room).
    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub(crate) fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_be_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub(crate) fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_be_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_be_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub(crate) fn bool(&mut self) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(WireError::Malformed(format!("bool byte {b}"))),
        }
    }

    /// Element counts are validated against the remaining bytes (one byte
    /// per element minimum) so a hostile count cannot drive a huge
    /// allocation out of a small frame.
    pub(crate) fn count(&mut self, min_elem_bytes: usize) -> Result<usize, WireError> {
        let n = self.u32()? as usize;
        if n.saturating_mul(min_elem_bytes.max(1)) > self.buf.len() - self.pos {
            return Err(WireError::Truncated);
        }
        Ok(n)
    }

    pub(crate) fn str(&mut self) -> Result<String, WireError> {
        let n = self.count(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::Malformed("string is not UTF-8".into()))
    }

    pub(crate) fn res(&mut self) -> Result<Res, WireError> {
        let m = self.count(8)?;
        let mut v = Vec::with_capacity(m);
        for _ in 0..m {
            v.push(self.f64()?);
        }
        Ok(Res(v))
    }
}

// ---- primitive writers --------------------------------------------------

pub(crate) fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_be_bytes());
    out.extend_from_slice(s.as_bytes());
}

pub(crate) fn put_res(out: &mut Vec<u8>, r: &Res) {
    out.extend_from_slice(&(r.0.len() as u32).to_be_bytes());
    for &x in &r.0 {
        out.extend_from_slice(&x.to_bits().to_be_bytes());
    }
}

pub(crate) fn put_f64(out: &mut Vec<u8>, x: f64) {
    out.extend_from_slice(&x.to_bits().to_be_bytes());
}

// ---- shared composite types ---------------------------------------------

fn engine_tag(e: Engine) -> u8 {
    match e {
        Engine::MxNet => 0,
        Engine::TensorFlow => 1,
        Engine::Petuum => 2,
        Engine::MpiCaffe => 3,
    }
}

fn engine_of(tag: u8) -> Result<Engine, WireError> {
    Ok(match tag {
        0 => Engine::MxNet,
        1 => Engine::TensorFlow,
        2 => Engine::Petuum,
        3 => Engine::MpiCaffe,
        t => return Err(WireError::Malformed(format!("engine tag {t}"))),
    })
}

pub(crate) fn state_tag(s: AppState) -> u8 {
    match s {
        AppState::Pending => 0,
        AppState::Running => 1,
        AppState::Checkpointing => 2,
        AppState::Killed => 3,
        AppState::Resuming => 4,
        AppState::Degraded => 5,
        AppState::Recovering => 6,
        AppState::Completed => 7,
        AppState::Failed => 8,
    }
}

pub(crate) fn state_of(tag: u8) -> Result<AppState, WireError> {
    Ok(match tag {
        0 => AppState::Pending,
        1 => AppState::Running,
        2 => AppState::Checkpointing,
        3 => AppState::Killed,
        4 => AppState::Resuming,
        5 => AppState::Degraded,
        6 => AppState::Recovering,
        7 => AppState::Completed,
        8 => AppState::Failed,
        t => return Err(WireError::Malformed(format!("app-state tag {t}"))),
    })
}

pub(crate) fn put_spec(out: &mut Vec<u8>, s: &AppSpec) {
    out.push(engine_tag(s.executor));
    put_res(out, &s.demand);
    out.extend_from_slice(&s.weight.to_be_bytes());
    out.extend_from_slice(&s.n_max.to_be_bytes());
    out.extend_from_slice(&s.n_min.to_be_bytes());
    put_str(out, &s.cmd[0]);
    put_str(out, &s.cmd[1]);
}

pub(crate) fn spec(c: &mut Cur) -> Result<AppSpec, WireError> {
    Ok(AppSpec {
        executor: engine_of(c.u8()?)?,
        demand: c.res()?,
        weight: c.u32()?,
        n_max: c.u32()?,
        n_min: c.u32()?,
        cmd: [c.str()?, c.str()?],
    })
}

fn put_report(out: &mut Vec<u8>, r: &SlaveReport) {
    put_str(out, &r.name);
    put_res(out, &r.capacity);
    put_res(out, &r.available);
    out.extend_from_slice(&(r.containers.len() as u32).to_be_bytes());
    for (id, n) in &r.containers {
        out.extend_from_slice(&id.0.to_be_bytes());
        out.extend_from_slice(&n.to_be_bytes());
    }
}

fn report(c: &mut Cur) -> Result<SlaveReport, WireError> {
    let name = c.str()?;
    let capacity = c.res()?;
    let available = c.res()?;
    let n = c.count(12)?;
    let mut containers = BTreeMap::new();
    for _ in 0..n {
        let id = AppId(c.u64()?);
        containers.insert(id, c.u32()?);
    }
    Ok(SlaveReport { name, capacity, available, containers })
}

fn put_directive(out: &mut Vec<u8>, d: &Directive) {
    match d {
        Directive::Create { app, demand, count } => {
            out.push(0);
            out.extend_from_slice(&app.0.to_be_bytes());
            put_res(out, demand);
            out.extend_from_slice(&count.to_be_bytes());
        }
        Directive::Destroy { app, count } => {
            out.push(1);
            out.extend_from_slice(&app.0.to_be_bytes());
            out.extend_from_slice(&count.to_be_bytes());
        }
        Directive::DestroyAll { app } => {
            out.push(2);
            out.extend_from_slice(&app.0.to_be_bytes());
        }
    }
}

fn directive(c: &mut Cur) -> Result<Directive, WireError> {
    Ok(match c.u8()? {
        0 => Directive::Create { app: AppId(c.u64()?), demand: c.res()?, count: c.u32()? },
        1 => Directive::Destroy { app: AppId(c.u64()?), count: c.u32()? },
        2 => Directive::DestroyAll { app: AppId(c.u64()?) },
        t => return Err(WireError::Malformed(format!("directive tag {t}"))),
    })
}

/// Fixed-width ack element: kind byte, app id, applied byte (v1.2).
const ACK_BYTES: usize = 10;

fn put_ack(out: &mut Vec<u8>, a: &DirectiveAck) {
    out.push(match a.kind {
        AckKind::Create => 0,
        AckKind::Destroy => 1,
        AckKind::DestroyAll => 2,
    });
    out.extend_from_slice(&a.app.0.to_be_bytes());
    out.push(u8::from(a.applied));
}

fn ack(c: &mut Cur) -> Result<DirectiveAck, WireError> {
    let kind = match c.u8()? {
        0 => AckKind::Create,
        1 => AckKind::Destroy,
        2 => AckKind::DestroyAll,
        t => return Err(WireError::Malformed(format!("ack kind {t}"))),
    };
    Ok(DirectiveAck { kind, app: AppId(c.u64()?), applied: c.bool()? })
}

// ---- requests -----------------------------------------------------------

const REQ_HELLO: u8 = 0x01;
const REQ_SUBMIT: u8 = 0x02;
const REQ_COMPLETE: u8 = 0x03;
const REQ_HEARTBEAT: u8 = 0x04;
const REQ_CREATE: u8 = 0x05;
const REQ_DESTROY: u8 = 0x06;
const REQ_CHECKPOINT: u8 = 0x07;
const REQ_ADVANCE: u8 = 0x08;
const REQ_REALLOCATE: u8 = 0x09;
const REQ_EXPIRE: u8 = 0x0a;
const REQ_FAIL: u8 = 0x0b;
const REQ_RECOVER: u8 = 0x0c;
const REQ_QUERY: u8 = 0x0d;
const REQ_SHUTDOWN: u8 = 0x0e;
const REQ_REGISTER: u8 = 0x0f;

/// Encode a request payload (tag byte + fields; no frame header — pair
/// with [`write_frame`]).  The v1.0-compatible base encoding: retry ids
/// go through [`encode_request_rid`] instead.
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut out = Vec::with_capacity(32);
    match req {
        Request::Hello { major, minor } => {
            out.push(REQ_HELLO);
            out.extend_from_slice(&major.to_be_bytes());
            out.extend_from_slice(&minor.to_be_bytes());
        }
        Request::Submit { spec } => {
            out.push(REQ_SUBMIT);
            put_spec(&mut out, spec);
        }
        Request::Complete { app } => {
            out.push(REQ_COMPLETE);
            out.extend_from_slice(&app.0.to_be_bytes());
        }
        Request::Heartbeat { server, now_hours, report, acks } => {
            out.push(REQ_HEARTBEAT);
            out.extend_from_slice(&server.to_be_bytes());
            put_f64(&mut out, *now_hours);
            match report {
                None => out.push(0),
                Some(r) => {
                    out.push(1);
                    put_report(&mut out, r);
                }
            }
            // v1.2 batched directive acks, deliberately trailing (after
            // every v1.1 field) so an ack-less decoder still parses
            out.extend_from_slice(&(acks.len() as u32).to_be_bytes());
            for a in acks {
                put_ack(&mut out, a);
            }
        }
        Request::CreateContainers { server, app, demand, count } => {
            out.push(REQ_CREATE);
            out.extend_from_slice(&server.to_be_bytes());
            out.extend_from_slice(&app.0.to_be_bytes());
            put_res(&mut out, demand);
            out.extend_from_slice(&count.to_be_bytes());
        }
        Request::Destroy { server, app, count } => {
            out.push(REQ_DESTROY);
            out.extend_from_slice(&server.to_be_bytes());
            out.extend_from_slice(&app.0.to_be_bytes());
            match count {
                None => out.push(0),
                Some(n) => {
                    out.push(1);
                    out.extend_from_slice(&n.to_be_bytes());
                }
            }
        }
        Request::CheckpointApp { app } => {
            out.push(REQ_CHECKPOINT);
            out.extend_from_slice(&app.0.to_be_bytes());
        }
        Request::AdvanceSteps { app, steps } => {
            out.push(REQ_ADVANCE);
            out.extend_from_slice(&app.0.to_be_bytes());
            out.extend_from_slice(&steps.to_be_bytes());
        }
        Request::Reallocate => out.push(REQ_REALLOCATE),
        Request::ExpireLeases { now_hours } => {
            out.push(REQ_EXPIRE);
            put_f64(&mut out, *now_hours);
        }
        Request::FailServer { server } => {
            out.push(REQ_FAIL);
            out.extend_from_slice(&server.to_be_bytes());
        }
        Request::RecoverServer { server, now_hours } => {
            out.push(REQ_RECOVER);
            out.extend_from_slice(&server.to_be_bytes());
            put_f64(&mut out, *now_hours);
        }
        Request::QueryState { app } => {
            out.push(REQ_QUERY);
            match app {
                None => out.push(0),
                Some(id) => {
                    out.push(1);
                    out.extend_from_slice(&id.0.to_be_bytes());
                }
            }
        }
        Request::Shutdown => out.push(REQ_SHUTDOWN),
        Request::Register { name, capacity } => {
            out.push(REQ_REGISTER);
            put_str(&mut out, name);
            put_res(&mut out, capacity);
        }
    }
    out
}

/// Encode a request with a client-generated retry id appended as a
/// trailing extension (v1.3).  Only the mutating requests a retrying
/// client may legally re-send across a failover re-dial — `Submit` and
/// `Complete` — are stamped; for everything else the bytes are exactly
/// [`encode_request`] (idempotent requests need no dedupe, and `Heartbeat`
/// already uses its trailing room for the ack batch).
pub fn encode_request_rid(req: &Request, rid: Option<u64>) -> Vec<u8> {
    let mut out = encode_request(req);
    if let (Some(rid), Request::Submit { .. } | Request::Complete { .. }) = (rid, req) {
        out.extend_from_slice(&rid.to_be_bytes());
    }
    out
}

/// Decode a request plus the optional trailing retry id.  `None` means a
/// pre-v1.3 peer (or a request kind that is never stamped).
pub fn decode_request_rid(payload: &[u8]) -> Result<(Request, Option<u64>), WireError> {
    let mut c = Cur::new(payload);
    let req = decode_request_cur(&mut c)?;
    let rid = match req {
        Request::Submit { .. } | Request::Complete { .. } if c.remaining() >= 8 => {
            Some(c.u64()?)
        }
        _ => None,
    };
    Ok((req, rid))
}

/// Decode a request payload, ignoring any trailing extensions (the v1.0
/// view of the bytes; servers use [`decode_request_rid`] to also see
/// the retry id).
pub fn decode_request(payload: &[u8]) -> Result<Request, WireError> {
    decode_request_cur(&mut Cur::new(payload))
}

fn decode_request_cur(c: &mut Cur) -> Result<Request, WireError> {
    let req = match c.u8()? {
        REQ_HELLO => Request::Hello { major: c.u16()?, minor: c.u16()? },
        REQ_SUBMIT => Request::Submit { spec: spec(&mut c)? },
        REQ_COMPLETE => Request::Complete { app: AppId(c.u64()?) },
        REQ_HEARTBEAT => {
            let server = c.u32()?;
            let now_hours = c.f64()?;
            let report = if c.bool()? { Some(report(&mut c)?) } else { None };
            // trailing v1.2 field: absent from a v1.1 peer's frame, in
            // which case the batch is simply empty
            let acks = if c.remaining() >= 4 {
                let n = c.count(ACK_BYTES)?;
                let mut acks = Vec::with_capacity(n);
                for _ in 0..n {
                    acks.push(ack(&mut c)?);
                }
                acks
            } else {
                Vec::new()
            };
            Request::Heartbeat { server, now_hours, report, acks }
        }
        REQ_CREATE => Request::CreateContainers {
            server: c.u32()?,
            app: AppId(c.u64()?),
            demand: c.res()?,
            count: c.u32()?,
        },
        REQ_DESTROY => {
            let server = c.u32()?;
            let app = AppId(c.u64()?);
            let count = if c.bool()? { Some(c.u32()?) } else { None };
            Request::Destroy { server, app, count }
        }
        REQ_CHECKPOINT => Request::CheckpointApp { app: AppId(c.u64()?) },
        REQ_ADVANCE => Request::AdvanceSteps { app: AppId(c.u64()?), steps: c.u64()? },
        REQ_REALLOCATE => Request::Reallocate,
        REQ_EXPIRE => Request::ExpireLeases { now_hours: c.f64()? },
        REQ_FAIL => Request::FailServer { server: c.u32()? },
        REQ_RECOVER => Request::RecoverServer { server: c.u32()?, now_hours: c.f64()? },
        REQ_QUERY => {
            let app = if c.bool()? { Some(AppId(c.u64()?)) } else { None };
            Request::QueryState { app }
        }
        REQ_SHUTDOWN => Request::Shutdown,
        REQ_REGISTER => Request::Register { name: c.str()?, capacity: c.res()? },
        t => return Err(WireError::UnknownRequestTag(t)),
    };
    Ok(req)
}

// ---- responses ----------------------------------------------------------

const RSP_HELLO_ACK: u8 = 0x81;
const RSP_OK: u8 = 0x82;
const RSP_SUBMITTED: u8 = 0x83;
const RSP_HEARTBEAT_ACK: u8 = 0x84;
const RSP_EXPIRED: u8 = 0x85;
const RSP_AFFECTED: u8 = 0x86;
const RSP_STATE: u8 = 0x87;
const RSP_ERROR: u8 = 0x88;
const RSP_REGISTERED: u8 = 0x89;

/// Encode a response payload (tag byte + fields; no frame header).  The
/// v1.0-compatible base encoding: serving masters append their epoch via
/// [`encode_response_ep`].
pub fn encode_response(rsp: &Response) -> Vec<u8> {
    let mut out = Vec::with_capacity(32);
    match rsp {
        Response::HelloAck { major, minor } => {
            out.push(RSP_HELLO_ACK);
            out.extend_from_slice(&major.to_be_bytes());
            out.extend_from_slice(&minor.to_be_bytes());
        }
        Response::Ok => out.push(RSP_OK),
        Response::Submitted { app } => {
            out.push(RSP_SUBMITTED);
            out.extend_from_slice(&app.0.to_be_bytes());
        }
        Response::HeartbeatAck { alive, directives } => {
            out.push(RSP_HEARTBEAT_ACK);
            out.push(u8::from(*alive));
            out.extend_from_slice(&(directives.len() as u32).to_be_bytes());
            for d in directives {
                put_directive(&mut out, d);
            }
        }
        Response::Expired { dead } => {
            out.push(RSP_EXPIRED);
            out.extend_from_slice(&(dead.len() as u32).to_be_bytes());
            for j in dead {
                out.extend_from_slice(&j.to_be_bytes());
            }
        }
        Response::Affected { apps } => {
            out.push(RSP_AFFECTED);
            out.extend_from_slice(&(apps.len() as u32).to_be_bytes());
            for a in apps {
                out.extend_from_slice(&a.0.to_be_bytes());
            }
        }
        Response::State(v) => {
            out.push(RSP_STATE);
            out.extend_from_slice(&v.clock.to_be_bytes());
            out.extend_from_slice(&v.alive_servers.to_be_bytes());
            out.extend_from_slice(&v.total_servers.to_be_bytes());
            out.extend_from_slice(&v.active_apps.to_be_bytes());
            out.extend_from_slice(&v.total_adjustments.to_be_bytes());
            out.extend_from_slice(&v.total_recoveries.to_be_bytes());
            put_f64(&mut out, v.utilization);
            out.extend_from_slice(&(v.apps.len() as u32).to_be_bytes());
            for a in &v.apps {
                out.extend_from_slice(&a.id.0.to_be_bytes());
                out.push(state_tag(a.state));
                out.extend_from_slice(&a.containers.to_be_bytes());
                out.extend_from_slice(&a.steps_done.to_be_bytes());
                out.extend_from_slice(&a.ckpt_step.to_be_bytes());
                out.extend_from_slice(&a.adjustments.to_be_bytes());
                out.extend_from_slice(&a.recoveries.to_be_bytes());
            }
            // v1.1 addition, deliberately *trailing* (after every v1.0
            // field) so an epoch-less v1.0 decoder still parses the body
            out.extend_from_slice(&v.epoch.to_be_bytes());
        }
        Response::Error(e) => {
            out.push(RSP_ERROR);
            out.extend_from_slice(&e.code.as_u16().to_be_bytes());
            put_str(&mut out, &e.detail);
        }
        Response::Registered { server } => {
            out.push(RSP_REGISTERED);
            out.extend_from_slice(&server.to_be_bytes());
        }
    }
    out
}

/// Encode a response with the serving master's epoch (term) appended as a
/// trailing field — v1.1's same-major extension (DESIGN.md §11).  A v1.0
/// decoder ignores the trailing bytes; a v1.1 peer reads the epoch and
/// uses it for split-brain fencing.
pub fn encode_response_ep(rsp: &Response, epoch: u64) -> Vec<u8> {
    let mut out = encode_response(rsp);
    out.extend_from_slice(&epoch.to_be_bytes());
    out
}

/// Decode a response plus the optional trailing epoch.  `None` means the
/// peer is an epoch-less v1.0 master.
pub fn decode_response_ep(payload: &[u8]) -> Result<(Response, Option<u64>), WireError> {
    let mut c = Cur::new(payload);
    let rsp = decode_response_cur(&mut c)?;
    let epoch = if c.remaining() >= 8 { Some(c.u64()?) } else { None };
    Ok((rsp, epoch))
}

/// Decode a response payload, ignoring any trailing extensions (clients
/// that fence epochs use [`decode_response_ep`]).
pub fn decode_response(payload: &[u8]) -> Result<Response, WireError> {
    decode_response_cur(&mut Cur::new(payload))
}

fn decode_response_cur(c: &mut Cur) -> Result<Response, WireError> {
    let rsp = match c.u8()? {
        RSP_HELLO_ACK => Response::HelloAck { major: c.u16()?, minor: c.u16()? },
        RSP_OK => Response::Ok,
        RSP_SUBMITTED => Response::Submitted { app: AppId(c.u64()?) },
        RSP_HEARTBEAT_ACK => {
            let alive = c.bool()?;
            let n = c.count(9)?;
            let mut directives = Vec::with_capacity(n);
            for _ in 0..n {
                directives.push(directive(c)?);
            }
            Response::HeartbeatAck { alive, directives }
        }
        RSP_EXPIRED => {
            let n = c.count(4)?;
            let mut dead = Vec::with_capacity(n);
            for _ in 0..n {
                dead.push(c.u32()?);
            }
            Response::Expired { dead }
        }
        RSP_AFFECTED => {
            let n = c.count(8)?;
            let mut apps = Vec::with_capacity(n);
            for _ in 0..n {
                apps.push(AppId(c.u64()?));
            }
            Response::Affected { apps }
        }
        RSP_STATE => {
            let clock = c.u64()?;
            let alive_servers = c.u32()?;
            let total_servers = c.u32()?;
            let active_apps = c.u32()?;
            let total_adjustments = c.u32()?;
            let total_recoveries = c.u32()?;
            let utilization = c.f64()?;
            let n = c.count(37)?;
            let mut apps = Vec::with_capacity(n);
            for _ in 0..n {
                apps.push(AppView {
                    id: AppId(c.u64()?),
                    state: state_of(c.u8()?)?,
                    containers: c.u32()?,
                    steps_done: c.u64()?,
                    ckpt_step: c.u64()?,
                    adjustments: c.u32()?,
                    recoveries: c.u32()?,
                });
            }
            // trailing v1.1 field: absent from a v1.0 master's body, in
            // which case the epoch is simply unknown (0 = pre-epoch)
            let epoch = if c.remaining() >= 8 { c.u64()? } else { 0 };
            Response::State(StateView {
                clock,
                epoch,
                alive_servers,
                total_servers,
                active_apps,
                total_adjustments,
                total_recoveries,
                utilization,
                apps,
            })
        }
        RSP_ERROR => Response::Error(ProtoError {
            code: ErrorCode::from_u16(c.u16()?),
            detail: c.str()?,
        }),
        RSP_REGISTERED => Response::Registered { server: c.u32()? },
        t => return Err(WireError::UnknownResponseTag(t)),
    };
    Ok(rsp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample_requests() -> Vec<Request> {
        let spec = AppSpec {
            executor: Engine::MpiCaffe,
            demand: Res::cpu_gpu_ram(1.0, 1.0, 8.0),
            weight: 2,
            n_max: 5,
            n_min: 1,
            cmd: ["lr".into(), "lr --resume".into()],
        };
        let report = SlaveReport {
            name: "slave03".into(),
            capacity: Res::cpu_gpu_ram(12.0, 0.0, 128.0),
            available: Res::cpu_gpu_ram(8.0, 0.0, 96.0),
            containers: [(AppId(1), 2), (AppId(9), 1)].into_iter().collect(),
        };
        vec![
            Request::Hello { major: 1, minor: 0 },
            Request::Submit { spec },
            Request::Complete { app: AppId(7) },
            Request::Heartbeat {
                server: 3,
                now_hours: 2.25,
                report: Some(report),
                acks: vec![
                    DirectiveAck { app: AppId(1), kind: AckKind::Create, applied: true },
                    DirectiveAck { app: AppId(9), kind: AckKind::Destroy, applied: false },
                    DirectiveAck { app: AppId(2), kind: AckKind::DestroyAll, applied: true },
                ],
            },
            Request::Heartbeat {
                server: 0,
                now_hours: f64::NAN,
                report: None,
                acks: vec![],
            },
            Request::CreateContainers {
                server: 1,
                app: AppId(4),
                demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0),
                count: 3,
            },
            Request::Destroy { server: 1, app: AppId(4), count: Some(2) },
            Request::Destroy { server: 1, app: AppId(4), count: None },
            Request::CheckpointApp { app: AppId(4) },
            Request::AdvanceSteps { app: AppId(4), steps: 1_000_000 },
            Request::Reallocate,
            Request::ExpireLeases { now_hours: 17.5 },
            Request::FailServer { server: 19 },
            Request::RecoverServer { server: 19, now_hours: 18.0 },
            Request::QueryState { app: None },
            Request::QueryState { app: Some(AppId(2)) },
            Request::Shutdown,
            Request::Register {
                name: "slave07".into(),
                capacity: Res::cpu_gpu_ram(16.0, 2.0, 128.0),
            },
        ]
    }

    fn sample_responses() -> Vec<Response> {
        vec![
            Response::HelloAck { major: 1, minor: 0 },
            Response::Ok,
            Response::Submitted { app: AppId(11) },
            Response::HeartbeatAck {
                alive: true,
                directives: vec![
                    Directive::Create {
                        app: AppId(1),
                        demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0),
                        count: 4,
                    },
                    Directive::Destroy { app: AppId(2), count: 1 },
                    Directive::DestroyAll { app: AppId(3) },
                ],
            },
            Response::HeartbeatAck { alive: false, directives: vec![] },
            Response::Expired { dead: vec![0, 5] },
            Response::Affected { apps: vec![AppId(1), AppId(2)] },
            Response::State(StateView {
                clock: 42,
                epoch: 3,
                alive_servers: 3,
                total_servers: 4,
                active_apps: 2,
                total_adjustments: 5,
                total_recoveries: 1,
                utilization: 1.875,
                apps: vec![AppView {
                    id: AppId(1),
                    state: AppState::Recovering,
                    containers: 6,
                    steps_done: 1000,
                    ckpt_step: 900,
                    adjustments: 2,
                    recoveries: 1,
                }],
            }),
            Response::Error(ProtoError::new(ErrorCode::UnknownApp, "app9 not found")),
            Response::Registered { server: 7 },
        ]
    }

    /// NaN != NaN, so request equality is checked through the debug form.
    #[test]
    fn requests_roundtrip() {
        for req in sample_requests() {
            let buf = encode_request(&req);
            let back = decode_request(&buf).unwrap();
            assert_eq!(format!("{back:?}"), format!("{req:?}"));
        }
    }

    #[test]
    fn responses_roundtrip() {
        for rsp in sample_responses() {
            let buf = encode_response(&rsp);
            assert_eq!(decode_response(&buf).unwrap(), rsp);
        }
    }

    #[test]
    fn trailing_bytes_are_extension_room() {
        // a same-major peer may append fields; decoders must not reject
        let mut buf = encode_request(&Request::Reallocate);
        buf.extend_from_slice(&[1, 2, 3]);
        assert_eq!(decode_request(&buf).unwrap(), Request::Reallocate);
    }

    /// The epoch envelope is exactly such a trailing extension: epoch-aware
    /// decoders read it, epoch-less ones ignore it, and every response
    /// variant carries it unchanged.
    #[test]
    fn epoch_envelope_roundtrips_on_every_response() {
        for rsp in sample_responses() {
            let buf = encode_response_ep(&rsp, 7);
            let (back, epoch) = decode_response_ep(&buf).unwrap();
            assert_eq!(back, rsp);
            assert_eq!(epoch, Some(7));
            // a v1.0-style decoder sees the same response, no epoch
            assert_eq!(decode_response(&buf).unwrap(), rsp);
        }
        // an epoch-less frame decodes with None (v1.0 master)
        let bare = encode_response(&Response::Ok);
        assert_eq!(decode_response_ep(&bare).unwrap(), (Response::Ok, None));
    }

    /// The retry id is a trailing extension on exactly the re-sendable
    /// mutating requests: stamped frames round-trip it, bare frames decode
    /// as `None` (pre-v1.3 peer), and never-stamped kinds ignore it.
    #[test]
    fn retry_id_roundtrips_on_mutating_requests() {
        let submit = Request::Submit {
            spec: AppSpec {
                executor: Engine::MxNet,
                demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0),
                weight: 1,
                n_max: 4,
                n_min: 1,
                cmd: ["lr".into(), "lr".into()],
            },
        };
        let complete = Request::Complete { app: AppId(3) };
        for req in [&submit, &complete] {
            let buf = encode_request_rid(req, Some(0xDEAD_BEEF));
            let (back, rid) = decode_request_rid(&buf).unwrap();
            assert_eq!(&back, req);
            assert_eq!(rid, Some(0xDEAD_BEEF));
            // a rid-less decoder still parses the request itself
            assert_eq!(&decode_request(&buf).unwrap(), req);
            // a rid-less frame decodes with None
            let bare = encode_request(req);
            assert_eq!(decode_request_rid(&bare).unwrap(), (req.clone(), None));
        }
        // non-stamped kinds: the rid argument is dropped on encode and
        // trailing bytes are never misread as one on decode
        let q = Request::QueryState { app: None };
        let buf = encode_request_rid(&q, Some(7));
        assert_eq!(buf, encode_request(&q));
        let mut padded = encode_request(&Request::Reallocate);
        padded.extend_from_slice(&7u64.to_be_bytes());
        assert_eq!(decode_request_rid(&padded).unwrap(), (Request::Reallocate, None));
    }

    #[test]
    fn unknown_tags_are_typed() {
        assert!(matches!(
            decode_request(&[0x7f]),
            Err(WireError::UnknownRequestTag(0x7f))
        ));
        assert!(matches!(
            decode_response(&[0x03]),
            Err(WireError::UnknownResponseTag(0x03))
        ));
        assert!(matches!(decode_request(&[]), Err(WireError::Truncated)));
    }

    /// Every truncation of every sample message must produce a typed
    /// error, never a panic or a bogus success that consumed garbage.
    #[test]
    fn truncations_never_panic() {
        for req in sample_requests() {
            let buf = encode_request(&req);
            for cut in 0..buf.len() {
                let _ = decode_request(&buf[..cut]);
            }
        }
        for rsp in sample_responses() {
            let buf = encode_response(&rsp);
            for cut in 0..buf.len() {
                let _ = decode_response(&buf[..cut]);
            }
        }
    }

    /// Deterministic byte fuzz: random payloads decode to a typed error
    /// or a value — never a panic, never an oversized allocation.
    #[test]
    fn random_bytes_never_panic() {
        let mut rng = Rng::new(0xd0e);
        for _ in 0..2000 {
            let len = rng.below(64) as usize;
            let buf: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            let _ = decode_request(&buf);
            let _ = decode_response(&buf);
        }
    }

    /// A v1.1 peer's heartbeat has no trailing ack section; it must still
    /// decode, with an empty batch (the same-major evolution rule the
    /// epoch envelope uses, applied to a request).
    #[test]
    fn ackless_heartbeat_decodes_as_empty_batch() {
        let mut buf = vec![REQ_HEARTBEAT];
        buf.extend_from_slice(&3u32.to_be_bytes());
        buf.extend_from_slice(&2.5f64.to_bits().to_be_bytes());
        buf.push(0); // report: None — and the v1.1 frame ends here
        match decode_request(&buf).unwrap() {
            Request::Heartbeat { server, report, acks, .. } => {
                assert_eq!(server, 3);
                assert!(report.is_none());
                assert!(acks.is_empty());
            }
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn hostile_counts_rejected() {
        // Heartbeat with a report whose container count claims 2^31
        // entries but supplies none: must fail Truncated, not allocate.
        let mut buf = vec![REQ_HEARTBEAT];
        buf.extend_from_slice(&0u32.to_be_bytes());
        buf.extend_from_slice(&1.0f64.to_bits().to_be_bytes());
        buf.push(1); // Some(report)
        buf.extend_from_slice(&2u32.to_be_bytes()); // name len 2
        buf.extend_from_slice(b"s0");
        buf.extend_from_slice(&0u32.to_be_bytes()); // capacity m=0
        buf.extend_from_slice(&0u32.to_be_bytes()); // available m=0
        buf.extend_from_slice(&0x8000_0000u32.to_be_bytes()); // container count
        assert!(matches!(decode_request(&buf), Err(WireError::Truncated)));
    }

    #[test]
    fn framing_roundtrip_and_limits() {
        let payload = encode_request(&Request::QueryState { app: None });
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload, 1024).unwrap();
        assert_eq!(buf.len(), FRAME_HEADER + payload.len());
        let mut rd = &buf[..];
        assert_eq!(read_frame(&mut rd, 1024).unwrap(), payload);

        // oversize refused on both sides
        assert!(matches!(
            write_frame(&mut Vec::new(), &[0u8; 100], 64),
            Err(WireError::FrameTooLarge { .. })
        ));
        let mut huge = Vec::new();
        huge.extend_from_slice(&(1_000_000u32).to_be_bytes());
        let mut rd = &huge[..];
        assert!(matches!(
            read_frame(&mut rd, 64),
            Err(WireError::FrameTooLarge { len: 1_000_000, max: 64 })
        ));

        // truncated stream: typed io error, no hang on in-memory readers
        let mut partial = Vec::new();
        partial.extend_from_slice(&(10u32).to_be_bytes());
        partial.extend_from_slice(&[1, 2, 3]);
        let mut rd = &partial[..];
        assert!(matches!(read_frame(&mut rd, 64), Err(WireError::Io(_))));
    }
}

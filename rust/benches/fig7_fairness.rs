//! Fig. 7 reproduction: fairness loss of the testbed over 24 h.
//!
//! Paper headlines (§V-B-2): Dorm bounds fairness loss by θ₁·2m (Dorm-1
//! within ~1.5, Dorm-3 within ~0.6); Dorm-3 reduces fairness loss ×1.52
//! vs the baseline on average.

#[path = "harness/mod.rs"]
mod harness;

use dorm::report;
use dorm::sim::{fairness_reduction, Experiment};

fn main() {
    harness::banner("Fig. 7 — fairness loss over 24 h");
    let exp = Experiment::paper(17);
    let runs = exp.run_all();
    let (baseline, dorms) = runs.split_first().unwrap();

    let mut rows = Vec::new();
    for r in &runs {
        rows.push(vec![
            r.label.clone(),
            format!("{:.3}", r.metrics().fairness_loss.mean_over(0.0, 24.0)),
            format!("{:.3}", r.metrics().fairness_loss.max()),
        ]);
    }
    println!("{}", report::table(&["system", "mean loss", "max loss"], &rows));

    harness::paper_row(
        "Dorm-1 max fairness loss (θ₁=0.2 -> bound 1.2)",
        "<= ~1.5",
        &format!("{:.2}", dorms[0].metrics().fairness_loss.max()),
    );
    harness::paper_row(
        "Dorm-3 max fairness loss (θ₁=0.1 -> bound 0.6)",
        "<= ~0.6",
        &format!("{:.2}", dorms[2].metrics().fairness_loss.max()),
    );
    harness::paper_row(
        "Dorm-3 fairness-loss reduction vs baseline",
        "1.52x",
        &format!("{:.2}x", fairness_reduction(&dorms[2], baseline, 24.0)),
    );
    harness::paper_row(
        "Dorm-1 tolerates more loss than Dorm-3",
        "yes",
        if dorms[0].metrics().fairness_loss.max()
            >= dorms[2].metrics().fairness_loss.max() - 1e-9
        {
            "yes"
        } else {
            "no"
        },
    );

    let series: Vec<(String, Vec<(f64, f64)>)> = runs
        .iter()
        .map(|r| (r.label.clone(), r.metrics().fairness_loss.resample(0.0, 24.0, 64)))
        .collect();
    let refs: Vec<(&str, &[(f64, f64)])> =
        series.iter().map(|(l, s)| (l.as_str(), s.as_slice())).collect();
    println!("\n{}", report::ascii_chart(&refs, 12, 64));
}

//! BSP data-parallel trainer over the PJRT compute service.
//!
//! One [`Trainer`] is the execution side of one Dorm application: its
//! worker slots correspond to the containers of the application's
//! partition.  The step loop is the PS framework's BSP round (Fig. 2):
//!
//! ```text
//! step s: for each worker w < W:  (loss_w, g_w) = grad(params, shard(w, s))
//!         params <- apply(params, Σ g_w, W, lr)
//! ```
//!
//! Checkpointing snapshots `(params, step)` through the digest-checked
//! [`CheckpointStore`]; resuming at a different worker count W′ continues
//! the same training run at the new data-parallel width — the property
//! Dorm's checkpoint-based resource adjustment (§III-C-2) relies on.

use anyhow::{bail, Context, Result};

use crate::app::{AppId, Checkpoint, CheckpointStore};
use crate::runtime::{ComputeHandle, ModelMeta};

use super::data::ShardGen;

/// Task-scheduling policy of the local TaskScheduler (§II-A: "such as
/// Bulk Synchronous Parallel (BSP) or Stale Synchronous Parallel (SSP)").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncMode {
    /// All workers' gradients are averaged into one update per step.
    Bsp,
    /// Workers push gradients one at a time against a cached copy of the
    /// parameters that may be up to `staleness` steps old (SSP bound s).
    Ssp { staleness: u32 },
}

/// Trainer hyperparameters.
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    /// Worker slots (= containers of the partition).
    pub workers: u32,
    pub lr: f32,
    /// Parameter-init seed.
    pub seed: i32,
    /// Data seed (teacher + shards).
    pub data_seed: u64,
    pub mode: SyncMode,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig { workers: 1, lr: 0.1, seed: 1, data_seed: 1, mode: SyncMode::Bsp }
    }
}

/// One step's record (loss curve entry).
#[derive(Clone, Copy, Debug)]
pub struct StepLog {
    pub step: u64,
    /// Mean worker loss at this step.
    pub loss: f32,
    pub wall_millis: u128,
}

/// The live trainer for one application.
pub struct Trainer {
    pub app: AppId,
    meta: ModelMeta,
    compute: ComputeHandle,
    shards: ShardGen,
    cfg: TrainerConfig,
    params: Vec<f32>,
    step: u64,
    /// SSP: per-worker cached params + the step they were refreshed at.
    stale_cache: Vec<(u64, Vec<f32>)>,
    pub history: Vec<StepLog>,
}

impl Trainer {
    /// Fresh trainer: params from the model's AOT'd init program.
    pub fn new(
        app: AppId,
        meta: &ModelMeta,
        compute: ComputeHandle,
        cfg: TrainerConfig,
    ) -> Result<Trainer> {
        if cfg.workers == 0 {
            bail!("trainer needs at least one worker slot");
        }
        let params = compute
            .init(&meta.name, cfg.seed)
            .context("init params")?;
        Ok(Trainer {
            app,
            meta: meta.clone(),
            shards: ShardGen::new(meta, cfg.data_seed),
            compute,
            cfg,
            params,
            step: 0,
            stale_cache: Vec::new(),
            history: Vec::new(),
        })
    }

    /// Resume from the newest checkpoint in `store` with a (possibly
    /// different) worker count — the §III-C-2 resume path.
    pub fn resume(
        app: AppId,
        meta: &ModelMeta,
        compute: ComputeHandle,
        cfg: TrainerConfig,
        store: &CheckpointStore,
    ) -> Result<Trainer> {
        let ckpt = store
            .load_latest(app)?
            .ok_or_else(|| anyhow::anyhow!("no checkpoint for {app}"))?;
        if ckpt.model != meta.name {
            bail!("checkpoint is for model {:?}, app runs {:?}", ckpt.model, meta.name);
        }
        if ckpt.params.len() != meta.n_params {
            bail!(
                "checkpoint has {} params, model wants {}",
                ckpt.params.len(),
                meta.n_params
            );
        }
        Ok(Trainer {
            app,
            meta: meta.clone(),
            shards: ShardGen::new(meta, cfg.data_seed),
            compute,
            cfg,
            step: ckpt.step,
            params: ckpt.params,
            stale_cache: Vec::new(),
            history: Vec::new(),
        })
    }

    /// One training step across all worker slots (BSP or SSP semantics).
    pub fn step(&mut self) -> Result<StepLog> {
        let t0 = std::time::Instant::now();
        let loss = match self.cfg.mode {
            SyncMode::Bsp => self.step_bsp()?,
            SyncMode::Ssp { staleness } => self.step_ssp(staleness)?,
        };
        self.step += 1;
        let log = StepLog {
            step: self.step,
            loss,
            wall_millis: t0.elapsed().as_millis(),
        };
        self.history.push(log);
        Ok(log)
    }

    /// BSP round: every worker's gradient on the *same* params, one update.
    fn step_bsp(&mut self) -> Result<f32> {
        let mut gsum = vec![0.0f32; self.meta.n_params];
        let mut loss_sum = 0.0f32;
        for w in 0..self.cfg.workers {
            let (x, y) = self.shards.batch(w, self.step);
            let out = self
                .compute
                .grad(&self.meta.name, self.params.clone(), x, y)
                .with_context(|| format!("grad worker {w} step {}", self.step))?;
            for (acc, g) in gsum.iter_mut().zip(&out.grads) {
                *acc += g;
            }
            loss_sum += out.loss;
        }
        self.params = self
            .compute
            .apply(
                &self.meta.name,
                std::mem::take(&mut self.params),
                gsum,
                self.cfg.workers as f32,
                self.cfg.lr,
            )
            .context("apply")?;
        Ok(loss_sum / self.cfg.workers as f32)
    }

    /// SSP round: each worker computes against a cached parameter copy no
    /// older than `staleness` steps and the server applies immediately
    /// (per-worker updates within the round, count = 1).
    fn step_ssp(&mut self, staleness: u32) -> Result<f32> {
        if self.stale_cache.len() != self.cfg.workers as usize {
            self.stale_cache = (0..self.cfg.workers)
                .map(|_| (self.step, self.params.clone()))
                .collect();
        }
        let mut loss_sum = 0.0f32;
        for w in 0..self.cfg.workers {
            let (refreshed, cached) = &mut self.stale_cache[w as usize];
            if self.step - *refreshed >= staleness as u64 {
                *refreshed = self.step;
                *cached = self.params.clone();
            }
            let (x, y) = self.shards.batch(w, self.step);
            let out = self
                .compute
                .grad(&self.meta.name, cached.clone(), x, y)
                .with_context(|| format!("ssp grad worker {w} step {}", self.step))?;
            self.params = self
                .compute
                .apply(
                    &self.meta.name,
                    std::mem::take(&mut self.params),
                    out.grads,
                    1.0,
                    self.cfg.lr,
                )
                .context("ssp apply")?;
            loss_sum += out.loss;
        }
        Ok(loss_sum / self.cfg.workers as f32)
    }

    /// Run `n` steps, returning the last log.
    pub fn run(&mut self, n: u64) -> Result<StepLog> {
        let mut last = None;
        for _ in 0..n {
            last = Some(self.step()?);
        }
        last.ok_or_else(|| anyhow::anyhow!("run(0)"))
    }

    /// Snapshot to the checkpoint store (§III-C-2 save path).
    pub fn checkpoint(&self, store: &CheckpointStore) -> Result<std::path::PathBuf> {
        store.save(&Checkpoint {
            app: self.app,
            step: self.step,
            model: self.meta.name.clone(),
            loss: self.history.last().map(|l| l.loss).unwrap_or(f32::NAN),
            params: self.params.clone(),
        })
    }

    pub fn current_step(&self) -> u64 {
        self.step
    }

    pub fn workers(&self) -> u32 {
        self.cfg.workers
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.history.last().map(|l| l.loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ComputeService, Manifest};

    fn service(models: &[&str]) -> Option<(Manifest, ComputeService)> {
        let dir = std::path::Path::new("artifacts");
        if !dir.join("manifest.kv").exists() {
            return None;
        }
        let manifest = Manifest::load(dir).unwrap();
        let svc = ComputeService::start_filtered(&manifest, Some(models)).unwrap();
        Some((manifest, svc))
    }

    fn store(tag: &str) -> CheckpointStore {
        let d = std::env::temp_dir().join(format!("dorm_trainer_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        CheckpointStore::new(d).unwrap()
    }

    #[test]
    fn lr_learns_with_two_workers() {
        let Some((manifest, svc)) = service(&["lr"]) else { return };
        let meta = manifest.model("lr").unwrap();
        let cfg = TrainerConfig { workers: 2, lr: 0.5, ..Default::default() };
        let mut t = Trainer::new(AppId(1), meta, svc.handle(), cfg).unwrap();
        let first = t.step().unwrap().loss;
        let last = t.run(25).unwrap().loss;
        assert!(last < first * 0.7, "{first} -> {last}");
    }

    #[test]
    fn checkpoint_resume_roundtrip_preserves_state() {
        let Some((manifest, svc)) = service(&["lr"]) else { return };
        let meta = manifest.model("lr").unwrap();
        let st = store("roundtrip");
        let cfg = TrainerConfig { workers: 2, lr: 0.3, ..Default::default() };
        let mut t = Trainer::new(AppId(2), meta, svc.handle(), cfg.clone()).unwrap();
        t.run(5).unwrap();
        let params_before = t.params().to_vec();
        t.checkpoint(&st).unwrap();

        // kill + resume at a DIFFERENT width (the Dorm adjustment)
        let cfg2 = TrainerConfig { workers: 4, ..cfg };
        let mut r = Trainer::resume(AppId(2), meta, svc.handle(), cfg2, &st).unwrap();
        assert_eq!(r.current_step(), 5);
        assert_eq!(r.params(), params_before.as_slice());
        assert_eq!(r.workers(), 4);
        // training continues and still improves
        let l1 = r.step().unwrap().loss;
        let l2 = r.run(15).unwrap().loss;
        assert!(l2 < l1 * 1.05, "{l1} -> {l2}");
    }

    #[test]
    fn resume_guards_model_mismatch() {
        let Some((manifest, svc)) = service(&["lr", "mf"]) else { return };
        let st = store("mismatch");
        let lr = manifest.model("lr").unwrap();
        let mf = manifest.model("mf").unwrap();
        let mut t = Trainer::new(AppId(3), lr, svc.handle(), TrainerConfig::default()).unwrap();
        t.run(1).unwrap();
        t.checkpoint(&st).unwrap();
        let err = match Trainer::resume(AppId(3), mf, svc.handle(), TrainerConfig::default(), &st) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("resume with wrong model must fail"),
        };
        assert!(err.contains("model") || err.contains("checkpoint"), "{err}");
    }

    #[test]
    fn deterministic_replay_same_seeds() {
        let Some((manifest, svc)) = service(&["mf"]) else { return };
        let meta = manifest.model("mf").unwrap();
        let cfg = TrainerConfig { workers: 2, lr: 0.2, seed: 9, data_seed: 5, ..Default::default() };
        let mut a = Trainer::new(AppId(4), meta, svc.handle(), cfg.clone()).unwrap();
        let mut b = Trainer::new(AppId(5), meta, svc.handle(), cfg).unwrap();
        a.run(3).unwrap();
        b.run(3).unwrap();
        assert_eq!(a.params(), b.params(), "same seeds must replay identically");
    }

    #[test]
    fn zero_workers_rejected() {
        let Some((manifest, svc)) = service(&["lr"]) else { return };
        let meta = manifest.model("lr").unwrap();
        let cfg = TrainerConfig { workers: 0, ..Default::default() };
        assert!(Trainer::new(AppId(6), meta, svc.handle(), cfg).is_err());
    }
}

#[cfg(test)]
mod ssp_tests {
    use super::*;
    use crate::runtime::{ComputeService, Manifest};

    fn service() -> Option<(Manifest, ComputeService)> {
        let dir = std::path::Path::new("artifacts");
        if !dir.join("manifest.kv").exists() {
            return None;
        }
        let manifest = Manifest::load(dir).unwrap();
        let svc = ComputeService::start_filtered(&manifest, Some(&["lr"])).unwrap();
        Some((manifest, svc))
    }

    #[test]
    fn ssp_converges_and_differs_from_bsp() {
        let Some((manifest, svc)) = service() else { return };
        let meta = manifest.model("lr").unwrap();
        let bsp_cfg = TrainerConfig { workers: 3, lr: 0.3, ..Default::default() };
        let ssp_cfg = TrainerConfig { mode: SyncMode::Ssp { staleness: 2 }, ..bsp_cfg.clone() };

        let mut bsp = Trainer::new(crate::app::AppId(11), meta, svc.handle(), bsp_cfg).unwrap();
        let mut ssp = Trainer::new(crate::app::AppId(12), meta, svc.handle(), ssp_cfg).unwrap();
        let b0 = bsp.step().unwrap().loss;
        let s0 = ssp.step().unwrap().loss;
        let b = bsp.run(20).unwrap().loss;
        let s = ssp.run(20).unwrap().loss;
        assert!(b < b0 * 0.8, "bsp: {b0} -> {b}");
        assert!(s < s0 * 0.8, "ssp must converge too: {s0} -> {s}");
        // different update schedules -> different trajectories
        assert_ne!(bsp.params(), ssp.params());
    }

    #[test]
    fn ssp_staleness_zero_refreshes_every_step() {
        let Some((manifest, svc)) = service() else { return };
        let meta = manifest.model("lr").unwrap();
        let cfg = TrainerConfig {
            workers: 2,
            lr: 0.2,
            mode: SyncMode::Ssp { staleness: 0 },
            ..Default::default()
        };
        let mut t = Trainer::new(crate::app::AppId(13), meta, svc.handle(), cfg).unwrap();
        let first = t.step().unwrap().loss;
        let last = t.run(15).unwrap().loss;
        assert!(last < first, "{first} -> {last}");
    }
}

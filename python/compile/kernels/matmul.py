"""L1: tiled fused matmul Pallas kernel.

``fused_matmul(x, w, b, activation)`` computes ``act(x @ w + b)`` as a single
Pallas kernel.  This is the compute hot-spot shared by every L2 model in the
repo: the LR forward, the MF score path (dense variant) and every projection
/ MLP matmul inside the transformer LM.

TPU adaptation (DESIGN.md §2).  The paper's workloads were written for GPU
clusters (CUDA threadblocks staging tiles through shared memory).  Here the
same insight — keep operand tiles resident in fast memory and stream the K
dimension — is expressed the TPU way:

* ``BlockSpec`` carries the HBM->VMEM schedule.  The grid is
  ``(M/bm, N/bn, K/bk)`` and XLA/Mosaic double-buffers the HBM loads between
  grid steps; on GPU this is the hand-written cp.async pipeline.
* The (bm, bn) f32 accumulator lives in a VMEM scratch ref across the K
  grid dimension (revisiting semantics), mirroring the MXU's native
  accumulate-into-f32 path rather than WMMA fragment accumulation.
* Tile sizes default to 128-multiples when the problem allows, matching the
  128x128 MXU systolic array; small problems fall back to the full dim.

The kernel MUST run with ``interpret=True`` on this image: real TPU lowering
emits a Mosaic custom-call that the CPU PJRT plugin cannot execute.
Correctness is pinned against the pure-jnp oracle in ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Activations the kernel can fuse. Keys are stable strings so the L2 model
# code and the tests can enumerate them.
_ACTIVATIONS = {
    "linear": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "gelu": jax.nn.gelu,
    "sigmoid": jax.nn.sigmoid,
}


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= preferred, biased to MXU-friendly
    multiples.  Guarantees the grid exactly tiles the problem."""
    if dim <= preferred:
        return dim
    for cand in (preferred, 128, 64, 32, 16, 8, 4, 2):
        if cand <= preferred and dim % cand == 0:
            return cand
    return 1


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, act, k_steps):
    """Grid = (M/bm, N/bn, K/bk); K is the innermost (fastest) dimension so
    the accumulator scratch is revisited across K steps."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU path: accumulate in f32 regardless of input dtype.
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _store():
        out = acc_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = _ACTIVATIONS[act](out).astype(o_ref.dtype)


def fused_matmul_fwd(x, w, b, activation="linear", *, bm=128, bn=128, bk=128):
    """act(x @ w + b) as a Pallas kernel (forward only, no autodiff rule).

    x: [M, K], w: [K, N], b: [N]. Returns [M, N] in x.dtype.
    Tile sizes are clamped/snapped to divisors of the problem dims.
    """
    if x.ndim != 2 or w.ndim != 2 or b.ndim != 1:
        raise ValueError(f"fused_matmul expects x[M,K], w[K,N], b[N]; got "
                         f"{x.shape}, {w.shape}, {b.shape}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or b.shape[0] != n:
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")
    if activation not in _ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")

    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    grid = (m // bm, n // bn, k // bk)

    kernel = functools.partial(_matmul_kernel, act=activation, k_steps=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        # f32 VMEM accumulator tile, revisited across the K grid dim.
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_matmul(x, w, b, activation="linear"):
    """Differentiable fused matmul: forward runs the Pallas kernel, backward
    re-derives gradients with Pallas matmuls (dX = g @ Wt, dW = Xt @ g) plus
    the activation's local derivative — so the backward pass exercises the
    same L1 kernel."""
    return fused_matmul_fwd(x, w, b, activation)


def _vjp_fwd(x, w, b, activation):
    z = fused_matmul_fwd(x, w, b, "linear")  # pre-activation, saved for bwd
    y = _ACTIVATIONS[activation](z)
    return y.astype(x.dtype), (x, w, z)


def _vjp_bwd(activation, res, g):
    x, w, z = res
    # d act / d z evaluated via jax on the saved pre-activation.
    _, act_vjp = jax.vjp(_ACTIVATIONS[activation], z)
    (gz,) = act_vjp(g.astype(z.dtype))
    zeros_n = jnp.zeros((w.shape[1],), jnp.float32)
    zeros_k = jnp.zeros((w.shape[0],), jnp.float32)
    dx = fused_matmul_fwd(gz, w.T, zeros_k, "linear").astype(x.dtype)
    dw = fused_matmul_fwd(x.T, gz, zeros_n, "linear").astype(w.dtype)
    db = jnp.sum(gz, axis=0).astype(z.dtype)
    return dx, dw, db


fused_matmul.defvjp(_vjp_fwd, _vjp_bwd)


def vmem_footprint_bytes(m, k, n, bm=128, bn=128, bk=128, in_bytes=4):
    """Static VMEM footprint estimate for the chosen tiling (DESIGN.md §7):
    x tile + w tile + b tile + out tile + f32 accumulator, x2 for the
    double-buffered HBM->VMEM pipeline on the streamed operands."""
    bm, bn, bk = _pick_block(m, bm), _pick_block(n, bn), _pick_block(k, bk)
    stream = (bm * bk + bk * bn) * in_bytes * 2  # double-buffered
    resident = bn * in_bytes + bm * bn * in_bytes + bm * bn * 4
    return stream + resident


def mxu_utilization_estimate(m, k, n, bm=128, bn=128, bk=128):
    """Fraction of MXU 128x128x128 macro-ops doing useful work for this
    tiling — 1.0 when every tile dim is a 128 multiple."""
    bm, bn, bk = _pick_block(m, bm), _pick_block(n, bn), _pick_block(k, bk)
    eff = 1.0
    for t in (bm, bn, bk):
        eff *= min(t, 128) / 128.0 if t < 128 else 1.0
    return eff

"""L1 kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes/dtypes for both Pallas kernels and asserts
allclose against ref.py; explicit cases pin block-edge behaviour and the
custom-vjp backward passes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import (causal_attention, causal_attention_fwd,
                                       vmem_footprint_bytes as attn_vmem)
from compile.kernels.matmul import (fused_matmul, fused_matmul_fwd,
                                    mxu_utilization_estimate,
                                    vmem_footprint_bytes as mm_vmem)

RNG = np.random.default_rng(1234)


def randn(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


# ---------------------------------------------------------------- matmul --

ACTS = ["linear", "relu", "gelu", "sigmoid"]


@pytest.mark.parametrize("act", ACTS)
def test_matmul_matches_ref(act):
    x, w, b = randn((16, 24)), randn((24, 8)), randn((8,))
    got = fused_matmul_fwd(x, w, b, act, bm=8, bn=4, bk=8)
    want = ref.matmul_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([1, 2, 3, 8, 16, 31]),
    k=st.sampled_from([1, 4, 8, 24, 33]),
    n=st.sampled_from([1, 2, 8, 17]),
    act=st.sampled_from(ACTS),
    bm=st.sampled_from([2, 4, 8, 128]),
)
def test_matmul_hypothesis_shapes(m, k, n, act, bm):
    x, w, b = randn((m, k)), randn((k, n)), randn((n,))
    got = fused_matmul_fwd(x, w, b, act, bm=bm, bn=bm, bk=bm)
    want = ref.matmul_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_matmul_dtypes(dtype):
    x, w, b = randn((8, 16), dtype), randn((16, 8), dtype), randn((8,), dtype)
    got = fused_matmul_fwd(x, w, b, "linear", bm=4, bn=4, bk=4)
    want = ref.matmul_ref(x, w, b, "linear")
    assert got.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_matmul_grad_matches_ref_grad():
    x, w, b = randn((8, 12)), randn((12, 6)), randn((6,))

    def loss_kernel(x, w, b):
        return (fused_matmul(x, w, b, "gelu") ** 2).sum()

    def loss_ref(x, w, b):
        return (ref.matmul_ref(x, w, b, "gelu") ** 2).sum()

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        fused_matmul_fwd(randn((4, 4)), randn((5, 4)), randn((4,)))
    with pytest.raises(ValueError):
        fused_matmul_fwd(randn((4,)), randn((4, 4)), randn((4,)))
    with pytest.raises(ValueError):
        fused_matmul_fwd(randn((4, 4)), randn((4, 4)), randn((4,)),
                         activation="tanh")


def test_matmul_under_jit():
    x, w, b = randn((8, 8)), randn((8, 8)), randn((8,))
    got = jax.jit(lambda x, w, b: fused_matmul(x, w, b, "relu"))(x, w, b)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w, b, "relu"),
                               rtol=1e-5, atol=1e-5)


def test_vmem_and_mxu_estimates_sane():
    # 128-aligned problem should fully utilize the MXU model.
    assert mxu_utilization_estimate(256, 256, 256) == 1.0
    assert mxu_utilization_estimate(8, 8, 8) < 0.01
    # footprint is monotone in the tile sizes and bounded by ~16MB VMEM
    assert mm_vmem(128, 128, 128) < 16 * 2**20
    assert attn_vmem(128, 64) < 16 * 2**20


# ------------------------------------------------------------- attention --

def test_attention_matches_ref_single_block():
    q, k, v = (randn((2, 2, 8, 4)) for _ in range(3))
    got = causal_attention_fwd(q, k, v, bq=8, bk=8)
    np.testing.assert_allclose(got, ref.attention_ref(q, k, v),
                               rtol=1e-5, atol=1e-5)


def test_attention_matches_ref_blocked():
    q, k, v = (randn((1, 2, 32, 8)) for _ in range(3))
    got = causal_attention_fwd(q, k, v, bq=8, bk=4)
    np.testing.assert_allclose(got, ref.attention_ref(q, k, v),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    b=st.sampled_from([1, 2]),
    h=st.sampled_from([1, 3]),
    s=st.sampled_from([4, 16, 32]),
    dh=st.sampled_from([4, 8]),
    bq=st.sampled_from([2, 4, 128]),
)
def test_attention_hypothesis(b, h, s, dh, bq):
    q, k, v = (randn((b, h, s, dh)) for _ in range(3))
    got = causal_attention_fwd(q, k, v, bq=bq, bk=bq)
    np.testing.assert_allclose(got, ref.attention_ref(q, k, v),
                               rtol=1e-4, atol=1e-4)


def test_attention_causality():
    """Perturbing future keys/values must not change earlier outputs."""
    q, k, v = (randn((1, 1, 16, 4)) for _ in range(3))
    base = causal_attention_fwd(q, k, v, bq=4, bk=4)
    k2 = k.at[:, :, 12:].set(randn((1, 1, 4, 4)) * 100)
    v2 = v.at[:, :, 12:].set(randn((1, 1, 4, 4)) * 100)
    pert = causal_attention_fwd(q, k2, v2, bq=4, bk=4)
    np.testing.assert_allclose(base[:, :, :12], pert[:, :, :12],
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(base[:, :, 12:], pert[:, :, 12:])


def test_attention_grad_matches_ref_grad():
    q, k, v = (randn((1, 2, 8, 4)) for _ in range(3))
    gk = jax.grad(lambda *a: causal_attention(*a).sum(), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: ref.attention_ref(*a).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_attention_rejects_bad_shapes():
    with pytest.raises(ValueError):
        causal_attention_fwd(randn((2, 2, 8, 4)), randn((2, 2, 8, 5)),
                             randn((2, 2, 8, 4)))
    with pytest.raises(ValueError):
        causal_attention_fwd(randn((8, 4)), randn((8, 4)), randn((8, 4)))


def test_attention_bf16():
    q, k, v = (randn((1, 1, 8, 4), jnp.bfloat16) for _ in range(3))
    got = causal_attention_fwd(q, k, v, bq=4, bk=4)
    want = ref.attention_ref(q, k, v)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)

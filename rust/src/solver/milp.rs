//! Branch-and-bound MILP on top of the simplex LP relaxation.
//!
//! Depth-first with best-incumbent pruning, branching on the most
//! fractional integer variable; optional warm-start incumbent (the
//! optimizer passes the heuristic solution so B&B starts with a tight
//! bound).  Exact on the paper-scale count-aggregated P2 (≤ ~100 integer
//! variables); node/time limits turn it into an anytime solver beyond that.

use super::simplex::{self, Cmp, Constraint, Lp, LpOutcome};

/// MILP = LP + integrality markers (`integer[j]` ⇒ x\_j ∈ ℤ₊).
#[derive(Clone, Debug)]
pub struct Milp {
    pub lp: Lp,
    pub integer: Vec<bool>,
}

/// Search limits / tolerances.
#[derive(Clone, Debug)]
pub struct MilpOptions {
    /// Max branch-and-bound nodes before returning the incumbent.
    pub node_limit: usize,
    /// Integrality tolerance.
    pub int_tol: f64,
    /// Optional warm-start feasible point (must satisfy all constraints).
    pub warm_start: Option<Vec<f64>>,
}

impl Default for MilpOptions {
    fn default() -> Self {
        MilpOptions { node_limit: 20_000, int_tol: 1e-6, warm_start: None }
    }
}

/// Result of [`solve`].
#[derive(Clone, Debug)]
pub enum MilpOutcome {
    /// Proven optimal (search exhausted).
    Optimal { x: Vec<f64>, obj: f64, nodes: usize },
    /// Feasible incumbent, optimality not proven (node limit hit).
    Feasible { x: Vec<f64>, obj: f64, nodes: usize },
    Infeasible,
    Unbounded,
}

impl MilpOutcome {
    /// The solution vector if any feasible point was found.
    pub fn solution(&self) -> Option<(&[f64], f64)> {
        match self {
            MilpOutcome::Optimal { x, obj, .. } | MilpOutcome::Feasible { x, obj, .. } => {
                Some((x, *obj))
            }
            _ => None,
        }
    }
}

struct Node {
    /// Extra bound constraints (var, is_upper, value).
    bounds: Vec<(usize, bool, f64)>,
}

fn obj_value(lp: &Lp, x: &[f64]) -> f64 {
    lp.objective.iter().zip(x).map(|(c, v)| c * v).sum()
}

fn is_integral(milp: &Milp, x: &[f64], tol: f64) -> bool {
    milp.integer
        .iter()
        .zip(x)
        .all(|(&int, &v)| !int || (v - v.round()).abs() <= tol)
}

/// Check a candidate point against all constraints (warm-start validation).
fn feasible(milp: &Milp, x: &[f64], tol: f64) -> bool {
    if x.len() != milp.lp.n || x.iter().any(|&v| v < -tol) {
        return false;
    }
    if !is_integral(milp, x, tol) {
        return false;
    }
    milp.lp.constraints.iter().all(|c| {
        let lhs: f64 = c.coeffs.iter().map(|&(j, v)| v * x[j]).sum();
        match c.cmp {
            Cmp::Le => lhs <= c.rhs + 1e-6,
            Cmp::Ge => lhs >= c.rhs - 1e-6,
            Cmp::Eq => (lhs - c.rhs).abs() <= 1e-6,
        }
    })
}

/// Solve the MILP by branch and bound.
pub fn solve(milp: &Milp, opts: &MilpOptions) -> MilpOutcome {
    debug_assert_eq!(milp.integer.len(), milp.lp.n);
    let maximize = milp.lp.maximize;
    let better = |a: f64, b: f64| if maximize { a > b + 1e-9 } else { a < b - 1e-9 };

    let mut incumbent: Option<(Vec<f64>, f64)> = None;
    if let Some(ws) = &opts.warm_start {
        if feasible(milp, ws, opts.int_tol) {
            incumbent = Some((ws.clone(), obj_value(&milp.lp, ws)));
        }
    }

    let mut stack = vec![Node { bounds: vec![] }];
    let mut nodes = 0usize;
    let mut exhausted = true;

    while let Some(node) = stack.pop() {
        if nodes >= opts.node_limit {
            exhausted = false;
            break;
        }
        nodes += 1;

        // LP relaxation with the node's bound constraints appended.
        let mut lp = milp.lp.clone();
        for &(var, is_upper, val) in &node.bounds {
            lp.constraints.push(Constraint::new(
                vec![(var, 1.0)],
                if is_upper { Cmp::Le } else { Cmp::Ge },
                val,
            ));
        }
        let (x, obj) = match simplex::solve(&lp) {
            LpOutcome::Optimal { x, obj } => (x, obj),
            LpOutcome::Infeasible => continue,
            LpOutcome::Unbounded => return MilpOutcome::Unbounded,
        };

        // bound pruning
        if let Some((_, inc_obj)) = &incumbent {
            if !better(obj, *inc_obj) {
                continue;
            }
        }

        // most fractional integer variable
        let mut frac_var: Option<(usize, f64)> = None;
        for (j, (&int, &v)) in milp.integer.iter().zip(&x).enumerate() {
            if int {
                let f = (v - v.round()).abs();
                if f > opts.int_tol {
                    let dist = (v - v.floor() - 0.5).abs(); // 0 = most fractional
                    match frac_var {
                        Some((_, bd)) if bd <= dist => {}
                        _ => frac_var = Some((j, dist)),
                    }
                }
            }
        }

        match frac_var {
            None => {
                // integral: snap and accept as incumbent
                let xi: Vec<f64> = milp
                    .integer
                    .iter()
                    .zip(&x)
                    .map(|(&int, &v)| if int { v.round() } else { v })
                    .collect();
                let oi = obj_value(&milp.lp, &xi);
                if incumbent.as_ref().map_or(true, |(_, io)| better(oi, *io)) {
                    incumbent = Some((xi, oi));
                }
            }
            Some((j, _)) => {
                let v = x[j];
                // push "floor" branch last so it is explored first (DFS),
                // which tends to find feasible incumbents quickly here
                // (counts round down into capacity).
                let mut up = node.bounds.clone();
                up.push((j, false, v.ceil()));
                stack.push(Node { bounds: up });
                let mut down = node.bounds;
                down.push((j, true, v.floor()));
                stack.push(Node { bounds: down });
            }
        }
    }

    match incumbent {
        Some((x, obj)) if exhausted => MilpOutcome::Optimal { x, obj, nodes },
        Some((x, obj)) => MilpOutcome::Feasible { x, obj, nodes },
        None if exhausted => MilpOutcome::Infeasible,
        None => MilpOutcome::Infeasible,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn knapsack(values: &[f64], weights: &[f64], cap: f64) -> Milp {
        let n = values.len();
        let mut constraints = vec![Constraint::new(
            weights.iter().cloned().enumerate().collect(),
            Cmp::Le,
            cap,
        )];
        for j in 0..n {
            constraints.push(Constraint::new(vec![(j, 1.0)], Cmp::Le, 1.0));
        }
        Milp {
            lp: Lp { n, objective: values.to_vec(), maximize: true, constraints },
            integer: vec![true; n],
        }
    }

    #[test]
    fn solves_01_knapsack() {
        // items (v, w): (60,10) (100,20) (120,30), cap 50 -> best 220
        let m = knapsack(&[60.0, 100.0, 120.0], &[10.0, 20.0, 30.0], 50.0);
        match solve(&m, &MilpOptions::default()) {
            MilpOutcome::Optimal { x, obj, .. } => {
                assert!((obj - 220.0).abs() < 1e-6, "{x:?}");
                assert!((x[0] - 0.0).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn integer_rounding_matters() {
        // max x st 2x <= 5, x int -> 2 (LP gives 2.5)
        let m = Milp {
            lp: Lp {
                n: 1,
                objective: vec![1.0],
                maximize: true,
                constraints: vec![Constraint::new(vec![(0, 2.0)], Cmp::Le, 5.0)],
            },
            integer: vec![true],
        };
        match solve(&m, &MilpOptions::default()) {
            MilpOutcome::Optimal { x, obj, .. } => {
                assert_eq!(x[0], 2.0);
                assert_eq!(obj, 2.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn mixed_integer_continuous() {
        // max x + y, x int: x + y <= 3.7, x <= 2.2 -> x=2, y=1.7
        let m = Milp {
            lp: Lp {
                n: 2,
                objective: vec![1.0, 1.0],
                maximize: true,
                constraints: vec![
                    Constraint::new(vec![(0, 1.0), (1, 1.0)], Cmp::Le, 3.7),
                    Constraint::new(vec![(0, 1.0)], Cmp::Le, 2.2),
                ],
            },
            integer: vec![true, false],
        };
        match solve(&m, &MilpOptions::default()) {
            MilpOutcome::Optimal { x, obj, .. } => {
                assert_eq!(x[0], 2.0);
                assert!((obj - 3.7).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn infeasible_milp() {
        // x int, 0.4 <= x <= 0.6
        let m = Milp {
            lp: Lp {
                n: 1,
                objective: vec![1.0],
                maximize: true,
                constraints: vec![
                    Constraint::new(vec![(0, 1.0)], Cmp::Ge, 0.4),
                    Constraint::new(vec![(0, 1.0)], Cmp::Le, 0.6),
                ],
            },
            integer: vec![true],
        };
        assert!(matches!(solve(&m, &MilpOptions::default()), MilpOutcome::Infeasible));
    }

    #[test]
    fn warm_start_accepted_and_node_limit_returns_feasible() {
        let m = knapsack(&[60.0, 100.0, 120.0], &[10.0, 20.0, 30.0], 50.0);
        let opts = MilpOptions {
            node_limit: 1,
            warm_start: Some(vec![1.0, 0.0, 0.0]),
            ..Default::default()
        };
        match solve(&m, &opts) {
            MilpOutcome::Feasible { obj, .. } | MilpOutcome::Optimal { obj, .. } => {
                assert!(obj >= 60.0 - 1e-9);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bad_warm_start_rejected() {
        let m = knapsack(&[60.0], &[10.0], 5.0);
        let opts = MilpOptions {
            warm_start: Some(vec![1.0]), // violates capacity
            ..Default::default()
        };
        match solve(&m, &opts) {
            MilpOutcome::Optimal { x, .. } => assert_eq!(x[0], 0.0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn prop_milp_matches_exhaustive_small() {
        use crate::util::prop;
        prop::check(60, |rng| {
            // random 0/1 knapsack with n<=10: compare against brute force
            let n = rng.range_u64(1, 10) as usize;
            let values: Vec<f64> = (0..n).map(|_| rng.range_f64(1.0, 20.0)).collect();
            let weights: Vec<f64> = (0..n).map(|_| rng.range_f64(1.0, 10.0)).collect();
            let cap = rng.range_f64(5.0, 30.0);
            let m = knapsack(&values, &weights, cap);
            let got = match solve(&m, &MilpOptions::default()) {
                MilpOutcome::Optimal { obj, .. } => obj,
                other => return Err(format!("{other:?}")),
            };
            let mut best = 0.0f64;
            for mask in 0u32..(1 << n) {
                let (mut v, mut w) = (0.0, 0.0);
                for j in 0..n {
                    if mask & (1 << j) != 0 {
                        v += values[j];
                        w += weights[j];
                    }
                }
                if w <= cap + 1e-9 {
                    best = best.max(v);
                }
            }
            prop::close(got, best, 1e-5)
        });
    }
}

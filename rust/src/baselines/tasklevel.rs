//! Task-level sharing model: the per-task scheduling-latency pathology the
//! paper measures on Mesos (§II-C — "in a 100-node Mesos cluster ... the
//! average scheduling latency per task is about 430 ms").
//!
//! In task-level mode every short ML task must petition the central
//! resource manager for an offer before it can run.  That makes the
//! manager an M/M/1-style bottleneck: with `n` busy nodes each finishing a
//! ~1.5 s task (Fig. 1 median) and immediately requesting the next, the
//! request rate approaches saturation and latency explodes.  This module
//! gives both the analytic M/M/1 expectation and a discrete-event
//! simulation (FIFO central queue + offer round-trips), which
//! `benches/sched_latency.rs` sweeps over cluster size to regenerate the
//! 430 ms observation and the Dorm comparison (local TaskScheduler ⇒ no
//! central round-trip at all, §III-D).

use crate::util::stats;
use crate::util::Rng;

/// Parameters of the central-scheduler queueing model.
#[derive(Clone, Debug)]
pub struct TaskLevelModel {
    /// Nodes continuously producing tasks.
    pub nodes: usize,
    /// Mean task runtime in seconds (Fig. 1: ~1.5 s median).
    pub mean_task_secs: f64,
    /// Central manager's mean service time per scheduling request (offer
    /// construction + placement decision), seconds.
    pub service_secs: f64,
    /// Network round-trip per offer negotiation, seconds.
    pub rtt_secs: f64,
}

impl Default for TaskLevelModel {
    fn default() -> Self {
        TaskLevelModel {
            nodes: 100,
            mean_task_secs: 1.5,
            // 20 ms to build/commit an offer: the manager caps at μ = 50
            // grants/s while 100 free-running nodes would produce ≈ 66.7
            // requests/s.  The closed loop equilibrates where throughput
            // matches capacity: nodes/(task + W) = μ  ⇒  W = nodes/μ −
            // task = 100/50 − 1.5 = 0.5 s — the paper's ~430 ms regime.
            service_secs: 0.020,
            rtt_secs: 0.002,
        }
    }
}

/// Latency statistics from a run.
#[derive(Clone, Debug)]
pub struct LatencyStats {
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub utilization: f64,
}

impl TaskLevelModel {
    /// Offered load ρ = λ/μ of the central manager.
    pub fn rho(&self) -> f64 {
        let lambda = self.nodes as f64 / self.mean_task_secs;
        lambda * self.service_secs
    }

    /// Analytic M/M/1 mean sojourn (queue + service) + RTT, in ms.
    /// Returns `None` at or beyond saturation.
    pub fn analytic_mean_ms(&self) -> Option<f64> {
        let lambda = self.nodes as f64 / self.mean_task_secs;
        let mu = 1.0 / self.service_secs;
        if lambda >= mu {
            return None;
        }
        Some(((1.0 / (mu - lambda)) + self.rtt_secs) * 1000.0)
    }

    /// DES of the closed system: each node loops task -> request -> wait
    /// for grant -> next task.  Exponential task and service times.
    pub fn simulate(&self, tasks_per_node: usize, rng: &mut Rng) -> LatencyStats {
        #[derive(PartialEq, Clone, Debug)]
        enum Ev {
            TaskDone(usize),
            GrantReady,
        }
        let mut q: crate::sim::EventQueue<Ev> = crate::sim::EventQueue::new();
        // manager FIFO queue of (node, enqueue_time)
        let mut fifo: std::collections::VecDeque<(usize, f64)> =
            std::collections::VecDeque::new();
        let mut busy_until = 0.0f64;
        let mut latencies: Vec<f64> = Vec::new();
        let mut remaining = vec![tasks_per_node; self.nodes];
        let mut busy_time = 0.0f64;

        for node in 0..self.nodes {
            // stagger initial task completions
            q.schedule(rng.exponential(self.mean_task_secs), Ev::TaskDone(node));
        }

        while let Some(ev) = q.pop() {
            let now = ev.time;
            match ev.event {
                Ev::TaskDone(node) => {
                    if remaining[node] == 0 {
                        continue;
                    }
                    remaining[node] -= 1;
                    // node petitions the central manager (rtt/2 to arrive)
                    fifo.push_back((node, now + self.rtt_secs / 2.0));
                    // manager serves FIFO
                    let start = busy_until.max(now + self.rtt_secs / 2.0);
                    let service = rng.exponential(self.service_secs);
                    busy_until = start + service;
                    busy_time += service;
                    q.schedule(busy_until, Ev::GrantReady);
                }
                Ev::GrantReady => {
                    let Some((node, enq)) = fifo.pop_front() else { continue };
                    // grant travels back rtt/2; task then starts
                    let granted = now + self.rtt_secs / 2.0;
                    latencies.push((granted - enq + self.rtt_secs / 2.0) * 1000.0);
                    if remaining[node] > 0 {
                        q.schedule(
                            granted + rng.exponential(self.mean_task_secs),
                            Ev::TaskDone(node),
                        );
                    }
                }
            }
        }

        let total_time = busy_until.max(1e-9);
        LatencyStats {
            mean_ms: stats::mean(&latencies),
            p50_ms: stats::percentile(&latencies, 50.0),
            p99_ms: stats::percentile(&latencies, 99.0),
            utilization: busy_time / total_time,
        }
    }
}

/// Dorm's counterpart (§III-D): the TaskScheduler is local to the
/// container, so placing a task costs only the local dispatch — no central
/// round-trip.  Modeled as a constant few microseconds.
pub fn dorm_local_placement_ms() -> f64 {
    0.005
}

/// Task-level sharing as a [`CmsPolicy`]: placements match the static
/// baseline (fixed partitions, never resized), but every ~1.5 s task pays
/// the central manager's closed-loop scheduling wait before it can start,
/// shaving throughput to `task / (task + wait)` — at the paper's 100-node
/// regime (wait = nodes/μ − task = 0.5 s) that is a 25% slowdown on top of
/// static sharing.  This is the fourth baseline the simulator (and the
/// `crate::fault` churn experiment) runs against Dorm.
#[derive(Debug)]
pub struct TaskLevelPolicy {
    inner: crate::baselines::StaticPolicy,
    model: TaskLevelModel,
    /// Closed-loop per-task scheduling wait, seconds (module docs).
    wait_secs: f64,
}

impl TaskLevelPolicy {
    pub fn new() -> Self {
        Self::with_model(TaskLevelModel::default())
    }

    pub fn with_model(model: TaskLevelModel) -> Self {
        // closed-loop equilibrium: nodes/(task + W) = μ  ⇒  W = nodes·s − task
        let wait_secs =
            (model.nodes as f64 * model.service_secs - model.mean_task_secs).max(0.0);
        TaskLevelPolicy {
            inner: crate::baselines::StaticPolicy::new(),
            model,
            wait_secs,
        }
    }
}

impl Default for TaskLevelPolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl crate::sched::CmsPolicy for TaskLevelPolicy {
    fn name(&self) -> String {
        "task-level".into()
    }

    fn on_change(
        &mut self,
        ctx: &crate::sched::SchedCtx,
    ) -> Option<crate::sched::AllocationUpdate> {
        self.inner.on_change(ctx)
    }

    fn admission_latency_hours(&self) -> f64 {
        // first offer round-trip before any task runs
        (self.wait_secs + self.model.rtt_secs) / 3600.0
    }

    fn progress_factor(&self) -> f64 {
        self.model.mean_task_secs / (self.model.mean_task_secs + self.wait_secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_model_lands_in_papers_regime() {
        let m = TaskLevelModel::default();
        // open-loop saturated (that is the paper's point: short tasks
        // overwhelm the central manager); the closed loop equilibrates at
        // W = nodes/mu - task = 0.5 s of scheduling latency.
        assert!(m.rho() > 1.0, "rho {}", m.rho());
        assert!(m.analytic_mean_ms().is_none());
        let mut rng = Rng::new(42);
        let s = m.simulate(200, &mut rng);
        // the paper measured ~430 ms; shape-level agreement: hundreds of ms
        assert!(
            s.mean_ms > 200.0 && s.mean_ms < 900.0,
            "mean latency {} ms out of the paper's regime",
            s.mean_ms
        );
    }

    #[test]
    fn latency_explodes_with_cluster_size() {
        let mut rng = Rng::new(1);
        let small = TaskLevelModel { nodes: 20, ..Default::default() }
            .simulate(200, &mut rng);
        let large = TaskLevelModel { nodes: 100, ..Default::default() }
            .simulate(200, &mut rng);
        assert!(
            large.mean_ms > 3.0 * small.mean_ms,
            "large {} vs small {}",
            large.mean_ms,
            small.mean_ms
        );
    }

    #[test]
    fn analytic_and_sim_agree_at_moderate_load() {
        let m = TaskLevelModel { nodes: 50, ..Default::default() };
        let mut rng = Rng::new(9);
        let sim = m.simulate(400, &mut rng);
        let ana = m.analytic_mean_ms().unwrap();
        // closed-loop sim is below the open-loop M/M/1 bound; same order
        assert!(
            sim.mean_ms < ana * 1.5 && sim.mean_ms > ana * 0.1,
            "sim {} vs analytic {}",
            sim.mean_ms,
            ana
        );
    }

    #[test]
    fn saturation_detected() {
        let m = TaskLevelModel { nodes: 1000, ..Default::default() };
        assert!(m.analytic_mean_ms().is_none());
    }

    #[test]
    fn dorm_is_orders_of_magnitude_cheaper() {
        let m = TaskLevelModel::default();
        let mut rng = Rng::new(2);
        let s = m.simulate(100, &mut rng);
        assert!(s.mean_ms / dorm_local_placement_ms() > 1e4);
    }

    #[test]
    fn task_level_policy_is_static_but_slower() {
        use crate::config::{ClusterConfig, SimConfig};
        use crate::sched::CmsPolicy;
        use crate::sim::{run_sim, PerfModel};
        use crate::workload::{table2_rows, WorkloadApp};

        let pol = TaskLevelPolicy::new();
        // paper regime: W = 100·0.02 − 1.5 = 0.5 s -> factor 1.5/2.0
        assert!((pol.progress_factor() - 0.75).abs() < 1e-12);

        let rows = table2_rows();
        let wl = vec![WorkloadApp {
            row: 0,
            tag: "LR".into(),
            submit_hours: 0.0,
            duration_at_baseline_hours: 1.0,
            baseline_n: 8,
        }];
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 6.0, ..Default::default() };
        let mut pol = TaskLevelPolicy::new();
        let out = run_sim(&mut pol, &rows, &wl, &cfg, &sim, &PerfModel::default());
        assert_eq!(out.completed, 1);
        let dur = out.metrics.completions[0].1;
        // 1 h of baseline work at 75% throughput (+ tiny admission latency)
        assert!((dur - 1.0 / 0.75).abs() < 0.01, "duration {dur}");
        assert_eq!(out.metrics.adjustments.last(), Some(0.0), "never adjusts");
    }
}

//! Golden master/sim parity: the refactor's key invariant.
//!
//! The live `DormMaster` (no compute service attached) and the DES
//! `DormPolicy` now delegate to the same `sched::AllocationEngine`.  This
//! test replays one submission/completion trace through both backends and
//! asserts the *allocation sequences are identical event by event* — if
//! either side grows private admission/deferral/solve logic again, this
//! breaks.
//!
//! Protocol: run the DES first and record (a) each event's post-decision
//! container counts and (b) the event trace itself (arrival/completion
//! order, from submission times and simulated completion times).  Then
//! replay that exact trace into a live master and compare counts after
//! every event.

use std::collections::BTreeMap;

use dorm::app::{AppId, AppSpec, CheckpointStore, Engine};
use dorm::config::{ClusterConfig, DormConfig, SimConfig};
use dorm::master::DormMaster;
use dorm::resources::Res;
use dorm::sched::{AllocationUpdate, CmsPolicy, DormPolicy, SchedCtx};
use dorm::sim::{run_sim, PerfModel};
use dorm::workload::{Table2Row, WorkloadApp};

/// One synthetic application type, shared by both backends.
struct Spec {
    demand: Res,
    weight: u32,
    n_min: u32,
    n_max: u32,
    submit_hours: f64,
    duration_at_baseline_hours: f64,
}

fn trace() -> Vec<Spec> {
    vec![
        // grabs the whole cluster, then shrinks as others arrive
        Spec {
            demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0),
            weight: 1,
            n_min: 1,
            n_max: 24,
            submit_hours: 0.0,
            duration_at_baseline_hours: 1.0,
        },
        Spec {
            demand: Res::cpu_gpu_ram(2.0, 0.0, 6.0),
            weight: 2,
            n_min: 1,
            n_max: 24,
            submit_hours: 0.3,
            duration_at_baseline_hours: 2.0,
        },
        Spec {
            demand: Res::cpu_gpu_ram(4.0, 0.0, 6.0),
            weight: 1,
            n_min: 1,
            n_max: 8,
            submit_hours: 0.7,
            duration_at_baseline_hours: 1.5,
        },
        // arrives after the backlog drains: regrow + fresh admission
        Spec {
            demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0),
            weight: 1,
            n_min: 1,
            n_max: 24,
            submit_hours: 4.0,
            duration_at_baseline_hours: 1.0,
        },
    ]
}

fn cluster() -> ClusterConfig {
    ClusterConfig::uniform(4, Res::cpu_gpu_ram(12.0, 0.0, 64.0))
}

const CFG: DormConfig = DormConfig { theta1: 0.3, theta2: 0.34 };

/// Wraps the shared policy and records, after every event, the decided
/// container count of every active app (current count when the policy
/// keeps allocations).
struct Recording {
    inner: DormPolicy,
    log: Vec<BTreeMap<AppId, u32>>,
}

impl CmsPolicy for Recording {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn on_change(&mut self, ctx: &SchedCtx) -> Option<AllocationUpdate> {
        let update = self.inner.on_change(ctx);
        let counts: BTreeMap<AppId, u32> = ctx
            .apps
            .values()
            .map(|a| {
                let c = match &update {
                    Some(u) => u
                        .assignment
                        .get(&a.id)
                        .map(|row| row.values().sum())
                        .unwrap_or(0),
                    None => a.containers,
                };
                (a.id, c)
            })
            .collect();
        self.log.push(counts);
        update
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Ev {
    Arrival(usize),
    Completion(usize),
}

#[test]
fn master_and_sim_replay_identical_allocation_sequences() {
    let specs = trace();

    // ---- DES side -------------------------------------------------------
    let rows: Vec<Table2Row> = specs
        .iter()
        .map(|s| Table2Row {
            engine: Engine::MxNet,
            dataset: "synthetic",
            model: "parity",
            demand: s.demand.clone(),
            weight: s.weight,
            n_max: s.n_max,
            n_min: s.n_min,
            num: 1,
            baseline_containers: 8,
            duration_median_hours: s.duration_at_baseline_hours,
        })
        .collect();
    let workload: Vec<WorkloadApp> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| WorkloadApp {
            row: i,
            tag: format!("app{i}"),
            submit_hours: s.submit_hours,
            duration_at_baseline_hours: s.duration_at_baseline_hours,
            baseline_n: 8,
        })
        .collect();
    let sim = SimConfig { horizon_hours: 24.0, ..Default::default() };
    let mut pol = Recording { inner: DormPolicy::new(CFG), log: Vec::new() };
    let out = run_sim(&mut pol, &rows, &workload, &cluster(), &sim, &PerfModel::default());
    assert_eq!(out.completed, specs.len(), "trace must fully drain");

    // reconstruct the event order the DES processed: arrivals at their
    // submission times, completions at their simulated times
    let mut events: Vec<(f64, Ev)> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| (s.submit_hours, Ev::Arrival(i)))
        .collect();
    for (id, app) in &out.apps {
        let t = app.completed_at.expect("all apps completed");
        events.push((t, Ev::Completion(id.0 as usize)));
    }
    events.sort_by(|a, b| a.0.total_cmp(&b.0));
    assert_eq!(pol.log.len(), events.len(), "one decision per event");

    // sim allocation sequence, by workload index
    let sim_seq: Vec<Vec<u32>> = pol
        .log
        .iter()
        .map(|m| {
            (0..specs.len())
                .map(|i| m.get(&AppId(i as u64)).copied().unwrap_or(0))
                .collect()
        })
        .collect();

    // ---- live-master side ----------------------------------------------
    let dir = std::env::temp_dir().join(format!("dorm_parity_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = CheckpointStore::new(dir).unwrap();
    let mut master = DormMaster::new(&cluster(), CFG, store);
    let mut ids: BTreeMap<usize, AppId> = BTreeMap::new();
    let mut master_seq: Vec<Vec<u32>> = Vec::new();
    for &(_, ev) in &events {
        match ev {
            Ev::Arrival(i) => {
                let s = &specs[i];
                let id = master
                    .submit(AppSpec {
                        executor: Engine::MxNet,
                        demand: s.demand.clone(),
                        weight: s.weight,
                        n_max: s.n_max,
                        n_min: s.n_min,
                        cmd: ["parity".into(), "parity".into()],
                    })
                    .unwrap();
                ids.insert(i, id);
            }
            Ev::Completion(i) => {
                master.complete(ids[&i]).unwrap();
            }
        }
        master_seq.push(
            (0..specs.len())
                .map(|i| ids.get(&i).map(|&id| master.containers_of(id)).unwrap_or(0))
                .collect(),
        );
    }

    // ---- the invariant --------------------------------------------------
    assert_eq!(
        sim_seq, master_seq,
        "live master and DES must produce identical allocation sequences\n\
         events: {events:?}"
    );

    // sanity: the trace actually exercised the interesting paths
    let adjusted_total = master.total_adjustments;
    assert!(adjusted_total >= 1, "trace should force at least one adjustment");
    let peak_first = sim_seq[0][0];
    assert_eq!(peak_first, 24, "lone first app takes its n_max");
    let after_second = &sim_seq[1];
    assert!(after_second[1] >= 1, "second app admitted");
}

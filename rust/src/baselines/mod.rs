//! Baseline cluster-management systems the paper compares against (§II-B/C,
//! §V-A-4):
//!
//! * [`StaticPolicy`] — the Swarm baseline: fixed container counts per app
//!   type ("8, 8, 4, 2, 2, 2, 3"), FIFO admission when the fixed partition
//!   fits, never resized.
//! * [`MesosAppLevelPolicy`] — two-level offers in app-level mode: same
//!   static allocations, plus an offer-negotiation admission latency.
//! * [`IaasPolicy`] — OpenStack-style engine-partitioned virtual clusters
//!   (one app per engine at a time; capacity cannot flow between engines).
//! * [`tasklevel`] — the task-level sharing model behind the paper's
//!   "~430 ms average scheduling latency per task in a 100-node Mesos
//!   cluster" measurement (§II-C), reproduced by `benches/sched_latency.rs`,
//!   plus [`TaskLevelPolicy`], the same pathology as a runnable
//!   [`crate::sched::CmsPolicy`] (static placements at reduced throughput).

mod iaas;
mod mesos;
mod static_alloc;
pub mod tasklevel;

pub use iaas::IaasPolicy;
pub use mesos::MesosAppLevelPolicy;
pub use static_alloc::StaticPolicy;
pub use tasklevel::TaskLevelPolicy;

//! Ablation: sweep θ₁ (fairness threshold) and θ₂ (adjustment threshold)
//! over the §V workload to expose the design trade-off the paper's three
//! Dorm configurations sample — utilization vs fairness vs churn — plus a
//! fairness-only (DRF) and utilization-only corner.

#[path = "harness/mod.rs"]
mod harness;

use dorm::baselines::StaticPolicy;
use dorm::config::DormConfig;
use dorm::report;
use dorm::sim::{mean_speedup, utilization_ratio, DormPolicy, Experiment};

fn main() {
    harness::banner("ablation — θ₁/θ₂ sweep on the §V workload (12 h scaled)");
    let exp = Experiment::scaled(17, 12.0, 30);
    let baseline = exp.run(&mut StaticPolicy::new());

    let mut rows = Vec::new();
    for (t1, t2) in [
        (0.02, 0.1),
        (0.1, 0.1),
        (0.2, 0.1),
        (0.5, 0.1),
        (1.0, 0.1), // utilization-leaning corner
        (0.1, 0.0), // frozen allocations after admit
        (0.1, 0.05),
        (0.1, 0.2),
        (0.1, 0.5),
        (0.1, 1.0), // unbounded churn
    ] {
        let cfg = DormConfig { theta1: t1, theta2: t2 };
        let run = exp.run(&mut DormPolicy::new(cfg));
        rows.push(vec![
            format!("{t1}"),
            format!("{t2}"),
            format!("{:.2}", run.metrics().utilization.mean_over(0.0, 12.0)),
            format!("{:.2}x", utilization_ratio(&run, &baseline, 5.0)),
            format!("{:.2}", run.metrics().fairness_loss.max()),
            format!("{:.0}", run.metrics().adjustments.last().unwrap_or(0.0)),
            format!("{:.2}x", mean_speedup(&run, &baseline)),
        ]);
    }
    println!(
        "{}",
        report::table(
            &["θ₁", "θ₂", "mean util", "util gain", "max fair loss", "adjusted", "speedup"],
            &rows
        )
    );
    println!(
        "  reading: θ₁ trades fairness for utilization headroom; θ₂ trades\n\
         \x20 churn (kill/resume pauses) for tracking the optimum — the paper's\n\
         \x20 Dorm-1/2/3 sit on this frontier."
    );
}

//! `dorm` — the leader binary: run the §V simulation, train models through
//! the full three-layer stack, or analyze scheduling latency.  See
//! [`dorm::cli::USAGE`].

use anyhow::Result;

use dorm::app::{AppId, CheckpointStore};
use dorm::baselines::tasklevel::{dorm_local_placement_ms, TaskLevelModel};
use dorm::cli::{Cli, USAGE};
use dorm::ps::{Trainer, TrainerConfig};
use dorm::report;
use dorm::runtime::{ComputeService, Manifest};
use dorm::sim::{fairness_reduction, mean_speedup, utilization_ratio, Experiment};
use dorm::util::{stats, Rng};
use dorm::workload::{app_duration_hours, task_duration_secs, DurationModel};

fn main() {
    dorm::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args[0] == "help" || args[0] == "--help" {
        print!("{USAGE}");
        return;
    }
    let cli = match Cli::parse(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match cli.command.as_str() {
        "simulate" => cmd_simulate(&cli),
        "churn" => cmd_churn(&cli),
        "fig1" => cmd_fig1(),
        "train" => cmd_train(&cli),
        "latency" => cmd_latency(&cli),
        "master" => cmd_master(&cli),
        "slave" => cmd_slave(&cli),
        "ctl" => cmd_ctl(&cli),
        other => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_simulate(cli: &Cli) -> Result<()> {
    let seed = cli.u64_flag("seed", 17)?;
    let horizon = cli.f64_flag("horizon", 24.0)?;
    let mut exp = Experiment::paper(seed);
    exp.sim.horizon_hours = horizon;
    println!("§V experiment: 50 apps / 20 slaves / {horizon} h (seed {seed})");
    let runs = exp.run_all();
    let (baseline, dorms) = runs.split_first().unwrap();
    let mut rows = Vec::new();
    for r in &runs {
        rows.push(vec![
            r.label.clone(),
            format!("{:.2}", r.metrics().utilization.mean_over(0.0, horizon)),
            format!("{:.2}", r.metrics().fairness_loss.max()),
            format!("{:.0}", r.metrics().adjustments.last().unwrap_or(0.0)),
            format!("{}", r.outcome.completed),
        ]);
    }
    println!(
        "{}",
        report::table(
            &["system", "mean util", "max fairness loss", "adjusted", "completed"],
            &rows
        )
    );
    for d in dorms {
        println!(
            "{}: util gain {:.2}x | fairness reduction {:.2}x | speedup {:.2}x",
            d.label,
            utilization_ratio(d, baseline, 5.0_f64.min(horizon)),
            fairness_reduction(d, baseline, horizon),
            mean_speedup(d, baseline),
        );
    }
    Ok(())
}

fn cmd_churn(cli: &Cli) -> Result<()> {
    use dorm::config::FaultConfig;
    use dorm::fault::{churn_csv_columns, churn_sweep, churn_systems, churn_table};
    let seed = cli.u64_flag("seed", 17)?;
    let horizon = cli.f64_flag("horizon", 8.0)?;
    let napps = cli.u64_flag("apps", 16)? as usize;
    let defaults = FaultConfig::default();
    let fault = FaultConfig {
        enabled: true,
        mttr_hours: cli.f64_flag("mttr", defaults.mttr_hours)?,
        ckpt_period_hours: cli.f64_flag("ckpt", defaults.ckpt_period_hours)?,
        seed,
        ..defaults
    };
    let mtbfs: Vec<f64> = cli
        .str_flag("mtbfs", "2,4,8,16,32")
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--mtbfs wants numbers, got {s:?}"))
        })
        .collect::<Result<_>>()?;
    println!(
        "churn sweep: {napps} apps / {horizon} h / MTTR {} h / ckpt every {} h / \
         MTBF {mtbfs:?} (seed {seed})",
        fault.mttr_hours, fault.ckpt_period_hours
    );
    let points = churn_sweep(&fault, seed, horizon, napps, &mtbfs);
    println!("{}", churn_table(&points));
    if cli.bool_flag("csv") {
        for system in churn_systems(&points) {
            let cols = churn_csv_columns(&points, &system);
            let slug: String = system
                .chars()
                .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
                .collect();
            let path = report::write_csv(&format!("churn_{slug}.csv"), &cols)?;
            println!("wrote {}", path.display());
        }
    }
    Ok(())
}

fn cmd_fig1() -> Result<()> {
    let model = DurationModel::production();
    let mut rng = Rng::new(1);
    let apps: Vec<f64> = (0..20_000).map(|_| app_duration_hours(&model, &mut rng)).collect();
    let tasks: Vec<f64> = (0..20_000).map(|_| task_duration_secs(&model, &mut rng)).collect();
    println!(
        "app duration:  p10 {:.1}h  p50 {:.1}h  p90 {:.1}h   (paper: 90% > 6h)",
        stats::percentile(&apps, 10.0),
        stats::percentile(&apps, 50.0),
        stats::percentile(&apps, 90.0)
    );
    println!(
        "task duration: p10 {:.2}s  p50 {:.2}s  p90 {:.2}s   (paper: 50% < 1.5s)",
        stats::percentile(&tasks, 10.0),
        stats::percentile(&tasks, 50.0),
        stats::percentile(&tasks, 90.0)
    );
    Ok(())
}

fn cmd_train(cli: &Cli) -> Result<()> {
    let model = cli.str_flag("model", "lr");
    let steps = cli.u64_flag("steps", 100)?;
    let workers = cli.u64_flag("workers", 4)? as u32;
    let lr = cli.f64_flag("lr", 0.1)? as f32;

    let manifest = Manifest::load("artifacts")?;
    let service = ComputeService::start_filtered(&manifest, Some(&[model.as_str()]))?;
    let meta = manifest.model(&model)?;
    println!("training {model}: {} params, {workers} worker slots, {steps} steps", meta.n_params);
    let cfg = TrainerConfig { workers, lr, seed: 1, data_seed: 1, ..Default::default() };
    let mut t = Trainer::new(AppId(1), meta, service.handle(), cfg)?;
    let t0 = std::time::Instant::now();
    for chunk in 0..(steps / 10).max(1) {
        let log = t.run(10.min(steps - chunk * 10))?;
        println!("step {:4}  loss {:.4}", log.step, log.loss);
        if log.step >= steps {
            break;
        }
    }
    println!(
        "{} steps in {:.1?} ({:.0} ms/step)",
        t.current_step(),
        t0.elapsed(),
        t0.elapsed().as_millis() as f64 / t.current_step() as f64
    );
    let stats = service.handle().stats()?;
    let exec_ms = stats.exec_micros as f64 / 1000.0;
    let total_ms = t0.elapsed().as_millis() as f64;
    println!(
        "xla exec time: {:.0} ms of {:.0} ms total ({:.1}% — coordinator overhead {:.1}%)",
        exec_ms,
        total_ms,
        100.0 * exec_ms / total_ms,
        100.0 * (1.0 - exec_ms / total_ms)
    );
    let store = CheckpointStore::new("checkpoints")?;
    let path = t.checkpoint(&store)?;
    println!("checkpoint -> {}", path.display());
    Ok(())
}

/// Resolve the `[net]` configuration for the master/slave/ctl commands:
/// start from `--config FILE` (a TOML file whose `[net]` section is
/// parsed by `NetConfig::from_doc`) or the defaults, then apply the
/// per-run flag overrides (`--frame-kib`, `--io-timeout-ms`).
fn net_from_cli(cli: &Cli) -> Result<dorm::config::NetConfig> {
    use dorm::config::{parse_toml, NetConfig};
    let mut net = match cli.flags.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("--config {path}: {e}"))?;
            NetConfig::from_doc(&parse_toml(&text)?)?
        }
        None => NetConfig::default(),
    };
    if cli.flags.contains_key("frame-kib") {
        let kib = cli.u64_flag("frame-kib", 256)?;
        if kib == 0 {
            anyhow::bail!("--frame-kib must be >= 1");
        }
        net.max_frame_bytes = kib as usize * 1024;
    }
    if cli.flags.contains_key("io-timeout-ms") {
        net.io_timeout_ms = cli.u64_flag("io-timeout-ms", net.io_timeout_ms)?;
    }
    Ok(net)
}

/// `dorm master`: serve the control plane over TCP until a `ctl shutdown`
/// arrives (the two-process demo in README.md; DESIGN.md §9).
fn cmd_master(cli: &Cli) -> Result<()> {
    use dorm::config::{ClusterConfig, DormConfig, FaultConfig};
    use dorm::master::DormMaster;
    use dorm::proto::{PROTO_MAJOR, PROTO_MINOR};
    use dorm::resources::Res;

    let slaves = cli.u64_flag("slaves", 2)? as usize;
    let cap = Res::cpu_gpu_ram(
        cli.f64_flag("cpu", 12.0)?,
        cli.f64_flag("gpu", 0.0)?,
        cli.f64_flag("ram", 64.0)?,
    );
    let dorm_cfg = DormConfig {
        theta1: cli.f64_flag("theta1", 0.1)?,
        theta2: cli.f64_flag("theta2", 0.1)?,
    };
    let lease_ms = cli.u64_flag("lease-ms", 0)?;
    let mut net = net_from_cli(cli)?;
    net.bind_addr = cli.str_flag("bind", &net.bind_addr);
    net.lease_sweep_ms =
        cli.u64_flag("sweep-ms", if lease_ms > 0 { 250 } else { net.lease_sweep_ms })?;
    let store = CheckpointStore::new(cli.str_flag("store", "net_checkpoints"))?;
    let mut master = DormMaster::new(&ClusterConfig::uniform(slaves, cap), dorm_cfg, store);
    if lease_ms > 0 {
        master = master.with_fault(&FaultConfig {
            lease_timeout_hours: lease_ms as f64 / 3_600_000.0,
            ..FaultConfig::default()
        });
    }
    let handle = dorm::net::serve(master, &net)?;
    println!(
        "dorm master listening on {} (proto v{PROTO_MAJOR}.{PROTO_MINOR}, {slaves} slaves, \
         lease timeout {})",
        handle.addr(),
        if lease_ms > 0 { format!("{lease_ms} ms") } else { "off".into() },
    );
    handle.wait();
    println!("dorm master: shutdown complete");
    Ok(())
}

/// `dorm slave`: one per-server agent as its own process, heartbeating
/// its report and applying the master's reconciliation directives.
fn cmd_slave(cli: &Cli) -> Result<()> {
    use dorm::net::{SlaveAgent, TcpTransport};
    use dorm::resources::Res;
    use dorm::slave::DormSlave;

    let addr = cli.str_flag("connect", "127.0.0.1:4600");
    let index = cli.u64_flag("index", 0)? as u32;
    let net = net_from_cli(cli)?;
    // --period-ms overrides the [net].heartbeat_period_ms config knob
    let period = cli.u64_flag("period-ms", net.heartbeat_period_ms)?;
    let cap = Res::cpu_gpu_ram(
        cli.f64_flag("cpu", 12.0)?,
        cli.f64_flag("gpu", 0.0)?,
        cli.f64_flag("ram", 64.0)?,
    );
    let name = cli.str_flag("name", &format!("slave{index:02}"));
    let transport = TcpTransport::connect(&addr, &net)?;
    let mut agent = SlaveAgent::new(DormSlave::new(name.clone(), cap), index, transport);
    println!("dorm slave {name} (server {index}) connected to {addr}, beating every {period} ms");
    let beats = agent.run(std::time::Duration::from_millis(period))?;
    println!("dorm slave {name}: master gone after {beats} beats; exiting");
    Ok(())
}

/// `dorm ctl`: issue one typed request against a running master and
/// print the response (the scriptable harness the CI smoke test drives).
fn cmd_ctl(cli: &Cli) -> Result<()> {
    use dorm::app::{AppSpec, Engine};
    use dorm::net::{ControlPlane, TcpTransport};
    use dorm::proto::{Request, Response};
    use dorm::resources::Res;

    let addr = cli.str_flag("connect", "127.0.0.1:4600");
    let op = cli
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("ctl needs an operation (see `dorm help`)"))?;
    let req = match op {
        "submit" => Request::Submit {
            spec: AppSpec {
                executor: Engine::MxNet,
                demand: Res::cpu_gpu_ram(
                    cli.f64_flag("cpu", 2.0)?,
                    cli.f64_flag("gpu", 0.0)?,
                    cli.f64_flag("ram", 8.0)?,
                ),
                weight: cli.u64_flag("weight", 1)? as u32,
                n_min: cli.u64_flag("nmin", 1)? as u32,
                n_max: cli.u64_flag("nmax", 8)? as u32,
                cmd: [cli.str_flag("model", "lr"), cli.str_flag("model", "lr")],
            },
        },
        "complete" => Request::Complete { app: AppId(cli.u64_flag("app", 0)?) },
        // --app N filters to one app; absent = the whole view
        "query" => Request::QueryState {
            app: match cli.flags.get("app") {
                Some(_) => Some(AppId(cli.u64_flag("app", 0)?)),
                None => None,
            },
        },
        "advance" => Request::AdvanceSteps {
            app: AppId(cli.u64_flag("app", 0)?),
            steps: cli.u64_flag("steps", 1)?,
        },
        "checkpoint" => Request::CheckpointApp { app: AppId(cli.u64_flag("app", 0)?) },
        "expire" => Request::ExpireLeases { now_hours: f64::NAN },
        "fail" => Request::FailServer { server: cli.u64_flag("server", 0)? as u32 },
        "recover" => Request::RecoverServer {
            server: cli.u64_flag("server", 0)? as u32,
            now_hours: f64::NAN,
        },
        "shutdown" => Request::Shutdown,
        other => anyhow::bail!("unknown ctl op {other:?} (see `dorm help`)"),
    };
    let net = net_from_cli(cli)?;
    let mut t = TcpTransport::connect(&addr, &net)?;
    match t.call(req)? {
        Response::Submitted { app } => println!("submitted app{}", app.0),
        Response::Ok => println!("ok"),
        Response::Expired { dead } => println!("expired servers {dead:?}"),
        Response::Affected { apps } => {
            println!("affected apps {:?}", apps.iter().map(|a| a.0).collect::<Vec<_>>())
        }
        Response::State(v) => {
            println!(
                "clock={} servers={}/{} active={} adjustments={} recoveries={} util={:.3}",
                v.clock,
                v.alive_servers,
                v.total_servers,
                v.active_apps,
                v.total_adjustments,
                v.total_recoveries,
                v.utilization
            );
            for a in &v.apps {
                println!(
                    "app{} {:?} containers={} steps={} ckpt={} adj={} rec={}",
                    a.id.0,
                    a.state,
                    a.containers,
                    a.steps_done,
                    a.ckpt_step,
                    a.adjustments,
                    a.recoveries
                );
            }
        }
        Response::Error(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
        other => println!("{other:?}"),
    }
    Ok(())
}

fn cmd_latency(cli: &Cli) -> Result<()> {
    let nodes = cli.u64_flag("nodes", 100)? as usize;
    let m = TaskLevelModel { nodes, ..Default::default() };
    let mut rng = Rng::new(7);
    let s = m.simulate(300, &mut rng);
    println!(
        "task-level two-level sharing, {nodes} nodes: mean {:.0} ms, p50 {:.0} ms, p99 {:.0} ms",
        s.mean_ms, s.p50_ms, s.p99_ms
    );
    println!("(paper measured ~430 ms at 100 nodes)");
    println!(
        "Dorm local placement (§III-D): {:.3} ms ({:.0}x faster)",
        dorm_local_placement_ms(),
        s.mean_ms / dorm_local_placement_ms()
    );
    Ok(())
}

//! The shared allocation engine: Dorm's decision loop, extracted so the
//! live master and the simulator run byte-identical scheduling code.
//!
//! Responsibilities (§III-C Fig. 5 steps (1)–(2), §IV-B):
//!
//! 1. split the snapshot into carried (running) and pending apps, order
//!    pending FIFO by submission;
//! 2. admit the longest feasible FIFO prefix — on infeasibility the
//!    *newest* pending app is deferred first and the solve retried
//!    ("Dorm would keep existing resource allocations until more running
//!    applications finish");
//! 3. solve the count-aggregated P2 through [`Optimizer`] and return the
//!    [`Decision`] (counts + placement + adjusted set).
//!
//! Incremental re-solve state, per engine:
//!
//! * **snapshot cache** — the paper rebuilds and solves P2 on every event,
//!   but consecutive events frequently present an identical (apps,
//!   capacity) snapshot (metric samples, no-op completions of deferred
//!   apps, replayed events).  The engine keys the last decision by the
//!   exact bit pattern of its inputs and returns it without solving when
//!   the key matches ([`SolveStats::cache_hit`]).
//! * **warm start** — the previous solution's counts are fed to the next
//!   solve as an incumbent: the heuristic anchors a candidate pipeline on
//!   them and branch-and-bound starts with their objective as its pruning
//!   bound ([`SolveStats::warm_start`]), instead of only the per-call
//!   heuristic incumbent.  `benches/sched_latency.rs` and
//!   `benches/solver_micro.rs` quantify both paths.

use std::collections::BTreeMap;

use crate::app::AppId;
use crate::config::DormConfig;
use crate::optimizer::{Decision, OptApp, Optimizer, SolveMode};
use crate::resources::Res;

use super::policy::{AllocationUpdate, CmsPolicy, SchedApp, SchedCtx};

/// One application as the engine sees it: the optimizer row plus the FIFO
/// admission key.
#[derive(Clone, Debug)]
pub struct EngineApp {
    pub opt: OptApp,
    /// FIFO key; ties broken by [`AppId`] (submission order).
    pub submit: f64,
}

impl EngineApp {
    /// Build the engine row from a policy-level snapshot row.
    pub fn from_sched(a: &SchedApp) -> EngineApp {
        EngineApp {
            opt: OptApp {
                id: a.id,
                demand: a.demand.clone(),
                weight: a.weight,
                n_min: a.n_min,
                n_max: a.n_max,
                prev: (a.containers > 0).then_some(a.containers),
                current: a.placement.clone(),
            },
            submit: a.submit,
        }
    }
}

/// Engine-lifetime telemetry (cache + warm-start effectiveness).
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    /// Decisions served by actually solving.
    pub solves: u64,
    /// Decisions served from the snapshot cache without solving.
    pub cache_hits: u64,
    /// Solves where the previous solution seeded a feasible incumbent.
    pub warm_start_hits: u64,
}

/// Exact-input key for the snapshot cache: every field the solve depends
/// on, with floats compared by bit pattern (NaN-safe, no tolerance —
/// a near-identical snapshot must re-solve).
#[derive(Clone, Debug, PartialEq, Eq)]
struct SnapshotKey {
    apps: Vec<AppKey>,
    caps: Vec<Vec<u64>>,
}

#[derive(Clone, Debug, PartialEq, Eq)]
struct AppKey {
    id: u64,
    demand: Vec<u64>,
    weight: u64,
    n_min: u32,
    n_max: u32,
    prev: Option<u32>,
    current: Vec<(usize, u32)>,
}

fn res_bits(r: &Res) -> Vec<u64> {
    r.0.iter().map(|v| v.to_bits()).collect()
}

fn snapshot_key(apps: &[&EngineApp], capacities: &[Res]) -> SnapshotKey {
    SnapshotKey {
        apps: apps
            .iter()
            .map(|e| AppKey {
                id: e.opt.id.0,
                demand: res_bits(&e.opt.demand),
                weight: e.opt.weight.to_bits(),
                n_min: e.opt.n_min,
                n_max: e.opt.n_max,
                prev: e.opt.prev,
                current: e.opt.current.iter().map(|(s, &c)| (s.0, c)).collect(),
            })
            .collect(),
        caps: capacities.iter().map(res_bits).collect(),
    }
}

struct CacheEntry {
    key: SnapshotKey,
    decision: Decision,
}

/// The shared Dorm decision loop (see module docs).
pub struct AllocationEngine {
    optimizer: Optimizer,
    cache: Option<CacheEntry>,
    /// Counts of the last enforced decision, per app — the warm-start
    /// incumbent for the next solve.
    prev_counts: BTreeMap<AppId, u32>,
    stats: EngineStats,
}

impl AllocationEngine {
    pub fn new(cfg: DormConfig) -> Self {
        Self::with_mode(cfg, SolveMode::Heuristic)
    }

    pub fn with_mode(cfg: DormConfig, mode: SolveMode) -> Self {
        AllocationEngine {
            optimizer: Optimizer::with_mode(cfg, mode),
            cache: None,
            prev_counts: BTreeMap::new(),
            stats: EngineStats::default(),
        }
    }

    pub fn config(&self) -> &DormConfig {
        &self.optimizer.cfg
    }

    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// Drop the cached solution and warm-start state (e.g. after an
    /// out-of-band capacity change the caller knows invalidates them).
    pub fn invalidate(&mut self) {
        self.cache = None;
        self.prev_counts.clear();
    }

    /// The shared loop: admission ordering, newest-first deferral, solve.
    /// `None` = no feasible allocation even with every pending app deferred
    /// — the backend keeps existing partitions (§IV-B).
    pub fn decide(&mut self, apps: &[EngineApp], capacities: &[Res]) -> Option<Decision> {
        // carried apps first (input order), then pending FIFO by submit
        let running: Vec<&EngineApp> =
            apps.iter().filter(|e| e.opt.prev.is_some()).collect();
        let mut pending: Vec<&EngineApp> =
            apps.iter().filter(|e| e.opt.prev.is_none()).collect();
        pending.sort_by(|a, b| {
            a.submit.total_cmp(&b.submit).then(a.opt.id.cmp(&b.opt.id))
        });

        let ordered: Vec<&EngineApp> =
            running.iter().chain(pending.iter()).copied().collect();
        let key = snapshot_key(&ordered, capacities);
        if let Some(entry) = &self.cache {
            if entry.key == key {
                self.stats.cache_hits += 1;
                let mut d = entry.decision.clone();
                d.stats.cache_hit = true;
                return Some(d);
            }
        }

        self.stats.solves += 1;
        let running_opts: Vec<OptApp> =
            running.iter().map(|e| e.opt.clone()).collect();
        let pending_opts: Vec<OptApp> =
            pending.iter().map(|e| e.opt.clone()).collect();
        // snapshot the incumbent (cheap: one count per app) so the borrow
        // doesn't conflict with updating it on success
        let warm_counts = self.prev_counts.clone();
        let warm = (!warm_counts.is_empty()).then_some(&warm_counts);

        // admit as many pending apps (FIFO) as stay feasible
        for admit in (0..=pending_opts.len()).rev() {
            let mut try_apps = running_opts.clone();
            try_apps.extend(pending_opts[..admit].iter().cloned());
            if let Some(d) = self.optimizer.allocate_warm(&try_apps, capacities, warm) {
                if d.stats.warm_start {
                    self.stats.warm_start_hits += 1;
                }
                self.prev_counts = d.counts.clone();
                self.cache = Some(CacheEntry { key, decision: d.clone() });
                return Some(d);
            }
        }
        None
    }
}

/// Dorm as a [`CmsPolicy`]: a thin adapter over [`AllocationEngine`] —
/// usable unchanged by the live [`crate::master::DormMaster`] and the DES
/// ([`crate::sim::run_sim`]).
pub struct DormPolicy {
    pub engine: AllocationEngine,
    label: String,
}

impl DormPolicy {
    pub fn new(cfg: DormConfig) -> Self {
        Self::with_mode(cfg, SolveMode::Heuristic)
    }

    pub fn with_mode(cfg: DormConfig, mode: SolveMode) -> Self {
        DormPolicy {
            label: format!("dorm(t1={},t2={})", cfg.theta1, cfg.theta2),
            engine: AllocationEngine::with_mode(cfg, mode),
        }
    }
}

impl CmsPolicy for DormPolicy {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn on_change(&mut self, ctx: &SchedCtx) -> Option<AllocationUpdate> {
        let apps: Vec<EngineApp> = ctx.apps.values().map(EngineApp::from_sched).collect();
        let d = self.engine.decide(&apps, ctx.capacities)?;
        Some(AllocationUpdate {
            assignment: d.placement.assignment,
            adjusted: d.adjusted,
        })
    }

    /// A server died or recovered (`crate::fault`): the cached decision and
    /// the warm-start incumbent were solved against a capacity vector that
    /// no longer exists — drop both so the next decide() is a cold solve.
    fn on_capacity_change(&mut self) {
        self.engine.invalidate();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ServerId;

    fn eapp(id: u64, cpu: f64, ram: f64, lo: u32, hi: u32, held: u32, submit: f64) -> EngineApp {
        let current: BTreeMap<ServerId, u32> = if held > 0 {
            [(ServerId(0), held)].into_iter().collect()
        } else {
            BTreeMap::new()
        };
        EngineApp {
            opt: OptApp {
                id: AppId(id),
                demand: Res(vec![cpu, ram]),
                weight: 1.0,
                n_min: lo,
                n_max: hi,
                prev: (held > 0).then_some(held),
                current,
            },
            submit,
        }
    }

    fn caps(n: usize, cpu: f64, ram: f64) -> Vec<Res> {
        (0..n).map(|_| Res(vec![cpu, ram])).collect()
    }

    #[test]
    fn identical_snapshot_is_served_from_cache() {
        let mut eng = AllocationEngine::new(DormConfig::DORM3);
        let apps = vec![eapp(1, 2.0, 8.0, 1, 10, 0, 0.0)];
        let capacities = caps(4, 12.0, 64.0);
        let d1 = eng.decide(&apps, &capacities).unwrap();
        assert!(!d1.stats.cache_hit);
        let d2 = eng.decide(&apps, &capacities).unwrap();
        assert!(d2.stats.cache_hit);
        assert_eq!(d1.counts, d2.counts);
        assert_eq!(eng.stats().solves, 1);
        assert_eq!(eng.stats().cache_hits, 1);
    }

    #[test]
    fn changed_snapshot_resolves_with_warm_start() {
        let mut eng = AllocationEngine::new(DormConfig { theta1: 1.0, theta2: 1.0 });
        let capacities = caps(2, 20.0, 20.0);
        let a = eapp(1, 1.0, 1.0, 1, 40, 0, 0.0);
        let d1 = eng.decide(&[a.clone()], &capacities).unwrap();
        let held = d1.counts[&AppId(1)];
        assert!(held > 0);
        // second event: app 1 carried at its decided width, app 2 arrives
        let carried = eapp(1, 1.0, 1.0, 1, 40, held, 0.0);
        let arriving = eapp(2, 1.0, 1.0, 1, 40, 0, 1.0);
        let d2 = eng.decide(&[carried, arriving], &capacities).unwrap();
        assert!(!d2.stats.cache_hit);
        assert!(d2.stats.warm_start, "previous counts must seed the solve");
        assert_eq!(eng.stats().solves, 2);
        assert!(eng.stats().warm_start_hits >= 1);
        assert!(d2.counts[&AppId(2)] >= 1);
    }

    #[test]
    fn newest_pending_deferred_first() {
        let mut eng = AllocationEngine::new(DormConfig { theta1: 1.0, theta2: 1.0 });
        let capacities = caps(1, 10.0, 10.0);
        // each app floors at 3 containers of 2 CPUs: only one fits
        let old = eapp(1, 2.0, 1.0, 3, 5, 0, 0.0);
        let newer = eapp(2, 2.0, 1.0, 3, 5, 0, 1.0);
        let d = eng.decide(&[newer.clone(), old.clone()], &capacities).unwrap();
        assert!(d.counts.contains_key(&AppId(1)), "older app admitted");
        assert!(!d.counts.contains_key(&AppId(2)), "newest deferred first");
    }

    #[test]
    fn explicit_invalidate_forces_cold_resolve() {
        use super::super::policy::CmsPolicy;
        let mut pol = DormPolicy::new(DormConfig::DORM3);
        let apps = vec![eapp(1, 2.0, 8.0, 1, 10, 0, 0.0)];
        let capacities = caps(4, 12.0, 64.0);
        let d1 = pol.engine.decide(&apps, &capacities).unwrap();
        pol.on_capacity_change();
        // identical snapshot, but the fault path dropped the cache: the
        // engine must solve again (and reproduce the same counts)
        let d2 = pol.engine.decide(&apps, &capacities).unwrap();
        assert!(!d2.stats.cache_hit, "invalidate must force a re-solve");
        assert_eq!(d1.counts, d2.counts);
        assert_eq!(pol.engine.stats().solves, 2);
    }

    #[test]
    fn cache_invalidated_by_capacity_change() {
        let mut eng = AllocationEngine::new(DormConfig::DORM3);
        let apps = vec![eapp(1, 2.0, 8.0, 1, 10, 0, 0.0)];
        let d1 = eng.decide(&apps, &caps(4, 12.0, 64.0)).unwrap();
        let d2 = eng.decide(&apps, &caps(2, 12.0, 64.0)).unwrap();
        assert!(!d2.stats.cache_hit, "smaller cluster must re-solve");
        assert!(d2.counts[&AppId(1)] <= d1.counts[&AppId(1)]);
        assert_eq!(eng.stats().solves, 2);
    }
}

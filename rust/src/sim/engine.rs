//! Event queue: a min-heap over (time, sequence) with lazy cancellation.
//!
//! Completion events are invalidated whenever an app's allocation changes;
//! instead of deleting from the heap, each event carries a version and the
//! runner drops events whose version no longer matches the app's.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation time in hours. Finite by construction.
pub type SimTime = f64;

#[derive(Clone, Debug, PartialEq)]
pub struct Scheduled<E> {
    pub time: SimTime,
    /// Tie-break: FIFO among equal times (deterministic replay).
    pub seq: u64,
    pub event: E,
}

impl<E: PartialEq> Eq for Scheduled<E> {}

impl<E: PartialEq> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E: PartialEq> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first.
        // Deliberately `partial_cmp().expect(..)` rather than `total_cmp`:
        // a NaN event time is a scheduling bug (durations or pauses went
        // NaN upstream) and must abort the run loudly — total ordering
        // would silently sink NaNs to one end of the heap and the sim
        // would produce garbage metrics instead of a stack trace.
        // `schedule()` also debug_asserts `t.is_finite()`.
        other
            .time
            .partial_cmp(&self.time)
            .expect("sim time must be finite")
            .then(other.seq.cmp(&self.seq))
    }
}

/// Time-ordered event queue.
#[derive(Clone, Debug)]
pub struct EventQueue<E: PartialEq> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
    now: SimTime,
}

impl<E: PartialEq> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }
}

impl<E: PartialEq> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current simulation time (the time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` at absolute time `t` (must be >= now).
    pub fn schedule(&mut self, t: SimTime, event: E) {
        debug_assert!(t >= self.now - 1e-12, "scheduling into the past: {t} < {}", self.now);
        debug_assert!(t.is_finite());
        self.seq += 1;
        self.heap.push(Scheduled { time: t.max(self.now), seq: self.seq, event });
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<Scheduled<E>> {
        let s = self.heap.pop()?;
        self.now = s.time;
        Some(s)
    }

    /// Time of the earliest scheduled event without popping it.  The
    /// runner uses this to interleave externally-sourced arrivals (held
    /// *outside* the heap, see `runner::ArrivalSource`) at exactly the
    /// priority pre-scheduled arrivals would have had: an arrival due at
    /// or before the head event runs first, matching the FIFO-seq order
    /// of a queue whose arrivals were all scheduled up front.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|s| s.time)
    }

    /// Pop the head event only when `pred` accepts it.  The runner drains
    /// same-time batches this way — a correlated rack outage schedules one
    /// `ServerFail` per member at one timestamp, and the live master's
    /// lease sweep expires those slaves as *one* batch with one re-solve,
    /// so the DES must consume them in one handler pass to stay
    /// decision-identical (`tests/fault.rs`).
    pub fn pop_if(&mut self, pred: impl Fn(&Scheduled<E>) -> bool) -> Option<Scheduled<E>> {
        if pred(self.heap.peek()?) {
            self.pop()
        } else {
            None
        }
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|s| s.event)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_among_equal_times() {
        let mut q = EventQueue::new();
        q.schedule(1.0, "first");
        q.schedule(1.0, "second");
        q.schedule(1.0, "third");
        assert_eq!(q.pop().unwrap().event, "first");
        assert_eq!(q.pop().unwrap().event, "second");
        assert_eq!(q.pop().unwrap().event, "third");
    }

    #[test]
    fn pop_if_only_takes_matching_heads() {
        let mut q = EventQueue::new();
        q.schedule(1.0, "a");
        q.schedule(1.0, "b");
        q.schedule(2.0, "c");
        assert_eq!(q.pop().unwrap().event, "a");
        // same-time sibling drains; the later event does not
        assert_eq!(q.pop_if(|s| s.time == 1.0).unwrap().event, "b");
        assert_eq!(q.pop_if(|s| s.time == 1.0), None);
        assert_eq!(q.pop().unwrap().event, "c");
        assert_eq!(q.pop_if(|_| true), None, "empty queue");
    }

    #[test]
    fn peek_matches_pop_order() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        assert_eq!(q.peek_time(), Some(1.0));
        q.pop();
        assert_eq!(q.peek_time(), Some(3.0));
        q.pop();
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(5.0, 1);
        q.schedule(2.0, 2);
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 2.0);
        q.schedule(2.5, 3); // scheduling relative to new now is fine
        q.pop();
        assert_eq!(q.now(), 2.5);
        q.pop();
        assert_eq!(q.now(), 5.0);
        assert!(q.is_empty());
    }
}

//! §II-C reproduction: per-task scheduling latency of task-level two-level
//! sharing (Mesos-like) vs Dorm's local task placement.
//!
//! Paper measurement: "in a 100-node Mesos cluster ... the average
//! scheduling latency per task is about 430 ms"; Dorm places tasks on the
//! local TaskExecutor (§III-D) with no central round-trip.

#[path = "harness/mod.rs"]
mod harness;

use dorm::baselines::tasklevel::{dorm_local_placement_ms, TaskLevelModel};
use dorm::report;
use dorm::util::Rng;

fn main() {
    harness::banner("§II-C — task-level scheduling latency vs cluster size");
    let mut rng = Rng::new(7);
    let sizes = [10usize, 25, 50, 75, 100, 150];
    let mut rows = Vec::new();
    let mut means = Vec::new();
    for &nodes in &sizes {
        let m = TaskLevelModel { nodes, ..Default::default() };
        let s = m.simulate(300, &mut rng);
        means.push((nodes as f64, s.mean_ms));
        rows.push(vec![
            format!("{nodes}"),
            format!("{:.2}", m.rho()),
            m.analytic_mean_ms()
                .map(|a| format!("{a:.0}"))
                .unwrap_or_else(|| "sat".into()),
            format!("{:.0}", s.mean_ms),
            format!("{:.0}", s.p50_ms),
            format!("{:.0}", s.p99_ms),
        ]);
    }
    println!(
        "{}",
        report::table(
            &["nodes", "offered load ρ", "M/M/1 (ms)", "mean (ms)", "p50", "p99"],
            &rows
        )
    );

    let hundred = means.iter().find(|(n, _)| *n == 100.0).unwrap().1;
    harness::paper_row(
        "mean scheduling latency per task, 100 nodes",
        "~430 ms",
        &format!("{hundred:.0} ms"),
    );
    harness::paper_row(
        "Dorm local task placement (§III-D)",
        "~0 (no petition)",
        &format!("{:.3} ms", dorm_local_placement_ms()),
    );
    harness::paper_row(
        "latency ratio (task-level / Dorm)",
        ">> 10^4",
        &format!("{:.0}x", hundred / dorm_local_placement_ms()),
    );

    println!("\nlatency vs cluster size:");
    println!("{}", report::ascii_chart(&[("mean ms", &means)], 10, 60));
}

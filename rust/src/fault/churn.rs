//! The churn experiment: what machine failure does to each CMS.
//!
//! An evaluation axis the paper never had: sweep per-server MTBF and run
//! Dorm and all four baselines (static/Swarm, Mesos app-level, IaaS
//! engine-partitioned, task-level) over the same workload and failure
//! trace, reporting mean utilization, fairness loss, cumulative lost work,
//! mean recovery time and goodput through [`crate::metrics`].  Exposed on
//! the CLI as `dorm churn`; `report::write_csv` emits per-system series
//! for external plotting.
//!
//! [`correlated_sweep`] is the failure-domain variant (DESIGN.md §14):
//! whole racks die in one batch under
//! [`crate::fault::FailureModel::Correlated`], with rack 0 `hot_factor`×
//! less reliable than the rest, and each Dorm config
//! runs twice — risk-blind and risk-aware
//! ([`DormPolicy::enable_risk_aware`]) — so the sweep measures what the
//! online MTBF estimator's placement steering is worth in lost work,
//! recovery time and goodput.

use anyhow::Result;

use crate::baselines::{IaasPolicy, MesosAppLevelPolicy, StaticPolicy, TaskLevelPolicy};
use crate::config::{DormConfig, FaultConfig};
use crate::fault::DomainTopology;
use crate::report;
use crate::sched::CmsPolicy;
use crate::sim::{DormPolicy, Experiment, SystemRun};

/// One (system, MTBF) cell of the sweep.
#[derive(Clone, Debug)]
pub struct ChurnPoint {
    pub system: String,
    pub mtbf_hours: f64,
    /// Mean Eq. 1 utilization over the horizon.
    pub mean_utilization: f64,
    /// Mean Eq. 2 fairness loss over the horizon.
    pub mean_fairness_loss: f64,
    /// Cumulative work-hours discarded by server deaths.
    pub lost_work: f64,
    /// Mean hours from server death to the affected app running again.
    pub mean_recovery_hours: f64,
    /// Mean sampled useful-progress rate (work-units/hour).
    pub mean_goodput: f64,
    pub completed: usize,
    /// Allocation decisions deferred by a master outage (0 when
    /// `[fault].master_fail_at_hours` is off) — the takeover's "lost
    /// adjustments" cost, DESIGN.md §11.
    pub deferred_allocs: usize,
}

impl ChurnPoint {
    fn from_run(run: &SystemRun, mtbf_hours: f64, horizon: f64) -> Self {
        let m = run.metrics();
        ChurnPoint {
            system: run.label.clone(),
            mtbf_hours,
            mean_utilization: m.utilization.mean_over(0.0, horizon),
            mean_fairness_loss: m.fairness_loss.mean_over(0.0, horizon),
            lost_work: m.lost_work.last().unwrap_or(0.0),
            mean_recovery_hours: m.mean_recovery_hours(),
            mean_goodput: m.goodput.mean_over(0.0, horizon),
            completed: run.outcome.completed,
            deferred_allocs: run.outcome.deferred_allocations,
        }
    }
}

/// Dorm (three θ configs) + the four baselines, freshly constructed per
/// run (policies are stateful).
fn systems(n_servers: usize) -> Vec<Box<dyn CmsPolicy>> {
    vec![
        Box::new(DormPolicy::new(DormConfig::DORM1)),
        Box::new(DormPolicy::new(DormConfig::DORM2)),
        Box::new(DormPolicy::new(DormConfig::DORM3)),
        Box::new(StaticPolicy::new()),
        Box::new(MesosAppLevelPolicy::new()),
        Box::new(IaasPolicy::proportional(n_servers)),
        Box::new(TaskLevelPolicy::new()),
    ]
}

/// Sweep MTBF over the scaled §V experiment.  `base` supplies every
/// `[fault]` knob except `mtbf_hours` (MTTR, failure seed, periodic
/// checkpoint cadence); each sweep point overrides the MTBF and forces
/// `enabled`.  Every system sees the same workload and the same failure
/// trace per MTBF; the paper's original no-churn world is recoverable by
/// adding a very large MTBF to the sweep.  When
/// `base.master_fail_at_hours > 0` the trace additionally kills the CMS
/// master at that hour, with the standby takeover completing
/// `master_takeover_hours` later — so Fig-style experiments can quantify
/// takeover latency and lost adjustments (DESIGN.md §11).
pub fn churn_sweep(
    base: &FaultConfig,
    seed: u64,
    horizon_hours: f64,
    napps: usize,
    mtbfs: &[f64],
) -> Result<Vec<ChurnPoint>> {
    use crate::fault::FailureEvent;
    let mut out = Vec::new();
    for &mtbf in mtbfs {
        let mut exp = Experiment::scaled(seed, horizon_hours, napps);
        let n_servers = exp.cluster.servers.len();
        let cfg = FaultConfig { enabled: true, mtbf_hours: mtbf, ..base.clone() };
        let mut trace = exp.apply_fault(&cfg)?;
        if base.master_fail_at_hours > 0.0 {
            trace.push(FailureEvent::master_kill(base.master_fail_at_hours));
            trace.push(FailureEvent::master_recover(
                base.master_fail_at_hours + base.master_takeover_hours,
            ));
        }
        for mut policy in systems(n_servers) {
            let run = exp.run_with_faults(policy.as_mut(), &trace);
            out.push(ChurnPoint::from_run(&run, mtbf, horizon_hours));
        }
    }
    Ok(out)
}

/// One (system, domain MTBF) cell of the correlated-outage sweep.
#[derive(Clone, Debug)]
pub struct CorrelatedPoint {
    pub system: String,
    /// Whether this run steered placement with the online estimator.
    pub risk_aware: bool,
    pub domain_mtbf_hours: f64,
    pub domain_size: usize,
    pub mean_utilization: f64,
    /// Cumulative work-hours discarded by rack/server deaths.
    pub lost_work: f64,
    /// Mean hours from outage to the affected app running again.
    pub mean_recovery_hours: f64,
    pub mean_goodput: f64,
    pub completed: usize,
}

impl CorrelatedPoint {
    fn from_run(run: &SystemRun, cfg: &FaultConfig, horizon: f64) -> Self {
        let m = run.metrics();
        CorrelatedPoint {
            system: run.label.clone(),
            risk_aware: run.label.ends_with("+risk"),
            domain_mtbf_hours: cfg.domains.domain_mtbf_hours,
            domain_size: cfg.domains.domain_size,
            mean_utilization: m.utilization.mean_over(0.0, horizon),
            lost_work: m.lost_work.last().unwrap_or(0.0),
            mean_recovery_hours: m.mean_recovery_hours(),
            mean_goodput: m.goodput.mean_over(0.0, horizon),
            completed: run.outcome.completed,
        }
    }
}

/// Sweep the *domain* MTBF under
/// [`crate::fault::FailureModel::Correlated`] (DESIGN.md
/// §14): whole racks of `base.domains.domain_size` servers die in one
/// batch, with rack 0 `hot_factor`× less reliable.  Every Dorm θ config
/// runs twice over the identical workload and failure trace — risk-blind,
/// and risk-aware with a fresh online [`crate::fault::MtbfEstimator`] —
/// and the four baselines run blind, so the risk-aware/risk-blind delta
/// in lost work, recovery time and goodput is attributable to placement
/// steering alone.
pub fn correlated_sweep(
    base: &FaultConfig,
    seed: u64,
    horizon_hours: f64,
    napps: usize,
    domain_mtbfs: &[f64],
) -> Result<Vec<CorrelatedPoint>> {
    let mut out = Vec::new();
    for &mtbf in domain_mtbfs {
        let mut exp = Experiment::scaled(seed, horizon_hours, napps);
        let n_servers = exp.cluster.servers.len();
        let mut cfg = FaultConfig { enabled: true, ..base.clone() };
        cfg.domains.enabled = true;
        cfg.domains.domain_mtbf_hours = mtbf;
        let trace = exp.apply_fault(&cfg)?;
        let topo = DomainTopology::grouped(
            n_servers,
            cfg.domains.domain_size,
            cfg.domains.racks_per_power,
        );
        for dorm in [DormConfig::DORM1, DormConfig::DORM2, DormConfig::DORM3] {
            for aware in [false, true] {
                let mut policy = DormPolicy::new(dorm);
                if aware {
                    policy.enable_risk_aware(topo.clone());
                }
                let run = exp.run_with_faults(&mut policy, &trace);
                out.push(CorrelatedPoint::from_run(&run, &cfg, horizon_hours));
            }
        }
        let baselines: Vec<Box<dyn CmsPolicy>> = vec![
            Box::new(StaticPolicy::new()),
            Box::new(MesosAppLevelPolicy::new()),
            Box::new(IaasPolicy::proportional(n_servers)),
            Box::new(TaskLevelPolicy::new()),
        ];
        for mut policy in baselines {
            let run = exp.run_with_faults(policy.as_mut(), &trace);
            out.push(CorrelatedPoint::from_run(&run, &cfg, horizon_hours));
        }
    }
    Ok(out)
}

/// ASCII table of a correlated sweep, one row per (system, domain MTBF).
pub fn correlated_table(points: &[CorrelatedPoint]) -> String {
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.system.clone(),
                format!("{:.1}", p.domain_mtbf_hours),
                format!("{}", p.domain_size),
                format!("{:.3}", p.mean_utilization),
                format!("{:.2}", p.lost_work),
                format!("{:.3}", p.mean_recovery_hours),
                format!("{:.1}", p.mean_goodput),
                format!("{}", p.completed),
            ]
        })
        .collect();
    report::table(
        &[
            "system",
            "dom_mtbf_h",
            "dom_size",
            "mean util",
            "lost work",
            "recovery_h",
            "goodput",
            "completed",
        ],
        &rows,
    )
}

/// Per-system CSV columns of a correlated sweep for
/// [`crate::report::write_csv`].
pub fn correlated_csv_columns(
    points: &[CorrelatedPoint],
    system: &str,
) -> Vec<(&'static str, Vec<f64>)> {
    let rows: Vec<&CorrelatedPoint> = points.iter().filter(|p| p.system == system).collect();
    vec![
        ("domain_mtbf_hours", rows.iter().map(|p| p.domain_mtbf_hours).collect()),
        ("mean_utilization", rows.iter().map(|p| p.mean_utilization).collect()),
        ("lost_work", rows.iter().map(|p| p.lost_work).collect()),
        ("mean_recovery_hours", rows.iter().map(|p| p.mean_recovery_hours).collect()),
        ("mean_goodput", rows.iter().map(|p| p.mean_goodput).collect()),
        ("completed", rows.iter().map(|p| p.completed as f64).collect()),
    ]
}

/// ASCII table of a sweep, one row per (system, MTBF).
pub fn churn_table(points: &[ChurnPoint]) -> String {
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.system.clone(),
                format!("{:.1}", p.mtbf_hours),
                format!("{:.3}", p.mean_utilization),
                format!("{:.3}", p.mean_fairness_loss),
                format!("{:.2}", p.lost_work),
                format!("{:.3}", p.mean_recovery_hours),
                format!("{:.1}", p.mean_goodput),
                format!("{}", p.completed),
                format!("{}", p.deferred_allocs),
            ]
        })
        .collect();
    report::table(
        &[
            "system",
            "mtbf_h",
            "mean util",
            "fairness loss",
            "lost work",
            "recovery_h",
            "goodput",
            "completed",
            "deferred",
        ],
        &rows,
    )
}

/// Per-system CSV columns (mtbf, util, fairness, lost work, recovery,
/// goodput) for [`crate::report::write_csv`].
pub fn churn_csv_columns(
    points: &[ChurnPoint],
    system: &str,
) -> Vec<(&'static str, Vec<f64>)> {
    let rows: Vec<&ChurnPoint> = points.iter().filter(|p| p.system == system).collect();
    vec![
        ("mtbf_hours", rows.iter().map(|p| p.mtbf_hours).collect()),
        ("mean_utilization", rows.iter().map(|p| p.mean_utilization).collect()),
        ("mean_fairness_loss", rows.iter().map(|p| p.mean_fairness_loss).collect()),
        ("lost_work", rows.iter().map(|p| p.lost_work).collect()),
        ("mean_recovery_hours", rows.iter().map(|p| p.mean_recovery_hours).collect()),
        ("mean_goodput", rows.iter().map(|p| p.mean_goodput).collect()),
        ("completed", rows.iter().map(|p| p.completed as f64).collect()),
        ("deferred_allocs", rows.iter().map(|p| p.deferred_allocs as f64).collect()),
    ]
}

/// Distinct system labels in sweep order.
pub fn churn_systems(points: &[ChurnPoint]) -> Vec<String> {
    let mut labels: Vec<String> = Vec::new();
    for p in points {
        if !labels.contains(&p.system) {
            labels.push(p.system.clone());
        }
    }
    labels
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smoke the whole sweep at a small scale: every system runs under
    /// churn, emits the fault metrics, and the harsher MTBF loses at least
    /// as much work as the milder one for the same system.
    #[test]
    fn sweep_covers_dorm_and_all_four_baselines() {
        let base = FaultConfig {
            mttr_hours: 0.25,
            ckpt_period_hours: 0.5,
            seed: 11,
            ..Default::default()
        };
        let points = churn_sweep(&base, 11, 4.0, 6, &[1.0, 16.0]).unwrap();
        let labels = churn_systems(&points);
        for want in ["dorm(t1=0.2,t2=0.1)", "static", "mesos-app", "iaas", "task-level"] {
            assert!(
                labels.iter().any(|l| l == want),
                "system {want} missing from {labels:?}"
            );
        }
        assert_eq!(points.len(), 2 * 7, "7 systems x 2 MTBFs");
        for p in &points {
            assert!(p.mean_utilization >= 0.0);
            assert!(p.lost_work >= 0.0);
            assert!(p.mean_recovery_hours >= 0.0);
        }
        let table = churn_table(&points);
        assert!(table.contains("mtbf_h"));
        let cols = churn_csv_columns(&points, "static");
        assert_eq!(cols[0].1.len(), 2);
        // no master outage configured: nothing deferred anywhere
        assert!(points.iter().all(|p| p.deferred_allocs == 0));
    }

    /// With a master outage injected mid-run, every system records the
    /// allocation work it had to defer until the standby took over.
    #[test]
    fn master_outage_sweeps_report_deferred_allocations() {
        let base = FaultConfig {
            mttr_hours: 0.25,
            ckpt_period_hours: 0.5,
            seed: 11,
            master_fail_at_hours: 1.0,
            master_takeover_hours: 1.0,
            ..Default::default()
        };
        let points = churn_sweep(&base, 11, 4.0, 6, &[1.0]).unwrap();
        assert_eq!(points.len(), 7, "7 systems x 1 MTBF");
        assert!(
            points.iter().any(|p| p.deferred_allocs > 0),
            "a 1 h outage over a 4 h run must defer something: {points:?}"
        );
        assert!(churn_table(&points).contains("deferred"));
    }

    /// A bad base config surfaces the typed [`crate::fault::FaultError`]
    /// through the sweep instead of panicking (satellite of DESIGN.md §14).
    #[test]
    fn sweeps_surface_typed_errors_for_bad_configs() {
        let base = FaultConfig { mttr_hours: -1.0, ..Default::default() };
        let err = churn_sweep(&base, 1, 1.0, 2, &[4.0]).unwrap_err();
        assert!(err.downcast_ref::<crate::fault::FaultError>().is_some(), "{err}");
        let mut base = FaultConfig::default();
        base.domains.hot_factor = 0.0;
        let err = correlated_sweep(&base, 1, 1.0, 2, &[4.0]).unwrap_err();
        assert!(err.downcast_ref::<crate::fault::FaultError>().is_some(), "{err}");
    }

    /// Structural smoke of the correlated sweep: every Dorm config appears
    /// risk-blind *and* risk-aware over the identical trace, the four
    /// baselines run blind, and every point carries finite fault metrics.
    #[test]
    fn correlated_sweep_runs_aware_and_blind_over_one_trace() {
        let mut base = FaultConfig {
            mttr_hours: 0.25,
            ckpt_period_hours: 0.5,
            seed: 7,
            // effectively no independent churn: isolate the rack outages
            mtbf_hours: 1e9,
            ..Default::default()
        };
        base.domains.domain_size = 4;
        base.domains.domain_mttr_hours = 0.25;
        base.domains.hot_factor = 4.0;
        let points = correlated_sweep(&base, 7, 4.0, 6, &[2.0]).unwrap();
        assert_eq!(points.len(), 3 * 2 + 4, "3 Dorm configs x {{blind,aware}} + 4 baselines");
        assert_eq!(points.iter().filter(|p| p.risk_aware).count(), 3);
        for p in &points {
            assert_eq!(p.risk_aware, p.system.ends_with("+risk"), "{}", p.system);
            assert_eq!(p.domain_mtbf_hours, 2.0);
            assert_eq!(p.domain_size, 4);
            assert!(p.mean_utilization.is_finite() && p.mean_utilization >= 0.0);
            assert!(p.lost_work.is_finite() && p.lost_work >= 0.0);
            assert!(p.mean_recovery_hours.is_finite() && p.mean_recovery_hours >= 0.0);
            assert!(p.mean_goodput.is_finite());
        }
        let table = correlated_table(&points);
        assert!(table.contains("dom_mtbf_h") && table.contains("+risk"));
        let aware = points.iter().find(|p| p.risk_aware).unwrap();
        let cols = correlated_csv_columns(&points, &aware.system);
        assert_eq!(cols[0].0, "domain_mtbf_hours");
        assert_eq!(cols[0].1.len(), 1);
    }

    /// The §14 headline, pinned deterministically: two racks of four
    /// one-container servers, rack 0 suffering scripted whole-rack outages
    /// at t = 1 h and t = 3 h.  Both systems lose the first outage's work
    /// (the first app is already running when rack 0 first dies), but the
    /// app arriving *between* the outages lands on rack 0 under risk-blind
    /// placement (lowest-index tie-break) and on rack 1 under risk-aware
    /// placement (the estimator holds a rack-0 failure observation per
    /// member by then) — so the second outage costs the blind system more
    /// lost work, an extra recovery cycle, and a longer completion.
    #[test]
    fn risk_aware_strictly_dominates_risk_blind_on_scripted_rack_outages() {
        use crate::app::Engine;
        use crate::config::{ClusterConfig, SimConfig};
        use crate::fault::FailureEvent;
        use crate::resources::Res;
        use crate::sim::{run_sim_faulty, PerfModel};
        use crate::workload::{Table2Row, WorkloadApp};

        // each server fits exactly one 8-CPU container
        let rows = vec![Table2Row {
            engine: Engine::MxNet,
            dataset: "synthetic",
            model: "WIDE",
            demand: Res::cpu_gpu_ram(8.0, 0.0, 16.0),
            weight: 1,
            n_max: 2,
            n_min: 1,
            num: 2,
            baseline_containers: 2,
            duration_median_hours: 4.0,
        }];
        let wl = vec![
            WorkloadApp {
                row: 0,
                tag: "WIDE".into(),
                submit_hours: 0.0,
                duration_at_baseline_hours: 4.0,
                baseline_n: 2,
            },
            WorkloadApp {
                row: 0,
                tag: "WIDE".into(),
                submit_hours: 2.0,
                duration_at_baseline_hours: 4.0,
                baseline_n: 2,
            },
        ];
        let cluster = ClusterConfig::uniform(8, Res::cpu_gpu_ram(12.0, 0.0, 64.0));
        let sim = SimConfig { horizon_hours: 12.0, ..Default::default() };
        let pm = PerfModel { ckpt_period_hours: 0.25, ..Default::default() };
        // rack 0 = servers 0..4, killed as whole-rack batches
        let mut faults = Vec::new();
        for &t in &[1.0, 3.0] {
            for j in 0..4usize {
                faults.push(FailureEvent::kill(t, j));
                faults.push(FailureEvent::recover(t + 0.4, j));
            }
        }
        faults.sort_by(|a, b| a.time.total_cmp(&b.time).then(a.server.cmp(&b.server)));

        let run = |aware: bool| {
            let mut pol = DormPolicy::new(DormConfig::DORM1);
            if aware {
                pol.enable_risk_aware(DomainTopology::grouped(8, 4, 1));
            }
            run_sim_faulty(&mut pol, &rows, &wl, &cluster, &sim, &pm, &faults)
        };
        let blind = run(false);
        let aware = run(true);
        assert_eq!(blind.completed, 2, "blind run must finish both apps");
        assert_eq!(aware.completed, 2, "aware run must finish both apps");

        let lost = |o: &crate::sim::SimOutcome| o.metrics.lost_work.last().unwrap_or(0.0);
        assert!(
            lost(&aware) < lost(&blind),
            "risk-aware must lose strictly less work: aware {} vs blind {}",
            lost(&aware),
            lost(&blind)
        );
        let recoveries = |o: &crate::sim::SimOutcome| -> u32 {
            o.apps.values().map(|a| a.recoveries).sum()
        };
        assert!(
            recoveries(&aware) < recoveries(&blind),
            "the second outage must not touch the risk-aware run: aware {} vs blind {}",
            recoveries(&aware),
            recoveries(&blind)
        );
        // the app submitted between the outages (AppId 1) finishes sooner
        // when placed off the hot rack
        let dur = |o: &crate::sim::SimOutcome| {
            let a = &o.apps[&crate::app::AppId(1)];
            a.completed_at.unwrap() - a.submit
        };
        assert!(
            dur(&aware) < dur(&blind),
            "aware {} vs blind {}",
            dur(&aware),
            dur(&blind)
        );
    }
}

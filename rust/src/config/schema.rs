//! Typed configuration schemas on top of the TOML-subset parser.
//!
//! `ClusterConfig::paper_testbed()` reproduces the paper's §V-A testbed:
//! 20 DormSlaves totalling 240 CPU cores, 5 GPUs and 2.5 TB RAM.

use anyhow::{bail, Result};

use super::parse::TomlDoc;
use crate::resources::Res;

/// One DormSlave's capacity.
#[derive(Clone, Debug, PartialEq)]
pub struct ServerConfig {
    pub name: String,
    pub capacity: Res,
}

/// The whole cluster.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub servers: Vec<ServerConfig>,
}

impl ClusterConfig {
    /// Paper §V-A: 20 DormSlaves, 240 CPUs / 5 GPUs / 2560 GB total.
    /// 12 CPUs and 128 GB per slave; the 5 GPUs live on the first 5 slaves.
    pub fn paper_testbed() -> Self {
        let servers = (0..20)
            .map(|i| ServerConfig {
                name: format!("slave{i:02}"),
                capacity: Res::cpu_gpu_ram(12.0, if i < 5 { 1.0 } else { 0.0 }, 128.0),
            })
            .collect();
        ClusterConfig { servers }
    }

    /// Uniform synthetic cluster (tests / ablations).
    pub fn uniform(n: usize, per_server: Res) -> Self {
        ClusterConfig {
            servers: (0..n)
                .map(|i| ServerConfig {
                    name: format!("slave{i:02}"),
                    capacity: per_server.clone(),
                })
                .collect(),
        }
    }

    /// Aggregate capacity Σ c_h (the denominator of Eqs 1–2).
    pub fn total_capacity(&self) -> Res {
        let m = self.servers.first().map(|s| s.capacity.m()).unwrap_or(0);
        self.servers
            .iter()
            .fold(Res::zeros(m), |mut acc, s| {
                acc += &s.capacity;
                acc
            })
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let n = doc.u32_of("cluster", "slaves")? as usize;
        let caps = doc
            .get("cluster", "capacity_per_slave")
            .and_then(|v| v.as_array())
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect::<Vec<_>>());
        let Some(caps) = caps else {
            bail!("[cluster].capacity_per_slave must be an array of numbers");
        };
        let gpus_total = doc.u32_or("cluster", "gpus_total", 0);
        let mut cfg = ClusterConfig::uniform(n, Res(caps));
        // distribute whole GPUs over the first servers (paper style)
        if cfg.servers.first().map(|s| s.capacity.m()) == Some(3) {
            for (i, s) in cfg.servers.iter_mut().enumerate() {
                s.capacity.0[1] = if (i as u32) < gpus_total { 1.0 } else { 0.0 };
            }
        }
        Ok(cfg)
    }
}

/// Dorm's optimizer thresholds (§V-A-2 configurations).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DormConfig {
    /// θ₁: fairness-loss threshold.
    pub theta1: f64,
    /// θ₂: adjustment-overhead threshold.
    pub theta2: f64,
}

impl DormConfig {
    pub const DORM1: DormConfig = DormConfig { theta1: 0.2, theta2: 0.1 };
    pub const DORM2: DormConfig = DormConfig { theta1: 0.1, theta2: 0.2 };
    pub const DORM3: DormConfig = DormConfig { theta1: 0.1, theta2: 0.1 };

    pub fn named(name: &str) -> Result<Self> {
        Ok(match name {
            "dorm1" | "Dorm-1" => Self::DORM1,
            "dorm2" | "Dorm-2" => Self::DORM2,
            "dorm3" | "Dorm-3" => Self::DORM3,
            other => bail!("unknown Dorm config {other:?} (dorm1|dorm2|dorm3)"),
        })
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let c = DormConfig {
            theta1: doc.f64_or("dorm", "theta1", 0.1),
            theta2: doc.f64_or("dorm", "theta2", 0.1),
        };
        if !(0.0..=1.0).contains(&c.theta1) || !(0.0..=1.0).contains(&c.theta2) {
            bail!("theta1/theta2 must be in [0,1], got {c:?}");
        }
        Ok(c)
    }
}

/// Failure-domain knobs (`[fault.domains]`, `crate::fault::domains`,
/// DESIGN.md §14): the correlated-outage model and the topology the online
/// MTBF estimator ranks.
#[derive(Clone, Debug, PartialEq)]
pub struct DomainsConfig {
    /// Draw correlated whole-rack outages (on top of `[fault]` churn).
    pub enabled: bool,
    /// Servers per rack (contiguous grouping; ≥ 1).
    pub domain_size: usize,
    /// Mean time between whole-rack outages, hours, per rack.
    pub domain_mtbf_hours: f64,
    /// Mean rack repair time, hours.
    pub domain_mttr_hours: f64,
    /// Rack 0 fails this many times more often than the rest (≥ 1;
    /// 1 = homogeneous racks).  Heterogeneous reliability is what the
    /// online estimator learns and risk-aware placement exploits.
    pub hot_factor: f64,
    /// Consecutive racks per power domain (≥ 1).
    pub racks_per_power: usize,
    /// Apply the estimator's risk ranking to placement (the
    /// `SpreadCtx` tie-break + cell-routing penalty); off = risk-blind
    /// placement under the same correlated trace.
    pub risk_aware: bool,
}

impl Default for DomainsConfig {
    fn default() -> Self {
        DomainsConfig {
            enabled: false,
            domain_size: 4,
            domain_mtbf_hours: 2000.0,
            domain_mttr_hours: 1.0,
            hot_factor: 1.0,
            racks_per_power: 2,
            risk_aware: true,
        }
    }
}

impl DomainsConfig {
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        use crate::fault::model::{require_at_least, require_non_negative, require_positive};
        let d = DomainsConfig::default();
        let c = DomainsConfig {
            enabled: doc
                .get("fault.domains", "enabled")
                .and_then(|v| v.as_bool())
                .unwrap_or(d.enabled),
            domain_size: doc.u32_or("fault.domains", "domain_size", d.domain_size as u32)
                as usize,
            domain_mtbf_hours: doc
                .f64_or("fault.domains", "domain_mtbf_hours", d.domain_mtbf_hours),
            domain_mttr_hours: doc
                .f64_or("fault.domains", "domain_mttr_hours", d.domain_mttr_hours),
            hot_factor: doc.f64_or("fault.domains", "hot_factor", d.hot_factor),
            racks_per_power: doc
                .u32_or("fault.domains", "racks_per_power", d.racks_per_power as u32)
                as usize,
            risk_aware: doc
                .get("fault.domains", "risk_aware")
                .and_then(|v| v.as_bool())
                .unwrap_or(d.risk_aware),
        };
        require_at_least("[fault.domains].domain_size", c.domain_size as f64, 1.0)?;
        require_positive("[fault.domains].domain_mtbf_hours", c.domain_mtbf_hours)?;
        require_non_negative("[fault.domains].domain_mttr_hours", c.domain_mttr_hours)?;
        require_at_least("[fault.domains].hot_factor", c.hot_factor, 1.0)?;
        require_at_least("[fault.domains].racks_per_power", c.racks_per_power as f64, 1.0)?;
        Ok(c)
    }
}

/// Fault-tolerance knobs (`crate::fault`, DESIGN.md §8): liveness leases,
/// checkpoint cadence/retention, and the failure-injection model.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultConfig {
    /// Inject failures at all (off reproduces the paper's no-churn world).
    pub enabled: bool,
    /// Per-server mean time between failures, hours.
    pub mtbf_hours: f64,
    /// Per-server mean time to repair, hours.
    pub mttr_hours: f64,
    /// A server whose lease is older than this is declared dead.
    pub lease_timeout_hours: f64,
    /// Periodic checkpoint cadence (0 = checkpoint only on adjustment,
    /// the bare §III-C-2 protocol).
    pub ckpt_period_hours: f64,
    /// Keep only the newest N checkpoints per app (≥ 1).
    pub ckpt_retain: usize,
    /// Failure-trace RNG seed.
    pub seed: u64,
    /// Inject a *master* failure at this hour (0 = no master outage) —
    /// the DES defers every allocation decision from then until the
    /// standby takeover completes (DESIGN.md §11).
    pub master_fail_at_hours: f64,
    /// How long the standby takeover takes (lease detection + restore).
    pub master_takeover_hours: f64,
    /// Correlated failure-domain model (`[fault.domains]`).
    pub domains: DomainsConfig,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            enabled: false,
            // commodity-server churn scaled to the 24 h experiment
            mtbf_hours: 168.0,
            mttr_hours: 0.5,
            // 3 missed 12 s heartbeats
            lease_timeout_hours: 0.01,
            ckpt_period_hours: 0.0,
            ckpt_retain: 3,
            seed: 23,
            master_fail_at_hours: 0.0,
            master_takeover_hours: 0.05,
            domains: DomainsConfig::default(),
        }
    }
}

impl FaultConfig {
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let d = FaultConfig::default();
        let c = FaultConfig {
            enabled: doc
                .get("fault", "enabled")
                .and_then(|v| v.as_bool())
                .unwrap_or(d.enabled),
            mtbf_hours: doc.f64_or("fault", "mtbf_hours", d.mtbf_hours),
            mttr_hours: doc.f64_or("fault", "mttr_hours", d.mttr_hours),
            lease_timeout_hours: doc
                .f64_or("fault", "lease_timeout_hours", d.lease_timeout_hours),
            ckpt_period_hours: doc
                .f64_or("fault", "ckpt_period_hours", d.ckpt_period_hours),
            ckpt_retain: doc.u32_or("fault", "ckpt_retain", d.ckpt_retain as u32) as usize,
            seed: doc.f64_or("fault", "seed", d.seed as f64) as u64,
            master_fail_at_hours: doc
                .f64_or("fault", "master_fail_at_hours", d.master_fail_at_hours),
            master_takeover_hours: doc
                .f64_or("fault", "master_takeover_hours", d.master_takeover_hours),
            domains: DomainsConfig::from_doc(doc)?,
        };
        // typed [`crate::fault::FaultError`]s (not asserts/anyhow strings),
        // so a hostile `[fault]` section fails cleanly from the CLI
        use crate::fault::model::{require_at_least, require_non_negative, require_positive};
        require_positive("[fault].mtbf_hours", c.mtbf_hours)?;
        require_non_negative("[fault].mttr_hours", c.mttr_hours)?;
        require_positive("[fault].lease_timeout_hours", c.lease_timeout_hours)?;
        require_non_negative("[fault].ckpt_period_hours", c.ckpt_period_hours)?;
        require_at_least("[fault].ckpt_retain", c.ckpt_retain as f64, 1.0)?;
        require_non_negative("[fault].master_fail_at_hours", c.master_fail_at_hours)?;
        require_non_negative("[fault].master_takeover_hours", c.master_takeover_hours)?;
        Ok(c)
    }
}

/// Cell-sharding knobs (`crate::sched::cells`, DESIGN.md §12): how many
/// independently-solved cells the servers are partitioned into, and when
/// the root router migrates apps to re-level them.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CellsConfig {
    /// Number of cells (≥ 1).  1 = the unsharded single-engine path,
    /// bit-identical to a plain `DormPolicy` (`tests/cells.rs`).
    pub count: usize,
    /// Consider rebalancing every N scheduling events (≥ 1).
    pub rebalance_every: u64,
    /// Rebalance when max/min cell dominant-share utilization exceeds
    /// this ratio (≥ 1.0; higher = more tolerance, less churn).
    pub imbalance_threshold: f64,
}

impl Default for CellsConfig {
    fn default() -> Self {
        CellsConfig {
            count: 1,
            rebalance_every: 32,
            imbalance_threshold: 1.5,
        }
    }
}

impl CellsConfig {
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let d = CellsConfig::default();
        let c = CellsConfig {
            count: doc.u32_or("cells", "count", d.count as u32) as usize,
            rebalance_every: doc.u32_or("cells", "rebalance_every", d.rebalance_every as u32)
                as u64,
            imbalance_threshold: doc
                .f64_or("cells", "imbalance_threshold", d.imbalance_threshold),
        };
        if c.count == 0 {
            bail!("[cells].count must be >= 1");
        }
        if c.rebalance_every == 0 {
            bail!("[cells].rebalance_every must be >= 1");
        }
        if !(c.imbalance_threshold.is_finite() && c.imbalance_threshold >= 1.0) {
            bail!(
                "[cells].imbalance_threshold must be a finite ratio >= 1.0, got {}",
                c.imbalance_threshold
            );
        }
        Ok(c)
    }
}

/// Networked control-plane knobs (`crate::net`, DESIGN.md §9): where the
/// master listens, the frame-size limit both sides enforce, and the two
/// cadences of the live loop (slave heartbeats, master lease sweeps).
#[derive(Clone, Debug, PartialEq)]
pub struct NetConfig {
    /// Master bind address (`127.0.0.1:0` picks an ephemeral port).
    pub bind_addr: String,
    /// Maximum frame payload either side will send or accept, bytes.
    pub max_frame_bytes: usize,
    /// Slave heartbeat period, milliseconds.
    pub heartbeat_period_ms: u64,
    /// Socket read/write timeout, milliseconds (0 = block forever).  A
    /// half-sent frame is abandoned after this long, so a stalled peer
    /// cannot wedge a handler thread.
    pub io_timeout_ms: u64,
    /// Master-driven lease-sweep period, milliseconds (0 = the server
    /// never expires leases on its own; a client must send
    /// ExpireLeases).  Pair with `[fault].lease_timeout_hours`.
    pub lease_sweep_ms: u64,
    /// `FailoverTransport`: candidate-sweep rounds per call before the
    /// control plane is declared gone.  Together with
    /// `redial_backoff_ms` this must cover a standby takeover window
    /// (`[ha].master_lease_ms` plus restore time).
    pub redial_rounds: u64,
    /// `FailoverTransport`: pause between candidate sweeps, milliseconds.
    pub redial_backoff_ms: u64,
    /// Multiplexed-server worker threads (0 = auto: one per available
    /// core, capped at 8).  Each worker owns a share of the open
    /// connections and polls them round-robin, so N workers bound the
    /// master-lock contention regardless of connection count.
    pub workers: usize,
    /// Maximum simultaneous connections the server will hold open.
    /// Arrivals beyond the limit are answered with a typed
    /// `TooManyConnections` error and closed, never silently dropped.
    pub max_conns: usize,
    /// Coalesce heartbeats that arrive within one poll tick into a
    /// single lease-table update with at most one re-solve (DESIGN.md
    /// §15).  Disable to force one dispatch per heartbeat.
    pub coalesce_heartbeats: bool,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            bind_addr: "127.0.0.1:4600".into(),
            max_frame_bytes: 256 * 1024,
            heartbeat_period_ms: 500,
            io_timeout_ms: 5000,
            lease_sweep_ms: 0,
            // 24 x 250 ms = a 6 s takeover ride-out by default
            redial_rounds: 24,
            redial_backoff_ms: 250,
            workers: 0,
            max_conns: 1024,
            coalesce_heartbeats: true,
        }
    }
}

impl NetConfig {
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let d = NetConfig::default();
        let c = NetConfig {
            bind_addr: doc
                .get("net", "bind_addr")
                .and_then(|v| v.as_str().map(str::to_string))
                .unwrap_or(d.bind_addr),
            max_frame_bytes: doc.u32_or("net", "max_frame_bytes", d.max_frame_bytes as u32)
                as usize,
            heartbeat_period_ms: doc
                .u32_or("net", "heartbeat_period_ms", d.heartbeat_period_ms as u32)
                as u64,
            io_timeout_ms: doc.u32_or("net", "io_timeout_ms", d.io_timeout_ms as u32) as u64,
            lease_sweep_ms: doc.u32_or("net", "lease_sweep_ms", d.lease_sweep_ms as u32) as u64,
            redial_rounds: doc.u32_or("net", "redial_rounds", d.redial_rounds as u32) as u64,
            redial_backoff_ms: doc
                .u32_or("net", "redial_backoff_ms", d.redial_backoff_ms as u32)
                as u64,
            workers: doc.u32_or("net", "workers", d.workers as u32) as usize,
            max_conns: doc.u32_or("net", "max_conns", d.max_conns as u32) as usize,
            coalesce_heartbeats: doc
                .get("net", "coalesce_heartbeats")
                .and_then(|v| v.as_bool())
                .unwrap_or(d.coalesce_heartbeats),
        };
        // the smallest legal frame must fit a handshake/error response;
        // 64 B is already absurdly tight but still functional
        if c.max_frame_bytes < 64 {
            bail!("[net].max_frame_bytes must be >= 64, got {}", c.max_frame_bytes);
        }
        if c.heartbeat_period_ms == 0 {
            bail!("[net].heartbeat_period_ms must be >= 1");
        }
        if c.bind_addr.is_empty() {
            bail!("[net].bind_addr must be non-empty");
        }
        if c.redial_rounds == 0 {
            bail!("[net].redial_rounds must be >= 1");
        }
        if c.max_conns == 0 {
            bail!("[net].max_conns must be >= 1");
        }
        Ok(c)
    }
}

/// Master high-availability knobs (`crate::master::ha` + `crate::net::standby`,
/// DESIGN.md §11): the candidate master addresses clients re-dial, the
/// lease a standby holds over the primary, and the self-checkpoint
/// cadence.
#[derive(Clone, Debug, PartialEq)]
pub struct HaConfig {
    /// Arm master self-checkpointing (`dorm master --ha` forces it on).
    pub enabled: bool,
    /// Master addresses in dial order (primary first, then standbys).
    /// Clients (`dorm slave`, `dorm ctl`) walk this list on connection
    /// loss; empty = single-master, no failover.
    pub candidates: Vec<String>,
    /// A standby declares the primary dead after this long without a
    /// successful probe.
    pub master_lease_ms: u64,
    /// Standby probe cadence.
    pub probe_period_ms: u64,
    /// Full master snapshot every N mutating dispatches (WAL in between).
    pub snapshot_every: u64,
    /// Master snapshot files retained (≥ 1; older ones pruned).
    pub snapshots_retain: usize,
}

impl Default for HaConfig {
    fn default() -> Self {
        HaConfig {
            enabled: false,
            candidates: Vec::new(),
            master_lease_ms: 2000,
            probe_period_ms: 250,
            snapshot_every: 64,
            snapshots_retain: 3,
        }
    }
}

impl HaConfig {
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let d = HaConfig::default();
        let candidates = match doc.get("ha", "candidates") {
            None => d.candidates,
            Some(v) => {
                let Some(items) = v.as_array() else {
                    bail!("[ha].candidates must be an array of addresses");
                };
                let mut out = Vec::with_capacity(items.len());
                for item in items {
                    match item.as_str() {
                        Some(s) if !s.is_empty() => out.push(s.to_string()),
                        _ => bail!("[ha].candidates entries must be non-empty strings"),
                    }
                }
                out
            }
        };
        let c = HaConfig {
            enabled: doc
                .get("ha", "enabled")
                .and_then(|v| v.as_bool())
                .unwrap_or(d.enabled),
            candidates,
            master_lease_ms: doc.u32_or("ha", "master_lease_ms", d.master_lease_ms as u32)
                as u64,
            probe_period_ms: doc.u32_or("ha", "probe_period_ms", d.probe_period_ms as u32)
                as u64,
            snapshot_every: doc.u32_or("ha", "snapshot_every", d.snapshot_every as u32) as u64,
            snapshots_retain: doc
                .u32_or("ha", "snapshots_retain", d.snapshots_retain as u32)
                as usize,
        };
        if c.master_lease_ms == 0 {
            bail!("[ha].master_lease_ms must be >= 1");
        }
        if c.probe_period_ms == 0 {
            bail!("[ha].probe_period_ms must be >= 1");
        }
        if c.snapshot_every == 0 {
            bail!("[ha].snapshot_every must be >= 1");
        }
        if c.snapshots_retain == 0 {
            bail!("[ha].snapshots_retain must be >= 1 (never drop the newest)");
        }
        Ok(c)
    }
}

/// Simulation parameters (§V-A-3 workload + horizon).
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Simulation horizon in hours (paper: 24 h).
    pub horizon_hours: f64,
    /// Mean inter-arrival time in minutes (paper: 20 min).
    pub mean_interarrival_min: f64,
    /// Metric sampling period in minutes.
    pub sample_period_min: f64,
    /// RNG seed (workload + arrival order).
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            horizon_hours: 24.0,
            mean_interarrival_min: 20.0,
            sample_period_min: 5.0,
            seed: 17,
        }
    }
}

impl SimConfig {
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let d = SimConfig::default();
        Ok(SimConfig {
            horizon_hours: doc.f64_or("sim", "horizon_hours", d.horizon_hours),
            mean_interarrival_min: doc
                .f64_or("sim", "mean_interarrival_min", d.mean_interarrival_min),
            sample_period_min: doc.f64_or("sim", "sample_period_min", d.sample_period_min),
            seed: doc.f64_or("sim", "seed", d.seed as f64) as u64,
        })
    }
}

/// `[trace]` — trace replay (`dorm replay`, DESIGN.md §13).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceConfig {
    /// Bounded look-ahead of the streaming replay driver, records.
    pub buffer: usize,
    /// Open-loop timestamp multiplier (0.5 = replay 2× faster).
    pub time_scale: f64,
    /// Closed-loop sustained arrival rate per simulated hour
    /// (0 = open loop, use recorded timestamps).
    pub rate_per_hour: f64,
    /// Clamp on widths taken from trace instance-count columns.
    pub max_width: u32,
    /// Width used when a foreign schema has no instance-count column.
    pub default_width: u32,
    /// Live replay wall-clock pacing, milliseconds of real time per
    /// replayed hour (0 = as fast as the master admits).
    pub ms_per_hour: f64,
    /// Live replay in-flight window: past this many active apps the
    /// oldest is completed before the next submit.
    pub window: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            buffer: 4096,
            time_scale: 1.0,
            rate_per_hour: 0.0,
            max_width: 32,
            default_width: 8,
            ms_per_hour: 0.0,
            window: 64,
        }
    }
}

impl TraceConfig {
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let d = TraceConfig::default();
        let c = TraceConfig {
            buffer: doc.u32_or("trace", "buffer", d.buffer as u32) as usize,
            time_scale: doc.f64_or("trace", "time_scale", d.time_scale),
            rate_per_hour: doc.f64_or("trace", "rate_per_hour", d.rate_per_hour),
            max_width: doc.u32_or("trace", "max_width", d.max_width),
            default_width: doc.u32_or("trace", "default_width", d.default_width),
            ms_per_hour: doc.f64_or("trace", "ms_per_hour", d.ms_per_hour),
            window: doc.u32_or("trace", "window", d.window as u32) as usize,
        };
        if c.buffer == 0 {
            bail!("[trace].buffer must be >= 1");
        }
        if !(c.time_scale > 0.0 && c.time_scale.is_finite()) {
            bail!("[trace].time_scale must be finite and > 0");
        }
        if !(c.rate_per_hour >= 0.0 && c.rate_per_hour.is_finite()) {
            bail!("[trace].rate_per_hour must be finite and >= 0");
        }
        if c.max_width == 0 || c.default_width == 0 {
            bail!("[trace].max_width and default_width must be >= 1");
        }
        if c.default_width > c.max_width {
            bail!("[trace].default_width must not exceed max_width");
        }
        if !(c.ms_per_hour >= 0.0 && c.ms_per_hour.is_finite()) {
            bail!("[trace].ms_per_hour must be finite and >= 0");
        }
        if c.window == 0 {
            bail!("[trace].window must be >= 1");
        }
        Ok(c)
    }

    /// The schema-layer view of these knobs.
    pub fn schema_defaults(&self) -> crate::workload::trace::SchemaDefaults {
        crate::workload::trace::SchemaDefaults {
            max_width: self.max_width,
            default_width: self.default_width,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse::parse_toml;

    #[test]
    fn paper_testbed_totals() {
        let c = ClusterConfig::paper_testbed();
        assert_eq!(c.servers.len(), 20);
        let total = c.total_capacity();
        assert_eq!(total, Res::cpu_gpu_ram(240.0, 5.0, 2560.0));
    }

    #[test]
    fn dorm_named_configs_match_paper() {
        assert_eq!(DormConfig::named("dorm1").unwrap(), DormConfig { theta1: 0.2, theta2: 0.1 });
        assert_eq!(DormConfig::named("dorm2").unwrap(), DormConfig { theta1: 0.1, theta2: 0.2 });
        assert_eq!(DormConfig::named("dorm3").unwrap(), DormConfig { theta1: 0.1, theta2: 0.1 });
        assert!(DormConfig::named("dorm9").is_err());
    }

    #[test]
    fn cluster_from_doc() {
        let doc = parse_toml(
            "[cluster]\nslaves = 4\ncapacity_per_slave = [12, 0, 128]\ngpus_total = 2\n",
        )
        .unwrap();
        let c = ClusterConfig::from_doc(&doc).unwrap();
        assert_eq!(c.servers.len(), 4);
        assert_eq!(c.total_capacity(), Res::cpu_gpu_ram(48.0, 2.0, 512.0));
    }

    #[test]
    fn dorm_from_doc_validates_range() {
        let ok = parse_toml("[dorm]\ntheta1 = 0.2\ntheta2 = 0.1\n").unwrap();
        assert_eq!(DormConfig::from_doc(&ok).unwrap(), DormConfig::DORM1);
        let bad = parse_toml("[dorm]\ntheta1 = 1.5\n").unwrap();
        assert!(DormConfig::from_doc(&bad).is_err());
    }

    #[test]
    fn trace_section_parses_and_validates() {
        let doc = parse_toml(
            "[trace]\nbuffer = 512\ntime_scale = 0.5\nrate_per_hour = 1000\n\
             max_width = 16\nwindow = 32\n",
        )
        .unwrap();
        let c = TraceConfig::from_doc(&doc).unwrap();
        assert_eq!(c.buffer, 512);
        assert_eq!(c.time_scale, 0.5);
        assert_eq!(c.rate_per_hour, 1000.0);
        assert_eq!(c.max_width, 16);
        assert_eq!(c.window, 32);
        assert_eq!(c.schema_defaults().max_width, 16);

        // defaults when the section is absent
        let empty = parse_toml("").unwrap();
        assert_eq!(TraceConfig::from_doc(&empty).unwrap(), TraceConfig::default());

        // invalid values rejected
        for bad in [
            "[trace]\nbuffer = 0\n",
            "[trace]\ntime_scale = 0\n",
            "[trace]\nrate_per_hour = -5\n",
            "[trace]\nmax_width = 0\n",
            "[trace]\ndefault_width = 64\nmax_width = 32\n",
            "[trace]\nwindow = 0\n",
        ] {
            let doc = parse_toml(bad).unwrap();
            assert!(TraceConfig::from_doc(&doc).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn cells_section_parses_and_validates() {
        let doc = parse_toml(
            "[cells]\ncount = 4\nrebalance_every = 16\nimbalance_threshold = 2.0\n",
        )
        .unwrap();
        let c = CellsConfig::from_doc(&doc).unwrap();
        assert_eq!(c.count, 4);
        assert_eq!(c.rebalance_every, 16);
        assert_eq!(c.imbalance_threshold, 2.0);

        // defaults when the section is absent
        let empty = parse_toml("").unwrap();
        assert_eq!(CellsConfig::from_doc(&empty).unwrap(), CellsConfig::default());
        assert_eq!(CellsConfig::default().count, 1, "unsharded by default");

        // invalid values rejected
        for bad in [
            "[cells]\ncount = 0\n",
            "[cells]\nrebalance_every = 0\n",
            "[cells]\nimbalance_threshold = 0.5\n",
        ] {
            let doc = parse_toml(bad).unwrap();
            assert!(CellsConfig::from_doc(&doc).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn fault_section_parses_and_validates() {
        let doc = parse_toml(
            "[fault]\nenabled = true\nmtbf_hours = 8\nmttr_hours = 0.25\n\
             lease_timeout_hours = 0.02\nckpt_period_hours = 0.5\nckpt_retain = 2\nseed = 5\n",
        )
        .unwrap();
        let c = FaultConfig::from_doc(&doc).unwrap();
        assert!(c.enabled);
        assert_eq!(c.mtbf_hours, 8.0);
        assert_eq!(c.mttr_hours, 0.25);
        assert_eq!(c.ckpt_retain, 2);
        assert_eq!(c.seed, 5);

        // defaults when the section is absent
        let empty = parse_toml("").unwrap();
        assert_eq!(FaultConfig::from_doc(&empty).unwrap(), FaultConfig::default());

        // invalid values rejected
        for bad in [
            "[fault]\nmtbf_hours = 0\n",
            "[fault]\nmttr_hours = -1\n",
            "[fault]\nlease_timeout_hours = 0\n",
            "[fault]\nckpt_retain = 0\n",
        ] {
            let doc = parse_toml(bad).unwrap();
            assert!(FaultConfig::from_doc(&doc).is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn net_section_parses_and_validates() {
        let doc = parse_toml(
            "[net]\nbind_addr = \"0.0.0.0:7000\"\nmax_frame_bytes = 4096\n\
             heartbeat_period_ms = 100\nio_timeout_ms = 250\nlease_sweep_ms = 50\n\
             workers = 4\nmax_conns = 128\ncoalesce_heartbeats = false\n",
        )
        .unwrap();
        let c = NetConfig::from_doc(&doc).unwrap();
        assert_eq!(c.bind_addr, "0.0.0.0:7000");
        assert_eq!(c.max_frame_bytes, 4096);
        assert_eq!(c.heartbeat_period_ms, 100);
        assert_eq!(c.io_timeout_ms, 250);
        assert_eq!(c.lease_sweep_ms, 50);
        assert_eq!(c.workers, 4);
        assert_eq!(c.max_conns, 128);
        assert!(!c.coalesce_heartbeats);

        // defaults when the section is absent
        let empty = parse_toml("").unwrap();
        assert_eq!(NetConfig::from_doc(&empty).unwrap(), NetConfig::default());

        for bad in [
            "[net]\nmax_frame_bytes = 16\n",
            "[net]\nheartbeat_period_ms = 0\n",
            "[net]\nbind_addr = \"\"\n",
            "[net]\nmax_conns = 0\n",
        ] {
            let doc = parse_toml(bad).unwrap();
            assert!(NetConfig::from_doc(&doc).is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn ha_section_parses_and_validates() {
        let doc = parse_toml(
            "[ha]\nenabled = true\n\
             candidates = [\"127.0.0.1:4600\", \"127.0.0.1:4601\"]\n\
             master_lease_ms = 1500\nprobe_period_ms = 100\n\
             snapshot_every = 16\nsnapshots_retain = 2\n",
        )
        .unwrap();
        let c = HaConfig::from_doc(&doc).unwrap();
        assert!(c.enabled);
        assert_eq!(c.candidates, vec!["127.0.0.1:4600", "127.0.0.1:4601"]);
        assert_eq!(c.master_lease_ms, 1500);
        assert_eq!(c.probe_period_ms, 100);
        assert_eq!(c.snapshot_every, 16);
        assert_eq!(c.snapshots_retain, 2);

        // defaults when the section is absent
        let empty = parse_toml("").unwrap();
        assert_eq!(HaConfig::from_doc(&empty).unwrap(), HaConfig::default());

        for bad in [
            "[ha]\nmaster_lease_ms = 0\n",
            "[ha]\nprobe_period_ms = 0\n",
            "[ha]\nsnapshot_every = 0\n",
            "[ha]\nsnapshots_retain = 0\n",
            "[ha]\ncandidates = \"not-an-array\"\n",
            "[ha]\ncandidates = [\"\"]\n",
        ] {
            let doc = parse_toml(bad).unwrap();
            assert!(HaConfig::from_doc(&doc).is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn fault_master_outage_knobs_parse() {
        let doc = parse_toml(
            "[fault]\nmaster_fail_at_hours = 2.5\nmaster_takeover_hours = 0.1\n",
        )
        .unwrap();
        let c = FaultConfig::from_doc(&doc).unwrap();
        assert_eq!(c.master_fail_at_hours, 2.5);
        assert_eq!(c.master_takeover_hours, 0.1);
        for bad in [
            "[fault]\nmaster_fail_at_hours = -1\n",
            "[fault]\nmaster_takeover_hours = -0.5\n",
        ] {
            let doc = parse_toml(bad).unwrap();
            assert!(FaultConfig::from_doc(&doc).is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn fault_domains_section_parses_and_validates() {
        let doc = parse_toml(
            "[fault]\nenabled = true\n[fault.domains]\nenabled = true\n\
             domain_size = 4\ndomain_mtbf_hours = 12\ndomain_mttr_hours = 0.5\n\
             hot_factor = 4\nracks_per_power = 2\nrisk_aware = false\n",
        )
        .unwrap();
        let c = FaultConfig::from_doc(&doc).unwrap();
        assert!(c.domains.enabled);
        assert_eq!(c.domains.domain_size, 4);
        assert_eq!(c.domains.domain_mtbf_hours, 12.0);
        assert_eq!(c.domains.domain_mttr_hours, 0.5);
        assert_eq!(c.domains.hot_factor, 4.0);
        assert_eq!(c.domains.racks_per_power, 2);
        assert!(!c.domains.risk_aware);

        // defaults when the subsection is absent (and risk-aware by default)
        let empty = parse_toml("").unwrap();
        let d = FaultConfig::from_doc(&empty).unwrap();
        assert_eq!(d.domains, DomainsConfig::default());
        assert!(!d.domains.enabled);
        assert!(d.domains.risk_aware);

        // invalid values surface as typed FaultError, not a panic
        for bad in [
            "[fault.domains]\ndomain_size = 0\n",
            "[fault.domains]\ndomain_mtbf_hours = 0\n",
            "[fault.domains]\ndomain_mttr_hours = -1\n",
            "[fault.domains]\nhot_factor = 0.5\n",
            "[fault.domains]\nracks_per_power = 0\n",
        ] {
            let doc = parse_toml(bad).unwrap();
            let err = FaultConfig::from_doc(&doc).unwrap_err();
            assert!(
                err.downcast_ref::<crate::fault::FaultError>().is_some(),
                "{bad:?}: not a FaultError: {err}"
            );
        }
    }

    #[test]
    fn sim_defaults() {
        let doc = parse_toml("").unwrap();
        let s = SimConfig::from_doc(&doc).unwrap();
        assert_eq!(s.horizon_hours, 24.0);
        assert_eq!(s.mean_interarrival_min, 20.0);
    }
}

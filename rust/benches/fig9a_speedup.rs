//! Fig. 9(a) reproduction: application speedup ratio vs the static
//! baseline, per model type (matched pairs — same app under both systems).
//!
//! Paper headline (§V-B-4): Dorm-1/2/3 speed applications up by ×2.79 /
//! ×2.73 / ×2.72 on average.

#[path = "harness/mod.rs"]
mod harness;

use dorm::report;
use dorm::sim::{mean_speedup, speedup_by_tag, Experiment};

fn main() {
    harness::banner("Fig. 9a — application speedup vs static baseline");
    let exp = Experiment::paper(17);
    let runs = exp.run_all();
    let (baseline, dorms) = runs.split_first().unwrap();

    // per-tag table (the Fig. 9a bars), one column per Dorm config
    let tags: Vec<String> = speedup_by_tag(&dorms[0], baseline)
        .into_iter()
        .map(|(t, _)| t)
        .collect();
    let mut rows = Vec::new();
    for tag in &tags {
        let mut row = vec![tag.clone()];
        for d in dorms {
            let by = speedup_by_tag(d, baseline);
            let v = by
                .iter()
                .find(|(t, _)| t == tag)
                .map(|&(_, s)| format!("{s:.2}x"))
                .unwrap_or_else(|| "-".into());
            row.push(v);
        }
        rows.push(row);
    }
    println!(
        "{}",
        report::table(&["model", "Dorm-1", "Dorm-2", "Dorm-3"], &rows)
    );

    let paper = ["2.79x", "2.73x", "2.72x"];
    for (d, p) in dorms.iter().zip(paper) {
        harness::paper_row(
            &format!("mean speedup ({})", d.label),
            p,
            &format!("{:.2}x", mean_speedup(d, baseline)),
        );
    }
    harness::paper_row(
        "Dorm consistently faster than baseline",
        "yes",
        if dorms.iter().all(|d| mean_speedup(d, baseline) > 1.0) {
            "yes"
        } else {
            "no"
        },
    );
}

//! The Swarm baseline (§V-A-4): statically sized partitions.
//!
//! Each app type has a fixed container count; an arriving app is admitted
//! iff its full fixed partition can be placed right now, otherwise it waits
//! in FIFO order.  Allocations are never adjusted — exactly the "app-level
//! static sharing" behaviour §II-C attributes to existing CMSs.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::cluster::{place, PlacementInput, ServerId};
use crate::sched::{AllocationUpdate, CmsPolicy, SchedCtx};

/// Swarm-like static allocator.
#[derive(Debug, Default)]
pub struct StaticPolicy {
    _private: (),
}

impl StaticPolicy {
    pub fn new() -> Self {
        StaticPolicy { _private: () }
    }
}

impl CmsPolicy for StaticPolicy {
    fn name(&self) -> String {
        "static".into()
    }

    fn on_change(&mut self, ctx: &SchedCtx) -> Option<AllocationUpdate> {
        // running apps stay pinned exactly as they are
        let mut assignment: BTreeMap<_, BTreeMap<ServerId, u32>> = BTreeMap::new();
        let mut pinned: Vec<PlacementInput> = Vec::new();
        for app in ctx.apps.values() {
            if app.containers > 0 {
                let cur = app.placement.clone();
                assignment.insert(app.id, cur.clone());
                pinned.push(PlacementInput {
                    app: app.id,
                    demand: app.demand.clone(),
                    target: app.containers,
                    current: cur,
                });
            }
        }

        // pending apps admitted FIFO (by submit time) if the full fixed
        // partition fits
        let mut pending: Vec<_> = ctx
            .apps
            .values()
            .filter(|a| a.containers == 0)
            .collect();
        pending.sort_by(|a, b| a.submit.total_cmp(&b.submit));

        for app in pending {
            let mut inputs = pinned.clone();
            inputs.push(PlacementInput {
                app: app.id,
                demand: app.demand.clone(),
                target: app.baseline_n,
                current: BTreeMap::new(),
            });
            if let Some(p) = place(&inputs, ctx.capacities) {
                let placed = p.assignment[&app.id].clone();
                pinned.push(PlacementInput {
                    app: app.id,
                    demand: app.demand.clone(),
                    target: app.baseline_n,
                    current: placed.clone(),
                });
                assignment.insert(app.id, placed);
            }
            // head-of-line blocking is intentional? No: Swarm admits any
            // app that fits (others keep waiting), so continue scanning.
        }

        Some(AllocationUpdate { assignment: Arc::new(assignment), adjusted: vec![] })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, SimConfig};
    use crate::sim::{run_sim, PerfModel};
    use crate::workload::{table2_rows, WorkloadApp};

    #[test]
    fn admits_when_fits_queues_when_not() {
        // cluster fits exactly one LR partition (8 x <2,0,8>)
        let rows = table2_rows();
        let wl = vec![
            WorkloadApp { row: 0, tag: "LR".into(), submit_hours: 0.0,
                duration_at_baseline_hours: 1.0, baseline_n: 8 },
            WorkloadApp { row: 0, tag: "LR".into(), submit_hours: 0.1,
                duration_at_baseline_hours: 1.0, baseline_n: 8 },
        ];
        let cfg = ClusterConfig::uniform(
            2,
            crate::resources::Res::cpu_gpu_ram(8.0, 0.0, 64.0),
        );
        let sim = SimConfig { horizon_hours: 5.0, ..Default::default() };
        let mut pol = StaticPolicy::new();
        let out = run_sim(&mut pol, &rows, &wl, &cfg, &sim, &PerfModel::default());
        assert_eq!(out.completed, 2);
        // second app had to wait for the first -> duration from submit
        // is ~1.0 (first) and ~1.9 (second waited 0.9h)
        let durs: Vec<f64> = out.metrics.completions.iter().map(|&(_, d)| d).collect();
        assert!((durs[0] - 1.0).abs() < 1e-6);
        assert!(durs[1] > 1.5, "queued app should wait, got {}", durs[1]);
    }

    #[test]
    fn never_adjusts() {
        let rows = table2_rows();
        let wl: Vec<WorkloadApp> = (0..6)
            .map(|i| WorkloadApp {
                row: 0,
                tag: "LR".into(),
                submit_hours: i as f64 * 0.2,
                duration_at_baseline_hours: 1.0,
                baseline_n: 4,
            })
            .collect();
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 6.0, ..Default::default() };
        let mut pol = StaticPolicy::new();
        let out = run_sim(&mut pol, &rows, &wl, &cfg, &sim, &PerfModel::default());
        assert_eq!(out.metrics.adjustments.last(), Some(0.0));
        assert!(out.metrics.adjustment_batch_sizes.is_empty());
    }
}

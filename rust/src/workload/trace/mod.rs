//! Trace-driven workloads (DESIGN.md §13): parse recorded job-arrival
//! traces and stream them — without ever materializing them — through the
//! DES ([`driver::replay_des`]) or a live master over the control plane
//! ([`driver::replay_live`], [`driver::rate_sweep`]).
//!
//! Layering:
//!
//! * [`schema`] — [`TraceRecord`] + the schema-adapter layer mapping
//!   foreign CSV column layouts (Alibaba-like, Borg-like) and the native
//!   export layout onto one internal record, with typed [`TraceError`]s
//!   for every malformed input.
//! * [`reader`] — [`TraceReader`], a line-at-a-time iterator of validated
//!   records over any `BufRead` (file, socket, in-memory buffer).
//! * [`export`] — write synthesized workloads back out in the native
//!   schema, losslessly (`dorm replay --export`).
//! * [`driver`] — the bounded-buffer [`TraceSource`] adapter into the
//!   simulator's `ArrivalSource` seam plus the replay entry points the
//!   `dorm replay` verb calls.
//!
//! Memory discipline: every stage is an iterator; the only buffering
//! between a trace file and the DES/master is [`TraceSource`]'s bounded
//! look-ahead (`[trace] buffer`), whose high-water mark is asserted in
//! `tests/trace.rs` against a 100k-arrival trace.

pub mod driver;
pub mod export;
pub mod reader;
pub mod schema;

pub use driver::{
    rate_sweep, replay_des, replay_live, DesReplayReport, LiveOpts, LiveReplayReport,
    RatePoint, ReplayOpts, TraceSource,
};
pub use export::{export_workload, record_line, record_of, write_records, DORM_HEADER};
pub use reader::TraceReader;
pub use schema::{
    SchemaAdapter, SchemaDefaults, TraceError, TraceRecord, TraceSchema, BORG_MACHINE,
};

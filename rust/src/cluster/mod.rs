//! Cluster model: servers (DormSlaves' resource views), per-application
//! partitions, and the placement bookkeeping shared by the real runtime
//! ([`crate::master`]) and the simulator ([`crate::sim`]).
//!
//! A *partition* (§III-A) is the set of containers an application owns; a
//! *container* is a uniform resource bundle `d` on one server.  State here
//! is pure bookkeeping — actually starting/stopping work is the slaves'
//! job — which is what lets the simulator and the live master share it.

mod placement;

pub use placement::{
    place, place_delta, place_spread, Assignment, PackState, Placement, PlacementInput,
    SpreadCtx,
};

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::app::AppId;
use crate::config::ClusterConfig;
use crate::resources::Res;

/// Index into the cluster's server list.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ServerId(pub usize);

/// One server's live allocation state.
#[derive(Clone, Debug)]
pub struct Server {
    pub name: String,
    pub capacity: Res,
    /// Containers per application on this server (the paper's xᵢⱼ).
    pub containers: BTreeMap<AppId, u32>,
}

impl Server {
    /// Resources currently committed on this server.
    pub fn used(&self, demands: &BTreeMap<AppId, Res>) -> Res {
        let mut used = Res::zeros(self.capacity.m());
        for (app, &count) in &self.containers {
            if let Some(d) = demands.get(app) {
                used += &d.times(count);
            }
        }
        used
    }

    pub fn free(&self, demands: &BTreeMap<AppId, Res>) -> Res {
        self.capacity.saturating_sub(&self.used(demands))
    }
}

/// Whole-cluster allocation state: servers + per-app demand vectors.
#[derive(Clone, Debug)]
pub struct ClusterState {
    pub servers: Vec<Server>,
    /// Demand vector per admitted application (uniform per container,
    /// §III-A-4).
    pub demands: BTreeMap<AppId, Res>,
}

impl ClusterState {
    pub fn new(cfg: &ClusterConfig) -> Self {
        ClusterState {
            servers: cfg
                .servers
                .iter()
                .map(|s| Server {
                    name: s.name.clone(),
                    capacity: s.capacity.clone(),
                    containers: BTreeMap::new(),
                })
                .collect(),
            demands: BTreeMap::new(),
        }
    }

    pub fn total_capacity(&self) -> Res {
        let m = self.servers.first().map(|s| s.capacity.m()).unwrap_or(0);
        self.servers.iter().fold(Res::zeros(m), |mut acc, s| {
            acc += &s.capacity;
            acc
        })
    }

    /// Register an application's demand vector (at admission).
    pub fn register_app(&mut self, app: AppId, demand: Res) {
        self.demands.insert(app, demand);
    }

    /// Drop an application and all its containers (at completion).
    pub fn remove_app(&mut self, app: AppId) {
        self.demands.remove(&app);
        for s in &mut self.servers {
            s.containers.remove(&app);
        }
    }

    /// Create `count` containers of `app` on `server`, enforcing capacity.
    pub fn create_containers(&mut self, app: AppId, server: ServerId, count: u32) -> Result<()> {
        let Some(demand) = self.demands.get(&app).cloned() else {
            bail!("{app} has no registered demand");
        };
        let s = &mut self.servers[server.0];
        let mut used = Res::zeros(s.capacity.m());
        for (a, &c) in &s.containers {
            used += &self.demands[a].times(c);
        }
        used += &demand.times(count);
        if !used.fits_in(&s.capacity) {
            bail!(
                "capacity exceeded on {}: used {used:?} > cap {:?}",
                s.name,
                s.capacity
            );
        }
        *s.containers.entry(app).or_insert(0) += count;
        Ok(())
    }

    /// Destroy `count` containers of `app` on `server`.
    pub fn destroy_containers(&mut self, app: AppId, server: ServerId, count: u32) -> Result<()> {
        let s = &mut self.servers[server.0];
        let have = s.containers.get(&app).copied().unwrap_or(0);
        if have < count {
            bail!("{app} has only {have} containers on {}, asked {count}", s.name);
        }
        if have == count {
            s.containers.remove(&app);
        } else {
            *s.containers.get_mut(&app).unwrap() -= count;
        }
        Ok(())
    }

    /// The paper's xᵢⱼ row for one application.
    pub fn placement_of(&self, app: AppId) -> BTreeMap<ServerId, u32> {
        self.servers
            .iter()
            .enumerate()
            .filter_map(|(j, s)| {
                s.containers
                    .get(&app)
                    .map(|&c| (ServerId(j), c))
                    .filter(|&(_, c)| c > 0)
            })
            .collect()
    }

    /// Σⱼ xᵢⱼ.
    pub fn container_count(&self, app: AppId) -> u32 {
        self.servers
            .iter()
            .map(|s| s.containers.get(&app).copied().unwrap_or(0))
            .sum()
    }

    /// Cluster-wide usage vector (numerator of Eq. 1).
    pub fn total_used(&self) -> Res {
        let m = self.total_capacity().m();
        self.servers.iter().fold(Res::zeros(m), |mut acc, s| {
            acc += &s.used(&self.demands);
            acc
        })
    }

    /// Eq. 1: ResourceUtilization(t) = Σₖ uₖ — ranges in [0, m].
    pub fn utilization(&self) -> f64 {
        self.total_used().utilization_sum(&self.total_capacity())
    }

    /// Application `i`'s actual dominant share sᵢ (Table I).
    pub fn dominant_share(&self, app: AppId) -> f64 {
        match self.demands.get(&app) {
            Some(d) => d
                .times(self.container_count(app))
                .dominant_share(&self.total_capacity()),
            None => 0.0,
        }
    }

    /// Sanity invariant: every server within capacity (debug builds assert
    /// this after each adjustment; also property-tested).
    pub fn check_invariants(&self) -> Result<()> {
        for s in &self.servers {
            let used = s.used(&self.demands);
            if !used.fits_in(&s.capacity) {
                bail!("invariant violated: {} over capacity ({used:?})", s.name);
            }
        }
        for s in &self.servers {
            for app in s.containers.keys() {
                if !self.demands.contains_key(app) {
                    bail!("invariant violated: {} hosts unregistered {app}", s.name);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ClusterState {
        ClusterState::new(&ClusterConfig::uniform(2, Res::cpu_gpu_ram(8.0, 1.0, 64.0)))
    }

    #[test]
    fn create_destroy_roundtrip() {
        let mut cs = small();
        let a = AppId(1);
        cs.register_app(a, Res::cpu_gpu_ram(2.0, 0.0, 8.0));
        cs.create_containers(a, ServerId(0), 3).unwrap();
        assert_eq!(cs.container_count(a), 3);
        cs.destroy_containers(a, ServerId(0), 2).unwrap();
        assert_eq!(cs.container_count(a), 1);
        cs.check_invariants().unwrap();
    }

    #[test]
    fn capacity_enforced() {
        let mut cs = small();
        let a = AppId(1);
        cs.register_app(a, Res::cpu_gpu_ram(2.0, 0.0, 8.0));
        assert!(cs.create_containers(a, ServerId(0), 5).is_err()); // 10 CPU > 8
        cs.create_containers(a, ServerId(0), 4).unwrap();
        assert!(cs.create_containers(a, ServerId(0), 1).is_err());
    }

    #[test]
    fn destroy_more_than_held_fails() {
        let mut cs = small();
        let a = AppId(1);
        cs.register_app(a, Res::cpu_gpu_ram(1.0, 0.0, 1.0));
        cs.create_containers(a, ServerId(0), 1).unwrap();
        assert!(cs.destroy_containers(a, ServerId(0), 2).is_err());
        assert!(cs.destroy_containers(a, ServerId(1), 1).is_err());
    }

    #[test]
    fn utilization_eq1() {
        let mut cs = small(); // totals: 16 cpu, 2 gpu, 128 ram
        let a = AppId(1);
        cs.register_app(a, Res::cpu_gpu_ram(4.0, 1.0, 32.0));
        cs.create_containers(a, ServerId(0), 1).unwrap();
        cs.create_containers(a, ServerId(1), 1).unwrap();
        // u = 8/16 + 2/2 + 64/128 = 0.5 + 1 + 0.5 = 2.0
        assert!((cs.utilization() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn dominant_share_tracks_gpu() {
        let mut cs = small();
        let a = AppId(1);
        cs.register_app(a, Res::cpu_gpu_ram(1.0, 1.0, 8.0));
        cs.create_containers(a, ServerId(0), 1).unwrap();
        // shares: 1/16 cpu, 1/2 gpu, 8/128 ram -> dominant 0.5
        assert!((cs.dominant_share(a) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn remove_app_clears_everything() {
        let mut cs = small();
        let a = AppId(1);
        cs.register_app(a, Res::cpu_gpu_ram(1.0, 0.0, 1.0));
        cs.create_containers(a, ServerId(0), 2).unwrap();
        cs.remove_app(a);
        assert_eq!(cs.container_count(a), 0);
        assert_eq!(cs.utilization(), 0.0);
        cs.check_invariants().unwrap();
    }

    #[test]
    fn placement_of_lists_only_nonzero() {
        let mut cs = small();
        let a = AppId(1);
        cs.register_app(a, Res::cpu_gpu_ram(1.0, 0.0, 1.0));
        cs.create_containers(a, ServerId(1), 2).unwrap();
        let p = cs.placement_of(a);
        assert_eq!(p.len(), 1);
        assert_eq!(p[&ServerId(1)], 2);
    }
}
